//! Minimal HTTP/1.1 front-end on `std::net::TcpListener`.
//!
//! Endpoints:
//! * `POST /predict` — body `{"model": "<name>", "features": [f32...]}`
//!   (`model` optional when exactly one model is registered). The request
//!   is admitted to the batching queue and the handler blocks on its
//!   one-shot channel; reply `{"model", "prediction", "batch_size",
//!   "latency_ms", "request_id"}`.
//! * `GET /models`  — registry listing with storage stats, alias/version
//!   fields and swap/eviction totals.
//! * `POST /models` — control plane (DESIGN.md §13): body
//!   `{"name": "alias@version", "lazy": false}` verifies the bundle's
//!   HMAC signature + per-file sha256 through the attached repo, loads
//!   it, and repoints the alias (drain-then-swap: in-flight requests
//!   finish on the old version). `409`/`bundle_rejected` on any
//!   signature/digest/parse failure — nothing registers;
//!   `409`/`swap_in_progress` while another swap owns the alias.
//! * `DELETE /models/<name>` — drop an alias (or one `alias@version`
//!   slot); in-flight requests drain on their `Arc`, memory frees when
//!   the last reference drops. `409` while a swap is in progress.
//! * `GET /metrics` — latency percentiles, queue depth, served-batch-size
//!   histogram, throughput ([`ServeMetrics::snapshot`]); add
//!   `?format=prometheus` for the text exposition
//!   ([`ServeMetrics::prometheus`] plus pool/kernel counters).
//! * `GET /models/<name>/profile` — per-layer stage timing aggregated
//!   from traced forwards ([`trace::Profile`]); empty until the trace
//!   dial (`FLEXOR_TRACE` / [`ServeConfig::trace`]) samples a forward in.
//! * `GET /healthz` — liveness (the process answers).
//! * `GET /readyz` — readiness: `503` while draining or while no worker
//!   is alive, `200` otherwise (DESIGN.md §12).
//!
//! Every request carries an id: `X-Request-Id` is honored when the
//! client sends one (sanitized), generated otherwise, echoed back as a
//! response header, and included in predict/error JSON bodies — so a
//! client-reported failure can be joined against the server's
//! structured log lines ([`trace::log`]).
//!
//! Failure model (DESIGN.md §12): every non-2xx body carries a stable
//! machine-readable `code` ([`ErrorCode`]). Requests may carry an
//! `X-Deadline-Ms` budget (default [`ServeConfig::default_deadline_ms`] /
//! `FLEXOR_DEADLINE_MS`); a request still queued past its deadline is
//! shed with `503`/`deadline_exceeded` instead of computed. Overload
//! degrades to fast `503`s with a `Retry-After` hint (non-blocking
//! admission); bodies beyond the byte bound
//! ([`ServeConfig::max_body_bytes`] / `FLEXOR_MAX_BODY_BYTES`) get `413`
//! without buffering. Shutdown is graceful: mark draining (late
//! arrivals get `503`/`draining`), stop accepting, drain the queue,
//! join the workers.
//!
//! One thread per connection with keep-alive — plenty for the loopback /
//! benchmark traffic this repo drives today; the accept loop is the
//! obvious seam for a future acceptor/reactor upgrade.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, OnceLock};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::error::ErrorCode;
use super::metrics::ServeMetrics;
use super::queue::{BatchQueue, PushError};
use super::registry::{ControlError, Registry};
use super::worker::{Request, WorkerPool};
use crate::inference::bitslice::popcount;
use crate::substrate::json::{self, Json};
use crate::substrate::pool;
use crate::substrate::trace::{self, Level};

const CT_JSON: &str = "application/json";
const CT_PROM: &str = "text/plain; version=0.0.4";

/// Serving policy knobs. Compute-engine selection is *not* here: it is
/// a property of the registry the caller builds and hands to
/// [`Server::start`] — `Registry::with_default_policy` /
/// `Registry::load_with_policy` (per-layer `ModePolicy`, DESIGN.md §9),
/// as `examples/serve.rs` does.
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// Worker threads draining the queue.
    pub workers: usize,
    /// Most requests coalesced into one forward pass.
    pub max_batch: usize,
    /// How long a worker lingers for a fuller batch after the first
    /// request arrives (µs). The latency/throughput trade-off dial.
    pub max_wait_us: u64,
    /// Admission queue bound; beyond it requests get `503`.
    pub queue_capacity: usize,
    /// Intra-op threads each forward pass shards its GEMMs across
    /// (the substrate compute pool, DESIGN.md §7). `0` = auto:
    /// `available_parallelism / workers`, so worker-level and GEMM-level
    /// parallelism compose instead of oversubscribing the machine.
    pub intra_threads: usize,
    /// Stage-tracing dial for served forwards. `None` (default) defers
    /// to the `FLEXOR_TRACE` env var; tests and embedders set an explicit
    /// mode so they never touch process-global env state.
    pub trace: Option<trace::TraceMode>,
    /// Default per-request deadline in ms applied when the client sends
    /// no `X-Deadline-Ms` header. `None` (default) defers to the
    /// `FLEXOR_DEADLINE_MS` env var; unset/0 = no default deadline.
    pub default_deadline_ms: Option<u64>,
    /// Request body byte bound; larger bodies get `413` without
    /// buffering. `None` (default) defers to `FLEXOR_MAX_BODY_BYTES`,
    /// else 8 MiB.
    pub max_body_bytes: Option<usize>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            workers: 2,
            max_batch: 16,
            max_wait_us: 2_000,
            queue_capacity: 1024,
            intra_threads: 0,
            trace: None,
            default_deadline_ms: None,
            max_body_bytes: None,
        }
    }
}

/// A running server: accept thread + worker pool over the shared registry.
pub struct Server {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    draining: Arc<AtomicBool>,
    queue: Arc<BatchQueue<Request>>,
    registry: Arc<Registry>,
    metrics: Arc<ServeMetrics>,
    accept_handle: thread::JoinHandle<()>,
    workers: WorkerPool,
}

impl Server {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port), spawn the
    /// worker pool and the accept loop, and return immediately.
    pub fn start<A: ToSocketAddrs>(addr: A, registry: Registry, cfg: ServeConfig) -> Result<Server> {
        // an empty registry is fine when a bundle repo is attached: the
        // control plane (`POST /models`) populates it at runtime
        anyhow::ensure!(
            !registry.is_empty() || registry.has_repo(),
            "registry has no models to serve and no bundle repo to load from"
        );
        anyhow::ensure!(cfg.workers > 0 && cfg.max_batch > 0 && cfg.queue_capacity > 0,
                        "serve config must be positive: {cfg:?}");
        // size the intra-op compute pool before the first forward builds
        // it: explicit budget, or cores split evenly across the workers
        let intra = if cfg.intra_threads > 0 {
            cfg.intra_threads
        } else {
            let cores = thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
            (cores / cfg.workers).max(1)
        };
        if !pool::configure_global(intra) && pool::global().threads() != intra {
            // the pool is built once per process; a budget requested after
            // that cannot apply, so say so instead of silently ignoring it
            trace::log(Level::Warn, "pool_already_sized", &[
                ("threads", Json::num(pool::global().threads() as f64)),
                ("requested", Json::num(intra as f64)),
            ]);
        }
        let trace_mode = cfg.trace.unwrap_or_else(trace::env_mode);
        // env fallbacks are read per server start (not OnceLock-cached)
        // so tests can run servers with different dials in one process
        let default_deadline = cfg
            .default_deadline_ms
            .or_else(|| {
                std::env::var("FLEXOR_DEADLINE_MS").ok().and_then(|v| v.trim().parse().ok())
            })
            .filter(|&ms| ms > 0);
        let max_body = cfg
            .max_body_bytes
            .or_else(|| {
                std::env::var("FLEXOR_MAX_BODY_BYTES").ok().and_then(|v| v.trim().parse().ok())
            })
            .filter(|&b| b > 0)
            .unwrap_or(DEFAULT_MAX_BODY_BYTES);
        let listener = TcpListener::bind(addr).context("binding serve socket")?;
        let local = listener.local_addr()?;

        let registry = Arc::new(registry);
        let metrics = Arc::new(ServeMetrics::new());
        let queue = Arc::new(BatchQueue::bounded(cfg.queue_capacity));
        let workers = WorkerPool::spawn(
            cfg.workers,
            queue.clone(),
            metrics.clone(),
            cfg.max_batch,
            Duration::from_micros(cfg.max_wait_us),
            Some(trace_mode),
        );

        trace::log(Level::Info, "serve_started", &[
            ("addr", Json::str(local.to_string())),
            ("workers", Json::num(cfg.workers as f64)),
            ("intra_threads", Json::num(pool::global().threads() as f64)),
            ("models", Json::num(registry.len() as f64)),
            ("trace", Json::str(trace_mode.label())),
        ]);

        let shutdown = Arc::new(AtomicBool::new(false));
        let draining = Arc::new(AtomicBool::new(false));
        let workers_alive = workers.alive_handle();
        let accept_handle = {
            let shutdown = shutdown.clone();
            let draining = draining.clone();
            let registry = registry.clone();
            let metrics = metrics.clone();
            let queue = queue.clone();
            thread::Builder::new()
                .name("serve-accept".to_string())
                .spawn(move || {
                    for stream in listener.incoming() {
                        if shutdown.load(Ordering::SeqCst) {
                            break;
                        }
                        let Ok(stream) = stream else { continue };
                        let ctx = ConnCtx {
                            registry: registry.clone(),
                            metrics: metrics.clone(),
                            queue: queue.clone(),
                            shutdown: shutdown.clone(),
                            draining: draining.clone(),
                            workers_alive: workers_alive.clone(),
                            trace_mode,
                            default_deadline,
                            max_body,
                        };
                        thread::Builder::new()
                            .name("serve-conn".to_string())
                            .spawn(move || handle_conn(stream, &ctx))
                            .ok();
                    }
                })
                .context("spawning accept thread")?
        };

        Ok(Server {
            addr: local,
            shutdown,
            draining,
            queue,
            registry,
            metrics,
            accept_handle,
            workers,
        })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    pub fn metrics(&self) -> &ServeMetrics {
        &self.metrics
    }

    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    /// Workers currently serving (the `/readyz` signal).
    pub fn workers_alive(&self) -> usize {
        self.workers.alive()
    }

    /// Enter draining: `/readyz` flips to 503 and new `/predict`s get
    /// `503`/`draining`, while admitted requests keep completing.
    /// Idempotent; `shutdown` calls it first.
    pub fn begin_drain(&self) {
        if !self.draining.swap(true, Ordering::SeqCst) {
            trace::log(Level::Info, "serve_draining", &[
                ("addr", Json::str(self.addr.to_string())),
            ]);
        }
    }

    /// Whether [`begin_drain`](Server::begin_drain) has been called.
    pub fn is_draining(&self) -> bool {
        self.draining.load(Ordering::SeqCst)
    }

    /// Graceful shutdown: mark draining, stop accepting, drain admitted
    /// requests, join the workers.
    pub fn shutdown(self) {
        self.begin_drain();
        self.shutdown.store(true, Ordering::SeqCst);
        // unblock the accept loop with a wake-up connection
        TcpStream::connect(self.addr).ok();
        self.accept_handle.join().ok();
        self.queue.close();
        self.workers.join();
        trace::log(Level::Info, "serve_stopped", &[
            ("addr", Json::str(self.addr.to_string())),
        ]);
    }
}

struct ConnCtx {
    registry: Arc<Registry>,
    metrics: Arc<ServeMetrics>,
    queue: Arc<BatchQueue<Request>>,
    shutdown: Arc<AtomicBool>,
    draining: Arc<AtomicBool>,
    workers_alive: Arc<AtomicUsize>,
    trace_mode: trace::TraceMode,
    /// Deadline applied when the client sends no `X-Deadline-Ms` (ms).
    default_deadline: Option<u64>,
    /// Request body byte bound (`413` beyond it).
    max_body: usize,
}

const DEFAULT_MAX_BODY_BYTES: usize = 8 << 20;
const MAX_HEADER_LINES: usize = 64;
const MAX_LINE_BYTES: usize = 8 << 10;

/// Requests slower than this log a `slow_request` warning
/// (`FLEXOR_SLOW_MS`, default 1000).
fn slow_ms() -> f64 {
    static SLOW_MS: OnceLock<f64> = OnceLock::new();
    *SLOW_MS.get_or_init(|| {
        std::env::var("FLEXOR_SLOW_MS")
            .ok()
            .and_then(|v| v.trim().parse::<f64>().ok())
            .filter(|v| *v > 0.0)
            .unwrap_or(1000.0)
    })
}

/// `read_line` with a hard length cap, so a newline-free stream cannot
/// grow memory unboundedly. A line that fills the cap without a trailing
/// newline was truncated — callers must treat that as malformed.
fn read_line_capped<R: BufRead>(r: &mut R, line: &mut String) -> std::io::Result<usize> {
    r.by_ref().take(MAX_LINE_BYTES as u64).read_line(line)
}

fn line_truncated(line: &str) -> bool {
    line.len() >= MAX_LINE_BYTES && !line.ends_with('\n')
}

/// A parsed request head + body.
struct HttpRequest {
    method: String,
    path: String,
    keep_alive: bool,
    /// Client-supplied `X-Request-Id`, sanitized; `None` → generate one.
    request_id: Option<String>,
    /// Client-supplied `X-Deadline-Ms` latency budget.
    deadline_ms: Option<u64>,
    body: String,
}

/// Clamp a client-supplied request id to something log-safe: keep
/// `[A-Za-z0-9._-]`, cap at 64 chars, drop the rest.
fn sanitize_rid(v: &str) -> Option<String> {
    let cleaned: String = v
        .chars()
        .filter(|c| c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.'))
        .take(64)
        .collect();
    if cleaned.is_empty() {
        None
    } else {
        Some(cleaned)
    }
}

fn handle_conn(stream: TcpStream, ctx: &ConnCtx) {
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(Duration::from_secs(30))).ok();
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(read_half);
    let mut writer = stream;
    loop {
        let req = match read_request(&mut reader, ctx.max_body) {
            Ok(Some(r)) => r,
            Ok(None) => return, // clean EOF / idle timeout
            Err((status, msg)) => {
                let rid = trace::next_request_id();
                let code = if status == 413 {
                    ErrorCode::BodyTooLarge
                } else {
                    ErrorCode::BadRequest
                };
                ctx.metrics.record_rejected();
                trace::log(Level::Warn, "bad_request", &[
                    ("request_id", Json::str(rid.clone())),
                    ("status", Json::num(status as f64)),
                    ("error", Json::str(msg.clone())),
                ]);
                write_response(
                    &mut writer,
                    status,
                    &err_json(code, &msg, Some(&rid)),
                    CT_JSON,
                    Some(&rid),
                    None,
                    None,
                    false,
                )
                .ok();
                return;
            }
        };
        let rid = req.request_id.clone().unwrap_or_else(trace::next_request_id);
        let keep_alive = req.keep_alive && !ctx.shutdown.load(Ordering::SeqCst);
        let t0 = Instant::now();
        let (status, body, ctype, retry_after, allow) = route(&req, ctx, &rid);
        let latency_ms = t0.elapsed().as_secs_f64() * 1e3;
        let fields = |extra: &mut Vec<(&'static str, Json)>| {
            let mut f = vec![
                ("request_id", Json::str(rid.clone())),
                ("method", Json::str(req.method.clone())),
                ("path", Json::str(req.path.clone())),
                ("status", Json::num(status as f64)),
                ("latency_ms", Json::num(latency_ms)),
            ];
            f.append(extra);
            f
        };
        if status >= 500 {
            trace::log(Level::Error, "request_failed", &fields(&mut vec![]));
        } else if latency_ms > slow_ms() {
            trace::log(Level::Warn, "slow_request", &fields(&mut vec![
                ("threshold_ms", Json::num(slow_ms())),
            ]));
        } else {
            trace::log(Level::Debug, "request", &fields(&mut vec![]));
        }
        if write_response(
            &mut writer, status, &body, ctype, Some(&rid), retry_after, allow, keep_alive,
        )
        .is_err()
            || !keep_alive
        {
            return;
        }
    }
}

/// Parse one request off the wire. `Ok(None)` = connection closed/idle;
/// `Err((status, msg))` = malformed (`400`) or oversized (`413`).
fn read_request<R: BufRead>(
    r: &mut R,
    max_body: usize,
) -> std::result::Result<Option<HttpRequest>, (u16, String)> {
    let bad = |msg: String| (400u16, msg);
    let mut line = String::new();
    match read_line_capped(r, &mut line) {
        Ok(0) => return Ok(None),
        Ok(_) => {}
        Err(_) => return Ok(None), // timeout / reset: drop quietly
    }
    if line_truncated(&line) {
        return Err(bad(format!("request line exceeds {MAX_LINE_BYTES} bytes")));
    }
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_ascii_uppercase();
    let path = parts.next().unwrap_or("").to_string();
    let version = parts.next().unwrap_or("");
    if method.is_empty() || path.is_empty() || !version.starts_with("HTTP/") {
        return Err(bad(format!("malformed request line {:?}", line.trim_end())));
    }

    let mut content_length = 0usize;
    let mut keep_alive = version != "HTTP/1.0";
    let mut request_id: Option<String> = None;
    let mut deadline_ms: Option<u64> = None;
    for _ in 0..MAX_HEADER_LINES {
        let mut h = String::new();
        match read_line_capped(r, &mut h) {
            Ok(0) => return Err(bad("connection closed mid-headers".to_string())),
            Ok(_) => {}
            Err(e) => return Err(bad(format!("reading headers: {e}"))),
        }
        if line_truncated(&h) {
            return Err(bad(format!("header line exceeds {MAX_LINE_BYTES} bytes")));
        }
        let t = h.trim();
        if t.is_empty() {
            let body = if content_length > 0 {
                if content_length > max_body {
                    // refuse before buffering: the body is never read
                    return Err((
                        413,
                        format!("body too large ({content_length} bytes, limit {max_body})"),
                    ));
                }
                let mut buf = vec![0u8; content_length];
                r.read_exact(&mut buf).map_err(|e| bad(format!("reading body: {e}")))?;
                String::from_utf8(buf).map_err(|_| bad("body is not utf-8".to_string()))?
            } else {
                String::new()
            };
            return Ok(Some(HttpRequest {
                method,
                path,
                keep_alive,
                request_id,
                deadline_ms,
                body,
            }));
        }
        let lower = t.to_ascii_lowercase();
        if let Some(v) = lower.strip_prefix("content-length:") {
            content_length = v
                .trim()
                .parse()
                .map_err(|_| bad(format!("bad content-length {:?}", v.trim())))?;
        } else if let Some(v) = lower.strip_prefix("connection:") {
            match v.trim() {
                "close" => keep_alive = false,
                "keep-alive" => keep_alive = true,
                _ => {}
            }
        } else if lower.starts_with("x-request-id:") {
            // take the value from the original line — lowercasing is
            // length-preserving for ASCII, so the offset is the same —
            // to keep the client's id case intact
            request_id = sanitize_rid(t["x-request-id:".len()..].trim());
        } else if let Some(v) = lower.strip_prefix("x-deadline-ms:") {
            let ms: u64 = v
                .trim()
                .parse()
                .map_err(|_| bad(format!("bad x-deadline-ms {:?}", v.trim())))?;
            if ms == 0 {
                return Err(bad("x-deadline-ms must be positive".to_string()));
            }
            deadline_ms = Some(ms);
        }
    }
    Err(bad("too many header lines".to_string()))
}

/// Route one request:
/// `(status, body, content-type, Retry-After secs, Allow header)`.
///
/// Known paths hit with the wrong method answer `405` with an `Allow`
/// header naming the methods that would have worked; only genuinely
/// unknown paths get `404`/`no_route`.
fn route(
    req: &HttpRequest,
    ctx: &ConnCtx,
    rid: &str,
) -> (u16, String, &'static str, Option<u32>, Option<&'static str>) {
    let (path, query) = match req.path.split_once('?') {
        Some((p, q)) => (p, q),
        None => (req.path.as_str(), ""),
    };
    let method = req.method.as_str();
    let json5 =
        |(status, body, retry): (u16, String, Option<u32>)| (status, body, CT_JSON, retry, None);
    let not_allowed = |allow: &'static str| {
        (
            405,
            err_json(
                ErrorCode::MethodNotAllowed,
                &format!("method {} not allowed for {path} (allow: {allow})", req.method),
                Some(rid),
            ),
            CT_JSON,
            None,
            Some(allow),
        )
    };
    match (method, path) {
        ("POST", "/predict") => json5(handle_predict(req, ctx, rid)),
        (_, "/predict") => not_allowed("POST"),
        ("GET", "/models") => (200, ctx.registry.to_json().to_string(), CT_JSON, None, None),
        ("POST", "/models") => {
            let (status, body) = handle_admit(req, ctx, rid);
            (status, body, CT_JSON, None, None)
        }
        (_, "/models") => not_allowed("GET, POST"),
        ("GET", "/metrics") => {
            if query.split('&').any(|kv| kv == "format=prometheus") {
                (200, prometheus_body(ctx), CT_PROM, None, None)
            } else {
                (200, ctx.metrics.snapshot(ctx.queue.len()).to_string(), CT_JSON, None, None)
            }
        }
        (_, "/metrics") => not_allowed("GET"),
        ("GET", "/healthz") => (200, r#"{"status":"ok"}"#.to_string(), CT_JSON, None, None),
        (_, "/healthz") => not_allowed("GET"),
        ("GET", "/readyz") => {
            // readiness: reachable AND able to make progress — not
            // draining, and at least one worker alive to drain the queue
            let draining = ctx.draining.load(Ordering::SeqCst);
            let alive = ctx.workers_alive.load(Ordering::Acquire);
            let ready = !draining && alive > 0;
            let body = Json::obj(vec![
                ("ready", Json::Bool(ready)),
                ("draining", Json::Bool(draining)),
                ("workers_alive", Json::num(alive as f64)),
            ])
            .to_string();
            (if ready { 200 } else { 503 }, body, CT_JSON, None, None)
        }
        (_, "/readyz") => not_allowed("GET"),
        (m, p) => {
            if let Some(rest) = p.strip_prefix("/models/") {
                if rest.is_empty() {
                    // "/models/" names no model — fall through to 404
                } else if let Some(name) = rest.strip_suffix("/profile") {
                    if m == "GET" {
                        let (status, body) = handle_profile(name, ctx, rid);
                        return (status, body, CT_JSON, None, None);
                    }
                    return not_allowed("GET");
                } else if !rest.contains('/') {
                    if m == "DELETE" {
                        let (status, body) = handle_delete(rest, ctx, rid);
                        return (status, body, CT_JSON, None, None);
                    }
                    return not_allowed("DELETE");
                }
            }
            (
                404,
                err_json(ErrorCode::NoRoute, &format!("no route {p}"), Some(rid)),
                CT_JSON,
                None,
                None,
            )
        }
    }
}

/// `GET /metrics?format=prometheus`: the serve metrics exposition plus
/// process-wide compute counters (intra-op pool, popcount kernel
/// dispatch) and the active trace mode.
fn prometheus_body(ctx: &ConnCtx) -> String {
    let mut out = ctx.metrics.prometheus(ctx.queue.len());
    // per-model engine + residency gauges off the registry: the mode
    // label each entry serves under and the storage it actually keeps
    // resident (sub-1-bit/weight on the Encrypted engine)
    let resident = ctx.registry.resident_entries();
    out.push_str(
        "# HELP flexor_model_compute_mode Engine the model serves on (1 = this mode).\n\
         # TYPE flexor_model_compute_mode gauge\n",
    );
    for e in &resident {
        out.push_str(&format!(
            "flexor_model_compute_mode{{model=\"{}\",mode=\"{}\"}} 1\n",
            e.name,
            e.model.mode_label()
        ));
    }
    out.push_str(
        "# HELP flexor_model_resident_bytes Resident weight bytes (quantized + FP residue).\n\
         # TYPE flexor_model_resident_bytes gauge\n",
    );
    for e in &resident {
        out.push_str(&format!(
            "flexor_model_resident_bytes{{model=\"{}\"}} {}\n",
            e.name,
            e.model.resident_bytes()
        ));
    }
    out.push_str(
        "# HELP flexor_model_resident_bits_per_weight Resident bits per quantized weight under the active modes.\n\
         # TYPE flexor_model_resident_bits_per_weight gauge\n",
    );
    for e in &resident {
        out.push_str(&format!(
            "flexor_model_resident_bits_per_weight{{model=\"{}\"}} {}\n",
            e.name,
            e.model.resident_bits_per_weight()
        ));
    }
    out.push_str(&format!(
        "# HELP flexor_model_swaps_total Alias repoints performed by the control plane.\n\
         # TYPE flexor_model_swaps_total counter\n\
         flexor_model_swaps_total {}\n",
        ctx.registry.swaps_total()
    ));
    out.push_str(&format!(
        "# HELP flexor_model_evictions_total Resident models evicted to stay under the byte budget.\n\
         # TYPE flexor_model_evictions_total counter\n\
         flexor_model_evictions_total {}\n",
        ctx.registry.evictions_total()
    ));
    let p = pool::global();
    let c = p.counters();
    out.push_str(&format!(
        "# HELP flexor_pool_threads Intra-op compute threads (incl. callers).\n\
         # TYPE flexor_pool_threads gauge\n\
         flexor_pool_threads {}\n",
        p.threads()
    ));
    out.push_str(&format!(
        "# HELP flexor_pool_jobs_total Jobs submitted to the intra-op pool.\n\
         # TYPE flexor_pool_jobs_total counter\n\
         flexor_pool_jobs_total {}\n",
        c.jobs
    ));
    out.push_str(&format!(
        "# HELP flexor_pool_shards_total Shards dispatched across all jobs.\n\
         # TYPE flexor_pool_shards_total counter\n\
         flexor_pool_shards_total {}\n",
        c.shards
    ));
    out.push_str(&format!(
        "# HELP flexor_pool_shard_panics_total Shards that panicked (contained, DESIGN.md §12).\n\
         # TYPE flexor_pool_shard_panics_total counter\n\
         flexor_pool_shard_panics_total {}\n",
        c.panics
    ));
    out.push_str(&format!(
        "# HELP flexor_pool_job_wait_seconds_total Summed submit-to-first-claim wait.\n\
         # TYPE flexor_pool_job_wait_seconds_total counter\n\
         flexor_pool_job_wait_seconds_total {}\n",
        c.job_wait_ns as f64 / 1e9
    ));
    out.push_str(
        "# HELP flexor_pool_busy_seconds_total Per-thread shard compute time (traced scopes only).\n\
         # TYPE flexor_pool_busy_seconds_total counter\n",
    );
    for (i, &ns) in c.busy_ns.iter().enumerate() {
        let thread = if i == 0 { "caller".to_string() } else { format!("worker-{}", i - 1) };
        out.push_str(&format!(
            "flexor_pool_busy_seconds_total{{thread=\"{thread}\"}} {}\n",
            ns as f64 / 1e9
        ));
    }
    out.push_str(
        "# HELP flexor_popcount_dispatch_total XNOR-GEMM calls per popcount kernel.\n\
         # TYPE flexor_popcount_dispatch_total counter\n",
    );
    for (k, n) in popcount::dispatch_counts() {
        out.push_str(&format!(
            "flexor_popcount_dispatch_total{{kernel=\"{}\"}} {n}\n",
            k.label()
        ));
    }
    out.push_str(&format!(
        "# HELP flexor_trace_mode Active trace sampling mode (1 = this mode).\n\
         # TYPE flexor_trace_mode gauge\n\
         flexor_trace_mode{{mode=\"{}\"}} 1\n",
        ctx.trace_mode.label()
    ));
    out
}

/// `GET /models/<name>/profile`: the model's aggregated per-layer stage
/// timing, annotated with its compute mode and the server's trace dial.
fn handle_profile(name: &str, ctx: &ConnCtx, rid: &str) -> (u16, String) {
    match ctx.registry.get(name) {
        Some(e) => {
            let mut j = e.profile.to_json();
            j.set("model", Json::str(name));
            j.set("compute_mode", Json::str(e.model.mode_label()));
            j.set("trace_mode", Json::str(ctx.trace_mode.label()));
            (200, j.to_string())
        }
        None => {
            (404, err_json(ErrorCode::UnknownModel, &format!("unknown model '{name}'"), Some(rid)))
        }
    }
}

/// Map a control-plane failure onto the HTTP error contract. The
/// acceptance-critical arm: any signature/digest/parse rejection is
/// `409`/`bundle_rejected` — by the time the error reaches here the
/// registry is guaranteed unchanged ([`Registry::admit_from_repo`]).
fn control_error(e: &ControlError) -> (ErrorCode, String) {
    match e {
        ControlError::SwapInProgress(_) => (ErrorCode::SwapInProgress, e.to_string()),
        ControlError::Rejected(_) => (ErrorCode::BundleRejected, e.to_string()),
        ControlError::BadSpec(_) | ControlError::NoRepo => (ErrorCode::BadRequest, e.to_string()),
        ControlError::Unknown(_) => (ErrorCode::UnknownModel, e.to_string()),
    }
}

/// `POST /models`: verify + load `alias@version` from the attached
/// bundle repo and repoint the alias (drain-then-swap, DESIGN.md §13).
fn handle_admit(req: &HttpRequest, ctx: &ConnCtx, rid: &str) -> (u16, String) {
    let parsed = match json::parse(&req.body) {
        Ok(v) => v,
        Err(e) => {
            return (
                400,
                err_json(ErrorCode::BadRequest, &format!("bad json body: {e}"), Some(rid)),
            )
        }
    };
    let Some(spec) = parsed.get("name").as_str() else {
        return (
            400,
            err_json(
                ErrorCode::BadRequest,
                "field 'name' must be a string like \"resnet20@v2\"",
                Some(rid),
            ),
        );
    };
    let lazy = parsed.get("lazy").as_bool().unwrap_or(false);
    match ctx.registry.admit_from_repo(spec, lazy) {
        Ok(report) => {
            trace::log(Level::Info, "model_admitted", &[
                ("request_id", Json::str(rid)),
                ("name", Json::str(report.name.clone())),
                ("swapped_from", match &report.swapped_from {
                    Some(f) => Json::str(f.clone()),
                    None => Json::Null,
                }),
                ("lazy", Json::Bool(report.lazy)),
            ]);
            (
                200,
                Json::obj(vec![
                    ("name", Json::str(report.name)),
                    ("alias", Json::str(report.alias)),
                    ("version", Json::str(report.version)),
                    ("swapped_from", match report.swapped_from {
                        Some(f) => Json::str(f),
                        None => Json::Null,
                    }),
                    ("load_ms", Json::num(report.load_ms)),
                    ("lazy", Json::Bool(report.lazy)),
                    ("request_id", Json::str(rid)),
                ])
                .to_string(),
            )
        }
        Err(e) => {
            let (code, msg) = control_error(&e);
            trace::log(Level::Warn, "model_admit_rejected", &[
                ("request_id", Json::str(rid)),
                ("spec", Json::str(spec)),
                ("code", Json::str(code.label())),
                ("error", Json::str(msg.clone())),
            ]);
            (code.status(), err_json(code, &msg, Some(rid)))
        }
    }
}

/// `DELETE /models/<name>`: drop an alias (all versions) or a single
/// `alias@version` slot. In-flight requests hold their `Arc` and drain;
/// memory frees when the last clone drops.
fn handle_delete(name: &str, ctx: &ConnCtx, rid: &str) -> (u16, String) {
    match ctx.registry.remove(name) {
        Ok(removed) => {
            trace::log(Level::Info, "model_deleted", &[
                ("request_id", Json::str(rid)),
                ("name", Json::str(name)),
                ("removed_versions", Json::num(removed as f64)),
            ]);
            (
                200,
                Json::obj(vec![
                    ("name", Json::str(name)),
                    ("removed_versions", Json::num(removed as f64)),
                    ("request_id", Json::str(rid)),
                ])
                .to_string(),
            )
        }
        Err(e) => {
            let (code, msg) = control_error(&e);
            trace::log(Level::Warn, "model_delete_rejected", &[
                ("request_id", Json::str(rid)),
                ("name", Json::str(name)),
                ("code", Json::str(code.label())),
                ("error", Json::str(msg.clone())),
            ]);
            (code.status(), err_json(code, &msg, Some(rid)))
        }
    }
}

/// Seconds a shed client should wait before retrying: scale the current
/// backlog by the observed mean latency, clamped to [1, 30].
fn retry_after_hint(ctx: &ConnCtx) -> u32 {
    let backlog_ms = ctx.queue.len() as f64 * ctx.metrics.mean_latency_ms();
    ((1.0 + backlog_ms / 1000.0) as u32).clamp(1, 30)
}

fn handle_predict(req: &HttpRequest, ctx: &ConnCtx, rid: &str) -> (u16, String, Option<u32>) {
    // rejections never reach a worker; count + log them so /metrics and
    // the structured log show load shedding and client errors instead of
    // a silent flat line
    let reject = |code: ErrorCode, msg: &str, retry: Option<u32>| {
        ctx.metrics.record_rejected();
        if retry.is_some() {
            // 503s with a retry hint are load shedding, not client error
            ctx.metrics.record_shed();
        }
        trace::log(Level::Warn, "request_rejected", &[
            ("request_id", Json::str(rid)),
            ("status", Json::num(code.status() as f64)),
            ("code", Json::str(code.label())),
            ("reason", Json::str(msg)),
        ]);
        (code.status(), err_json(code, msg, Some(rid)), retry)
    };
    if ctx.draining.load(Ordering::SeqCst) {
        return reject(
            ErrorCode::Draining,
            "server is draining, not accepting new requests",
            Some(retry_after_hint(ctx)),
        );
    }
    let parsed = match json::parse(&req.body) {
        Ok(v) => v,
        Err(e) => return reject(ErrorCode::BadRequest, &format!("bad json body: {e}"), None),
    };
    // resolution may lazily (re)load an evicted or lazily-admitted
    // bundle from the repo — a load/verify failure there is a server
    // fault, not a client error
    let entry = {
        let m = parsed.get("model");
        if m.is_null() {
            match ctx.registry.resolve_sole() {
                Ok(Some(e)) => e,
                Ok(None) => {
                    return reject(
                        ErrorCode::BadRequest,
                        "field 'model' is required when multiple models are registered",
                        None,
                    )
                }
                Err(e) => {
                    return reject(ErrorCode::Internal, &format!("model load failed: {e:#}"), None)
                }
            }
        } else {
            let Some(name) = m.as_str() else {
                return reject(ErrorCode::BadRequest, "field 'model' must be a string", None);
            };
            match ctx.registry.resolve(name) {
                Ok(Some(e)) => e,
                Ok(None) => {
                    return reject(
                        ErrorCode::UnknownModel,
                        &format!("unknown model '{name}'"),
                        None,
                    )
                }
                Err(e) => {
                    return reject(ErrorCode::Internal, &format!("model load failed: {e:#}"), None)
                }
            }
        }
    };
    let Some(features) = parsed.get("features").as_f32_vec() else {
        return reject(
            ErrorCode::BadRequest,
            "field 'features' must be an array of numbers",
            None,
        );
    };
    if features.len() != entry.feature_len {
        return reject(
            ErrorCode::BadRequest,
            &format!(
                "expected {} features for model '{}', got {}",
                entry.feature_len,
                entry.name,
                features.len()
            ),
            None,
        );
    }

    let enqueued = Instant::now();
    let deadline = req
        .deadline_ms
        .or(ctx.default_deadline)
        .map(|ms| enqueued + Duration::from_millis(ms));
    let (tx, rx) = mpsc::channel();
    let request = Request { entry, features, respond: tx, enqueued, deadline };
    if let Err((_, e)) = ctx.queue.try_push(request) {
        let (code, msg) = match e {
            PushError::Full => (ErrorCode::QueueFull, "admission queue full, retry later"),
            PushError::Closed => (ErrorCode::Draining, "server is shutting down"),
        };
        return reject(code, msg, Some(retry_after_hint(ctx)));
    }
    match rx.recv_timeout(Duration::from_secs(30)) {
        Ok(Ok(p)) => (
            200,
            Json::obj(vec![
                ("model", Json::str(p.model)),
                ("prediction", Json::num(p.class as f64)),
                ("batch_size", Json::num(p.batch_size as f64)),
                ("latency_ms", Json::num(p.latency_ms)),
                ("request_id", Json::str(rid)),
            ])
            .to_string(),
            None,
        ),
        Ok(Err(e)) => {
            let retry = if e.code == ErrorCode::DeadlineExceeded {
                Some(retry_after_hint(ctx))
            } else {
                None
            };
            (e.status(), err_json(e.code, &e.message, Some(rid)), retry)
        }
        Err(mpsc::RecvTimeoutError::Timeout) => {
            (504, err_json(ErrorCode::Timeout, "inference timed out", Some(rid)), None)
        }
        Err(mpsc::RecvTimeoutError::Disconnected) => (
            500,
            err_json(ErrorCode::Internal, "worker dropped the request", Some(rid)),
            None,
        ),
    }
}

fn err_json(code: ErrorCode, msg: &str, rid: Option<&str>) -> String {
    let mut o = Json::obj(vec![
        ("error", Json::str(msg)),
        ("code", Json::str(code.label())),
    ]);
    if let Some(r) = rid {
        o.set("request_id", Json::str(r));
    }
    o.to_string()
}

fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        413 => "Payload Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

#[allow(clippy::too_many_arguments)]
fn write_response<W: Write>(
    w: &mut W,
    status: u16,
    body: &str,
    content_type: &str,
    request_id: Option<&str>,
    retry_after: Option<u32>,
    allow: Option<&'static str>,
    keep_alive: bool,
) -> std::io::Result<()> {
    // one write_all per response: formatting straight into a NODELAY
    // socket would issue a syscall (and possibly a packet) per fragment
    let rid_header = request_id
        .map(|r| format!("X-Request-Id: {r}\r\n"))
        .unwrap_or_default();
    let retry_header = retry_after
        .map(|s| format!("Retry-After: {s}\r\n"))
        .unwrap_or_default();
    let allow_header = allow
        .map(|a| format!("Allow: {a}\r\n"))
        .unwrap_or_default();
    let msg = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\n{}{}{}Connection: {}\r\n\r\n{}",
        status,
        reason(status),
        content_type,
        body.len(),
        rid_header,
        retry_header,
        allow_header,
        if keep_alive { "keep-alive" } else { "close" },
        body
    );
    w.write_all(msg.as_bytes())?;
    w.flush()
}

/// One-shot HTTP/1.1 client — enough for the tests, benches and the
/// `serve` example to drive the server without external crates.
pub mod client {
    use super::*;

    /// Send `method path` with an optional JSON body; returns
    /// `(status, body)`. Uses `Connection: close` (one request per call).
    pub fn request(
        addr: SocketAddr,
        method: &str,
        path: &str,
        body: Option<&str>,
    ) -> Result<(u16, String)> {
        let (status, _headers, body) = request_with_headers(addr, method, path, &[], body)?;
        Ok((status, body))
    }

    /// [`request`] with extra request headers; returns
    /// `(status, response_headers, body)` with header names lower-cased.
    pub fn request_with_headers(
        addr: SocketAddr,
        method: &str,
        path: &str,
        headers: &[(&str, &str)],
        body: Option<&str>,
    ) -> Result<(u16, Vec<(String, String)>, String)> {
        let mut stream = TcpStream::connect(addr).context("connecting to server")?;
        stream.set_nodelay(true).ok();
        stream.set_read_timeout(Some(Duration::from_secs(60)))?;
        let b = body.unwrap_or("");
        let extra: String =
            headers.iter().map(|(k, v)| format!("{k}: {v}\r\n")).collect();
        let msg = format!(
            "{method} {path} HTTP/1.1\r\nHost: flexor-serve\r\nContent-Type: application/json\r\nContent-Length: {}\r\n{extra}Connection: close\r\n\r\n{b}",
            b.len()
        );
        stream.write_all(msg.as_bytes())?;
        stream.flush()?;

        let mut reader = BufReader::new(stream);
        let mut status_line = String::new();
        reader.read_line(&mut status_line)?;
        let status: u16 = status_line
            .split_whitespace()
            .nth(1)
            .with_context(|| format!("bad status line {status_line:?}"))?
            .parse()
            .context("non-numeric status code")?;
        let mut content_length = 0usize;
        let mut resp_headers = Vec::new();
        loop {
            let mut h = String::new();
            if reader.read_line(&mut h)? == 0 {
                break;
            }
            let t = h.trim();
            if t.is_empty() {
                break;
            }
            if let Some((name, value)) = t.split_once(':') {
                resp_headers.push((name.to_ascii_lowercase(), value.trim().to_string()));
            }
            let lower = t.to_ascii_lowercase();
            if let Some(v) = lower.strip_prefix("content-length:") {
                content_length = v.trim().parse().context("bad content-length")?;
            }
        }
        let mut buf = vec![0u8; content_length];
        reader.read_exact(&mut buf)?;
        Ok((status, resp_headers, String::from_utf8(buf).context("non-utf8 response body")?))
    }
}

#[cfg(test)]
mod tests {
    //! Wire-format units; full registry → queue → worker → HTTP round
    //! trips live in `rust/tests/serve.rs` and `rust/tests/observe.rs`
    //! (they need a model bundle).
    use super::*;
    use std::io::Cursor;

    fn parse_str(s: &str) -> std::result::Result<Option<HttpRequest>, (u16, String)> {
        read_request(&mut Cursor::new(s.as_bytes().to_vec()), DEFAULT_MAX_BODY_BYTES)
    }

    #[test]
    fn parses_post_with_body() {
        let req = parse_str(
            "POST /predict HTTP/1.1\r\nHost: x\r\nContent-Length: 11\r\n\r\nhello world",
        )
        .unwrap()
        .unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/predict");
        assert!(req.keep_alive); // HTTP/1.1 default
        assert!(req.request_id.is_none());
        assert_eq!(req.body, "hello world");
    }

    #[test]
    fn connection_header_controls_keep_alive() {
        let req = parse_str("GET /metrics HTTP/1.1\r\nConnection: close\r\n\r\n")
            .unwrap()
            .unwrap();
        assert!(!req.keep_alive);
        let req = parse_str("GET /metrics HTTP/1.0\r\n\r\n").unwrap().unwrap();
        assert!(!req.keep_alive);
        let req = parse_str("GET / HTTP/1.0\r\nConnection: keep-alive\r\n\r\n")
            .unwrap()
            .unwrap();
        assert!(req.keep_alive);
    }

    #[test]
    fn request_id_header_parsed_case_preserving() {
        let req = parse_str("GET /metrics HTTP/1.1\r\nX-Request-ID: My-Id.01\r\n\r\n")
            .unwrap()
            .unwrap();
        assert_eq!(req.request_id.as_deref(), Some("My-Id.01"));
        assert_eq!(req.deadline_ms, None);
        // hostile values are stripped, not echoed verbatim
        let req = parse_str(
            "GET /metrics HTTP/1.1\r\nX-Request-Id: a b\"c\u{7f}d\r\n\r\n",
        )
        .unwrap()
        .unwrap();
        assert_eq!(req.request_id.as_deref(), Some("abcd"));
        let req = parse_str("GET /metrics HTTP/1.1\r\nX-Request-Id: \"\"\r\n\r\n")
            .unwrap()
            .unwrap();
        assert!(req.request_id.is_none());
    }

    #[test]
    fn sanitize_rid_caps_length() {
        let long = "x".repeat(200);
        assert_eq!(sanitize_rid(&long).unwrap().len(), 64);
        assert_eq!(sanitize_rid("ok-1_2.3"), Some("ok-1_2.3".to_string()));
        assert_eq!(sanitize_rid("<>!"), None);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_str("NOT-HTTP\r\n\r\n").is_err());
        assert!(parse_str("GET /x HTTP/1.1\r\nContent-Length: zebra\r\n\r\n").is_err());
        assert_eq!(parse_str("").unwrap().map(|r| r.path), None); // EOF
    }

    #[test]
    fn oversized_lines_rejected_not_buffered() {
        // newline-free / giant lines must be refused, not accumulated
        let big_line = format!("GET /{} HTTP/1.1\r\n\r\n", "a".repeat(2 * MAX_LINE_BYTES));
        assert!(parse_str(&big_line).is_err());
        let big_header = format!(
            "GET /x HTTP/1.1\r\nX-Pad: {}\r\n\r\n",
            "b".repeat(2 * MAX_LINE_BYTES)
        );
        assert!(parse_str(&big_header).is_err());
        let no_newline = "c".repeat(2 * MAX_LINE_BYTES);
        assert!(parse_str(&no_newline).is_err());
    }

    #[test]
    fn response_wire_format() {
        let mut out = Vec::new();
        write_response(&mut out, 404, r#"{"error":"x"}"#, CT_JSON, Some("rid-1"), None, None, false)
            .unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.starts_with("HTTP/1.1 404 Not Found\r\n"));
        assert!(s.contains("Content-Type: application/json\r\n"));
        assert!(s.contains("Content-Length: 13\r\n"));
        assert!(s.contains("X-Request-Id: rid-1\r\n"));
        assert!(!s.contains("Retry-After"));
        assert!(!s.contains("Allow:"));
        assert!(s.contains("Connection: close\r\n"));
        assert!(s.ends_with(r#"{"error":"x"}"#));
    }

    #[test]
    fn retry_after_header_emitted_on_shed() {
        let mut out = Vec::new();
        write_response(&mut out, 503, "{}", CT_JSON, Some("r"), Some(7), None, false).unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.starts_with("HTTP/1.1 503 Service Unavailable\r\n"));
        assert!(s.contains("Retry-After: 7\r\n"));
    }

    #[test]
    fn allow_header_emitted_on_405() {
        let mut out = Vec::new();
        write_response(&mut out, 405, "{}", CT_JSON, Some("r"), None, Some("GET, POST"), false)
            .unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.starts_with("HTTP/1.1 405 Method Not Allowed\r\n"));
        assert!(s.contains("Allow: GET, POST\r\n"));
    }

    #[test]
    fn conflict_reason_phrase() {
        assert_eq!(reason(409), "Conflict");
    }

    #[test]
    fn error_bodies_carry_code_and_request_id() {
        let body = err_json(ErrorCode::Internal, "boom", Some("rid-9"));
        let j = json::parse(&body).unwrap();
        assert_eq!(j.get("error").as_str(), Some("boom"));
        assert_eq!(j.get("code").as_str(), Some("internal"));
        assert_eq!(j.get("request_id").as_str(), Some("rid-9"));
        let anon = err_json(ErrorCode::BadRequest, "x", None);
        let j = json::parse(&anon).unwrap();
        assert_eq!(j.get("code").as_str(), Some("bad_request"));
        assert!(j.get("request_id").is_null());
    }

    #[test]
    fn deadline_header_parsed_and_validated() {
        let req = parse_str("POST /predict HTTP/1.1\r\nX-Deadline-Ms: 250\r\n\r\n")
            .unwrap()
            .unwrap();
        assert_eq!(req.deadline_ms, Some(250));
        // zero and garbage deadlines are client errors, not silent no-ops
        let err = parse_str("POST /predict HTTP/1.1\r\nX-Deadline-Ms: 0\r\n\r\n").unwrap_err();
        assert_eq!(err.0, 400);
        let err = parse_str("POST /predict HTTP/1.1\r\nX-Deadline-Ms: soon\r\n\r\n").unwrap_err();
        assert_eq!(err.0, 400);
    }

    #[test]
    fn oversized_body_is_413_before_buffering() {
        // a tiny max_body: the declared content-length alone must trip
        // the refusal, without the body being read
        let req = "POST /predict HTTP/1.1\r\nContent-Length: 100\r\n\r\n";
        let err = read_request(&mut Cursor::new(req.as_bytes().to_vec()), 64).unwrap_err();
        assert_eq!(err.0, 413);
        assert!(err.1.contains("body too large"), "{}", err.1);
        // at the limit is fine
        let body = "x".repeat(64);
        let ok = read_request(
            &mut Cursor::new(format!("POST /p HTTP/1.1\r\nContent-Length: 64\r\n\r\n{body}")
                .into_bytes()),
            64,
        )
        .unwrap()
        .unwrap();
        assert_eq!(ok.body.len(), 64);
    }

    #[test]
    fn status_reasons() {
        assert_eq!(reason(200), "OK");
        assert_eq!(reason(413), "Payload Too Large");
        assert_eq!(reason(503), "Service Unavailable");
        assert_eq!(reason(599), "Unknown");
    }
}
