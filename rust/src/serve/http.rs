//! Minimal HTTP/1.1 front-end on `std::net::TcpListener`.
//!
//! Endpoints:
//! * `POST /predict` — body `{"model": "<name>", "features": [f32...]}`
//!   (`model` optional when exactly one model is registered). The request
//!   is admitted to the batching queue and the handler blocks on its
//!   one-shot channel; reply `{"model", "prediction", "batch_size",
//!   "latency_ms"}`.
//! * `GET /models`  — registry listing with storage stats.
//! * `GET /metrics` — latency percentiles, queue depth, served-batch-size
//!   histogram, throughput ([`ServeMetrics::snapshot`]).
//! * `GET /healthz` — liveness.
//!
//! Overload degrades to fast `503`s (non-blocking admission); shutdown is
//! graceful: stop accepting, drain the queue, join the workers.
//!
//! One thread per connection with keep-alive — plenty for the loopback /
//! benchmark traffic this repo drives today; the accept loop is the
//! obvious seam for a future acceptor/reactor upgrade.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::metrics::ServeMetrics;
use super::queue::{BatchQueue, PushError};
use super::registry::Registry;
use super::worker::{Request, WorkerPool};
use crate::substrate::json::{self, Json};
use crate::substrate::pool;

/// Serving policy knobs. Compute-engine selection is *not* here: it is
/// a property of the registry the caller builds and hands to
/// [`Server::start`] — `Registry::with_default_policy` /
/// `Registry::load_with_policy` (per-layer `ModePolicy`, DESIGN.md §9),
/// as `examples/serve.rs` does.
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// Worker threads draining the queue.
    pub workers: usize,
    /// Most requests coalesced into one forward pass.
    pub max_batch: usize,
    /// How long a worker lingers for a fuller batch after the first
    /// request arrives (µs). The latency/throughput trade-off dial.
    pub max_wait_us: u64,
    /// Admission queue bound; beyond it requests get `503`.
    pub queue_capacity: usize,
    /// Intra-op threads each forward pass shards its GEMMs across
    /// (the substrate compute pool, DESIGN.md §7). `0` = auto:
    /// `available_parallelism / workers`, so worker-level and GEMM-level
    /// parallelism compose instead of oversubscribing the machine.
    pub intra_threads: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            workers: 2,
            max_batch: 16,
            max_wait_us: 2_000,
            queue_capacity: 1024,
            intra_threads: 0,
        }
    }
}

/// A running server: accept thread + worker pool over the shared registry.
pub struct Server {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    queue: Arc<BatchQueue<Request>>,
    registry: Arc<Registry>,
    metrics: Arc<ServeMetrics>,
    accept_handle: thread::JoinHandle<()>,
    workers: WorkerPool,
}

impl Server {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port), spawn the
    /// worker pool and the accept loop, and return immediately.
    pub fn start<A: ToSocketAddrs>(addr: A, registry: Registry, cfg: ServeConfig) -> Result<Server> {
        anyhow::ensure!(!registry.is_empty(), "registry has no models to serve");
        anyhow::ensure!(cfg.workers > 0 && cfg.max_batch > 0 && cfg.queue_capacity > 0,
                        "serve config must be positive: {cfg:?}");
        // size the intra-op compute pool before the first forward builds
        // it: explicit budget, or cores split evenly across the workers
        let intra = if cfg.intra_threads > 0 {
            cfg.intra_threads
        } else {
            let cores = thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
            (cores / cfg.workers).max(1)
        };
        if !pool::configure_global(intra) && pool::global().threads() != intra {
            // the pool is built once per process; a budget requested after
            // that cannot apply, so say so instead of silently ignoring it
            eprintln!(
                "serve: intra-op pool already sized to {} threads; requested {intra} ignored",
                pool::global().threads()
            );
        }
        let listener = TcpListener::bind(addr).context("binding serve socket")?;
        let local = listener.local_addr()?;

        let registry = Arc::new(registry);
        let metrics = Arc::new(ServeMetrics::new());
        let queue = Arc::new(BatchQueue::bounded(cfg.queue_capacity));
        let workers = WorkerPool::spawn(
            cfg.workers,
            queue.clone(),
            metrics.clone(),
            cfg.max_batch,
            Duration::from_micros(cfg.max_wait_us),
        );

        let shutdown = Arc::new(AtomicBool::new(false));
        let accept_handle = {
            let shutdown = shutdown.clone();
            let registry = registry.clone();
            let metrics = metrics.clone();
            let queue = queue.clone();
            thread::Builder::new()
                .name("serve-accept".to_string())
                .spawn(move || {
                    for stream in listener.incoming() {
                        if shutdown.load(Ordering::SeqCst) {
                            break;
                        }
                        let Ok(stream) = stream else { continue };
                        let ctx = ConnCtx {
                            registry: registry.clone(),
                            metrics: metrics.clone(),
                            queue: queue.clone(),
                            shutdown: shutdown.clone(),
                        };
                        thread::Builder::new()
                            .name("serve-conn".to_string())
                            .spawn(move || handle_conn(stream, &ctx))
                            .ok();
                    }
                })
                .context("spawning accept thread")?
        };

        Ok(Server { addr: local, shutdown, queue, registry, metrics, accept_handle, workers })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    pub fn metrics(&self) -> &ServeMetrics {
        &self.metrics
    }

    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    /// Graceful shutdown: stop accepting, drain admitted requests, join
    /// the workers.
    pub fn shutdown(self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // unblock the accept loop with a wake-up connection
        TcpStream::connect(self.addr).ok();
        self.accept_handle.join().ok();
        self.queue.close();
        self.workers.join();
    }
}

struct ConnCtx {
    registry: Arc<Registry>,
    metrics: Arc<ServeMetrics>,
    queue: Arc<BatchQueue<Request>>,
    shutdown: Arc<AtomicBool>,
}

const MAX_BODY_BYTES: usize = 8 << 20;
const MAX_HEADER_LINES: usize = 64;
const MAX_LINE_BYTES: usize = 8 << 10;

/// `read_line` with a hard length cap, so a newline-free stream cannot
/// grow memory unboundedly. A line that fills the cap without a trailing
/// newline was truncated — callers must treat that as malformed.
fn read_line_capped<R: BufRead>(r: &mut R, line: &mut String) -> std::io::Result<usize> {
    r.by_ref().take(MAX_LINE_BYTES as u64).read_line(line)
}

fn line_truncated(line: &str) -> bool {
    line.len() >= MAX_LINE_BYTES && !line.ends_with('\n')
}

/// A parsed request head + body.
struct HttpRequest {
    method: String,
    path: String,
    keep_alive: bool,
    body: String,
}

fn handle_conn(stream: TcpStream, ctx: &ConnCtx) {
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(Duration::from_secs(30))).ok();
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(read_half);
    let mut writer = stream;
    loop {
        let req = match read_request(&mut reader) {
            Ok(Some(r)) => r,
            Ok(None) => return, // clean EOF / idle timeout
            Err(msg) => {
                write_response(&mut writer, 400, &err_json(&msg), false).ok();
                return;
            }
        };
        let keep_alive = req.keep_alive && !ctx.shutdown.load(Ordering::SeqCst);
        let (status, body) = route(&req, ctx);
        if write_response(&mut writer, status, &body, keep_alive).is_err() || !keep_alive {
            return;
        }
    }
}

/// Parse one request off the wire. `Ok(None)` = connection closed/idle.
fn read_request<R: BufRead>(r: &mut R) -> std::result::Result<Option<HttpRequest>, String> {
    let mut line = String::new();
    match read_line_capped(r, &mut line) {
        Ok(0) => return Ok(None),
        Ok(_) => {}
        Err(_) => return Ok(None), // timeout / reset: drop quietly
    }
    if line_truncated(&line) {
        return Err(format!("request line exceeds {MAX_LINE_BYTES} bytes"));
    }
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_ascii_uppercase();
    let path = parts.next().unwrap_or("").to_string();
    let version = parts.next().unwrap_or("");
    if method.is_empty() || path.is_empty() || !version.starts_with("HTTP/") {
        return Err(format!("malformed request line {:?}", line.trim_end()));
    }

    let mut content_length = 0usize;
    let mut keep_alive = version != "HTTP/1.0";
    for _ in 0..MAX_HEADER_LINES {
        let mut h = String::new();
        match read_line_capped(r, &mut h) {
            Ok(0) => return Err("connection closed mid-headers".to_string()),
            Ok(_) => {}
            Err(e) => return Err(format!("reading headers: {e}")),
        }
        if line_truncated(&h) {
            return Err(format!("header line exceeds {MAX_LINE_BYTES} bytes"));
        }
        let t = h.trim();
        if t.is_empty() {
            let body = if content_length > 0 {
                if content_length > MAX_BODY_BYTES {
                    return Err(format!("body too large ({content_length} bytes)"));
                }
                let mut buf = vec![0u8; content_length];
                r.read_exact(&mut buf).map_err(|e| format!("reading body: {e}"))?;
                String::from_utf8(buf).map_err(|_| "body is not utf-8".to_string())?
            } else {
                String::new()
            };
            return Ok(Some(HttpRequest { method, path, keep_alive, body }));
        }
        let lower = t.to_ascii_lowercase();
        if let Some(v) = lower.strip_prefix("content-length:") {
            content_length = v
                .trim()
                .parse()
                .map_err(|_| format!("bad content-length {:?}", v.trim()))?;
        } else if let Some(v) = lower.strip_prefix("connection:") {
            match v.trim() {
                "close" => keep_alive = false,
                "keep-alive" => keep_alive = true,
                _ => {}
            }
        }
    }
    Err("too many header lines".to_string())
}

fn route(req: &HttpRequest, ctx: &ConnCtx) -> (u16, String) {
    match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/predict") => handle_predict(&req.body, ctx),
        ("GET", "/models") => (200, ctx.registry.to_json().to_string()),
        ("GET", "/metrics") => (200, ctx.metrics.snapshot(ctx.queue.len()).to_string()),
        ("GET", "/healthz") => (200, r#"{"status":"ok"}"#.to_string()),
        ("POST", _) | ("GET", _) => (404, err_json(&format!("no route {}", req.path))),
        _ => (405, err_json(&format!("method {} not allowed", req.method))),
    }
}

fn handle_predict(body: &str, ctx: &ConnCtx) -> (u16, String) {
    // rejections never reach a worker; count them so /metrics shows load
    // shedding and client errors instead of a silent flat line
    let reject = |status: u16, msg: &str| {
        ctx.metrics.record_rejected();
        (status, err_json(msg))
    };
    let parsed = match json::parse(body) {
        Ok(v) => v,
        Err(e) => return reject(400, &format!("bad json body: {e}")),
    };
    let entry = {
        let m = parsed.get("model");
        if m.is_null() {
            match ctx.registry.sole() {
                Some(e) => e,
                None => {
                    return reject(
                        400,
                        "field 'model' is required when multiple models are registered",
                    )
                }
            }
        } else {
            let Some(name) = m.as_str() else {
                return reject(400, "field 'model' must be a string");
            };
            match ctx.registry.get(name) {
                Some(e) => e,
                None => return reject(404, &format!("unknown model '{name}'")),
            }
        }
    };
    let Some(features) = parsed.get("features").as_f32_vec() else {
        return reject(400, "field 'features' must be an array of numbers");
    };
    if features.len() != entry.feature_len {
        return reject(400, &format!(
            "expected {} features for model '{}', got {}",
            entry.feature_len,
            entry.name,
            features.len()
        ));
    }

    let (tx, rx) = mpsc::channel();
    let request = Request { entry, features, respond: tx, enqueued: Instant::now() };
    if let Err((_, e)) = ctx.queue.try_push(request) {
        let msg = match e {
            PushError::Full => "admission queue full, retry later",
            PushError::Closed => "server is shutting down",
        };
        return reject(503, msg);
    }
    match rx.recv_timeout(Duration::from_secs(30)) {
        Ok(Ok(p)) => (
            200,
            Json::obj(vec![
                ("model", Json::str(p.model)),
                ("prediction", Json::num(p.class as f64)),
                ("batch_size", Json::num(p.batch_size as f64)),
                ("latency_ms", Json::num(p.latency_ms)),
            ])
            .to_string(),
        ),
        Ok(Err(msg)) => (500, err_json(&msg)),
        Err(mpsc::RecvTimeoutError::Timeout) => (504, err_json("inference timed out")),
        Err(mpsc::RecvTimeoutError::Disconnected) => {
            (500, err_json("worker dropped the request"))
        }
    }
}

fn err_json(msg: &str) -> String {
    Json::obj(vec![("error", Json::str(msg))]).to_string()
}

fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

fn write_response<W: Write>(w: &mut W, status: u16, body: &str, keep_alive: bool) -> std::io::Result<()> {
    // one write_all per response: formatting straight into a NODELAY
    // socket would issue a syscall (and possibly a packet) per fragment
    let msg = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: {}\r\n\r\n{}",
        status,
        reason(status),
        body.len(),
        if keep_alive { "keep-alive" } else { "close" },
        body
    );
    w.write_all(msg.as_bytes())?;
    w.flush()
}

/// One-shot HTTP/1.1 client — enough for the tests, benches and the
/// `serve` example to drive the server without external crates.
pub mod client {
    use super::*;

    /// Send `method path` with an optional JSON body; returns
    /// `(status, body)`. Uses `Connection: close` (one request per call).
    pub fn request(
        addr: SocketAddr,
        method: &str,
        path: &str,
        body: Option<&str>,
    ) -> Result<(u16, String)> {
        let mut stream = TcpStream::connect(addr).context("connecting to server")?;
        stream.set_nodelay(true).ok();
        stream.set_read_timeout(Some(Duration::from_secs(60)))?;
        let b = body.unwrap_or("");
        let msg = format!(
            "{method} {path} HTTP/1.1\r\nHost: flexor-serve\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{b}",
            b.len()
        );
        stream.write_all(msg.as_bytes())?;
        stream.flush()?;

        let mut reader = BufReader::new(stream);
        let mut status_line = String::new();
        reader.read_line(&mut status_line)?;
        let status: u16 = status_line
            .split_whitespace()
            .nth(1)
            .with_context(|| format!("bad status line {status_line:?}"))?
            .parse()
            .context("non-numeric status code")?;
        let mut content_length = 0usize;
        loop {
            let mut h = String::new();
            if reader.read_line(&mut h)? == 0 {
                break;
            }
            let t = h.trim();
            if t.is_empty() {
                break;
            }
            let lower = t.to_ascii_lowercase();
            if let Some(v) = lower.strip_prefix("content-length:") {
                content_length = v.trim().parse().context("bad content-length")?;
            }
        }
        let mut buf = vec![0u8; content_length];
        reader.read_exact(&mut buf)?;
        Ok((status, String::from_utf8(buf).context("non-utf8 response body")?))
    }
}

#[cfg(test)]
mod tests {
    //! Wire-format units; full registry → queue → worker → HTTP round
    //! trips live in `rust/tests/serve.rs` (they need a model bundle).
    use super::*;
    use std::io::Cursor;

    fn parse_str(s: &str) -> std::result::Result<Option<HttpRequest>, String> {
        read_request(&mut Cursor::new(s.as_bytes().to_vec()))
    }

    #[test]
    fn parses_post_with_body() {
        let req = parse_str(
            "POST /predict HTTP/1.1\r\nHost: x\r\nContent-Length: 11\r\n\r\nhello world",
        )
        .unwrap()
        .unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/predict");
        assert!(req.keep_alive); // HTTP/1.1 default
        assert_eq!(req.body, "hello world");
    }

    #[test]
    fn connection_header_controls_keep_alive() {
        let req = parse_str("GET /metrics HTTP/1.1\r\nConnection: close\r\n\r\n")
            .unwrap()
            .unwrap();
        assert!(!req.keep_alive);
        let req = parse_str("GET /metrics HTTP/1.0\r\n\r\n").unwrap().unwrap();
        assert!(!req.keep_alive);
        let req = parse_str("GET / HTTP/1.0\r\nConnection: keep-alive\r\n\r\n")
            .unwrap()
            .unwrap();
        assert!(req.keep_alive);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_str("NOT-HTTP\r\n\r\n").is_err());
        assert!(parse_str("GET /x HTTP/1.1\r\nContent-Length: zebra\r\n\r\n").is_err());
        assert_eq!(parse_str("").unwrap().map(|r| r.path), None); // EOF
    }

    #[test]
    fn oversized_lines_rejected_not_buffered() {
        // newline-free / giant lines must be refused, not accumulated
        let big_line = format!("GET /{} HTTP/1.1\r\n\r\n", "a".repeat(2 * MAX_LINE_BYTES));
        assert!(parse_str(&big_line).is_err());
        let big_header = format!(
            "GET /x HTTP/1.1\r\nX-Pad: {}\r\n\r\n",
            "b".repeat(2 * MAX_LINE_BYTES)
        );
        assert!(parse_str(&big_header).is_err());
        let no_newline = "c".repeat(2 * MAX_LINE_BYTES);
        assert!(parse_str(&no_newline).is_err());
    }

    #[test]
    fn response_wire_format() {
        let mut out = Vec::new();
        write_response(&mut out, 404, r#"{"error":"x"}"#, false).unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.starts_with("HTTP/1.1 404 Not Found\r\n"));
        assert!(s.contains("Content-Length: 13\r\n"));
        assert!(s.contains("Connection: close\r\n"));
        assert!(s.ends_with(r#"{"error":"x"}"#));
    }

    #[test]
    fn status_reasons() {
        assert_eq!(reason(200), "OK");
        assert_eq!(reason(503), "Service Unavailable");
        assert_eq!(reason(599), "Unknown");
    }
}
