//! Minimal HTTP/1.1 front-end on `std::net::TcpListener`.
//!
//! Endpoints:
//! * `POST /predict` — body `{"model": "<name>", "features": [f32...]}`
//!   (`model` optional when exactly one model is registered). The request
//!   is admitted to the batching queue and the handler blocks on its
//!   one-shot channel; reply `{"model", "prediction", "batch_size",
//!   "latency_ms", "request_id"}`.
//! * `GET /models`  — registry listing with storage stats, alias/version
//!   fields and swap/eviction totals.
//! * `POST /models` — control plane (DESIGN.md §13): body
//!   `{"name": "alias@version", "lazy": false}` verifies the bundle's
//!   HMAC signature + per-file sha256 through the attached repo, loads
//!   it, and repoints the alias (drain-then-swap: in-flight requests
//!   finish on the old version). `409`/`bundle_rejected` on any
//!   signature/digest/parse failure — nothing registers;
//!   `409`/`swap_in_progress` while another swap owns the alias.
//! * `DELETE /models/<name>` — drop an alias (or one `alias@version`
//!   slot); in-flight requests drain on their `Arc`, memory frees when
//!   the last reference drops. `409` while a swap is in progress.
//! * `GET /metrics` — latency percentiles, queue depth, served-batch-size
//!   histogram, throughput ([`ServeMetrics::snapshot`]); add
//!   `?format=prometheus` for the text exposition
//!   ([`ServeMetrics::prometheus`] plus pool/kernel counters).
//! * `GET /models/<name>/profile` — per-layer stage timing aggregated
//!   from traced forwards ([`trace::Profile`]); empty until the trace
//!   dial (`FLEXOR_TRACE` / [`ServeConfig::trace`]) samples a forward in.
//! * `GET /healthz` — liveness (the process answers).
//! * `GET /readyz` — readiness: `503` while draining or while no worker
//!   is alive, `200` otherwise (DESIGN.md §12).
//!
//! Every request carries an id: `X-Request-Id` is honored when the
//! client sends one (sanitized), generated otherwise, echoed back as a
//! response header, and included in predict/error JSON bodies — so a
//! client-reported failure can be joined against the server's
//! structured log lines ([`trace::log`]).
//!
//! Failure model (DESIGN.md §12): every non-2xx body carries a stable
//! machine-readable `code` ([`ErrorCode`]). Requests may carry an
//! `X-Deadline-Ms` budget (default [`ServeConfig::default_deadline_ms`] /
//! `FLEXOR_DEADLINE_MS`); a request still queued past its deadline is
//! shed with `503`/`deadline_exceeded` instead of computed. Overload
//! degrades to fast `503`s with a `Retry-After` hint (non-blocking
//! admission); bodies beyond the byte bound
//! ([`ServeConfig::max_body_bytes`] / `FLEXOR_MAX_BODY_BYTES`) get `413`
//! without buffering. Shutdown is graceful: mark draining (late
//! arrivals get `503`/`draining`), stop accepting, drain the queue,
//! join the workers.
//!
//! Two front-end concurrency models share this module (DESIGN.md §14):
//!
//! * [`HttpMode::EventLoop`] (default) — a single nonblocking readiness
//!   loop (`substrate::net`, epoll on Linux) multiplexing every
//!   connection: keep-alive + HTTP/1.1 pipelining, bounded
//!   per-connection buffers, incremental framing ([`FrameParser`]),
//!   idle/header timeouts (`408`/`431`), a connection cap, and explicit
//!   backpressure — a full admission queue suspends reads instead of
//!   buffering unboundedly. `/predict` bodies stream through the
//!   zero-allocation [`json::Lexer`] via [`PredictVisitor`] into
//!   arena-recycled feature buffers; worker completions come back over a
//!   [`CompletionBoard`](super::worker::CompletionBoard) that wakes the
//!   loop.
//! * [`HttpMode::Threads`] — the original one-blocking-thread-per-
//!   connection model, kept as a fallback (`FLEXOR_HTTP_MODE=threads`)
//!   and as the behavioral oracle for differential tests.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, OnceLock};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::error::ErrorCode;
use super::metrics::ServeMetrics;
use super::queue::{BatchQueue, PushError};
use super::registry::{ControlError, Registry};
use super::worker::{Request, Responder, WorkerPool};
use crate::inference::bitslice::popcount;
use crate::substrate::json::{self, Json};
use crate::substrate::pool;
use crate::substrate::trace::{self, Level};

const CT_JSON: &str = "application/json";
const CT_PROM: &str = "text/plain; version=0.0.4";

/// Front-end concurrency model (DESIGN.md §14).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HttpMode {
    /// One nonblocking readiness loop multiplexing every connection
    /// (epoll on Linux via `substrate::net`). The default.
    EventLoop,
    /// One blocking thread per connection — the pre-§14 model, kept as a
    /// fallback and as the behavioral oracle in differential tests.
    Threads,
}

impl HttpMode {
    pub fn label(self) -> &'static str {
        match self {
            HttpMode::EventLoop => "event_loop",
            HttpMode::Threads => "threads",
        }
    }
}

/// Serving policy knobs. Compute-engine selection is *not* here: it is
/// a property of the registry the caller builds and hands to
/// [`Server::start`] — `Registry::with_default_policy` /
/// `Registry::load_with_policy` (per-layer `ModePolicy`, DESIGN.md §9),
/// as `examples/serve.rs` does.
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// Worker threads draining the queue.
    pub workers: usize,
    /// Most requests coalesced into one forward pass.
    pub max_batch: usize,
    /// How long a worker lingers for a fuller batch after the first
    /// request arrives (µs). The latency/throughput trade-off dial.
    pub max_wait_us: u64,
    /// Admission queue bound; beyond it requests get `503`.
    pub queue_capacity: usize,
    /// Intra-op threads each forward pass shards its GEMMs across
    /// (the substrate compute pool, DESIGN.md §7). `0` = auto:
    /// `available_parallelism / workers`, so worker-level and GEMM-level
    /// parallelism compose instead of oversubscribing the machine.
    pub intra_threads: usize,
    /// Stage-tracing dial for served forwards. `None` (default) defers
    /// to the `FLEXOR_TRACE` env var; tests and embedders set an explicit
    /// mode so they never touch process-global env state.
    pub trace: Option<trace::TraceMode>,
    /// Default per-request deadline in ms applied when the client sends
    /// no `X-Deadline-Ms` header. `None` (default) defers to the
    /// `FLEXOR_DEADLINE_MS` env var; unset/0 = no default deadline.
    pub default_deadline_ms: Option<u64>,
    /// Request body byte bound; larger bodies get `413` without
    /// buffering. `None` (default) defers to `FLEXOR_MAX_BODY_BYTES`,
    /// else 8 MiB.
    pub max_body_bytes: Option<usize>,
    /// Front-end concurrency model. `None` (default) defers to
    /// `FLEXOR_HTTP_MODE` (`event_loop` | `threads`), else the event
    /// loop. Non-unix platforms always fall back to threads.
    pub http_mode: Option<HttpMode>,
    /// Idle keep-alive connections are closed silently after this many
    /// ms without traffic (event-loop mode). `None` (default) defers to
    /// `FLEXOR_HTTP_IDLE_MS`, else 30 000.
    pub idle_timeout_ms: Option<u64>,
    /// A connection that dribbles its request head/body slower than this
    /// budget (ms) gets `408`/`request_timeout` and is closed — the
    /// slowloris defense (event-loop mode). `None` (default) defers to
    /// `FLEXOR_HTTP_HEADER_MS`, else 10 000.
    pub header_timeout_ms: Option<u64>,
    /// Simultaneous-connection cap; beyond it new connections get an
    /// immediate `503` + `Retry-After` (event-loop mode). `None`
    /// (default) defers to `FLEXOR_MAX_CONNECTIONS`, else 4096.
    pub max_connections: Option<usize>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            workers: 2,
            max_batch: 16,
            max_wait_us: 2_000,
            queue_capacity: 1024,
            intra_threads: 0,
            trace: None,
            default_deadline_ms: None,
            max_body_bytes: None,
            http_mode: None,
            idle_timeout_ms: None,
            header_timeout_ms: None,
            max_connections: None,
        }
    }
}

fn env_u64(key: &str) -> Option<u64> {
    std::env::var(key).ok().and_then(|v| v.trim().parse().ok())
}

fn http_mode_env() -> Option<HttpMode> {
    match std::env::var("FLEXOR_HTTP_MODE").ok()?.trim().to_ascii_lowercase().as_str() {
        "threads" | "thread" => Some(HttpMode::Threads),
        "event_loop" | "event-loop" | "eventloop" | "epoll" => Some(HttpMode::EventLoop),
        _ => None,
    }
}

/// A running server: accept thread + worker pool over the shared registry.
pub struct Server {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    draining: Arc<AtomicBool>,
    queue: Arc<BatchQueue<Request>>,
    registry: Arc<Registry>,
    metrics: Arc<ServeMetrics>,
    accept_handle: thread::JoinHandle<()>,
    workers: WorkerPool,
}

impl Server {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port), spawn the
    /// worker pool and the accept loop, and return immediately.
    pub fn start<A: ToSocketAddrs>(addr: A, registry: Registry, cfg: ServeConfig) -> Result<Server> {
        // an empty registry is fine when a bundle repo is attached: the
        // control plane (`POST /models`) populates it at runtime
        anyhow::ensure!(
            !registry.is_empty() || registry.has_repo(),
            "registry has no models to serve and no bundle repo to load from"
        );
        anyhow::ensure!(cfg.workers > 0 && cfg.max_batch > 0 && cfg.queue_capacity > 0,
                        "serve config must be positive: {cfg:?}");
        // size the intra-op compute pool before the first forward builds
        // it: explicit budget, or cores split evenly across the workers
        let intra = if cfg.intra_threads > 0 {
            cfg.intra_threads
        } else {
            let cores = thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
            (cores / cfg.workers).max(1)
        };
        if !pool::configure_global(intra) && pool::global().threads() != intra {
            // the pool is built once per process; a budget requested after
            // that cannot apply, so say so instead of silently ignoring it
            trace::log(Level::Warn, "pool_already_sized", &[
                ("threads", Json::num(pool::global().threads() as f64)),
                ("requested", Json::num(intra as f64)),
            ]);
        }
        let trace_mode = cfg.trace.unwrap_or_else(trace::env_mode);
        // env fallbacks are read per server start (not OnceLock-cached)
        // so tests can run servers with different dials in one process
        let default_deadline = cfg
            .default_deadline_ms
            .or_else(|| {
                std::env::var("FLEXOR_DEADLINE_MS").ok().and_then(|v| v.trim().parse().ok())
            })
            .filter(|&ms| ms > 0);
        let max_body = cfg
            .max_body_bytes
            .or_else(|| {
                std::env::var("FLEXOR_MAX_BODY_BYTES").ok().and_then(|v| v.trim().parse().ok())
            })
            .filter(|&b| b > 0)
            .unwrap_or(DEFAULT_MAX_BODY_BYTES);
        let mode = cfg.http_mode.or_else(http_mode_env).unwrap_or(HttpMode::EventLoop);
        #[cfg(not(unix))]
        let mode = HttpMode::Threads;
        let dials = LoopDials {
            idle_ms: cfg
                .idle_timeout_ms
                .or_else(|| env_u64("FLEXOR_HTTP_IDLE_MS"))
                .filter(|&ms| ms > 0)
                .unwrap_or(30_000),
            header_ms: cfg
                .header_timeout_ms
                .or_else(|| env_u64("FLEXOR_HTTP_HEADER_MS"))
                .filter(|&ms| ms > 0)
                .unwrap_or(10_000),
            max_conns: cfg
                .max_connections
                .or_else(|| env_u64("FLEXOR_MAX_CONNECTIONS").map(|v| v as usize))
                .filter(|&n| n > 0)
                .unwrap_or(4096),
        };
        let listener = TcpListener::bind(addr).context("binding serve socket")?;
        let local = listener.local_addr()?;

        let registry = Arc::new(registry);
        let metrics = Arc::new(ServeMetrics::new());
        let queue = Arc::new(BatchQueue::bounded(cfg.queue_capacity));
        let workers = WorkerPool::spawn(
            cfg.workers,
            queue.clone(),
            metrics.clone(),
            cfg.max_batch,
            Duration::from_micros(cfg.max_wait_us),
            Some(trace_mode),
        );

        trace::log(Level::Info, "serve_started", &[
            ("addr", Json::str(local.to_string())),
            ("workers", Json::num(cfg.workers as f64)),
            ("intra_threads", Json::num(pool::global().threads() as f64)),
            ("models", Json::num(registry.len() as f64)),
            ("trace", Json::str(trace_mode.label())),
            ("http_mode", Json::str(mode.label())),
        ]);

        let shutdown = Arc::new(AtomicBool::new(false));
        let draining = Arc::new(AtomicBool::new(false));
        let workers_alive = workers.alive_handle();
        let ctx = ConnCtx {
            registry: registry.clone(),
            metrics: metrics.clone(),
            queue: queue.clone(),
            shutdown: shutdown.clone(),
            draining: draining.clone(),
            workers_alive,
            trace_mode,
            default_deadline,
            max_body,
        };
        let accept_handle = spawn_front_end(mode, listener, ctx, dials)?;

        Ok(Server {
            addr: local,
            shutdown,
            draining,
            queue,
            registry,
            metrics,
            accept_handle,
            workers,
        })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    pub fn metrics(&self) -> &ServeMetrics {
        &self.metrics
    }

    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    /// Workers currently serving (the `/readyz` signal).
    pub fn workers_alive(&self) -> usize {
        self.workers.alive()
    }

    /// Enter draining: `/readyz` flips to 503 and new `/predict`s get
    /// `503`/`draining`, while admitted requests keep completing.
    /// Idempotent; `shutdown` calls it first.
    pub fn begin_drain(&self) {
        if !self.draining.swap(true, Ordering::SeqCst) {
            trace::log(Level::Info, "serve_draining", &[
                ("addr", Json::str(self.addr.to_string())),
            ]);
        }
    }

    /// Whether [`begin_drain`](Server::begin_drain) has been called.
    pub fn is_draining(&self) -> bool {
        self.draining.load(Ordering::SeqCst)
    }

    /// Graceful shutdown: mark draining, stop accepting, drain admitted
    /// requests, join the workers.
    pub fn shutdown(self) {
        self.begin_drain();
        self.shutdown.store(true, Ordering::SeqCst);
        // unblock the accept loop with a wake-up connection
        TcpStream::connect(self.addr).ok();
        self.accept_handle.join().ok();
        self.queue.close();
        self.workers.join();
        trace::log(Level::Info, "serve_stopped", &[
            ("addr", Json::str(self.addr.to_string())),
        ]);
    }
}

#[derive(Clone)]
struct ConnCtx {
    registry: Arc<Registry>,
    metrics: Arc<ServeMetrics>,
    queue: Arc<BatchQueue<Request>>,
    shutdown: Arc<AtomicBool>,
    draining: Arc<AtomicBool>,
    workers_alive: Arc<AtomicUsize>,
    trace_mode: trace::TraceMode,
    /// Deadline applied when the client sends no `X-Deadline-Ms` (ms).
    default_deadline: Option<u64>,
    /// Request body byte bound (`413` beyond it).
    max_body: usize,
}

/// Event-loop dials resolved per server start (env fallbacks are read at
/// start, not OnceLock-cached, so tests can vary them in one process).
#[derive(Clone, Copy, Debug)]
#[allow(dead_code)] // unread in threads-only (non-unix) builds
struct LoopDials {
    idle_ms: u64,
    header_ms: u64,
    max_conns: usize,
}

#[cfg(unix)]
fn spawn_front_end(
    mode: HttpMode,
    listener: TcpListener,
    ctx: ConnCtx,
    dials: LoopDials,
) -> Result<thread::JoinHandle<()>> {
    match mode {
        HttpMode::EventLoop => ev::spawn(listener, ctx, dials),
        HttpMode::Threads => spawn_thread_accept(listener, ctx),
    }
}

#[cfg(not(unix))]
fn spawn_front_end(
    _mode: HttpMode,
    listener: TcpListener,
    ctx: ConnCtx,
    _dials: LoopDials,
) -> Result<thread::JoinHandle<()>> {
    spawn_thread_accept(listener, ctx)
}

/// [`HttpMode::Threads`]: blocking accept loop, one thread per
/// connection running [`handle_conn`].
fn spawn_thread_accept(listener: TcpListener, ctx: ConnCtx) -> Result<thread::JoinHandle<()>> {
    let shutdown = ctx.shutdown.clone();
    thread::Builder::new()
        .name("serve-accept".to_string())
        .spawn(move || {
            for stream in listener.incoming() {
                if shutdown.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = stream else { continue };
                let ctx = ctx.clone();
                thread::Builder::new()
                    .name("serve-conn".to_string())
                    .spawn(move || handle_conn(stream, &ctx))
                    .ok();
            }
        })
        .context("spawning accept thread")
}

const DEFAULT_MAX_BODY_BYTES: usize = 8 << 20;
const MAX_HEADER_LINES: usize = 64;
const MAX_LINE_BYTES: usize = 8 << 10;

/// Requests slower than this log a `slow_request` warning
/// (`FLEXOR_SLOW_MS`, default 1000).
fn slow_ms() -> f64 {
    static SLOW_MS: OnceLock<f64> = OnceLock::new();
    *SLOW_MS.get_or_init(|| {
        std::env::var("FLEXOR_SLOW_MS")
            .ok()
            .and_then(|v| v.trim().parse::<f64>().ok())
            .filter(|v| *v > 0.0)
            .unwrap_or(1000.0)
    })
}

/// `read_line` with a hard length cap, so a newline-free stream cannot
/// grow memory unboundedly. A line that fills the cap without a trailing
/// newline was truncated — callers must treat that as malformed.
fn read_line_capped<R: BufRead>(r: &mut R, line: &mut String) -> std::io::Result<usize> {
    r.by_ref().take(MAX_LINE_BYTES as u64).read_line(line)
}

fn line_truncated(line: &str) -> bool {
    line.len() >= MAX_LINE_BYTES && !line.ends_with('\n')
}

/// A parsed request head + body.
struct HttpRequest {
    method: String,
    path: String,
    keep_alive: bool,
    /// Client-supplied `X-Request-Id`, sanitized; `None` → generate one.
    request_id: Option<String>,
    /// Client-supplied `X-Deadline-Ms` latency budget.
    deadline_ms: Option<u64>,
    body: String,
}

/// Clamp a client-supplied request id to something log-safe: keep
/// `[A-Za-z0-9._-]`, cap at 64 chars, drop the rest.
fn sanitize_rid(v: &str) -> Option<String> {
    let cleaned: String = v
        .chars()
        .filter(|c| c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.'))
        .take(64)
        .collect();
    if cleaned.is_empty() {
        None
    } else {
        Some(cleaned)
    }
}

fn handle_conn(stream: TcpStream, ctx: &ConnCtx) {
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(Duration::from_secs(30))).ok();
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(read_half);
    let mut writer = stream;
    loop {
        let req = match read_request(&mut reader, ctx.max_body) {
            Ok(Some(r)) => r,
            Ok(None) => return, // clean EOF / idle timeout
            Err((status, msg)) => {
                let rid = trace::next_request_id();
                let code = if status == 413 {
                    ErrorCode::BodyTooLarge
                } else {
                    ErrorCode::BadRequest
                };
                ctx.metrics.record_rejected();
                trace::log(Level::Warn, "bad_request", &[
                    ("request_id", Json::str(rid.clone())),
                    ("status", Json::num(status as f64)),
                    ("error", Json::str(msg.clone())),
                ]);
                write_response(
                    &mut writer,
                    status,
                    &err_json(code, &msg, Some(&rid)),
                    CT_JSON,
                    Some(&rid),
                    None,
                    None,
                    false,
                )
                .ok();
                return;
            }
        };
        let rid = req.request_id.clone().unwrap_or_else(trace::next_request_id);
        let keep_alive = req.keep_alive && !ctx.shutdown.load(Ordering::SeqCst);
        let t0 = Instant::now();
        let (status, body, ctype, retry_after, allow) = route(&req, ctx, &rid);
        let latency_ms = t0.elapsed().as_secs_f64() * 1e3;
        let fields = |extra: &mut Vec<(&'static str, Json)>| {
            let mut f = vec![
                ("request_id", Json::str(rid.clone())),
                ("method", Json::str(req.method.clone())),
                ("path", Json::str(req.path.clone())),
                ("status", Json::num(status as f64)),
                ("latency_ms", Json::num(latency_ms)),
            ];
            f.append(extra);
            f
        };
        if status >= 500 {
            trace::log(Level::Error, "request_failed", &fields(&mut vec![]));
        } else if latency_ms > slow_ms() {
            trace::log(Level::Warn, "slow_request", &fields(&mut vec![
                ("threshold_ms", Json::num(slow_ms())),
            ]));
        } else {
            trace::log(Level::Debug, "request", &fields(&mut vec![]));
        }
        if write_response(
            &mut writer, status, &body, ctype, Some(&rid), retry_after, allow, keep_alive,
        )
        .is_err()
            || !keep_alive
        {
            return;
        }
    }
}

/// Parse one request off the wire. `Ok(None)` = connection closed/idle;
/// `Err((status, msg))` = malformed (`400`) or oversized (`413`).
fn read_request<R: BufRead>(
    r: &mut R,
    max_body: usize,
) -> std::result::Result<Option<HttpRequest>, (u16, String)> {
    let bad = |msg: String| (400u16, msg);
    let mut line = String::new();
    match read_line_capped(r, &mut line) {
        Ok(0) => return Ok(None),
        Ok(_) => {}
        Err(_) => return Ok(None), // timeout / reset: drop quietly
    }
    if line_truncated(&line) {
        return Err(bad(format!("request line exceeds {MAX_LINE_BYTES} bytes")));
    }
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_ascii_uppercase();
    let path = parts.next().unwrap_or("").to_string();
    let version = parts.next().unwrap_or("");
    if method.is_empty() || path.is_empty() || !version.starts_with("HTTP/") {
        return Err(bad(format!("malformed request line {:?}", line.trim_end())));
    }

    let mut content_length = 0usize;
    let mut keep_alive = version != "HTTP/1.0";
    let mut request_id: Option<String> = None;
    let mut deadline_ms: Option<u64> = None;
    for _ in 0..MAX_HEADER_LINES {
        let mut h = String::new();
        match read_line_capped(r, &mut h) {
            Ok(0) => return Err(bad("connection closed mid-headers".to_string())),
            Ok(_) => {}
            Err(e) => return Err(bad(format!("reading headers: {e}"))),
        }
        if line_truncated(&h) {
            return Err(bad(format!("header line exceeds {MAX_LINE_BYTES} bytes")));
        }
        let t = h.trim();
        if t.is_empty() {
            let body = if content_length > 0 {
                if content_length > max_body {
                    // refuse before buffering: the body is never read
                    return Err((
                        413,
                        format!("body too large ({content_length} bytes, limit {max_body})"),
                    ));
                }
                let mut buf = vec![0u8; content_length];
                r.read_exact(&mut buf).map_err(|e| bad(format!("reading body: {e}")))?;
                String::from_utf8(buf).map_err(|_| bad("body is not utf-8".to_string()))?
            } else {
                String::new()
            };
            return Ok(Some(HttpRequest {
                method,
                path,
                keep_alive,
                request_id,
                deadline_ms,
                body,
            }));
        }
        let lower = t.to_ascii_lowercase();
        if let Some(v) = lower.strip_prefix("content-length:") {
            content_length = v
                .trim()
                .parse()
                .map_err(|_| bad(format!("bad content-length {:?}", v.trim())))?;
        } else if let Some(v) = lower.strip_prefix("connection:") {
            match v.trim() {
                "close" => keep_alive = false,
                "keep-alive" => keep_alive = true,
                _ => {}
            }
        } else if lower.starts_with("x-request-id:") {
            // take the value from the original line — lowercasing is
            // length-preserving for ASCII, so the offset is the same —
            // to keep the client's id case intact
            request_id = sanitize_rid(t["x-request-id:".len()..].trim());
        } else if let Some(v) = lower.strip_prefix("x-deadline-ms:") {
            let ms: u64 = v
                .trim()
                .parse()
                .map_err(|_| bad(format!("bad x-deadline-ms {:?}", v.trim())))?;
            if ms == 0 {
                return Err(bad("x-deadline-ms must be positive".to_string()));
            }
            deadline_ms = Some(ms);
        }
    }
    Err(bad("too many header lines".to_string()))
}

/// Accumulated request-head bound for the incremental parser; beyond it
/// the connection gets `431` (the event-loop slowloris/garbage bound).
pub const MAX_HEAD_BYTES: usize = 16 << 10;
const MAX_METHOD_BYTES: usize = 16;
const MAX_PATH_BYTES: usize = 256;

/// A framing failure, carrying the wire contract directly: HTTP status,
/// stable [`ErrorCode`], human message. The connection closes after the
/// error response — framing state cannot be resynchronized.
#[derive(Debug)]
pub struct FrameError {
    pub status: u16,
    pub code: ErrorCode,
    pub msg: String,
}

impl FrameError {
    fn bad(msg: String) -> FrameError {
        FrameError { status: 400, code: ErrorCode::BadRequest, msg }
    }

    fn too_large(msg: String) -> FrameError {
        FrameError { status: 431, code: ErrorCode::HeadersTooLarge, msg }
    }
}

/// One complete request framed off the wire, borrowing from the parser's
/// buffers — no per-request allocation. `path` is empty when the raw
/// path was oversized or non-UTF-8 (nothing routable is); `body` is raw
/// bytes so `/predict` can stream-lex without materializing a `String`.
pub struct Frame<'a> {
    pub method: &'a str,
    pub path: &'a str,
    pub keep_alive: bool,
    pub request_id: Option<&'a str>,
    pub deadline_ms: Option<u64>,
    pub body: &'a [u8],
}

enum FrameState {
    /// Accumulating until the blank line ends the head.
    Head,
    /// Head parsed; waiting for `body_len` bytes after `head_len`.
    Body { head_len: usize, body_len: usize },
}

/// Incremental, resumable HTTP/1.1 request framer for the event loop.
///
/// Feed raw socket bytes with [`feed`](FrameParser::feed); pull complete
/// requests with [`next_frame`](FrameParser::next_frame) and release each
/// with [`consume`](FrameParser::consume) (pipelined requests queue up in
/// the same buffer). The state machine is byte-boundary agnostic: a
/// request split at every byte yields exactly the same frames as one
/// arriving whole. Steady state allocates nothing — head fields land in
/// inline arrays, the buffer's warm capacity is reused, and `consume`
/// compacts in place.
///
/// Error contract mirrors [`read_request`] (`400` malformed, `413`
/// oversized body before buffering) plus `431` for head-size violations
/// only the incremental path can meter (total head bytes, line length,
/// header count).
pub struct FrameParser {
    buf: Vec<u8>,
    /// Resume point for the head-terminator scan (no O(n²) re-scans
    /// under byte-at-a-time arrival).
    scan: usize,
    state: FrameState,
    max_body: usize,
    method: [u8; MAX_METHOD_BYTES],
    method_len: usize,
    path: [u8; MAX_PATH_BYTES],
    path_len: usize,
    path_bad: bool,
    rid: [u8; 64],
    rid_len: usize,
    keep_alive: bool,
    deadline_ms: Option<u64>,
    /// Bytes of the last yielded frame, drained by `consume`.
    yielded: usize,
}

fn strip_cr(l: &[u8]) -> &[u8] {
    match l.split_last() {
        Some((&b'\r', rest)) => rest,
        _ => l,
    }
}

fn trim_bytes(mut b: &[u8]) -> &[u8] {
    while let Some((f, rest)) = b.split_first() {
        if f.is_ascii_whitespace() {
            b = rest;
        } else {
            break;
        }
    }
    while let Some((l, rest)) = b.split_last() {
        if l.is_ascii_whitespace() {
            b = rest;
        } else {
            break;
        }
    }
    b
}

/// Digits-only integer parse with an overflow guard (19 digits max).
fn parse_dec_u64(b: &[u8]) -> Option<u64> {
    if b.is_empty() || b.len() > 19 {
        return None;
    }
    let mut n = 0u64;
    for &c in b {
        if !c.is_ascii_digit() {
            return None;
        }
        n = n * 10 + (c - b'0') as u64;
    }
    Some(n)
}

impl FrameParser {
    pub fn new(max_body: usize) -> FrameParser {
        FrameParser {
            buf: Vec::new(),
            scan: 0,
            state: FrameState::Head,
            max_body,
            method: [0; MAX_METHOD_BYTES],
            method_len: 0,
            path: [0; MAX_PATH_BYTES],
            path_len: 0,
            path_bad: false,
            rid: [0; 64],
            rid_len: 0,
            keep_alive: true,
            deadline_ms: None,
            yielded: 0,
        }
    }

    /// Append raw socket bytes.
    pub fn feed(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Bytes buffered but not yet released by [`consume`](Self::consume).
    pub fn buffered(&self) -> usize {
        self.buf.len()
    }

    /// Drop the last yielded frame's bytes; must be called once per
    /// yielded frame before asking for the next one.
    pub fn consume(&mut self) {
        if self.yielded > 0 {
            self.buf.drain(..self.yielded);
            self.yielded = 0;
        }
        self.scan = 0;
        self.state = FrameState::Head;
    }

    /// Try to frame one complete request out of the buffer. `Ok(None)` =
    /// need more bytes; errors are terminal for the connection. Calling
    /// again without [`consume`](Self::consume) re-yields the same frame.
    pub fn next_frame(&mut self) -> Result<Option<Frame<'_>>, FrameError> {
        if let FrameState::Head = self.state {
            let Some(head_end) = self.find_head_end() else {
                if self.buf.len() > MAX_HEAD_BYTES {
                    return Err(FrameError::too_large(format!(
                        "request head exceeds {MAX_HEAD_BYTES} bytes"
                    )));
                }
                return Ok(None);
            };
            // parse out of a temporarily moved buffer so the head parse
            // can fill `self`'s inline fields (no copy: Vec move)
            let buf = std::mem::take(&mut self.buf);
            let parsed = self.parse_head(&buf[..head_end]);
            self.buf = buf;
            let body_len = parsed?;
            self.state = FrameState::Body { head_len: head_end, body_len };
        }
        let FrameState::Body { head_len, body_len } = self.state else { unreachable!() };
        if self.buf.len() < head_len + body_len {
            return Ok(None);
        }
        self.yielded = head_len + body_len;
        Ok(Some(Frame {
            method: core::str::from_utf8(&self.method[..self.method_len]).unwrap_or(""),
            path: if self.path_bad {
                ""
            } else {
                core::str::from_utf8(&self.path[..self.path_len]).unwrap_or("")
            },
            keep_alive: self.keep_alive,
            request_id: if self.rid_len > 0 {
                core::str::from_utf8(&self.rid[..self.rid_len]).ok()
            } else {
                None
            },
            deadline_ms: self.deadline_ms,
            body: &self.buf[head_len..head_len + body_len],
        }))
    }

    /// Index one past the head terminator (`\r\n\r\n` or, leniently like
    /// [`read_request`]'s `read_line`, bare `\n\n` / `\n\r\n`).
    fn find_head_end(&mut self) -> Option<usize> {
        let buf = &self.buf;
        let mut i = self.scan.saturating_sub(2);
        while i < buf.len() {
            if buf[i] == b'\n' {
                if i + 1 < buf.len() && buf[i + 1] == b'\n' {
                    return Some(i + 2);
                }
                if i + 2 < buf.len() && buf[i + 1] == b'\r' && buf[i + 2] == b'\n' {
                    return Some(i + 3);
                }
            }
            i += 1;
        }
        self.scan = buf.len();
        None
    }

    /// Parse a complete head into the inline fields; returns the body
    /// length. Semantics track [`read_request`] line by line.
    fn parse_head(&mut self, head: &[u8]) -> Result<usize, FrameError> {
        self.method_len = 0;
        self.path_len = 0;
        self.path_bad = false;
        self.rid_len = 0;
        self.deadline_ms = None;
        let mut lines = head.split(|&b| b == b'\n');
        let req_line = strip_cr(lines.next().unwrap_or(&[]));
        if req_line.len() > MAX_LINE_BYTES {
            return Err(FrameError::too_large(format!(
                "request line exceeds {MAX_LINE_BYTES} bytes"
            )));
        }
        let mut parts =
            req_line.split(|&b| b == b' ' || b == b'\t').filter(|t| !t.is_empty());
        let method = parts.next().unwrap_or(&[]);
        let path = parts.next().unwrap_or(&[]);
        let version = parts.next().unwrap_or(&[]);
        if method.is_empty() || path.is_empty() || !version.starts_with(b"HTTP/") {
            return Err(FrameError::bad(format!(
                "malformed request line {:?}",
                String::from_utf8_lossy(req_line)
            )));
        }
        for (i, &b) in method.iter().take(MAX_METHOD_BYTES).enumerate() {
            self.method[i] = b.to_ascii_uppercase();
            self.method_len = i + 1;
        }
        if path.len() > MAX_PATH_BYTES || core::str::from_utf8(path).is_err() {
            self.path_bad = true; // nothing routable is that long — 404s
        } else {
            self.path[..path.len()].copy_from_slice(path);
            self.path_len = path.len();
        }
        self.keep_alive = version != &b"HTTP/1.0"[..];

        let mut content_length = 0usize;
        let mut nlines = 0usize;
        for raw in lines {
            let line = strip_cr(raw);
            if line.is_empty() {
                break; // blank terminator
            }
            nlines += 1;
            if nlines > MAX_HEADER_LINES {
                return Err(FrameError::too_large("too many header lines".to_string()));
            }
            if line.len() > MAX_LINE_BYTES {
                return Err(FrameError::too_large(format!(
                    "header line exceeds {MAX_LINE_BYTES} bytes"
                )));
            }
            let Some(colon) = line.iter().position(|&b| b == b':') else { continue };
            let name = &line[..colon];
            let value = trim_bytes(&line[colon + 1..]);
            if name.eq_ignore_ascii_case(b"content-length") {
                content_length = parse_dec_u64(value).ok_or_else(|| {
                    FrameError::bad(format!(
                        "bad content-length {:?}",
                        String::from_utf8_lossy(value)
                    ))
                })? as usize;
                if content_length > self.max_body {
                    return Err(FrameError {
                        status: 413,
                        code: ErrorCode::BodyTooLarge,
                        msg: format!(
                            "body too large ({content_length} bytes, limit {})",
                            self.max_body
                        ),
                    });
                }
            } else if name.eq_ignore_ascii_case(b"connection") {
                if value.eq_ignore_ascii_case(b"close") {
                    self.keep_alive = false;
                } else if value.eq_ignore_ascii_case(b"keep-alive") {
                    self.keep_alive = true;
                }
            } else if name.eq_ignore_ascii_case(b"x-request-id") {
                for &b in value {
                    if self.rid_len == self.rid.len() {
                        break;
                    }
                    if b.is_ascii_alphanumeric() || matches!(b, b'-' | b'_' | b'.') {
                        self.rid[self.rid_len] = b;
                        self.rid_len += 1;
                    }
                }
            } else if name.eq_ignore_ascii_case(b"x-deadline-ms") {
                let ms = parse_dec_u64(value).ok_or_else(|| {
                    FrameError::bad(format!(
                        "bad x-deadline-ms {:?}",
                        String::from_utf8_lossy(value)
                    ))
                })?;
                if ms == 0 {
                    return Err(FrameError::bad("x-deadline-ms must be positive".to_string()));
                }
                self.deadline_ms = Some(ms);
            }
        }
        Ok(content_length)
    }
}

/// Route one request:
/// `(status, body, content-type, Retry-After secs, Allow header)`.
///
/// Known paths hit with the wrong method answer `405` with an `Allow`
/// header naming the methods that would have worked; only genuinely
/// unknown paths get `404`/`no_route`.
fn route(
    req: &HttpRequest,
    ctx: &ConnCtx,
    rid: &str,
) -> (u16, String, &'static str, Option<u32>, Option<&'static str>) {
    let (path, query) = match req.path.split_once('?') {
        Some((p, q)) => (p, q),
        None => (req.path.as_str(), ""),
    };
    let method = req.method.as_str();
    let json5 =
        |(status, body, retry): (u16, String, Option<u32>)| (status, body, CT_JSON, retry, None);
    let not_allowed = |allow: &'static str| {
        (
            405,
            err_json(
                ErrorCode::MethodNotAllowed,
                &format!("method {} not allowed for {path} (allow: {allow})", req.method),
                Some(rid),
            ),
            CT_JSON,
            None,
            Some(allow),
        )
    };
    match (method, path) {
        ("POST", "/predict") => json5(handle_predict(req, ctx, rid)),
        (_, "/predict") => not_allowed("POST"),
        ("GET", "/models") => (200, ctx.registry.to_json().to_string(), CT_JSON, None, None),
        ("POST", "/models") => {
            let (status, body) = handle_admit(req, ctx, rid);
            (status, body, CT_JSON, None, None)
        }
        (_, "/models") => not_allowed("GET, POST"),
        ("GET", "/metrics") => {
            if query.split('&').any(|kv| kv == "format=prometheus") {
                (200, prometheus_body(ctx), CT_PROM, None, None)
            } else {
                (200, ctx.metrics.snapshot(ctx.queue.len()).to_string(), CT_JSON, None, None)
            }
        }
        (_, "/metrics") => not_allowed("GET"),
        ("GET", "/healthz") => (200, r#"{"status":"ok"}"#.to_string(), CT_JSON, None, None),
        (_, "/healthz") => not_allowed("GET"),
        ("GET", "/readyz") => {
            // readiness: reachable AND able to make progress — not
            // draining, and at least one worker alive to drain the queue
            let draining = ctx.draining.load(Ordering::SeqCst);
            let alive = ctx.workers_alive.load(Ordering::Acquire);
            let ready = !draining && alive > 0;
            let body = Json::obj(vec![
                ("ready", Json::Bool(ready)),
                ("draining", Json::Bool(draining)),
                ("workers_alive", Json::num(alive as f64)),
            ])
            .to_string();
            (if ready { 200 } else { 503 }, body, CT_JSON, None, None)
        }
        (_, "/readyz") => not_allowed("GET"),
        (m, p) => {
            if let Some(rest) = p.strip_prefix("/models/") {
                if rest.is_empty() {
                    // "/models/" names no model — fall through to 404
                } else if let Some(name) = rest.strip_suffix("/profile") {
                    if m == "GET" {
                        let (status, body) = handle_profile(name, ctx, rid);
                        return (status, body, CT_JSON, None, None);
                    }
                    return not_allowed("GET");
                } else if !rest.contains('/') {
                    if m == "DELETE" {
                        let (status, body) = handle_delete(rest, ctx, rid);
                        return (status, body, CT_JSON, None, None);
                    }
                    return not_allowed("DELETE");
                }
            }
            (
                404,
                err_json(ErrorCode::NoRoute, &format!("no route {p}"), Some(rid)),
                CT_JSON,
                None,
                None,
            )
        }
    }
}

/// `GET /metrics?format=prometheus`: the serve metrics exposition plus
/// process-wide compute counters (intra-op pool, popcount kernel
/// dispatch) and the active trace mode.
fn prometheus_body(ctx: &ConnCtx) -> String {
    let mut out = ctx.metrics.prometheus(ctx.queue.len());
    // per-model engine + residency gauges off the registry: the mode
    // label each entry serves under and the storage it actually keeps
    // resident (sub-1-bit/weight on the Encrypted engine)
    let resident = ctx.registry.resident_entries();
    out.push_str(
        "# HELP flexor_model_compute_mode Engine the model serves on (1 = this mode).\n\
         # TYPE flexor_model_compute_mode gauge\n",
    );
    for e in &resident {
        out.push_str(&format!(
            "flexor_model_compute_mode{{model=\"{}\",mode=\"{}\"}} 1\n",
            e.name,
            e.model.mode_label()
        ));
    }
    out.push_str(
        "# HELP flexor_model_resident_bytes Resident weight bytes (quantized + FP residue).\n\
         # TYPE flexor_model_resident_bytes gauge\n",
    );
    for e in &resident {
        out.push_str(&format!(
            "flexor_model_resident_bytes{{model=\"{}\"}} {}\n",
            e.name,
            e.model.resident_bytes()
        ));
    }
    out.push_str(
        "# HELP flexor_model_resident_bits_per_weight Resident bits per quantized weight under the active modes.\n\
         # TYPE flexor_model_resident_bits_per_weight gauge\n",
    );
    for e in &resident {
        out.push_str(&format!(
            "flexor_model_resident_bits_per_weight{{model=\"{}\"}} {}\n",
            e.name,
            e.model.resident_bits_per_weight()
        ));
    }
    out.push_str(&format!(
        "# HELP flexor_model_swaps_total Alias repoints performed by the control plane.\n\
         # TYPE flexor_model_swaps_total counter\n\
         flexor_model_swaps_total {}\n",
        ctx.registry.swaps_total()
    ));
    out.push_str(&format!(
        "# HELP flexor_model_evictions_total Resident models evicted to stay under the byte budget.\n\
         # TYPE flexor_model_evictions_total counter\n\
         flexor_model_evictions_total {}\n",
        ctx.registry.evictions_total()
    ));
    let p = pool::global();
    let c = p.counters();
    out.push_str(&format!(
        "# HELP flexor_pool_threads Intra-op compute threads (incl. callers).\n\
         # TYPE flexor_pool_threads gauge\n\
         flexor_pool_threads {}\n",
        p.threads()
    ));
    out.push_str(&format!(
        "# HELP flexor_pool_jobs_total Jobs submitted to the intra-op pool.\n\
         # TYPE flexor_pool_jobs_total counter\n\
         flexor_pool_jobs_total {}\n",
        c.jobs
    ));
    out.push_str(&format!(
        "# HELP flexor_pool_shards_total Shards dispatched across all jobs.\n\
         # TYPE flexor_pool_shards_total counter\n\
         flexor_pool_shards_total {}\n",
        c.shards
    ));
    out.push_str(&format!(
        "# HELP flexor_pool_shard_panics_total Shards that panicked (contained, DESIGN.md §12).\n\
         # TYPE flexor_pool_shard_panics_total counter\n\
         flexor_pool_shard_panics_total {}\n",
        c.panics
    ));
    out.push_str(&format!(
        "# HELP flexor_pool_job_wait_seconds_total Summed submit-to-first-claim wait.\n\
         # TYPE flexor_pool_job_wait_seconds_total counter\n\
         flexor_pool_job_wait_seconds_total {}\n",
        c.job_wait_ns as f64 / 1e9
    ));
    out.push_str(
        "# HELP flexor_pool_busy_seconds_total Per-thread shard compute time (traced scopes only).\n\
         # TYPE flexor_pool_busy_seconds_total counter\n",
    );
    for (i, &ns) in c.busy_ns.iter().enumerate() {
        let thread = if i == 0 { "caller".to_string() } else { format!("worker-{}", i - 1) };
        out.push_str(&format!(
            "flexor_pool_busy_seconds_total{{thread=\"{thread}\"}} {}\n",
            ns as f64 / 1e9
        ));
    }
    out.push_str(
        "# HELP flexor_popcount_dispatch_total XNOR-GEMM calls per popcount kernel.\n\
         # TYPE flexor_popcount_dispatch_total counter\n",
    );
    for (k, n) in popcount::dispatch_counts() {
        out.push_str(&format!(
            "flexor_popcount_dispatch_total{{kernel=\"{}\"}} {n}\n",
            k.label()
        ));
    }
    out.push_str(&format!(
        "# HELP flexor_trace_mode Active trace sampling mode (1 = this mode).\n\
         # TYPE flexor_trace_mode gauge\n\
         flexor_trace_mode{{mode=\"{}\"}} 1\n",
        ctx.trace_mode.label()
    ));
    out
}

/// `GET /models/<name>/profile`: the model's aggregated per-layer stage
/// timing, annotated with its compute mode and the server's trace dial.
fn handle_profile(name: &str, ctx: &ConnCtx, rid: &str) -> (u16, String) {
    match ctx.registry.get(name) {
        Some(e) => {
            let mut j = e.profile.to_json();
            j.set("model", Json::str(name));
            j.set("compute_mode", Json::str(e.model.mode_label()));
            j.set("trace_mode", Json::str(ctx.trace_mode.label()));
            (200, j.to_string())
        }
        None => {
            (404, err_json(ErrorCode::UnknownModel, &format!("unknown model '{name}'"), Some(rid)))
        }
    }
}

/// Map a control-plane failure onto the HTTP error contract. The
/// acceptance-critical arm: any signature/digest/parse rejection is
/// `409`/`bundle_rejected` — by the time the error reaches here the
/// registry is guaranteed unchanged ([`Registry::admit_from_repo`]).
fn control_error(e: &ControlError) -> (ErrorCode, String) {
    match e {
        ControlError::SwapInProgress(_) => (ErrorCode::SwapInProgress, e.to_string()),
        ControlError::Rejected(_) => (ErrorCode::BundleRejected, e.to_string()),
        ControlError::BadSpec(_) | ControlError::NoRepo => (ErrorCode::BadRequest, e.to_string()),
        ControlError::Unknown(_) => (ErrorCode::UnknownModel, e.to_string()),
    }
}

/// `POST /models`: verify + load `alias@version` from the attached
/// bundle repo and repoint the alias (drain-then-swap, DESIGN.md §13).
fn handle_admit(req: &HttpRequest, ctx: &ConnCtx, rid: &str) -> (u16, String) {
    let parsed = match json::parse(&req.body) {
        Ok(v) => v,
        Err(e) => {
            return (
                400,
                err_json(ErrorCode::BadRequest, &format!("bad json body: {e}"), Some(rid)),
            )
        }
    };
    let Some(spec) = parsed.get("name").as_str() else {
        return (
            400,
            err_json(
                ErrorCode::BadRequest,
                "field 'name' must be a string like \"resnet20@v2\"",
                Some(rid),
            ),
        );
    };
    let lazy = parsed.get("lazy").as_bool().unwrap_or(false);
    match ctx.registry.admit_from_repo(spec, lazy) {
        Ok(report) => {
            trace::log(Level::Info, "model_admitted", &[
                ("request_id", Json::str(rid)),
                ("name", Json::str(report.name.clone())),
                ("swapped_from", match &report.swapped_from {
                    Some(f) => Json::str(f.clone()),
                    None => Json::Null,
                }),
                ("lazy", Json::Bool(report.lazy)),
            ]);
            (
                200,
                Json::obj(vec![
                    ("name", Json::str(report.name)),
                    ("alias", Json::str(report.alias)),
                    ("version", Json::str(report.version)),
                    ("swapped_from", match report.swapped_from {
                        Some(f) => Json::str(f),
                        None => Json::Null,
                    }),
                    ("load_ms", Json::num(report.load_ms)),
                    ("lazy", Json::Bool(report.lazy)),
                    ("request_id", Json::str(rid)),
                ])
                .to_string(),
            )
        }
        Err(e) => {
            let (code, msg) = control_error(&e);
            trace::log(Level::Warn, "model_admit_rejected", &[
                ("request_id", Json::str(rid)),
                ("spec", Json::str(spec)),
                ("code", Json::str(code.label())),
                ("error", Json::str(msg.clone())),
            ]);
            (code.status(), err_json(code, &msg, Some(rid)))
        }
    }
}

/// `DELETE /models/<name>`: drop an alias (all versions) or a single
/// `alias@version` slot. In-flight requests hold their `Arc` and drain;
/// memory frees when the last clone drops.
fn handle_delete(name: &str, ctx: &ConnCtx, rid: &str) -> (u16, String) {
    match ctx.registry.remove(name) {
        Ok(removed) => {
            trace::log(Level::Info, "model_deleted", &[
                ("request_id", Json::str(rid)),
                ("name", Json::str(name)),
                ("removed_versions", Json::num(removed as f64)),
            ]);
            (
                200,
                Json::obj(vec![
                    ("name", Json::str(name)),
                    ("removed_versions", Json::num(removed as f64)),
                    ("request_id", Json::str(rid)),
                ])
                .to_string(),
            )
        }
        Err(e) => {
            let (code, msg) = control_error(&e);
            trace::log(Level::Warn, "model_delete_rejected", &[
                ("request_id", Json::str(rid)),
                ("name", Json::str(name)),
                ("code", Json::str(code.label())),
                ("error", Json::str(msg.clone())),
            ]);
            (code.status(), err_json(code, &msg, Some(rid)))
        }
    }
}

/// Seconds a shed client should wait before retrying: scale the current
/// backlog by the observed mean latency, clamped to [1, 30].
fn retry_after_hint(ctx: &ConnCtx) -> u32 {
    let backlog_ms = ctx.queue.len() as f64 * ctx.metrics.mean_latency_ms();
    ((1.0 + backlog_ms / 1000.0) as u32).clamp(1, 30)
}

/// Count + log a rejection that never reached a worker, so /metrics and
/// the structured log show load shedding and client errors instead of a
/// silent flat line. `shed` marks the 503-with-retry-hint flavour.
fn record_reject(ctx: &ConnCtx, rid: &str, code: ErrorCode, msg: &str, shed: bool) {
    ctx.metrics.record_rejected();
    if shed {
        // 503s with a retry hint are load shedding, not client error
        ctx.metrics.record_shed();
    }
    trace::log(Level::Warn, "request_rejected", &[
        ("request_id", Json::str(rid)),
        ("status", Json::num(code.status() as f64)),
        ("code", Json::str(code.label())),
        ("reason", Json::str(msg)),
    ]);
}

fn handle_predict(req: &HttpRequest, ctx: &ConnCtx, rid: &str) -> (u16, String, Option<u32>) {
    let reject = |code: ErrorCode, msg: &str, retry: Option<u32>| {
        record_reject(ctx, rid, code, msg, retry.is_some());
        (code.status(), err_json(code, msg, Some(rid)), retry)
    };
    if ctx.draining.load(Ordering::SeqCst) {
        return reject(
            ErrorCode::Draining,
            "server is draining, not accepting new requests",
            Some(retry_after_hint(ctx)),
        );
    }
    let parsed = match json::parse(&req.body) {
        Ok(v) => v,
        Err(e) => return reject(ErrorCode::BadRequest, &format!("bad json body: {e}"), None),
    };
    // resolution may lazily (re)load an evicted or lazily-admitted
    // bundle from the repo — a load/verify failure there is a server
    // fault, not a client error
    let entry = {
        let m = parsed.get("model");
        if m.is_null() {
            match ctx.registry.resolve_sole() {
                Ok(Some(e)) => e,
                Ok(None) => {
                    return reject(
                        ErrorCode::BadRequest,
                        "field 'model' is required when multiple models are registered",
                        None,
                    )
                }
                Err(e) => {
                    return reject(ErrorCode::Internal, &format!("model load failed: {e:#}"), None)
                }
            }
        } else {
            let Some(name) = m.as_str() else {
                return reject(ErrorCode::BadRequest, "field 'model' must be a string", None);
            };
            match ctx.registry.resolve(name) {
                Ok(Some(e)) => e,
                Ok(None) => {
                    return reject(
                        ErrorCode::UnknownModel,
                        &format!("unknown model '{name}'"),
                        None,
                    )
                }
                Err(e) => {
                    return reject(ErrorCode::Internal, &format!("model load failed: {e:#}"), None)
                }
            }
        }
    };
    let Some(features) = parsed.get("features").as_f32_vec() else {
        return reject(
            ErrorCode::BadRequest,
            "field 'features' must be an array of numbers",
            None,
        );
    };
    if features.len() != entry.feature_len {
        return reject(
            ErrorCode::BadRequest,
            &format!(
                "expected {} features for model '{}', got {}",
                entry.feature_len,
                entry.name,
                features.len()
            ),
            None,
        );
    }

    let enqueued = Instant::now();
    let deadline = req
        .deadline_ms
        .or(ctx.default_deadline)
        .map(|ms| enqueued + Duration::from_millis(ms));
    let (tx, rx) = mpsc::channel();
    let request = Request { entry, features, respond: Responder::Channel(tx), enqueued, deadline };
    if let Err((_, e)) = ctx.queue.try_push(request) {
        let (code, msg) = match e {
            PushError::Full => (ErrorCode::QueueFull, "admission queue full, retry later"),
            PushError::Closed => (ErrorCode::Draining, "server is shutting down"),
        };
        return reject(code, msg, Some(retry_after_hint(ctx)));
    }
    match rx.recv_timeout(Duration::from_secs(30)) {
        Ok(Ok(p)) => (
            200,
            Json::obj(vec![
                ("model", Json::str(p.model)),
                ("prediction", Json::num(p.class as f64)),
                ("batch_size", Json::num(p.batch_size as f64)),
                ("latency_ms", Json::num(p.latency_ms)),
                ("request_id", Json::str(rid)),
            ])
            .to_string(),
            None,
        ),
        Ok(Err(e)) => {
            let retry = if e.code == ErrorCode::DeadlineExceeded {
                Some(retry_after_hint(ctx))
            } else {
                None
            };
            (e.status(), err_json(e.code, &e.message, Some(rid)), retry)
        }
        Err(mpsc::RecvTimeoutError::Timeout) => {
            (504, err_json(ErrorCode::Timeout, "inference timed out", Some(rid)), None)
        }
        Err(mpsc::RecvTimeoutError::Disconnected) => (
            500,
            err_json(ErrorCode::Internal, "worker dropped the request", Some(rid)),
            None,
        ),
    }
}

/// Longest model name the zero-allocation predict path captures inline;
/// longer names are structurally valid but can never match a registered
/// alias, so they report as unknown without being materialized.
pub const MAX_MODEL_NAME: usize = 160;

#[derive(Clone, Copy, PartialEq, Eq)]
enum PendingKey {
    None,
    Model,
    Features,
}

/// Streaming [`json::Visitor`] for the hot `/predict` body shape
/// `{"model": <str?>, "features": [f32...]}` — extracts both fields in a
/// single pass over the raw bytes with zero allocation in steady state:
/// the model name lands in an inline array and the feature values are
/// written directly into a recycled `Vec<f32>` from the connection
/// arena. Unknown top-level keys are skipped; duplicate keys are
/// last-wins, matching the tree parser the threads path uses.
pub struct PredictVisitor {
    pub features: Vec<f32>,
    model: [u8; MAX_MODEL_NAME],
    model_len: usize,
    model_seen: bool,
    model_bad: bool,
    model_overflow: bool,
    features_seen: bool,
    features_bad: bool,
    depth: u32,
    pending: PendingKey,
    in_features: bool,
}

impl PredictVisitor {
    /// `features` should come from the arena (cleared); its warm capacity
    /// is what makes the steady-state parse allocation-free.
    pub fn new(features: Vec<f32>) -> PredictVisitor {
        PredictVisitor {
            features,
            model: [0; MAX_MODEL_NAME],
            model_len: 0,
            model_seen: false,
            model_bad: false,
            model_overflow: false,
            features_seen: false,
            features_bad: false,
            depth: 0,
            pending: PendingKey::None,
            in_features: false,
        }
    }

    /// The captured model name; `None` when the field was absent, null,
    /// or longer than [`MAX_MODEL_NAME`] (check [`model_seen`] /
    /// [`model_overflow`] to tell which).
    ///
    /// [`model_seen`]: Self::model_seen
    /// [`model_overflow`]: Self::model_overflow
    pub fn model(&self) -> Option<&str> {
        if !self.model_seen || self.model_overflow {
            return None;
        }
        core::str::from_utf8(&self.model[..self.model_len]).ok()
    }

    /// Whether a non-null `model` value appeared (tree-parser parity:
    /// `model: null` behaves exactly like an absent field).
    pub fn model_seen(&self) -> bool {
        self.model_seen
    }

    /// `model` was present but not a string.
    pub fn model_bad(&self) -> bool {
        self.model_bad
    }

    /// `model` was a string longer than the inline capture buffer.
    pub fn model_overflow(&self) -> bool {
        self.model_overflow
    }

    /// `features` was present and a flat array of numbers.
    pub fn features_ok(&self) -> bool {
        self.features_seen && !self.features_bad
    }

    /// Reclaim the feature buffer (for the queue or back to the arena).
    pub fn into_features(self) -> Vec<f32> {
        self.features
    }

    fn scalar_value(&mut self) {
        if self.in_features {
            if self.depth == 2 {
                // handled by the typed callbacks; on_num pushes, the
                // rest mark the array mixed-typed
            } else {
                self.features_bad = true;
            }
        }
        self.pending = PendingKey::None;
    }
}

impl json::Visitor for PredictVisitor {
    fn on_key(&mut self, key: &str) -> Result<(), &'static str> {
        if self.depth == 1 {
            self.pending = match key {
                "model" => {
                    // duplicate key: last value wins, like the tree parser
                    self.model_len = 0;
                    self.model_seen = false;
                    self.model_bad = false;
                    self.model_overflow = false;
                    PendingKey::Model
                }
                "features" => {
                    self.features.clear();
                    self.features_seen = false;
                    self.features_bad = false;
                    PendingKey::Features
                }
                _ => PendingKey::None,
            };
        }
        Ok(())
    }

    fn on_null(&mut self) -> Result<(), &'static str> {
        if self.depth == 1 && self.pending == PendingKey::Features {
            self.features_seen = true;
            self.features_bad = true;
        }
        if self.in_features && self.depth == 2 {
            self.features_bad = true;
        }
        // model: null stays "unseen" — tree-parser parity with is_null()
        self.scalar_value();
        Ok(())
    }

    fn on_bool(&mut self, _b: bool) -> Result<(), &'static str> {
        if self.depth == 1 {
            match self.pending {
                PendingKey::Model => {
                    self.model_seen = true;
                    self.model_bad = true;
                }
                PendingKey::Features => {
                    self.features_seen = true;
                    self.features_bad = true;
                }
                PendingKey::None => {}
            }
        }
        if self.in_features && self.depth == 2 {
            self.features_bad = true;
        }
        self.scalar_value();
        Ok(())
    }

    fn on_num(&mut self, n: f64) -> Result<(), &'static str> {
        if self.in_features && self.depth == 2 {
            self.features.push(n as f32);
        } else if self.depth == 1 {
            match self.pending {
                PendingKey::Model => {
                    self.model_seen = true;
                    self.model_bad = true;
                }
                PendingKey::Features => {
                    self.features_seen = true;
                    self.features_bad = true;
                }
                PendingKey::None => {}
            }
        }
        self.scalar_value();
        Ok(())
    }

    fn on_str(&mut self, s: &str) -> Result<(), &'static str> {
        if self.depth == 1 {
            match self.pending {
                PendingKey::Model => {
                    self.model_seen = true;
                    if s.len() > MAX_MODEL_NAME {
                        self.model_overflow = true;
                    } else {
                        self.model[..s.len()].copy_from_slice(s.as_bytes());
                        self.model_len = s.len();
                    }
                }
                PendingKey::Features => {
                    self.features_seen = true;
                    self.features_bad = true;
                }
                PendingKey::None => {}
            }
        }
        if self.in_features && self.depth == 2 {
            self.features_bad = true;
        }
        self.scalar_value();
        Ok(())
    }

    fn begin_arr(&mut self) -> Result<(), &'static str> {
        if self.in_features {
            // nested array inside features → not a flat numeric vector
            self.features_bad = true;
        } else if self.depth == 1 {
            match self.pending {
                PendingKey::Features => {
                    self.in_features = true;
                    self.features_seen = true;
                }
                PendingKey::Model => {
                    self.model_seen = true;
                    self.model_bad = true;
                }
                PendingKey::None => {}
            }
        }
        self.depth += 1;
        self.pending = PendingKey::None;
        Ok(())
    }

    fn end_arr(&mut self) -> Result<(), &'static str> {
        self.depth = self.depth.saturating_sub(1);
        if self.in_features && self.depth == 1 {
            self.in_features = false;
        }
        Ok(())
    }

    fn begin_obj(&mut self) -> Result<(), &'static str> {
        if self.in_features {
            self.features_bad = true;
        } else if self.depth == 1 {
            match self.pending {
                PendingKey::Model => {
                    self.model_seen = true;
                    self.model_bad = true;
                }
                PendingKey::Features => {
                    self.features_seen = true;
                    self.features_bad = true;
                }
                PendingKey::None => {}
            }
        }
        self.depth += 1;
        self.pending = PendingKey::None;
        Ok(())
    }

    fn end_obj(&mut self) -> Result<(), &'static str> {
        self.depth = self.depth.saturating_sub(1);
        Ok(())
    }
}

fn err_json(code: ErrorCode, msg: &str, rid: Option<&str>) -> String {
    let mut o = Json::obj(vec![
        ("error", Json::str(msg)),
        ("code", Json::str(code.label())),
    ]);
    if let Some(r) = rid {
        o.set("request_id", Json::str(r));
    }
    o.to_string()
}

fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        409 => "Conflict",
        413 => "Payload Too Large",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

/// Render a full response to wire bytes — the single source of the
/// response format for both front-ends (the thread-per-connection path
/// writes it straight out; the event loop appends it to a write buffer).
#[allow(clippy::too_many_arguments)]
fn render_response(
    status: u16,
    body: &str,
    content_type: &str,
    request_id: Option<&str>,
    retry_after: Option<u32>,
    allow: Option<&'static str>,
    keep_alive: bool,
) -> Vec<u8> {
    let rid_header = request_id
        .map(|r| format!("X-Request-Id: {r}\r\n"))
        .unwrap_or_default();
    let retry_header = retry_after
        .map(|s| format!("Retry-After: {s}\r\n"))
        .unwrap_or_default();
    let allow_header = allow
        .map(|a| format!("Allow: {a}\r\n"))
        .unwrap_or_default();
    format!(
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\n{}{}{}Connection: {}\r\n\r\n{}",
        status,
        reason(status),
        content_type,
        body.len(),
        rid_header,
        retry_header,
        allow_header,
        if keep_alive { "keep-alive" } else { "close" },
        body
    )
    .into_bytes()
}

#[allow(clippy::too_many_arguments)]
fn write_response<W: Write>(
    w: &mut W,
    status: u16,
    body: &str,
    content_type: &str,
    request_id: Option<&str>,
    retry_after: Option<u32>,
    allow: Option<&'static str>,
    keep_alive: bool,
) -> std::io::Result<()> {
    // one write_all per response: formatting straight into a NODELAY
    // socket would issue a syscall (and possibly a packet) per fragment
    let msg =
        render_response(status, body, content_type, request_id, retry_after, allow, keep_alive);
    w.write_all(&msg)?;
    w.flush()
}

/// One-shot HTTP/1.1 client — enough for the tests, benches and the
/// `serve` example to drive the server without external crates.
pub mod client {
    use super::*;

    /// Send `method path` with an optional JSON body; returns
    /// `(status, body)`. Uses `Connection: close` (one request per call).
    pub fn request(
        addr: SocketAddr,
        method: &str,
        path: &str,
        body: Option<&str>,
    ) -> Result<(u16, String)> {
        let (status, _headers, body) = request_with_headers(addr, method, path, &[], body)?;
        Ok((status, body))
    }

    /// [`request`] with extra request headers; returns
    /// `(status, response_headers, body)` with header names lower-cased.
    pub fn request_with_headers(
        addr: SocketAddr,
        method: &str,
        path: &str,
        headers: &[(&str, &str)],
        body: Option<&str>,
    ) -> Result<(u16, Vec<(String, String)>, String)> {
        let mut stream = TcpStream::connect(addr).context("connecting to server")?;
        stream.set_nodelay(true).ok();
        stream.set_read_timeout(Some(Duration::from_secs(60)))?;
        let b = body.unwrap_or("");
        let extra: String =
            headers.iter().map(|(k, v)| format!("{k}: {v}\r\n")).collect();
        let msg = format!(
            "{method} {path} HTTP/1.1\r\nHost: flexor-serve\r\nContent-Type: application/json\r\nContent-Length: {}\r\n{extra}Connection: close\r\n\r\n{b}",
            b.len()
        );
        stream.write_all(msg.as_bytes())?;
        stream.flush()?;

        let mut reader = BufReader::new(stream);
        read_response(&mut reader)
    }

    /// A persistent keep-alive connection: many requests over one
    /// socket. This is what the concurrency bench/smoke uses to hold
    /// hundreds of sockets open against the event-loop front-end —
    /// each `request` reuses the established TCP connection instead of
    /// paying a connect per call.
    pub struct Conn {
        reader: BufReader<TcpStream>,
    }

    impl Conn {
        pub fn connect(addr: SocketAddr) -> Result<Self> {
            let stream = TcpStream::connect(addr).context("connecting to server")?;
            stream.set_nodelay(true).ok();
            stream.set_read_timeout(Some(Duration::from_secs(60)))?;
            Ok(Conn { reader: BufReader::new(stream) })
        }

        /// Send one request on the persistent socket and read its
        /// response; the connection stays open for the next call.
        pub fn request(
            &mut self,
            method: &str,
            path: &str,
            body: Option<&str>,
        ) -> Result<(u16, String)> {
            let b = body.unwrap_or("");
            let msg = format!(
                "{method} {path} HTTP/1.1\r\nHost: flexor-serve\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n{b}",
                b.len()
            );
            let stream = self.reader.get_mut();
            stream.write_all(msg.as_bytes())?;
            stream.flush()?;
            let (status, _headers, body) = read_response(&mut self.reader)?;
            Ok((status, body))
        }
    }

    fn read_response(
        reader: &mut BufReader<TcpStream>,
    ) -> Result<(u16, Vec<(String, String)>, String)> {
        let mut status_line = String::new();
        reader.read_line(&mut status_line)?;
        let status: u16 = status_line
            .split_whitespace()
            .nth(1)
            .with_context(|| format!("bad status line {status_line:?}"))?
            .parse()
            .context("non-numeric status code")?;
        let mut content_length = 0usize;
        let mut resp_headers = Vec::new();
        loop {
            let mut h = String::new();
            if reader.read_line(&mut h)? == 0 {
                break;
            }
            let t = h.trim();
            if t.is_empty() {
                break;
            }
            if let Some((name, value)) = t.split_once(':') {
                resp_headers.push((name.to_ascii_lowercase(), value.trim().to_string()));
            }
            let lower = t.to_ascii_lowercase();
            if let Some(v) = lower.strip_prefix("content-length:") {
                content_length = v.trim().parse().context("bad content-length")?;
            }
        }
        let mut buf = vec![0u8; content_length];
        reader.read_exact(&mut buf)?;
        Ok((status, resp_headers, String::from_utf8(buf).context("non-utf8 response body")?))
    }
}

/// Nonblocking readiness-loop front-end: one thread, every connection.
///
/// Architecture (DESIGN.md §14): a level-triggered [`net::Poller`] owns
/// the listener, a cross-thread waker, and all client sockets. Each
/// connection carries an incremental [`FrameParser`], an ordered slot
/// queue (pipelining), and a bounded write buffer. `/predict` bodies are
/// stream-lexed by [`PredictVisitor`] into arena-recycled feature
/// buffers and answered asynchronously through the worker-side
/// [`CompletionBoard`]; admissions (disk + signature verify) and lazy
/// model loads run on short-lived helper threads that answer through an
/// equivalent HTTP board. Everything else routes inline through the same
/// [`route`] used by the threads front-end.
///
/// Backpressure is explicit: a full admission queue, a full pipeline, or
/// a slow reader *suspends* the connection — read interest is dropped,
/// `flexor_http_suspended_connections` rises — and the tick resumes it
/// once [`BatchQueue::has_space`] reports room again.
#[cfg(unix)]
mod ev {
    use std::collections::{HashMap, VecDeque};
    use std::io::ErrorKind;
    use std::os::unix::io::AsRawFd;
    use std::sync::Mutex;

    use super::super::worker::{Completion, CompletionBoard, Response};
    use super::*;
    use crate::substrate::net::{self, Interest};

    const TOKEN_LISTENER: u64 = 0;
    const TOKEN_WAKER: u64 = 1;
    /// In-flight + queued responses per connection before reads pause.
    const MAX_PIPELINE: usize = 16;
    /// Unflushed response bytes per connection before reads pause
    /// (slow-reader bound; responses already promised still queue).
    const MAX_WBUF_BYTES: usize = 256 << 10;
    /// Hard cap on one in-flight request — mirrors the threads path's
    /// 30 s `recv_timeout`; a later completion is dropped.
    const PENDING_TIMEOUT: Duration = Duration::from_secs(30);
    /// Poll timeout: timers (idle/header/pending) are checked per tick.
    const TICK_MS: i32 = 50;
    /// Feature buffers kept warm for zero-allocation `/predict` parses.
    const MAX_ARENA_BUFS: usize = 64;
    /// Shutdown waits this long for in-flight requests to flush.
    const SHUTDOWN_GRACE: Duration = Duration::from_secs(5);

    /// What a pending slot is waiting for — only used to label the
    /// request log line once the answer arrives.
    enum PendingKind {
        Predict,
        Admit,
    }

    impl PendingKind {
        fn method_path(&self) -> (&'static str, &'static str) {
            match self {
                PendingKind::Predict => ("POST", "/predict"),
                PendingKind::Admit => ("POST", "/models"),
            }
        }
    }

    /// Per-request slot, kept in arrival order so pipelined responses go
    /// out in request order regardless of completion order.
    enum Slot {
        /// Rendered response bytes awaiting the write buffer.
        Ready { bytes: Vec<u8>, close: bool },
        /// Answer still being computed elsewhere.
        Pending { seq: u64, t0: Instant, rid: String, keep_alive: bool, kind: PendingKind },
    }

    /// A finished off-loop HTTP unit of work (admission, lazy-load
    /// failure, …) routed back to its connection/slot.
    struct HttpDone {
        conn: u64,
        seq: u64,
        status: u16,
        body: String,
        retry_after: Option<u32>,
    }

    /// [`CompletionBoard`]'s sibling for non-prediction results.
    struct HttpBoard {
        inner: Mutex<Vec<HttpDone>>,
        waker: net::WakeHandle,
    }

    impl HttpBoard {
        fn new(waker: net::WakeHandle) -> HttpBoard {
            HttpBoard { inner: Mutex::new(Vec::new()), waker }
        }

        fn push(&self, d: HttpDone) {
            self.inner.lock().unwrap().push(d);
            self.waker.wake();
        }

        fn drain(&self, out: &mut Vec<HttpDone>) {
            out.append(&mut self.inner.lock().unwrap());
        }
    }

    /// Immediate outcome of dispatching one framed request.
    enum Out {
        Ready { bytes: Vec<u8>, close: bool, suspend: bool },
        Pending { rid: String, keep_alive: bool, kind: PendingKind, t0: Instant },
    }

    /// An asynchronous answer arriving at the loop.
    enum Done {
        Predict(Response),
        Http { status: u16, body: String, retry_after: Option<u32> },
    }

    struct Conn {
        stream: TcpStream,
        token: u64,
        parser: FrameParser,
        slots: VecDeque<Slot>,
        next_seq: u64,
        wbuf: Vec<u8>,
        wpos: usize,
        last_activity: Instant,
        /// When an incomplete request head/body started arriving (the
        /// slowloris clock); cleared on a complete frame or empty buffer.
        head_started: Option<Instant>,
        suspended: bool,
        peer_closed: bool,
        close_after_flush: bool,
        /// Requests served on this connection (keep-alive accounting).
        served: u64,
    }

    pub(super) fn spawn(
        listener: TcpListener,
        ctx: ConnCtx,
        dials: LoopDials,
    ) -> Result<thread::JoinHandle<()>> {
        listener.set_nonblocking(true).context("nonblocking listener")?;
        let mut poller = net::Poller::new().context("creating poller")?;
        let waker = net::Waker::new().context("creating waker")?;
        poller
            .register(listener.as_raw_fd(), TOKEN_LISTENER, Interest::Read)
            .context("registering listener")?;
        poller
            .register(waker.fd(), TOKEN_WAKER, Interest::Read)
            .context("registering waker")?;
        let board = Arc::new(CompletionBoard::new(waker.handle()));
        let http_board = Arc::new(HttpBoard::new(waker.handle()));
        thread::Builder::new()
            .name("serve-loop".to_string())
            .spawn(move || run(listener, poller, waker, board, http_board, ctx, dials))
            .context("spawning event loop")
    }

    #[allow(clippy::too_many_arguments)]
    fn run(
        listener: TcpListener,
        mut poller: net::Poller,
        mut waker: net::Waker,
        board: Arc<CompletionBoard>,
        http_board: Arc<HttpBoard>,
        ctx: ConnCtx,
        dials: LoopDials,
    ) {
        let mut conns: HashMap<u64, Conn> = HashMap::new();
        let mut next_token: u64 = 2;
        let mut events: Vec<net::Event> = Vec::with_capacity(256);
        let mut done: Vec<Completion> = Vec::new();
        let mut admin: Vec<HttpDone> = Vec::new();
        let mut arena: Vec<Vec<f32>> = Vec::new();
        let mut lexer = json::Lexer::new();
        let mut shutdown_since: Option<Instant> = None;
        let mut dead: Vec<u64> = Vec::new();

        loop {
            if poller.wait(TICK_MS, &mut events).is_err() {
                thread::sleep(Duration::from_millis(5));
            }
            let now = Instant::now();
            let shutting_down = ctx.shutdown.load(Ordering::SeqCst);
            if shutting_down && shutdown_since.is_none() {
                shutdown_since = Some(now);
                poller.deregister(listener.as_raw_fd()).ok();
            }

            for e in &events {
                match e.token {
                    TOKEN_LISTENER => accept_ready(
                        &listener,
                        &mut poller,
                        &mut conns,
                        &mut next_token,
                        &ctx,
                        &dials,
                        shutting_down,
                        now,
                    ),
                    TOKEN_WAKER => waker.drain(),
                    tok => {
                        let Some(conn) = conns.get_mut(&tok) else { continue };
                        let mut alive = true;
                        if e.readable && !conn.suspended {
                            alive = conn_read(
                                conn,
                                &ctx,
                                &mut lexer,
                                &mut arena,
                                &board,
                                &http_board,
                                shutting_down,
                                now,
                            );
                        }
                        if alive {
                            alive = flush_conn(conn, now);
                        }
                        if !alive || (e.closed && !e.readable) {
                            dead.push(tok);
                        } else {
                            update_interest(&mut poller, conn);
                        }
                    }
                }
            }

            // answers computed elsewhere: workers (predictions, with the
            // feature buffer riding back for the arena) and helper
            // threads (admissions, lazy loads)
            board.drain(&mut done);
            for c in done.drain(..) {
                recycle(&mut arena, c.features);
                deliver(&mut conns, &mut poller, &ctx, c.conn, c.seq, Done::Predict(c.result), now, &mut dead);
            }
            http_board.drain(&mut admin);
            for d in admin.drain(..) {
                let out = Done::Http { status: d.status, body: d.body, retry_after: d.retry_after };
                deliver(&mut conns, &mut poller, &ctx, d.conn, d.seq, out, now, &mut dead);
            }

            // timers + backpressure resume, once per tick
            for (tok, conn) in conns.iter_mut() {
                if dead.contains(tok) {
                    continue;
                }
                let mut changed = false;
                if conn.suspended
                    && ctx.queue.has_space()
                    && conn.slots.len() < MAX_PIPELINE
                    && conn.wbuf.len() - conn.wpos <= MAX_WBUF_BYTES
                {
                    set_suspended(conn, false, &ctx.metrics);
                    process_frames(
                        conn,
                        &ctx,
                        &mut lexer,
                        &mut arena,
                        &board,
                        &http_board,
                        shutting_down,
                        now,
                    );
                    changed = true;
                }
                if let Some(t) = conn.head_started {
                    if !conn.close_after_flush
                        && now.duration_since(t).as_millis() as u64 > dials.header_ms
                    {
                        // slowloris: an incomplete head/body outstayed its
                        // budget — answer 408 and hang up
                        let rid = trace::next_request_id();
                        let msg = "timed out waiting for request head/body";
                        record_reject(&ctx, &rid, ErrorCode::RequestTimeout, msg, false);
                        let body = err_json(ErrorCode::RequestTimeout, msg, Some(&rid));
                        conn.slots.push_back(Slot::Ready {
                            bytes: render_response(408, &body, CT_JSON, Some(&rid), None, None, false),
                            close: true,
                        });
                        conn.close_after_flush = true;
                        conn.head_started = None;
                        changed = true;
                    }
                }
                if conn.parser.buffered() == 0
                    && conn.slots.is_empty()
                    && conn.wpos == conn.wbuf.len()
                    && now.duration_since(conn.last_activity).as_millis() as u64 > dials.idle_ms
                {
                    dead.push(*tok);
                    continue;
                }
                for slot in conn.slots.iter_mut() {
                    let (rid, keep_alive, t0, method, path) = match &*slot {
                        Slot::Pending { rid, keep_alive, t0, kind, .. }
                            if now.duration_since(*t0) > PENDING_TIMEOUT =>
                        {
                            let (m, p) = kind.method_path();
                            (rid.clone(), *keep_alive, *t0, m, p)
                        }
                        _ => continue,
                    };
                    log_request(&rid, method, path, 504, t0);
                    let body = err_json(ErrorCode::Timeout, "inference timed out", Some(&rid));
                    let bytes =
                        render_response(504, &body, CT_JSON, Some(&rid), None, None, keep_alive);
                    *slot = Slot::Ready { bytes, close: !keep_alive };
                    changed = true;
                }
                if changed {
                    if flush_conn(conn, now) {
                        update_interest(&mut poller, conn);
                    } else {
                        dead.push(*tok);
                    }
                }
            }

            dead.sort_unstable();
            dead.dedup();
            for tok in dead.drain(..) {
                if let Some(conn) = conns.remove(&tok) {
                    poller.deregister(conn.stream.as_raw_fd()).ok();
                    if conn.suspended {
                        ctx.metrics.conn_resumed();
                    }
                    ctx.metrics.conn_closed();
                }
            }

            if let Some(t) = shutdown_since {
                let busy = conns.values().any(|c| {
                    c.wpos < c.wbuf.len()
                        || c.slots.iter().any(|s| matches!(s, Slot::Pending { .. }))
                });
                if !busy || now.duration_since(t) > SHUTDOWN_GRACE {
                    break;
                }
            }
        }

        for (_, conn) in conns.drain() {
            poller.deregister(conn.stream.as_raw_fd()).ok();
            if conn.suspended {
                ctx.metrics.conn_resumed();
            }
            ctx.metrics.conn_closed();
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn accept_ready(
        listener: &TcpListener,
        poller: &mut net::Poller,
        conns: &mut HashMap<u64, Conn>,
        next_token: &mut u64,
        ctx: &ConnCtx,
        dials: &LoopDials,
        shutting_down: bool,
        now: Instant,
    ) {
        loop {
            let (stream, _peer) = match listener.accept() {
                Ok(s) => s,
                Err(e) if e.kind() == ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => return,
            };
            if shutting_down {
                continue; // shutdown wake-connect or a last-gasp client
            }
            if conns.len() >= dials.max_conns {
                let rid = trace::next_request_id();
                let msg = format!("connection limit reached ({}), retry later", dials.max_conns);
                record_reject(ctx, &rid, ErrorCode::QueueFull, &msg, true);
                let body = err_json(ErrorCode::QueueFull, &msg, Some(&rid));
                let bytes = render_response(503, &body, CT_JSON, Some(&rid), Some(1), None, false);
                // best-effort: the 503 fits in the socket buffer or the
                // client just sees a close — either way we shed
                stream.set_nonblocking(true).ok();
                let _ = (&stream).write(&bytes);
                continue;
            }
            stream.set_nodelay(true).ok();
            if stream.set_nonblocking(true).is_err() {
                continue;
            }
            let token = *next_token;
            *next_token += 1;
            if poller.register(stream.as_raw_fd(), token, Interest::Read).is_err() {
                continue;
            }
            ctx.metrics.conn_opened();
            conns.insert(token, Conn {
                stream,
                token,
                parser: FrameParser::new(ctx.max_body),
                slots: VecDeque::new(),
                next_seq: 0,
                wbuf: Vec::new(),
                wpos: 0,
                last_activity: now,
                head_started: None,
                suspended: false,
                peer_closed: false,
                close_after_flush: false,
                served: 0,
            });
        }
    }

    /// Pull everything the socket has, then frame + dispatch. `false` =
    /// connection is finished.
    #[allow(clippy::too_many_arguments)]
    fn conn_read(
        conn: &mut Conn,
        ctx: &ConnCtx,
        lexer: &mut json::Lexer,
        arena: &mut Vec<Vec<f32>>,
        board: &Arc<CompletionBoard>,
        http_board: &Arc<HttpBoard>,
        shutting_down: bool,
        now: Instant,
    ) -> bool {
        let mut scratch = [0u8; 16 << 10];
        loop {
            match (&conn.stream).read(&mut scratch) {
                Ok(0) => {
                    conn.peer_closed = true;
                    break;
                }
                Ok(n) => {
                    conn.parser.feed(&scratch[..n]);
                    conn.last_activity = now;
                    // a body + pipelined head can legitimately buffer up
                    // to max_body + a head; beyond that, let frames drain
                    if conn.parser.buffered() > ctx.max_body + MAX_HEAD_BYTES + scratch.len() {
                        break;
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => return false,
            }
        }
        process_frames(conn, ctx, lexer, arena, board, http_board, shutting_down, now);
        if conn.peer_closed
            && conn.slots.is_empty()
            && conn.wpos == conn.wbuf.len()
            && conn.parser.buffered() == 0
        {
            return false;
        }
        true
    }

    /// Frame and dispatch as many buffered requests as backpressure
    /// allows; updates the slowloris clock.
    #[allow(clippy::too_many_arguments)]
    fn process_frames(
        conn: &mut Conn,
        ctx: &ConnCtx,
        lexer: &mut json::Lexer,
        arena: &mut Vec<Vec<f32>>,
        board: &Arc<CompletionBoard>,
        http_board: &Arc<HttpBoard>,
        shutting_down: bool,
        now: Instant,
    ) {
        loop {
            if conn.close_after_flush {
                break;
            }
            if conn.slots.len() >= MAX_PIPELINE || conn.wbuf.len() - conn.wpos > MAX_WBUF_BYTES {
                set_suspended(conn, true, &ctx.metrics);
                break;
            }
            let seq = conn.next_seq;
            let out = match conn.parser.next_frame() {
                Ok(None) => break,
                Err(fe) => {
                    // framing is unrecoverable: answer + close, like the
                    // threads path's bad-request arm
                    let rid = trace::next_request_id();
                    ctx.metrics.record_rejected();
                    trace::log(Level::Warn, "bad_request", &[
                        ("request_id", Json::str(rid.clone())),
                        ("status", Json::num(fe.status as f64)),
                        ("error", Json::str(fe.msg.clone())),
                    ]);
                    let body = err_json(fe.code, &fe.msg, Some(&rid));
                    conn.slots.push_back(Slot::Ready {
                        bytes: render_response(fe.status, &body, CT_JSON, Some(&rid), None, None, false),
                        close: true,
                    });
                    conn.close_after_flush = true;
                    break;
                }
                Ok(Some(frame)) => dispatch_frame(
                    frame,
                    conn.token,
                    seq,
                    ctx,
                    lexer,
                    arena,
                    board,
                    http_board,
                    shutting_down,
                ),
            };
            conn.parser.consume();
            conn.served += 1;
            if conn.served > 1 {
                ctx.metrics.record_keepalive_reuse();
            }
            match out {
                Out::Ready { bytes, close, suspend } => {
                    conn.slots.push_back(Slot::Ready { bytes, close });
                    if close {
                        conn.close_after_flush = true;
                    }
                    if suspend {
                        set_suspended(conn, true, &ctx.metrics);
                    }
                    if close || suspend {
                        break;
                    }
                }
                Out::Pending { rid, keep_alive, kind, t0 } => {
                    conn.slots.push_back(Slot::Pending { seq, t0, rid, keep_alive, kind });
                    conn.next_seq += 1;
                    if !keep_alive {
                        conn.close_after_flush = true;
                        break;
                    }
                }
            }
        }
        conn.head_started = if conn.parser.buffered() > 0
            && !conn.suspended
            && !conn.close_after_flush
        {
            Some(conn.head_started.unwrap_or(now))
        } else {
            None
        };
    }

    /// One framed request → an immediate response or a pending slot.
    #[allow(clippy::too_many_arguments)]
    fn dispatch_frame(
        frame: Frame<'_>,
        token: u64,
        seq: u64,
        ctx: &ConnCtx,
        lexer: &mut json::Lexer,
        arena: &mut Vec<Vec<f32>>,
        board: &Arc<CompletionBoard>,
        http_board: &Arc<HttpBoard>,
        shutting_down: bool,
    ) -> Out {
        let t0 = Instant::now();
        let rid = frame.request_id.map(str::to_string).unwrap_or_else(trace::next_request_id);
        let keep_alive = frame.keep_alive && !shutting_down;
        let path_only = frame.path.split('?').next().unwrap_or("");
        if frame.method == "POST" && path_only == "/predict" {
            return ev_predict(
                frame.body,
                frame.deadline_ms,
                rid,
                keep_alive,
                token,
                seq,
                ctx,
                lexer,
                arena,
                board,
                http_board,
                t0,
            );
        }
        if frame.method == "POST" && path_only == "/models" {
            // admissions hit disk + signature verification — off-loop
            let Ok(body) = core::str::from_utf8(frame.body) else {
                let msg = "body is not utf-8";
                record_reject(ctx, &rid, ErrorCode::BadRequest, msg, false);
                log_request(&rid, "POST", "/models", 400, t0);
                let body = err_json(ErrorCode::BadRequest, msg, Some(&rid));
                return Out::Ready {
                    bytes: render_response(400, &body, CT_JSON, Some(&rid), None, None, keep_alive),
                    close: !keep_alive,
                    suspend: false,
                };
            };
            let body = body.to_string();
            let ctx2 = ctx.clone();
            let hb = http_board.clone();
            let rid2 = rid.clone();
            let deadline_ms = frame.deadline_ms;
            let spawned = thread::Builder::new()
                .name("serve-admit".to_string())
                .spawn(move || {
                    let req = HttpRequest {
                        method: "POST".to_string(),
                        path: "/models".to_string(),
                        keep_alive: true,
                        request_id: Some(rid2.clone()),
                        deadline_ms,
                        body,
                    };
                    let (status, body) = handle_admit(&req, &ctx2, &rid2);
                    hb.push(HttpDone { conn: token, seq, status, body, retry_after: None });
                })
                .is_ok();
            if !spawned {
                let msg = "admission worker unavailable";
                record_reject(ctx, &rid, ErrorCode::Internal, msg, false);
                log_request(&rid, "POST", "/models", 500, t0);
                let body = err_json(ErrorCode::Internal, msg, Some(&rid));
                return Out::Ready {
                    bytes: render_response(500, &body, CT_JSON, Some(&rid), None, None, keep_alive),
                    close: !keep_alive,
                    suspend: false,
                };
            }
            return Out::Pending { rid, keep_alive, kind: PendingKind::Admit, t0 };
        }
        // everything else (metrics, health, registry peeks, 404/405s) is
        // in-memory cheap — route inline, exactly as the threads path
        let req = HttpRequest {
            method: frame.method.to_string(),
            path: frame.path.to_string(),
            keep_alive,
            request_id: Some(rid.clone()),
            deadline_ms: frame.deadline_ms,
            body: String::new(),
        };
        let (status, body, ctype, retry_after, allow) = route(&req, ctx, &rid);
        log_request(&rid, &req.method, &req.path, status, t0);
        Out::Ready {
            bytes: render_response(status, &body, ctype, Some(&rid), retry_after, allow, keep_alive),
            close: !keep_alive,
            suspend: false,
        }
    }

    /// Reject a `/predict` without touching a worker: count, log the
    /// request line, render. `suspend` marks queue-full backpressure.
    #[allow(clippy::too_many_arguments)]
    fn reject(
        ctx: &ConnCtx,
        rid: &str,
        code: ErrorCode,
        msg: &str,
        retry: Option<u32>,
        keep_alive: bool,
        suspend: bool,
        t0: Instant,
    ) -> Out {
        record_reject(ctx, rid, code, msg, retry.is_some());
        log_request(rid, "POST", "/predict", code.status(), t0);
        let body = err_json(code, msg, Some(rid));
        Out::Ready {
            bytes: render_response(code.status(), &body, CT_JSON, Some(rid), retry, None, keep_alive),
            close: !keep_alive,
            suspend,
        }
    }

    /// The hot path: stream-lex the body straight into an arena buffer,
    /// resolve the model without locks when it is resident, enqueue with
    /// a [`Responder::Completion`] so the answer comes back through the
    /// board. Error contract is byte-for-byte the threads path's.
    #[allow(clippy::too_many_arguments)]
    fn ev_predict(
        body: &[u8],
        deadline_ms: Option<u64>,
        rid: String,
        keep_alive: bool,
        token: u64,
        seq: u64,
        ctx: &ConnCtx,
        lexer: &mut json::Lexer,
        arena: &mut Vec<Vec<f32>>,
        board: &Arc<CompletionBoard>,
        http_board: &Arc<HttpBoard>,
        t0: Instant,
    ) -> Out {
        if ctx.draining.load(Ordering::SeqCst) {
            return reject(
                ctx,
                &rid,
                ErrorCode::Draining,
                "server is draining, not accepting new requests",
                Some(retry_after_hint(ctx)),
                keep_alive,
                false,
                t0,
            );
        }
        let mut feats = arena.pop().unwrap_or_default();
        feats.clear();
        let mut v = PredictVisitor::new(feats);
        if let Err(e) = lexer.lex(body, &mut v) {
            let msg = format!("bad json body: {e}");
            recycle(arena, v.into_features());
            return reject(ctx, &rid, ErrorCode::BadRequest, &msg, None, keep_alive, false, t0);
        }
        if v.model_bad() {
            recycle(arena, v.into_features());
            return reject(
                ctx,
                &rid,
                ErrorCode::BadRequest,
                "field 'model' must be a string",
                None,
                keep_alive,
                false,
                t0,
            );
        }
        // lock-free-ish fast path: resident models resolve with a peek;
        // anything that might need a bundle load leaves the loop thread
        let entry = if !v.model_seen() {
            ctx.registry.sole()
        } else if let Some(name) = v.model() {
            ctx.registry.get(name)
        } else {
            // longer than any registrable alias — cannot exist
            let msg = format!("unknown model (name exceeds {MAX_MODEL_NAME} bytes)");
            recycle(arena, v.into_features());
            return reject(ctx, &rid, ErrorCode::UnknownModel, &msg, None, keep_alive, false, t0);
        };
        let Some(entry) = entry else {
            return offload_predict(v, deadline_ms, rid, keep_alive, token, seq, ctx, board, http_board, t0);
        };
        if !v.features_ok() {
            recycle(arena, v.into_features());
            return reject(
                ctx,
                &rid,
                ErrorCode::BadRequest,
                "field 'features' must be an array of numbers",
                None,
                keep_alive,
                false,
                t0,
            );
        }
        if v.features.len() != entry.feature_len {
            let msg = format!(
                "expected {} features for model '{}', got {}",
                entry.feature_len,
                entry.name,
                v.features.len()
            );
            recycle(arena, v.into_features());
            return reject(ctx, &rid, ErrorCode::BadRequest, &msg, None, keep_alive, false, t0);
        }
        let enqueued = Instant::now();
        let deadline = deadline_ms
            .or(ctx.default_deadline)
            .map(|ms| enqueued + Duration::from_millis(ms));
        let request = Request {
            entry,
            features: v.into_features(),
            respond: Responder::Completion { board: board.clone(), conn: token, seq },
            enqueued,
            deadline,
        };
        match ctx.queue.try_push(request) {
            Ok(()) => Out::Pending { rid, keep_alive, kind: PendingKind::Predict, t0 },
            Err((req, e)) => {
                recycle(arena, req.features);
                let (code, msg) = match e {
                    PushError::Full => (ErrorCode::QueueFull, "admission queue full, retry later"),
                    PushError::Closed => (ErrorCode::Draining, "server is shutting down"),
                };
                // Full → stop reading this connection until the queue
                // drains (satellite contract: stalled queue is visible
                // as rising suspended-connection gauge, not a read spin)
                let suspend = e == PushError::Full;
                reject(ctx, &rid, code, msg, Some(retry_after_hint(ctx)), keep_alive, suspend, t0)
            }
        }
    }

    /// Slow-path `/predict`: the model may need a repo load (disk +
    /// signature verify), which must not stall the loop. A helper thread
    /// resolves, re-validates, and either enqueues (same completion
    /// route) or pushes the rejection through the HTTP board.
    #[allow(clippy::too_many_arguments)]
    fn offload_predict(
        v: PredictVisitor,
        deadline_ms: Option<u64>,
        rid: String,
        keep_alive: bool,
        token: u64,
        seq: u64,
        ctx: &ConnCtx,
        board: &Arc<CompletionBoard>,
        http_board: &Arc<HttpBoard>,
        t0: Instant,
    ) -> Out {
        let ctx2 = ctx.clone();
        let board = board.clone();
        let hb = http_board.clone();
        let rid2 = rid.clone();
        let name = v.model().map(str::to_string);
        let features_ok = v.features_ok();
        let features = v.into_features();
        let spawned = thread::Builder::new()
            .name("serve-resolve".to_string())
            .spawn(move || {
                let fail = |code: ErrorCode, msg: &str, retry: Option<u32>| {
                    record_reject(&ctx2, &rid2, code, msg, retry.is_some());
                    hb.push(HttpDone {
                        conn: token,
                        seq,
                        status: code.status(),
                        body: err_json(code, msg, Some(&rid2)),
                        retry_after: retry,
                    });
                };
                let resolved = match &name {
                    None => match ctx2.registry.resolve_sole() {
                        Ok(Some(e)) => Ok(e),
                        Ok(None) => Err((
                            ErrorCode::BadRequest,
                            "field 'model' is required when multiple models are registered"
                                .to_string(),
                        )),
                        Err(e) => Err((ErrorCode::Internal, format!("model load failed: {e:#}"))),
                    },
                    Some(n) => match ctx2.registry.resolve(n) {
                        Ok(Some(e)) => Ok(e),
                        Ok(None) => Err((ErrorCode::UnknownModel, format!("unknown model '{n}'"))),
                        Err(e) => Err((ErrorCode::Internal, format!("model load failed: {e:#}"))),
                    },
                };
                let entry = match resolved {
                    Ok(e) => e,
                    Err((code, msg)) => return fail(code, &msg, None),
                };
                if !features_ok {
                    return fail(
                        ErrorCode::BadRequest,
                        "field 'features' must be an array of numbers",
                        None,
                    );
                }
                if features.len() != entry.feature_len {
                    let msg = format!(
                        "expected {} features for model '{}', got {}",
                        entry.feature_len,
                        entry.name,
                        features.len()
                    );
                    return fail(ErrorCode::BadRequest, &msg, None);
                }
                let enqueued = Instant::now();
                let deadline = deadline_ms
                    .or(ctx2.default_deadline)
                    .map(|ms| enqueued + Duration::from_millis(ms));
                let request = Request {
                    entry,
                    features,
                    respond: Responder::Completion { board, conn: token, seq },
                    enqueued,
                    deadline,
                };
                if let Err((_, e)) = ctx2.queue.try_push(request) {
                    let (code, msg) = match e {
                        PushError::Full => {
                            (ErrorCode::QueueFull, "admission queue full, retry later")
                        }
                        PushError::Closed => (ErrorCode::Draining, "server is shutting down"),
                    };
                    fail(code, msg, Some(retry_after_hint(&ctx2)));
                }
            })
            .is_ok();
        if !spawned {
            let msg = "resolver worker unavailable";
            return reject(ctx, &rid, ErrorCode::Internal, msg, None, keep_alive, false, t0);
        }
        Out::Pending { rid, keep_alive, kind: PendingKind::Predict, t0 }
    }

    /// Route an asynchronous answer into its connection's slot, keeping
    /// pipelined response order. A missing connection or slot means the
    /// client is gone or the request already 504'd — drop silently.
    #[allow(clippy::too_many_arguments)]
    fn deliver(
        conns: &mut HashMap<u64, Conn>,
        poller: &mut net::Poller,
        ctx: &ConnCtx,
        tok: u64,
        seq: u64,
        done: Done,
        now: Instant,
        dead: &mut Vec<u64>,
    ) {
        let Some(conn) = conns.get_mut(&tok) else { return };
        let Some(idx) = conn.slots.iter().position(|s| match s {
            Slot::Pending { seq: s2, .. } => *s2 == seq,
            Slot::Ready { .. } => false,
        }) else {
            return;
        };
        let (rid, keep_alive, t0, method, path) = match &conn.slots[idx] {
            Slot::Pending { rid, keep_alive, t0, kind, .. } => {
                let (m, p) = kind.method_path();
                (rid.clone(), *keep_alive, *t0, m, p)
            }
            Slot::Ready { .. } => return,
        };
        let (status, body, retry_after) = match done {
            Done::Predict(Ok(p)) => (
                200,
                Json::obj(vec![
                    ("model", Json::str(p.model)),
                    ("prediction", Json::num(p.class as f64)),
                    ("batch_size", Json::num(p.batch_size as f64)),
                    ("latency_ms", Json::num(p.latency_ms)),
                    ("request_id", Json::str(rid.clone())),
                ])
                .to_string(),
                None,
            ),
            Done::Predict(Err(e)) => {
                let retry = if e.code == ErrorCode::DeadlineExceeded {
                    Some(retry_after_hint(ctx))
                } else {
                    None
                };
                (e.status(), err_json(e.code, &e.message, Some(&rid)), retry)
            }
            Done::Http { status, body, retry_after } => (status, body, retry_after),
        };
        log_request(&rid, method, path, status, t0);
        conn.slots[idx] = Slot::Ready {
            bytes: render_response(status, &body, CT_JSON, Some(&rid), retry_after, None, keep_alive),
            close: !keep_alive,
        };
        if flush_conn(conn, now) {
            update_interest(poller, conn);
        } else {
            dead.push(tok);
        }
    }

    /// Promote the contiguous Ready prefix into the write buffer, then
    /// push bytes until the socket would block. `false` = connection
    /// finished (closing response flushed, or peer gone and drained).
    fn flush_conn(conn: &mut Conn, now: Instant) -> bool {
        while matches!(conn.slots.front(), Some(Slot::Ready { .. })) {
            let Some(Slot::Ready { bytes, close }) = conn.slots.pop_front() else {
                unreachable!()
            };
            conn.wbuf.extend_from_slice(&bytes);
            if close {
                conn.close_after_flush = true;
            }
        }
        while conn.wpos < conn.wbuf.len() {
            match (&conn.stream).write(&conn.wbuf[conn.wpos..]) {
                Ok(0) => return false,
                Ok(n) => {
                    conn.wpos += n;
                    conn.last_activity = now;
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => return false,
            }
        }
        if conn.wpos == conn.wbuf.len() {
            conn.wbuf.clear();
            conn.wpos = 0;
            if conn.close_after_flush && conn.slots.is_empty() {
                return false;
            }
            if conn.peer_closed && conn.slots.is_empty() && conn.parser.buffered() == 0 {
                return false;
            }
        }
        true
    }

    /// Re-derive the poller interest set from connection state. Uses
    /// idempotent `register` (not `set_interest`) so a fully-parked
    /// connection that was deregistered can come back.
    fn update_interest(poller: &mut net::Poller, conn: &Conn) {
        let wants_write = conn.wpos < conn.wbuf.len();
        let wants_read = !conn.suspended
            && !conn.close_after_flush
            && !conn.peer_closed
            && conn.slots.len() < MAX_PIPELINE;
        let fd = conn.stream.as_raw_fd();
        let res = match (wants_read, wants_write) {
            (true, true) => poller.register(fd, conn.token, Interest::ReadWrite),
            (true, false) => poller.register(fd, conn.token, Interest::Read),
            (false, true) => poller.register(fd, conn.token, Interest::Write),
            // level-triggered: a parked connection must leave the set or
            // its readable socket would spin the loop; the tick timer is
            // what watches it while parked
            (false, false) => poller.deregister(fd),
        };
        res.ok();
    }

    fn set_suspended(conn: &mut Conn, on: bool, metrics: &ServeMetrics) {
        if conn.suspended == on {
            return;
        }
        conn.suspended = on;
        if on {
            metrics.conn_suspended();
        } else {
            metrics.conn_resumed();
        }
    }

    /// Return a feature buffer to the warm arena (bounded).
    fn recycle(arena: &mut Vec<Vec<f32>>, mut buf: Vec<f32>) {
        if arena.len() < MAX_ARENA_BUFS {
            buf.clear();
            arena.push(buf);
        }
    }

    /// The per-request log line, mirroring the threads path exactly.
    fn log_request(rid: &str, method: &str, path: &str, status: u16, t0: Instant) {
        let latency_ms = t0.elapsed().as_secs_f64() * 1e3;
        let mut f = vec![
            ("request_id", Json::str(rid)),
            ("method", Json::str(method)),
            ("path", Json::str(path)),
            ("status", Json::num(status as f64)),
            ("latency_ms", Json::num(latency_ms)),
        ];
        if status >= 500 {
            trace::log(Level::Error, "request_failed", &f);
        } else if latency_ms > slow_ms() {
            f.push(("threshold_ms", Json::num(slow_ms())));
            trace::log(Level::Warn, "slow_request", &f);
        } else {
            trace::log(Level::Debug, "request", &f);
        }
    }
}

#[cfg(test)]
mod tests {
    //! Wire-format units; full registry → queue → worker → HTTP round
    //! trips live in `rust/tests/serve.rs` and `rust/tests/observe.rs`
    //! (they need a model bundle).
    use super::*;
    use std::io::Cursor;

    fn parse_str(s: &str) -> std::result::Result<Option<HttpRequest>, (u16, String)> {
        read_request(&mut Cursor::new(s.as_bytes().to_vec()), DEFAULT_MAX_BODY_BYTES)
    }

    #[test]
    fn parses_post_with_body() {
        let req = parse_str(
            "POST /predict HTTP/1.1\r\nHost: x\r\nContent-Length: 11\r\n\r\nhello world",
        )
        .unwrap()
        .unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/predict");
        assert!(req.keep_alive); // HTTP/1.1 default
        assert!(req.request_id.is_none());
        assert_eq!(req.body, "hello world");
    }

    #[test]
    fn connection_header_controls_keep_alive() {
        let req = parse_str("GET /metrics HTTP/1.1\r\nConnection: close\r\n\r\n")
            .unwrap()
            .unwrap();
        assert!(!req.keep_alive);
        let req = parse_str("GET /metrics HTTP/1.0\r\n\r\n").unwrap().unwrap();
        assert!(!req.keep_alive);
        let req = parse_str("GET / HTTP/1.0\r\nConnection: keep-alive\r\n\r\n")
            .unwrap()
            .unwrap();
        assert!(req.keep_alive);
    }

    #[test]
    fn request_id_header_parsed_case_preserving() {
        let req = parse_str("GET /metrics HTTP/1.1\r\nX-Request-ID: My-Id.01\r\n\r\n")
            .unwrap()
            .unwrap();
        assert_eq!(req.request_id.as_deref(), Some("My-Id.01"));
        assert_eq!(req.deadline_ms, None);
        // hostile values are stripped, not echoed verbatim
        let req = parse_str(
            "GET /metrics HTTP/1.1\r\nX-Request-Id: a b\"c\u{7f}d\r\n\r\n",
        )
        .unwrap()
        .unwrap();
        assert_eq!(req.request_id.as_deref(), Some("abcd"));
        let req = parse_str("GET /metrics HTTP/1.1\r\nX-Request-Id: \"\"\r\n\r\n")
            .unwrap()
            .unwrap();
        assert!(req.request_id.is_none());
    }

    #[test]
    fn sanitize_rid_caps_length() {
        let long = "x".repeat(200);
        assert_eq!(sanitize_rid(&long).unwrap().len(), 64);
        assert_eq!(sanitize_rid("ok-1_2.3"), Some("ok-1_2.3".to_string()));
        assert_eq!(sanitize_rid("<>!"), None);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_str("NOT-HTTP\r\n\r\n").is_err());
        assert!(parse_str("GET /x HTTP/1.1\r\nContent-Length: zebra\r\n\r\n").is_err());
        assert_eq!(parse_str("").unwrap().map(|r| r.path), None); // EOF
    }

    #[test]
    fn oversized_lines_rejected_not_buffered() {
        // newline-free / giant lines must be refused, not accumulated
        let big_line = format!("GET /{} HTTP/1.1\r\n\r\n", "a".repeat(2 * MAX_LINE_BYTES));
        assert!(parse_str(&big_line).is_err());
        let big_header = format!(
            "GET /x HTTP/1.1\r\nX-Pad: {}\r\n\r\n",
            "b".repeat(2 * MAX_LINE_BYTES)
        );
        assert!(parse_str(&big_header).is_err());
        let no_newline = "c".repeat(2 * MAX_LINE_BYTES);
        assert!(parse_str(&no_newline).is_err());
    }

    #[test]
    fn response_wire_format() {
        let mut out = Vec::new();
        write_response(&mut out, 404, r#"{"error":"x"}"#, CT_JSON, Some("rid-1"), None, None, false)
            .unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.starts_with("HTTP/1.1 404 Not Found\r\n"));
        assert!(s.contains("Content-Type: application/json\r\n"));
        assert!(s.contains("Content-Length: 13\r\n"));
        assert!(s.contains("X-Request-Id: rid-1\r\n"));
        assert!(!s.contains("Retry-After"));
        assert!(!s.contains("Allow:"));
        assert!(s.contains("Connection: close\r\n"));
        assert!(s.ends_with(r#"{"error":"x"}"#));
    }

    #[test]
    fn retry_after_header_emitted_on_shed() {
        let mut out = Vec::new();
        write_response(&mut out, 503, "{}", CT_JSON, Some("r"), Some(7), None, false).unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.starts_with("HTTP/1.1 503 Service Unavailable\r\n"));
        assert!(s.contains("Retry-After: 7\r\n"));
    }

    #[test]
    fn allow_header_emitted_on_405() {
        let mut out = Vec::new();
        write_response(&mut out, 405, "{}", CT_JSON, Some("r"), None, Some("GET, POST"), false)
            .unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.starts_with("HTTP/1.1 405 Method Not Allowed\r\n"));
        assert!(s.contains("Allow: GET, POST\r\n"));
    }

    #[test]
    fn conflict_reason_phrase() {
        assert_eq!(reason(409), "Conflict");
    }

    #[test]
    fn error_bodies_carry_code_and_request_id() {
        let body = err_json(ErrorCode::Internal, "boom", Some("rid-9"));
        let j = json::parse(&body).unwrap();
        assert_eq!(j.get("error").as_str(), Some("boom"));
        assert_eq!(j.get("code").as_str(), Some("internal"));
        assert_eq!(j.get("request_id").as_str(), Some("rid-9"));
        let anon = err_json(ErrorCode::BadRequest, "x", None);
        let j = json::parse(&anon).unwrap();
        assert_eq!(j.get("code").as_str(), Some("bad_request"));
        assert!(j.get("request_id").is_null());
    }

    #[test]
    fn deadline_header_parsed_and_validated() {
        let req = parse_str("POST /predict HTTP/1.1\r\nX-Deadline-Ms: 250\r\n\r\n")
            .unwrap()
            .unwrap();
        assert_eq!(req.deadline_ms, Some(250));
        // zero and garbage deadlines are client errors, not silent no-ops
        let err = parse_str("POST /predict HTTP/1.1\r\nX-Deadline-Ms: 0\r\n\r\n").unwrap_err();
        assert_eq!(err.0, 400);
        let err = parse_str("POST /predict HTTP/1.1\r\nX-Deadline-Ms: soon\r\n\r\n").unwrap_err();
        assert_eq!(err.0, 400);
    }

    #[test]
    fn oversized_body_is_413_before_buffering() {
        // a tiny max_body: the declared content-length alone must trip
        // the refusal, without the body being read
        let req = "POST /predict HTTP/1.1\r\nContent-Length: 100\r\n\r\n";
        let err = read_request(&mut Cursor::new(req.as_bytes().to_vec()), 64).unwrap_err();
        assert_eq!(err.0, 413);
        assert!(err.1.contains("body too large"), "{}", err.1);
        // at the limit is fine
        let body = "x".repeat(64);
        let ok = read_request(
            &mut Cursor::new(format!("POST /p HTTP/1.1\r\nContent-Length: 64\r\n\r\n{body}")
                .into_bytes()),
            64,
        )
        .unwrap()
        .unwrap();
        assert_eq!(ok.body.len(), 64);
    }

    #[test]
    fn status_reasons() {
        assert_eq!(reason(200), "OK");
        assert_eq!(reason(408), "Request Timeout");
        assert_eq!(reason(413), "Payload Too Large");
        assert_eq!(reason(431), "Request Header Fields Too Large");
        assert_eq!(reason(503), "Service Unavailable");
        assert_eq!(reason(599), "Unknown");
    }

    #[test]
    fn frame_parser_frames_whole_and_split_requests() {
        let wire = b"POST /predict HTTP/1.1\r\nContent-Length: 5\r\nX-Request-Id: r-1\r\n\r\nhello";
        let mut p = FrameParser::new(1024);
        p.feed(wire);
        {
            let f = p.next_frame().unwrap().unwrap();
            assert_eq!(f.method, "POST");
            assert_eq!(f.path, "/predict");
            assert!(f.keep_alive);
            assert_eq!(f.request_id, Some("r-1"));
            assert_eq!(f.body, b"hello");
        }
        p.consume();
        assert!(p.next_frame().unwrap().is_none());
        assert_eq!(p.buffered(), 0);
        // byte-boundary independence: a request cut at any point frames
        // identically once the rest arrives
        for cut in 1..wire.len() {
            let mut p = FrameParser::new(1024);
            p.feed(&wire[..cut]);
            assert!(p.next_frame().unwrap().is_none(), "cut at {cut}");
            p.feed(&wire[cut..]);
            let f = p.next_frame().unwrap().unwrap();
            assert_eq!(f.method, "POST");
            assert_eq!(f.body, b"hello");
        }
    }

    #[test]
    fn frame_parser_pipelines_back_to_back_requests() {
        let mut p = FrameParser::new(1024);
        p.feed(
            b"GET /healthz HTTP/1.1\r\n\r\nGET /metrics HTTP/1.0\r\nConnection: keep-alive\r\n\r\n",
        );
        // re-yield before consume is idempotent
        let first = {
            let f = p.next_frame().unwrap().unwrap();
            f.path.to_string()
        };
        let again = {
            let f = p.next_frame().unwrap().unwrap();
            f.path.to_string()
        };
        assert_eq!(first, "/healthz");
        assert_eq!(first, again);
        p.consume();
        {
            let f = p.next_frame().unwrap().unwrap();
            assert_eq!(f.path, "/metrics");
            assert!(f.keep_alive); // HTTP/1.0 + explicit keep-alive
        }
        p.consume();
        assert!(p.next_frame().unwrap().is_none());
        assert_eq!(p.buffered(), 0);
    }

    #[test]
    fn frame_parser_rejects_oversized_and_malformed() {
        // terminator-free garbage → 431 once past the head bound
        let mut p = FrameParser::new(1024);
        p.feed(&vec![b'a'; MAX_HEAD_BYTES + 1]);
        let e = p.next_frame().unwrap_err();
        assert_eq!(e.status, 431);
        assert_eq!(e.code, ErrorCode::HeadersTooLarge);
        // declared body beyond max_body → 413 before any body byte
        let mut p = FrameParser::new(8);
        p.feed(b"POST /predict HTTP/1.1\r\nContent-Length: 9\r\n\r\n");
        let e = p.next_frame().unwrap_err();
        assert_eq!(e.status, 413);
        assert!(e.msg.contains("body too large"), "{}", e.msg);
        // malformed request line → 400
        let mut p = FrameParser::new(8);
        p.feed(b"NOT-HTTP\r\n\r\n");
        assert_eq!(p.next_frame().unwrap_err().status, 400);
        // zero deadline → 400 (parity with read_request)
        let mut p = FrameParser::new(64);
        p.feed(b"POST /p HTTP/1.1\r\nX-Deadline-Ms: 0\r\n\r\n");
        assert_eq!(p.next_frame().unwrap_err().status, 400);
    }

    #[test]
    fn predict_visitor_matches_tree_parser() {
        let mut lx = json::Lexer::new();
        let mut v = PredictVisitor::new(Vec::new());
        lx.lex(br#"{"model": "resnet20@v2", "features": [1, 2.5, -3e-2]}"#, &mut v).unwrap();
        assert_eq!(v.model(), Some("resnet20@v2"));
        assert!(v.features_ok());
        assert_eq!(v.features, vec![1.0, 2.5, -0.03]);
        // model: null behaves like an absent field (sole-model path)
        let mut v = PredictVisitor::new(Vec::new());
        lx.lex(br#"{"model": null, "features": []}"#, &mut v).unwrap();
        assert!(!v.model_seen());
        assert!(v.features_ok());
        // non-string model is a distinct client error
        let mut v = PredictVisitor::new(Vec::new());
        lx.lex(br#"{"model": 3, "features": [1]}"#, &mut v).unwrap();
        assert!(v.model_bad());
        // anything but a flat numeric array is not a feature vector
        for bad in [
            &br#"{"features": [1, "x"]}"#[..],
            br#"{"features": [1, null]}"#,
            br#"{"features": [[1]]}"#,
            br#"{"features": {"a": 1}}"#,
            br#"{"features": null}"#,
            br#"{"features": "1,2"}"#,
            br#"{"model": "m"}"#,
        ] {
            let mut v = PredictVisitor::new(Vec::new());
            lx.lex(bad, &mut v).unwrap();
            assert!(!v.features_ok(), "{}", String::from_utf8_lossy(bad));
        }
        // unknown/nested keys skipped; duplicate keys are last-wins
        let mut v = PredictVisitor::new(Vec::new());
        lx.lex(
            br#"{"extra": {"features": [9]}, "features": [7], "model": "a", "model": "b"}"#,
            &mut v,
        )
        .unwrap();
        assert_eq!(v.model(), Some("b"));
        assert!(v.features_ok());
        assert_eq!(v.features, vec![7.0]);
    }
}
