//! Bounded MPSC admission queue with micro-batch coalescing.
//!
//! The serving front-end admits single-example requests; the forward pass
//! is much cheaper per example when batched (one im2col/GEMM per layer
//! instead of N). [`BatchQueue::pop_batch`] bridges the two: a consumer
//! blocks for the first item, then *lingers* up to `max_wait` for more
//! arrivals before returning up to `max_batch` items. Under concurrent
//! load this converges to near-full batches; under light load it adds at
//! most `max_wait` of latency.
//!
//! Built on `std::sync::{Mutex, Condvar}` only (no external channel
//! crates — DESIGN.md §5). Admission is non-blocking ([`try_push`]) so an
//! overloaded server degrades to fast 503s instead of unbounded memory or
//! hung connections; [`push`] offers blocking backpressure for in-process
//! producers.
//!
//! [`try_push`]: BatchQueue::try_push
//! [`push`]: BatchQueue::push

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Why an item was not admitted.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PushError {
    /// At capacity — shed load.
    Full,
    /// [`BatchQueue::close`] was called — shutting down.
    Closed,
}

struct State<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// A bounded multi-producer queue whose consumer side pops *batches*.
pub struct BatchQueue<T> {
    state: Mutex<State<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    capacity: usize,
}

impl<T> BatchQueue<T> {
    /// A queue admitting at most `capacity` in-flight items.
    pub fn bounded(capacity: usize) -> Self {
        assert!(capacity > 0, "zero-capacity queue");
        BatchQueue {
            state: Mutex::new(State { items: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            capacity,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Current depth (racy by nature; for metrics/monitoring).
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn is_closed(&self) -> bool {
        self.state.lock().unwrap().closed
    }

    /// Whether an admission would currently succeed. Racy by nature —
    /// used by the event-loop front-end as the backpressure hint for
    /// resuming suspended connections, where a stale answer only costs
    /// one extra `try_push` round trip.
    pub fn has_space(&self) -> bool {
        let s = self.state.lock().unwrap();
        !s.closed && s.items.len() < self.capacity
    }

    /// Non-blocking admission; returns the item back on rejection.
    pub fn try_push(&self, item: T) -> Result<(), (T, PushError)> {
        let mut s = self.state.lock().unwrap();
        if s.closed {
            return Err((item, PushError::Closed));
        }
        if s.items.len() >= self.capacity {
            return Err((item, PushError::Full));
        }
        s.items.push_back(item);
        drop(s);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking admission: waits for space, fails only once closed.
    pub fn push(&self, item: T) -> Result<(), (T, PushError)> {
        let mut s = self.state.lock().unwrap();
        while !s.closed && s.items.len() >= self.capacity {
            s = self.not_full.wait(s).unwrap();
        }
        if s.closed {
            return Err((item, PushError::Closed));
        }
        s.items.push_back(item);
        drop(s);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Coalescing pop: block until at least one item is available, then
    /// linger up to `max_wait` (or until `max_batch` items are ready) and
    /// return the batch — always non-empty. Returns `None` once the queue
    /// is closed *and* drained — the worker-thread exit signal.
    pub fn pop_batch(&self, max_batch: usize, max_wait: Duration) -> Option<Vec<T>> {
        self.pop_batch_timed(max_batch, max_wait).map(|(batch, _)| batch)
    }

    /// [`pop_batch`](Self::pop_batch) plus the *assembly time*: how long
    /// this call spent coalescing after its first item became available
    /// (zero when the batch filled instantly). Blocking for the first item
    /// is queue idle time, not assembly, so it is excluded.
    pub fn pop_batch_timed(
        &self,
        max_batch: usize,
        max_wait: Duration,
    ) -> Option<(Vec<T>, Duration)> {
        assert!(max_batch > 0, "zero max_batch");
        let mut s = self.state.lock().unwrap();
        loop {
            while s.items.is_empty() {
                if s.closed {
                    return None;
                }
                s = self.not_empty.wait(s).unwrap();
            }
            // assembly clock starts once the first item is visible
            let assembly_start = Instant::now();
            if s.items.len() < max_batch && !s.closed && !max_wait.is_zero() {
                let deadline = assembly_start + max_wait;
                while s.items.len() < max_batch && !s.closed {
                    let remaining = deadline.saturating_duration_since(Instant::now());
                    if remaining.is_zero() {
                        break;
                    }
                    s = self.not_empty.wait_timeout(s, remaining).unwrap().0;
                }
            }
            let take = s.items.len().min(max_batch);
            if take == 0 {
                continue; // another consumer drained the linger window's items
            }
            let batch: Vec<T> = s.items.drain(..take).collect();
            drop(s);
            self.not_full.notify_all();
            return Some((batch, assembly_start.elapsed()));
        }
    }

    /// Stop admitting; wake all waiters. Already-queued items still drain
    /// through `pop_batch` (graceful shutdown).
    pub fn close(&self) {
        let mut s = self.state.lock().unwrap();
        s.closed = true;
        drop(s);
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::thread;

    const MS: Duration = Duration::from_millis(1);

    #[test]
    fn fifo_and_batch_limits() {
        let q = BatchQueue::bounded(16);
        for i in 0..5u32 {
            q.try_push(i).unwrap();
        }
        assert_eq!(q.len(), 5);
        let b = q.pop_batch(3, Duration::ZERO).unwrap();
        assert_eq!(b, vec![0, 1, 2]);
        let b = q.pop_batch(8, Duration::ZERO).unwrap();
        assert_eq!(b, vec![3, 4]);
        assert!(q.is_empty());
    }

    #[test]
    fn bounded_rejects_when_full() {
        let q = BatchQueue::bounded(2);
        q.try_push(1u32).unwrap();
        q.try_push(2).unwrap();
        let (item, e) = q.try_push(3).unwrap_err();
        assert_eq!((item, e), (3, PushError::Full));
        q.pop_batch(1, Duration::ZERO).unwrap();
        q.try_push(3).unwrap();
    }

    #[test]
    fn close_rejects_pushes_but_drains() {
        let q = BatchQueue::bounded(4);
        q.try_push(7u32).unwrap();
        q.close();
        assert_eq!(q.try_push(8).unwrap_err().1, PushError::Closed);
        assert_eq!(q.push(9).unwrap_err().1, PushError::Closed);
        assert_eq!(q.pop_batch(4, 5 * MS).unwrap(), vec![7]);
        assert_eq!(q.pop_batch(4, 5 * MS), None);
    }

    #[test]
    fn pop_blocks_until_item_or_close() {
        let q = Arc::new(BatchQueue::bounded(4));
        let q2 = q.clone();
        let t = thread::spawn(move || q2.pop_batch(4, Duration::ZERO));
        thread::sleep(5 * MS);
        q.try_push(42u32).unwrap();
        assert_eq!(t.join().unwrap(), Some(vec![42]));

        let q2 = q.clone();
        let t = thread::spawn(move || q2.pop_batch(4, Duration::ZERO));
        thread::sleep(5 * MS);
        q.close();
        assert_eq!(t.join().unwrap(), None);
    }

    #[test]
    fn linger_coalesces_concurrent_producers() {
        let q = Arc::new(BatchQueue::bounded(64));
        q.try_push(0u32).unwrap();
        let producers: Vec<_> = (1..8u32)
            .map(|i| {
                let q = q.clone();
                thread::spawn(move || {
                    thread::sleep(i * MS);
                    q.push(i).unwrap();
                })
            })
            .collect();
        // the linger window (200ms) comfortably covers the staggered pushes
        let b = q.pop_batch(8, Duration::from_millis(200)).unwrap();
        for p in producers {
            p.join().unwrap();
        }
        assert_eq!(b.len(), 8, "expected a fully coalesced batch, got {b:?}");
    }

    #[test]
    fn timed_pop_reports_assembly_window() {
        let q = BatchQueue::bounded(8);
        q.try_push(1u32).unwrap();
        // batch fills instantly at max_batch=1 → negligible assembly time
        let (b, dt) = q.pop_batch_timed(1, Duration::from_millis(200)).unwrap();
        assert_eq!(b, vec![1]);
        assert!(dt < Duration::from_millis(100), "assembly {dt:?}");
        // lingering for a batch that never fills costs ~max_wait
        q.try_push(2u32).unwrap();
        let (b, dt) = q.pop_batch_timed(4, 10 * MS).unwrap();
        assert_eq!(b, vec![2]);
        assert!(dt >= 10 * MS, "assembly {dt:?}");
    }

    /// Overflow + drain interleaving under the shedding contract: a full
    /// queue rejects with `Full` (returning the item), admits again the
    /// moment a batch drains, and the drain preserves FIFO order across
    /// the rejection — shed items simply never existed as far as
    /// ordering is concerned.
    #[test]
    fn overflow_and_timed_pop_preserve_fifo_across_rejections() {
        let q = BatchQueue::bounded(3);
        q.try_push(0u32).unwrap();
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        // two rejected while full — both come back intact
        assert_eq!(q.try_push(3).unwrap_err(), (3, PushError::Full));
        assert_eq!(q.try_push(4).unwrap_err(), (4, PushError::Full));
        // drain a partial batch, then interleave new admissions
        let (b, _) = q.pop_batch_timed(2, Duration::ZERO).unwrap();
        assert_eq!(b, vec![0, 1]);
        q.try_push(5).unwrap();
        q.try_push(6).unwrap();
        assert_eq!(q.try_push(7).unwrap_err(), (7, PushError::Full));
        // FIFO over the survivors only: 2 (pre-overflow), then 5, 6
        let (b, _) = q.pop_batch_timed(8, Duration::ZERO).unwrap();
        assert_eq!(b, vec![2, 5, 6]);
        assert!(q.is_empty());
        q.try_push(7).unwrap();
        assert_eq!(q.pop_batch(8, Duration::ZERO).unwrap(), vec![7]);
    }

    #[test]
    fn blocking_push_waits_for_space() {
        let q = Arc::new(BatchQueue::bounded(1));
        q.try_push(1u32).unwrap();
        let q2 = q.clone();
        let t = thread::spawn(move || q2.push(2).map_err(|(_, e)| e));
        thread::sleep(5 * MS);
        assert_eq!(q.pop_batch(1, Duration::ZERO).unwrap(), vec![1]);
        t.join().unwrap().unwrap();
        assert_eq!(q.pop_batch(1, Duration::ZERO).unwrap(), vec![2]);
    }
}
