//! Model registry: named, decrypt-once-at-load model hosting.
//!
//! The paper's deployment story (Fig. 1, Algorithm 1) pays the XOR
//! decryption cost **once**, when the encrypted `.fxr` bundle is loaded;
//! after that the resident weights serve every request. The registry
//! owns that step for any number of bundles, keyed by name, each on its
//! own [`ModePolicy`] — a single server mixes FP-exact DenseF32 models,
//! high-density BitPlane models, sub-1-bit Encrypted models (which skip
//! the decrypt-at-load step entirely and decrypt panels inside the GEMM
//! tile loop), and per-layer mixed-mode entries (big convs on
//! XNOR/popcount, tiny layers FP-exact). `GET /models` reports
//! per-model storage stats (`bits/weight`, compression ratio), the
//! resident bytes each entry actually keeps under its modes (quantized
//! vs FP residue, plus `resident_bits_per_weight` — sub-1.0 on the
//! Encrypted engine), and the per-layer `layer_modes` assignment;
//! [`Registry::unload`] releases a model's memory.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, ensure, Result};

use crate::inference::{ComputeMode, InferenceModel, ModePolicy};
use crate::substrate::json::Json;
use crate::substrate::trace;

/// One hosted model plus its serving metadata.
pub struct ModelEntry {
    /// Registry key (what requests address the model by).
    pub name: String,
    pub model: InferenceModel,
    /// Flat features per example (`input_dims` product) — requests in a
    /// coalesced batch must all match this.
    pub feature_len: usize,
    /// Load + decrypt wall time (the one-time XOR cost).
    pub load_ms: f64,
    /// Per-layer stage-timing aggregate fed by traced forwards (the
    /// `GET /models/<name>/profile` body). Always present; stays empty
    /// while tracing is off.
    pub profile: Arc<trace::Profile>,
}

/// Name → model map shared between the HTTP front-end and the workers.
pub struct Registry {
    models: BTreeMap<String, Arc<ModelEntry>>,
    /// Policy [`Registry::load`] puts new entries on (per-call overrides
    /// go through [`Registry::load_with_mode`] /
    /// [`Registry::load_with_policy`]).
    default_policy: ModePolicy,
}

impl Registry {
    /// An empty registry whose `load` uses the DenseF32 engine.
    pub fn new() -> Self {
        Self::with_default_policy(ModePolicy::uniform(ComputeMode::DenseF32))
    }

    /// An empty registry whose `load` uses a uniform `mode` policy.
    pub fn with_default_mode(mode: ComputeMode) -> Self {
        Self::with_default_policy(ModePolicy::uniform(mode))
    }

    /// An empty registry whose `load` uses `policy` — the consumption
    /// point for the `--compute-mode` policy grammar when a binary
    /// builds the registry it hands to `Server::start` (see
    /// `examples/serve.rs`).
    pub fn with_default_policy(policy: ModePolicy) -> Self {
        Registry { models: BTreeMap::new(), default_policy: policy }
    }

    /// The base engine of the registry's default policy.
    pub fn default_mode(&self) -> ComputeMode {
        self.default_policy.base
    }

    /// The policy `load` puts new entries on.
    pub fn default_policy(&self) -> &ModePolicy {
        &self.default_policy
    }

    /// Load `<stem>.fxr` + sidecars from `dir` and register as `name` on
    /// the registry's default policy, timing the decrypt-at-load step.
    pub fn load(&mut self, name: &str, dir: &Path, stem: &str) -> Result<Arc<ModelEntry>> {
        self.load_with_policy(name, dir, stem, self.default_policy.clone())
    }

    /// Load and register on an explicit uniform compute mode (BitPlane
    /// entries keep their quantized layers as packed bit-planes — see
    /// `inference::bitslice`).
    pub fn load_with_mode(
        &mut self,
        name: &str,
        dir: &Path,
        stem: &str,
        mode: ComputeMode,
    ) -> Result<Arc<ModelEntry>> {
        self.load_with_policy(name, dir, stem, ModePolicy::uniform(mode))
    }

    /// Load and register under a per-layer compute policy (mixed
    /// entries run big layers on XNOR/popcount and small ones FP-exact;
    /// `GET /models` reports the per-layer assignment).
    pub fn load_with_policy(
        &mut self,
        name: &str,
        dir: &Path,
        stem: &str,
        policy: ModePolicy,
    ) -> Result<Arc<ModelEntry>> {
        ensure!(!self.models.contains_key(name), "model '{name}' already registered");
        let t0 = Instant::now();
        let model = InferenceModel::load_with_policy(dir, stem, policy)
            .with_context(|| {
                format!(
                    "loading model '{name}' from {} (stem '{stem}') — bundle \
                     rejected, nothing registered",
                    dir.display()
                )
            })?;
        let load_ms = t0.elapsed().as_secs_f64() * 1e3;
        self.register(name, model, load_ms)
    }

    /// Register an already-loaded model (tests, warm handoff).
    pub fn register(
        &mut self,
        name: &str,
        model: InferenceModel,
        load_ms: f64,
    ) -> Result<Arc<ModelEntry>> {
        ensure!(!name.is_empty(), "empty model name");
        ensure!(!self.models.contains_key(name), "model '{name}' already registered");
        let feature_len = model.input_dims.iter().product::<usize>().max(1);
        let entry = Arc::new(ModelEntry {
            name: name.to_string(),
            model,
            feature_len,
            load_ms,
            profile: Arc::new(trace::Profile::default()),
        });
        self.models.insert(name.to_string(), entry.clone());
        Ok(entry)
    }

    /// Remove `name` from the registry and return its entry. In-flight
    /// requests holding the `Arc` finish normally; the model's resident
    /// weights are freed once the last reference drops — the registry is
    /// no longer grow-only.
    pub fn unload(&mut self, name: &str) -> Result<Arc<ModelEntry>> {
        self.models
            .remove(name)
            .with_context(|| format!("model '{name}' is not registered"))
    }

    pub fn get(&self, name: &str) -> Option<Arc<ModelEntry>> {
        self.models.get(name).cloned()
    }

    /// The single registered model, if exactly one — the default target
    /// for requests that omit the `model` field.
    pub fn sole(&self) -> Option<Arc<ModelEntry>> {
        if self.models.len() == 1 {
            self.models.values().next().cloned()
        } else {
            None
        }
    }

    pub fn names(&self) -> Vec<&str> {
        self.models.keys().map(String::as_str).collect()
    }

    pub fn len(&self) -> usize {
        self.models.len()
    }

    pub fn is_empty(&self) -> bool {
        self.models.is_empty()
    }

    /// The `GET /models` body.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![(
            "models",
            Json::arr(self.models.values().map(|e| {
                Json::obj(vec![
                    ("name", Json::str(e.name.clone())),
                    ("model", Json::str(e.model.model.clone())),
                    ("num_classes", Json::num(e.model.num_classes as f64)),
                    ("input_dims",
                     Json::arr(e.model.input_dims.iter().map(|&d| Json::num(d as f64)))),
                    ("feature_len", Json::num(e.feature_len as f64)),
                    ("bits_per_weight", Json::num(e.model.bits_per_weight)),
                    ("compression_ratio", Json::num(e.model.compression_ratio)),
                    ("compute_mode", Json::str(e.model.mode_label())),
                    ("layer_modes",
                     Json::arr(e.model.layer_modes().into_iter().map(|lm| {
                         Json::obj(vec![
                             ("idx", Json::num(lm.idx as f64)),
                             ("mode", Json::str(lm.mode.label())),
                             ("act_planes",
                              lm.mode
                                  .act_planes()
                                  .map_or(Json::Null, |m| Json::num(m as f64))),
                             ("weights", Json::num(lm.weights as f64)),
                         ])
                     }))),
                    ("quantized_weight_bytes",
                     Json::num(e.model.quantized_resident_bytes() as f64)),
                    ("fp_weight_bytes",
                     Json::num(e.model.fp_resident_bytes() as f64)),
                    ("resident_bytes", Json::num(e.model.resident_bytes() as f64)),
                    // serving-time storage rate over the quantized layers
                    // (sub-1.0 on the Encrypted engine) — the headline
                    // the decrypt-on-demand path exists to deliver
                    ("resident_bits_per_weight",
                     Json::num(e.model.resident_bits_per_weight())),
                    ("load_ms", Json::num(e.load_ms)),
                ])
            })),
        )])
    }
}

impl Default for Registry {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    //! Registry tests that need a real model go through a synthetic bundle
    //! in `rust/tests/serve.rs` (InferenceModel is only constructible via
    //! `load`). Here: empty-registry behavior.
    use super::*;

    #[test]
    fn empty_registry() {
        let r = Registry::new();
        assert!(r.is_empty());
        assert_eq!(r.len(), 0);
        assert!(r.get("x").is_none());
        assert!(r.sole().is_none());
        assert!(r.names().is_empty());
        assert_eq!(r.to_json().get("models").as_arr().map(|a| a.len()), Some(0));
    }

    #[test]
    fn unload_unknown_model_fails() {
        // full load → unload → reload round trips live in
        // rust/tests/bitslice.rs (they need a real bundle)
        let mut r = Registry::new();
        let err = r.unload("ghost").unwrap_err();
        assert!(err.to_string().contains("ghost"), "{err}");
    }

    #[test]
    fn load_missing_bundle_fails() {
        let mut r = Registry::new();
        let err = r
            .load("ghost", Path::new("/nonexistent/dir"), "nope")
            .unwrap_err();
        assert!(!err.to_string().is_empty());
    }
}
