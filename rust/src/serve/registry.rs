//! Model registry: versioned, decrypt-once-at-load model hosting with
//! drain-then-swap semantics.
//!
//! The paper's deployment story (Fig. 1, Algorithm 1) pays the XOR
//! decryption cost **once**, when the encrypted `.fxr` bundle is loaded;
//! after that the resident weights serve every request. The registry
//! owns that step for any number of bundles, keyed by **versioned
//! alias** (`resnet20@v2`; the bare alias resolves the latest version),
//! each on its own [`ModePolicy`].
//!
//! Control plane (DESIGN.md §13):
//! * **Swap**: [`Registry::admit_from_repo`] verifies a bundle's HMAC
//!   signature and per-file SHA-256 through the attached
//!   [`BundleRepo`] *before* the fxr parser touches a byte, loads it,
//!   and atomically repoints the alias. In-flight requests hold an
//!   `Arc<ModelEntry>` resolved at admission, so they finish on the old
//!   version while new admissions route to the new one — drain-then-swap
//!   for free. A rejected bundle (bad signature, bad digest, parse
//!   failure) registers **nothing**.
//! * **Lazy load**: a slot admitted with `lazy` (or evicted) keeps only
//!   its source; the first [`Registry::resolve`] re-verifies and reloads.
//! * **LRU eviction**: when total [`resident_bytes`] exceed the budget
//!   (`FLEXOR_MAX_RESIDENT_BYTES` / [`Registry::set_resident_budget`]),
//!   the least-recently-used reloadable slot drops its weights; the slot
//!   stays registered and reloads bit-identically on next use.
//!
//! `GET /models` reports per-version storage stats (`bits/weight`,
//! compression ratio, resident bytes under the active modes, per-layer
//! `layer_modes`) plus `alias`/`version`/`serving`/`resident` fields and
//! the swap/eviction totals; [`Registry::unload`] releases memory
//! in-process, `DELETE /models/<name>` does it over HTTP.
//!
//! [`resident_bytes`]: crate::inference::InferenceModel::resident_bytes

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Instant;

use anyhow::{bail, ensure, Context, Result};

use crate::inference::{ComputeMode, InferenceModel, ModePolicy};
use crate::repo::BundleRepo;
use crate::substrate::json::Json;
use crate::substrate::trace::{self, Level};

/// Version assumed when a model is registered or addressed without `@`.
pub const IMPLICIT_VERSION: &str = "v1";

/// One hosted model version plus its serving metadata.
pub struct ModelEntry {
    /// Full registered name, exactly as passed to `load`/`register`/
    /// admitted from the repo (`"alpha"`, `"resnet20@v2"`) — the
    /// per-model metrics label and the `model` field of predict bodies.
    pub name: String,
    /// Alias half of the name (`"resnet20"` for `"resnet20@v2"`).
    pub alias: String,
    /// Version half (`"v2"`; [`IMPLICIT_VERSION`] when unversioned).
    pub version: String,
    pub model: InferenceModel,
    /// Flat features per example (`input_dims` product) — requests in a
    /// coalesced batch must all match this.
    pub feature_len: usize,
    /// Load + decrypt wall time (the one-time XOR cost).
    pub load_ms: f64,
    /// Per-layer stage-timing aggregate fed by traced forwards (the
    /// `GET /models/<name>/profile` body). Always present; stays empty
    /// while tracing is off.
    pub profile: Arc<trace::Profile>,
}

/// Where a slot's bundle came from — enough to reload it after eviction
/// or a lazy admit, re-verified through the repo when it came from one.
#[derive(Clone)]
struct Source {
    dir: PathBuf,
    stem: String,
    policy: ModePolicy,
    /// `(repo bundle name, version)` re-verified (signature + sha256)
    /// before every (re)load when the slot was admitted from the repo.
    verify: Option<(String, String)>,
}

/// One version slot under an alias.
struct Slot {
    /// Full registered name (what reloads resurrect the entry as).
    name: String,
    /// `None` while lazy/evicted; the weights live only here.
    resident: Option<Arc<ModelEntry>>,
    source: Option<Source>,
    last_used: u64,
    installed: u64,
}

/// A named model with one or more version slots.
struct Alias {
    versions: BTreeMap<String, Slot>,
    /// Version the bare alias resolves to (most recently installed).
    latest: String,
    /// A `POST /models` swap is mid-flight: concurrent swaps/removals
    /// answer 409 instead of interleaving.
    swapping: bool,
}

struct Inner {
    aliases: BTreeMap<String, Alias>,
    /// LRU clock: bumped on every resolve/install, stamped into
    /// `Slot::last_used`.
    clock: u64,
}

/// Control-plane failures with distinct HTTP mappings (the `POST
/// /models` / `DELETE /models/<name>` contract).
#[derive(Debug)]
pub enum ControlError {
    /// 409 `swap_in_progress` — another swap owns the alias right now.
    SwapInProgress(String),
    /// 409 `bundle_rejected` — signature/digest/parse failure; nothing
    /// was registered.
    Rejected(String),
    /// 400 — malformed `name@version` spec.
    BadSpec(String),
    /// 400 — no bundle repo attached to the registry.
    NoRepo,
    /// 404 — alias/version not registered.
    Unknown(String),
}

impl std::fmt::Display for ControlError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ControlError::SwapInProgress(n) => {
                write!(f, "a swap is already in progress for '{n}'")
            }
            ControlError::Rejected(msg) => write!(f, "bundle rejected: {msg}"),
            ControlError::BadSpec(msg) => write!(f, "{msg}"),
            ControlError::NoRepo => write!(
                f,
                "no bundle repo attached (serve with --repo / Registry::set_repo)"
            ),
            ControlError::Unknown(n) => write!(f, "model '{n}' is not registered"),
        }
    }
}

impl std::error::Error for ControlError {}

/// What a successful [`Registry::admit_from_repo`] did.
#[derive(Clone, Debug)]
pub struct SwapReport {
    /// Full name of the admitted version (`alias@version`).
    pub name: String,
    pub alias: String,
    pub version: String,
    /// Full name the alias served before this admit, when it changed.
    pub swapped_from: Option<String>,
    /// Load + decrypt wall time (0 for lazy admits).
    pub load_ms: f64,
    pub lazy: bool,
}

/// Alias → versions map shared between the HTTP front-end and the
/// workers. Interior-mutable: every method takes `&self`, so the
/// control plane mutates the registry behind the same `Arc` the serving
/// path reads.
pub struct Registry {
    inner: Mutex<Inner>,
    /// Policy [`Registry::load`] puts new entries on (per-call overrides
    /// go through [`Registry::load_with_mode`] /
    /// [`Registry::load_with_policy`]).
    default_policy: ModePolicy,
    /// Signed bundle store `admit_from_repo` verifies against.
    repo: Option<BundleRepo>,
    /// Total resident-bytes budget LRU eviction enforces (`None` = no
    /// bound). Seeded from `FLEXOR_MAX_RESIDENT_BYTES` at construction.
    max_resident_bytes: Option<usize>,
    swaps: AtomicU64,
    evictions: AtomicU64,
}

/// `"resnet20@v2"` → `("resnet20", Some("v2"))`; `"alpha"` → `("alpha", None)`.
fn split_name(name: &str) -> (&str, Option<&str>) {
    match name.split_once('@') {
        Some((a, v)) => (a, Some(v)),
        None => (name, None),
    }
}

impl Registry {
    /// An empty registry whose `load` uses the DenseF32 engine.
    pub fn new() -> Self {
        Self::with_default_policy(ModePolicy::uniform(ComputeMode::DenseF32))
    }

    /// An empty registry whose `load` uses a uniform `mode` policy.
    pub fn with_default_mode(mode: ComputeMode) -> Self {
        Self::with_default_policy(ModePolicy::uniform(mode))
    }

    /// An empty registry whose `load` uses `policy` — the consumption
    /// point for the `--compute-mode` policy grammar when a binary
    /// builds the registry it hands to `Server::start` (see
    /// `examples/serve.rs`).
    pub fn with_default_policy(policy: ModePolicy) -> Self {
        let max_resident_bytes = std::env::var("FLEXOR_MAX_RESIDENT_BYTES")
            .ok()
            .and_then(|v| v.trim().parse::<usize>().ok())
            .filter(|&b| b > 0);
        Registry {
            inner: Mutex::new(Inner { aliases: BTreeMap::new(), clock: 0 }),
            default_policy: policy,
            repo: None,
            max_resident_bytes,
            swaps: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    /// The base engine of the registry's default policy.
    pub fn default_mode(&self) -> ComputeMode {
        self.default_policy.base
    }

    /// The policy `load` puts new entries on.
    pub fn default_policy(&self) -> &ModePolicy {
        &self.default_policy
    }

    /// Attach the signed bundle repo `admit_from_repo` loads from.
    pub fn set_repo(&mut self, repo: BundleRepo) {
        self.repo = Some(repo);
    }

    pub fn has_repo(&self) -> bool {
        self.repo.is_some()
    }

    /// Override the resident-bytes budget (`None` = unbounded). Eviction
    /// runs at the next install/reload, not retroactively here.
    pub fn set_resident_budget(&mut self, bytes: Option<usize>) {
        self.max_resident_bytes = bytes.filter(|&b| b > 0);
    }

    pub fn resident_budget(&self) -> Option<usize> {
        self.max_resident_bytes
    }

    pub fn swaps_total(&self) -> u64 {
        self.swaps.load(Ordering::Relaxed)
    }

    pub fn evictions_total(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    fn lock(&self) -> MutexGuard<'_, Inner> {
        // a panic while holding the registry lock must not wedge the
        // whole control plane; the state transitions are all small and
        // self-consistent, so recover the guard
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn make_entry(
        name: &str,
        alias: &str,
        version: &str,
        model: InferenceModel,
        load_ms: f64,
    ) -> Arc<ModelEntry> {
        let feature_len = model.input_dims.iter().product::<usize>().max(1);
        Arc::new(ModelEntry {
            name: name.to_string(),
            alias: alias.to_string(),
            version: version.to_string(),
            model,
            feature_len,
            load_ms,
            profile: Arc::new(trace::Profile::default()),
        })
    }

    /// Load `<stem>.fxr` + sidecars from `dir` and register as `name`
    /// (`alias[@version]`) on the registry's default policy, timing the
    /// decrypt-at-load step.
    pub fn load(&self, name: &str, dir: &Path, stem: &str) -> Result<Arc<ModelEntry>> {
        self.load_with_policy(name, dir, stem, self.default_policy.clone())
    }

    /// Load and register on an explicit uniform compute mode (BitPlane
    /// entries keep their quantized layers as packed bit-planes — see
    /// `inference::bitslice`).
    pub fn load_with_mode(
        &self,
        name: &str,
        dir: &Path,
        stem: &str,
        mode: ComputeMode,
    ) -> Result<Arc<ModelEntry>> {
        self.load_with_policy(name, dir, stem, ModePolicy::uniform(mode))
    }

    /// Load and register under a per-layer compute policy (mixed
    /// entries run big layers on XNOR/popcount and small ones FP-exact;
    /// `GET /models` reports the per-layer assignment). The source is
    /// remembered, so the entry is evictable and lazily reloadable.
    pub fn load_with_policy(
        &self,
        name: &str,
        dir: &Path,
        stem: &str,
        policy: ModePolicy,
    ) -> Result<Arc<ModelEntry>> {
        self.ensure_unregistered(name)?;
        let t0 = Instant::now();
        let model = InferenceModel::load_with_policy(dir, stem, policy.clone())
            .with_context(|| {
                format!(
                    "loading model '{name}' from {} (stem '{stem}') — bundle \
                     rejected, nothing registered",
                    dir.display()
                )
            })?;
        let load_ms = t0.elapsed().as_secs_f64() * 1e3;
        let source = Source {
            dir: dir.to_path_buf(),
            stem: stem.to_string(),
            policy,
            verify: None,
        };
        self.install(name, model, load_ms, Some(source))
    }

    /// Register an already-loaded model (tests, warm handoff). No source
    /// is remembered, so the entry is never evicted.
    pub fn register(
        &self,
        name: &str,
        model: InferenceModel,
        load_ms: f64,
    ) -> Result<Arc<ModelEntry>> {
        self.install(name, model, load_ms, None)
    }

    fn ensure_unregistered(&self, name: &str) -> Result<()> {
        ensure!(!name.is_empty(), "empty model name");
        let (alias, ver) = split_name(name);
        ensure!(!alias.is_empty(), "empty model alias in '{name}'");
        let version = ver.unwrap_or(IMPLICIT_VERSION);
        ensure!(!version.is_empty(), "empty version in '{name}'");
        let inner = self.lock();
        if let Some(a) = inner.aliases.get(alias) {
            ensure!(
                !a.versions.contains_key(version),
                "model '{name}' already registered"
            );
        }
        Ok(())
    }

    fn install(
        &self,
        name: &str,
        model: InferenceModel,
        load_ms: f64,
        source: Option<Source>,
    ) -> Result<Arc<ModelEntry>> {
        self.ensure_unregistered(name)?;
        let (alias, ver) = split_name(name);
        let version = ver.unwrap_or(IMPLICIT_VERSION);
        let entry = Self::make_entry(name, alias, version, model, load_ms);
        let mut inner = self.lock();
        inner.clock += 1;
        let tick = inner.clock;
        let a = inner.aliases.entry(alias.to_string()).or_insert_with(|| Alias {
            versions: BTreeMap::new(),
            latest: String::new(),
            swapping: false,
        });
        ensure!(
            !a.versions.contains_key(version),
            "model '{name}' already registered"
        );
        a.versions.insert(
            version.to_string(),
            Slot {
                name: name.to_string(),
                resident: Some(entry.clone()),
                source,
                last_used: tick,
                installed: tick,
            },
        );
        a.latest = version.to_string();
        self.evict_to_budget(&mut inner, (alias, version));
        Ok(entry)
    }

    /// Remove `name` from the registry and return its entry. A bare
    /// alias removes every version; `alias@version` removes one slot.
    /// In-flight requests holding the `Arc` finish normally; the model's
    /// resident weights are freed once the last reference drops.
    pub fn unload(&self, name: &str) -> Result<Arc<ModelEntry>> {
        let (alias, ver) = split_name(name);
        let mut inner = self.lock();
        let Some(a) = inner.aliases.get(alias) else {
            bail!("model '{name}' is not registered");
        };
        ensure!(!a.swapping, "model '{name}' has a swap in progress; retry");
        match ver {
            Some(v) => {
                let Some(slot) = a.versions.get(v) else {
                    bail!("model '{name}' is not registered");
                };
                let Some(entry) = slot.resident.clone() else {
                    bail!("model '{name}' is not resident (evicted); use remove()");
                };
                let a = inner.aliases.get_mut(alias).unwrap();
                a.versions.remove(v);
                if a.versions.is_empty() {
                    inner.aliases.remove(alias);
                } else if a.latest == v {
                    // repoint the bare alias at the most recent survivor
                    a.latest = a
                        .versions
                        .iter()
                        .max_by_key(|(_, s)| s.installed)
                        .map(|(ver, _)| ver.clone())
                        .unwrap_or_default();
                }
                Ok(entry)
            }
            None => {
                let entry = a
                    .versions
                    .get(&a.latest)
                    .and_then(|s| s.resident.clone())
                    .or_else(|| a.versions.values().find_map(|s| s.resident.clone()));
                let Some(entry) = entry else {
                    bail!("model '{name}' has no resident versions; use remove()");
                };
                inner.aliases.remove(alias);
                Ok(entry)
            }
        }
    }

    /// `DELETE /models/<name>`: drop the alias (or one version) entirely,
    /// resident or not. Returns the number of version slots removed.
    pub fn remove(&self, name: &str) -> std::result::Result<usize, ControlError> {
        let (alias, ver) = split_name(name);
        let mut inner = self.lock();
        let Some(a) = inner.aliases.get_mut(alias) else {
            return Err(ControlError::Unknown(name.to_string()));
        };
        if a.swapping {
            return Err(ControlError::SwapInProgress(alias.to_string()));
        }
        match ver {
            Some(v) => {
                if a.versions.remove(v).is_none() {
                    return Err(ControlError::Unknown(name.to_string()));
                }
                if a.versions.is_empty() {
                    inner.aliases.remove(alias);
                } else if a.latest == v {
                    a.latest = a
                        .versions
                        .iter()
                        .max_by_key(|(_, s)| s.installed)
                        .map(|(ver, _)| ver.clone())
                        .unwrap_or_default();
                }
                Ok(1)
            }
            None => {
                let n = a.versions.len();
                inner.aliases.remove(alias);
                Ok(n)
            }
        }
    }

    /// Resident peek — no lazy load, no error. Bare aliases resolve the
    /// latest version; `alias@version` is exact.
    pub fn get(&self, name: &str) -> Option<Arc<ModelEntry>> {
        let (alias, ver) = split_name(name);
        let mut inner = self.lock();
        inner.clock += 1;
        let tick = inner.clock;
        let a = inner.aliases.get_mut(alias)?;
        let version = match ver {
            Some(v) => v.to_string(),
            None => a.latest.clone(),
        };
        let slot = a.versions.get_mut(&version)?;
        let e = slot.resident.clone()?;
        slot.last_used = tick;
        Some(e)
    }

    /// Resolve for serving: like [`Registry::get`], but a known slot
    /// whose weights are not resident (lazy admit / evicted) is
    /// re-verified through the repo (when repo-sourced) and reloaded
    /// first. `Ok(None)` = not registered; `Err` = the reload failed.
    ///
    /// The reload runs under the registry lock: concurrent resolves of
    /// the same cold model load once, and the resident fast path is a
    /// few map lookups.
    pub fn resolve(&self, name: &str) -> Result<Option<Arc<ModelEntry>>> {
        let mut inner = self.lock();
        inner.clock += 1;
        let tick = inner.clock;
        let (alias, ver) = split_name(name);
        let Some(a) = inner.aliases.get_mut(alias) else {
            return Ok(None);
        };
        let version = match ver {
            Some(v) => v.to_string(),
            None => {
                if a.latest.is_empty() {
                    return Ok(None);
                }
                a.latest.clone()
            }
        };
        let Some(slot) = a.versions.get_mut(&version) else {
            return Ok(None);
        };
        if let Some(e) = &slot.resident {
            slot.last_used = tick;
            return Ok(Some(e.clone()));
        }
        let Some(src) = slot.source.clone() else {
            return Ok(None);
        };
        let slot_name = slot.name.clone();
        if let Some((rn, rv)) = &src.verify {
            let repo = self.repo.as_ref().with_context(|| {
                format!("model '{slot_name}' needs repo re-verification but no repo is attached")
            })?;
            repo.verify(rn, rv)
                .with_context(|| format!("re-verifying '{slot_name}' before reload"))?;
        }
        let t0 = Instant::now();
        let model = InferenceModel::load_with_policy(&src.dir, &src.stem, src.policy.clone())
            .with_context(|| format!("lazily reloading model '{slot_name}'"))?;
        let load_ms = t0.elapsed().as_secs_f64() * 1e3;
        let entry = Self::make_entry(&slot_name, alias, &version, model, load_ms);
        let slot = inner
            .aliases
            .get_mut(alias)
            .and_then(|a| a.versions.get_mut(&version))
            .expect("slot vanished under the registry lock");
        slot.resident = Some(entry.clone());
        slot.last_used = tick;
        trace::log(Level::Info, "model_reloaded", &[
            ("model", Json::str(slot_name)),
            ("load_ms", Json::num(load_ms)),
        ]);
        self.evict_to_budget(&mut inner, (alias, &version));
        Ok(Some(entry))
    }

    /// Verify `spec` (`name@version`) against the attached repo, load
    /// it (unless `lazy`), and repoint the alias — the `POST /models`
    /// entry point. On any failure nothing is registered and the
    /// previous version keeps serving. Concurrent swaps of the same
    /// alias are rejected with [`ControlError::SwapInProgress`].
    pub fn admit_from_repo(
        &self,
        spec: &str,
        lazy: bool,
    ) -> std::result::Result<SwapReport, ControlError> {
        let (name, version) = crate::repo::parse_spec(spec)
            .map_err(|e| ControlError::BadSpec(format!("{e:#}")))?;
        let repo = self.repo.as_ref().ok_or(ControlError::NoRepo)?;
        let full_name = format!("{name}@{version}");

        // phase 1: claim the alias (create a placeholder if new)
        let swapped_from = {
            let mut inner = self.lock();
            let a = inner.aliases.entry(name.clone()).or_insert_with(|| Alias {
                versions: BTreeMap::new(),
                latest: String::new(),
                swapping: false,
            });
            if a.swapping {
                // a freshly created placeholder can't be swapping, so
                // this only fires for pre-existing aliases — nothing to
                // clean up
                return Err(ControlError::SwapInProgress(name));
            }
            a.swapping = true;
            (!a.latest.is_empty() && a.latest != version)
                .then(|| a.versions.get(&a.latest).map(|s| s.name.clone()))
                .flatten()
        };

        // phase 2: verify + load with the lock released — the serving
        // path keeps resolving the old version throughout
        let verified = match repo.verify(&name, &version) {
            Ok(v) => v,
            Err(e) => {
                self.abort_swap(&name);
                return Err(ControlError::Rejected(format!("{e:#}")));
            }
        };
        let (resident, load_ms) = if lazy {
            (None, 0.0)
        } else {
            let t0 = Instant::now();
            match InferenceModel::load_with_policy(
                &verified.dir,
                &verified.stem,
                self.default_policy.clone(),
            ) {
                Ok(model) => {
                    let load_ms = t0.elapsed().as_secs_f64() * 1e3;
                    (
                        Some(Self::make_entry(&full_name, &name, &version, model, load_ms)),
                        load_ms,
                    )
                }
                Err(e) => {
                    self.abort_swap(&name);
                    return Err(ControlError::Rejected(format!("{e:#}")));
                }
            }
        };

        // phase 3: install the slot and repoint the alias atomically
        let source = Source {
            dir: verified.dir.clone(),
            stem: verified.stem.clone(),
            policy: self.default_policy.clone(),
            verify: Some((name.clone(), version.clone())),
        };
        {
            let mut inner = self.lock();
            inner.clock += 1;
            let tick = inner.clock;
            let a = inner
                .aliases
                .get_mut(&name)
                .expect("alias held by the swapping flag vanished");
            let had_versions = !a.latest.is_empty();
            a.versions.insert(
                version.clone(),
                Slot {
                    name: full_name.clone(),
                    resident,
                    source: Some(source),
                    last_used: tick,
                    installed: tick,
                },
            );
            a.latest = version.clone();
            a.swapping = false;
            if had_versions {
                self.swaps.fetch_add(1, Ordering::Relaxed);
            }
            self.evict_to_budget(&mut inner, (&name, &version));
        }
        trace::log(Level::Info, "model_swapped", &[
            ("model", Json::str(full_name.clone())),
            ("swapped_from", swapped_from.clone().map(Json::str).unwrap_or(Json::Null)),
            ("lazy", Json::Bool(lazy)),
            ("load_ms", Json::num(load_ms)),
        ]);
        Ok(SwapReport {
            name: full_name,
            alias: name,
            version,
            swapped_from,
            load_ms,
            lazy,
        })
    }

    /// Clear the swapping flag after a failed admit, dropping the
    /// placeholder if the alias never had a version.
    fn abort_swap(&self, alias: &str) {
        let mut inner = self.lock();
        if let Some(a) = inner.aliases.get_mut(alias) {
            a.swapping = false;
            if a.versions.is_empty() {
                inner.aliases.remove(alias);
            }
        }
    }

    /// Evict least-recently-used reloadable slots until resident bytes
    /// fit the budget. The slot named by `protect` (the one just
    /// installed) is never evicted, so a single oversized model still
    /// serves. Entries without a source (plain `register`) are pinned.
    fn evict_to_budget(&self, inner: &mut Inner, protect: (&str, &str)) {
        let Some(budget) = self.max_resident_bytes else { return };
        loop {
            let total: usize = inner
                .aliases
                .values()
                .flat_map(|a| a.versions.values())
                .filter_map(|s| s.resident.as_ref())
                .map(|e| e.model.resident_bytes())
                .sum();
            if total <= budget {
                return;
            }
            let mut victim: Option<(String, String, u64)> = None;
            for (an, a) in &inner.aliases {
                for (vn, s) in &a.versions {
                    if s.resident.is_none() || s.source.is_none() {
                        continue;
                    }
                    if (an.as_str(), vn.as_str()) == protect {
                        continue;
                    }
                    if victim.as_ref().map_or(true, |(_, _, lu)| s.last_used < *lu) {
                        victim = Some((an.clone(), vn.clone(), s.last_used));
                    }
                }
            }
            let Some((an, vn, _)) = victim else { return };
            let slot = inner
                .aliases
                .get_mut(&an)
                .and_then(|a| a.versions.get_mut(&vn))
                .expect("victim slot vanished");
            let freed = slot.resident.as_ref().map_or(0, |e| e.model.resident_bytes());
            slot.resident = None;
            self.evictions.fetch_add(1, Ordering::Relaxed);
            trace::log(Level::Info, "model_evicted", &[
                ("model", Json::str(slot.name.clone())),
                ("freed_bytes", Json::num(freed as f64)),
                ("budget_bytes", Json::num(budget as f64)),
            ]);
        }
    }

    /// The single registered alias's latest resident entry, if exactly
    /// one alias exists — the default target for requests that omit the
    /// `model` field.
    pub fn sole(&self) -> Option<Arc<ModelEntry>> {
        let inner = self.lock();
        if inner.aliases.len() != 1 {
            return None;
        }
        let a = inner.aliases.values().next()?;
        a.versions.get(&a.latest).and_then(|s| s.resident.clone())
    }

    /// [`Registry::sole`] with lazy reload: the single alias resolves
    /// even when its latest slot was evicted or admitted lazily.
    pub fn resolve_sole(&self) -> Result<Option<Arc<ModelEntry>>> {
        let name = {
            let inner = self.lock();
            if inner.aliases.len() != 1 {
                return Ok(None);
            }
            inner.aliases.keys().next().cloned()
        };
        match name {
            Some(n) => self.resolve(&n),
            None => Ok(None),
        }
    }

    /// Full names of every registered version slot (resident or not).
    pub fn names(&self) -> Vec<String> {
        let inner = self.lock();
        inner
            .aliases
            .values()
            .flat_map(|a| a.versions.values())
            .map(|s| s.name.clone())
            .collect()
    }

    /// Every resident entry (what `/metrics` reports gauges for).
    pub fn resident_entries(&self) -> Vec<Arc<ModelEntry>> {
        let inner = self.lock();
        inner
            .aliases
            .values()
            .flat_map(|a| a.versions.values())
            .filter_map(|s| s.resident.clone())
            .collect()
    }

    /// Total bytes the resident entries keep loaded — what the eviction
    /// budget bounds.
    pub fn resident_bytes_total(&self) -> usize {
        self.resident_entries()
            .iter()
            .map(|e| e.model.resident_bytes())
            .sum()
    }

    /// Registered version slots (resident or not).
    pub fn len(&self) -> usize {
        let inner = self.lock();
        inner.aliases.values().map(|a| a.versions.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The `GET /models` body: one record per version slot (full stats
    /// for resident ones), plus control-plane totals.
    pub fn to_json(&self) -> Json {
        let inner = self.lock();
        let mut models = Vec::new();
        for (an, a) in &inner.aliases {
            for (vn, s) in &a.versions {
                let serving = *vn == a.latest;
                let mut fields = vec![
                    ("name", Json::str(s.name.clone())),
                    ("alias", Json::str(an.clone())),
                    ("version", Json::str(vn.clone())),
                    ("serving", Json::Bool(serving)),
                    ("resident", Json::Bool(s.resident.is_some())),
                ];
                if let Some(e) = &s.resident {
                    fields.extend(vec![
                        ("model", Json::str(e.model.model.clone())),
                        ("num_classes", Json::num(e.model.num_classes as f64)),
                        ("input_dims",
                         Json::arr(e.model.input_dims.iter().map(|&d| Json::num(d as f64)))),
                        ("feature_len", Json::num(e.feature_len as f64)),
                        ("bits_per_weight", Json::num(e.model.bits_per_weight)),
                        ("compression_ratio", Json::num(e.model.compression_ratio)),
                        ("compute_mode", Json::str(e.model.mode_label())),
                        ("layer_modes",
                         Json::arr(e.model.layer_modes().into_iter().map(|lm| {
                             Json::obj(vec![
                                 ("idx", Json::num(lm.idx as f64)),
                                 ("mode", Json::str(lm.mode.label())),
                                 ("act_planes",
                                  lm.mode
                                      .act_planes()
                                      .map_or(Json::Null, |m| Json::num(m as f64))),
                                 ("weights", Json::num(lm.weights as f64)),
                             ])
                         }))),
                        ("quantized_weight_bytes",
                         Json::num(e.model.quantized_resident_bytes() as f64)),
                        ("fp_weight_bytes",
                         Json::num(e.model.fp_resident_bytes() as f64)),
                        ("resident_bytes", Json::num(e.model.resident_bytes() as f64)),
                        // serving-time storage rate over the quantized layers
                        // (sub-1.0 on the Encrypted engine) — the headline
                        // the decrypt-on-demand path exists to deliver
                        ("resident_bits_per_weight",
                         Json::num(e.model.resident_bits_per_weight())),
                        ("load_ms", Json::num(e.load_ms)),
                    ]);
                }
                models.push(Json::obj(fields));
            }
        }
        Json::obj(vec![
            ("models", Json::Arr(models)),
            ("swaps_total", Json::num(self.swaps_total() as f64)),
            ("evictions_total", Json::num(self.evictions_total() as f64)),
        ])
    }
}

impl Default for Registry {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    //! Registry tests that need a real model go through a synthetic bundle
    //! in `rust/tests/serve.rs` / `rust/tests/control_plane.rs`
    //! (InferenceModel is only constructible via `load`). Here:
    //! empty-registry behavior and name grammar.
    use super::*;

    #[test]
    fn empty_registry() {
        let r = Registry::new();
        assert!(r.is_empty());
        assert_eq!(r.len(), 0);
        assert!(r.get("x").is_none());
        assert!(r.sole().is_none());
        assert!(r.names().is_empty());
        assert!(r.resolve("x").unwrap().is_none());
        assert!(r.resolve_sole().unwrap().is_none());
        assert_eq!(r.to_json().get("models").as_arr().map(|a| a.len()), Some(0));
        assert_eq!(r.swaps_total(), 0);
        assert_eq!(r.evictions_total(), 0);
        assert_eq!(r.resident_bytes_total(), 0);
    }

    #[test]
    fn split_name_grammar() {
        assert_eq!(split_name("alpha"), ("alpha", None));
        assert_eq!(split_name("resnet20@v2"), ("resnet20", Some("v2")));
        assert_eq!(split_name("a@b@c"), ("a", Some("b@c")));
    }

    #[test]
    fn unload_unknown_model_fails() {
        // full load → unload → reload round trips live in
        // rust/tests/bitslice.rs (they need a real bundle)
        let r = Registry::new();
        let err = r.unload("ghost").unwrap_err();
        assert!(err.to_string().contains("ghost"), "{err}");
        let err = r.unload("ghost@v3").unwrap_err();
        assert!(err.to_string().contains("ghost@v3"), "{err}");
    }

    #[test]
    fn remove_unknown_is_a_control_error() {
        let r = Registry::new();
        match r.remove("ghost") {
            Err(ControlError::Unknown(n)) => assert_eq!(n, "ghost"),
            other => panic!("expected Unknown, got {other:?}"),
        }
    }

    #[test]
    fn load_missing_bundle_fails() {
        let r = Registry::new();
        let err = r
            .load("ghost", Path::new("/nonexistent/dir"), "nope")
            .unwrap_err();
        assert!(!err.to_string().is_empty());
        assert!(r.is_empty(), "failed load must register nothing");
    }

    #[test]
    fn admit_without_repo_is_rejected() {
        let r = Registry::new();
        match r.admit_from_repo("m@v1", false) {
            Err(ControlError::NoRepo) => {}
            other => panic!("expected NoRepo, got {other:?}"),
        }
        match r.admit_from_repo("bare-name", false) {
            Err(ControlError::BadSpec(m)) => assert!(m.contains("name@version"), "{m}"),
            other => panic!("expected BadSpec, got {other:?}"),
        }
    }

    #[test]
    fn control_error_messages() {
        assert!(ControlError::SwapInProgress("m".into()).to_string().contains("in progress"));
        assert!(ControlError::Unknown("m".into()).to_string().contains("not registered"));
        assert!(ControlError::NoRepo.to_string().contains("repo"));
    }
}
