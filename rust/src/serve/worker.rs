//! Worker-thread pool: drains the admission queue against the shared
//! decrypted models and fans results back through per-request channels.
//!
//! Each worker loops on [`BatchQueue::pop_batch_timed`], sheds requests
//! whose deadline expired while queued (they get a coded
//! `deadline_exceeded` error, never a forward pass), groups the
//! surviving requests by target model (a popped batch may interleave
//! models), runs **one forward pass per group**, and answers every
//! request on its own one-shot channel. Workers exit when the queue is
//! closed and drained, so shutdown never drops an admitted request.
//!
//! Fault containment (DESIGN.md §12): every batch forward runs inside
//! `catch_unwind`, so a panicking shard (or an integrity-check panic in
//! the Encrypted engine) poisons exactly one batch — its requests get a
//! coded `500` with the panic message, the worker keeps serving, and
//! nothing is left blocked on a Condvar. A worker that panics
//! [`MAX_CONSECUTIVE_PANICS`] times in a row exits and is respawned by
//! the pool's supervisor thread, which keeps the live-worker count (the
//! `/readyz` signal) honest.
//!
//! Observability: each forward runs inside a [`trace`] scope carrying
//! the model's [`Profile`](trace::Profile) sink, so (when the server's
//! [`TraceMode`](trace::TraceMode) samples it in) every pipeline stage
//! lands in `GET /models/<name>/profile`. Queue wait, batch-assembly
//! time, deadline sheds, panics, and respawns feed [`ServeMetrics`].
//!
//! Thread budget: each forward shards its GEMMs across the shared
//! intra-op pool (`substrate::pool`, sized by `ServeConfig::intra_threads`
//! at server start). Concurrent workers submit jobs to the same pool —
//! jobs queue FIFO and every worker always advances its own job, so
//! worker-level and GEMM-level parallelism compose without deadlock or
//! oversubscription (DESIGN.md §7).

use std::collections::BTreeMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use crate::substrate::json::Json;
use crate::substrate::{fault, net, trace};

use super::error::{ErrorCode, ServeError};
use super::metrics::ServeMetrics;
use super::queue::BatchQueue;
use super::registry::ModelEntry;

/// A worker that panics this many batches in a row exits (and is
/// respawned fresh by the supervisor): the forward state is assumed
/// wedged beyond what batch-level containment can fix.
pub const MAX_CONSECUTIVE_PANICS: u32 = 3;

/// A successfully served prediction.
#[derive(Clone, Debug)]
pub struct Prediction {
    /// Registry name of the model that served the request.
    pub model: String,
    /// Argmax class index.
    pub class: i32,
    /// How many requests shared the forward pass (coalescing visibility).
    pub batch_size: usize,
    /// Admission → response latency in milliseconds.
    pub latency_ms: f64,
}

/// What comes back on a request's response channel.
pub type Response = std::result::Result<Prediction, ServeError>;

/// One finished prediction handed back to the event loop: which
/// connection/sequence slot it answers, the result, and the features
/// buffer riding along so the loop thread can recycle it through its
/// thread-local arena (arenas do not share across threads).
pub struct Completion {
    pub conn: u64,
    pub seq: u64,
    pub result: Response,
    pub features: Vec<f32>,
}

/// Shared mailbox between worker threads and a nonblocking event loop:
/// workers push finished predictions and nudge the loop's waker; the
/// loop drains on wakeup. The blocking front-end never uses this — it
/// keeps per-request channels.
pub struct CompletionBoard {
    inner: Mutex<Vec<Completion>>,
    waker: net::WakeHandle,
}

impl CompletionBoard {
    pub fn new(waker: net::WakeHandle) -> CompletionBoard {
        CompletionBoard { inner: Mutex::new(Vec::new()), waker }
    }

    pub fn push(&self, c: Completion) {
        self.inner.lock().unwrap().push(c);
        self.waker.wake();
    }

    /// Move all pending completions into `out` (amortized allocation:
    /// the internal Vec keeps its capacity).
    pub fn drain(&self, out: &mut Vec<Completion>) {
        out.append(&mut self.inner.lock().unwrap());
    }
}

/// Where a request's answer goes: a blocking one-shot channel
/// (thread-per-connection mode, benches, direct tests) or a completion
/// slot on the event loop's board. `complete` consumes the responder —
/// every request is answered exactly once.
pub enum Responder {
    Channel(mpsc::Sender<Response>),
    Completion { board: Arc<CompletionBoard>, conn: u64, seq: u64 },
}

impl Responder {
    pub fn complete(self, result: Response, features: Vec<f32>) {
        match self {
            Responder::Channel(tx) => {
                tx.send(result).ok();
            }
            Responder::Completion { board, conn, seq } => {
                board.push(Completion { conn, seq, result, features });
            }
        }
    }
}

/// One admitted inference request.
pub struct Request {
    /// Resolved at admission so workers never need the registry lock.
    pub entry: Arc<ModelEntry>,
    /// Flat input features, length `entry.feature_len`.
    pub features: Vec<f32>,
    /// One-shot response path back to the waiting connection.
    pub respond: Responder,
    /// Admission timestamp (latency accounting).
    pub enqueued: Instant,
    /// Absolute deadline (from `X-Deadline-Ms` / `FLEXOR_DEADLINE_MS`);
    /// requests still queued past it are shed, not computed.
    pub deadline: Option<Instant>,
}

/// Everything a worker thread needs; cloned per (re)spawn.
struct WorkerCfg {
    queue: Arc<BatchQueue<Request>>,
    metrics: Arc<ServeMetrics>,
    max_batch: usize,
    max_wait: Duration,
    mode: trace::TraceMode,
}

impl Clone for WorkerCfg {
    fn clone(&self) -> Self {
        WorkerCfg {
            queue: self.queue.clone(),
            metrics: self.metrics.clone(),
            max_batch: self.max_batch,
            max_wait: self.max_wait,
            mode: self.mode,
        }
    }
}

/// Handle over the spawned worker threads plus their supervisor.
pub struct WorkerPool {
    handles: Arc<Mutex<Vec<thread::JoinHandle<()>>>>,
    supervisor: Option<thread::JoinHandle<()>>,
    stop: Arc<AtomicBool>,
    alive: Arc<AtomicUsize>,
    size: usize,
}

impl WorkerPool {
    /// Spawn `n` workers draining `queue` with the given batching policy,
    /// plus a supervisor thread that joins dead workers and respawns
    /// them while the queue is open. `trace_mode` decides which forwards
    /// get stage-level spans (`None` defers to the `FLEXOR_TRACE` env
    /// dial).
    pub fn spawn(
        n: usize,
        queue: Arc<BatchQueue<Request>>,
        metrics: Arc<ServeMetrics>,
        max_batch: usize,
        max_wait: Duration,
        trace_mode: Option<trace::TraceMode>,
    ) -> WorkerPool {
        assert!(n > 0, "worker pool needs at least one thread");
        let mode = trace_mode.unwrap_or_else(trace::env_mode);
        let cfg = WorkerCfg { queue, metrics, max_batch, max_wait, mode };
        let alive = Arc::new(AtomicUsize::new(0));
        let handles: Vec<thread::JoinHandle<()>> =
            (0..n).map(|i| spawn_worker(i, cfg.clone(), alive.clone())).collect();
        let handles = Arc::new(Mutex::new(handles));
        let stop = Arc::new(AtomicBool::new(false));

        let supervisor = {
            let handles = handles.clone();
            let stop = stop.clone();
            let alive = alive.clone();
            let cfg = cfg.clone();
            thread::Builder::new()
                .name("serve-supervisor".into())
                .spawn(move || supervise(n, &handles, &stop, &alive, &cfg))
                .expect("spawning serve supervisor")
        };

        WorkerPool { handles, supervisor: Some(supervisor), stop, alive, size: n }
    }

    /// Configured worker count.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Workers currently inside their serve loop.
    pub fn alive(&self) -> usize {
        self.alive.load(Ordering::Acquire)
    }

    /// Shared live-worker counter for `/readyz` reporting.
    pub fn alive_handle(&self) -> Arc<AtomicUsize> {
        self.alive.clone()
    }

    /// Wait for all workers to exit (close the queue first). Stops the
    /// supervisor before joining so no worker is respawned mid-shutdown.
    pub fn join(mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(s) = self.supervisor.take() {
            s.join().ok();
        }
        let handles = std::mem::take(&mut *self.handles.lock().unwrap());
        for h in handles {
            h.join().ok();
        }
    }
}

fn spawn_worker(id: usize, cfg: WorkerCfg, alive: Arc<AtomicUsize>) -> thread::JoinHandle<()> {
    alive.fetch_add(1, Ordering::AcqRel);
    let res = thread::Builder::new()
        .name(format!("serve-worker-{id}"))
        .spawn(move || {
            // decrement on every exit path, panic included
            struct AliveGuard(Arc<AtomicUsize>);
            impl Drop for AliveGuard {
                fn drop(&mut self) {
                    self.0.fetch_sub(1, Ordering::AcqRel);
                }
            }
            let _g = AliveGuard(alive);
            worker_loop(&cfg.queue, &cfg.metrics, cfg.max_batch, cfg.max_wait, cfg.mode);
        });
    res.expect("spawning serve worker")
}

/// Supervisor loop: poll for finished (dead or exited) workers, join
/// them, and respawn replacements while the queue is still open.
fn supervise(
    n: usize,
    handles: &Mutex<Vec<thread::JoinHandle<()>>>,
    stop: &AtomicBool,
    alive: &Arc<AtomicUsize>,
    cfg: &WorkerCfg,
) {
    let mut next_id = n;
    while !stop.load(Ordering::Acquire) {
        thread::sleep(Duration::from_millis(20));
        let mut dead = Vec::new();
        {
            let mut hs = handles.lock().unwrap();
            let mut i = 0;
            while i < hs.len() {
                if hs[i].is_finished() {
                    dead.push(hs.swap_remove(i));
                } else {
                    i += 1;
                }
            }
        }
        if dead.is_empty() {
            continue;
        }
        for h in dead {
            h.join().ok();
            // a clean exit only happens when the queue closed for
            // shutdown; everything else is a crash worth replacing
            if cfg.queue.is_closed() || stop.load(Ordering::Acquire) {
                continue;
            }
            cfg.metrics.record_worker_restart();
            trace::log(
                trace::Level::Warn,
                "worker_respawned",
                &[("workers_alive", Json::num(alive.load(Ordering::Acquire) as f64))],
            );
            let nh = spawn_worker(next_id, cfg.clone(), alive.clone());
            next_id += 1;
            handles.lock().unwrap().push(nh);
        }
    }
}

fn worker_loop(
    queue: &BatchQueue<Request>,
    metrics: &ServeMetrics,
    max_batch: usize,
    max_wait: Duration,
    mode: trace::TraceMode,
) {
    let mut consecutive_panics: u32 = 0;
    while let Some((batch, assembly)) = queue.pop_batch_timed(max_batch, max_wait) {
        metrics.record_batch_assembly(assembly.as_secs_f64() * 1e3);
        // fault hook first so `dequeued` (the deadline check's "now")
        // sees the stalled age — a queue_stall fault must expire
        // deadlines exactly like a genuinely wedged assembly stage
        fault::maybe_queue_stall();
        let dequeued = Instant::now();
        // group by model, preserving arrival order within each group;
        // shed expired requests before any batch assembly
        let mut groups: BTreeMap<String, Vec<Request>> = BTreeMap::new();
        for r in batch {
            // queue wait = admission → dequeue (assembly linger included,
            // forward excluded)
            let waited_ms =
                dequeued.saturating_duration_since(r.enqueued).as_secs_f64() * 1e3;
            metrics.record_queue_wait(waited_ms);
            if let Some(deadline) = r.deadline {
                if deadline < dequeued {
                    metrics.record_expired();
                    trace::log(
                        trace::Level::Warn,
                        "deadline_expired",
                        &[
                            ("model", Json::str(r.entry.name.clone())),
                            ("queue_wait_ms", Json::num(waited_ms)),
                        ],
                    );
                    let Request { respond, features, .. } = r;
                    respond.complete(
                        Err(ServeError::new(
                            ErrorCode::DeadlineExceeded,
                            format!("deadline exceeded after {waited_ms:.1} ms in queue"),
                        )),
                        features,
                    );
                    continue;
                }
            }
            groups.entry(r.entry.name.clone()).or_default().push(r);
        }
        let mut any_panicked = false;
        for (_, reqs) in groups {
            any_panicked |= serve_group(reqs, metrics, mode);
        }
        if any_panicked {
            consecutive_panics += 1;
            if consecutive_panics >= MAX_CONSECUTIVE_PANICS {
                trace::log(
                    trace::Level::Error,
                    "worker_exiting_after_repeated_panics",
                    &[("consecutive_panics", Json::num(consecutive_panics as f64))],
                );
                return; // supervisor respawns a fresh worker
            }
        } else {
            consecutive_panics = 0;
        }
    }
}

/// Run one batched forward for requests that share a model. Returns
/// true when the forward panicked (contained by `catch_unwind`).
fn serve_group(reqs: Vec<Request>, metrics: &ServeMetrics, mode: trace::TraceMode) -> bool {
    let entry = reqs[0].entry.clone();
    let fl = entry.feature_len;

    // admission validates lengths; anything inconsistent is answered
    // individually instead of poisoning the whole batch
    let mut batch = Vec::with_capacity(reqs.len());
    let mut x = Vec::with_capacity(reqs.len() * fl);
    for r in reqs {
        if r.features.len() == fl {
            x.extend_from_slice(&r.features);
            batch.push(r);
        } else {
            let msg = format!(
                "feature length {} != model feature_len {fl}",
                r.features.len()
            );
            metrics.record_request(&entry.name, elapsed_ms(&r), false);
            let Request { respond, features, .. } = r;
            respond.complete(Err(ServeError::new(ErrorCode::BadRequest, msg)), features);
        }
    }
    if batch.is_empty() {
        return false;
    }

    let n = batch.len();
    metrics.record_batch(&entry.name, n);
    // catch_unwind contains shard panics: substrate::pool::run re-raises
    // a shard's panic payload on this (the submitting) thread after all
    // shards settle, so both direct forward panics and intra-op shard
    // panics land here instead of wedging the Condvar protocol.
    let result = catch_unwind(AssertUnwindSafe(|| {
        // scope drops (deactivating tracing) before responses are sent
        let _t = trace::scope_with(mode, Some(entry.profile.clone()));
        fault::maybe_slow_layer();
        fault::maybe_panic_shard();
        entry.model.predict(&x, n)
    }));
    match result {
        Ok(Ok(preds)) => {
            for (r, &class) in batch.into_iter().zip(&preds) {
                let latency_ms = elapsed_ms(&r);
                metrics.record_request(&entry.name, latency_ms, true);
                let Request { respond, features, .. } = r;
                respond.complete(
                    Ok(Prediction {
                        model: entry.name.clone(),
                        class,
                        batch_size: n,
                        latency_ms,
                    }),
                    features,
                );
            }
            false
        }
        Ok(Err(e)) => {
            let msg = format!("forward pass failed: {e:#}");
            trace::log(
                trace::Level::Error,
                "forward_failed",
                &[
                    ("model", Json::str(entry.name.clone())),
                    ("batch_size", Json::num(n as f64)),
                    ("error", Json::str(format!("{e:#}"))),
                ],
            );
            for r in batch {
                metrics.record_request(&entry.name, elapsed_ms(&r), false);
                let Request { respond, features, .. } = r;
                respond.complete(Err(ServeError::new(ErrorCode::Internal, msg.clone())), features);
            }
            false
        }
        Err(payload) => {
            let msg = trace::panic_message(payload.as_ref());
            metrics.record_worker_panic();
            trace::log(
                trace::Level::Error,
                "worker_panic",
                &[
                    ("model", Json::str(entry.name.clone())),
                    ("batch_size", Json::num(n as f64)),
                    ("panic", Json::str(msg.clone())),
                ],
            );
            // integrity-check panics (Encrypted engine checksum
            // mismatch) get their own code so clients can tell data
            // corruption from compute bugs
            let code = if msg.contains("integrity") {
                ErrorCode::Integrity
            } else {
                ErrorCode::WorkerPanic
            };
            for r in batch {
                metrics.record_request(&entry.name, elapsed_ms(&r), false);
                let Request { respond, features, .. } = r;
                respond.complete(
                    Err(ServeError::new(code, format!("worker panicked: {msg}"))),
                    features,
                );
            }
            true
        }
    }
}

fn elapsed_ms(r: &Request) -> f64 {
    r.enqueued.elapsed().as_secs_f64() * 1e3
}
