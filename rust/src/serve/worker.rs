//! Worker-thread pool: drains the admission queue against the shared
//! decrypted models and fans results back through per-request channels.
//!
//! Each worker loops on [`BatchQueue::pop_batch_timed`], groups the
//! coalesced requests by target model (a popped batch may interleave
//! models), runs **one forward pass per group**, and answers every
//! request on its own one-shot channel. Workers exit when the queue is
//! closed and drained, so shutdown never drops an admitted request.
//!
//! Observability: each forward runs inside a [`trace`] scope carrying
//! the model's [`Profile`](trace::Profile) sink, so (when the server's
//! [`TraceMode`](trace::TraceMode) samples it in) every pipeline stage
//! lands in `GET /models/<name>/profile`. Queue wait and batch-assembly
//! time feed [`ServeMetrics`] per dequeue.
//!
//! Thread budget: each forward shards its GEMMs across the shared
//! intra-op pool (`substrate::pool`, sized by `ServeConfig::intra_threads`
//! at server start). Concurrent workers submit jobs to the same pool —
//! jobs queue FIFO and every worker always advances its own job, so
//! worker-level and GEMM-level parallelism compose without deadlock or
//! oversubscription (DESIGN.md §7).

use std::collections::BTreeMap;
use std::sync::mpsc;
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use crate::substrate::trace;

use super::metrics::ServeMetrics;
use super::queue::BatchQueue;
use super::registry::ModelEntry;

/// A successfully served prediction.
#[derive(Clone, Debug)]
pub struct Prediction {
    /// Registry name of the model that served the request.
    pub model: String,
    /// Argmax class index.
    pub class: i32,
    /// How many requests shared the forward pass (coalescing visibility).
    pub batch_size: usize,
    /// Admission → response latency in milliseconds.
    pub latency_ms: f64,
}

/// What comes back on a request's response channel.
pub type Response = std::result::Result<Prediction, String>;

/// One admitted inference request.
pub struct Request {
    /// Resolved at admission so workers never need the registry lock.
    pub entry: Arc<ModelEntry>,
    /// Flat input features, length `entry.feature_len`.
    pub features: Vec<f32>,
    /// One-shot response channel back to the waiting connection handler.
    pub respond: mpsc::Sender<Response>,
    /// Admission timestamp (latency accounting).
    pub enqueued: Instant,
}

/// Handle over the spawned worker threads.
pub struct WorkerPool {
    handles: Vec<thread::JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `n` workers draining `queue` with the given batching policy.
    /// `trace_mode` decides which forwards get stage-level spans
    /// (`None` defers to the `FLEXOR_TRACE` env dial).
    pub fn spawn(
        n: usize,
        queue: Arc<BatchQueue<Request>>,
        metrics: Arc<ServeMetrics>,
        max_batch: usize,
        max_wait: Duration,
        trace_mode: Option<trace::TraceMode>,
    ) -> WorkerPool {
        assert!(n > 0, "worker pool needs at least one thread");
        let mode = trace_mode.unwrap_or_else(trace::env_mode);
        let handles = (0..n)
            .map(|i| {
                let queue = queue.clone();
                let metrics = metrics.clone();
                thread::Builder::new()
                    .name(format!("serve-worker-{i}"))
                    .spawn(move || worker_loop(&queue, &metrics, max_batch, max_wait, mode))
                    .expect("spawning serve worker")
            })
            .collect();
        WorkerPool { handles }
    }

    pub fn size(&self) -> usize {
        self.handles.len()
    }

    /// Wait for all workers to exit (close the queue first).
    pub fn join(self) {
        for h in self.handles {
            h.join().ok();
        }
    }
}

fn worker_loop(
    queue: &BatchQueue<Request>,
    metrics: &ServeMetrics,
    max_batch: usize,
    max_wait: Duration,
    mode: trace::TraceMode,
) {
    while let Some((batch, assembly)) = queue.pop_batch_timed(max_batch, max_wait) {
        metrics.record_batch_assembly(assembly.as_secs_f64() * 1e3);
        let dequeued = Instant::now();
        // group by model, preserving arrival order within each group
        let mut groups: BTreeMap<String, Vec<Request>> = BTreeMap::new();
        for r in batch {
            // queue wait = admission → dequeue (assembly linger included,
            // forward excluded)
            metrics.record_queue_wait(
                dequeued.saturating_duration_since(r.enqueued).as_secs_f64() * 1e3,
            );
            groups.entry(r.entry.name.clone()).or_default().push(r);
        }
        for (_, reqs) in groups {
            serve_group(reqs, metrics, mode);
        }
    }
}

/// Run one batched forward for requests that share a model.
fn serve_group(reqs: Vec<Request>, metrics: &ServeMetrics, mode: trace::TraceMode) {
    let entry = reqs[0].entry.clone();
    let fl = entry.feature_len;

    // admission validates lengths; anything inconsistent is answered
    // individually instead of poisoning the whole batch
    let mut batch = Vec::with_capacity(reqs.len());
    let mut x = Vec::with_capacity(reqs.len() * fl);
    for r in reqs {
        if r.features.len() == fl {
            x.extend_from_slice(&r.features);
            batch.push(r);
        } else {
            let msg = format!(
                "feature length {} != model feature_len {fl}",
                r.features.len()
            );
            metrics.record_request(&entry.name, elapsed_ms(&r), false);
            r.respond.send(Err(msg)).ok();
        }
    }
    if batch.is_empty() {
        return;
    }

    let n = batch.len();
    metrics.record_batch(&entry.name, n);
    let result = {
        // scope drops (deactivating tracing) before responses are sent
        let _t = trace::scope_with(mode, Some(entry.profile.clone()));
        entry.model.predict(&x, n)
    };
    match result {
        Ok(preds) => {
            for (r, &class) in batch.iter().zip(&preds) {
                let latency_ms = elapsed_ms(r);
                metrics.record_request(&entry.name, latency_ms, true);
                r.respond
                    .send(Ok(Prediction {
                        model: entry.name.clone(),
                        class,
                        batch_size: n,
                        latency_ms,
                    }))
                    .ok();
            }
        }
        Err(e) => {
            let msg = format!("forward pass failed: {e:#}");
            trace::log(
                trace::Level::Error,
                "forward_failed",
                &[
                    ("model", crate::substrate::json::Json::str(entry.name.clone())),
                    ("batch_size", crate::substrate::json::Json::num(n as f64)),
                    ("error", crate::substrate::json::Json::str(format!("{e:#}"))),
                ],
            );
            for r in &batch {
                metrics.record_request(&entry.name, elapsed_ms(r), false);
                r.respond.send(Err(msg.clone())).ok();
            }
        }
    }
}

fn elapsed_ms(r: &Request) -> f64 {
    r.enqueued.elapsed().as_secs_f64() * 1e3
}
