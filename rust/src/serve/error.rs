//! Structured serving errors: every non-2xx response carries a stable
//! machine-readable `code` alongside the human message and request id
//! (DESIGN.md §12). Workers send [`ServeError`] back through the
//! response channel so the HTTP layer can map failure classes to
//! status codes without string matching.

use std::fmt;

/// Stable error codes for the HTTP surface. The `label()` strings are
/// part of the wire contract (README error-code table) — add variants
/// freely, never rename existing labels.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrorCode {
    /// 400 — malformed JSON, wrong-shape features, bad headers.
    BadRequest,
    /// 404 — model name not in the registry.
    UnknownModel,
    /// 404 — no route for the path.
    NoRoute,
    /// 405 — route exists, method does not.
    MethodNotAllowed,
    /// 408 — client was too slow delivering the request head or body
    /// (per-connection header/body timeout).
    RequestTimeout,
    /// 409 — a model swap is already in progress for the alias.
    SwapInProgress,
    /// 409 — bundle failed signature/digest/parse checks; nothing was
    /// registered.
    BundleRejected,
    /// 413 — request body exceeds the configured byte bound.
    BodyTooLarge,
    /// 431 — request line or header block exceeds the line/count bounds.
    HeadersTooLarge,
    /// 500 — forward pass returned an error.
    Internal,
    /// 500 — a worker panicked while serving the batch.
    WorkerPanic,
    /// 500 — bundle integrity check failed at decrypt time.
    Integrity,
    /// 503 — admission queue full; retry later.
    QueueFull,
    /// 503 — server is draining for shutdown.
    Draining,
    /// 503 — request deadline expired before compute started.
    DeadlineExceeded,
    /// 504 — worker did not answer within the response timeout.
    Timeout,
}

impl ErrorCode {
    /// HTTP status code for this error class.
    pub fn status(self) -> u16 {
        match self {
            ErrorCode::BadRequest => 400,
            ErrorCode::UnknownModel | ErrorCode::NoRoute => 404,
            ErrorCode::MethodNotAllowed => 405,
            ErrorCode::RequestTimeout => 408,
            ErrorCode::SwapInProgress | ErrorCode::BundleRejected => 409,
            ErrorCode::BodyTooLarge => 413,
            ErrorCode::HeadersTooLarge => 431,
            ErrorCode::Internal | ErrorCode::WorkerPanic | ErrorCode::Integrity => 500,
            ErrorCode::QueueFull | ErrorCode::Draining | ErrorCode::DeadlineExceeded => 503,
            ErrorCode::Timeout => 504,
        }
    }

    /// Stable machine-readable label carried in error bodies.
    pub fn label(self) -> &'static str {
        match self {
            ErrorCode::BadRequest => "bad_request",
            ErrorCode::UnknownModel => "unknown_model",
            ErrorCode::NoRoute => "no_route",
            ErrorCode::MethodNotAllowed => "method_not_allowed",
            ErrorCode::RequestTimeout => "request_timeout",
            ErrorCode::HeadersTooLarge => "headers_too_large",
            ErrorCode::SwapInProgress => "swap_in_progress",
            ErrorCode::BundleRejected => "bundle_rejected",
            ErrorCode::BodyTooLarge => "body_too_large",
            ErrorCode::Internal => "internal",
            ErrorCode::WorkerPanic => "worker_panic",
            ErrorCode::Integrity => "integrity",
            ErrorCode::QueueFull => "queue_full",
            ErrorCode::Draining => "draining",
            ErrorCode::DeadlineExceeded => "deadline_exceeded",
            ErrorCode::Timeout => "timeout",
        }
    }
}

/// A coded serving failure: travels from workers to the HTTP layer and
/// renders as `{"error", "code", "request_id"}`.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeError {
    pub code: ErrorCode,
    pub message: String,
}

impl ServeError {
    pub fn new(code: ErrorCode, message: impl Into<String>) -> Self {
        ServeError { code, message: message.into() }
    }

    pub fn status(&self) -> u16 {
        self.code.status()
    }
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}: {}", self.code.label(), self.message)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codes_map_to_statuses() {
        assert_eq!(ErrorCode::BadRequest.status(), 400);
        assert_eq!(ErrorCode::UnknownModel.status(), 404);
        assert_eq!(ErrorCode::SwapInProgress.status(), 409);
        assert_eq!(ErrorCode::BundleRejected.status(), 409);
        assert_eq!(ErrorCode::BodyTooLarge.status(), 413);
        assert_eq!(ErrorCode::RequestTimeout.status(), 408);
        assert_eq!(ErrorCode::HeadersTooLarge.status(), 431);
        assert_eq!(ErrorCode::WorkerPanic.status(), 500);
        assert_eq!(ErrorCode::QueueFull.status(), 503);
        assert_eq!(ErrorCode::DeadlineExceeded.status(), 503);
        assert_eq!(ErrorCode::Timeout.status(), 504);
    }

    #[test]
    fn labels_are_stable() {
        assert_eq!(ErrorCode::DeadlineExceeded.label(), "deadline_exceeded");
        assert_eq!(ErrorCode::Draining.label(), "draining");
        assert_eq!(ErrorCode::QueueFull.label(), "queue_full");
        assert_eq!(ErrorCode::Integrity.label(), "integrity");
        assert_eq!(ErrorCode::SwapInProgress.label(), "swap_in_progress");
        assert_eq!(ErrorCode::BundleRejected.label(), "bundle_rejected");
        let e = ServeError::new(ErrorCode::Timeout, "inference timed out");
        assert_eq!(e.to_string(), "timeout: inference timed out");
        assert_eq!(e.status(), 504);
    }
}
