//! Serving metrics: request latency distribution, served-batch-size
//! histogram, throughput and error counters — the numbers `GET /metrics`
//! reports and the integration tests assert on (e.g. that the admission
//! queue actually coalesced requests: mean served batch size > 1).
//!
//! Percentiles are computed over a sliding window of recent requests
//! (bounded memory under sustained traffic); totals are exact counters.

use std::collections::{BTreeMap, VecDeque};
use std::sync::Mutex;
use std::time::Instant;

use crate::substrate::json::Json;
use crate::substrate::stats::{percentiles, Moments};

/// Latencies retained for percentile estimation.
const LATENCY_WINDOW: usize = 8192;

#[derive(Default)]
struct Inner {
    /// Sliding window of per-request latencies (ms), newest at the back.
    lat_window: VecDeque<f64>,
    /// Exact running moments over *all* request latencies.
    lat_all: Moments,
    /// Served (per-model forward) batch size → count.
    batch_hist: BTreeMap<usize, u64>,
    batches: u64,
    examples: u64,
    ok: u64,
    errors: u64,
    /// Requests refused at the HTTP layer (bad body, unknown model,
    /// load-shed 503) — they never reached a worker, so they are counted
    /// separately from served-request errors.
    rejected: u64,
}

/// Shared, thread-safe serving metrics.
pub struct ServeMetrics {
    start: Instant,
    inner: Mutex<Inner>,
}

impl ServeMetrics {
    pub fn new() -> Self {
        ServeMetrics { start: Instant::now(), inner: Mutex::new(Inner::default()) }
    }

    /// One forward pass served `n` coalesced requests.
    pub fn record_batch(&self, n: usize) {
        let mut m = self.inner.lock().unwrap();
        *m.batch_hist.entry(n).or_insert(0) += 1;
        m.batches += 1;
        m.examples += n as u64;
    }

    /// One request completed (admission → response) in `latency_ms`.
    pub fn record_request(&self, latency_ms: f64, ok: bool) {
        let mut m = self.inner.lock().unwrap();
        if m.lat_window.len() == LATENCY_WINDOW {
            m.lat_window.pop_front();
        }
        m.lat_window.push_back(latency_ms);
        m.lat_all.push(latency_ms);
        if ok {
            m.ok += 1;
        } else {
            m.errors += 1;
        }
    }

    /// One request refused before admission (4xx/503 at the HTTP layer).
    pub fn record_rejected(&self) {
        self.inner.lock().unwrap().rejected += 1;
    }

    /// Completed requests (ok + errors).
    pub fn requests_total(&self) -> u64 {
        let m = self.inner.lock().unwrap();
        m.ok + m.errors
    }

    /// Examples served per forward pass, averaged — the coalescing factor.
    pub fn mean_batch_size(&self) -> f64 {
        let m = self.inner.lock().unwrap();
        if m.batches == 0 {
            0.0
        } else {
            m.examples as f64 / m.batches as f64
        }
    }

    /// Full snapshot as JSON (the `GET /metrics` body). `queue_depth` is
    /// sampled by the caller from the admission queue.
    pub fn snapshot(&self, queue_depth: usize) -> Json {
        let m = self.inner.lock().unwrap();
        let uptime_s = self.start.elapsed().as_secs_f64();
        let window: Vec<f64> = m.lat_window.iter().copied().collect();
        let (p50, p95, p99) = if window.is_empty() {
            (0.0, 0.0, 0.0)
        } else {
            let p = percentiles(&window, &[50.0, 95.0, 99.0]);
            (p[0], p[1], p[2])
        };
        let total = m.ok + m.errors;
        let mean_batch = if m.batches == 0 {
            0.0
        } else {
            m.examples as f64 / m.batches as f64
        };
        Json::obj(vec![
            ("uptime_s", Json::num(uptime_s)),
            ("requests_total", Json::num(total as f64)),
            ("errors_total", Json::num(m.errors as f64)),
            ("rejected_total", Json::num(m.rejected as f64)),
            ("examples_total", Json::num(m.examples as f64)),
            ("batches_total", Json::num(m.batches as f64)),
            ("mean_batch_size", Json::num(mean_batch)),
            ("batch_size_hist",
             Json::arr(m.batch_hist.iter().map(|(&size, &count)| {
                 Json::obj(vec![
                     ("batch", Json::num(size as f64)),
                     ("count", Json::num(count as f64)),
                 ])
             }))),
            ("queue_depth", Json::num(queue_depth as f64)),
            ("latency_ms",
             Json::obj(vec![
                 ("count", Json::num(m.lat_all.count() as f64)),
                 ("mean", Json::num(if m.lat_all.count() == 0 { 0.0 } else { m.lat_all.mean() })),
                 ("max", Json::num(if m.lat_all.count() == 0 { 0.0 } else { m.lat_all.max() })),
                 ("p50", Json::num(p50)),
                 ("p95", Json::num(p95)),
                 ("p99", Json::num(p99)),
             ])),
            ("throughput_rps",
             Json::num(if uptime_s > 0.0 { total as f64 / uptime_s } else { 0.0 })),
        ])
    }
}

impl Default for ServeMetrics {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_accounting() {
        let m = ServeMetrics::new();
        m.record_batch(1);
        m.record_batch(4);
        m.record_batch(4);
        assert!((m.mean_batch_size() - 3.0).abs() < 1e-12);
        let j = m.snapshot(2);
        assert_eq!(j.get("batches_total").as_usize(), Some(3));
        assert_eq!(j.get("examples_total").as_usize(), Some(9));
        assert_eq!(j.get("queue_depth").as_usize(), Some(2));
        // histogram: batch size 4 seen twice
        let hist = j.get("batch_size_hist");
        assert_eq!(hist.at(1).get("batch").as_usize(), Some(4));
        assert_eq!(hist.at(1).get("count").as_usize(), Some(2));
    }

    #[test]
    fn request_latency_percentiles() {
        let m = ServeMetrics::new();
        for i in 1..=100 {
            m.record_request(i as f64, i != 13);
        }
        let j = m.snapshot(0);
        assert_eq!(j.get("requests_total").as_usize(), Some(100));
        assert_eq!(j.get("errors_total").as_usize(), Some(1));
        let lat = j.get("latency_ms");
        assert_eq!(lat.get("count").as_usize(), Some(100));
        let p50 = lat.get("p50").as_f64().unwrap();
        assert!((p50 - 50.5).abs() < 1.0, "p50 {p50}");
        assert!(lat.get("p99").as_f64().unwrap() >= p50);
        assert_eq!(lat.get("max").as_f64(), Some(100.0));
    }

    #[test]
    fn empty_snapshot_is_well_formed() {
        let j = ServeMetrics::new().snapshot(0);
        assert_eq!(j.get("requests_total").as_usize(), Some(0));
        assert_eq!(j.get("rejected_total").as_usize(), Some(0));
        assert_eq!(j.get("mean_batch_size").as_f64(), Some(0.0));
        assert_eq!(j.get("latency_ms").get("p99").as_f64(), Some(0.0));
    }

    #[test]
    fn rejections_counted_separately() {
        let m = ServeMetrics::new();
        m.record_rejected();
        m.record_rejected();
        m.record_request(1.0, true);
        let j = m.snapshot(0);
        assert_eq!(j.get("rejected_total").as_usize(), Some(2));
        assert_eq!(j.get("requests_total").as_usize(), Some(1));
        assert_eq!(j.get("errors_total").as_usize(), Some(0));
    }

    #[test]
    fn window_is_bounded() {
        let m = ServeMetrics::new();
        for i in 0..(LATENCY_WINDOW + 10) {
            m.record_request(i as f64, true);
        }
        let inner = m.inner.lock().unwrap();
        assert_eq!(inner.lat_window.len(), LATENCY_WINDOW);
        assert_eq!(inner.lat_all.count() as usize, LATENCY_WINDOW + 10);
    }
}
