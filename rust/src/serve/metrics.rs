//! Serving metrics: request latency distribution, served-batch-size
//! histogram, throughput and error counters — per model *and* in
//! aggregate — plus admission queue wait and batch-assembly timing.
//! Backs both `GET /metrics` bodies: the JSON snapshot and the
//! Prometheus text exposition (`?format=prometheus`).
//!
//! Percentiles are computed over a sliding window of recent requests
//! (bounded memory under sustained traffic); totals are exact counters.
//!
//! Lock discipline: every reader copies the inner state out under the
//! mutex and formats *after* release, so a slow `/metrics` scrape never
//! stalls the workers recording latencies.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::substrate::json::Json;
use crate::substrate::stats::{percentiles, Moments};

/// Latencies retained for percentile estimation (per model, and again
/// for the aggregate view).
const LATENCY_WINDOW: usize = 8192;

/// One model's (or the aggregate's) counters and latency window.
#[derive(Clone, Default)]
struct ModelStats {
    /// Sliding window of per-request latencies (ms), newest at the back.
    lat_window: VecDeque<f64>,
    /// Exact running moments over *all* request latencies.
    lat_all: Moments,
    /// Served (per-model forward) batch size → count.
    batch_hist: BTreeMap<usize, u64>,
    batches: u64,
    examples: u64,
    ok: u64,
    errors: u64,
}

impl ModelStats {
    fn record_batch(&mut self, n: usize) {
        *self.batch_hist.entry(n).or_insert(0) += 1;
        self.batches += 1;
        self.examples += n as u64;
    }

    fn record_request(&mut self, latency_ms: f64, ok: bool) {
        if self.lat_window.len() == LATENCY_WINDOW {
            self.lat_window.pop_front();
        }
        self.lat_window.push_back(latency_ms);
        self.lat_all.push(latency_ms);
        if ok {
            self.ok += 1;
        } else {
            self.errors += 1;
        }
    }

    fn total(&self) -> u64 {
        self.ok + self.errors
    }

    fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.examples as f64 / self.batches as f64
        }
    }

    /// (p50, p95, p99) over the sliding window.
    fn lat_percentiles(&self) -> (f64, f64, f64) {
        if self.lat_window.is_empty() {
            return (0.0, 0.0, 0.0);
        }
        let window: Vec<f64> = self.lat_window.iter().copied().collect();
        let p = percentiles(&window, &[50.0, 95.0, 99.0]);
        (p[0], p[1], p[2])
    }

    fn latency_json(&self) -> Json {
        let (p50, p95, p99) = self.lat_percentiles();
        let empty = self.lat_all.count() == 0;
        Json::obj(vec![
            ("count", Json::num(self.lat_all.count() as f64)),
            ("mean", Json::num(if empty { 0.0 } else { self.lat_all.mean() })),
            ("max", Json::num(if empty { 0.0 } else { self.lat_all.max() })),
            ("p50", Json::num(p50)),
            ("p95", Json::num(p95)),
            ("p99", Json::num(p99)),
        ])
    }
}

#[derive(Clone, Default)]
struct Inner {
    /// Aggregate across every model (the pre-existing `/metrics` keys).
    global: ModelStats,
    /// Per-model breakdown, keyed by registry name.
    per_model: BTreeMap<String, ModelStats>,
    /// Requests refused at the HTTP layer (bad body, unknown model,
    /// load-shed 503) — they never reached a worker, so they are counted
    /// separately from served-request errors.
    rejected: u64,
    /// Overload/lifecycle subset of `rejected`: queue-full and draining
    /// 503s (explicit load shedding, DESIGN.md §12).
    shed: u64,
    /// Requests shed worker-side because their deadline expired while
    /// queued.
    expired: u64,
    /// Batch forwards that panicked (contained by `catch_unwind`).
    worker_panics: u64,
    /// Dead workers respawned by the supervisor.
    worker_restarts: u64,
    /// Admission → dequeue wait per request (ms).
    queue_wait_ms: Moments,
    /// Time `pop_batch` spent coalescing after its first item (ms).
    assembly_ms: Moments,
}

/// Shared, thread-safe serving metrics.
pub struct ServeMetrics {
    start: Instant,
    inner: Mutex<Inner>,
    /// Connection-level counters live outside the mutex: the event loop
    /// bumps them on its hot path (accept, suspend/resume, keep-alive
    /// reuse), where a contended lock would serialize all connections.
    conn_open: AtomicU64,
    conn_total: AtomicU64,
    conn_suspended: AtomicU64,
    keepalive_requests: AtomicU64,
}

impl ServeMetrics {
    /// Fresh metrics; uptime starts now.
    pub fn new() -> Self {
        ServeMetrics {
            start: Instant::now(),
            inner: Mutex::new(Inner::default()),
            conn_open: AtomicU64::new(0),
            conn_total: AtomicU64::new(0),
            conn_suspended: AtomicU64::new(0),
            keepalive_requests: AtomicU64::new(0),
        }
    }

    // ---- connection-level accounting (event-loop front-end) ----------------

    /// A connection was accepted.
    pub fn conn_opened(&self) {
        self.conn_open.fetch_add(1, Ordering::Relaxed);
        self.conn_total.fetch_add(1, Ordering::Relaxed);
    }

    /// A connection was closed (any reason).
    pub fn conn_closed(&self) {
        self.conn_open.fetch_sub(1, Ordering::Relaxed);
    }

    /// A connection stopped being read (backpressure / pipeline cap).
    pub fn conn_suspended(&self) {
        self.conn_suspended.fetch_add(1, Ordering::Relaxed);
    }

    /// A suspended connection resumed reading.
    pub fn conn_resumed(&self) {
        self.conn_suspended.fetch_sub(1, Ordering::Relaxed);
    }

    /// A request was served on an already-used connection (keep-alive or
    /// pipelining reuse — request ≥ 2 on its connection).
    pub fn record_keepalive_reuse(&self) {
        self.keepalive_requests.fetch_add(1, Ordering::Relaxed);
    }

    /// Currently open connections.
    pub fn connections_open(&self) -> u64 {
        self.conn_open.load(Ordering::Relaxed)
    }

    /// Connections accepted since start.
    pub fn connections_total(&self) -> u64 {
        self.conn_total.load(Ordering::Relaxed)
    }

    /// Connections currently suspended for backpressure.
    pub fn suspended_connections(&self) -> u64 {
        self.conn_suspended.load(Ordering::Relaxed)
    }

    /// Requests served beyond the first on their connection.
    pub fn keepalive_requests_total(&self) -> u64 {
        self.keepalive_requests.load(Ordering::Relaxed)
    }

    /// One forward pass on `model` served `n` coalesced requests.
    pub fn record_batch(&self, model: &str, n: usize) {
        let mut m = self.inner.lock().unwrap();
        m.global.record_batch(n);
        m.per_model.entry(model.to_string()).or_default().record_batch(n);
    }

    /// One request to `model` completed (admission → response) in
    /// `latency_ms`.
    pub fn record_request(&self, model: &str, latency_ms: f64, ok: bool) {
        let mut m = self.inner.lock().unwrap();
        m.global.record_request(latency_ms, ok);
        m.per_model.entry(model.to_string()).or_default().record_request(latency_ms, ok);
    }

    /// One request refused before admission (4xx/503 at the HTTP layer).
    pub fn record_rejected(&self) {
        self.inner.lock().unwrap().rejected += 1;
    }

    /// One request load-shed (queue full / draining). Callers also
    /// record a rejection — shed is the overload-attributable subset.
    pub fn record_shed(&self) {
        self.inner.lock().unwrap().shed += 1;
    }

    /// One queued request expired past its deadline and was shed by a
    /// worker before batch assembly.
    pub fn record_expired(&self) {
        self.inner.lock().unwrap().expired += 1;
    }

    /// One batch forward panicked (contained; its requests got 500s).
    pub fn record_worker_panic(&self) {
        self.inner.lock().unwrap().worker_panics += 1;
    }

    /// The supervisor respawned a dead worker.
    pub fn record_worker_restart(&self) {
        self.inner.lock().unwrap().worker_restarts += 1;
    }

    /// One request waited `ms` between admission and worker dequeue.
    pub fn record_queue_wait(&self, ms: f64) {
        self.inner.lock().unwrap().queue_wait_ms.push(ms);
    }

    /// One `pop_batch` spent `ms` coalescing after its first item.
    pub fn record_batch_assembly(&self, ms: f64) {
        self.inner.lock().unwrap().assembly_ms.push(ms);
    }

    /// Completed requests (ok + errors), across all models.
    pub fn requests_total(&self) -> u64 {
        self.inner.lock().unwrap().global.total()
    }

    /// Examples served per forward pass, averaged — the coalescing factor.
    pub fn mean_batch_size(&self) -> f64 {
        self.inner.lock().unwrap().global.mean_batch()
    }

    /// Mean request latency over all completed requests (ms); 0 when
    /// nothing has been served. Feeds the `Retry-After` hint.
    pub fn mean_latency_ms(&self) -> f64 {
        let m = self.inner.lock().unwrap();
        if m.global.lat_all.count() == 0 {
            0.0
        } else {
            m.global.lat_all.mean()
        }
    }

    /// Copy the inner state out under the lock (cheap: counters, bounded
    /// windows) so formatting happens lock-free.
    fn copy_inner(&self) -> Inner {
        self.inner.lock().unwrap().clone()
    }

    /// Full snapshot as JSON (the `GET /metrics` body). `queue_depth` is
    /// sampled by the caller from the admission queue.
    pub fn snapshot(&self, queue_depth: usize) -> Json {
        let m = self.copy_inner(); // lock released here; format below
        let uptime_s = self.start.elapsed().as_secs_f64();
        let total = m.global.total();
        let moments_json = |w: &Moments| {
            let empty = w.count() == 0;
            Json::obj(vec![
                ("count", Json::num(w.count() as f64)),
                ("mean", Json::num(if empty { 0.0 } else { w.mean() })),
                ("max", Json::num(if empty { 0.0 } else { w.max() })),
            ])
        };
        Json::obj(vec![
            ("uptime_s", Json::num(uptime_s)),
            ("requests_total", Json::num(total as f64)),
            ("errors_total", Json::num(m.global.errors as f64)),
            ("rejected_total", Json::num(m.rejected as f64)),
            ("shed_total", Json::num(m.shed as f64)),
            ("deadline_expired_total", Json::num(m.expired as f64)),
            ("worker_panics_total", Json::num(m.worker_panics as f64)),
            ("worker_restarts_total", Json::num(m.worker_restarts as f64)),
            ("examples_total", Json::num(m.global.examples as f64)),
            ("batches_total", Json::num(m.global.batches as f64)),
            ("mean_batch_size", Json::num(m.global.mean_batch())),
            ("batch_size_hist",
             Json::arr(m.global.batch_hist.iter().map(|(&size, &count)| {
                 Json::obj(vec![
                     ("batch", Json::num(size as f64)),
                     ("count", Json::num(count as f64)),
                 ])
             }))),
            ("queue_depth", Json::num(queue_depth as f64)),
            ("connections_open", Json::num(self.connections_open() as f64)),
            ("connections_total", Json::num(self.connections_total() as f64)),
            ("suspended_connections", Json::num(self.suspended_connections() as f64)),
            ("keepalive_requests_total", Json::num(self.keepalive_requests_total() as f64)),
            ("queue_wait_ms", moments_json(&m.queue_wait_ms)),
            ("batch_assembly_ms", moments_json(&m.assembly_ms)),
            ("latency_ms", m.global.latency_json()),
            ("models", {
                let mut o = Json::obj(vec![]);
                for (name, s) in &m.per_model {
                    o.set(
                        name,
                        Json::obj(vec![
                            ("requests_total", Json::num(s.total() as f64)),
                            ("errors_total", Json::num(s.errors as f64)),
                            ("examples_total", Json::num(s.examples as f64)),
                            ("batches_total", Json::num(s.batches as f64)),
                            ("mean_batch_size", Json::num(s.mean_batch())),
                            ("latency_ms", s.latency_json()),
                        ]),
                    );
                }
                o
            }),
            ("throughput_rps",
             Json::num(if uptime_s > 0.0 { total as f64 / uptime_s } else { 0.0 })),
        ])
    }

    /// Prometheus text exposition (the `GET /metrics?format=prometheus`
    /// body, minus the pool/kernel lines `serve::http` appends). Names
    /// and label schema are part of the public contract pinned by
    /// `tests/observe.rs`.
    pub fn prometheus(&self, queue_depth: usize) -> String {
        let m = self.copy_inner(); // lock released here; format below
        let uptime_s = self.start.elapsed().as_secs_f64();
        let mut p = Prom::default();

        p.header("flexor_uptime_seconds", "Server uptime.", "gauge");
        p.line("flexor_uptime_seconds", &[], uptime_s);
        p.header("flexor_requests_total", "Completed requests (ok + errors).", "counter");
        p.line("flexor_requests_total", &[], m.global.total() as f64);
        p.header("flexor_errors_total", "Requests that failed in a worker.", "counter");
        p.line("flexor_errors_total", &[], m.global.errors as f64);
        p.header("flexor_rejected_total", "Requests refused before admission.", "counter");
        p.line("flexor_rejected_total", &[], m.rejected as f64);
        p.header("flexor_shed_total", "Requests load-shed (queue full / draining).", "counter");
        p.line("flexor_shed_total", &[], m.shed as f64);
        p.header(
            "flexor_deadline_expired_total",
            "Requests shed after their deadline expired in the queue.",
            "counter",
        );
        p.line("flexor_deadline_expired_total", &[], m.expired as f64);
        p.header("flexor_worker_panics_total", "Batch forwards that panicked.", "counter");
        p.line("flexor_worker_panics_total", &[], m.worker_panics as f64);
        p.header("flexor_worker_restarts_total", "Workers respawned by the supervisor.", "counter");
        p.line("flexor_worker_restarts_total", &[], m.worker_restarts as f64);
        p.header("flexor_examples_total", "Examples served across batches.", "counter");
        p.line("flexor_examples_total", &[], m.global.examples as f64);
        p.header("flexor_batches_total", "Forward passes run.", "counter");
        p.line("flexor_batches_total", &[], m.global.batches as f64);
        p.header("flexor_mean_batch_size", "Examples per forward pass.", "gauge");
        p.line("flexor_mean_batch_size", &[], m.global.mean_batch());
        p.header("flexor_queue_depth", "Admission queue depth at scrape time.", "gauge");
        p.line("flexor_queue_depth", &[], queue_depth as f64);
        p.header("flexor_http_connections_open", "Open HTTP connections.", "gauge");
        p.line("flexor_http_connections_open", &[], self.connections_open() as f64);
        p.header("flexor_http_connections_total", "HTTP connections accepted.", "counter");
        p.line("flexor_http_connections_total", &[], self.connections_total() as f64);
        p.header(
            "flexor_http_suspended_connections",
            "Connections paused by backpressure (queue full / pipeline cap).",
            "gauge",
        );
        p.line("flexor_http_suspended_connections", &[], self.suspended_connections() as f64);
        p.header(
            "flexor_http_keepalive_requests_total",
            "Requests served beyond the first on their connection.",
            "counter",
        );
        p.line(
            "flexor_http_keepalive_requests_total",
            &[],
            self.keepalive_requests_total() as f64,
        );

        p.header("flexor_request_latency_ms", "Request latency (window percentiles).", "summary");
        p.summary("flexor_request_latency_ms", &[], &m.global);

        p.header("flexor_queue_wait_ms", "Admission → dequeue wait.", "summary");
        p.moments("flexor_queue_wait_ms", &[], &m.queue_wait_ms);
        p.header("flexor_batch_assembly_ms", "Coalescing time after first item.", "summary");
        p.moments("flexor_batch_assembly_ms", &[], &m.assembly_ms);

        p.header("flexor_model_requests_total", "Completed requests per model.", "counter");
        for (name, s) in &m.per_model {
            p.line("flexor_model_requests_total", &[("model", name.as_str())], s.total() as f64);
        }
        p.header("flexor_model_errors_total", "Failed requests per model.", "counter");
        for (name, s) in &m.per_model {
            p.line("flexor_model_errors_total", &[("model", name.as_str())], s.errors as f64);
        }
        p.header("flexor_model_examples_total", "Examples served per model.", "counter");
        for (name, s) in &m.per_model {
            p.line("flexor_model_examples_total", &[("model", name.as_str())], s.examples as f64);
        }
        p.header("flexor_model_batches_total", "Forward passes per model.", "counter");
        for (name, s) in &m.per_model {
            p.line("flexor_model_batches_total", &[("model", name.as_str())], s.batches as f64);
        }
        p.header("flexor_model_latency_ms", "Request latency per model.", "summary");
        for (name, s) in &m.per_model {
            p.summary("flexor_model_latency_ms", &[("model", name.as_str())], s);
        }
        p.out
    }
}

impl Default for ServeMetrics {
    fn default() -> Self {
        Self::new()
    }
}

/// Tiny Prometheus text-format builder (exposition format 0.0.4).
#[derive(Default)]
struct Prom {
    out: String,
}

impl Prom {
    fn header(&mut self, name: &str, help: &str, typ: &str) {
        self.out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {typ}\n"));
    }

    fn line(&mut self, name: &str, labels: &[(&str, &str)], v: f64) {
        self.out.push_str(name);
        if !labels.is_empty() {
            self.out.push('{');
            for (i, (k, val)) in labels.iter().enumerate() {
                if i > 0 {
                    self.out.push(',');
                }
                self.out.push_str(&format!("{k}=\"{}\"", escape_label(val)));
            }
            self.out.push('}');
        }
        self.out.push_str(&format!(" {v}\n"));
    }

    /// Quantiles + `_sum`/`_count` rows for one latency distribution.
    fn summary(&mut self, name: &str, labels: &[(&str, &str)], s: &ModelStats) {
        let (p50, p95, p99) = s.lat_percentiles();
        for (q, v) in [("0.5", p50), ("0.95", p95), ("0.99", p99)] {
            let mut with_q: Vec<(&str, &str)> = labels.to_vec();
            with_q.push(("quantile", q));
            self.line(name, &with_q, v);
        }
        let sum = if s.lat_all.count() == 0 { 0.0 } else { s.lat_all.mean() * s.lat_all.count() as f64 };
        self.line(&format!("{name}_sum"), labels, sum);
        self.line(&format!("{name}_count"), labels, s.lat_all.count() as f64);
    }

    /// `_sum`/`_count` rows for a plain [`Moments`] accumulator.
    fn moments(&mut self, name: &str, labels: &[(&str, &str)], w: &Moments) {
        let sum = if w.count() == 0 { 0.0 } else { w.mean() * w.count() as f64 };
        self.line(&format!("{name}_sum"), labels, sum);
        self.line(&format!("{name}_count"), labels, w.count() as f64);
    }
}

/// Escape a label value per the exposition format: backslash, quote,
/// newline.
fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn batch_accounting() {
        let m = ServeMetrics::new();
        m.record_batch("a", 1);
        m.record_batch("a", 4);
        m.record_batch("a", 4);
        assert!((m.mean_batch_size() - 3.0).abs() < 1e-12);
        let j = m.snapshot(2);
        assert_eq!(j.get("batches_total").as_usize(), Some(3));
        assert_eq!(j.get("examples_total").as_usize(), Some(9));
        assert_eq!(j.get("queue_depth").as_usize(), Some(2));
        // histogram: batch size 4 seen twice
        let hist = j.get("batch_size_hist");
        assert_eq!(hist.at(1).get("batch").as_usize(), Some(4));
        assert_eq!(hist.at(1).get("count").as_usize(), Some(2));
    }

    #[test]
    fn request_latency_percentiles() {
        let m = ServeMetrics::new();
        for i in 1..=100 {
            m.record_request("a", i as f64, i != 13);
        }
        let j = m.snapshot(0);
        assert_eq!(j.get("requests_total").as_usize(), Some(100));
        assert_eq!(j.get("errors_total").as_usize(), Some(1));
        let lat = j.get("latency_ms");
        assert_eq!(lat.get("count").as_usize(), Some(100));
        let p50 = lat.get("p50").as_f64().unwrap();
        assert!((p50 - 50.5).abs() < 1.0, "p50 {p50}");
        assert!(lat.get("p99").as_f64().unwrap() >= p50);
        assert_eq!(lat.get("max").as_f64(), Some(100.0));
    }

    #[test]
    fn empty_snapshot_is_well_formed() {
        let j = ServeMetrics::new().snapshot(0);
        assert_eq!(j.get("requests_total").as_usize(), Some(0));
        assert_eq!(j.get("rejected_total").as_usize(), Some(0));
        assert_eq!(j.get("mean_batch_size").as_f64(), Some(0.0));
        assert_eq!(j.get("latency_ms").get("p99").as_f64(), Some(0.0));
        assert_eq!(j.get("queue_wait_ms").get("count").as_usize(), Some(0));
        assert!(j.get("models").as_obj().unwrap().is_empty());
    }

    #[test]
    fn rejections_counted_separately() {
        let m = ServeMetrics::new();
        m.record_rejected();
        m.record_rejected();
        m.record_request("a", 1.0, true);
        let j = m.snapshot(0);
        assert_eq!(j.get("rejected_total").as_usize(), Some(2));
        assert_eq!(j.get("requests_total").as_usize(), Some(1));
        assert_eq!(j.get("errors_total").as_usize(), Some(0));
    }

    #[test]
    fn fault_counters_land_in_both_expositions() {
        let m = ServeMetrics::new();
        m.record_rejected();
        m.record_shed();
        m.record_expired();
        m.record_expired();
        m.record_worker_panic();
        m.record_worker_restart();
        let j = m.snapshot(0);
        assert_eq!(j.get("shed_total").as_usize(), Some(1));
        assert_eq!(j.get("deadline_expired_total").as_usize(), Some(2));
        assert_eq!(j.get("worker_panics_total").as_usize(), Some(1));
        assert_eq!(j.get("worker_restarts_total").as_usize(), Some(1));
        // expired/shed requests never complete, so they are not requests
        assert_eq!(j.get("requests_total").as_usize(), Some(0));
        let text = m.prometheus(0);
        for line in [
            "flexor_shed_total 1",
            "flexor_deadline_expired_total 2",
            "flexor_worker_panics_total 1",
            "flexor_worker_restarts_total 1",
        ] {
            assert!(text.contains(line), "missing {line:?} in:\n{text}");
        }
    }

    #[test]
    fn connection_counters_land_in_both_expositions() {
        let m = ServeMetrics::new();
        m.conn_opened();
        m.conn_opened();
        m.conn_closed();
        m.conn_suspended();
        m.record_keepalive_reuse();
        m.record_keepalive_reuse();
        m.record_keepalive_reuse();
        let j = m.snapshot(0);
        assert_eq!(j.get("connections_open").as_usize(), Some(1));
        assert_eq!(j.get("connections_total").as_usize(), Some(2));
        assert_eq!(j.get("suspended_connections").as_usize(), Some(1));
        assert_eq!(j.get("keepalive_requests_total").as_usize(), Some(3));
        m.conn_resumed();
        assert_eq!(m.suspended_connections(), 0);
        let text = m.prometheus(0);
        for line in [
            "flexor_http_connections_open 1",
            "flexor_http_connections_total 2",
            "flexor_http_suspended_connections 0",
            "flexor_http_keepalive_requests_total 3",
        ] {
            assert!(text.contains(line), "missing {line:?} in:\n{text}");
        }
    }

    #[test]
    fn mean_latency_feeds_retry_hint() {
        let m = ServeMetrics::new();
        assert_eq!(m.mean_latency_ms(), 0.0);
        m.record_request("a", 2.0, true);
        m.record_request("a", 4.0, true);
        assert!((m.mean_latency_ms() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn window_is_bounded() {
        let m = ServeMetrics::new();
        for i in 0..(LATENCY_WINDOW + 10) {
            m.record_request("a", i as f64, true);
        }
        let inner = m.inner.lock().unwrap();
        assert_eq!(inner.global.lat_window.len(), LATENCY_WINDOW);
        assert_eq!(inner.global.lat_all.count() as usize, LATENCY_WINDOW + 10);
        assert_eq!(inner.per_model["a"].lat_window.len(), LATENCY_WINDOW);
    }

    #[test]
    fn per_model_stats_are_disjoint() {
        let m = ServeMetrics::new();
        m.record_batch("a", 2);
        m.record_request("a", 1.0, true);
        m.record_request("a", 2.0, true);
        m.record_batch("b", 1);
        m.record_request("b", 5.0, false);
        let j = m.snapshot(0);
        assert_eq!(j.get("requests_total").as_usize(), Some(3));
        let a = j.get("models").get("a");
        let b = j.get("models").get("b");
        assert_eq!(a.get("requests_total").as_usize(), Some(2));
        assert_eq!(a.get("errors_total").as_usize(), Some(0));
        assert_eq!(b.get("requests_total").as_usize(), Some(1));
        assert_eq!(b.get("errors_total").as_usize(), Some(1));
        assert_eq!(a.get("examples_total").as_usize(), Some(2));
        assert_eq!(b.get("examples_total").as_usize(), Some(1));
    }

    #[test]
    fn queue_wait_and_assembly_land_in_snapshot() {
        let m = ServeMetrics::new();
        m.record_queue_wait(2.0);
        m.record_queue_wait(4.0);
        m.record_batch_assembly(1.0);
        let j = m.snapshot(0);
        assert_eq!(j.get("queue_wait_ms").get("count").as_usize(), Some(2));
        assert!((j.get("queue_wait_ms").get("mean").as_f64().unwrap() - 3.0).abs() < 1e-12);
        assert_eq!(j.get("batch_assembly_ms").get("count").as_usize(), Some(1));
    }

    #[test]
    fn prometheus_exposition_has_stable_names() {
        let m = ServeMetrics::new();
        m.record_batch("mod\"el", 2);
        m.record_request("mod\"el", 1.5, true);
        m.record_rejected();
        let text = m.prometheus(3);
        for name in [
            "flexor_uptime_seconds",
            "flexor_requests_total",
            "flexor_rejected_total",
            "flexor_queue_depth 3",
            "flexor_request_latency_ms{quantile=\"0.5\"}",
            "flexor_request_latency_ms_count 1",
            "flexor_model_requests_total{model=\"mod\\\"el\"} 1",
            "flexor_model_latency_ms{model=\"mod\\\"el\",quantile=\"0.99\"}",
        ] {
            assert!(text.contains(name), "missing {name:?} in:\n{text}");
        }
        // every HELP has a TYPE
        let helps = text.matches("# HELP").count();
        let types = text.matches("# TYPE").count();
        assert_eq!(helps, types);
    }

    /// Satellite: snapshot no longer formats under the metrics mutex —
    /// hammer records from several threads while snapshotting and check
    /// nothing deadlocks and the final totals are exact.
    #[test]
    fn snapshot_under_contention_is_consistent() {
        let m = Arc::new(ServeMetrics::new());
        const THREADS: usize = 4;
        const PER_THREAD: usize = 5_000;
        let recorders: Vec<_> = (0..THREADS)
            .map(|t| {
                let m = m.clone();
                std::thread::spawn(move || {
                    let model = format!("m{t}");
                    for i in 0..PER_THREAD {
                        m.record_request(&model, i as f64 % 7.0, true);
                        if i % 8 == 0 {
                            m.record_batch(&model, 8);
                        }
                    }
                })
            })
            .collect();
        // concurrent scrapes: every intermediate snapshot must be
        // internally consistent (requests == sum of per-model requests)
        for _ in 0..50 {
            let j = m.snapshot(0);
            let total = j.get("requests_total").as_usize().unwrap();
            let sum: usize = j
                .get("models")
                .as_obj()
                .unwrap()
                .values()
                .map(|v| v.get("requests_total").as_usize().unwrap())
                .sum();
            assert_eq!(total, sum, "global and per-model counters diverged");
            let _ = m.prometheus(0);
        }
        for r in recorders {
            r.join().unwrap();
        }
        let j = m.snapshot(0);
        assert_eq!(j.get("requests_total").as_usize(), Some(THREADS * PER_THREAD));
        for t in 0..THREADS {
            let s = j.get("models").get(&format!("m{t}"));
            assert_eq!(s.get("requests_total").as_usize(), Some(PER_THREAD));
        }
    }
}
