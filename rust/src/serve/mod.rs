//! Model-serving subsystem: the paper's deployment story under traffic.
//!
//! FleXOR's pitch (Fig. 1–3, Algorithm 1) is that encrypted binary codes
//! are cheap to serve: decrypt once at load through the XOR engine, then
//! every request is binary-code arithmetic. This module turns the
//! single-threaded `examples/serve.rs` loop into an actual server:
//!
//! ```text
//!  POST /predict ──► http  ──► queue ───────► worker pool ──► forward
//!  GET  /models        │     (bounded MPSC,   (decrypt-once   (batched,
//!  GET  /metrics       │      micro-batch      shared model)   grouped
//!                   registry   coalescing)          │          by model)
//!                      ▲                            └──► per-request
//!                      └── .fxr bundles                   response channels
//! ```
//!
//! * [`registry`] — named `.fxr` bundle hosting, decrypt-once-at-load,
//!   per-model compute mode (DenseF32 packed-FP engine or BitPlane
//!   XNOR/popcount engine — DESIGN.md §8), per-model storage stats and
//!   resident-bytes accounting, `unload` to release memory. Names are
//!   versioned aliases (`resnet20@v2`; the bare alias resolves the
//!   serving version), swapped atomically by the control plane
//!   (drain-then-swap on `Arc`s, DESIGN.md §13) with lazy
//!   load-on-first-request and LRU eviction under a
//!   `FLEXOR_MAX_RESIDENT_BYTES` budget;
//! * [`queue`]    — bounded admission + micro-batch coalescing
//!   (`max_batch` / `max_wait_us`) on `std::sync::{Mutex, Condvar}`;
//! * [`worker`]   — thread pool draining the queue, one forward pass per
//!   coalesced per-model group, results fanned back over one-shot
//!   channels;
//! * [`metrics`]  — latency percentiles (global + per model), batch-size
//!   histogram, queue depth/wait and batch-assembly timing, JSON and
//!   Prometheus text exposition;
//! * [`http`]     — HTTP/1.1 front-end (`/predict`, `GET|POST /models`,
//!   `DELETE /models/<name>`, `/metrics` — `?format=prometheus` for the
//!   text exposition, `/models/<name>/profile`, `/healthz` liveness,
//!   `/readyz` readiness), `X-Request-Id` generation/echo, structured
//!   request logging, plus a one-shot client for tests/benches. Two
//!   front-end modes (DESIGN.md §14): the default nonblocking readiness
//!   loop (epoll/poll via `substrate::net`, keep-alive + pipelining,
//!   incremental framing, idle/header timeouts, suspension-based
//!   backpressure, streaming zero-allocation `/predict` parsing) and the
//!   thread-per-connection fallback (`FLEXOR_HTTP_MODE=threads`), kept
//!   as the behavioral oracle;
//! * [`error`]    — the stable error-code vocabulary every non-2xx body
//!   carries (`code` field), shared between workers and the HTTP layer.
//!
//! Fault tolerance (DESIGN.md §12): per-request deadlines
//! (`X-Deadline-Ms` / `FLEXOR_DEADLINE_MS`) shed expired requests
//! before batch assembly; bounded admission degrades to `503` +
//! `Retry-After`; batch forwards run under `catch_unwind` with a
//! supervisor respawning dead workers; `substrate::fault` injects
//! faults for the chaos harness (`rust/tests/chaos.rs`).
//!
//! Forward passes inside the workers run on the packed parallel compute
//! engine (`inference::gemm`, DESIGN.md §7); `ServeConfig::intra_threads`
//! sizes that intra-op pool so per-request parallelism composes with the
//! worker pool (`workers × intra_threads ≈ cores`) instead of
//! oversubscribing the machine.
//!
//! Everything is dependency-free `std` (DESIGN.md §5/§6).

pub mod error;
pub mod http;
pub mod metrics;
pub mod queue;
pub mod registry;
pub mod worker;

pub use error::{ErrorCode, ServeError};
pub use http::{Frame, FrameError, FrameParser, HttpMode, PredictVisitor, ServeConfig, Server};
pub use metrics::ServeMetrics;
pub use queue::{BatchQueue, PushError};
pub use registry::{ControlError, ModelEntry, Registry, SwapReport};
pub use worker::{Prediction, Request, Responder, Response, WorkerPool};
