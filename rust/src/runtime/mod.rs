//! PJRT runtime: loads the HLO-text artifacts emitted by `python/compile/aot.py`
//! and executes them on the CPU PJRT client. Python never runs here.
//!
//! * [`manifest`] — artifact index + per-config `meta.json` (leaf layout,
//!   calling convention, M⊕ matrices, storage accounting);
//! * [`initbin`]  — the `init.bin` initial-state parser (FXIN format);
//! * [`client`]   — `PjRtClient` wrapper: HLO text → compiled executable,
//!   literal marshalling helpers, executable cache.

pub mod client;
pub mod initbin;
pub mod manifest;

pub use client::{Executable, Runtime};
pub use initbin::read_init_bin;
pub use manifest::{ConfigMeta, Manifest};
