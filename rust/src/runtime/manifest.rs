//! Artifact manifest + per-config metadata (the contract with aot.py).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, ensure, Context, Result};

use crate::flexor::MXor;
use crate::substrate::json::{self, Json};

/// One leaf of the flattened (params, opt, bn) state.
#[derive(Clone, Debug)]
pub struct LeafMeta {
    pub role: String, // "params" | "opt" | "bn"
    pub path: String, // jax keystr, e.g. "['convs'][0]['w_enc']"
    pub shape: Vec<usize>,
    pub dtype: String, // "float32" | "int32"
}

impl LeafMeta {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    /// Index into `path` brackets: returns the integer inside the first
    /// `[<n>]` after `needle`, e.g. layer index of `['convs'][3]['w_enc']`.
    pub fn index_after(&self, needle: &str) -> Option<usize> {
        let pos = self.path.find(needle)? + needle.len();
        let rest = &self.path[pos..];
        let open = rest.find('[')?;
        let close = rest[open..].find(']')? + open;
        rest[open + 1..close].parse().ok()
    }
}

/// One FleXOR spec (mirrors python's FlexorSpec serialization).
#[derive(Clone, Debug)]
pub struct SpecMeta {
    pub q: usize,
    pub n_in: usize,
    pub n_out: usize,
    pub bits_per_weight: f64,
    pub mxor: Vec<MXor>, // one per bit-plane
}

impl SpecMeta {
    fn from_json(v: &Json) -> Result<Self> {
        let mxor = v
            .get("mxor")
            .as_arr()
            .context("spec mxor missing")?
            .iter()
            .map(MXor::from_json)
            .collect::<Result<Vec<_>>>()?;
        ensure!(!mxor.is_empty(), "spec with no M⊕ planes");
        Ok(SpecMeta {
            q: v.get("q").as_usize().context("spec q")?,
            n_in: v.get("n_in").as_usize().context("spec n_in")?,
            n_out: v.get("n_out").as_usize().context("spec n_out")?,
            bits_per_weight: v.get("bits_per_weight").as_f64().unwrap_or(0.0),
            mxor,
        })
    }
}

/// Per-quantized-layer storage row (Table 5 bookkeeping).
#[derive(Clone, Debug)]
pub struct LayerStorage {
    pub idx: usize,
    pub shape: Vec<usize>,
    pub weights: usize,
    pub stored_bits: usize,
}

/// Parsed `meta.json` for one lowered config.
#[derive(Clone, Debug)]
pub struct ConfigMeta {
    pub name: String,
    pub dir: PathBuf,
    pub model: String,
    pub quantizer_kind: String,
    pub batch: usize,
    pub input_shape: Vec<usize>,
    pub num_classes: usize,
    pub optimizer: String,
    pub leaves: Vec<LeafMeta>,
    pub n_params: usize,
    pub n_opt: usize,
    pub n_bn: usize,
    pub train_scalar_order: Vec<String>,
    pub eval_scalar_order: Vec<String>,
    pub storage_layers: Vec<LayerStorage>,
    pub bits_per_weight: f64,
    pub flexor_default: Option<SpecMeta>,
    pub flexor_per_layer: BTreeMap<usize, SpecMeta>,
    pub raw: Json,
}

impl ConfigMeta {
    pub fn load(dir: &Path) -> Result<Self> {
        let meta_path = dir.join("meta.json");
        let text = std::fs::read_to_string(&meta_path)
            .with_context(|| format!("reading {}", meta_path.display()))?;
        let v = json::parse(&text).context("parsing meta.json")?;
        Self::from_json(dir, &v)
    }

    pub fn from_json(dir: &Path, v: &Json) -> Result<Self> {
        let cfg = v.get("config");
        let counts = v.get("counts");
        let leaves = v
            .get("leaves")
            .as_arr()
            .context("meta leaves missing")?
            .iter()
            .map(|l| {
                Ok(LeafMeta {
                    role: l.get("role").as_str().context("leaf role")?.to_string(),
                    path: l.get("path").as_str().context("leaf path")?.to_string(),
                    shape: l
                        .get("shape")
                        .as_arr()
                        .context("leaf shape")?
                        .iter()
                        .map(|d| d.as_usize().context("dim"))
                        .collect::<Result<Vec<_>>>()?,
                    dtype: l.get("dtype").as_str().unwrap_or("float32").to_string(),
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let storage = v.get("storage");
        let storage_layers = storage
            .get("layers")
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .map(|l| {
                Ok(LayerStorage {
                    idx: l.get("idx").as_usize().context("layer idx")?,
                    shape: l
                        .get("shape")
                        .as_arr()
                        .context("layer shape")?
                        .iter()
                        .map(|d| d.as_usize().context("dim"))
                        .collect::<Result<Vec<_>>>()?,
                    weights: l.get("weights").as_usize().context("weights")?,
                    stored_bits: l.get("stored_bits").as_usize().context("bits")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let fx = v.get("flexor");
        let flexor_default = if fx.is_null() {
            None
        } else {
            Some(SpecMeta::from_json(fx.get("default"))?)
        };
        let mut flexor_per_layer = BTreeMap::new();
        if let Some(per) = fx.get("per_layer").as_obj() {
            for (k, spec) in per {
                let idx: usize = k.parse().context("per_layer key")?;
                flexor_per_layer.insert(idx, SpecMeta::from_json(spec)?);
            }
        }

        let scalar_vec = |io: &Json| -> Vec<String> {
            io.get("scalar_order")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|s| s.as_str().map(str::to_string))
                .collect()
        };

        let m = ConfigMeta {
            name: cfg.get("name").as_str().context("config name")?.to_string(),
            dir: dir.to_path_buf(),
            model: cfg.get("model").as_str().context("model")?.to_string(),
            quantizer_kind: cfg
                .get("quantizer")
                .get("kind")
                .as_str()
                .unwrap_or("fp")
                .to_string(),
            batch: v.get("batch").as_usize().context("batch")?,
            input_shape: v
                .get("input")
                .get("shape")
                .as_arr()
                .context("input shape")?
                .iter()
                .map(|d| d.as_usize().context("dim"))
                .collect::<Result<Vec<_>>>()?,
            num_classes: v.get("input").get("classes").as_usize().unwrap_or(10),
            optimizer: cfg.get("optimizer").as_str().unwrap_or("sgd").to_string(),
            leaves,
            n_params: counts.get("params").as_usize().context("counts.params")?,
            n_opt: counts.get("opt").as_usize().context("counts.opt")?,
            n_bn: counts.get("bn").as_usize().context("counts.bn")?,
            train_scalar_order: scalar_vec(v.get("train_io")),
            eval_scalar_order: scalar_vec(v.get("eval_io")),
            storage_layers,
            bits_per_weight: storage.get("bits_per_weight").as_f64().unwrap_or(32.0),
            flexor_default,
            flexor_per_layer,
            raw: v.clone(),
        };
        ensure!(
            m.leaves.len() == m.n_params + m.n_opt + m.n_bn,
            "leaf count {} != counts sum {}",
            m.leaves.len(),
            m.n_params + m.n_opt + m.n_bn
        );
        Ok(m)
    }

    pub fn train_hlo_path(&self) -> PathBuf {
        self.dir.join("train_step.hlo.txt")
    }

    pub fn eval_hlo_path(&self) -> PathBuf {
        self.dir.join("eval_step.hlo.txt")
    }

    pub fn init_bin_path(&self) -> PathBuf {
        self.dir.join("init.bin")
    }

    /// Total state leaves fed back between train steps.
    pub fn n_state(&self) -> usize {
        self.n_params + self.n_opt + self.n_bn
    }

    /// FleXOR spec for a quantized layer index (per-layer override or default).
    pub fn spec_for(&self, layer_idx: usize) -> Option<&SpecMeta> {
        self.flexor_per_layer
            .get(&layer_idx)
            .or(self.flexor_default.as_ref())
    }

    /// Param-leaf indices (into `leaves`) for `w_enc`/`alpha` of each
    /// quantized layer, keyed by layer index. Uses the path structure
    /// `...[<idx>]['w_enc']`.
    pub fn quantized_param_leaves(&self) -> BTreeMap<usize, (usize, usize)> {
        let mut enc: BTreeMap<usize, usize> = BTreeMap::new();
        let mut alpha: BTreeMap<usize, usize> = BTreeMap::new();
        for (i, l) in self.leaves.iter().enumerate() {
            if l.role != "params" {
                continue;
            }
            if l.path.contains("'w_enc'") {
                if let Some(idx) = layer_index(&l.path) {
                    enc.insert(idx, i);
                }
            } else if l.path.contains("'alpha'") {
                if let Some(idx) = layer_index(&l.path) {
                    alpha.insert(idx, i);
                }
            }
        }
        enc.into_iter()
            .filter_map(|(k, e)| alpha.get(&k).map(|&a| (k, (e, a))))
            .collect()
    }
}

/// Extract the layer index from a keystr like `['convs'][3]['w_enc']` or
/// `['layers'][0]['alpha']`: the last bare `[<int>]` before the field name.
fn layer_index(path: &str) -> Option<usize> {
    let mut last: Option<usize> = None;
    let bytes = path.as_bytes();
    let mut i = 0;
    while i < bytes.len() {
        if bytes[i] == b'[' {
            let close = path[i..].find(']')? + i;
            let inner = &path[i + 1..close];
            if let Ok(n) = inner.parse::<usize>() {
                last = Some(n);
            }
            i = close + 1;
        } else {
            i += 1;
        }
    }
    last
}

/// The `artifacts/manifest.json` index.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub root: PathBuf,
    pub configs: BTreeMap<String, String>, // name -> dir
}

impl Manifest {
    pub fn load(root: &Path) -> Result<Self> {
        let path = root.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                path.display()
            )
        })?;
        let v = json::parse(&text)?;
        let mut configs = BTreeMap::new();
        if let Some(obj) = v.get("configs").as_obj() {
            for (name, e) in obj {
                let dir = e.get("dir").as_str().unwrap_or(name).to_string();
                configs.insert(name.clone(), dir);
            }
        }
        Ok(Manifest { root: root.to_path_buf(), configs })
    }

    pub fn config(&self, name: &str) -> Result<ConfigMeta> {
        let Some(dir) = self.configs.get(name) else {
            bail!(
                "config '{name}' not in manifest; available: {:?}\n\
                 (build it with: cd python && python -m compile.aot --out ../artifacts --only {name})",
                self.configs.keys().collect::<Vec<_>>()
            );
        };
        ConfigMeta::load(&self.root.join(dir))
    }

    pub fn names(&self) -> impl Iterator<Item = &String> {
        self.configs.keys()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_index_parses_keystrs() {
        assert_eq!(layer_index("['convs'][3]['w_enc']"), Some(3));
        assert_eq!(layer_index("['layers'][0]['alpha']"), Some(0));
        assert_eq!(layer_index("['head']['w']"), None);
        assert_eq!(layer_index("['bn'][12]['scale']"), Some(12));
    }

    #[test]
    fn leaf_meta_helpers() {
        let l = LeafMeta {
            role: "params".into(),
            path: "['convs'][5]['w_enc']".into(),
            shape: vec![1, 20, 8],
            dtype: "float32".into(),
        };
        assert_eq!(l.element_count(), 160);
        assert_eq!(l.index_after("'convs'"), Some(5));
    }

    #[test]
    fn config_meta_from_minimal_json() {
        let text = r#"{
          "config": {"name": "t", "model": "mlp", "optimizer": "adam",
                     "quantizer": {"kind": "flexor"}},
          "batch": 8,
          "input": {"shape": [8, 16], "classes": 4},
          "counts": {"params": 2, "opt": 1, "bn": 0},
          "train_io": {"scalar_order": ["lr", "s_tanh", "relax_lambda"]},
          "eval_io": {"scalar_order": ["s_tanh", "relax_lambda"]},
          "leaves": [
            {"role": "params", "path": "['layers'][0]['w_enc']", "shape": [1, 26, 4], "dtype": "float32"},
            {"role": "params", "path": "['layers'][0]['alpha']", "shape": [1, 8], "dtype": "float32"},
            {"role": "opt", "path": "['t']", "shape": [], "dtype": "float32"}
          ],
          "storage": {"bits_per_weight": 0.8125,
            "layers": [{"idx": 0, "shape": [16, 8], "weights": 128, "stored_bits": 104}]},
          "flexor": {"default": {"q": 1, "n_in": 4, "n_out": 5, "bits_per_weight": 0.8,
            "mxor": [[[1,1,0,0],[0,1,1,0],[0,0,1,1],[1,0,0,1],[1,0,1,0]]]}}
        }"#;
        let v = json::parse(text).unwrap();
        let m = ConfigMeta::from_json(Path::new("/tmp/x"), &v).unwrap();
        assert_eq!(m.name, "t");
        assert_eq!(m.n_state(), 3);
        assert_eq!(m.batch, 8);
        assert_eq!(m.num_classes, 4);
        let spec = m.spec_for(0).unwrap();
        assert_eq!(spec.n_out, 5);
        assert_eq!(spec.mxor[0].n_in(), 4);
        let q = m.quantized_param_leaves();
        assert_eq!(q.get(&0), Some(&(0, 1)));
        assert_eq!(m.storage_layers[0].weights, 128);
        assert_eq!(m.train_scalar_order, vec!["lr", "s_tanh", "relax_lambda"]);
    }

    #[test]
    fn config_meta_rejects_count_mismatch() {
        let text = r#"{
          "config": {"name": "t", "model": "mlp", "quantizer": {"kind": "fp"}},
          "batch": 8, "input": {"shape": [8, 16], "classes": 4},
          "counts": {"params": 5, "opt": 0, "bn": 0},
          "train_io": {}, "eval_io": {}, "leaves": [], "storage": {},
          "flexor": null
        }"#;
        let v = json::parse(text).unwrap();
        assert!(ConfigMeta::from_json(Path::new("/tmp"), &v).is_err());
    }
}
