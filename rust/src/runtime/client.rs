//! PJRT client wrapper: HLO text → compiled executable → typed execution.
//!
//! Interchange is HLO **text** (`HloModuleProto::from_text_file`): jax ≥ 0.5
//! serialized protos carry 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids (see /opt/xla-example/README.md).

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

use anyhow::{Context, Result};
use xla::{Literal, PjRtClient, PjRtLoadedExecutable};

/// Process-wide PJRT runtime (CPU). Compiled executables are cached by
/// artifact path so table runners can reuse them across sweep points.
pub struct Runtime {
    client: PjRtClient,
    cache: Mutex<HashMap<String, std::sync::Arc<Executable>>>,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, cache: Mutex::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile an HLO-text artifact (cached).
    pub fn load_hlo(&self, path: &Path) -> Result<std::sync::Arc<Executable>> {
        let key = path.to_string_lossy().to_string();
        if let Some(e) = self.cache.lock().unwrap().get(&key) {
            return Ok(e.clone());
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        let exe = std::sync::Arc::new(Executable { exe, name: key.clone() });
        self.cache.lock().unwrap().insert(key, exe.clone());
        Ok(exe)
    }
}

/// A compiled artifact with the flat tuple calling convention
/// (aot.py lowers with `return_tuple=True`).
pub struct Executable {
    exe: PjRtLoadedExecutable,
    pub name: String,
}

impl Executable {
    /// Execute with literal inputs; returns the flattened output tuple.
    pub fn run(&self, inputs: &[&Literal]) -> Result<Vec<Literal>> {
        let bufs = self
            .exe
            .execute::<&Literal>(inputs)
            .with_context(|| format!("executing {}", self.name))?;
        let out = bufs[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        out.to_tuple().context("untupling result")
    }
}

/// Literal construction helpers (the marshalling layer between the Rust
/// data substrates and the HLO calling convention).
pub mod lit {
    use super::*;

    /// f32 tensor from a flat host vector + dims.
    pub fn f32_tensor(data: &[f32], dims: &[usize]) -> Result<Literal> {
        let n: usize = dims.iter().product();
        anyhow::ensure!(n == data.len(), "shape {:?} != len {}", dims, data.len());
        let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
        Ok(Literal::vec1(data).reshape(&dims_i64)?)
    }

    /// i32 vector (labels).
    pub fn i32_vec(data: &[i32]) -> Literal {
        Literal::vec1(data)
    }

    /// f32 scalar (schedule inputs).
    pub fn f32_scalar(x: f32) -> Literal {
        Literal::scalar(x)
    }

    /// Read back a scalar f32 from an output literal.
    pub fn scalar_f32(l: &Literal) -> Result<f32> {
        Ok(l.get_first_element::<f32>()?)
    }

    /// Read back a full f32 tensor.
    pub fn to_f32_vec(l: &Literal) -> Result<Vec<f32>> {
        Ok(l.to_vec::<f32>()?)
    }
}

#[cfg(test)]
mod tests {
    //! Runtime tests that need a PJRT client + artifacts live in
    //! `rust/tests/` (integration) — creating multiple CPU clients inside
    //! one test process is safe but slow. Here: literal marshalling only.
    use super::lit;

    #[test]
    fn literal_roundtrip_f32() {
        let l = lit::f32_tensor(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]).unwrap();
        assert_eq!(lit::to_f32_vec(&l).unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn literal_shape_mismatch() {
        assert!(lit::f32_tensor(&[1.0, 2.0], &[3]).is_err());
    }

    #[test]
    fn scalar_roundtrip() {
        let l = lit::f32_scalar(0.125);
        assert_eq!(lit::scalar_f32(&l).unwrap(), 0.125);
    }
}
