//! Parser for `init.bin` (FXIN): the initial (params, opt_state, bn_state)
//! leaves serialized by aot.py. Layout (little-endian):
//!
//! ```text
//! "FXIN" | u32 version | u32 n_leaves
//! leaf*:  u8 dtype (0=f32, 1=i32) | u8 rank | u16 pad | rank×u32 dims | data
//! ```

use anyhow::{bail, ensure, Context, Result};
use xla::{ElementType, Literal};

pub const MAGIC: &[u8; 4] = b"FXIN";

/// A parsed leaf: shape + raw host data, convertible to an xla Literal.
#[derive(Clone, Debug)]
pub struct Leaf {
    pub dtype: LeafType,
    pub shape: Vec<usize>,
    pub bytes: Vec<u8>,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LeafType {
    F32,
    I32,
}

impl Leaf {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    pub fn as_f32(&self) -> Result<Vec<f32>> {
        ensure!(self.dtype == LeafType::F32, "leaf is not f32");
        Ok(self
            .bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    pub fn to_literal(&self) -> Literal {
        let ty = match self.dtype {
            LeafType::F32 => ElementType::F32,
            LeafType::I32 => ElementType::S32,
        };
        Literal::create_from_shape_and_untyped_data(ty, &self.shape, &self.bytes)
            .expect("leaf -> literal")
    }
}

/// Parse the full file into leaves.
pub fn read_init_bin(bytes: &[u8]) -> Result<Vec<Leaf>> {
    ensure!(bytes.len() >= 12, "truncated init.bin");
    ensure!(&bytes[..4] == MAGIC, "bad init.bin magic");
    let version = u32::from_le_bytes(bytes[4..8].try_into()?);
    ensure!(version == 1, "unsupported init.bin version {version}");
    let n = u32::from_le_bytes(bytes[8..12].try_into()?) as usize;
    let mut leaves = Vec::with_capacity(n);
    let mut i = 12usize;
    for li in 0..n {
        ensure!(i + 4 <= bytes.len(), "truncated leaf header {li}");
        let dtype = match bytes[i] {
            0 => LeafType::F32,
            1 => LeafType::I32,
            t => bail!("leaf {li}: unknown dtype tag {t}"),
        };
        let rank = bytes[i + 1] as usize;
        i += 4;
        ensure!(i + 4 * rank <= bytes.len(), "truncated dims of leaf {li}");
        let shape: Vec<usize> = (0..rank)
            .map(|d| {
                u32::from_le_bytes(bytes[i + 4 * d..i + 4 * d + 4].try_into().unwrap())
                    as usize
            })
            .collect();
        i += 4 * rank;
        let count: usize = shape.iter().product::<usize>().max(1);
        let nbytes = count * 4;
        ensure!(i + nbytes <= bytes.len(), "truncated data of leaf {li}");
        leaves.push(Leaf { dtype, shape, bytes: bytes[i..i + nbytes].to_vec() });
        i += nbytes;
    }
    ensure!(i == bytes.len(), "trailing bytes in init.bin");
    Ok(leaves)
}

/// Load and parse from a file path.
pub fn load_init_bin(path: &std::path::Path) -> Result<Vec<Leaf>> {
    let bytes = std::fs::read(path)
        .with_context(|| format!("reading {}", path.display()))?;
    read_init_bin(&bytes)
}

/// Serialize leaves back to the FXIN format (checkpointing / FP sidecars).
pub fn write_init_bin(leaves: &[Leaf]) -> Vec<u8> {
    let mut b = Vec::new();
    b.extend_from_slice(MAGIC);
    b.extend_from_slice(&1u32.to_le_bytes());
    b.extend_from_slice(&(leaves.len() as u32).to_le_bytes());
    for l in leaves {
        b.push(match l.dtype {
            LeafType::F32 => 0,
            LeafType::I32 => 1,
        });
        b.push(l.shape.len() as u8);
        b.extend_from_slice(&[0, 0]);
        for &d in &l.shape {
            b.extend_from_slice(&(d as u32).to_le_bytes());
        }
        b.extend_from_slice(&l.bytes);
    }
    b
}

#[cfg(test)]
mod tests {
    use super::*;

    fn encode(leaves: &[(LeafType, Vec<u32>, Vec<u8>)]) -> Vec<u8> {
        let mut b = Vec::new();
        b.extend_from_slice(MAGIC);
        b.extend_from_slice(&1u32.to_le_bytes());
        b.extend_from_slice(&(leaves.len() as u32).to_le_bytes());
        for (t, dims, data) in leaves {
            b.push(match t {
                LeafType::F32 => 0,
                LeafType::I32 => 1,
            });
            b.push(dims.len() as u8);
            b.extend_from_slice(&[0, 0]);
            for d in dims {
                b.extend_from_slice(&d.to_le_bytes());
            }
            b.extend_from_slice(data);
        }
        b
    }

    #[test]
    fn roundtrip_two_leaves() {
        let f: Vec<u8> = [1.5f32, -2.0, 0.25, 8.0]
            .iter()
            .flat_map(|x| x.to_le_bytes())
            .collect();
        let s: Vec<u8> = 7f32.to_le_bytes().to_vec();
        let bytes = encode(&[
            (LeafType::F32, vec![2, 2], f),
            (LeafType::F32, vec![], s),
        ]);
        let leaves = read_init_bin(&bytes).unwrap();
        assert_eq!(leaves.len(), 2);
        assert_eq!(leaves[0].shape, vec![2, 2]);
        assert_eq!(leaves[0].as_f32().unwrap(), vec![1.5, -2.0, 0.25, 8.0]);
        assert_eq!(leaves[1].shape, Vec::<usize>::new());
        assert_eq!(leaves[1].element_count(), 1);
    }

    #[test]
    fn rejects_corruption() {
        let f: Vec<u8> = 1f32.to_le_bytes().to_vec();
        let good = encode(&[(LeafType::F32, vec![1], f)]);
        assert!(read_init_bin(&good[..good.len() - 1]).is_err());
        let mut bad_magic = good.clone();
        bad_magic[0] = b'X';
        assert!(read_init_bin(&bad_magic).is_err());
        let mut bad_tag = good.clone();
        bad_tag[12] = 9;
        assert!(read_init_bin(&bad_tag).is_err());
        let mut trailing = good.clone();
        trailing.push(0);
        assert!(read_init_bin(&trailing).is_err());
    }

    #[test]
    fn writer_reader_roundtrip() {
        let leaves = vec![
            Leaf {
                dtype: LeafType::F32,
                shape: vec![3],
                bytes: [1f32, 2.0, 3.0].iter().flat_map(|x| x.to_le_bytes()).collect(),
            },
            Leaf {
                dtype: LeafType::I32,
                shape: vec![2, 1],
                bytes: [7i32, -1].iter().flat_map(|x| x.to_le_bytes()).collect(),
            },
        ];
        let bytes = write_init_bin(&leaves);
        let back = read_init_bin(&bytes).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].as_f32().unwrap(), vec![1.0, 2.0, 3.0]);
        assert_eq!(back[1].shape, vec![2, 1]);
        assert_eq!(back[1].bytes, leaves[1].bytes);
    }

    #[test]
    fn i32_leaf() {
        let d: Vec<u8> = [1i32, -5, 100]
            .iter()
            .flat_map(|x| x.to_le_bytes())
            .collect();
        let bytes = encode(&[(LeafType::I32, vec![3], d)]);
        let leaves = read_init_bin(&bytes).unwrap();
        assert_eq!(leaves[0].dtype, LeafType::I32);
        assert!(leaves[0].as_f32().is_err());
    }
}
