//! # FleXOR: Trainable Fractional Quantization — full-system reproduction
//!
//! Rust coordinator (L3) of the three-layer stack reproducing
//! *FleXOR: Trainable Fractional Quantization* (Lee et al., NeurIPS 2020).
//!
//! The paper compresses binary-coding-quantized DNN weights **below one bit
//! per weight** by storing `N_in` "encrypted" bits per slice and
//! reconstructing `N_out` quantized bits through a fixed random XOR-gate
//! network `M⊕ ∈ {0,1}^{N_out×N_in}` — trained end-to-end with a
//! tanh-derived custom gradient.
//!
//! Layer map (see `DESIGN.md`):
//! * **L1/L2** (build-time Python, `python/compile/`): Pallas kernels + JAX
//!   model/optimizer, lowered once to HLO text artifacts.
//! * **L3** (this crate): training coordinator, schedules, synthetic data
//!   substrates, the PJRT runtime that executes the artifacts, the
//!   bit-level XOR **decryption engine**, the `.fxr` encrypted checkpoint
//!   container, and a pure-Rust binary-code inference engine — i.e. the
//!   paper's deployment story (Fig. 1–3, Algorithm 1) implemented with
//!   word-parallel XOR/popcount.
//! * **Inference** ([`inference`], DESIGN.md §7–§9): two compute engines
//!   behind one [`inference::ModePolicy`] — the packed-FP fused GEMM
//!   engine (cache-aligned panels, register-blocked microkernel, fused
//!   bias/BN/ReLU/residual epilogues) and the bit-plane XNOR/popcount
//!   engine (quantized layers stay packed bit-plane panels, dot products
//!   run on runtime-dispatched scalar/unrolled/AVX2 popcount kernels).
//!   Both shard across the [`substrate::pool`] thread pool and are
//!   bit-identical across thread counts and kernels.
//! * **Serving** ([`serve`], DESIGN.md §6): a multi-threaded batched
//!   inference server over the encrypted-bundle engine — model registry
//!   (decrypt once at load, per-layer compute modes), micro-batching
//!   admission queue, worker pool, and an HTTP/1.1 front-end
//!   (`/predict`, `/models`, `/metrics`, `/healthz`).
//!
//! Build and test (tier-1, offline — vendored stand-ins only):
//! ```bash
//! cargo build --release && cargo test -q
//! ```
//!
//! Serve a bundle (synthesizes one when no artifacts are present):
//! ```bash
//! cargo run --release --example serve -- --compute-mode bitplane
//! ```
//!
//! Runtime dials: `FLEXOR_THREADS` (intra-op pool size),
//! `FLEXOR_COMPUTE` (compute-mode policy, e.g. `bitplane:8@min=4096`),
//! `FLEXOR_SIMD` (`scalar|unrolled|avx2` popcount kernel override),
//! `FLEXOR_TRACE` (`off|sample:N|all` stage tracing — DESIGN.md §10),
//! `FLEXOR_LOG` (`error|warn|info|debug` structured-log threshold),
//! `FLEXOR_SLOW_MS` (slow-request warning threshold).
//! See `README.md` for the full quickstart and the endpoint table.

pub mod substrate;
pub mod flexor;
pub mod runtime;
pub mod coordinator;
pub mod data;
pub mod inference;
pub mod serve;
pub mod repo;
pub mod config;

/// Crate version (mirrors Cargo.toml).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");

/// Default artifacts directory, relative to the repository root.
pub const ARTIFACTS_DIR: &str = "artifacts";
