//! Signed on-disk bundle repository — the control plane's artifact store.
//!
//! FleXOR's deployable unit is the encrypted bundle triple
//! (`<stem>.fxr` + `<stem>.fp.bin` + `<stem>.bundle.json`, DESIGN.md §4);
//! at sub-1-bit-per-weight it is cheap enough to publish per model
//! *version* and swap under live traffic. This module gives those
//! bundles provenance on top of the fxr container's corruption checks:
//!
//! * a JSON `manifest.json` at the repo root lists every published
//!   `name@version` with per-file SHA-256 digests and byte sizes;
//! * each record carries an HMAC-SHA256 **signature** over a canonical
//!   encoding of (name, version, stem, file digests), keyed by the repo
//!   key (`FLEXOR_REPO_KEY` / `--key`);
//! * [`BundleRepo::verify`] checks the signature **first**, then each
//!   file's size and SHA-256 — all before the decryptor or the fxr
//!   parser ever touches a byte. The PR 8 integrity chain ("did the
//!   bytes rot?") extends to provenance ("are these the bytes the
//!   publisher signed?").
//!
//! Storage layout: `<root>/<name>/<version>/<stem>.{fxr,fp.bin,bundle.json}`.
//! Names and versions are restricted to `[A-Za-z0-9._-]` so a manifest
//! entry can never escape the repo root.
//!
//! Everything is dependency-free `std` (DESIGN.md §5): SHA-256 and HMAC
//! are vendored in [`sha`], like the CRC-32 in `flexor::fxr`.

pub mod sha;

use std::path::{Path, PathBuf};

use anyhow::{bail, ensure, Context, Result};

use crate::substrate::json::{self, Json};

/// Manifest schema version.
pub const REPO_VERSION: u64 = 1;
const MANIFEST: &str = "manifest.json";
/// Domain-separation prefix of the canonical signing encoding.
const SIGNING_CONTEXT: &str = "flexor-bundle-v1";

/// One file of a published bundle: name, content digest, size.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FileRecord {
    pub file: String,
    pub sha256: String,
    pub bytes: u64,
}

/// One published `name@version` with its signature.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BundleRecord {
    pub name: String,
    pub version: String,
    pub stem: String,
    pub files: Vec<FileRecord>,
    /// HMAC-SHA256 (hex) over [`BundleRecord::signing_bytes`].
    pub signature: String,
}

impl BundleRecord {
    /// Canonical byte encoding the signature covers. Files are sorted by
    /// name so the encoding is independent of manifest ordering.
    pub fn signing_bytes(&self) -> Vec<u8> {
        let mut files = self.files.clone();
        files.sort_by(|a, b| a.file.cmp(&b.file));
        let mut s = format!(
            "{SIGNING_CONTEXT}\n{}\n{}\n{}\n",
            self.name, self.version, self.stem
        );
        for f in &files {
            s.push_str(&format!("{}:{}:{}\n", f.file, f.sha256, f.bytes));
        }
        s.into_bytes()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("version", Json::str(self.version.clone())),
            ("stem", Json::str(self.stem.clone())),
            (
                "files",
                Json::arr(self.files.iter().map(|f| {
                    Json::obj(vec![
                        ("file", Json::str(f.file.clone())),
                        ("sha256", Json::str(f.sha256.clone())),
                        ("bytes", Json::num(f.bytes as f64)),
                    ])
                })),
            ),
            ("signature", Json::str(self.signature.clone())),
        ])
    }

    fn from_json(j: &Json) -> Result<Self> {
        let field = |k: &str| {
            j.get(k)
                .as_str()
                .map(str::to_string)
                .with_context(|| format!("manifest bundle record missing '{k}'"))
        };
        let files = j
            .get("files")
            .as_arr()
            .context("manifest bundle record missing 'files'")?
            .iter()
            .map(|f| {
                Ok(FileRecord {
                    file: f.get("file").as_str().context("file record missing 'file'")?.to_string(),
                    sha256: f
                        .get("sha256")
                        .as_str()
                        .context("file record missing 'sha256'")?
                        .to_string(),
                    bytes: f.get("bytes").as_f64().context("file record missing 'bytes'")? as u64,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(BundleRecord {
            name: field("name")?,
            version: field("version")?,
            stem: field("stem")?,
            files,
            signature: field("signature")?,
        })
    }
}

/// A bundle that passed signature + digest verification: safe to hand to
/// the fxr parser / registry loader.
#[derive(Clone, Debug)]
pub struct VerifiedBundle {
    /// Directory holding the verified files (inside the repo store).
    pub dir: PathBuf,
    pub stem: String,
    pub record: BundleRecord,
}

/// An on-disk signed bundle repository.
#[derive(Clone, Debug)]
pub struct BundleRepo {
    root: PathBuf,
    key: Vec<u8>,
}

/// Reject anything that could traverse out of the repo root; the same
/// grammar request ids use, so names are also log- and URL-safe.
pub fn validate_component(kind: &str, s: &str) -> Result<()> {
    ensure!(!s.is_empty(), "{kind} must not be empty");
    ensure!(s.len() <= 64, "{kind} '{s}' exceeds 64 chars");
    ensure!(
        s.chars().all(|c| c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.')),
        "{kind} '{s}' has characters outside [A-Za-z0-9._-]"
    );
    ensure!(s != "." && s != "..", "{kind} '{s}' is reserved");
    Ok(())
}

/// The three files a bundle triple consists of.
fn bundle_files(stem: &str) -> [String; 3] {
    [
        format!("{stem}.fxr"),
        format!("{stem}.fp.bin"),
        format!("{stem}.bundle.json"),
    ]
}

impl BundleRepo {
    /// Create a fresh repo at `root` (fails if one already exists there).
    pub fn init(root: &Path, key: &[u8]) -> Result<Self> {
        ensure!(!key.is_empty(), "repo key must not be empty (FLEXOR_REPO_KEY / --key)");
        let manifest = root.join(MANIFEST);
        ensure!(
            !manifest.exists(),
            "repo already initialized at {} ({MANIFEST} exists)",
            root.display()
        );
        std::fs::create_dir_all(root)
            .with_context(|| format!("creating repo root {}", root.display()))?;
        let repo = BundleRepo { root: root.to_path_buf(), key: key.to_vec() };
        repo.write_manifest(&[])?;
        Ok(repo)
    }

    /// Open an existing repo (its `manifest.json` must exist).
    pub fn open(root: &Path, key: &[u8]) -> Result<Self> {
        ensure!(!key.is_empty(), "repo key must not be empty (FLEXOR_REPO_KEY / --key)");
        ensure!(
            root.join(MANIFEST).exists(),
            "no bundle repo at {} (missing {MANIFEST}; run `flexor repo init` first)",
            root.display()
        );
        Ok(BundleRepo { root: root.to_path_buf(), key: key.to_vec() })
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    /// Where `name@version`'s files live inside the store.
    pub fn bundle_dir(&self, name: &str, version: &str) -> PathBuf {
        self.root.join(name).join(version)
    }

    /// All published records, manifest order.
    pub fn list(&self) -> Result<Vec<BundleRecord>> {
        self.read_manifest()
    }

    /// Copy `src_dir/<stem>.*` into the store, record per-file SHA-256,
    /// sign the record, and update the manifest. Republishing the same
    /// `name@version` replaces the record (and its files).
    pub fn publish(
        &self,
        name: &str,
        version: &str,
        src_dir: &Path,
        stem: &str,
    ) -> Result<BundleRecord> {
        validate_component("bundle name", name)?;
        validate_component("bundle version", version)?;
        validate_component("bundle stem", stem)?;
        let mut files = Vec::new();
        let mut contents = Vec::new();
        for file in bundle_files(stem) {
            let path = src_dir.join(&file);
            let bytes = std::fs::read(&path).with_context(|| {
                format!("reading bundle file {} for publish", path.display())
            })?;
            files.push(FileRecord {
                file: file.clone(),
                sha256: sha::hex(&sha::sha256(&bytes)),
                bytes: bytes.len() as u64,
            });
            contents.push((file, bytes));
        }
        let mut record = BundleRecord {
            name: name.to_string(),
            version: version.to_string(),
            stem: stem.to_string(),
            files,
            signature: String::new(),
        };
        record.signature = sha::hex(&sha::hmac_sha256(&self.key, &record.signing_bytes()));

        // files land before the manifest points at them, so a crash
        // between the two leaves no record of a half-published bundle
        let dir = self.bundle_dir(name, version);
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating bundle dir {}", dir.display()))?;
        for (file, bytes) in &contents {
            std::fs::write(dir.join(file), bytes)
                .with_context(|| format!("writing {} into the repo store", file))?;
        }
        let mut records = self.read_manifest()?;
        records.retain(|r| !(r.name == name && r.version == version));
        records.push(record.clone());
        self.write_manifest(&records)?;
        Ok(record)
    }

    /// Verify `name@version`: HMAC signature over the manifest record
    /// first (provenance), then each stored file's size and SHA-256
    /// (content) — all **before** any parser touches the bytes. Errors
    /// name the bundle so a `POST /models` 409 body is actionable.
    pub fn verify(&self, name: &str, version: &str) -> Result<VerifiedBundle> {
        validate_component("bundle name", name)?;
        validate_component("bundle version", version)?;
        let records = self.read_manifest()?;
        let record = records
            .into_iter()
            .find(|r| r.name == name && r.version == version)
            .with_context(|| format!("bundle {name}@{version} is not in the repo manifest"))?;
        let expect = sha::hex(&sha::hmac_sha256(&self.key, &record.signing_bytes()));
        ensure!(
            sha::ct_eq(&expect, &record.signature),
            "signature mismatch for bundle {name}@{version} — manifest record was not signed \
             by this repo key; refusing to load"
        );
        let dir = self.bundle_dir(name, version);
        for f in &record.files {
            validate_component("bundle file", &f.file)?;
            let path = dir.join(&f.file);
            let bytes = std::fs::read(&path).with_context(|| {
                format!("reading {} of bundle {name}@{version}", path.display())
            })?;
            ensure!(
                bytes.len() as u64 == f.bytes,
                "size mismatch for {} of bundle {name}@{version}: manifest says {} bytes, \
                 store has {}",
                f.file,
                f.bytes,
                bytes.len()
            );
            let got = sha::hex(&sha::sha256(&bytes));
            ensure!(
                sha::ct_eq(&got, &f.sha256),
                "sha256 mismatch for {} of bundle {name}@{version} — stored bytes do not \
                 match the signed digest; refusing to load",
                f.file
            );
        }
        Ok(VerifiedBundle { dir, stem: record.stem.clone(), record })
    }

    /// Verify, then copy the bundle triple into `dest`.
    pub fn fetch(&self, name: &str, version: &str, dest: &Path) -> Result<VerifiedBundle> {
        let v = self.verify(name, version)?;
        std::fs::create_dir_all(dest)
            .with_context(|| format!("creating fetch dest {}", dest.display()))?;
        for f in &v.record.files {
            std::fs::copy(v.dir.join(&f.file), dest.join(&f.file))
                .with_context(|| format!("fetching {} to {}", f.file, dest.display()))?;
        }
        Ok(VerifiedBundle { dir: dest.to_path_buf(), stem: v.stem.clone(), record: v.record })
    }

    fn read_manifest(&self) -> Result<Vec<BundleRecord>> {
        let path = self.root.join(MANIFEST);
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = json::parse(&text).context("parsing repo manifest json")?;
        let v = j.get("repo_version").as_f64().context("manifest missing repo_version")? as u64;
        ensure!(v == REPO_VERSION, "unsupported repo_version {v} (this build reads {REPO_VERSION})");
        j.get("bundles")
            .as_arr()
            .context("manifest missing 'bundles'")?
            .iter()
            .map(BundleRecord::from_json)
            .collect()
    }

    fn write_manifest(&self, records: &[BundleRecord]) -> Result<()> {
        let j = Json::obj(vec![
            ("repo_version", Json::num(REPO_VERSION as f64)),
            ("bundles", Json::arr(records.iter().map(|r| r.to_json()))),
        ]);
        let path = self.root.join(MANIFEST);
        std::fs::write(&path, j.to_string_pretty())
            .with_context(|| format!("writing {}", path.display()))
    }
}

/// Split a `name@version` spec; both halves must be present and valid.
pub fn parse_spec(spec: &str) -> Result<(String, String)> {
    match spec.split_once('@') {
        Some((n, v)) => {
            validate_component("bundle name", n)?;
            validate_component("bundle version", v)?;
            Ok((n.to_string(), v.to_string()))
        }
        None => bail!("bundle spec '{spec}' must be name@version (e.g. resnet20@v2)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_root(tag: &str) -> PathBuf {
        let p = std::env::temp_dir().join(format!("flexor_repo_{tag}_{}", std::process::id()));
        std::fs::remove_dir_all(&p).ok();
        p
    }

    /// The repo layer never parses bundle contents, so unit tests can
    /// publish arbitrary bytes under the right file names; real-bundle
    /// flows live in `rust/tests/control_plane.rs`.
    fn fake_bundle(dir: &Path, stem: &str, seed: u8) {
        std::fs::create_dir_all(dir).unwrap();
        for (i, file) in bundle_files(stem).iter().enumerate() {
            let body: Vec<u8> = (0..64u8).map(|b| b ^ seed ^ (i as u8)).collect();
            std::fs::write(dir.join(file), body).unwrap();
        }
    }

    #[test]
    fn publish_verify_fetch_roundtrip() {
        let root = temp_root("roundtrip");
        let src = root.join("src");
        fake_bundle(&src, "m", 1);
        let repo = BundleRepo::init(&root.join("store"), b"secret").unwrap();
        let rec = repo.publish("demo", "v1", &src, "m").unwrap();
        assert_eq!(rec.files.len(), 3);
        assert_eq!(rec.signature.len(), 64);
        assert_eq!(repo.list().unwrap().len(), 1);

        let v = repo.verify("demo", "v1").unwrap();
        assert_eq!(v.stem, "m");
        let dest = root.join("fetched");
        let f = repo.fetch("demo", "v1", &dest).unwrap();
        assert_eq!(f.dir, dest);
        for file in bundle_files("m") {
            assert_eq!(
                std::fs::read(src.join(&file)).unwrap(),
                std::fs::read(dest.join(&file)).unwrap()
            );
        }
        // reopen with the same key: still verifies
        let again = BundleRepo::open(repo.root(), b"secret").unwrap();
        again.verify("demo", "v1").unwrap();
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn tampered_file_rejected_naming_bundle() {
        let root = temp_root("tamper");
        let src = root.join("src");
        fake_bundle(&src, "m", 2);
        let repo = BundleRepo::init(&root.join("store"), b"secret").unwrap();
        repo.publish("demo", "v1", &src, "m").unwrap();
        // flip one byte of the stored .fxr
        let path = repo.bundle_dir("demo", "v1").join("m.fxr");
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[10] ^= 0xFF;
        std::fs::write(&path, bytes).unwrap();
        let err = repo.verify("demo", "v1").unwrap_err().to_string();
        assert!(err.contains("sha256 mismatch"), "{err}");
        assert!(err.contains("demo@v1"), "{err}");
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn wrong_key_and_tampered_manifest_rejected() {
        let root = temp_root("sig");
        let src = root.join("src");
        fake_bundle(&src, "m", 3);
        let repo = BundleRepo::init(&root.join("store"), b"secret").unwrap();
        repo.publish("demo", "v1", &src, "m").unwrap();

        // wrong key: signature check fails before any file is hashed
        let wrong = BundleRepo::open(repo.root(), b"not-the-key").unwrap();
        let err = wrong.verify("demo", "v1").unwrap_err().to_string();
        assert!(err.contains("signature mismatch"), "{err}");
        assert!(err.contains("demo@v1"), "{err}");

        // manifest edited after signing (size bumped): signature breaks
        let mpath = repo.root().join(MANIFEST);
        let text = std::fs::read_to_string(&mpath).unwrap().replace("64", "65");
        std::fs::write(&mpath, text).unwrap();
        let err = repo.verify("demo", "v1").unwrap_err().to_string();
        assert!(err.contains("signature mismatch"), "{err}");
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn republish_replaces_and_versions_coexist() {
        let root = temp_root("versions");
        let (s1, s2) = (root.join("s1"), root.join("s2"));
        fake_bundle(&s1, "m", 4);
        fake_bundle(&s2, "m", 5);
        let repo = BundleRepo::init(&root.join("store"), b"k").unwrap();
        repo.publish("demo", "v1", &s1, "m").unwrap();
        repo.publish("demo", "v2", &s2, "m").unwrap();
        assert_eq!(repo.list().unwrap().len(), 2);
        // republish v1 from the v2 source: replaced, not duplicated
        repo.publish("demo", "v1", &s2, "m").unwrap();
        let list = repo.list().unwrap();
        assert_eq!(list.len(), 2);
        repo.verify("demo", "v1").unwrap();
        repo.verify("demo", "v2").unwrap();
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn bad_names_and_specs_rejected() {
        let root = temp_root("names");
        let repo = BundleRepo::init(&root, b"k").unwrap();
        assert!(repo.verify("../escape", "v1").is_err());
        assert!(repo.verify("ok", "v/1").is_err());
        assert!(repo.verify("", "v1").is_err());
        assert!(parse_spec("noversion").is_err());
        assert!(parse_spec("a@b@c").is_err());
        assert!(parse_spec("a@..").is_err());
        let (n, v) = parse_spec("resnet20@v2").unwrap();
        assert_eq!((n.as_str(), v.as_str()), ("resnet20", "v2"));
        assert!(BundleRepo::init(&root, b"k").is_err(), "double init must fail");
        assert!(BundleRepo::open(&root.join("missing"), b"k").is_err());
        assert!(BundleRepo::open(&root, b"").is_err(), "empty key must fail");
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn missing_bundle_is_a_clear_error() {
        let root = temp_root("missing");
        let repo = BundleRepo::init(&root, b"k").unwrap();
        let err = repo.verify("ghost", "v9").unwrap_err().to_string();
        assert!(err.contains("ghost@v9"), "{err}");
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn signing_bytes_are_order_independent() {
        let mk = |order_swapped: bool| {
            let mut files = vec![
                FileRecord { file: "a.fxr".into(), sha256: "aa".into(), bytes: 1 },
                FileRecord { file: "b.bin".into(), sha256: "bb".into(), bytes: 2 },
            ];
            if order_swapped {
                files.reverse();
            }
            BundleRecord {
                name: "n".into(),
                version: "v".into(),
                stem: "s".into(),
                files,
                signature: String::new(),
            }
        };
        assert_eq!(mk(false).signing_bytes(), mk(true).signing_bytes());
    }
}
