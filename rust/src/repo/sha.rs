//! Vendored SHA-256 + HMAC-SHA256 (FIPS 180-4 / RFC 2104) — the repo
//! layer's provenance primitives, dependency-free per DESIGN.md §5,
//! sitting alongside the vendored CRC-32 the fxr container already uses
//! for corruption detection. CRC answers "did the bytes rot?"; SHA-256 +
//! HMAC answer "are these the bytes the publisher signed?".

/// Round constants: first 32 bits of the fractional parts of the cube
/// roots of the first 64 primes.
const K: [u32; 64] = [
    0x428a2f98, 0x71374491, 0xb5c0fbcf, 0xe9b5dba5, 0x3956c25b, 0x59f111f1,
    0x923f82a4, 0xab1c5ed5, 0xd807aa98, 0x12835b01, 0x243185be, 0x550c7dc3,
    0x72be5d74, 0x80deb1fe, 0x9bdc06a7, 0xc19bf174, 0xe49b69c1, 0xefbe4786,
    0x0fc19dc6, 0x240ca1cc, 0x2de92c6f, 0x4a7484aa, 0x5cb0a9dc, 0x76f988da,
    0x983e5152, 0xa831c66d, 0xb00327c8, 0xbf597fc7, 0xc6e00bf3, 0xd5a79147,
    0x06ca6351, 0x14292967, 0x27b70a85, 0x2e1b2138, 0x4d2c6dfc, 0x53380d13,
    0x650a7354, 0x766a0abb, 0x81c2c92e, 0x92722c85, 0xa2bfe8a1, 0xa81a664b,
    0xc24b8b70, 0xc76c51a3, 0xd192e819, 0xd6990624, 0xf40e3585, 0x106aa070,
    0x19a4c116, 0x1e376c08, 0x2748774c, 0x34b0bcb5, 0x391c0cb3, 0x4ed8aa4a,
    0x5b9cca4f, 0x682e6ff3, 0x748f82ee, 0x78a5636f, 0x84c87814, 0x8cc70208,
    0x90befffa, 0xa4506ceb, 0xbef9a3f7, 0xc67178f2,
];

/// Initial hash state: first 32 bits of the fractional parts of the
/// square roots of the first 8 primes.
const IV: [u32; 8] = [
    0x6a09e667, 0xbb67ae85, 0x3c6ef372, 0xa54ff53a, 0x510e527f, 0x9b05688c,
    0x1f83d9ab, 0x5be0cd19,
];

/// Streaming SHA-256 (64-byte blocks, 64-bit length counter).
pub struct Sha256 {
    state: [u32; 8],
    buf: [u8; 64],
    buf_len: usize,
    total_bytes: u64,
}

impl Sha256 {
    pub fn new() -> Self {
        Sha256 { state: IV, buf: [0u8; 64], buf_len: 0, total_bytes: 0 }
    }

    pub fn update(&mut self, mut data: &[u8]) {
        self.total_bytes = self.total_bytes.wrapping_add(data.len() as u64);
        if self.buf_len > 0 {
            let take = (64 - self.buf_len).min(data.len());
            self.buf[self.buf_len..self.buf_len + take].copy_from_slice(&data[..take]);
            self.buf_len += take;
            data = &data[take..];
            if self.buf_len == 64 {
                let block = self.buf;
                self.compress(&block);
                self.buf_len = 0;
            }
        }
        while data.len() >= 64 {
            let mut block = [0u8; 64];
            block.copy_from_slice(&data[..64]);
            self.compress(&block);
            data = &data[64..];
        }
        if !data.is_empty() {
            self.buf[..data.len()].copy_from_slice(data);
            self.buf_len = data.len();
        }
    }

    pub fn finalize(mut self) -> [u8; 32] {
        let bit_len = self.total_bytes.wrapping_mul(8);
        // pad: 0x80, zeros, 64-bit big-endian bit length
        self.update_padding(&[0x80]);
        while self.buf_len != 56 {
            self.update_padding(&[0]);
        }
        self.update_padding(&bit_len.to_be_bytes());
        debug_assert_eq!(self.buf_len, 0);
        let mut out = [0u8; 32];
        for (i, w) in self.state.iter().enumerate() {
            out[4 * i..4 * i + 4].copy_from_slice(&w.to_be_bytes());
        }
        out
    }

    /// `update` without advancing the message length counter (padding
    /// bytes are not message bytes).
    fn update_padding(&mut self, data: &[u8]) {
        for &b in data {
            self.buf[self.buf_len] = b;
            self.buf_len += 1;
            if self.buf_len == 64 {
                let block = self.buf;
                self.compress(&block);
                self.buf_len = 0;
            }
        }
    }

    fn compress(&mut self, block: &[u8; 64]) {
        let mut w = [0u32; 64];
        for i in 0..16 {
            w[i] = u32::from_be_bytes([
                block[4 * i],
                block[4 * i + 1],
                block[4 * i + 2],
                block[4 * i + 3],
            ]);
        }
        for i in 16..64 {
            let s0 = w[i - 15].rotate_right(7) ^ w[i - 15].rotate_right(18) ^ (w[i - 15] >> 3);
            let s1 = w[i - 2].rotate_right(17) ^ w[i - 2].rotate_right(19) ^ (w[i - 2] >> 10);
            w[i] = w[i - 16]
                .wrapping_add(s0)
                .wrapping_add(w[i - 7])
                .wrapping_add(s1);
        }
        let [mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut h] = self.state;
        for i in 0..64 {
            let s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
            let ch = (e & f) ^ (!e & g);
            let t1 = h
                .wrapping_add(s1)
                .wrapping_add(ch)
                .wrapping_add(K[i])
                .wrapping_add(w[i]);
            let s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
            let maj = (a & b) ^ (a & c) ^ (b & c);
            let t2 = s0.wrapping_add(maj);
            h = g;
            g = f;
            f = e;
            e = d.wrapping_add(t1);
            d = c;
            c = b;
            b = a;
            a = t1.wrapping_add(t2);
        }
        self.state[0] = self.state[0].wrapping_add(a);
        self.state[1] = self.state[1].wrapping_add(b);
        self.state[2] = self.state[2].wrapping_add(c);
        self.state[3] = self.state[3].wrapping_add(d);
        self.state[4] = self.state[4].wrapping_add(e);
        self.state[5] = self.state[5].wrapping_add(f);
        self.state[6] = self.state[6].wrapping_add(g);
        self.state[7] = self.state[7].wrapping_add(h);
    }
}

impl Default for Sha256 {
    fn default() -> Self {
        Self::new()
    }
}

/// One-shot SHA-256.
pub fn sha256(bytes: &[u8]) -> [u8; 32] {
    let mut h = Sha256::new();
    h.update(bytes);
    h.finalize()
}

/// HMAC-SHA256 (RFC 2104): keys longer than the 64-byte block are hashed
/// first, shorter ones zero-padded.
pub fn hmac_sha256(key: &[u8], msg: &[u8]) -> [u8; 32] {
    let mut k = [0u8; 64];
    if key.len() > 64 {
        k[..32].copy_from_slice(&sha256(key));
    } else {
        k[..key.len()].copy_from_slice(key);
    }
    let mut ipad = [0u8; 64];
    let mut opad = [0u8; 64];
    for i in 0..64 {
        ipad[i] = k[i] ^ 0x36;
        opad[i] = k[i] ^ 0x5c;
    }
    let mut inner = Sha256::new();
    inner.update(&ipad);
    inner.update(msg);
    let inner_digest = inner.finalize();
    let mut outer = Sha256::new();
    outer.update(&opad);
    outer.update(&inner_digest);
    outer.finalize()
}

/// Lowercase hex encoding (the manifest's digest/signature wire format).
pub fn hex(bytes: &[u8]) -> String {
    const DIGITS: &[u8; 16] = b"0123456789abcdef";
    let mut s = String::with_capacity(bytes.len() * 2);
    for &b in bytes {
        s.push(DIGITS[(b >> 4) as usize] as char);
        s.push(DIGITS[(b & 0xF) as usize] as char);
    }
    s
}

/// Constant-time equality for hex digests: a signature check must not
/// leak the matching prefix length through timing.
pub fn ct_eq(a: &str, b: &str) -> bool {
    if a.len() != b.len() {
        return false;
    }
    let mut diff = 0u8;
    for (x, y) in a.bytes().zip(b.bytes()) {
        diff |= x ^ y;
    }
    diff == 0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sha256_fips_vectors() {
        assert_eq!(
            hex(&sha256(b"")),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
        assert_eq!(
            hex(&sha256(b"abc")),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
        assert_eq!(
            hex(&sha256(b"abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq")),
            "248d6a61d20638b8e5c026930c3e6039a33ce45964ff2167f6ecedd419db06c1"
        );
    }

    #[test]
    fn sha256_streaming_matches_oneshot() {
        let msg: Vec<u8> = (0..1000u32).flat_map(|i| i.to_le_bytes()).collect();
        let oneshot = sha256(&msg);
        // feed in ragged chunks that straddle block boundaries
        let mut h = Sha256::new();
        let mut i = 0;
        for (step, chunk) in [1usize, 63, 64, 65, 7, 128, 500].iter().cycle().enumerate() {
            if i >= msg.len() {
                break;
            }
            let end = (i + chunk).min(msg.len());
            h.update(&msg[i..end]);
            i = end;
            let _ = step;
        }
        assert_eq!(h.finalize(), oneshot);
    }

    #[test]
    fn sha256_million_a() {
        // FIPS 180-4 long vector: 1,000,000 × 'a'
        let mut h = Sha256::new();
        let chunk = [b'a'; 1000];
        for _ in 0..1000 {
            h.update(&chunk);
        }
        assert_eq!(
            hex(&h.finalize()),
            "cdc76e5c9914fb9281a1c7e284d73e67f1809a48a497200e046d39ccc7112cd0"
        );
    }

    #[test]
    fn hmac_rfc4231_vectors() {
        // test case 1
        assert_eq!(
            hex(&hmac_sha256(&[0x0b; 20], b"Hi There")),
            "b0344c61d8db38535ca8afceaf0bf12b881dc200c9833da726e9376c2e32cff7"
        );
        // test case 2
        assert_eq!(
            hex(&hmac_sha256(b"Jefe", b"what do ya want for nothing?")),
            "5bdcc146bf60754e6a042426089575c75a003f089d2739839dec58b964ec3843"
        );
        // test case 6: key longer than the block size is hashed first
        assert_eq!(
            hex(&hmac_sha256(
                &[0xaa; 131],
                b"Test Using Larger Than Block-Size Key - Hash Key First"
            )),
            "60e431591ee0b67f0d8a26aacbf5b77f8e0bc6213728c5140546040f0ee37f54"
        );
    }

    #[test]
    fn hex_and_ct_eq() {
        assert_eq!(hex(&[0x00, 0xff, 0x1a]), "00ff1a");
        assert!(ct_eq("deadbeef", "deadbeef"));
        assert!(!ct_eq("deadbeef", "deadbeee"));
        assert!(!ct_eq("dead", "deadbeef"));
    }
}
