//! Infrastructure substrates built in-repo (DESIGN.md §5: the offline build
//! image vendors only `xla` + `anyhow`, so everything else a framework
//! normally takes from crates.io is implemented here, with tests).

pub mod prng;
pub mod json;
pub mod net;
pub mod argparse;
pub mod stats;
pub mod bench;
pub mod fault;
pub mod pool;
pub mod ptest;
pub mod trace;
