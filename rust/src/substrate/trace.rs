//! Lock-light span tracing + structured logging (the observability
//! substrate; DESIGN.md §10).
//!
//! Three cooperating pieces:
//!
//! * **Spans.** A traced *scope* (one forward pass, opened by the serve
//!   worker or the `flexor profile` CLI) activates recording on the
//!   current thread. Inside an active scope, [`span`] / [`layer_span`]
//!   guards time a stage (`im2col`, `gemm`, `binarize`, …) or a layer
//!   (`q3:bitplane1@avx2`) and append a [`SpanRec`] to a bounded
//!   per-thread ring buffer on drop. Outside an active scope every guard
//!   constructor is a thread-local load returning `None` — the hot path
//!   never takes a lock, allocates, or reads the clock when tracing is
//!   off. Results are untouched either way: tracing only observes time,
//!   so forward outputs are bit-identical with tracing off, sampled, or
//!   on (`tests/observe.rs`).
//!
//! * **Profiles.** A scope may carry an [`Profile`] sink (one per served
//!   model, owned by the registry entry): every span lands there as a
//!   `(layer, stage) → {count, total_ns}` aggregate, which backs
//!   `GET /models/<name>/profile` and the `flexor profile` table.
//!
//! * **Logger.** [`log`] emits one JSON object per line to stderr with a
//!   level dial (`FLEXOR_LOG=error|warn|info|debug`, default `info`),
//!   replacing ad-hoc `eprintln!`s on the serving error paths.
//!
//! Sampling dial: `FLEXOR_TRACE=off|sample:N|all` (default `off`) decides
//! per *scope* — a sampled-out forward records nothing at all, so
//! `sample:N` traces every Nth forward end to end rather than a random
//! subset of its spans.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock, Weak};
use std::time::{Instant, SystemTime, UNIX_EPOCH};

use anyhow::{bail, Result};

use super::json::Json;

// ---- sampling mode ----------------------------------------------------------

/// How many traced scopes to record: the `FLEXOR_TRACE` dial.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceMode {
    /// Record nothing (default): guards are inert.
    Off,
    /// Trace every Nth scope (N ≥ 1); `Sample(1)` behaves like `All`.
    Sample(u64),
    /// Trace every scope.
    All,
}

impl TraceMode {
    /// Parse the `FLEXOR_TRACE` syntax: `off`, `all`, or `sample:N`.
    pub fn parse(s: &str) -> Result<TraceMode> {
        let t = s.trim().to_ascii_lowercase();
        match t.as_str() {
            "off" | "0" | "" => return Ok(TraceMode::Off),
            "all" | "on" | "1" => return Ok(TraceMode::All),
            _ => {}
        }
        if let Some(n) = t.strip_prefix("sample:") {
            match n.parse::<u64>() {
                Ok(n) if n >= 1 => return Ok(TraceMode::Sample(n)),
                _ => bail!("bad sample rate in FLEXOR_TRACE: {s:?} (want sample:N, N ≥ 1)"),
            }
        }
        bail!("bad FLEXOR_TRACE value {s:?} (want off | sample:N | all)")
    }

    /// Human-readable form, mirroring the `FLEXOR_TRACE` syntax.
    pub fn label(&self) -> String {
        match self {
            TraceMode::Off => "off".to_string(),
            TraceMode::Sample(n) => format!("sample:{n}"),
            TraceMode::All => "all".to_string(),
        }
    }
}

static ENV_MODE: OnceLock<TraceMode> = OnceLock::new();

/// The process-wide mode from `FLEXOR_TRACE`, parsed once (default
/// [`TraceMode::Off`]; a malformed value logs a warning and stays off).
pub fn env_mode() -> TraceMode {
    *ENV_MODE.get_or_init(|| match std::env::var("FLEXOR_TRACE") {
        Ok(v) => TraceMode::parse(&v).unwrap_or_else(|e| {
            log(Level::Warn, "bad_flexor_trace", &[("error", Json::str(e.to_string()))]);
            TraceMode::Off
        }),
        Err(_) => TraceMode::Off,
    })
}

static SAMPLE_COUNTER: AtomicU64 = AtomicU64::new(0);

fn sampled(mode: TraceMode) -> bool {
    match mode {
        TraceMode::Off => false,
        TraceMode::All => true,
        TraceMode::Sample(n) => {
            SAMPLE_COUNTER.fetch_add(1, Ordering::Relaxed) % n.max(1) == 0
        }
    }
}

// ---- scopes -----------------------------------------------------------------

struct ScopeCtx {
    profile: Option<Arc<Profile>>,
    layer: Option<Arc<str>>,
}

thread_local! {
    static SCOPE: RefCell<Option<ScopeCtx>> = const { RefCell::new(None) };
}

/// Count of live traced scopes across all threads; lets remote shard
/// workers (which don't share the scope's thread-local) cheaply decide
/// whether per-shard busy timing is worth the clock reads.
static ACTIVE_SCOPES: AtomicUsize = AtomicUsize::new(0);

/// RAII guard for one traced unit of work; see [`scope`].
pub struct ScopeGuard {
    active: bool,
    prev: Option<ScopeCtx>,
}

/// Open a scope under the process-wide [`env_mode`], attaching spans to
/// `profile` when sampled in. The serve worker opens one per forward.
pub fn scope(profile: Option<Arc<Profile>>) -> ScopeGuard {
    scope_with(env_mode(), profile)
}

/// Open a scope under an explicit mode (tests, `ServeConfig::trace`
/// override, and the `flexor profile` CLI — none of which may mutate
/// process-global state).
pub fn scope_with(mode: TraceMode, profile: Option<Arc<Profile>>) -> ScopeGuard {
    if !sampled(mode) {
        return ScopeGuard { active: false, prev: None };
    }
    let prev = SCOPE
        .with(|s| s.borrow_mut().replace(ScopeCtx { profile, layer: None }));
    ACTIVE_SCOPES.fetch_add(1, Ordering::Relaxed);
    ScopeGuard { active: true, prev }
}

impl Drop for ScopeGuard {
    fn drop(&mut self) {
        if self.active {
            ACTIVE_SCOPES.fetch_sub(1, Ordering::Relaxed);
            SCOPE.with(|s| *s.borrow_mut() = self.prev.take());
        }
    }
}

/// Whether the current thread is inside a traced scope (the guard fast
/// path; one thread-local read).
pub fn active() -> bool {
    SCOPE.with(|s| s.borrow().is_some())
}

/// Whether *any* thread currently holds a traced scope — the gate for
/// pool per-shard busy timing, which runs on threads that never see the
/// scope's thread-local.
pub fn pool_timing() -> bool {
    ACTIVE_SCOPES.load(Ordering::Relaxed) > 0
}

// ---- spans ------------------------------------------------------------------

/// Times one pipeline stage; records on drop. Obtained from [`span`].
pub struct SpanGuard {
    stage: &'static str,
    start: Instant,
}

/// Open a stage span (`im2col`, `gemm`, `binarize`, `xnor_gemm`,
/// `forward`, …). Returns `None` — at the cost of a single thread-local
/// read — when the current thread is not inside a traced scope.
pub fn span(stage: &'static str) -> Option<SpanGuard> {
    if !active() {
        return None;
    }
    Some(SpanGuard { stage, start: Instant::now() })
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        record(self.stage, self.start);
    }
}

/// Times one model layer and labels every stage span recorded while it
/// is alive. Obtained from [`layer_span`].
pub struct LayerGuard {
    start: Instant,
    prev: Option<Arc<str>>,
}

/// Open a layer span. The label closure (`q3:bitplane1@avx2`, `stem`,
/// `head`, …) only runs when the scope is traced, so label formatting
/// costs nothing when tracing is off. Stage spans opened underneath
/// inherit the label; on drop a `layer` stage span is recorded with the
/// layer's total time.
pub fn layer_span<F: FnOnce() -> String>(label: F) -> Option<LayerGuard> {
    if !active() {
        return None;
    }
    let l: Arc<str> = label().into();
    let prev = SCOPE.with(|s| {
        s.borrow_mut()
            .as_mut()
            .and_then(|ctx| std::mem::replace(&mut ctx.layer, Some(l)))
    });
    Some(LayerGuard { start: Instant::now(), prev })
}

impl Drop for LayerGuard {
    fn drop(&mut self) {
        // record first (while the layer label is still installed) …
        record("layer", self.start);
        // … then restore the enclosing layer, if any.
        SCOPE.with(|s| {
            if let Some(ctx) = s.borrow_mut().as_mut() {
                ctx.layer = self.prev.take();
            }
        });
    }
}

static PROCESS_START: OnceLock<Instant> = OnceLock::new();

fn process_start() -> Instant {
    *PROCESS_START.get_or_init(Instant::now)
}

fn record(stage: &'static str, start: Instant) {
    let dur_ns = start.elapsed().as_nanos() as u64;
    let start_ns = start.saturating_duration_since(process_start()).as_nanos() as u64;
    SCOPE.with(|s| {
        let b = s.borrow();
        let Some(ctx) = b.as_ref() else { return };
        let layer: &str = ctx.layer.as_deref().unwrap_or("");
        ring_push(SpanRec { stage, layer: layer.to_string(), start_ns, dur_ns });
        if let Some(p) = &ctx.profile {
            p.add(layer, stage, dur_ns);
        }
    });
}

// ---- per-thread ring buffers ------------------------------------------------

/// Ring capacity per thread: memory is bounded at
/// `threads × RING_CAPACITY × sizeof(SpanRec)` no matter how long the
/// process traces for (oldest spans are overwritten).
pub const RING_CAPACITY: usize = 4096;

/// One recorded span: which stage, under which layer label, when
/// (nanoseconds since the first span of the process), and for how long.
#[derive(Clone, Debug)]
pub struct SpanRec {
    /// Stage name (`layer`, `forward`, `im2col`, `gemm`, …).
    pub stage: &'static str,
    /// Enclosing layer label (`""` for top-level spans like `forward`).
    pub layer: String,
    /// Span start, ns relative to the process's first recorded span.
    pub start_ns: u64,
    /// Span duration in ns.
    pub dur_ns: u64,
}

struct Ring {
    slots: Mutex<RingInner>,
}

struct RingInner {
    buf: Vec<SpanRec>,
    next: usize,
    total: u64,
}

static RINGS: Mutex<Vec<Weak<Ring>>> = Mutex::new(Vec::new());

thread_local! {
    static RING: Arc<Ring> = register_ring();
}

fn register_ring() -> Arc<Ring> {
    let r = Arc::new(Ring {
        slots: Mutex::new(RingInner { buf: Vec::new(), next: 0, total: 0 }),
    });
    let mut rings = RINGS.lock().unwrap();
    rings.retain(|w| w.strong_count() > 0); // drop rings of exited threads
    rings.push(Arc::downgrade(&r));
    r
}

fn ring_push(rec: SpanRec) {
    RING.with(|r| {
        // Uncontended in steady state: only this thread pushes; readers
        // ([`recent_spans`]) are rare, so this lock is effectively free.
        let mut s = r.slots.lock().unwrap();
        s.total += 1;
        if s.buf.len() < RING_CAPACITY {
            s.buf.push(rec);
        } else {
            let n = s.next;
            s.buf[n] = rec;
        }
        s.next = (s.next + 1) % RING_CAPACITY;
    });
}

/// Snapshot the retained spans of every live thread's ring (unordered
/// across threads). Debugging aid; the aggregated view is [`Profile`].
pub fn recent_spans() -> Vec<SpanRec> {
    let rings: Vec<Arc<Ring>> =
        RINGS.lock().unwrap().iter().filter_map(Weak::upgrade).collect();
    let mut out = Vec::new();
    for r in rings {
        out.extend(r.slots.lock().unwrap().buf.iter().cloned());
    }
    out
}

/// (retained, total-ever-recorded) span counts for the calling thread's
/// ring — `retained ≤ RING_CAPACITY` is the memory bound the tests pin.
pub fn thread_ring_stats() -> (usize, u64) {
    RING.with(|r| {
        let s = r.slots.lock().unwrap();
        (s.buf.len(), s.total)
    })
}

// ---- profiles ---------------------------------------------------------------

#[derive(Clone, Copy, Default)]
struct Agg {
    count: u64,
    ns: u64,
}

#[derive(Default)]
struct ProfileInner {
    /// Layer labels in first-seen order (so `q0` prints before `q10`).
    order: Vec<String>,
    agg: BTreeMap<(String, &'static str), Agg>,
}

/// Aggregated span sink: `(layer, stage) → {count, total_ns}`. One per
/// served model (owned by its registry entry) plus ad-hoc instances in
/// the `flexor profile` CLI and tests.
#[derive(Default)]
pub struct Profile {
    forwards: AtomicU64,
    inner: Mutex<ProfileInner>,
}

/// One row of the aggregated profile table.
#[derive(Clone, Debug)]
pub struct ProfileRow {
    /// Layer label (`""` for top-level stages like `forward`).
    pub layer: String,
    /// Stage name within the layer (`layer` = the layer's own total).
    pub stage: String,
    /// Number of recorded spans.
    pub count: u64,
    /// Summed duration across those spans.
    pub total_ns: u64,
}

impl Profile {
    /// Empty profile.
    pub fn new() -> Profile {
        Profile::default()
    }

    fn add(&self, layer: &str, stage: &'static str, ns: u64) {
        if stage == "forward" {
            self.forwards.fetch_add(1, Ordering::Relaxed);
        }
        let mut i = self.inner.lock().unwrap();
        if stage == "layer" && !i.order.iter().any(|l| l == layer) {
            i.order.push(layer.to_string());
        }
        let e = i.agg.entry((layer.to_string(), stage)).or_default();
        e.count += 1;
        e.ns += ns;
    }

    /// How many `forward` spans have landed here (traced forwards).
    pub fn traced_forwards(&self) -> u64 {
        self.forwards.load(Ordering::Relaxed)
    }

    /// Flat rows in display order: layers first-seen first, each layer's
    /// own total (`stage == "layer"`) before its stage breakdown, then
    /// top-level stages (e.g. `forward`) at the end.
    pub fn rows(&self) -> Vec<ProfileRow> {
        let i = self.inner.lock().unwrap();
        let row = |layer: &str, stage: &'static str, a: Agg| ProfileRow {
            layer: layer.to_string(),
            stage: stage.to_string(),
            count: a.count,
            total_ns: a.ns,
        };
        let mut out = Vec::new();
        for layer in &i.order {
            if let Some(a) = i.agg.get(&(layer.clone(), "layer")) {
                out.push(row(layer, "layer", *a));
            }
            for ((l, stage), a) in i.agg.iter() {
                if l == layer && *stage != "layer" {
                    out.push(row(l, stage, *a));
                }
            }
        }
        for ((l, stage), a) in i.agg.iter() {
            if l.is_empty() {
                out.push(row(l, stage, *a));
            }
        }
        out
    }

    /// JSON for `GET /models/<name>/profile`: traced-forward count, the
    /// end-to-end `forward` aggregate, and the per-layer stage breakdown.
    pub fn to_json(&self) -> Json {
        let (order, agg) = {
            // copy out under the lock, format after release (same
            // discipline as `ServeMetrics::snapshot`)
            let i = self.inner.lock().unwrap();
            (i.order.clone(), i.agg.clone())
        };
        let ms = |ns: u64| ns as f64 / 1e6;
        let agg_json = |a: &Agg| {
            Json::obj(vec![
                ("count", Json::num(a.count as f64)),
                ("total_ms", Json::num(ms(a.ns))),
                (
                    "mean_us",
                    Json::num(if a.count == 0 {
                        0.0
                    } else {
                        a.ns as f64 / a.count as f64 / 1e3
                    }),
                ),
            ])
        };
        let layers = Json::arr(order.iter().map(|layer| {
            let mut o = Json::obj(vec![("layer", Json::str(layer.clone()))]);
            if let Some(a) = agg.get(&(layer.clone(), "layer")) {
                o.set("count", Json::num(a.count as f64));
                o.set("total_ms", Json::num(ms(a.ns)));
            }
            o.set(
                "stages",
                Json::arr(agg.iter().filter(|((l, s), _)| l == layer && *s != "layer").map(
                    |((_, s), a)| {
                        let mut so = agg_json(a);
                        so.set("stage", Json::str(*s));
                        so
                    },
                )),
            );
            o
        }));
        let mut out = Json::obj(vec![
            ("traced_forwards", Json::num(self.traced_forwards() as f64)),
            ("layers", layers),
        ]);
        if let Some(f) = agg.get(&(String::new(), "forward")) {
            out.set("forward", agg_json(f));
        }
        out
    }
}

// ---- request ids ------------------------------------------------------------

static RID_SEED: OnceLock<u64> = OnceLock::new();
static RID_COUNTER: AtomicU64 = AtomicU64::new(0);

/// Fresh request id: a per-process prefix (boot-time derived) plus a
/// monotone counter — unique within and across typical restarts, cheap,
/// and dependency-free.
pub fn next_request_id() -> String {
    let seed = *RID_SEED.get_or_init(|| {
        let t = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .unwrap_or_default()
            .as_nanos() as u64;
        t ^ (std::process::id() as u64) << 32
    });
    let n = RID_COUNTER.fetch_add(1, Ordering::Relaxed);
    format!("{:08x}-{:04x}", (seed >> 16) as u32, n & 0xffff)
}

// ---- structured logger ------------------------------------------------------

/// Log severity, most severe first. `FLEXOR_LOG` picks the threshold.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    /// Unrecoverable request/server failures.
    Error,
    /// Degraded-but-serving conditions (rejections, slow requests).
    Warn,
    /// Lifecycle events (startup, shutdown). The default threshold.
    Info,
    /// Per-request chatter.
    Debug,
}

impl Level {
    /// Lower-case name as emitted in the `level` field.
    pub fn label(&self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }

    /// Parse a `FLEXOR_LOG` value.
    pub fn parse(s: &str) -> Option<Level> {
        match s.trim().to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            _ => None,
        }
    }
}

static ENV_LEVEL: OnceLock<Level> = OnceLock::new();

fn env_level() -> Level {
    *ENV_LEVEL.get_or_init(|| {
        std::env::var("FLEXOR_LOG").ok().and_then(|v| Level::parse(&v)).unwrap_or(Level::Info)
    })
}

/// Whether `level` passes the `FLEXOR_LOG` threshold.
pub fn log_enabled(level: Level) -> bool {
    level <= env_level()
}

/// Emit one structured log line (a JSON object on stderr):
/// `{"ts_ms":…,"level":…,"event":…,…fields}`. No-op below the threshold.
pub fn log(level: Level, event: &str, fields: &[(&str, Json)]) {
    if !log_enabled(level) {
        return;
    }
    let ts_ms = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .unwrap_or_default()
        .as_millis() as f64;
    let mut o = Json::obj(vec![
        ("ts_ms", Json::num(ts_ms)),
        ("level", Json::str(level.label())),
        ("event", Json::str(event)),
    ]);
    for (k, v) in fields {
        o.set(k, v.clone());
    }
    eprintln!("{o}");
}

/// Best-effort text of a `catch_unwind` payload: `panic!` with a string
/// literal or a formatted message covers essentially every panic in
/// this codebase (asserts included); anything else gets a placeholder.
pub fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_parsing() {
        assert_eq!(TraceMode::parse("off").unwrap(), TraceMode::Off);
        assert_eq!(TraceMode::parse("ALL").unwrap(), TraceMode::All);
        assert_eq!(TraceMode::parse(" sample:8 ").unwrap(), TraceMode::Sample(8));
        assert!(TraceMode::parse("sample:0").is_err());
        assert!(TraceMode::parse("sometimes").is_err());
        assert_eq!(TraceMode::parse("sample:3").unwrap().label(), "sample:3");
    }

    #[test]
    fn spans_are_inert_outside_a_scope() {
        assert!(!active());
        assert!(span("gemm").is_none());
        assert!(layer_span(|| unreachable!("label must not be built")).is_none());
    }

    #[test]
    fn spans_record_into_profile_with_layer_labels() {
        let p = Arc::new(Profile::new());
        {
            let _t = scope_with(TraceMode::All, Some(p.clone()));
            assert!(active());
            let _f = span("forward");
            {
                let _l = layer_span(|| "q0:dense".to_string()).unwrap();
                let s = span("gemm").unwrap();
                drop(s);
                let s = span("gemm").unwrap();
                drop(s);
            }
            {
                let _l = layer_span(|| "q1:bitplane1".to_string()).unwrap();
                drop(span("xnor_gemm"));
            }
        }
        assert!(!active());
        assert_eq!(p.traced_forwards(), 1);
        let rows = p.rows();
        let find = |layer: &str, stage: &str| {
            rows.iter().find(|r| r.layer == layer && r.stage == stage).cloned()
        };
        assert_eq!(find("q0:dense", "gemm").unwrap().count, 2);
        assert_eq!(find("q0:dense", "layer").unwrap().count, 1);
        assert_eq!(find("q1:bitplane1", "xnor_gemm").unwrap().count, 1);
        assert_eq!(find("", "forward").unwrap().count, 1);
        // layer order is first-seen, not lexicographic
        let order: Vec<&str> = rows
            .iter()
            .filter(|r| r.stage == "layer")
            .map(|r| r.layer.as_str())
            .collect();
        assert_eq!(order, vec!["q0:dense", "q1:bitplane1"]);
        // JSON shape
        let j = p.to_json();
        assert_eq!(j.get("traced_forwards").as_usize(), Some(1));
        assert_eq!(j.get("layers").at(0).get("layer").as_str(), Some("q0:dense"));
        assert!(j.get("forward").get("total_ms").as_f64().unwrap() >= 0.0);
    }

    #[test]
    fn sampling_traces_every_nth_scope() {
        let p = Arc::new(Profile::new());
        let mut traced = 0;
        for _ in 0..40 {
            let t = scope_with(TraceMode::Sample(4), Some(p.clone()));
            if active() {
                traced += 1;
            }
            drop(t);
        }
        // the shared global counter may be offset by other tests, but the
        // rate must hold
        assert_eq!(traced, 10, "sample:4 should trace 10 of 40 scopes");
        assert!(!active());
    }

    /// Satellite: the per-thread ring never exceeds its bound no matter
    /// how many spans a sustained traced load records.
    #[test]
    fn ring_buffer_stays_bounded_under_sustained_load() {
        let _t = scope_with(TraceMode::All, None);
        let n = 3 * RING_CAPACITY;
        for _ in 0..n {
            drop(span("gemm"));
        }
        let (retained, total) = thread_ring_stats();
        assert!(retained <= RING_CAPACITY, "ring overflowed: {retained}");
        assert!(total >= n as u64, "spans were lost before the ring: {total}");
        assert!(!recent_spans().is_empty());
    }

    #[test]
    fn nested_scopes_restore_previous_context() {
        let outer = Arc::new(Profile::new());
        let inner = Arc::new(Profile::new());
        let _a = scope_with(TraceMode::All, Some(outer.clone()));
        {
            let _b = scope_with(TraceMode::All, Some(inner.clone()));
            drop(span("forward"));
        }
        drop(span("forward"));
        drop(_a);
        assert_eq!(inner.traced_forwards(), 1);
        assert_eq!(outer.traced_forwards(), 1);
        assert!(!active());
    }

    #[test]
    fn request_ids_are_unique_and_short() {
        let a = next_request_id();
        let b = next_request_id();
        assert_ne!(a, b);
        assert!(a.len() <= 16, "{a}");
    }

    #[test]
    fn levels_order_and_parse() {
        assert!(Level::Error < Level::Debug);
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse("nope"), None);
        // emitting below-threshold must be a cheap no-op, not a panic
        log(Level::Debug, "test_event", &[("k", Json::str("v"))]);
    }
}
