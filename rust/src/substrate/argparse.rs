//! CLI argument parsing substrate (clap is unavailable offline).
//!
//! Declarative flags with typed accessors, `--help` generation, positional
//! arguments and subcommand support — what the `flexor` launcher and the
//! example/bench binaries need.

use std::collections::BTreeMap;

/// One declared flag.
#[derive(Clone, Debug)]
struct FlagSpec {
    name: String,
    help: String,
    default: Option<String>,
    takes_value: bool,
}

/// Declarative argument parser.
///
/// ```
/// use flexor::substrate::argparse::Args;
/// let a = Args::new("demo", "demo tool")
///     .flag("steps", "number of steps", Some("100"))
///     .switch("verbose", "chatty output")
///     .positional("config", "path to config")
///     .parse_from(vec!["--steps".into(), "5".into(), "cfg.json".into()])
///     .unwrap();
/// assert_eq!(a.get_usize("steps"), 5);
/// assert!(!a.get_bool("verbose"));
/// assert_eq!(a.pos(0).unwrap(), "cfg.json");
/// ```
#[derive(Debug)]
pub struct Args {
    prog: String,
    about: String,
    flags: Vec<FlagSpec>,
    positionals: Vec<(String, String)>,
    values: BTreeMap<String, String>,
    switches: BTreeMap<String, bool>,
    pos_values: Vec<String>,
}

impl Args {
    pub fn new(prog: &str, about: &str) -> Self {
        Args {
            prog: prog.to_string(),
            about: about.to_string(),
            flags: Vec::new(),
            positionals: Vec::new(),
            values: BTreeMap::new(),
            switches: BTreeMap::new(),
            pos_values: Vec::new(),
        }
    }

    /// A `--name value` flag with optional default.
    pub fn flag(mut self, name: &str, help: &str, default: Option<&str>) -> Self {
        self.flags.push(FlagSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: default.map(str::to_string),
            takes_value: true,
        });
        self
    }

    /// A boolean `--name` switch (defaults to false).
    pub fn switch(mut self, name: &str, help: &str) -> Self {
        self.flags.push(FlagSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            takes_value: false,
        });
        self
    }

    /// A required positional argument.
    pub fn positional(mut self, name: &str, help: &str) -> Self {
        self.positionals.push((name.to_string(), help.to_string()));
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {}", self.prog, self.about, self.prog);
        for (p, _) in &self.positionals {
            s.push_str(&format!(" <{p}>"));
        }
        s.push_str(" [FLAGS]\n\nFLAGS:\n");
        for f in &self.flags {
            let arg = if f.takes_value {
                format!("--{} <v>", f.name)
            } else {
                format!("--{}", f.name)
            };
            let def = f
                .default
                .as_ref()
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("  {arg:24} {}{def}\n", f.help));
        }
        for (p, h) in &self.positionals {
            s.push_str(&format!("  <{p}>{:20} {h}\n", ""));
        }
        s
    }

    /// Parse from an explicit vector (tests) — `--help` returns Err(usage).
    pub fn parse_from(mut self, argv: Vec<String>) -> Result<Args, String> {
        // seed defaults
        for f in &self.flags {
            if let Some(d) = &f.default {
                self.values.insert(f.name.clone(), d.clone());
            }
            if !f.takes_value {
                self.switches.insert(f.name.clone(), false);
            }
        }
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if tok == "--help" || tok == "-h" {
                return Err(self.usage());
            }
            if let Some(name) = tok.strip_prefix("--") {
                // --name=value form
                let (name, inline) = match name.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (name, None),
                };
                let spec = self
                    .flags
                    .iter()
                    .find(|f| f.name == name)
                    .ok_or_else(|| format!("unknown flag --{name}\n\n{}", self.usage()))?
                    .clone();
                if spec.takes_value {
                    let v = match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| format!("flag --{name} needs a value"))?,
                    };
                    self.values.insert(spec.name, v);
                } else {
                    if inline.is_some() {
                        return Err(format!("switch --{name} takes no value"));
                    }
                    self.switches.insert(spec.name, true);
                }
            } else {
                self.pos_values.push(tok);
            }
        }
        if self.pos_values.len() < self.positionals.len() {
            return Err(format!(
                "missing positional <{}>\n\n{}",
                self.positionals[self.pos_values.len()].0,
                self.usage()
            ));
        }
        Ok(self)
    }

    /// Parse the process arguments; prints usage and exits on --help/error.
    pub fn parse(self) -> Args {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        match self.parse_from(argv) {
            Ok(a) => a,
            Err(msg) => {
                eprintln!("{msg}");
                std::process::exit(if msg.contains("USAGE") { 0 } else { 2 });
            }
        }
    }

    // ---- typed accessors (panic on undeclared flags: programmer error) ------

    pub fn get(&self, name: &str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("flag --{name} has no value"))
    }

    pub fn get_opt(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(String::as_str)
    }

    pub fn get_bool(&self, name: &str) -> bool {
        *self
            .switches
            .get(name)
            .unwrap_or_else(|| panic!("switch --{name} not declared"))
    }

    pub fn get_usize(&self, name: &str) -> usize {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} expects an integer"))
    }

    pub fn get_u64(&self, name: &str) -> u64 {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} expects an integer"))
    }

    pub fn get_f32(&self, name: &str) -> f32 {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} expects a float"))
    }

    pub fn pos(&self, i: usize) -> Option<&str> {
        self.pos_values.get(i).map(String::as_str)
    }

    pub fn positionals(&self) -> &[String] {
        &self.pos_values
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo() -> Args {
        Args::new("t", "test")
            .flag("steps", "steps", Some("10"))
            .flag("name", "a name", None)
            .switch("fast", "go fast")
            .positional("input", "input file")
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let a = demo().parse_from(sv(&["in.txt"])).unwrap();
        assert_eq!(a.get_usize("steps"), 10);
        assert!(!a.get_bool("fast"));
        let a = demo()
            .parse_from(sv(&["--steps", "42", "--fast", "in.txt"]))
            .unwrap();
        assert_eq!(a.get_usize("steps"), 42);
        assert!(a.get_bool("fast"));
        assert_eq!(a.pos(0), Some("in.txt"));
    }

    #[test]
    fn equals_form() {
        let a = demo().parse_from(sv(&["--steps=7", "x"])).unwrap();
        assert_eq!(a.get_usize("steps"), 7);
    }

    #[test]
    fn optional_flag_absent() {
        let a = demo().parse_from(sv(&["x"])).unwrap();
        assert_eq!(a.get_opt("name"), None);
    }

    #[test]
    fn unknown_flag_rejected() {
        assert!(demo().parse_from(sv(&["--nope", "x"])).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(demo().parse_from(sv(&["x", "--steps"])).is_err());
    }

    #[test]
    fn missing_positional_rejected() {
        let e = demo().parse_from(sv(&[])).unwrap_err();
        assert!(e.contains("missing positional <input>"));
    }

    #[test]
    fn help_returns_usage() {
        let e = demo().parse_from(sv(&["--help"])).unwrap_err();
        assert!(e.contains("USAGE"));
        assert!(e.contains("--steps"));
    }

    #[test]
    fn switch_with_value_rejected() {
        assert!(demo().parse_from(sv(&["--fast=1", "x"])).is_err());
    }

    #[test]
    fn f32_parsing() {
        let a = Args::new("t", "")
            .flag("lr", "", Some("0.1"))
            .parse_from(vec![])
            .unwrap();
        assert_eq!(a.get_f32("lr"), 0.1);
    }
}
