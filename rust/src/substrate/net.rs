//! Nonblocking readiness primitives built in-repo (DESIGN.md §5: no
//! external crates, so the mio-shaped surface the event-loop front-end
//! needs — an OS readiness queue plus a cross-thread waker — is vendored
//! here directly on top of raw syscalls).
//!
//! The [`Poller`] wraps `epoll(7)` on Linux and `poll(2)` on other unix
//! platforms behind one level-triggered API keyed by caller-chosen
//! `u64` tokens. The [`Waker`] is a loopback TCP socketpair: writing a
//! byte to one end makes the other end readable, which wakes a blocked
//! [`Poller::wait`] without any non-std `pipe()`/`eventfd()` bindings.
//! Both are deliberately tiny: the HTTP event loop in `serve/http.rs`
//! owns all buffering, timeout, and state-machine policy; this module
//! only answers "which sockets are ready right now?".

use std::collections::HashMap;
use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::io::{AsRawFd, RawFd};

/// Readiness interest for a registered descriptor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Interest {
    /// Wake when the descriptor is readable (or closed/errored).
    Read,
    /// Wake when the descriptor is writable (or closed/errored).
    Write,
    /// Wake on either direction.
    ReadWrite,
}

impl Interest {
    fn wants_read(self) -> bool {
        matches!(self, Interest::Read | Interest::ReadWrite)
    }

    fn wants_write(self) -> bool {
        matches!(self, Interest::Write | Interest::ReadWrite)
    }
}

/// One readiness report from [`Poller::wait`].
#[derive(Clone, Copy, Debug)]
pub struct Event {
    /// Caller-chosen token passed at registration.
    pub token: u64,
    /// Descriptor has bytes to read (or a pending accept).
    pub readable: bool,
    /// Descriptor can accept more bytes.
    pub writable: bool,
    /// Peer hung up or the descriptor errored; the owner should close.
    pub closed: bool,
}

// ---------------------------------------------------------------------------
// Linux: epoll via direct syscall declarations (no libc crate).
// ---------------------------------------------------------------------------

#[cfg(target_os = "linux")]
mod sys {
    use super::*;

    pub const EPOLLIN: u32 = 0x001;
    pub const EPOLLOUT: u32 = 0x004;
    pub const EPOLLERR: u32 = 0x008;
    pub const EPOLLHUP: u32 = 0x010;
    pub const EPOLLRDHUP: u32 = 0x2000;

    const EPOLL_CTL_ADD: i32 = 1;
    const EPOLL_CTL_DEL: i32 = 2;
    const EPOLL_CTL_MOD: i32 = 3;
    const EPOLL_CLOEXEC: i32 = 0o2000000;

    /// Kernel `struct epoll_event` — packed on x86-64 by ABI contract.
    #[repr(C)]
    #[cfg_attr(target_arch = "x86_64", repr(packed))]
    #[derive(Clone, Copy)]
    pub struct EpollEvent {
        pub events: u32,
        pub data: u64,
    }

    extern "C" {
        fn epoll_create1(flags: i32) -> i32;
        fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
        fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout: i32) -> i32;
        fn close(fd: i32) -> i32;
    }

    /// Level-triggered epoll instance. Level triggering keeps the event
    /// loop simple and loss-proof: a socket with unconsumed bytes keeps
    /// reporting readable, so suspending a connection is just "skip the
    /// read this tick" with no re-arm bookkeeping.
    pub struct Poller {
        epfd: i32,
        buf: Vec<EpollEvent>,
    }

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            // Safety: plain syscall; a negative return is reported via errno.
            let epfd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
            if epfd < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(Poller { epfd, buf: vec![EpollEvent { events: 0, data: 0 }; 1024] })
        }

        fn ctl(&self, op: i32, fd: RawFd, interest: Option<Interest>, token: u64) -> io::Result<()> {
            let mut ev = EpollEvent { events: 0, data: token };
            if let Some(i) = interest {
                if i.wants_read() {
                    ev.events |= EPOLLIN | EPOLLRDHUP;
                }
                if i.wants_write() {
                    ev.events |= EPOLLOUT;
                }
            }
            // Safety: `ev` outlives the call; the kernel copies it.
            let rc = unsafe { epoll_ctl(self.epfd, op, fd, &mut ev) };
            if rc < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(())
        }

        pub fn register(&mut self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
            self.ctl(EPOLL_CTL_ADD, fd, Some(interest), token)
        }

        pub fn reregister(&mut self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
            self.ctl(EPOLL_CTL_MOD, fd, Some(interest), token)
        }

        pub fn deregister(&mut self, fd: RawFd) -> io::Result<()> {
            self.ctl(EPOLL_CTL_DEL, fd, None, 0)
        }

        pub fn wait(&mut self, timeout_ms: i32, out: &mut Vec<Event>) -> io::Result<()> {
            out.clear();
            let n = loop {
                // Safety: `buf` stays alive and sized for the whole call.
                let rc = unsafe {
                    epoll_wait(self.epfd, self.buf.as_mut_ptr(), self.buf.len() as i32, timeout_ms)
                };
                if rc >= 0 {
                    break rc as usize;
                }
                let err = io::Error::last_os_error();
                if err.kind() != io::ErrorKind::Interrupted {
                    return Err(err);
                }
            };
            for ev in &self.buf[..n] {
                let bits = ev.events;
                out.push(Event {
                    token: ev.data,
                    readable: bits & (EPOLLIN | EPOLLRDHUP | EPOLLHUP) != 0,
                    writable: bits & EPOLLOUT != 0,
                    closed: bits & (EPOLLERR | EPOLLHUP | EPOLLRDHUP) != 0,
                });
            }
            Ok(())
        }
    }

    impl Drop for Poller {
        fn drop(&mut self) {
            // Safety: fd is owned by this instance and closed exactly once.
            unsafe { close(self.epfd) };
        }
    }
}

// ---------------------------------------------------------------------------
// Other unix: poll(2) fallback. Same level-triggered semantics, O(n) scan.
// ---------------------------------------------------------------------------

#[cfg(all(unix, not(target_os = "linux")))]
mod sys {
    use super::*;

    const POLLIN: i16 = 0x001;
    const POLLOUT: i16 = 0x004;
    const POLLERR: i16 = 0x008;
    const POLLHUP: i16 = 0x010;

    #[repr(C)]
    struct PollFd {
        fd: i32,
        events: i16,
        revents: i16,
    }

    extern "C" {
        fn poll(fds: *mut PollFd, nfds: u64, timeout: i32) -> i32;
    }

    pub struct Poller {
        entries: Vec<(RawFd, u64, Interest)>,
    }

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            Ok(Poller { entries: Vec::new() })
        }

        pub fn register(&mut self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
            self.entries.push((fd, token, interest));
            Ok(())
        }

        pub fn reregister(&mut self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
            for e in &mut self.entries {
                if e.0 == fd {
                    *e = (fd, token, interest);
                    return Ok(());
                }
            }
            Err(io::Error::new(io::ErrorKind::NotFound, "fd not registered"))
        }

        pub fn deregister(&mut self, fd: RawFd) -> io::Result<()> {
            self.entries.retain(|e| e.0 != fd);
            Ok(())
        }

        pub fn wait(&mut self, timeout_ms: i32, out: &mut Vec<Event>) -> io::Result<()> {
            out.clear();
            let mut fds: Vec<PollFd> = self
                .entries
                .iter()
                .map(|&(fd, _, interest)| {
                    let mut events = 0i16;
                    if interest.wants_read() {
                        events |= POLLIN;
                    }
                    if interest.wants_write() {
                        events |= POLLOUT;
                    }
                    PollFd { fd, events, revents: 0 }
                })
                .collect();
            let n = loop {
                // Safety: `fds` is a live, correctly-sized C-layout array.
                let rc = unsafe { poll(fds.as_mut_ptr(), fds.len() as u64, timeout_ms) };
                if rc >= 0 {
                    break rc;
                }
                let err = io::Error::last_os_error();
                if err.kind() != io::ErrorKind::Interrupted {
                    return Err(err);
                }
            };
            if n == 0 {
                return Ok(());
            }
            for (pfd, &(_, token, _)) in fds.iter().zip(self.entries.iter()) {
                let r = pfd.revents;
                if r == 0 {
                    continue;
                }
                out.push(Event {
                    token,
                    readable: r & (POLLIN | POLLHUP) != 0,
                    writable: r & POLLOUT != 0,
                    closed: r & (POLLERR | POLLHUP) != 0,
                });
            }
            Ok(())
        }
    }
}

// ---------------------------------------------------------------------------
// Non-unix: compile but fail at runtime (the threaded front-end remains
// available via FLEXOR_HTTP_MODE=threads).
// ---------------------------------------------------------------------------

#[cfg(not(unix))]
mod sys {
    use super::*;

    pub struct Poller;

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            Err(io::Error::new(
                io::ErrorKind::Unsupported,
                "event-loop poller requires unix; use FLEXOR_HTTP_MODE=threads",
            ))
        }

        pub fn register(&mut self, _fd: RawFd, _token: u64, _i: Interest) -> io::Result<()> {
            unreachable!("Poller cannot be constructed on this platform")
        }

        pub fn reregister(&mut self, _fd: RawFd, _token: u64, _i: Interest) -> io::Result<()> {
            unreachable!("Poller cannot be constructed on this platform")
        }

        pub fn deregister(&mut self, _fd: RawFd) -> io::Result<()> {
            unreachable!("Poller cannot be constructed on this platform")
        }

        pub fn wait(&mut self, _timeout_ms: i32, _out: &mut Vec<Event>) -> io::Result<()> {
            unreachable!("Poller cannot be constructed on this platform")
        }
    }
}

/// OS readiness queue: register descriptors under tokens, then block in
/// [`wait`](Poller::wait) until any become ready. Level-triggered on
/// every backend — an unconsumed readable socket reports again next
/// tick, which is exactly what connection-suspension backpressure needs.
pub struct Poller {
    inner: sys::Poller,
    /// Interest book-keeping so callers can `set_interest` idempotently
    /// without tracking registration state themselves.
    interests: HashMap<RawFd, Interest>,
}

impl Poller {
    pub fn new() -> io::Result<Poller> {
        Ok(Poller { inner: sys::Poller::new()?, interests: HashMap::new() })
    }

    /// Register `fd` under `token`. Registering an already-registered fd
    /// updates its token and interest instead of erroring.
    pub fn register(&mut self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
        if self.interests.contains_key(&fd) {
            self.inner.reregister(fd, token, interest)?;
        } else {
            self.inner.register(fd, token, interest)?;
        }
        self.interests.insert(fd, interest);
        Ok(())
    }

    /// Change the interest set of a registered fd; no-op when unchanged.
    pub fn set_interest(&mut self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
        if self.interests.get(&fd) == Some(&interest) {
            return Ok(());
        }
        self.inner.reregister(fd, token, interest)?;
        self.interests.insert(fd, interest);
        Ok(())
    }

    /// Remove `fd` from the readiness set (call before closing it).
    pub fn deregister(&mut self, fd: RawFd) -> io::Result<()> {
        if self.interests.remove(&fd).is_some() {
            self.inner.deregister(fd)?;
        }
        Ok(())
    }

    /// Block up to `timeout_ms` (negative = forever, 0 = poll) and fill
    /// `out` with ready descriptors. Spurious wakeups (empty `out`) are
    /// legal; EINTR is retried internally.
    pub fn wait(&mut self, timeout_ms: i32, out: &mut Vec<Event>) -> io::Result<()> {
        self.inner.wait(timeout_ms, out)
    }
}

/// Cross-thread wakeup for a blocked [`Poller::wait`], built from a
/// loopback TCP socketpair so it needs nothing beyond std. The read end
/// is registered in the poller under a reserved token; any thread holding
/// a [`WakeHandle`] can make it readable.
pub struct Waker {
    reader: TcpStream,
    writer: TcpStream,
}

/// Cheap clonable sender half of a [`Waker`].
#[derive(Clone)]
pub struct WakeHandle {
    writer: TcpStream,
}

impl Waker {
    pub fn new() -> io::Result<Waker> {
        // Loopback socketpair: connect to a throwaway ephemeral listener.
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let writer = TcpStream::connect(listener.local_addr()?)?;
        let (reader, _) = listener.accept()?;
        reader.set_nonblocking(true)?;
        writer.set_nonblocking(true)?;
        writer.set_nodelay(true)?;
        Ok(Waker { reader, writer })
    }

    /// Descriptor to register for `Interest::Read` in the poller.
    pub fn fd(&self) -> RawFd {
        self.reader.as_raw_fd()
    }

    /// Sender half; clone freely across threads.
    pub fn handle(&self) -> WakeHandle {
        WakeHandle { writer: self.writer.try_clone().expect("waker clone") }
    }

    /// Drain pending wake bytes after the poller reports the waker fd
    /// readable, so level-triggered polling does not spin.
    pub fn drain(&mut self) {
        let mut buf = [0u8; 64];
        loop {
            match self.reader.read(&mut buf) {
                Ok(0) => break,
                Ok(_) => continue,
                Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(_) => break,
            }
        }
    }
}

impl WakeHandle {
    /// Make the poller's waker fd readable. Best-effort: a full socket
    /// buffer already guarantees a pending wakeup, and errors mean the
    /// loop is gone, so both are ignored.
    pub fn wake(&self) {
        let mut w = &self.writer;
        let _ = w.write(&[1u8]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::{Duration, Instant};

    #[test]
    fn waker_wakes_blocked_poll() {
        let mut poller = Poller::new().unwrap();
        let mut waker = Waker::new().unwrap();
        poller.register(waker.fd(), 7, Interest::Read).unwrap();

        let handle = waker.handle();
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            handle.wake();
        });

        let start = Instant::now();
        let mut events = Vec::new();
        // Generous ceiling: the wake must arrive long before 5 s.
        poller.wait(5_000, &mut events).unwrap();
        assert!(start.elapsed() < Duration::from_secs(4), "poll did not wake early");
        assert!(events.iter().any(|e| e.token == 7 && e.readable), "waker event missing");
        waker.drain();
        t.join().unwrap();
    }

    #[test]
    fn listener_readable_on_pending_accept() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        listener.set_nonblocking(true).unwrap();
        let addr = listener.local_addr().unwrap();
        let mut poller = Poller::new().unwrap();
        poller.register(listener.as_raw_fd(), 1, Interest::Read).unwrap();

        let mut events = Vec::new();
        poller.wait(0, &mut events).unwrap();
        assert!(events.is_empty(), "no pending accept yet");

        let _client = TcpStream::connect(addr).unwrap();
        let deadline = Instant::now() + Duration::from_secs(5);
        let mut saw = false;
        while Instant::now() < deadline {
            poller.wait(100, &mut events).unwrap();
            if events.iter().any(|e| e.token == 1 && e.readable) {
                saw = true;
                break;
            }
        }
        assert!(saw, "listener never reported readable");
        let (conn, _) = listener.accept().unwrap();
        drop(conn);
    }

    #[test]
    fn interest_switching_gates_write_events() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        client.set_nonblocking(true).unwrap();
        let (server_side, _) = listener.accept().unwrap();

        let mut poller = Poller::new().unwrap();
        let fd = client.as_raw_fd();
        poller.register(fd, 3, Interest::Read).unwrap();

        // Idle socket with read-only interest: nothing to report.
        let mut events = Vec::new();
        poller.wait(50, &mut events).unwrap();
        assert!(!events.iter().any(|e| e.token == 3), "spurious read event");

        // Add write interest: an idle TCP socket is immediately writable.
        poller.set_interest(fd, 3, Interest::ReadWrite).unwrap();
        poller.wait(1_000, &mut events).unwrap();
        assert!(
            events.iter().any(|e| e.token == 3 && e.writable),
            "writable not reported after interest switch"
        );

        poller.deregister(fd).unwrap();
        drop(server_side);
    }
}
