//! Deterministic PRNG substrate: PCG32 (O'Neill 2014) plus distribution
//! helpers. Every stochastic component in the crate (data generators, M⊕
//! sampling, property tests, shuffling) draws from this so experiment runs
//! are exactly reproducible from a single `u64` seed.

/// PCG32 (XSH-RR variant): 64-bit state, 32-bit output.
///
/// Small, fast, and passes PractRand far beyond anything this crate needs.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Seed with a state and a stream id (any values are fine).
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience: stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Derive an independent child generator (for per-shard streams).
    pub fn fork(&mut self, stream: u64) -> Pcg32 {
        Pcg32::new(self.next_u64(), stream.wrapping_mul(0x9E3779B97F4A7C15) | 1)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in [0, 1) with f64 resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Unbiased uniform integer in [0, bound) (Lemire rejection).
    pub fn below(&mut self, bound: u32) -> u32 {
        assert!(bound > 0, "below(0)");
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let r = self.next_u32();
            let m = (r as u64) * (bound as u64);
            if (m as u32) >= threshold {
                return (m >> 32) as u32;
            }
        }
    }

    /// Uniform in [lo, hi).
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.next_f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.next_f64();
            let r = (-2.0 * u1.ln()).sqrt();
            return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
        }
    }

    /// Bernoulli(p).
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u32) as usize;
            xs.swap(i, j);
        }
    }

    /// k distinct indices from [0, n) (partial Fisher–Yates).
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "choose_k: k={k} > n={n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below((n - i) as u32) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_by_seed() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
        let mut c = Pcg32::seeded(43);
        assert_ne!(a.next_u32(), c.next_u32());
    }

    #[test]
    fn streams_are_independent() {
        let mut a = Pcg32::new(1, 1);
        let mut b = Pcg32::new(1, 2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Pcg32::seeded(7);
        for _ in 0..10_000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut r = Pcg32::seeded(3);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "bucket skew: {counts:?}");
        }
    }

    #[test]
    #[should_panic(expected = "below(0)")]
    fn below_zero_panics() {
        Pcg32::seeded(0).below(0);
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seeded(11);
        let n = 200_000;
        let (mut s, mut s2) = (0f64, 0f64);
        for _ in 0..n {
            let x = r.normal() as f64;
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seeded(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn choose_k_distinct() {
        let mut r = Pcg32::seeded(9);
        for _ in 0..100 {
            let ks = r.choose_k(20, 5);
            let mut s = ks.clone();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), 5);
            assert!(ks.iter().all(|&i| i < 20));
        }
    }

    #[test]
    fn fork_diverges_from_parent() {
        let mut a = Pcg32::seeded(1);
        let mut child = a.fork(1);
        let same = (0..64).filter(|_| a.next_u32() == child.next_u32()).count();
        assert!(same < 4);
    }
}
