//! Persistent intra-op thread pool for data-parallel kernels.
//!
//! A job is a shard counter over `len` indices: every participating thread
//! (the pool workers *and* the caller) grabs the next shard with a single
//! `fetch_add` until the counter is exhausted — no work stealing, no
//! per-shard allocation, no channel traffic. Callers block until every
//! shard has finished, so shard closures may borrow stack data; the
//! lifetime is erased internally and re-guaranteed by the completion wait.
//!
//! The pool composes with the serve worker pool (DESIGN.md §7): multiple
//! callers may submit jobs concurrently — jobs queue FIFO and idle workers
//! drain whichever job is at the front, while each caller always makes
//! progress on its own job. A forward pass therefore never deadlocks even
//! when every worker is busy elsewhere.
//!
//! Thread budget: `configure_global` (plumbed from `ServeConfig`) or the
//! `FLEXOR_THREADS` env var, falling back to `available_parallelism`.

use std::any::Any;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread;
use std::time::Instant;

use super::trace;

/// One data-parallel job: `len` independent shards over an erased closure.
struct Job {
    /// Type-erased `&(dyn Fn(usize) + Sync)` borrowed from the caller's
    /// stack. Valid until `completed == len`: `run` does not return before
    /// that, and no thread dereferences `f` after its `fetch_add` on
    /// `next` returns an index `>= len`.
    f: *const (dyn Fn(usize) + Sync),
    next: AtomicUsize,
    len: usize,
    completed: AtomicUsize,
    panicked: AtomicBool,
    /// First shard panic's payload, re-raised on the caller so the real
    /// message (assert text, index info) survives the pool boundary.
    payload: Mutex<Option<Box<dyn Any + Send + 'static>>>,
    done: Mutex<bool>,
    done_cv: Condvar,
    /// When the job was queued; the thread that claims shard 0 records
    /// the submit→first-claim gap as the job's queue wait.
    submitted: Instant,
}

unsafe impl Send for Job {}
unsafe impl Sync for Job {}

struct Shared {
    queue: Mutex<Vec<Arc<Job>>>,
    work_cv: Condvar,
    shutdown: AtomicBool,
    counters: PoolCounters,
}

/// Always-on cumulative pool counters (a handful of relaxed atomic adds
/// per *job*, not per shard — the shard path stays untouched unless a
/// traced scope is live, see [`trace::pool_timing`]).
struct PoolCounters {
    jobs: AtomicU64,
    shards: AtomicU64,
    /// Shards whose closure panicked (contained by `catch_unwind`,
    /// re-raised on the submitting thread after the job settles).
    panics: AtomicU64,
    /// Summed submit→first-claim gap across jobs (ns).
    job_wait_ns: AtomicU64,
    /// Per-compute-thread busy ns, only accumulated while a traced scope
    /// is live anywhere in the process. Slot 0 aggregates all callers;
    /// slots `1..threads` are the pool workers.
    busy_ns: Vec<AtomicU64>,
}

impl PoolCounters {
    fn new(threads: usize) -> PoolCounters {
        PoolCounters {
            jobs: AtomicU64::new(0),
            shards: AtomicU64::new(0),
            panics: AtomicU64::new(0),
            job_wait_ns: AtomicU64::new(0),
            busy_ns: (0..threads.max(1)).map(|_| AtomicU64::new(0)).collect(),
        }
    }
}

/// Point-in-time copy of a pool's counters (`/metrics` `"pool"` object).
#[derive(Clone, Debug, Default)]
pub struct PoolCountersSnapshot {
    /// Jobs submitted (one `run` call above the inline threshold, or one
    /// inline run).
    pub jobs: u64,
    /// Shards dispatched across all jobs.
    pub shards: u64,
    /// Shards that panicked (contained and re-raised, DESIGN.md §12).
    pub panics: u64,
    /// Summed submit→first-claim queue wait across jobs, ns.
    pub job_wait_ns: u64,
    /// Per-thread busy ns (slot 0 = callers, then workers); zeros unless
    /// tracing was live.
    pub busy_ns: Vec<u64>,
}

impl PoolCountersSnapshot {
    /// Total busy ns across all compute threads.
    pub fn busy_ns_total(&self) -> u64 {
        self.busy_ns.iter().sum()
    }
}

/// The pool. One instance per process is the normal mode ([`global`]);
/// tests build private pools to pin exact thread counts.
pub struct ThreadPool {
    shared: Arc<Shared>,
    handles: Vec<thread::JoinHandle<()>>,
    threads: usize,
}

impl ThreadPool {
    /// Pool with `threads` total compute threads (the caller counts as
    /// one, so `threads - 1` workers are spawned; `threads == 1` runs
    /// everything inline).
    pub fn new(threads: usize) -> ThreadPool {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(Vec::new()),
            work_cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            counters: PoolCounters::new(threads),
        });
        let handles = (1..threads)
            .map(|i| {
                let shared = shared.clone();
                thread::Builder::new()
                    .name(format!("flexor-pool-{i}"))
                    .spawn(move || worker_loop(&shared, i))
                    .expect("spawning pool worker")
            })
            .collect();
        ThreadPool { shared, handles, threads }
    }

    /// Total compute threads a job can shard across (workers + caller).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Snapshot the cumulative job/shard/wait/busy counters.
    pub fn counters(&self) -> PoolCountersSnapshot {
        let c = &self.shared.counters;
        PoolCountersSnapshot {
            jobs: c.jobs.load(Ordering::Relaxed),
            shards: c.shards.load(Ordering::Relaxed),
            panics: c.panics.load(Ordering::Relaxed),
            job_wait_ns: c.job_wait_ns.load(Ordering::Relaxed),
            busy_ns: c.busy_ns.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
        }
    }

    /// Run `f(0), f(1), …, f(len-1)` across the pool and the calling
    /// thread; returns when every index has completed. Panics (after all
    /// shards settle) if any shard panicked.
    pub fn run(&self, len: usize, f: &(dyn Fn(usize) + Sync)) {
        if len == 0 {
            return;
        }
        let c = &self.shared.counters;
        c.jobs.fetch_add(1, Ordering::Relaxed);
        c.shards.fetch_add(len as u64, Ordering::Relaxed);
        if self.threads == 1 || len == 1 {
            let t0 = trace::pool_timing().then(Instant::now);
            for i in 0..len {
                f(i);
            }
            if let Some(t0) = t0 {
                c.busy_ns[0].fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
            }
            return;
        }
        // Erase the closure's lifetime; see the safety note on `Job::f`.
        let f_static: *const (dyn Fn(usize) + Sync) =
            unsafe { std::mem::transmute(f) };
        let job = Arc::new(Job {
            f: f_static,
            next: AtomicUsize::new(0),
            len,
            completed: AtomicUsize::new(0),
            panicked: AtomicBool::new(false),
            payload: Mutex::new(None),
            done: Mutex::new(false),
            done_cv: Condvar::new(),
            submitted: Instant::now(),
        });
        self.shared.queue.lock().unwrap().push(job.clone());
        self.shared.work_cv.notify_all();

        run_shards(&job, c, 0);
        let mut done = job.done.lock().unwrap();
        while !*done {
            done = job.done_cv.wait(done).unwrap();
        }
        drop(done);
        if job.panicked.load(Ordering::Acquire) {
            match job.payload.lock().unwrap().take() {
                Some(p) => std::panic::resume_unwind(p),
                None => panic!("thread-pool shard panicked"),
            }
        }
    }

    /// Split `data` into `chunk` -sized runs and process them in parallel:
    /// `f(chunk_index, start_offset, chunk_slice)`. The disjointness of the
    /// chunks is what makes handing `&mut` slices to concurrent shards
    /// sound.
    pub fn run_chunks_mut<T, F>(&self, data: &mut [T], chunk: usize, f: F)
    where
        T: Send,
        F: Fn(usize, usize, &mut [T]) + Sync,
    {
        assert!(chunk > 0, "chunk size must be positive");
        let len = data.len();
        let n_chunks = len.div_ceil(chunk);
        let base = SendPtr(data.as_mut_ptr());
        self.run(n_chunks, &|ci| {
            let start = ci * chunk;
            let end = (start + chunk).min(len);
            // Safety: chunks [start, end) are pairwise disjoint across ci.
            let part = unsafe {
                std::slice::from_raw_parts_mut(base.0.add(start), end - start)
            };
            f(ci, start, part);
        });
    }
}

/// Raw-pointer wrapper so disjoint-chunk dispatch can cross the `Sync`
/// boundary of the shard closure. Shared with other data-parallel
/// kernels (e.g. `inference::bitslice::binarize`) that write disjoint
/// ranges of a second output buffer from inside a shard — keeping the
/// crate's unsafe Send/Sync surface in one place.
pub(crate) struct SendPtr<T>(pub(crate) *mut T);
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.work_cv.notify_all();
        for h in self.handles.drain(..) {
            h.join().ok();
        }
    }
}

fn worker_loop(shared: &Shared, slot: usize) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                // drop fully-dispatched jobs; their remaining shards are
                // finishing on the threads that claimed them
                q.retain(|j| j.next.load(Ordering::Relaxed) < j.len);
                if let Some(j) = q.first() {
                    break j.clone();
                }
                q = shared.work_cv.wait(q).unwrap();
            }
        };
        run_shards(&job, &shared.counters, slot);
    }
}

/// Claim and run shards of `job` until its counter is exhausted,
/// attributing busy time to `counters.busy_ns[slot]` while tracing is
/// live (one relaxed load per shard otherwise).
fn run_shards(job: &Job, counters: &PoolCounters, slot: usize) {
    let timing = trace::pool_timing();
    loop {
        let i = job.next.fetch_add(1, Ordering::Relaxed);
        if i >= job.len {
            return;
        }
        if i == 0 {
            counters
                .job_wait_ns
                .fetch_add(job.submitted.elapsed().as_nanos() as u64, Ordering::Relaxed);
        }
        let t0 = timing.then(Instant::now);
        // Safety: i < len, so the caller is still inside `run`.
        let f = unsafe { &*job.f };
        if let Err(p) = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(i))) {
            counters.panics.fetch_add(1, Ordering::Relaxed);
            let mut slot_p = job.payload.lock().unwrap();
            if slot_p.is_none() {
                *slot_p = Some(p);
            }
            drop(slot_p);
            job.panicked.store(true, Ordering::Release);
        }
        if let Some(t0) = t0 {
            counters.busy_ns[slot].fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        }
        if job.completed.fetch_add(1, Ordering::AcqRel) + 1 == job.len {
            let mut done = job.done.lock().unwrap();
            *done = true;
            job.done_cv.notify_all();
        }
    }
}

// ---- global pool ------------------------------------------------------------

static GLOBAL: OnceLock<ThreadPool> = OnceLock::new();
/// Thread count requested before the global pool is first used (0 = unset).
static REQUESTED: AtomicUsize = AtomicUsize::new(0);

/// Request a size for the process-wide pool. Takes effect only if the
/// pool has not been built yet (first `global()` call wins); returns
/// whether the request can still apply. `0` clears back to auto.
pub fn configure_global(threads: usize) -> bool {
    REQUESTED.store(threads, Ordering::SeqCst);
    GLOBAL.get().is_none()
}

/// Default thread budget: an explicit `configure_global` request wins,
/// else the `FLEXOR_THREADS` env var (standalone binaries), else
/// `available_parallelism`.
pub fn default_threads() -> usize {
    let req = REQUESTED.load(Ordering::SeqCst);
    if req > 0 {
        return req;
    }
    if let Ok(v) = std::env::var("FLEXOR_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n > 0 {
                return n;
            }
        }
    }
    thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// The process-wide pool, built on first use with [`default_threads`].
pub fn global() -> &'static ThreadPool {
    GLOBAL.get_or_init(|| ThreadPool::new(default_threads()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_every_index_exactly_once() {
        for threads in [1, 2, 4] {
            let pool = ThreadPool::new(threads);
            for len in [0usize, 1, 2, 7, 64, 1000] {
                let hits: Vec<AtomicUsize> =
                    (0..len).map(|_| AtomicUsize::new(0)).collect();
                pool.run(len, &|i| {
                    hits[i].fetch_add(1, Ordering::SeqCst);
                });
                assert!(
                    hits.iter().all(|h| h.load(Ordering::SeqCst) == 1),
                    "threads={threads} len={len}"
                );
            }
        }
    }

    #[test]
    fn parallel_sum_matches_serial() {
        let pool = ThreadPool::new(4);
        let acc = AtomicU64::new(0);
        pool.run(500, &|i| {
            acc.fetch_add(i as u64 * i as u64, Ordering::SeqCst);
        });
        let want: u64 = (0..500u64).map(|i| i * i).sum();
        assert_eq!(acc.load(Ordering::SeqCst), want);
    }

    #[test]
    fn chunked_mut_covers_disjointly() {
        let pool = ThreadPool::new(3);
        let mut data = vec![0u32; 103];
        pool.run_chunks_mut(&mut data, 10, |ci, start, part| {
            assert_eq!(start, ci * 10);
            for (o, v) in part.iter_mut().enumerate() {
                *v = (start + o) as u32;
            }
        });
        assert!(data.iter().enumerate().all(|(i, &v)| v == i as u32));
    }

    #[test]
    fn pool_is_reusable_and_concurrent_jobs_complete() {
        let pool = Arc::new(ThreadPool::new(4));
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let pool = pool.clone();
                thread::spawn(move || {
                    for _ in 0..20 {
                        let acc = AtomicUsize::new(0);
                        pool.run(37, &|_| {
                            acc.fetch_add(1, Ordering::SeqCst);
                        });
                        assert_eq!(acc.load(Ordering::SeqCst), 37);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn shard_panic_propagates_to_caller() {
        let pool = ThreadPool::new(2);
        let before = pool.counters().panics;
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(8, &|i| {
                if i == 3 {
                    panic!("boom");
                }
            });
        }));
        assert!(r.is_err());
        // the contained panic is counted for /metrics
        assert_eq!(pool.counters().panics - before, 1);
        // the pool survives a panicked job
        let acc = AtomicUsize::new(0);
        pool.run(8, &|_| {
            acc.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(acc.load(Ordering::SeqCst), 8);
        assert_eq!(pool.counters().panics - before, 1);
    }

    #[test]
    fn global_pool_exists() {
        assert!(global().threads() >= 1);
    }

    #[test]
    fn counters_track_jobs_shards_and_traced_busy_time() {
        let pool = ThreadPool::new(2);
        let before = pool.counters();
        pool.run(10, &|_| {});
        pool.run(1, &|_| {}); // inline path must count too
        let after = pool.counters();
        assert_eq!(after.jobs - before.jobs, 2);
        assert_eq!(after.shards - before.shards, 11);
        assert_eq!(after.busy_ns.len(), 2);

        // busy time accumulates only while a traced scope is live
        let _t = trace::scope_with(trace::TraceMode::All, None);
        let acc = AtomicU64::new(0);
        pool.run(64, &|i| {
            let mut s = 0u64;
            for k in 0..5_000u64 {
                s = std::hint::black_box(s.wrapping_add(k * i as u64));
            }
            acc.fetch_add(s | 1, Ordering::Relaxed);
        });
        let busy = pool.counters();
        assert!(
            busy.busy_ns_total() > after.busy_ns_total(),
            "no busy time recorded under a traced scope"
        );
    }
}
