//! Statistics substrate: streaming moments, percentiles, histograms and a
//! simple linear-regression helper — used by the metrics sinks, the bench
//! harness and the encrypted-weight-distribution experiments (Figs. 6/13/14).

/// Streaming mean/variance (Welford) with min/max.
#[derive(Clone, Debug, Default)]
pub struct Moments {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Moments {
    pub fn new() -> Self {
        Moments { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n-1); 0 for n < 2.
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Percentile over a sample (linear interpolation, like numpy's default).
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty(), "percentile of empty slice");
    assert!((0.0..=100.0).contains(&p));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Take several percentiles of a sample at once. Borrows the sample and
/// sorts a local copy, so callers keep their data (no more `lat.clone()`
/// at every call site).
///
/// # Examples
///
/// ```
/// use flexor::substrate::stats::percentiles;
///
/// let lat = vec![4.0, 1.0, 3.0, 2.0]; // unsorted is fine
/// assert_eq!(percentiles(&lat, &[0.0, 50.0, 100.0]), vec![1.0, 2.5, 4.0]);
/// ```
pub fn percentiles(xs: &[f64], ps: &[f64]) -> Vec<f64> {
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    ps.iter().map(|&p| percentile(&sorted, p)).collect()
}

/// Fixed-range histogram (the encrypted-weight distribution plots).
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
    pub underflow: u64,
    pub overflow: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(bins > 0 && hi > lo);
        Histogram { lo, hi, counts: vec![0; bins], underflow: 0, overflow: 0 }
    }

    pub fn push(&mut self, x: f64) {
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let last = self.counts.len() - 1;
            let b = ((x - self.lo) / (self.hi - self.lo) * self.counts.len() as f64)
                as usize;
            self.counts[b.min(last)] += 1;
        }
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum::<u64>() + self.underflow + self.overflow
    }

    /// Render as a one-line-per-bin ASCII bar chart (for experiment logs).
    pub fn ascii(&self, width: usize) -> String {
        let maxc = self.counts.iter().copied().max().unwrap_or(1).max(1);
        let bw = (self.hi - self.lo) / self.counts.len() as f64;
        let mut s = String::new();
        for (i, &c) in self.counts.iter().enumerate() {
            let bar = "#".repeat((c as usize * width / maxc as usize).max(0));
            s.push_str(&format!(
                "{:>9.4} | {:<width$} {}\n",
                self.lo + bw * i as f64,
                bar,
                c,
                width = width
            ));
        }
        s
    }
}

/// Ordinary least squares y = a + b·x; returns (a, b, r²).
pub fn linreg(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2);
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let sxx: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
    let syy: f64 = ys.iter().map(|y| (y - my) * (y - my)).sum();
    let b = sxy / sxx;
    let a = my - b * mx;
    let r2 = if syy == 0.0 { 1.0 } else { (sxy * sxy) / (sxx * syy) };
    (a, b, r2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn moments_basic() {
        let mut m = Moments::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            m.push(x);
        }
        assert_eq!(m.count(), 8);
        assert!((m.mean() - 5.0).abs() < 1e-12);
        assert!((m.var() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(m.min(), 2.0);
        assert_eq!(m.max(), 9.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
    }

    #[test]
    fn percentiles_unsorted_input() {
        let xs = [5.0, 1.0, 3.0];
        let got = percentiles(&xs, &[0.0, 50.0, 100.0]);
        assert_eq!(got, vec![1.0, 3.0, 5.0]);
        // the borrowed sample is left untouched
        assert_eq!(xs, [5.0, 1.0, 3.0]);
    }

    #[test]
    fn histogram_binning() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.push(i as f64 + 0.5);
        }
        h.push(-1.0);
        h.push(99.0);
        assert_eq!(h.counts, vec![1; 10]);
        assert_eq!(h.underflow, 1);
        assert_eq!(h.overflow, 1);
        assert_eq!(h.total(), 12);
        assert!(h.ascii(10).lines().count() == 10);
    }

    #[test]
    fn linreg_exact_line() {
        let xs = [0.0, 1.0, 2.0, 3.0];
        let ys = [1.0, 3.0, 5.0, 7.0];
        let (a, b, r2) = linreg(&xs, &ys);
        assert!((a - 1.0).abs() < 1e-12);
        assert!((b - 2.0).abs() < 1e-12);
        assert!((r2 - 1.0).abs() < 1e-12);
    }
}
