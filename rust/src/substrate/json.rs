//! Minimal JSON substrate (parser + serializer), sufficient for the
//! artifact manifests, experiment configs and metric sinks this crate
//! exchanges with the Python compile path. RFC 8259 subset: full value
//! grammar, UTF-8 input, `\uXXXX` escapes (incl. surrogate pairs), no
//! comments/trailing commas.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Objects use `BTreeMap` so serialization is
/// deterministic (stable key order) — handy for config hashing and tests.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- typed accessors ----------------------------------------------------

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|f| if f >= 0.0 { Some(f as usize) } else { None })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Flat f32 vector from an array of numbers (the serve request
    /// payload); `None` if not an array or any element is non-numeric.
    ///
    /// # Examples
    ///
    /// ```
    /// use flexor::substrate::json;
    ///
    /// let v = json::parse("[1, 2.5, -3]").unwrap();
    /// assert_eq!(v.as_f32_vec(), Some(vec![1.0, 2.5, -3.0]));
    /// assert_eq!(json::parse(r#"[1, "x"]"#).unwrap().as_f32_vec(), None);
    /// ```
    pub fn as_f32_vec(&self) -> Option<Vec<f32>> {
        let arr = self.as_arr()?;
        let mut out = Vec::with_capacity(arr.len());
        for v in arr {
            out.push(v.as_f64()? as f32);
        }
        Some(out)
    }

    /// Object field lookup; `Json::Null` for missing keys / non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(m) => m.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Array index lookup.
    pub fn at(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(v) => v.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    // ---- builders -------------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num<N: Into<f64>>(n: N) -> Json {
        Json::Num(n.into())
    }

    pub fn str<S: Into<String>>(s: S) -> Json {
        Json::Str(s.into())
    }

    /// Insert into an object (no-op with a debug panic on non-objects).
    pub fn set(&mut self, key: &str, value: Json) {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), value);
            }
            _ => debug_assert!(false, "set() on non-object"),
        }
    }

    // ---- serialization -----------------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(0));
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(ind) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat((ind + 1) * 2));
                        item.write(out, Some(ind + 1));
                    } else {
                        item.write(out, None);
                    }
                }
                if let Some(ind) = indent {
                    out.push('\n');
                    out.push_str(&" ".repeat(ind * 2));
                }
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(ind) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat((ind + 1) * 2));
                        write_escaped(out, k);
                        out.push_str(": ");
                        v.write(out, Some(ind + 1));
                    } else {
                        write_escaped(out, k);
                        out.push(':');
                        v.write(out, None);
                    }
                }
                if let Some(ind) = indent {
                    out.push('\n');
                    out.push_str(&" ".repeat(ind * 2));
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    /// Compact form.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s, None);
        f.write_str(&s)
    }
}

fn write_num(out: &mut String, n: f64) {
    if !n.is_finite() {
        out.push_str("null"); // JSON has no NaN/Inf
    } else if n == n.trunc() && n.abs() < 1e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---------------------------------------------------------------------------
// parser
// ---------------------------------------------------------------------------

/// Parse error with byte offset and message.
#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for ParseError {}

pub fn parse(input: &str) -> Result<Json, ParseError> {
    let mut p = Parser { b: input.as_bytes(), i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { offset: self.i, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            map.insert(key, self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            self.i += 1;
                            let hi = self.hex4()?;
                            let c = if (0xD800..0xDC00).contains(&hi) {
                                // surrogate pair
                                if !self.b[self.i..].starts_with(b"\\u") {
                                    return Err(self.err("lone high surrogate"));
                                }
                                self.i += 2;
                                let lo = self.hex4()?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    return Err(self.err("invalid low surrogate"));
                                }
                                let cp = 0x10000
                                    + ((hi - 0xD800) << 10)
                                    + (lo - 0xDC00);
                                char::from_u32(cp)
                            } else {
                                char::from_u32(hi)
                            };
                            s.push(c.ok_or_else(|| self.err("invalid codepoint"))?);
                            continue; // hex4 advanced past the escape
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 char
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    if (c as u32) < 0x20 {
                        return Err(self.err("control char in string"));
                    }
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        if self.i + 4 > self.b.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| self.err("bad \\u escape"))?;
        let v = u32::from_str_radix(hex, 16).map_err(|_| self.err("bad hex"))?;
        self.i += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| ParseError { offset: start, msg: "bad number".into() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(parse("-42").unwrap(), Json::Num(-42.0));
        assert_eq!(parse("1e3").unwrap(), Json::Num(1000.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").at(0).as_f64(), Some(1.0));
        assert_eq!(v.get("a").at(2).get("b"), &Json::Null);
        assert_eq!(v.get("c").as_str(), Some("x"));
        assert!(v.get("missing").is_null());
    }

    #[test]
    fn parse_escapes() {
        let v = parse(r#""a\n\t\"\\Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\Aé"));
    }

    #[test]
    fn parse_surrogate_pair() {
        let v = parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("😀"));
    }

    #[test]
    fn parse_errors() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
        assert!(parse("\"\\ud800x\"").is_err());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"b":[1,2.5,true,null,"s"],"a":{"x":-3}}"#;
        let v = parse(src).unwrap();
        let compact = v.to_string();
        assert_eq!(parse(&compact).unwrap(), v);
        let pretty = v.to_string_pretty();
        assert_eq!(parse(&pretty).unwrap(), v);
        assert!(pretty.contains('\n'));
    }

    #[test]
    fn deterministic_key_order() {
        let v = parse(r#"{"z":1,"a":2}"#).unwrap();
        assert_eq!(v.to_string(), r#"{"a":2,"z":1}"#);
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::num(4.0).to_string(), "4");
        assert_eq!(Json::num(4.5).to_string(), "4.5");
    }

    #[test]
    fn nonfinite_serializes_as_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }

    #[test]
    fn random_value_roundtrip_property() {
        use crate::substrate::ptest::{check, Gen};

        fn gen_value(g: &mut Gen, depth: usize) -> Json {
            match if depth == 0 { g.u32(4) } else { g.u32(6) } {
                0 => Json::Null,
                1 => Json::Bool(g.bool()),
                2 => Json::num((g.normal() * 1e3) as f64),
                3 => Json::str(format!("s{}-\"é\n{}", g.u32(100), g.u32(100))),
                4 => Json::arr((0..g.usize_in(0, 4)).map(|_| gen_value(g, depth - 1))),
                _ => {
                    let mut o = Json::Obj(Default::default());
                    for i in 0..g.usize_in(0, 4) {
                        o.set(&format!("k{i}"), gen_value(g, depth - 1));
                    }
                    o
                }
            }
        }

        check("json parse(to_string(v)) == v", 200, |g| {
            let v = gen_value(g, 3);
            parse(&v.to_string()) == Ok(v.clone())
                && parse(&v.to_string_pretty()) == Ok(v)
        });
    }

    #[test]
    fn f32_vec_accessor() {
        let v = parse("[1, -2.5, 3e2]").unwrap();
        assert_eq!(v.as_f32_vec(), Some(vec![1.0, -2.5, 300.0]));
        assert_eq!(parse("[]").unwrap().as_f32_vec(), Some(vec![]));
        assert_eq!(parse(r#"[1, "x"]"#).unwrap().as_f32_vec(), None);
        assert_eq!(parse("3").unwrap().as_f32_vec(), None);
        // f32 features survive the num → text → num round trip exactly
        let x = 0.1234567f32;
        let j = Json::num(x);
        let back = parse(&j.to_string()).unwrap().as_f32_vec();
        assert_eq!(back, None); // scalar, not array
        let arr = Json::arr([j]);
        assert_eq!(parse(&arr.to_string()).unwrap().as_f32_vec(), Some(vec![x]));
    }

    #[test]
    fn builders() {
        let v = Json::obj(vec![
            ("name", Json::str("x")),
            ("vals", Json::arr([Json::num(1), Json::num(2)])),
        ]);
        assert_eq!(v.get("vals").at(1).as_i64(), Some(2));
        let mut v2 = v.clone();
        v2.set("extra", Json::Bool(true));
        assert_eq!(v2.get("extra").as_bool(), Some(true));
    }
}
