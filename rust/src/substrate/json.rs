//! Minimal JSON substrate (parser + serializer), sufficient for the
//! artifact manifests, experiment configs and metric sinks this crate
//! exchanges with the Python compile path. RFC 8259 subset: full value
//! grammar, UTF-8 input, `\uXXXX` escapes (incl. surrogate pairs), no
//! comments/trailing commas.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Objects use `BTreeMap` so serialization is
/// deterministic (stable key order) — handy for config hashing and tests.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- typed accessors ----------------------------------------------------

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|f| if f >= 0.0 { Some(f as usize) } else { None })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Flat f32 vector from an array of numbers (the serve request
    /// payload); `None` if not an array or any element is non-numeric.
    ///
    /// # Examples
    ///
    /// ```
    /// use flexor::substrate::json;
    ///
    /// let v = json::parse("[1, 2.5, -3]").unwrap();
    /// assert_eq!(v.as_f32_vec(), Some(vec![1.0, 2.5, -3.0]));
    /// assert_eq!(json::parse(r#"[1, "x"]"#).unwrap().as_f32_vec(), None);
    /// ```
    pub fn as_f32_vec(&self) -> Option<Vec<f32>> {
        let arr = self.as_arr()?;
        let mut out = Vec::with_capacity(arr.len());
        for v in arr {
            out.push(v.as_f64()? as f32);
        }
        Some(out)
    }

    /// Object field lookup; `Json::Null` for missing keys / non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(m) => m.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Array index lookup.
    pub fn at(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(v) => v.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    // ---- builders -------------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num<N: Into<f64>>(n: N) -> Json {
        Json::Num(n.into())
    }

    pub fn str<S: Into<String>>(s: S) -> Json {
        Json::Str(s.into())
    }

    /// Insert into an object (no-op with a debug panic on non-objects).
    pub fn set(&mut self, key: &str, value: Json) {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), value);
            }
            _ => debug_assert!(false, "set() on non-object"),
        }
    }

    // ---- serialization -----------------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(0));
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(ind) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat((ind + 1) * 2));
                        item.write(out, Some(ind + 1));
                    } else {
                        item.write(out, None);
                    }
                }
                if let Some(ind) = indent {
                    out.push('\n');
                    out.push_str(&" ".repeat(ind * 2));
                }
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(ind) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat((ind + 1) * 2));
                        write_escaped(out, k);
                        out.push_str(": ");
                        v.write(out, Some(ind + 1));
                    } else {
                        write_escaped(out, k);
                        out.push(':');
                        v.write(out, None);
                    }
                }
                if let Some(ind) = indent {
                    out.push('\n');
                    out.push_str(&" ".repeat(ind * 2));
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    /// Compact form.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s, None);
        f.write_str(&s)
    }
}

fn write_num(out: &mut String, n: f64) {
    if !n.is_finite() {
        out.push_str("null"); // JSON has no NaN/Inf
    } else if n == n.trunc() && n.abs() < 1e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---------------------------------------------------------------------------
// parser
// ---------------------------------------------------------------------------

/// Parse error with byte offset and message.
#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for ParseError {}

pub fn parse(input: &str) -> Result<Json, ParseError> {
    let mut p = Parser { b: input.as_bytes(), i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { offset: self.i, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            map.insert(key, self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            self.i += 1;
                            let hi = self.hex4()?;
                            let c = if (0xD800..0xDC00).contains(&hi) {
                                // surrogate pair
                                if !self.b[self.i..].starts_with(b"\\u") {
                                    return Err(self.err("lone high surrogate"));
                                }
                                self.i += 2;
                                let lo = self.hex4()?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    return Err(self.err("invalid low surrogate"));
                                }
                                let cp = 0x10000
                                    + ((hi - 0xD800) << 10)
                                    + (lo - 0xDC00);
                                char::from_u32(cp)
                            } else {
                                char::from_u32(hi)
                            };
                            s.push(c.ok_or_else(|| self.err("invalid codepoint"))?);
                            continue; // hex4 advanced past the escape
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 char
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    if (c as u32) < 0x20 {
                        return Err(self.err("control char in string"));
                    }
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        if self.i + 4 > self.b.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| self.err("bad \\u escape"))?;
        let v = u32::from_str_radix(hex, 16).map_err(|_| self.err("bad hex"))?;
        self.i += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| ParseError { offset: start, msg: "bad number".into() })
    }
}

// ---------------------------------------------------------------------------
// streaming lexer (SAX-style visitor)
// ---------------------------------------------------------------------------

/// Maximum container nesting the streaming lexer accepts. The explicit
/// frame stack is preallocated to exactly this depth, so steady-state
/// lexing performs zero heap allocations; the recursive tree parser has
/// no such bound, so keep generated oracle documents shallower than this.
pub const MAX_LEX_DEPTH: usize = 128;

/// Visitor callbacks emitted by [`Lexer::lex`] in document order.
/// Returning `Err(msg)` aborts the lex with a [`ParseError`] at the
/// current byte offset. All methods default to "accept and continue" so
/// visitors only override the events they care about.
pub trait Visitor {
    fn on_null(&mut self) -> Result<(), &'static str> {
        Ok(())
    }
    fn on_bool(&mut self, _b: bool) -> Result<(), &'static str> {
        Ok(())
    }
    fn on_num(&mut self, _n: f64) -> Result<(), &'static str> {
        Ok(())
    }
    fn on_str(&mut self, _s: &str) -> Result<(), &'static str> {
        Ok(())
    }
    fn on_key(&mut self, _k: &str) -> Result<(), &'static str> {
        Ok(())
    }
    fn begin_arr(&mut self) -> Result<(), &'static str> {
        Ok(())
    }
    fn end_arr(&mut self) -> Result<(), &'static str> {
        Ok(())
    }
    fn begin_obj(&mut self) -> Result<(), &'static str> {
        Ok(())
    }
    fn end_obj(&mut self) -> Result<(), &'static str> {
        Ok(())
    }
}

#[derive(Clone, Copy, PartialEq)]
enum Frame {
    Arr,
    Obj,
}

/// Where a scanned string lives: borrowed straight from the input when
/// escape-free, or decoded into the lexer's reusable scratch buffer.
enum StrSpan {
    Borrowed(usize, usize),
    Scratch,
}

/// Reusable streaming JSON lexer. One instance per connection/thread:
/// the scratch `String` (escape decoding) and the container frame stack
/// are allocated once and recycled across calls, so lexing a request
/// whose strings fit the warm scratch capacity allocates nothing.
///
/// Grammar and acceptance are transcribed from [`parse`] (the tree
/// parser is the oracle the property tests pin this lexer to), with one
/// deliberate divergence: nesting deeper than [`MAX_LEX_DEPTH`] is
/// rejected instead of recursing.
///
/// # Examples
///
/// ```
/// use flexor::substrate::json::{Lexer, TreeBuilder, parse};
///
/// let doc = r#"{"model":"m","features":[1,2.5,-3e2]}"#;
/// let mut builder = TreeBuilder::new();
/// Lexer::new().lex(doc.as_bytes(), &mut builder).unwrap();
/// assert_eq!(builder.take(), parse(doc).ok());
/// ```
pub struct Lexer {
    scratch: String,
    stack: Vec<Frame>,
}

impl Default for Lexer {
    fn default() -> Self {
        Self::new()
    }
}

impl Lexer {
    pub fn new() -> Lexer {
        Lexer { scratch: String::with_capacity(128), stack: Vec::with_capacity(MAX_LEX_DEPTH) }
    }

    /// Lex `input` end to end, emitting events into `v`. Exactly one
    /// top-level value is accepted (leading/trailing whitespace allowed),
    /// matching [`parse`].
    pub fn lex<V: Visitor>(&mut self, input: &[u8], v: &mut V) -> Result<(), ParseError> {
        self.stack.clear();
        let b = input;
        let mut i = 0usize;
        skip_ws(b, &mut i);

        // Iterative value loop: each pass parses one value, then unwinds
        // closing brackets / separators until the next value position.
        'value: loop {
            match b.get(i).copied() {
                Some(b'n') => {
                    lit(b, &mut i, "null")?;
                    v.on_null().map_err(|m| verr(i, m))?;
                }
                Some(b't') => {
                    lit(b, &mut i, "true")?;
                    v.on_bool(true).map_err(|m| verr(i, m))?;
                }
                Some(b'f') => {
                    lit(b, &mut i, "false")?;
                    v.on_bool(false).map_err(|m| verr(i, m))?;
                }
                Some(b'"') => {
                    let span = self.scan_string(b, &mut i)?;
                    let s = self.span_str(b, span);
                    v.on_str(s).map_err(|m| verr(i, m))?;
                }
                Some(b'[') => {
                    if self.stack.len() >= MAX_LEX_DEPTH {
                        return Err(verr(i, "nesting too deep"));
                    }
                    i += 1;
                    v.begin_arr().map_err(|m| verr(i, m))?;
                    skip_ws(b, &mut i);
                    if b.get(i) == Some(&b']') {
                        i += 1;
                        v.end_arr().map_err(|m| verr(i, m))?;
                    } else {
                        self.stack.push(Frame::Arr);
                        skip_ws(b, &mut i);
                        continue 'value;
                    }
                }
                Some(b'{') => {
                    if self.stack.len() >= MAX_LEX_DEPTH {
                        return Err(verr(i, "nesting too deep"));
                    }
                    i += 1;
                    v.begin_obj().map_err(|m| verr(i, m))?;
                    skip_ws(b, &mut i);
                    if b.get(i) == Some(&b'}') {
                        i += 1;
                        v.end_obj().map_err(|m| verr(i, m))?;
                    } else {
                        self.stack.push(Frame::Obj);
                        skip_ws(b, &mut i);
                        let span = self.scan_string(b, &mut i)?;
                        {
                            let k = self.span_str(b, span);
                            v.on_key(k).map_err(|m| verr(i, m))?;
                        }
                        skip_ws(b, &mut i);
                        expect(b, &mut i, b':')?;
                        skip_ws(b, &mut i);
                        continue 'value;
                    }
                }
                Some(c) if c == b'-' || c.is_ascii_digit() => {
                    let n = scan_number(b, &mut i)?;
                    v.on_num(n).map_err(|m| verr(i, m))?;
                }
                Some(_) => return Err(verr(i, "unexpected character")),
                None => return Err(verr(i, "unexpected end of input")),
            }

            // A value just closed; pop containers / consume separators.
            loop {
                let Some(&frame) = self.stack.last() else {
                    skip_ws(b, &mut i);
                    if i != b.len() {
                        return Err(verr(i, "trailing characters"));
                    }
                    return Ok(());
                };
                skip_ws(b, &mut i);
                match frame {
                    Frame::Arr => match b.get(i).copied() {
                        Some(b',') => {
                            i += 1;
                            skip_ws(b, &mut i);
                            continue 'value;
                        }
                        Some(b']') => {
                            i += 1;
                            self.stack.pop();
                            v.end_arr().map_err(|m| verr(i, m))?;
                        }
                        _ => return Err(verr(i, "expected ',' or ']'")),
                    },
                    Frame::Obj => match b.get(i).copied() {
                        Some(b',') => {
                            i += 1;
                            skip_ws(b, &mut i);
                            let span = self.scan_string(b, &mut i)?;
                            {
                                let k = self.span_str(b, span);
                                v.on_key(k).map_err(|m| verr(i, m))?;
                            }
                            skip_ws(b, &mut i);
                            expect(b, &mut i, b':')?;
                            skip_ws(b, &mut i);
                            continue 'value;
                        }
                        Some(b'}') => {
                            i += 1;
                            self.stack.pop();
                            v.end_obj().map_err(|m| verr(i, m))?;
                        }
                        _ => return Err(verr(i, "expected ',' or '}'")),
                    },
                }
            }
        }
    }

    fn span_str<'a>(&'a self, b: &'a [u8], span: StrSpan) -> &'a str {
        match span {
            // Safety-free: scan_string validated this span as UTF-8.
            StrSpan::Borrowed(a, z) => std::str::from_utf8(&b[a..z]).unwrap_or(""),
            StrSpan::Scratch => &self.scratch,
        }
    }

    /// Scan a quoted string at `*i`. Escape-free strings are returned as
    /// a borrowed span (validated UTF-8, no copy); strings with escapes
    /// are decoded into the reusable scratch buffer. Acceptance matches
    /// `Parser::string`, including its `\u` quirks.
    fn scan_string(&mut self, b: &[u8], i: &mut usize) -> Result<StrSpan, ParseError> {
        expect(b, i, b'"')?;
        let start = *i;
        // Fast path: find the closing quote; bail to slow path on '\\'.
        // Byte-wise scanning is safe: '"' and '\\' are ASCII and cannot
        // appear inside a UTF-8 multi-byte sequence.
        loop {
            match b.get(*i).copied() {
                None => return Err(verr(*i, "unterminated string")),
                Some(b'"') => {
                    let span = &b[start..*i];
                    if std::str::from_utf8(span).is_err() {
                        return Err(verr(*i, "invalid utf-8"));
                    }
                    if span.iter().any(|&c| c < 0x20) {
                        return Err(verr(*i, "control char in string"));
                    }
                    *i += 1;
                    return Ok(StrSpan::Borrowed(start, *i - 1));
                }
                Some(b'\\') => break,
                Some(_) => *i += 1,
            }
        }

        // Slow path: decode into scratch, starting from the clean prefix.
        self.scratch.clear();
        {
            let prefix = &b[start..*i];
            let p = std::str::from_utf8(prefix).map_err(|_| verr(*i, "invalid utf-8"))?;
            if p.bytes().any(|c| c < 0x20) {
                return Err(verr(*i, "control char in string"));
            }
            self.scratch.push_str(p);
        }
        loop {
            match b.get(*i).copied() {
                None => return Err(verr(*i, "unterminated string")),
                Some(b'"') => {
                    *i += 1;
                    return Ok(StrSpan::Scratch);
                }
                Some(b'\\') => {
                    *i += 1;
                    match b.get(*i).copied() {
                        Some(b'"') => self.scratch.push('"'),
                        Some(b'\\') => self.scratch.push('\\'),
                        Some(b'/') => self.scratch.push('/'),
                        Some(b'b') => self.scratch.push('\u{8}'),
                        Some(b'f') => self.scratch.push('\u{c}'),
                        Some(b'n') => self.scratch.push('\n'),
                        Some(b'r') => self.scratch.push('\r'),
                        Some(b't') => self.scratch.push('\t'),
                        Some(b'u') => {
                            *i += 1;
                            let hi = hex4(b, i)?;
                            let c = if (0xD800..0xDC00).contains(&hi) {
                                if !b[*i..].starts_with(b"\\u") {
                                    return Err(verr(*i, "lone high surrogate"));
                                }
                                *i += 2;
                                let lo = hex4(b, i)?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    return Err(verr(*i, "invalid low surrogate"));
                                }
                                let cp = 0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00);
                                char::from_u32(cp)
                            } else {
                                char::from_u32(hi)
                            };
                            match c {
                                Some(c) => self.scratch.push(c),
                                None => return Err(verr(*i, "invalid codepoint")),
                            }
                            continue; // hex4 advanced past the escape
                        }
                        _ => return Err(verr(*i, "bad escape")),
                    }
                    *i += 1;
                }
                Some(c) => {
                    // Copy a maximal escape-free run in one validated chunk.
                    if c < 0x20 {
                        return Err(verr(*i, "control char in string"));
                    }
                    let run_start = *i;
                    while let Some(&c) = b.get(*i) {
                        if c == b'"' || c == b'\\' || c < 0x20 {
                            break;
                        }
                        *i += 1;
                    }
                    let run = std::str::from_utf8(&b[run_start..*i])
                        .map_err(|_| verr(*i, "invalid utf-8"))?;
                    self.scratch.push_str(run);
                }
            }
        }
    }
}

fn verr(offset: usize, msg: &str) -> ParseError {
    ParseError { offset, msg: msg.to_string() }
}

fn skip_ws(b: &[u8], i: &mut usize) {
    while matches!(b.get(*i), Some(b' ' | b'\t' | b'\n' | b'\r')) {
        *i += 1;
    }
}

fn expect(b: &[u8], i: &mut usize, c: u8) -> Result<(), ParseError> {
    if b.get(*i) == Some(&c) {
        *i += 1;
        Ok(())
    } else {
        Err(verr(*i, &format!("expected '{}'", c as char)))
    }
}

fn lit(b: &[u8], i: &mut usize, s: &str) -> Result<(), ParseError> {
    if b[*i..].starts_with(s.as_bytes()) {
        *i += s.len();
        Ok(())
    } else {
        Err(verr(*i, &format!("expected '{s}'")))
    }
}

fn hex4(b: &[u8], i: &mut usize) -> Result<u32, ParseError> {
    if *i + 4 > b.len() {
        return Err(verr(*i, "truncated \\u escape"));
    }
    let hex = std::str::from_utf8(&b[*i..*i + 4]).map_err(|_| verr(*i, "bad \\u escape"))?;
    let v = u32::from_str_radix(hex, 16).map_err(|_| verr(*i, "bad hex"))?;
    *i += 4;
    Ok(v)
}

fn scan_number(b: &[u8], i: &mut usize) -> Result<f64, ParseError> {
    let start = *i;
    if b.get(*i) == Some(&b'-') {
        *i += 1;
    }
    while matches!(b.get(*i), Some(c) if c.is_ascii_digit()) {
        *i += 1;
    }
    if b.get(*i) == Some(&b'.') {
        *i += 1;
        while matches!(b.get(*i), Some(c) if c.is_ascii_digit()) {
            *i += 1;
        }
    }
    if matches!(b.get(*i), Some(b'e' | b'E')) {
        *i += 1;
        if matches!(b.get(*i), Some(b'+' | b'-')) {
            *i += 1;
        }
        while matches!(b.get(*i), Some(c) if c.is_ascii_digit()) {
            *i += 1;
        }
    }
    // The number span is ASCII by construction; str::parse is the same
    // final arbiter the tree parser uses, so acceptance stays identical.
    let text = std::str::from_utf8(&b[start..*i]).unwrap();
    text.parse::<f64>().map_err(|_| verr(start, "bad number"))
}

/// Visitor that rebuilds the [`Json`] tree — the bridge used to check
/// lexer ≡ parser equivalence, and a drop-in for callers that want the
/// streaming entry point but still need a tree.
pub struct TreeBuilder {
    stack: Vec<Json>,
    keys: Vec<String>,
    root: Option<Json>,
}

impl Default for TreeBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl TreeBuilder {
    pub fn new() -> TreeBuilder {
        TreeBuilder { stack: Vec::new(), keys: Vec::new(), root: None }
    }

    /// The finished document (once [`Lexer::lex`] returned `Ok`).
    pub fn take(&mut self) -> Option<Json> {
        self.root.take()
    }

    fn place(&mut self, v: Json) -> Result<(), &'static str> {
        match self.stack.last_mut() {
            None => {
                self.root = Some(v);
                Ok(())
            }
            Some(Json::Arr(items)) => {
                items.push(v);
                Ok(())
            }
            Some(Json::Obj(map)) => {
                let k = self.keys.pop().ok_or("object value without key")?;
                map.insert(k, v);
                Ok(())
            }
            Some(_) => Err("value placed in non-container"),
        }
    }

    fn close(&mut self) -> Result<(), &'static str> {
        let v = self.stack.pop().ok_or("unbalanced close")?;
        self.place(v)
    }
}

impl Visitor for TreeBuilder {
    fn on_null(&mut self) -> Result<(), &'static str> {
        self.place(Json::Null)
    }
    fn on_bool(&mut self, b: bool) -> Result<(), &'static str> {
        self.place(Json::Bool(b))
    }
    fn on_num(&mut self, n: f64) -> Result<(), &'static str> {
        self.place(Json::Num(n))
    }
    fn on_str(&mut self, s: &str) -> Result<(), &'static str> {
        self.place(Json::Str(s.to_string()))
    }
    fn on_key(&mut self, k: &str) -> Result<(), &'static str> {
        self.keys.push(k.to_string());
        Ok(())
    }
    fn begin_arr(&mut self) -> Result<(), &'static str> {
        self.stack.push(Json::Arr(Vec::new()));
        Ok(())
    }
    fn end_arr(&mut self) -> Result<(), &'static str> {
        self.close()
    }
    fn begin_obj(&mut self) -> Result<(), &'static str> {
        self.stack.push(Json::Obj(BTreeMap::new()));
        Ok(())
    }
    fn end_obj(&mut self) -> Result<(), &'static str> {
        self.close()
    }
}

/// Convenience: lex `input` into a rebuilt tree with a fresh [`Lexer`].
pub fn lex_to_tree(input: &[u8]) -> Result<Json, ParseError> {
    let mut builder = TreeBuilder::new();
    Lexer::new().lex(input, &mut builder)?;
    builder.take().ok_or_else(|| verr(0, "empty document"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(parse("-42").unwrap(), Json::Num(-42.0));
        assert_eq!(parse("1e3").unwrap(), Json::Num(1000.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").at(0).as_f64(), Some(1.0));
        assert_eq!(v.get("a").at(2).get("b"), &Json::Null);
        assert_eq!(v.get("c").as_str(), Some("x"));
        assert!(v.get("missing").is_null());
    }

    #[test]
    fn parse_escapes() {
        let v = parse(r#""a\n\t\"\\Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\Aé"));
    }

    #[test]
    fn parse_surrogate_pair() {
        let v = parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("😀"));
    }

    #[test]
    fn parse_errors() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
        assert!(parse("\"\\ud800x\"").is_err());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"b":[1,2.5,true,null,"s"],"a":{"x":-3}}"#;
        let v = parse(src).unwrap();
        let compact = v.to_string();
        assert_eq!(parse(&compact).unwrap(), v);
        let pretty = v.to_string_pretty();
        assert_eq!(parse(&pretty).unwrap(), v);
        assert!(pretty.contains('\n'));
    }

    #[test]
    fn deterministic_key_order() {
        let v = parse(r#"{"z":1,"a":2}"#).unwrap();
        assert_eq!(v.to_string(), r#"{"a":2,"z":1}"#);
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::num(4.0).to_string(), "4");
        assert_eq!(Json::num(4.5).to_string(), "4.5");
    }

    #[test]
    fn nonfinite_serializes_as_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }

    #[test]
    fn random_value_roundtrip_property() {
        use crate::substrate::ptest::{check, Gen};

        fn gen_value(g: &mut Gen, depth: usize) -> Json {
            match if depth == 0 { g.u32(4) } else { g.u32(6) } {
                0 => Json::Null,
                1 => Json::Bool(g.bool()),
                2 => Json::num((g.normal() * 1e3) as f64),
                3 => Json::str(format!("s{}-\"é\n{}", g.u32(100), g.u32(100))),
                4 => Json::arr((0..g.usize_in(0, 4)).map(|_| gen_value(g, depth - 1))),
                _ => {
                    let mut o = Json::Obj(Default::default());
                    for i in 0..g.usize_in(0, 4) {
                        o.set(&format!("k{i}"), gen_value(g, depth - 1));
                    }
                    o
                }
            }
        }

        check("json parse(to_string(v)) == v", 200, |g| {
            let v = gen_value(g, 3);
            parse(&v.to_string()) == Ok(v.clone())
                && parse(&v.to_string_pretty()) == Ok(v)
        });
    }

    #[test]
    fn f32_vec_accessor() {
        let v = parse("[1, -2.5, 3e2]").unwrap();
        assert_eq!(v.as_f32_vec(), Some(vec![1.0, -2.5, 300.0]));
        assert_eq!(parse("[]").unwrap().as_f32_vec(), Some(vec![]));
        assert_eq!(parse(r#"[1, "x"]"#).unwrap().as_f32_vec(), None);
        assert_eq!(parse("3").unwrap().as_f32_vec(), None);
        // f32 features survive the num → text → num round trip exactly
        let x = 0.1234567f32;
        let j = Json::num(x);
        let back = parse(&j.to_string()).unwrap().as_f32_vec();
        assert_eq!(back, None); // scalar, not array
        let arr = Json::arr([j]);
        assert_eq!(parse(&arr.to_string()).unwrap().as_f32_vec(), Some(vec![x]));
    }

    #[test]
    fn lexer_matches_parser_on_basics() {
        for doc in [
            "null",
            "true",
            "false",
            "3.5",
            "-42",
            "1e3",
            "01",
            "1.",
            "\"hi\"",
            "[]",
            "{}",
            "[1, [2, []], {\"a\": null}]",
            r#"{"model":"mlp@v1","features":[0.5,-1e-3,3]}"#,
            r#""a\n\t\"\\Aé""#,
            r#""😀""#,
            r#""\ud83d\ude00""#,
            "  [ 1 , 2 ]  ",
        ] {
            assert_eq!(lex_to_tree(doc.as_bytes()).ok(), parse(doc).ok(), "doc={doc:?}");
        }
    }

    #[test]
    fn lexer_rejects_what_parser_rejects() {
        for doc in [
            "",
            "{",
            "[1,]",
            "tru",
            "1 2",
            r#"{"a" 1}"#,
            "\"\\ud800x\"",
            "\"unterminated",
            "\"ctl\u{1}\"",
            "[1, 2",
            "{\"a\":}",
            "-",
            "1e",
            ".5",
            "nan",
            "\"bad\\escape\"",
            "\"\\u12\"",
        ] {
            assert_eq!(
                lex_to_tree(doc.as_bytes()).is_ok(),
                parse(doc).is_ok(),
                "verdict diverged on {doc:?}"
            );
            assert!(lex_to_tree(doc.as_bytes()).is_err(), "lexer accepted {doc:?}");
        }
    }

    #[test]
    fn lexer_depth_bound_is_enforced() {
        let deep_ok = format!("{}0{}", "[".repeat(MAX_LEX_DEPTH), "]".repeat(MAX_LEX_DEPTH));
        assert!(lex_to_tree(deep_ok.as_bytes()).is_ok());
        let too_deep =
            format!("{}0{}", "[".repeat(MAX_LEX_DEPTH + 1), "]".repeat(MAX_LEX_DEPTH + 1));
        assert!(lex_to_tree(too_deep.as_bytes()).is_err());
    }

    #[test]
    fn lexer_reuse_across_documents() {
        let mut lexer = Lexer::new();
        for doc in [r#"{"a":"x\ny"}"#, "[1,2,3]", r#""plain""#] {
            let mut b = TreeBuilder::new();
            lexer.lex(doc.as_bytes(), &mut b).unwrap();
            assert_eq!(b.take(), parse(doc).ok(), "doc={doc:?}");
        }
    }

    #[test]
    fn visitor_abort_surfaces_as_parse_error() {
        struct NoStrings;
        impl Visitor for NoStrings {
            fn on_str(&mut self, _s: &str) -> Result<(), &'static str> {
                Err("strings not allowed here")
            }
        }
        let err = Lexer::new().lex(br#"[1, "x"]"#, &mut NoStrings).unwrap_err();
        assert!(err.msg.contains("strings not allowed"), "{err}");
    }

    #[test]
    fn builders() {
        let v = Json::obj(vec![
            ("name", Json::str("x")),
            ("vals", Json::arr([Json::num(1), Json::num(2)])),
        ]);
        assert_eq!(v.get("vals").at(1).as_i64(), Some(2));
        let mut v2 = v.clone();
        v2.set("extra", Json::Bool(true));
        assert_eq!(v2.get("extra").as_bool(), Some(true));
    }
}
