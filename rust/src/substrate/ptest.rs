//! Tiny property-testing kit (proptest is unavailable offline).
//!
//! Deterministic, PRNG-driven randomized testing with input shrinking for
//! integer-vector cases. Used for the coordinator/flexor invariants:
//! codec roundtrips, decrypt-engine equivalences, schedule monotonicity.
//!
//! ```
//! use flexor::substrate::ptest::{check, Gen};
//! check("reverse twice is identity", 100, |g| {
//!     let v = g.vec_u32(0..50, 1000);
//!     let mut w = v.clone();
//!     w.reverse();
//!     w.reverse();
//!     v == w
//! });
//! ```

use super::prng::Pcg32;

/// Random input generator handed to each property case.
pub struct Gen {
    rng: Pcg32,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Gen { rng: Pcg32::new(seed, 0xF1E0) }
    }

    pub fn u32(&mut self, bound: u32) -> u32 {
        self.rng.below(bound)
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.rng.below((hi - lo) as u32) as usize
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.range_f32(lo, hi)
    }

    pub fn normal(&mut self) -> f32 {
        self.rng.normal()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bernoulli(0.5)
    }

    /// Vector of random length in `len_range` with elements `< bound`.
    pub fn vec_u32(&mut self, len_range: std::ops::Range<usize>, bound: u32) -> Vec<u32> {
        let n = self.usize_in(len_range.start, len_range.end.max(len_range.start + 1));
        (0..n).map(|_| self.rng.below(bound)).collect()
    }

    pub fn vec_f32(&mut self, len_range: std::ops::Range<usize>) -> Vec<f32> {
        let n = self.usize_in(len_range.start, len_range.end.max(len_range.start + 1));
        (0..n).map(|_| self.rng.normal()).collect()
    }

    pub fn rng(&mut self) -> &mut Pcg32 {
        &mut self.rng
    }
}

/// Run `cases` random cases of `prop`; panic with the failing seed on the
/// first counterexample. Seeds are derived from the property name so
/// failures reproduce across runs but different properties explore
/// different streams.
pub fn check<F: FnMut(&mut Gen) -> bool>(name: &str, cases: u32, mut prop: F) {
    let base = fnv1a(name.as_bytes());
    for case in 0..cases {
        let seed = base ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut g = Gen::new(seed);
        if !prop(&mut g) {
            panic!(
                "property '{name}' failed at case {case} (seed {seed:#x}); \
                 rerun with Gen::new({seed:#x}) to reproduce"
            );
        }
    }
}

/// `check` variant whose property returns Result with a diagnostic.
pub fn check_msg<F: FnMut(&mut Gen) -> Result<(), String>>(
    name: &str,
    cases: u32,
    mut prop: F,
) {
    let base = fnv1a(name.as_bytes());
    for case in 0..cases {
        let seed = base ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut g = Gen::new(seed);
        if let Err(msg) = prop(&mut g) {
            panic!("property '{name}' failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add commutes", 50, |g| {
            let a = g.u32(1000) as u64;
            let b = g.u32(1000) as u64;
            a + b == b + a
        });
    }

    #[test]
    #[should_panic(expected = "property 'always false' failed")]
    fn failing_property_panics_with_seed() {
        check("always false", 10, |_| false);
    }

    #[test]
    fn deterministic_streams_per_name() {
        let mut first = Vec::new();
        check("stream probe", 5, |g| {
            first.push(g.u32(1_000_000));
            true
        });
        let mut second = Vec::new();
        check("stream probe", 5, |g| {
            second.push(g.u32(1_000_000));
            true
        });
        assert_eq!(first, second);
    }

    #[test]
    fn check_msg_reports() {
        let r = std::panic::catch_unwind(|| {
            check_msg("msg prop", 3, |g| {
                let v = g.u32(10);
                if v < 10 {
                    Ok(())
                } else {
                    Err(format!("impossible {v}"))
                }
            });
        });
        assert!(r.is_ok());
    }

    #[test]
    fn vec_generators_respect_bounds() {
        let mut g = Gen::new(1);
        for _ in 0..50 {
            let v = g.vec_u32(2..10, 7);
            assert!((2..10).contains(&v.len()));
            assert!(v.iter().all(|&x| x < 7));
        }
    }
}
