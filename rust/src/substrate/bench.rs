//! Criterion-like micro/macro benchmark harness (criterion is unavailable
//! offline). Warms up, auto-scales iteration counts to a target measurement
//! time, reports mean / median / p05 / p95 and throughput, and can emit the
//! results as JSON for EXPERIMENTS.md tooling.

use std::time::{Duration, Instant};

use super::json::Json;
use super::stats;

/// Machine-readable identity of a benchmark case for the cross-PR perf
/// trajectory (`BENCH_infer.json`): which op, on what shape, with how
/// many compute threads.
#[derive(Clone, Debug, Default)]
pub struct CaseMeta {
    pub op: String,
    pub shape: String,
    pub threads: usize,
}

impl CaseMeta {
    pub fn new(op: &str, shape: &str, threads: usize) -> CaseMeta {
        CaseMeta { op: op.to_string(), shape: shape.to_string(), threads }
    }
}

/// One benchmark's results.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub samples: Vec<f64>, // seconds per iteration
    pub mean_s: f64,
    pub median_s: f64,
    pub p05_s: f64,
    pub p95_s: f64,
    /// Optional units processed per iteration (bits, requests, ...)
    pub throughput_units: Option<f64>,
    pub unit_name: String,
    /// Optional machine-readable case identity (op/shape/threads).
    pub meta: Option<CaseMeta>,
}

impl BenchResult {
    pub fn throughput_per_s(&self) -> Option<f64> {
        self.throughput_units.map(|u| u / self.mean_s)
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("mean_s", Json::num(self.mean_s)),
            ("median_s", Json::num(self.median_s)),
            ("p05_s", Json::num(self.p05_s)),
            ("p95_s", Json::num(self.p95_s)),
            ("samples", Json::num(self.samples.len() as f64)),
        ]);
        if let Some(t) = self.throughput_per_s() {
            o.set("throughput_per_s", Json::num(t));
            o.set("unit", Json::str(self.unit_name.clone()));
        }
        if let Some(m) = &self.meta {
            o.set("op", Json::str(m.op.clone()));
            o.set("shape", Json::str(m.shape.clone()));
            o.set("threads", Json::num(m.threads as f64));
            o.set("ns_per_iter", Json::num(self.mean_s * 1e9));
        }
        o
    }
}

fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:8.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:8.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:8.2} ms", s * 1e3)
    } else {
        format!("{s:8.3} s ")
    }
}

fn fmt_rate(r: f64, unit: &str) -> String {
    if r >= 1e9 {
        format!("{:7.2} G{unit}/s", r / 1e9)
    } else if r >= 1e6 {
        format!("{:7.2} M{unit}/s", r / 1e6)
    } else if r >= 1e3 {
        format!("{:7.2} k{unit}/s", r / 1e3)
    } else {
        format!("{r:7.2} {unit}/s")
    }
}

/// The harness. Collects results so a bench binary can print a summary
/// table and dump JSON at the end.
pub struct Bench {
    pub warmup: Duration,
    pub measure: Duration,
    pub min_samples: usize,
    pub max_samples: usize,
    pub results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup: Duration::from_millis(200),
            measure: Duration::from_secs(1),
            min_samples: 10,
            max_samples: 2_000,
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn new() -> Self {
        Self::default()
    }

    /// Quick-mode harness for CI / smoke runs.
    pub fn quick() -> Self {
        Bench {
            warmup: Duration::from_millis(30),
            measure: Duration::from_millis(200),
            min_samples: 5,
            max_samples: 200,
            results: Vec::new(),
        }
    }

    /// Benchmark `f`; `black_box` the result inside the closure yourself if
    /// needed (use [`black_box`]).
    pub fn run<F: FnMut()>(&mut self, name: &str, f: F) -> &BenchResult {
        self.run_with_throughput(name, None, "", f)
    }

    /// Benchmark with a units-per-iteration throughput annotation.
    pub fn run_with_throughput<F: FnMut()>(
        &mut self,
        name: &str,
        units: Option<f64>,
        unit_name: &str,
        f: F,
    ) -> &BenchResult {
        self.run_case(name, None, units, unit_name, f)
    }

    /// Benchmark with full case metadata (op/shape/threads) for the
    /// machine-readable `BENCH_infer.json` trajectory.
    pub fn run_case<F: FnMut()>(
        &mut self,
        name: &str,
        meta: Option<CaseMeta>,
        units: Option<f64>,
        unit_name: &str,
        mut f: F,
    ) -> &BenchResult {
        // warmup + estimate per-iter cost
        let wstart = Instant::now();
        let mut iters: u64 = 0;
        while wstart.elapsed() < self.warmup || iters == 0 {
            f();
            iters += 1;
        }
        let per_iter = wstart.elapsed().as_secs_f64() / iters as f64;
        // choose batch size so one sample is ~ measure/min_samples but
        // at least one iteration
        let target_sample = self.measure.as_secs_f64() / self.min_samples as f64;
        let batch = ((target_sample / per_iter).floor() as u64).clamp(1, 1 << 24);

        let mut samples = Vec::new();
        let mstart = Instant::now();
        while (mstart.elapsed() < self.measure || samples.len() < self.min_samples)
            && samples.len() < self.max_samples
        {
            let t = Instant::now();
            for _ in 0..batch {
                f();
            }
            samples.push(t.elapsed().as_secs_f64() / batch as f64);
        }

        let mut sorted = samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let res = BenchResult {
            name: name.to_string(),
            mean_s: samples.iter().sum::<f64>() / samples.len() as f64,
            median_s: stats::percentile(&sorted, 50.0),
            p05_s: stats::percentile(&sorted, 5.0),
            p95_s: stats::percentile(&sorted, 95.0),
            samples,
            throughput_units: units,
            unit_name: unit_name.to_string(),
            meta,
        };
        let line = match res.throughput_per_s() {
            Some(r) => format!(
                "{:<44} {}  (p05 {} · p95 {})  {}",
                res.name,
                fmt_time(res.mean_s),
                fmt_time(res.p05_s),
                fmt_time(res.p95_s),
                fmt_rate(r, &res.unit_name)
            ),
            None => format!(
                "{:<44} {}  (p05 {} · p95 {})",
                res.name,
                fmt_time(res.mean_s),
                fmt_time(res.p05_s),
                fmt_time(res.p95_s)
            ),
        };
        println!("{line}");
        self.results.push(res);
        self.results.last().unwrap()
    }

    /// All results as a JSON array (for EXPERIMENTS.md §Perf bookkeeping).
    pub fn to_json(&self) -> Json {
        Json::arr(self.results.iter().map(|r| r.to_json()))
    }
}

/// Merge `records` into the machine-readable bench trajectory file at
/// `path` (`BENCH_infer.json`): existing records from other `source`s are
/// kept, records previously written by this `source` are replaced, and
/// every new record is stamped with `"source": source`. Benches from
/// different binaries therefore compose into one file across runs.
pub fn merge_bench_json(path: &std::path::Path, source: &str, records: Json) -> std::io::Result<()> {
    let mut kept: Vec<Json> = Vec::new();
    if let Ok(text) = std::fs::read_to_string(path) {
        if let Ok(existing) = super::json::parse(&text) {
            if let Some(arr) = existing.as_arr() {
                kept.extend(
                    arr.iter()
                        .filter(|r| r.get("source").as_str() != Some(source))
                        .cloned(),
                );
            }
        }
    }
    if let Some(arr) = records.as_arr() {
        for r in arr {
            let mut r = r.clone();
            r.set("source", Json::str(source));
            kept.push(r);
        }
    }
    std::fs::write(path, Json::arr(kept).to_string_pretty())
}

/// Mirror `records` into the committed `bench_history/BENCH_infer.json`
/// snapshot with [`merge_bench_json`] semantics, so per-PR bench numbers
/// accumulate in version control alongside the working-dir
/// `BENCH_infer.json`. Cargo runs benches with the package dir (`rust/`)
/// as cwd, so the repo-root `../bench_history` is tried too; when neither
/// directory exists (installed binary, bare checkout) this is a no-op.
pub fn merge_bench_history(source: &str, records: Json) -> std::io::Result<()> {
    match ["bench_history", "../bench_history"]
        .into_iter()
        .map(std::path::Path::new)
        .find(|d| d.is_dir())
    {
        Some(dir) => merge_bench_json(&dir.join("BENCH_infer.json"), source, records),
        None => Ok(()),
    }
}

/// Opaque value sink preventing the optimizer from deleting benchmarked work.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quickest() -> Bench {
        Bench {
            warmup: Duration::from_millis(2),
            measure: Duration::from_millis(20),
            min_samples: 3,
            max_samples: 50,
            results: Vec::new(),
        }
    }

    #[test]
    fn measures_something_positive() {
        let mut b = quickest();
        let mut acc = 0u64;
        let r = b
            .run("spin", || {
                for i in 0..100u64 {
                    acc = black_box(acc.wrapping_add(i));
                }
            })
            .clone();
        assert!(r.mean_s > 0.0);
        assert!(r.p05_s <= r.median_s && r.median_s <= r.p95_s);
        assert!(r.samples.len() >= 3);
    }

    #[test]
    fn throughput_math() {
        let mut b = quickest();
        let r = b
            .run_with_throughput("t", Some(1000.0), "item", || {
                black_box(0);
            })
            .clone();
        let tp = r.throughput_per_s().unwrap();
        assert!((tp - 1000.0 / r.mean_s).abs() / tp < 1e-9);
    }

    #[test]
    fn json_output_shape() {
        let mut b = quickest();
        b.run("a", || {
            black_box(1 + 1);
        });
        let j = b.to_json();
        assert_eq!(j.at(0).get("name").as_str(), Some("a"));
        assert!(j.at(0).get("mean_s").as_f64().unwrap() > 0.0);
    }

    #[test]
    fn case_meta_lands_in_json() {
        let mut b = quickest();
        b.run_case("m", Some(CaseMeta::new("gemm", "8x8x8", 4)), Some(512.0), "mac", || {
            black_box(1 + 1);
        });
        let j = b.to_json();
        assert_eq!(j.at(0).get("op").as_str(), Some("gemm"));
        assert_eq!(j.at(0).get("shape").as_str(), Some("8x8x8"));
        assert_eq!(j.at(0).get("threads").as_usize(), Some(4));
        assert!(j.at(0).get("ns_per_iter").as_f64().unwrap() > 0.0);
    }

    #[test]
    fn merge_bench_json_replaces_same_source_only() {
        let path = std::env::temp_dir()
            .join(format!("flexor_bench_merge_{}.json", std::process::id()));
        std::fs::remove_file(&path).ok();
        let rec = |name: &str| Json::arr([Json::obj(vec![("name", Json::str(name))])]);
        merge_bench_json(&path, "alpha", rec("a1")).unwrap();
        merge_bench_json(&path, "beta", rec("b1")).unwrap();
        // overwrite alpha; beta must survive
        merge_bench_json(&path, "alpha", rec("a2")).unwrap();
        let all = super::super::json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let arr = all.as_arr().unwrap();
        let names: Vec<_> = arr.iter().filter_map(|r| r.get("name").as_str()).collect();
        assert!(names.contains(&"a2") && names.contains(&"b1") && !names.contains(&"a1"),
                "{names:?}");
        let sources: Vec<_> = arr.iter().filter_map(|r| r.get("source").as_str()).collect();
        assert_eq!(sources.len(), 2);
        std::fs::remove_file(&path).ok();
    }
}
