//! Env-driven fault injection for the chaos harness (DESIGN.md §12).
//!
//! `FLEXOR_FAULT=panic_shard:p,slow_layer:ms,flip_word:p,queue_stall:ms`
//! arms a process-global [`FaultPlan`]; the serving stack calls the
//! `maybe_*` hooks at the seams the plan can perturb:
//!
//! - `panic_shard:p`  — each batch forward panics with probability `p`
//!   (exercises worker supervision / `catch_unwind` containment),
//! - `slow_layer:ms`  — each batch forward sleeps `ms` milliseconds
//!   (exercises deadlines racing slow compute),
//! - `flip_word:p`    — the Encrypted engine's integrity re-hash sees one
//!   encrypted word XOR-flipped with probability `p` (exercises checksum
//!   detection; the stored bundle is never mutated),
//! - `queue_stall:ms` — each dequeued batch stalls `ms` milliseconds
//!   before the deadline check (exercises queue-wait expiry shedding).
//!
//! The hooks are compiled unconditionally but cost one completed-`Once`
//! check plus one relaxed atomic load when no plan is armed, so
//! production binaries pay nothing for carrying the harness. Tests can
//! bypass the env with [`arm`]/[`disarm`]; either call consumes the env
//! spec so `FLEXOR_FAULT` never overrides an explicit choice afterwards.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, Once};
use std::time::Duration;

/// One process-wide fault plan; zeroed fields are inactive.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct FaultPlan {
    /// Probability in [0,1] that a batch forward panics.
    pub panic_shard_p: f64,
    /// Milliseconds each batch forward sleeps before computing.
    pub slow_layer_ms: u64,
    /// Probability in [0,1] that an integrity re-hash sees a flipped word.
    pub flip_word_p: f64,
    /// Milliseconds each dequeued batch stalls before the deadline check.
    pub queue_stall_ms: u64,
}

impl FaultPlan {
    /// True when every fault class is inactive.
    pub fn is_empty(&self) -> bool {
        self.panic_shard_p <= 0.0
            && self.slow_layer_ms == 0
            && self.flip_word_p <= 0.0
            && self.queue_stall_ms == 0
    }

    /// Parse the `FLEXOR_FAULT` grammar: comma-separated `key:value`
    /// pairs, any subset of `panic_shard:p`, `slow_layer:ms`,
    /// `flip_word:p`, `queue_stall:ms`.
    ///
    /// ```
    /// use flexor::substrate::fault::FaultPlan;
    /// let p = FaultPlan::parse("panic_shard:0.5,queue_stall:250").unwrap();
    /// assert_eq!(p.panic_shard_p, 0.5);
    /// assert_eq!(p.queue_stall_ms, 250);
    /// assert!(FaultPlan::parse("panic_shard:2.0").is_err());
    /// ```
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan::default();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (key, val) = part
                .split_once(':')
                .ok_or_else(|| format!("fault spec '{part}': expected key:value"))?;
            let prob = |v: &str| -> Result<f64, String> {
                let p: f64 = v
                    .parse()
                    .map_err(|_| format!("fault spec '{part}': bad probability '{v}'"))?;
                if !(0.0..=1.0).contains(&p) {
                    return Err(format!("fault spec '{part}': probability must be in [0,1]"));
                }
                Ok(p)
            };
            let millis = |v: &str| -> Result<u64, String> {
                v.parse()
                    .map_err(|_| format!("fault spec '{part}': bad millisecond count '{v}'"))
            };
            match key.trim() {
                "panic_shard" => plan.panic_shard_p = prob(val.trim())?,
                "slow_layer" => plan.slow_layer_ms = millis(val.trim())?,
                "flip_word" => plan.flip_word_p = prob(val.trim())?,
                "queue_stall" => plan.queue_stall_ms = millis(val.trim())?,
                other => {
                    return Err(format!(
                        "fault spec: unknown fault class '{other}' \
                         (expected panic_shard, slow_layer, flip_word, queue_stall)"
                    ))
                }
            }
        }
        Ok(plan)
    }
}

static ENV_INIT: Once = Once::new();
static ARMED: AtomicBool = AtomicBool::new(false);
static PLAN: Mutex<FaultPlan> = Mutex::new(FaultPlan {
    panic_shard_p: 0.0,
    slow_layer_ms: 0,
    flip_word_p: 0.0,
    queue_stall_ms: 0,
});
/// splitmix64 state for probability draws; fixed seed keeps chaos runs
/// reproducible for a given request interleaving.
static RNG: AtomicU64 = AtomicU64::new(0x9E3779B97F4A7C15);

fn env_init() {
    ENV_INIT.call_once(|| {
        if let Ok(spec) = std::env::var("FLEXOR_FAULT") {
            if !spec.trim().is_empty() {
                match FaultPlan::parse(&spec) {
                    Ok(plan) if !plan.is_empty() => {
                        *PLAN.lock().unwrap() = plan;
                        ARMED.store(true, Ordering::Release);
                    }
                    Ok(_) => {}
                    Err(e) => {
                        super::trace::log(
                            super::trace::Level::Warn,
                            "fault_spec_ignored",
                            &[("error", super::json::Json::str(e))],
                        );
                    }
                }
            }
        }
    });
}

/// Arm a fault plan, overriding (and permanently consuming) any
/// `FLEXOR_FAULT` env spec.
pub fn arm(plan: FaultPlan) {
    ENV_INIT.call_once(|| {});
    *PLAN.lock().unwrap() = plan;
    ARMED.store(!plan.is_empty(), Ordering::Release);
}

/// Disarm all faults; also consumes the env spec so it cannot re-arm.
pub fn disarm() {
    ENV_INIT.call_once(|| {});
    *PLAN.lock().unwrap() = FaultPlan::default();
    ARMED.store(false, Ordering::Release);
}

/// The armed plan, or `None` when injection is inactive.
pub fn current() -> Option<FaultPlan> {
    env_init();
    if !ARMED.load(Ordering::Acquire) {
        return None;
    }
    let plan = *PLAN.lock().unwrap();
    if plan.is_empty() {
        None
    } else {
        Some(plan)
    }
}

/// One splitmix64 step; uniform draw in [0,1).
fn draw_unit() -> f64 {
    let mut x = RNG.fetch_add(0x9E3779B97F4A7C15, Ordering::Relaxed);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
    x ^= x >> 31;
    (x >> 11) as f64 / (1u64 << 53) as f64
}

fn draw_u64() -> u64 {
    let mut x = RNG.fetch_add(0x9E3779B97F4A7C15, Ordering::Relaxed);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
    x ^ (x >> 31)
}

/// Hook: panic with probability `panic_shard_p`. Called inside the
/// worker's `catch_unwind` envelope, so a fired fault poisons exactly
/// one batch.
pub fn maybe_panic_shard() {
    if let Some(plan) = current() {
        if plan.panic_shard_p > 0.0 && draw_unit() < plan.panic_shard_p {
            panic!("injected fault: panic_shard");
        }
    }
}

/// Hook: sleep `slow_layer_ms` before a batch forward.
pub fn maybe_slow_layer() {
    if let Some(plan) = current() {
        if plan.slow_layer_ms > 0 {
            std::thread::sleep(Duration::from_millis(plan.slow_layer_ms));
        }
    }
}

/// Hook: stall `queue_stall_ms` after a batch is dequeued, before the
/// worker's deadline check, simulating a wedged assembly stage.
pub fn maybe_queue_stall() {
    if let Some(plan) = current() {
        if plan.queue_stall_ms > 0 {
            std::thread::sleep(Duration::from_millis(plan.queue_stall_ms));
        }
    }
}

/// Hook: XOR mask for one encrypted word during an integrity re-hash.
/// Returns 0 (identity) unless `flip_word:p` fires, in which case a
/// single random bit is set. The stored words are never mutated — the
/// flip perturbs only the checksum computation, modelling a corrupted
/// read.
pub fn flip_word_mask() -> u64 {
    match current() {
        Some(plan) if plan.flip_word_p > 0.0 && draw_unit() < plan.flip_word_p => {
            1u64 << (draw_u64() % 64)
        }
        _ => 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // NOTE: these tests never call arm()/disarm() — fault state is
    // process-global and the lib test binary runs tests concurrently,
    // so arming here would perturb unrelated engine tests. Arm/disarm
    // behaviour is exercised end-to-end in rust/tests/chaos.rs, which
    // is its own process and serializes via a global mutex.

    #[test]
    fn parse_full_spec() {
        let p = FaultPlan::parse("panic_shard:0.25,slow_layer:40,flip_word:1.0,queue_stall:300")
            .unwrap();
        assert_eq!(p.panic_shard_p, 0.25);
        assert_eq!(p.slow_layer_ms, 40);
        assert_eq!(p.flip_word_p, 1.0);
        assert_eq!(p.queue_stall_ms, 300);
        assert!(!p.is_empty());
    }

    #[test]
    fn parse_subset_and_whitespace() {
        let p = FaultPlan::parse(" slow_layer: 15 , queue_stall:0 ").unwrap();
        assert_eq!(p.slow_layer_ms, 15);
        assert_eq!(p.queue_stall_ms, 0);
        assert_eq!(p.panic_shard_p, 0.0);
        let empty = FaultPlan::parse("").unwrap();
        assert!(empty.is_empty());
    }

    #[test]
    fn parse_rejects_bad_specs() {
        assert!(FaultPlan::parse("panic_shard").is_err());
        assert!(FaultPlan::parse("panic_shard:1.5").is_err());
        assert!(FaultPlan::parse("panic_shard:-0.1").is_err());
        assert!(FaultPlan::parse("slow_layer:abc").is_err());
        assert!(FaultPlan::parse("warp_core:0.5").is_err());
    }

    #[test]
    fn draws_are_uniformish() {
        // sanity only: the splitmix64 stream should not be constant and
        // should stay in [0,1).
        let mut lo = 0usize;
        for _ in 0..1000 {
            let u = draw_unit();
            assert!((0.0..1.0).contains(&u));
            if u < 0.5 {
                lo += 1;
            }
        }
        assert!(lo > 350 && lo < 650, "suspicious draw distribution: {lo}/1000 below 0.5");
    }
}
