//! `.fxr` — the encrypted checkpoint container (the paper's deployment
//! artifact: what actually ships to a device).
//!
//! Stores, per quantized layer: the XOR network `M⊕` per bit-plane, the
//! per-output-channel scales α, and the **bit-packed encrypted weights**
//! (`sign(w^e)`, column-major for the word-parallel decryptor).
//! Integrity (DESIGN.md §12): version 2 carries a vendored CRC32 per
//! section — meta and each layer — verified on the *raw bytes before
//! they are parsed*, plus the whole-payload trailer verified last; a
//! corrupted bundle is rejected at load with a structured
//! [`IntegrityError`] naming the bad section, never served. Version 1
//! (trailer-only) files still load. All multi-byte values little-endian.
//!
//! ```text
//! "FXR1" | u32 version | u32 n_layers | u32 meta_len | u32 meta_crc | meta json
//! layer*: u32 layer_len | u32 layer_crc | layer bytes:
//!         u16 name_len | name | u8 q | u8 n_in | u8 n_out | u8 flags
//!         u64 n_weights | u32 c_out
//!         plane*: n_out×u32 row masks | c_out×f32 alpha
//!                 n_in × ceil(slices/64) × u64 packed columns
//! u32 crc32(payload after magic)
//! ```
//!
//! The container's size IS the paper's storage claim; `Container::stats()`
//! reproduces Table 5's compression-ratio accounting byte-exactly.

use anyhow::{bail, ensure, Context, Result};

use super::bitpack::ColumnBits;
use super::matrix::MXor;
use super::num_slices;
use crate::substrate::json::{self, Json};

pub const MAGIC: &[u8; 4] = b"FXR1";
pub const VERSION: u32 = 2;

/// A checksum mismatch while loading a bundle: the named section's
/// stored CRC32 disagrees with the bytes on disk. Typed (unlike the
/// other `anyhow!` load errors) so callers and tests can recognize
/// corruption by its stable `integrity:` display prefix.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct IntegrityError {
    /// Which section failed: `meta`, `layer[<idx>]`, or `container`.
    pub section: String,
    /// CRC32 stored in the file.
    pub stored: u32,
    /// CRC32 computed over the bytes actually read.
    pub computed: u32,
}

impl std::fmt::Display for IntegrityError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "integrity: {} crc32 mismatch (stored {:#010x}, computed {:#010x}) — corrupt fxr",
            self.section, self.stored, self.computed
        )
    }
}

impl std::error::Error for IntegrityError {}

/// One quantized layer's encrypted payload.
#[derive(Clone, Debug)]
pub struct Layer {
    pub name: String,
    pub n_weights: usize,
    pub c_out: usize,
    /// One (M⊕, α, encrypted bits) triple per bit-plane (q = planes.len()).
    pub planes: Vec<Plane>,
}

#[derive(Clone, Debug)]
pub struct Plane {
    pub mxor: MXor,
    pub alpha: Vec<f32>,
    pub enc: ColumnBits,
}

impl Layer {
    /// Validate internal consistency (slice counts, plane agreement).
    pub fn validate(&self) -> Result<()> {
        ensure!(!self.planes.is_empty(), "layer {} has no planes", self.name);
        let n_in = self.planes[0].mxor.n_in();
        let n_out = self.planes[0].mxor.n_out();
        let slices = num_slices(self.n_weights, n_out);
        for (i, p) in self.planes.iter().enumerate() {
            ensure!(
                p.mxor.n_in() == n_in && p.mxor.n_out() == n_out,
                "layer {} plane {i}: M⊕ geometry differs across planes",
                self.name
            );
            ensure!(
                p.alpha.len() == self.c_out,
                "layer {} plane {i}: alpha len {} != c_out {}",
                self.name,
                p.alpha.len(),
                self.c_out
            );
            ensure!(
                p.enc.width() == n_in && p.enc.slices() == slices,
                "layer {} plane {i}: encrypted bits {}×{} != {}×{}",
                self.name,
                p.enc.slices(),
                p.enc.width(),
                slices,
                n_in
            );
        }
        Ok(())
    }

    pub fn q(&self) -> usize {
        self.planes.len()
    }

    pub fn n_in(&self) -> usize {
        self.planes[0].mxor.n_in()
    }

    pub fn n_out(&self) -> usize {
        self.planes[0].mxor.n_out()
    }

    /// Stored encrypted bits (the paper's "bits" numerator).
    pub fn stored_bits(&self) -> usize {
        self.q() * num_slices(self.n_weights, self.n_out()) * self.n_in()
    }

    pub fn bits_per_weight(&self) -> f64 {
        self.stored_bits() as f64 / self.n_weights as f64
    }
}

/// A full encrypted checkpoint.
#[derive(Clone, Debug)]
pub struct Container {
    pub meta: Json,
    pub layers: Vec<Layer>,
}

/// Storage accounting for the container (Table 5's columns).
#[derive(Clone, Debug, PartialEq)]
pub struct Stats {
    pub total_weights: usize,
    pub encrypted_bits: usize,
    pub alpha_bits: usize,
    pub mxor_bits: usize,
    pub bits_per_weight: f64,
    pub compression_ratio_weights_only: f64,
    pub compression_ratio_with_alpha: f64,
}

impl Container {
    pub fn new(meta: Json) -> Self {
        Container { meta, layers: Vec::new() }
    }

    pub fn push(&mut self, layer: Layer) -> Result<()> {
        layer.validate()?;
        ensure!(
            !self.layers.iter().any(|l| l.name == layer.name),
            "duplicate layer name {}",
            layer.name
        );
        self.layers.push(layer);
        Ok(())
    }

    pub fn stats(&self) -> Stats {
        let total_weights: usize = self.layers.iter().map(|l| l.n_weights).sum();
        let encrypted_bits: usize = self.layers.iter().map(|l| l.stored_bits()).sum();
        let alpha_bits: usize =
            self.layers.iter().map(|l| 32 * l.q() * l.c_out).sum();
        let mxor_bits: usize = self
            .layers
            .iter()
            .map(|l| l.q() * l.n_out() * l.n_in())
            .sum();
        Stats {
            total_weights,
            encrypted_bits,
            alpha_bits,
            mxor_bits,
            bits_per_weight: encrypted_bits as f64 / total_weights.max(1) as f64,
            compression_ratio_weights_only: 32.0 * total_weights as f64
                / encrypted_bits.max(1) as f64,
            compression_ratio_with_alpha: 32.0 * total_weights as f64
                / (encrypted_bits + alpha_bits).max(1) as f64,
        }
    }

    // ---- serialization ------------------------------------------------------

    pub fn to_bytes(&self) -> Vec<u8> {
        let mut b: Vec<u8> = Vec::new();
        b.extend_from_slice(MAGIC);
        b.extend_from_slice(&VERSION.to_le_bytes());
        b.extend_from_slice(&(self.layers.len() as u32).to_le_bytes());
        let meta = self.meta.to_string();
        b.extend_from_slice(&(meta.len() as u32).to_le_bytes());
        b.extend_from_slice(&crc32(meta.as_bytes()).to_le_bytes());
        b.extend_from_slice(meta.as_bytes());
        for l in &self.layers {
            let body = layer_bytes(l);
            b.extend_from_slice(&(body.len() as u32).to_le_bytes());
            b.extend_from_slice(&crc32(&body).to_le_bytes());
            b.extend_from_slice(&body);
        }
        let crc = crc32(&b[4..]);
        b.extend_from_slice(&crc.to_le_bytes());
        b
    }

    /// Serialize in the legacy v1 layout: no per-section checksums, the
    /// whole-payload CRC trailer only. Kept as a real writer (not just
    /// test scaffolding) so compatibility fixtures — old-format bundles
    /// pushed through the signed repo and the serving stack — can be
    /// minted anywhere.
    pub fn to_bytes_v1(&self) -> Vec<u8> {
        let mut b: Vec<u8> = Vec::new();
        b.extend_from_slice(MAGIC);
        b.extend_from_slice(&1u32.to_le_bytes());
        b.extend_from_slice(&(self.layers.len() as u32).to_le_bytes());
        let meta = self.meta.to_string();
        b.extend_from_slice(&(meta.len() as u32).to_le_bytes());
        b.extend_from_slice(meta.as_bytes());
        for l in &self.layers {
            b.extend_from_slice(&layer_bytes(l));
        }
        let crc = crc32(&b[4..]);
        b.extend_from_slice(&crc.to_le_bytes());
        b
    }

    pub fn from_bytes(bytes: &[u8]) -> Result<Self> {
        ensure!(bytes.len() >= 16, "truncated fxr");
        ensure!(&bytes[..4] == MAGIC, "bad magic");
        let crc_stored = u32::from_le_bytes(bytes[bytes.len() - 4..].try_into()?);
        let payload = &bytes[4..bytes.len() - 4];
        ensure!(payload.len() >= 4, "truncated fxr");
        let version = u32::from_le_bytes(payload[..4].try_into()?);
        ensure!(version == 1 || version == VERSION, "unsupported fxr version {version}");
        if version == 1 {
            // legacy files have only the trailer; nothing else can vouch
            // for the bytes, so verify it before parsing anything
            ensure!(crc32(payload) == crc_stored, "crc mismatch (corrupt fxr)");
        }

        let mut r = Reader { b: payload, i: 4 };
        let n_layers = r.u32()? as usize;
        let meta_len = r.u32()? as usize;
        let meta_crc = if version >= 2 { Some(r.u32()?) } else { None };
        let meta_bytes = r.take(meta_len)?;
        if let Some(stored) = meta_crc {
            let computed = crc32(meta_bytes);
            if computed != stored {
                return Err(
                    IntegrityError { section: "meta".to_string(), stored, computed }.into()
                );
            }
        }
        let meta = json::parse(std::str::from_utf8(meta_bytes)?)
            .context("fxr meta json")?;

        let mut layers = Vec::with_capacity(n_layers);
        for idx in 0..n_layers {
            let layer = if version >= 2 {
                // section checksum guards the raw bytes *before* the
                // parser touches them, so corruption surfaces as a
                // structured integrity error, not a downstream parse
                // failure
                let layer_len = r.u32()? as usize;
                let stored = r.u32()?;
                let body = r.take(layer_len)?;
                let computed = crc32(body);
                if computed != stored {
                    return Err(IntegrityError {
                        section: format!("layer[{idx}]"),
                        stored,
                        computed,
                    }
                    .into());
                }
                let mut lr = Reader { b: body, i: 0 };
                let layer = parse_layer(&mut lr)?;
                ensure!(lr.i == body.len(), "trailing bytes in fxr layer section");
                layer
            } else {
                parse_layer(&mut r)?
            };
            layer.validate()?;
            layers.push(layer);
        }
        ensure!(r.i == payload.len(), "trailing bytes in fxr");
        if version >= 2 {
            // whole-payload trailer last: section checks give precise
            // blame, the trailer catches header/length-field damage
            let computed = crc32(payload);
            if computed != crc_stored {
                return Err(IntegrityError {
                    section: "container".to_string(),
                    stored: crc_stored,
                    computed,
                }
                .into());
            }
        }
        Ok(Container { meta, layers })
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.to_bytes())
            .with_context(|| format!("writing {}", path.display()))
    }

    pub fn load(path: &std::path::Path) -> Result<Self> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::from_bytes(&bytes)
    }
}

struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.i + n > self.b.len() {
            bail!("truncated fxr at offset {}", self.i);
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into()?))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into()?))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into()?))
    }
}

/// Serialize one layer's body (everything between the section header and
/// the next section) exactly as v1 laid it out inline.
fn layer_bytes(l: &Layer) -> Vec<u8> {
    let mut b: Vec<u8> = Vec::new();
    b.extend_from_slice(&(l.name.len() as u16).to_le_bytes());
    b.extend_from_slice(l.name.as_bytes());
    b.push(l.q() as u8);
    b.push(l.n_in() as u8);
    b.push(l.n_out() as u8);
    b.push(0); // flags
    b.extend_from_slice(&(l.n_weights as u64).to_le_bytes());
    b.extend_from_slice(&(l.c_out as u32).to_le_bytes());
    for p in &l.planes {
        for r in 0..p.mxor.n_out() {
            b.extend_from_slice(&p.mxor.row_mask(r).to_le_bytes());
        }
        for &a in &p.alpha {
            b.extend_from_slice(&a.to_le_bytes());
        }
        for j in 0..p.enc.width() {
            b.extend_from_slice(&p.enc.column(j).to_bytes());
        }
    }
    b
}

/// Parse one layer body; shared by the v1 inline path and the v2
/// per-section path.
fn parse_layer(r: &mut Reader) -> Result<Layer> {
    let name_len = r.u16()? as usize;
    let name = String::from_utf8(r.take(name_len)?.to_vec())?;
    let q = r.u8()? as usize;
    let n_in = r.u8()? as usize;
    let n_out = r.u8()? as usize;
    let _flags = r.u8()?;
    let n_weights = r.u64()? as usize;
    let c_out = r.u32()? as usize;
    ensure!(q >= 1 && n_in >= 1 && n_out >= n_in, "bad layer header");
    let slices = num_slices(n_weights, n_out);
    let col_bytes = slices.div_ceil(64) * 8;
    let mut planes = Vec::with_capacity(q);
    for _ in 0..q {
        let mut masks = Vec::with_capacity(n_out);
        for _ in 0..n_out {
            masks.push(r.u32()?);
        }
        let mxor = MXor::from_masks(n_in, masks)?;
        let mut alpha = Vec::with_capacity(c_out);
        for _ in 0..c_out {
            alpha.push(f32::from_le_bytes(r.take(4)?.try_into()?));
        }
        let mut enc = ColumnBits::zeros(slices, n_in);
        for j in 0..n_in {
            let col = super::bitpack::BitVec::from_bytes(slices, r.take(col_bytes)?)?;
            *enc.column_mut(j) = col;
        }
        planes.push(Plane { mxor, alpha, enc });
    }
    Ok(Layer { name, n_weights, c_out, planes })
}

/// CRC-32 (IEEE 802.3, reflected), table-driven.
pub fn crc32(bytes: &[u8]) -> u32 {
    static TABLE: std::sync::OnceLock<[u32; 256]> = std::sync::OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 == 1 { 0xEDB88320 ^ (c >> 1) } else { c >> 1 };
            }
            *e = c;
        }
        t
    });
    let mut c = !0u32;
    for &b in bytes {
        c = table[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

/// Streaming FNV-1a 64-bit hasher. The encrypted engine fingerprints
/// panel words with this at load and re-checks before each GEMM; FNV is
/// a few shifts and a multiply per word, cheap enough to run hot.
#[derive(Clone, Copy, Debug)]
pub struct Fnv64(u64);

impl Fnv64 {
    const OFFSET: u64 = 0xcbf29ce484222325;
    const PRIME: u64 = 0x100000001b3;

    pub fn new() -> Self {
        Fnv64(Self::OFFSET)
    }

    pub fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(Self::PRIME);
        }
    }

    pub fn write_u64(&mut self, v: u64) {
        self.write(&v.to_le_bytes());
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv64 {
    fn default() -> Self {
        Self::new()
    }
}

/// One-shot FNV-1a 64 over a byte slice.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = Fnv64::new();
    h.write(bytes);
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::prng::Pcg32;

    fn sample_layer(rng: &mut Pcg32, name: &str, q: usize, n_weights: usize) -> Layer {
        let (n_in, n_out, c_out) = (8, 10, 4);
        let slices = num_slices(n_weights, n_out);
        let planes = (0..q)
            .map(|_| {
                let mxor = MXor::with_ntap(n_out, n_in, 2, rng).unwrap();
                let alpha = (0..c_out).map(|_| rng.range_f32(0.05, 0.5)).collect();
                let bits: Vec<u8> =
                    (0..slices * n_in).map(|_| rng.bernoulli(0.5) as u8).collect();
                let enc = ColumnBits::from_row_major(&bits, n_in).unwrap();
                Plane { mxor, alpha, enc }
            })
            .collect();
        Layer { name: name.to_string(), n_weights, c_out, planes }
    }

    #[test]
    fn crc32_known_vector() {
        assert_eq!(crc32(b"123456789"), 0xCBF43926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn roundtrip() {
        let mut rng = Pcg32::seeded(1);
        let mut c = Container::new(Json::obj(vec![("model", Json::str("toy"))]));
        c.push(sample_layer(&mut rng, "conv1", 1, 123)).unwrap();
        c.push(sample_layer(&mut rng, "conv2", 2, 999)).unwrap();
        let bytes = c.to_bytes();
        let back = Container::from_bytes(&bytes).unwrap();
        assert_eq!(back.layers.len(), 2);
        assert_eq!(back.meta.get("model").as_str(), Some("toy"));
        for (a, b) in c.layers.iter().zip(&back.layers) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.n_weights, b.n_weights);
            assert_eq!(a.c_out, b.c_out);
            assert_eq!(a.q(), b.q());
            for (pa, pb) in a.planes.iter().zip(&b.planes) {
                assert_eq!(pa.mxor, pb.mxor);
                assert_eq!(pa.alpha, pb.alpha);
                assert_eq!(pa.enc, pb.enc);
            }
        }
    }

    #[test]
    fn corruption_detected() {
        let mut rng = Pcg32::seeded(2);
        let mut c = Container::new(Json::Null);
        c.push(sample_layer(&mut rng, "l", 1, 64)).unwrap();
        let mut bytes = c.to_bytes();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        let err = Container::from_bytes(&bytes).unwrap_err();
        assert!(err.to_string().contains("crc"), "{err}");
    }

    #[test]
    fn truncation_and_magic_detected() {
        let mut rng = Pcg32::seeded(3);
        let mut c = Container::new(Json::Null);
        c.push(sample_layer(&mut rng, "l", 1, 64)).unwrap();
        let bytes = c.to_bytes();
        assert!(Container::from_bytes(&bytes[..bytes.len() - 9]).is_err());
        let mut bad = bytes.clone();
        bad[0] = b'X';
        assert!(Container::from_bytes(&bad).is_err());
    }

    #[test]
    fn duplicate_layer_rejected() {
        let mut rng = Pcg32::seeded(4);
        let mut c = Container::new(Json::Null);
        c.push(sample_layer(&mut rng, "l", 1, 10)).unwrap();
        assert!(c.push(sample_layer(&mut rng, "l", 1, 10)).is_err());
    }

    #[test]
    fn stats_accounting() {
        let mut rng = Pcg32::seeded(5);
        let mut c = Container::new(Json::Null);
        c.push(sample_layer(&mut rng, "a", 1, 100)).unwrap(); // 10 slices × 8 bits
        c.push(sample_layer(&mut rng, "b", 2, 95)).unwrap(); // 2 × 10 × 8
        let st = c.stats();
        assert_eq!(st.total_weights, 195);
        assert_eq!(st.encrypted_bits, 80 + 160);
        assert_eq!(st.alpha_bits, 32 * (1 * 4 + 2 * 4));
        assert!((st.bits_per_weight - 240.0 / 195.0).abs() < 1e-12);
        assert!(
            (st.compression_ratio_weights_only - 32.0 * 195.0 / 240.0).abs() < 1e-9
        );
        assert!(st.compression_ratio_with_alpha < st.compression_ratio_weights_only);
    }

    #[test]
    fn layer_validate_rejects_mismatches() {
        let mut rng = Pcg32::seeded(6);
        let mut l = sample_layer(&mut rng, "x", 1, 100);
        l.planes[0].alpha.pop();
        assert!(l.validate().is_err());
        let mut l2 = sample_layer(&mut rng, "y", 2, 100);
        l2.planes[1].mxor = MXor::with_ntap(12, 8, 2, &mut rng).unwrap();
        assert!(l2.validate().is_err());
    }

    #[test]
    fn fnv_known_vectors() {
        assert_eq!(fnv1a64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a64(b"a"), 0xaf63dc4c8601ec8c);
        let mut h = Fnv64::new();
        h.write(b"a");
        assert_eq!(h.finish(), fnv1a64(b"a"));
        let mut w = Fnv64::new();
        w.write_u64(0x6162636465666768);
        assert_eq!(w.finish(), fnv1a64(b"hgfedcba"));
    }

    #[test]
    fn v2_corruption_blames_the_right_section() {
        let mut rng = Pcg32::seeded(8);
        let mut c = Container::new(Json::obj(vec![("model", Json::str("toy"))]));
        c.push(sample_layer(&mut rng, "l", 1, 64)).unwrap();
        let bytes = c.to_bytes();
        let meta_len =
            u32::from_le_bytes(bytes[12..16].try_into().unwrap()) as usize;

        // flip a byte inside the meta json
        let mut bad = bytes.clone();
        bad[20] ^= 0xFF;
        let err = Container::from_bytes(&bad).unwrap_err();
        assert!(err.to_string().contains("integrity: meta"), "{err}");

        // flip a byte inside the first layer body (skip its len+crc prefix)
        let mut bad = bytes.clone();
        bad[20 + meta_len + 8] ^= 0xFF;
        let err = Container::from_bytes(&bad).unwrap_err();
        assert!(err.to_string().contains("integrity: layer[0]"), "{err}");

        // damage only the whole-payload trailer: sections verify, the
        // container check catches it last
        let mut bad = bytes.clone();
        let n = bad.len();
        bad[n - 1] ^= 0xFF;
        let err = Container::from_bytes(&bad).unwrap_err();
        assert!(err.to_string().contains("integrity: container"), "{err}");
    }

    /// v1 files (no per-section checksums, whole-payload trailer only)
    /// must keep loading, via the legacy writer itself.
    #[test]
    fn v1_files_still_load() {
        let mut rng = Pcg32::seeded(9);
        let mut c = Container::new(Json::obj(vec![("model", Json::str("old"))]));
        c.push(sample_layer(&mut rng, "conv1", 2, 123)).unwrap();

        let b = c.to_bytes_v1();
        // v1 payloads are strictly smaller: no meta crc, no per-layer
        // len+crc prefixes
        assert!(b.len() < c.to_bytes().len());

        let back = Container::from_bytes(&b).unwrap();
        assert_eq!(back.meta.get("model").as_str(), Some("old"));
        assert_eq!(back.layers.len(), 1);
        assert_eq!(back.layers[0].name, "conv1");
        assert_eq!(back.layers[0].n_weights, 123);
        for (pa, pb) in c.layers[0].planes.iter().zip(&back.layers[0].planes) {
            assert_eq!(pa.mxor, pb.mxor);
            assert_eq!(pa.alpha, pb.alpha);
            assert_eq!(pa.enc, pb.enc);
        }

        // ...and a corrupt v1 file is still rejected via the trailer
        let mid = b.len() / 2;
        let mut bad = b.clone();
        bad[mid] ^= 0xFF;
        let err = Container::from_bytes(&bad).unwrap_err();
        assert!(err.to_string().contains("crc"), "{err}");
    }

    #[test]
    fn file_save_load() {
        let mut rng = Pcg32::seeded(7);
        let mut c = Container::new(Json::obj(vec![("k", Json::num(1))]));
        c.push(sample_layer(&mut rng, "l", 1, 50)).unwrap();
        let path = std::env::temp_dir().join("flexor_test_roundtrip.fxr");
        c.save(&path).unwrap();
        let back = Container::load(&path).unwrap();
        assert_eq!(back.layers[0].n_weights, 50);
        std::fs::remove_file(&path).ok();
    }
}
