//! Binary-code arithmetic: `W ≈ Σ_{i=1}^q α_i b_i` (paper §1, Fig. 1).
//!
//! Two compute paths, both used by the inference engine:
//! * [`reconstruct_dense`] — materialize the f32 weight tensor once at
//!   load time (what a CPU GEMM wants);
//! * [`dot_binary`] / [`BinaryCodeMatrix`] — the paper's multiply-free
//!   form: per bit-plane, the dot product is a signed accumulation
//!   (add where bit=+1, subtract where −1), then `q` scalar multiplies by
//!   α. This is what the decrypt bench measures to back Fig. 1's
//!   "v multiplies → q multiplies" claim.

use anyhow::{ensure, Result};

use super::bitpack::BitVec;

/// Reconstruct dense weights from q ±1 bit-planes and per-output-channel
/// scales. `planes[i]` has `n` entries (row-major with the **last axis** =
/// output channel, matching the Python layout), `alpha[i]` has `c_out`.
pub fn reconstruct_dense(
    planes: &[Vec<f32>],
    alpha: &[Vec<f32>],
    c_out: usize,
) -> Result<Vec<f32>> {
    ensure!(!planes.is_empty(), "no bit planes");
    ensure!(planes.len() == alpha.len(), "planes/alpha count mismatch");
    let n = planes[0].len();
    ensure!(n % c_out == 0, "n {n} not divisible by c_out {c_out}");
    ensure!(
        planes.iter().all(|p| p.len() == n),
        "ragged bit planes"
    );
    ensure!(alpha.iter().all(|a| a.len() == c_out), "alpha width mismatch");
    let mut w = vec![0.0f32; n];
    for (plane, al) in planes.iter().zip(alpha) {
        for (i, &b) in plane.iter().enumerate() {
            w[i] += b * al[i % c_out];
        }
    }
    Ok(w)
}

/// Multiply-free binary dot product: `Σ_j a_j b_j` with `b` a packed ±1
/// vector (bit 1 ⇔ −1). One pass of adds/subtracts — zero multiplies.
pub fn dot_binary(a: &[f32], bits: &BitVec) -> f32 {
    debug_assert_eq!(a.len(), bits.len());
    // Σ a_j b_j = Σ a_j − 2 Σ_{bit=1} a_j.  The negative-lane sum is
    // branchless (multiply by the extracted 0/1 bit) — with ~50% bit
    // density this beats the popcount-style set-bit iteration by >2×
    // (EXPERIMENTS.md §Perf) because there are no mispredicted branches
    // and no random-index loads.
    let total: f32 = a.iter().sum();
    total - 2.0 * neg_lane_sum(a, bits)
}

/// Σ_{j: bit_j=1} a_j — the branchless inner kernel shared by dot_binary
/// and the matvec (which hoists the Σa term out of its column loop).
#[inline]
fn neg_lane_sum(a: &[f32], bits: &BitVec) -> f32 {
    let mut neg = 0.0f32;
    for (w_idx, &word) in bits.words().iter().enumerate() {
        let base = w_idx * 64;
        let lane = &a[base..(base + 64).min(a.len())];
        // index-based bit extraction: no loop-carried shift dependency, so
        // the compiler can vectorize the multiply-accumulate
        for (k, &v) in lane.iter().enumerate() {
            neg += v * ((word >> k) & 1) as f32;
        }
    }
    neg
}

/// A (v × c) weight matrix held as q packed bit-planes + scales — the
/// paper's storage/compute format for a quantized FC layer.
#[derive(Clone, Debug)]
pub struct BinaryCodeMatrix {
    pub v: usize,
    pub c: usize,
    /// planes[i][col] = packed column (length v) of bit-plane i.
    planes: Vec<Vec<BitVec>>,
    /// alpha[i][col]
    alpha: Vec<Vec<f32>>,
}

impl BinaryCodeMatrix {
    /// Build from row-major ±1 planes (`planes[i][row*c + col]`).
    pub fn from_planes(
        v: usize,
        c: usize,
        planes: &[Vec<f32>],
        alpha: &[Vec<f32>],
    ) -> Result<Self> {
        ensure!(!planes.is_empty() && planes.len() == alpha.len());
        ensure!(planes.iter().all(|p| p.len() == v * c), "plane size mismatch");
        ensure!(alpha.iter().all(|a| a.len() == c), "alpha size mismatch");
        let mut packed = Vec::with_capacity(planes.len());
        for plane in planes {
            let mut cols = Vec::with_capacity(c);
            for col in 0..c {
                let mut bv = BitVec::zeros(v);
                for row in 0..v {
                    if plane[row * c + col] < 0.0 {
                        bv.set(row, true);
                    }
                }
                cols.push(bv);
            }
            packed.push(cols);
        }
        Ok(BinaryCodeMatrix { v, c, planes: packed, alpha: alpha.to_vec() })
    }

    /// `out[col] = Σ_i α_i[col] · (a · b_i[col])` — Fig. 1's computation:
    /// q multiplies per output instead of v.
    pub fn matvec(&self, a: &[f32]) -> Result<Vec<f32>> {
        ensure!(a.len() == self.v, "input length {} != v {}", a.len(), self.v);
        let total: f32 = a.iter().sum(); // hoisted out of the column loop
        let mut out = vec![0.0f32; self.c];
        for (plane, al) in self.planes.iter().zip(&self.alpha) {
            for (col, bits) in plane.iter().enumerate() {
                out[col] += al[col] * (total - 2.0 * neg_lane_sum(a, bits));
            }
        }
        Ok(out)
    }

    pub fn q(&self) -> usize {
        self.planes.len()
    }

    /// Stored bits for the quantized planes (excludes α).
    pub fn stored_bits(&self) -> usize {
        self.q() * self.v * self.c
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::prng::Pcg32;
    use crate::substrate::ptest::check_msg;

    #[test]
    fn reconstruct_q1() {
        // 4 weights, 2 out channels, plane [+1,-1,-1,+1], alpha [2, 3]
        let w = reconstruct_dense(
            &[vec![1.0, -1.0, -1.0, 1.0]],
            &[vec![2.0, 3.0]],
            2,
        )
        .unwrap();
        assert_eq!(w, vec![2.0, -3.0, -2.0, 3.0]);
    }

    #[test]
    fn reconstruct_q2_sums_planes() {
        let w = reconstruct_dense(
            &[vec![1.0, 1.0], vec![-1.0, 1.0]],
            &[vec![1.0], vec![0.25]],
            1,
        )
        .unwrap();
        assert_eq!(w, vec![0.75, 1.25]);
    }

    #[test]
    fn reconstruct_validation() {
        assert!(reconstruct_dense(&[], &[], 1).is_err());
        assert!(reconstruct_dense(&[vec![1.0; 4]], &[vec![1.0; 3]], 3).is_err());
        assert!(
            reconstruct_dense(&[vec![1.0; 4], vec![1.0; 5]], &[vec![1.0], vec![1.0]], 1)
                .is_err()
        );
    }

    #[test]
    fn dot_binary_matches_dense() {
        check_msg("dot_binary == dense dot", 80, |g| {
            let n = g.usize_in(1, 300);
            let a: Vec<f32> = (0..n).map(|_| g.normal()).collect();
            let signs: Vec<f32> =
                (0..n).map(|_| if g.bool() { 1.0 } else { -1.0 }).collect();
            let bits = BitVec::from_signs(&signs);
            let want: f32 = a.iter().zip(&signs).map(|(x, s)| x * s).sum();
            let got = dot_binary(&a, &bits);
            if (got - want).abs() > 1e-3 * (1.0 + want.abs()) {
                return Err(format!("{got} vs {want}"));
            }
            Ok(())
        });
    }

    #[test]
    fn matvec_matches_dense_gemv() {
        let mut rng = Pcg32::seeded(9);
        let (v, c, q) = (37, 5, 2);
        let planes: Vec<Vec<f32>> = (0..q)
            .map(|_| (0..v * c).map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 }).collect())
            .collect();
        let alpha: Vec<Vec<f32>> = (0..q)
            .map(|_| (0..c).map(|_| rng.range_f32(0.1, 1.0)).collect())
            .collect();
        let a: Vec<f32> = (0..v).map(|_| rng.normal()).collect();

        let m = BinaryCodeMatrix::from_planes(v, c, &planes, &alpha).unwrap();
        let got = m.matvec(&a).unwrap();

        // dense reference
        let mut want = vec![0.0f32; c];
        for i in 0..q {
            for col in 0..c {
                let mut acc = 0.0;
                for row in 0..v {
                    acc += a[row] * planes[i][row * c + col];
                }
                want[col] += alpha[i][col] * acc;
            }
        }
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-3, "{g} vs {w}");
        }
        assert_eq!(m.q(), 2);
        assert_eq!(m.stored_bits(), 2 * v * c);
    }

    #[test]
    fn matvec_validates_input_len() {
        let m = BinaryCodeMatrix::from_planes(
            4,
            1,
            &[vec![1.0, 1.0, 1.0, 1.0]],
            &[vec![1.0]],
        )
        .unwrap();
        assert!(m.matvec(&[1.0, 2.0]).is_err());
    }
}
