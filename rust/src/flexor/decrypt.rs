//! The bit-level XOR decryption engine (paper Fig. 3 / Algorithm 1).
//!
//! The paper decrypts each `N_in`-bit slice through a shared XOR-gate
//! network — "best implemented by ASIC or FPGA". The CPU-native analogue
//! is **word-parallel GF(2)**: encrypted bits are stored column-major
//! ([`ColumnBits`]), so producing quantized output bit `r` for 64 slices at
//! once is `N_tap` 64-bit XORs plus an optional complement (the Eq. 4
//! `(-1)^{n-1}` parity) — exactly the parallel-gate structure, 64 gates per
//! instruction.
//!
//! Two engines are provided:
//! * [`Decryptor::decrypt_columns`] — the fast word-parallel path;
//! * [`Decryptor::decrypt_scalar`] — a per-slice reference implementation
//!   (mask + popcount), used for cross-checking and as the clarity-first
//!   description of Algorithm 1.
//!
//! Both return "negative bits" (1 ⇔ quantized weight bit is −1), matching
//! the Python `decrypt_bits` convention; `to_signs()` recovers ±1.

use std::ops::Range;

use anyhow::{ensure, Result};

use super::bitpack::{BitVec, ColumnBits};
use super::matrix::MXor;

/// A decryption engine bound to one XOR-gate network.
#[derive(Clone, Debug)]
pub struct Decryptor {
    mxor: MXor,
    /// Per-row parity (Eq. 4's (-1)^{n_tap−1} as a complement bit).
    parity: Vec<bool>,
}

impl Decryptor {
    pub fn new(mxor: MXor) -> Self {
        let parity = (0..mxor.n_out()).map(|r| mxor.parity_bit(r)).collect();
        Decryptor { mxor, parity }
    }

    pub fn mxor(&self) -> &MXor {
        &self.mxor
    }

    /// Word-parallel decrypt: 64 slices per XOR instruction.
    ///
    /// `enc` must have width `N_in`; returns width-`N_out` columns over the
    /// same slice count.
    pub fn decrypt_columns(&self, enc: &ColumnBits) -> Result<ColumnBits> {
        ensure!(
            enc.width() == self.mxor.n_in(),
            "encrypted width {} != N_in {}",
            enc.width(),
            self.mxor.n_in()
        );
        let slices = enc.slices();
        let n_words = slices.div_ceil(64);
        let mut out = ColumnBits::zeros(slices, self.mxor.n_out());
        for r in 0..self.mxor.n_out() {
            let mask = self.mxor.row_mask(r);
            // XOR the tap columns word-by-word.
            let out_col = out.column_mut(r);
            {
                let words = out_col.words_mut();
                let mut taps = mask;
                while taps != 0 {
                    let j = taps.trailing_zeros() as usize;
                    taps &= taps - 1;
                    let src = enc.column(j).words();
                    for w in 0..n_words {
                        words[w] ^= src[w];
                    }
                }
                if self.parity[r] {
                    for w in words.iter_mut() {
                        *w = !*w;
                    }
                    // clear padding bits past `slices`
                    if slices % 64 != 0 {
                        let keep = (1u64 << (slices % 64)) - 1;
                        *words.last_mut().unwrap() &= keep;
                    }
                }
            }
        }
        Ok(out)
    }

    /// Per-slice reference decrypt (Algorithm 1 as literal bit ops).
    pub fn decrypt_scalar(&self, enc: &ColumnBits) -> Result<ColumnBits> {
        ensure!(enc.width() == self.mxor.n_in(), "width mismatch");
        let mut out = ColumnBits::zeros(enc.slices(), self.mxor.n_out());
        for s in 0..enc.slices() {
            let mut x = 0u32;
            for j in 0..enc.width() {
                if enc.get(s, j) {
                    x |= 1 << j;
                }
            }
            let y = self.mxor.decrypt_slice(x);
            for r in 0..self.mxor.n_out() {
                if (y >> r) & 1 == 1 {
                    out.set(s, r, true);
                }
            }
        }
        Ok(out)
    }

    /// Decrypt and flatten to ±1 signs, cropped to `n_weights`
    /// (slice-major: slice 0's N_out bits, then slice 1's, ... — the
    /// "reshape" of Fig. 3).
    pub fn decrypt_to_signs(&self, enc: &ColumnBits, n_weights: usize) -> Result<Vec<f32>> {
        let cols = self.decrypt_columns(enc)?;
        let n_out = self.mxor.n_out();
        let slices = cols.slices();
        ensure!(
            n_weights <= slices * n_out,
            "n_weights {} exceeds decrypted bits {}",
            n_weights,
            slices * n_out
        );
        let mut signs = vec![1.0f32; n_weights];
        for_each_weight_bit(&cols, n_weights, |i, bit| {
            // branchless ±1: 1 - 2*bit
            signs[i] = 1.0 - 2.0 * (bit as i32 as f32);
        });
        Ok(signs)
    }

    /// Decrypt and repack straight into **per-output-channel bit-plane
    /// rows** for the bit-slice compute engine (DESIGN.md §8) — the FP
    /// signs are never materialized.
    ///
    /// Quantized weights are row-major with the **last axis = output
    /// channel** (the Python layout), so weight `i` of a `(k × c_out)`
    /// GEMM right-hand side lives at reduction row `i / c_out` of output
    /// channel `i % c_out`. Returns `c_out` [`BitVec`]s of length
    /// `k = n_weights / c_out`; bit `t` of channel `j` is 1 ⇔ weight
    /// `(t, j)` decrypts to −1 (the crate-wide bit convention).
    ///
    /// The full-range case of [`Decryptor::decrypt_panel_rows`], so both
    /// materialization paths share one walk and can never disagree on
    /// the crop / block-boundary geometry.
    pub fn decrypt_to_plane_rows(
        &self,
        enc: &ColumnBits,
        n_weights: usize,
        c_out: usize,
    ) -> Result<Vec<BitVec>> {
        ensure!(c_out > 0, "c_out must be positive");
        self.decrypt_panel_rows(enc, n_weights, c_out, 0..c_out)
    }

    /// Decrypt only the output channels `cols` of a `(k × c_out)`
    /// quantized weight — the panel-granular entry point of the
    /// decrypt-on-demand engine (`ComputeMode::Encrypted`, DESIGN.md
    /// §11). Returns `cols.len()` [`BitVec`]s of length
    /// `k = n_weights / c_out`, matching the corresponding slice of
    /// [`Decryptor::decrypt_to_plane_rows`] bit-for-bit.
    ///
    /// Because one channel's bits stride through the **entire**
    /// encrypted stream (weight `t·c_out + j` lives at slice
    /// `(t·c_out + j) / n_out`), the walk still scans every slice — but
    /// it materializes only a transient 64-slice block of decrypted
    /// words ([`Self::decrypt_block`]-style: `N_tap` XORs + parity
    /// complement per column) and scatters just the requested channels'
    /// bits. No full decrypted plane ever exists.
    pub fn decrypt_panel_rows(
        &self,
        enc: &ColumnBits,
        n_weights: usize,
        c_out: usize,
        cols: Range<usize>,
    ) -> Result<Vec<BitVec>> {
        ensure!(c_out > 0, "c_out must be positive");
        ensure!(
            n_weights % c_out == 0,
            "n_weights {n_weights} not divisible by c_out {c_out}"
        );
        let jw = cols.len();
        let k = n_weights / c_out;
        let wpr = k.div_ceil(64);
        let mut buf = vec![0u64; wpr * jw];
        self.decrypt_panel_into(enc, n_weights, c_out, cols, jw.max(1), &mut buf)?;
        let mut rows = Vec::with_capacity(jw);
        for jj in 0..jw {
            let mut bv = BitVec::zeros(k);
            let words = bv.words_mut();
            for (w, word) in words.iter_mut().enumerate() {
                *word = buf[w * jw + jj];
            }
            rows.push(bv);
        }
        Ok(rows)
    }

    /// [`Decryptor::decrypt_panel_rows`] straight into an interleaved
    /// panel scratch tile — the hot-loop form the encrypted XNOR GEMM
    /// consumes (`inference::bitslice::encrypted`). Channel
    /// `cols.start + jj` lands at slot `jj` with word stride `stride`
    /// (`dst[w·stride + jj]` = word `w` of that channel), the exact
    /// [`PlaneStore`](crate::inference::bitslice::PlaneStore) panel
    /// layout when `stride` = NR. `dst` must be `⌈k/64⌉ · stride` words
    /// and is fully overwritten (slots past `cols` zeroed), so dirty
    /// arena buffers are fine.
    pub fn decrypt_panel_into(
        &self,
        enc: &ColumnBits,
        n_weights: usize,
        c_out: usize,
        cols: Range<usize>,
        stride: usize,
        dst: &mut [u64],
    ) -> Result<()> {
        ensure!(c_out > 0, "c_out must be positive");
        ensure!(
            n_weights % c_out == 0,
            "n_weights {n_weights} not divisible by c_out {c_out}"
        );
        ensure!(
            enc.width() == self.mxor.n_in(),
            "encrypted width {} != N_in {}",
            enc.width(),
            self.mxor.n_in()
        );
        ensure!(
            cols.start < cols.end && cols.end <= c_out,
            "bad channel range {cols:?} for c_out {c_out}"
        );
        ensure!(cols.len() <= stride, "channel range wider than panel stride");
        let n_out = self.mxor.n_out();
        let slices = enc.slices();
        ensure!(
            n_weights <= slices * n_out,
            "n_weights {} exceeds decrypted bits {}",
            n_weights,
            slices * n_out
        );
        let k = n_weights / c_out;
        let wpr = k.div_ceil(64);
        ensure!(
            dst.len() == wpr * stride,
            "dst is {} words, panel needs {wpr} x {stride}",
            dst.len()
        );
        dst.fill(0);
        let (c0, c1) = (cols.start, cols.end);

        // transient per-64-slice block of decrypted words (one per
        // output column) — the only decrypted state that ever exists
        let mut stack = [0u64; 64];
        let mut heap: Vec<u64>;
        let words: &mut [u64] = if n_out <= stack.len() {
            &mut stack[..n_out]
        } else {
            heap = vec![0u64; n_out];
            &mut heap
        };

        // incremental (reduction row t, channel j) walk over the
        // slice-major weight order — no per-bit div/mod
        let mut t = 0usize;
        let mut j = 0usize;
        let mut i = 0usize;
        'blocks: for blk in 0..slices.div_ceil(64) {
            self.decrypt_block(enc, blk, words);
            let s_end = (blk * 64 + 64).min(slices);
            for s in blk * 64..s_end {
                if i >= n_weights {
                    break 'blocks;
                }
                let shift = (s % 64) as u32;
                let r_end = n_out.min(n_weights - i);
                for &w in words[..r_end].iter() {
                    if j >= c0 && j < c1 {
                        let bit = (w >> shift) & 1;
                        dst[(t / 64) * stride + (j - c0)] |= bit << (t % 64);
                    }
                    j += 1;
                    if j == c_out {
                        j = 0;
                        t += 1;
                    }
                }
                i += r_end;
            }
        }
        Ok(())
    }

    /// Decrypt 64-slice block `blk` of every output column at once:
    /// `words[r]` gets column `r`'s decrypted word (tap XORs + parity
    /// complement, padding bits past `slices` kept clear). The
    /// word-level primitive behind the panel walk — same math as
    /// [`Decryptor::decrypt_columns`], one block at a time.
    fn decrypt_block(&self, enc: &ColumnBits, blk: usize, words: &mut [u64]) {
        let slices = enc.slices();
        let tail_mask = if (blk + 1) * 64 > slices && slices % 64 != 0 {
            (1u64 << (slices % 64)) - 1
        } else {
            u64::MAX
        };
        for (r, out) in words.iter_mut().enumerate() {
            let mut acc = 0u64;
            let mut taps = self.mxor.row_mask(r);
            while taps != 0 {
                let j = taps.trailing_zeros() as usize;
                taps &= taps - 1;
                acc ^= enc.column(j).words()[blk];
            }
            if self.parity[r] {
                acc = !acc & tail_mask;
            }
            *out = acc;
        }
    }

    /// Decrypted bits per stored bit — the decompression "gain".
    pub fn expansion(&self) -> f64 {
        self.mxor.n_out() as f64 / self.mxor.n_in() as f64
    }

    /// XOR 2-input gate count for one slice (ASIC cost model): each row
    /// needs `n_tap − 1` two-input XOR gates, plus an inverter when the
    /// parity bit is set. Returns (xor_gates, inverters).
    pub fn gate_cost(&self) -> (usize, usize) {
        let mut xors = 0;
        let mut invs = 0;
        for r in 0..self.mxor.n_out() {
            xors += self.mxor.n_tap(r).saturating_sub(1);
            invs += self.parity[r] as usize;
        }
        (xors, invs)
    }

    /// Critical-path depth in gate levels (balanced XOR tree per row).
    pub fn gate_depth(&self) -> usize {
        (0..self.mxor.n_out())
            .map(|r| {
                let t = self.mxor.n_tap(r);
                if t <= 1 {
                    0
                } else {
                    (usize::BITS - (t - 1).leading_zeros()) as usize
                }
            })
            .max()
            .unwrap_or(0)
    }
}

/// The block-transposed walk over decrypted quantized bits in weight
/// order (the "reshape" of Fig. 3, slice-major: slice 0's N_out bits,
/// then slice 1's, …): loads each output column's word once per
/// 64-slice block instead of a div/mod bit lookup per weight, and calls
/// `f(weight_index, bit)` for weights `0..n_weights`. The single
/// iteration shared by `decrypt_to_signs` and `decrypt_to_plane_rows`,
/// so the two materialization paths can never disagree on the crop /
/// block-boundary geometry.
fn for_each_weight_bit(cols: &ColumnBits, n_weights: usize, mut f: impl FnMut(usize, bool)) {
    let n_out = cols.width();
    let slices = cols.slices();
    // hard assert (not debug_assert): a geometry violation here means a
    // corrupt or mis-validated layer, and reading past the decrypted
    // bits would silently produce wrong weights in release builds; the
    // serving worker contains the panic (DESIGN.md §12)
    assert!(
        n_weights <= slices * n_out,
        "integrity: {n_weights} weights exceed {slices}×{n_out} decrypted bits"
    );
    let mut words = vec![0u64; n_out];
    for blk in 0..slices.div_ceil(64) {
        for (r, w) in words.iter_mut().enumerate() {
            *w = cols.column(r).words()[blk];
        }
        let s_end = (blk * 64 + 64).min(slices);
        for s in blk * 64..s_end {
            let shift = (s % 64) as u32;
            let base = s * n_out;
            if base >= n_weights {
                return;
            }
            let r_end = n_out.min(n_weights - base);
            for (r, &w) in words[..r_end].iter().enumerate() {
                f(base + r, (w >> shift) & 1 == 1);
            }
        }
    }
}

/// Pack a row-major encrypted sign tensor `(slices × N_in)` for decryption.
pub fn pack_encrypted(signs: &[f32], n_in: usize) -> Result<ColumnBits> {
    ColumnBits::from_signs_row_major(signs, n_in)
}

/// One-call helper: decrypt encrypted signs straight to quantized ±1 bits.
pub fn decrypt_signs(
    mxor: &MXor,
    enc_signs: &[f32],
    n_weights: usize,
) -> Result<Vec<f32>> {
    let enc = pack_encrypted(enc_signs, mxor.n_in())?;
    Decryptor::new(mxor.clone()).decrypt_to_signs(&enc, n_weights)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::prng::Pcg32;
    use crate::substrate::ptest::check_msg;

    fn rand_enc(rng: &mut Pcg32, slices: usize, n_in: usize) -> ColumnBits {
        let bits: Vec<u8> = (0..slices * n_in).map(|_| rng.bernoulli(0.5) as u8).collect();
        ColumnBits::from_row_major(&bits, n_in).unwrap()
    }

    #[test]
    fn word_parallel_matches_scalar() {
        check_msg("decrypt_columns == decrypt_scalar", 60, |g| {
            let n_in = g.usize_in(1, 25);
            let n_out = n_in + g.usize_in(0, 13);
            let slices = g.usize_in(1, 400);
            let mxor = if g.bool() {
                MXor::random(n_out, n_in, g.rng()).unwrap()
            } else {
                let t = 1 + g.usize_in(0, n_in.min(3));
                MXor::with_ntap(n_out, n_in, t, g.rng()).unwrap()
            };
            let enc = rand_enc(g.rng(), slices, n_in);
            let d = Decryptor::new(mxor);
            let fast = d.decrypt_columns(&enc).map_err(|e| e.to_string())?;
            let slow = d.decrypt_scalar(&enc).map_err(|e| e.to_string())?;
            if fast != slow {
                return Err("engines disagree".into());
            }
            Ok(())
        });
    }

    #[test]
    fn parity_complement_padding_mask_at_partial_words() {
        // The word-parallel engine complements whole 64-bit words for
        // parity rows, then masks the bits past `slices` back to zero.
        // Force that path: every even-tap row has the parity bit set, and
        // slice counts are deliberately NOT multiples of 64 so the last
        // word is partial. Cross-check against the scalar engine and
        // assert the padding bits really are clear (equality of packed
        // words would otherwise diverge even when visible bits agree).
        check_msg("parity rows keep padding bits clear", 60, |g| {
            let n_in = g.usize_in(2, 18);
            let n_out = n_in + g.usize_in(1, 10);
            let full_words = g.usize_in(0, 4);
            let slices = full_words * 64 + g.usize_in(1, 64); // ≢ 0 (mod 64)
            // n_tap = 2 ⇒ (-1)^{n_tap-1} = -1 on every row: all-parity M⊕
            let mxor = MXor::with_ntap(n_out, n_in, 2, g.rng()).unwrap();
            let d = Decryptor::new(mxor);
            let enc = rand_enc(g.rng(), slices, n_in);

            let fast = d.decrypt_columns(&enc).map_err(|e| e.to_string())?;
            let slow = d.decrypt_scalar(&enc).map_err(|e| e.to_string())?;
            if fast != slow {
                return Err(format!(
                    "engines disagree at slices={slices} n_in={n_in} n_out={n_out}"
                ));
            }
            for r in 0..n_out {
                let last = *fast.column(r).words().last().unwrap();
                if last >> (slices % 64) != 0 {
                    return Err(format!(
                        "row {r}: nonzero padding bits above slice {slices}"
                    ));
                }
            }
            // the complemented columns must still round-trip through the
            // byte serialization (which rejects dirty padding)
            let col0 = fast.column(0);
            crate::flexor::bitpack::BitVec::from_bytes(slices, &col0.to_bytes())
                .map_err(|e| format!("serialization rejected column: {e}"))?;
            Ok(())
        });
    }

    #[test]
    fn matches_pm1_product_semantics() {
        // Directly verify Eq. (4): y_r = (-1)^{n-1} ∏ sign(x_j).
        let mut rng = Pcg32::seeded(3);
        let mxor = MXor::random(10, 6, &mut rng).unwrap();
        let enc = rand_enc(&mut rng, 77, 6);
        let out = Decryptor::new(mxor.clone()).decrypt_columns(&enc).unwrap();
        for s in 0..77 {
            for r in 0..10 {
                let mut prod = 1.0f32;
                for j in 0..6 {
                    if mxor.row_mask(r) >> j & 1 == 1 {
                        prod *= if enc.get(s, j) { -1.0 } else { 1.0 };
                    }
                }
                let want = if (mxor.n_tap(r) - 1) % 2 == 1 { -prod } else { prod };
                let got = if out.get(s, r) { -1.0 } else { 1.0 };
                assert_eq!(got, want, "slice {s} row {r}");
            }
        }
    }

    #[test]
    fn gf2_linearity_property() {
        // In the bit domain (bit = sign<0), decrypt is affine over GF(2):
        // D(x ⊕ y) = D(x) ⊕ D(y) ⊕ D(0)  (D(0) = the parity constants).
        check_msg("decrypt is GF(2)-affine", 40, |g| {
            let n_in = g.usize_in(1, 20);
            let n_out = n_in + g.usize_in(0, 10);
            let mxor = MXor::random(n_out, n_in, g.rng()).unwrap();
            let x = g.u32(1 << n_in.min(31));
            let y = g.u32(1 << n_in.min(31));
            let dx = mxor.decrypt_slice(x);
            let dy = mxor.decrypt_slice(y);
            let d0 = mxor.decrypt_slice(0);
            let dxy = mxor.decrypt_slice(x ^ y);
            if dxy != dx ^ dy ^ d0 {
                return Err(format!("affinity broken: x={x:b} y={y:b}"));
            }
            Ok(())
        });
    }

    #[test]
    fn decrypt_to_signs_matches_per_bit_lookup() {
        // the block-transposed fast path vs a literal per-bit materialization
        check_msg("decrypt_to_signs == per-bit", 30, |g| {
            let n_in = g.usize_in(1, 16);
            let n_out = n_in + g.usize_in(0, 8);
            let slices = g.usize_in(1, 300);
            let mxor = MXor::with_ntap(n_out, n_in, 1 + g.usize_in(0, n_in.min(2)), g.rng()).unwrap();
            let enc = rand_enc(g.rng(), slices, n_in);
            let d = Decryptor::new(mxor);
            let n_weights = g.usize_in(1, slices * n_out + 1).min(slices * n_out);
            let fast = d.decrypt_to_signs(&enc, n_weights).map_err(|e| e.to_string())?;
            let cols = d.decrypt_columns(&enc).map_err(|e| e.to_string())?;
            for (i, &s) in fast.iter().enumerate() {
                let want = if cols.get(i / n_out, i % n_out) { -1.0 } else { 1.0 };
                if s != want {
                    return Err(format!("weight {i}: {s} vs {want}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn decrypt_to_plane_rows_matches_signs_repack() {
        // the no-FP repack path must agree bit-for-bit with materializing
        // signs and packing them per output channel
        check_msg("decrypt_to_plane_rows == signs repack", 30, |g| {
            let n_in = g.usize_in(1, 12);
            let n_out = n_in + g.usize_in(0, 8);
            let c_out = 1 + g.usize_in(0, 7);
            let k = 1 + g.usize_in(0, 90);
            let n_weights = k * c_out;
            let slices = crate::flexor::num_slices(n_weights, n_out);
            let mxor =
                MXor::with_ntap(n_out, n_in, 1 + g.usize_in(0, n_in.min(2)), g.rng())
                    .unwrap();
            let enc = rand_enc(g.rng(), slices, n_in);
            let d = Decryptor::new(mxor);
            let rows = d
                .decrypt_to_plane_rows(&enc, n_weights, c_out)
                .map_err(|e| e.to_string())?;
            if rows.len() != c_out || rows.iter().any(|r| r.len() != k) {
                return Err("wrong plane-row geometry".into());
            }
            let signs = d.decrypt_to_signs(&enc, n_weights).map_err(|e| e.to_string())?;
            for (i, &s) in signs.iter().enumerate() {
                let want = s < 0.0;
                if rows[i % c_out].get(i / c_out) != want {
                    return Err(format!(
                        "weight {i} (row {}, ch {}): {want} mismatch",
                        i / c_out,
                        i % c_out
                    ));
                }
            }
            Ok(())
        });
    }

    /// Satellite property: panel-by-panel decryption concatenated over
    /// ragged NR-width panels equals the full-range decrypt AND the
    /// independent signs oracle (random geometry, c_out rarely divisible
    /// by the panel width).
    #[test]
    fn decrypt_panel_rows_concat_matches_full_decrypt() {
        check_msg("panel concat == full decrypt == signs", 30, |g| {
            let n_in = g.usize_in(1, 12);
            let n_out = n_in + g.usize_in(0, 8);
            let c_out = 1 + g.usize_in(0, 21); // ragged vs panel width 8
            let k = 1 + g.usize_in(0, 150);
            let n_weights = k * c_out;
            let slices = crate::flexor::num_slices(n_weights, n_out);
            let mxor =
                MXor::with_ntap(n_out, n_in, 1 + g.usize_in(0, n_in.min(2)), g.rng())
                    .unwrap();
            let enc = rand_enc(g.rng(), slices, n_in);
            let d = Decryptor::new(mxor);
            let full = d
                .decrypt_to_plane_rows(&enc, n_weights, c_out)
                .map_err(|e| e.to_string())?;
            let signs = d.decrypt_to_signs(&enc, n_weights).map_err(|e| e.to_string())?;
            let mut got = Vec::with_capacity(c_out);
            for j0 in (0..c_out).step_by(8) {
                let j1 = (j0 + 8).min(c_out);
                let panel = d
                    .decrypt_panel_rows(&enc, n_weights, c_out, j0..j1)
                    .map_err(|e| e.to_string())?;
                if panel.len() != j1 - j0 {
                    return Err(format!("panel {j0}..{j1}: {} rows", panel.len()));
                }
                got.extend(panel);
            }
            if got != full {
                return Err(format!(
                    "panel concat != full decrypt (c_out={c_out} k={k})"
                ));
            }
            for (i, &s) in signs.iter().enumerate() {
                if got[i % c_out].get(i / c_out) != (s < 0.0) {
                    return Err(format!("weight {i} disagrees with signs oracle"));
                }
            }
            Ok(())
        });
    }

    /// Satellite property: the panel walk at k straddling u64 word
    /// boundaries, under an all-parity M⊕ (every row complements whole
    /// words, so the padding-mask edge the full-bundle parity test
    /// covers is exercised panel-by-panel too).
    #[test]
    fn decrypt_panel_rows_at_word_boundary_k_with_all_parity_rows() {
        let mut rng = Pcg32::seeded(23);
        for k in [1usize, 63, 64, 65, 127, 128] {
            for c_out in [5usize, 8, 11] {
                let (n_in, n_out) = (6, 10);
                let n_weights = k * c_out;
                let slices = crate::flexor::num_slices(n_weights, n_out);
                // n_tap = 2 ⇒ parity complement on every row
                let mxor = MXor::with_ntap(n_out, n_in, 2, &mut rng).unwrap();
                let enc = rand_enc(&mut rng, slices, n_in);
                let d = Decryptor::new(mxor);
                let signs = d.decrypt_to_signs(&enc, n_weights).unwrap();
                for j0 in (0..c_out).step_by(8) {
                    let j1 = (j0 + 8).min(c_out);
                    let rows =
                        d.decrypt_panel_rows(&enc, n_weights, c_out, j0..j1).unwrap();
                    for (jj, row) in rows.iter().enumerate() {
                        assert_eq!(row.len(), k);
                        // padding bits above k must be clear (serialization
                        // would reject them)
                        BitVec::from_bytes(k, &row.to_bytes()).unwrap();
                        for t in 0..k {
                            let want = signs[t * c_out + j0 + jj] < 0.0;
                            assert_eq!(
                                row.get(t),
                                want,
                                "k={k} c_out={c_out} ch {} bit {t}",
                                j0 + jj
                            );
                        }
                    }
                }
            }
        }
    }

    /// The interleaved hot-loop form writes the PlaneStore panel layout:
    /// `dst[w·stride + jj]` = word `w` of channel `cols.start + jj`,
    /// padding slots zeroed even when the buffer starts dirty.
    #[test]
    fn decrypt_panel_into_interleaved_layout() {
        let mut rng = Pcg32::seeded(29);
        let (n_in, n_out, c_out, k) = (6, 10, 11, 70);
        let n_weights = k * c_out;
        let slices = crate::flexor::num_slices(n_weights, n_out);
        let mxor = MXor::with_ntap(n_out, n_in, 2, &mut rng).unwrap();
        let enc = rand_enc(&mut rng, slices, n_in);
        let d = Decryptor::new(mxor);
        let stride = 8usize;
        let wpr = k.div_ceil(64);
        for j0 in (0..c_out).step_by(stride) {
            let j1 = (j0 + stride).min(c_out);
            let jw = j1 - j0;
            let mut dst = vec![u64::MAX; wpr * stride]; // deliberately dirty
            d.decrypt_panel_into(&enc, n_weights, c_out, j0..j1, stride, &mut dst)
                .unwrap();
            let rows = d.decrypt_panel_rows(&enc, n_weights, c_out, j0..j1).unwrap();
            for w in 0..wpr {
                for jj in 0..stride {
                    let want = if jj < jw { rows[jj].words()[w] } else { 0 };
                    assert_eq!(
                        dst[w * stride + jj],
                        want,
                        "panel {j0}..{j1} word {w} slot {jj}"
                    );
                }
            }
        }
    }

    #[test]
    fn decrypt_panel_rows_validates() {
        let mut rng = Pcg32::seeded(31);
        let mxor = MXor::with_ntap(10, 8, 2, &mut rng).unwrap();
        let enc = rand_enc(&mut rng, 13, 8);
        let d = Decryptor::new(mxor);
        assert!(d.decrypt_panel_rows(&enc, 95, 5, 0..5).is_ok());
        assert!(d.decrypt_panel_rows(&enc, 95, 5, 3..5).is_ok());
        assert!(d.decrypt_panel_rows(&enc, 95, 5, 3..6).is_err()); // past c_out
        assert!(d.decrypt_panel_rows(&enc, 95, 5, 3..3).is_err()); // empty range
        assert!(d.decrypt_panel_rows(&enc, 95, 4, 0..4).is_err()); // not divisible
        assert!(d.decrypt_panel_rows(&enc, 140, 5, 0..5).is_err()); // > 130 bits
        let mut dst = vec![0u64; 3];
        assert!(d
            .decrypt_panel_into(&enc, 95, 5, 0..5, 8, &mut dst)
            .is_err()); // wrong dst len
        assert!(d
            .decrypt_panel_into(&enc, 95, 5, 0..5, 4, &mut dst)
            .is_err()); // range wider than stride
    }

    #[test]
    fn decrypt_to_plane_rows_validates() {
        let mut rng = Pcg32::seeded(11);
        let mxor = MXor::with_ntap(10, 8, 2, &mut rng).unwrap();
        let enc = rand_enc(&mut rng, 13, 8);
        let d = Decryptor::new(mxor);
        assert!(d.decrypt_to_plane_rows(&enc, 95, 5).is_ok());
        assert!(d.decrypt_to_plane_rows(&enc, 95, 4).is_err()); // not divisible
        assert!(d.decrypt_to_plane_rows(&enc, 95, 0).is_err());
        assert!(d.decrypt_to_plane_rows(&enc, 140, 5).is_err()); // > 130 bits
    }

    #[test]
    fn decrypt_to_signs_crops() {
        let mut rng = Pcg32::seeded(5);
        let mxor = MXor::with_ntap(10, 8, 2, &mut rng).unwrap();
        let enc = rand_enc(&mut rng, 13, 8);
        let d = Decryptor::new(mxor);
        let signs = d.decrypt_to_signs(&enc, 95).unwrap();
        assert_eq!(signs.len(), 95);
        assert!(signs.iter().all(|&s| s == 1.0 || s == -1.0));
        assert!(d.decrypt_to_signs(&enc, 131).is_err()); // 13*10 = 130 max
    }

    #[test]
    fn width_mismatch_rejected() {
        let mut rng = Pcg32::seeded(6);
        let mxor = MXor::with_ntap(10, 8, 2, &mut rng).unwrap();
        let enc = rand_enc(&mut rng, 4, 6);
        assert!(Decryptor::new(mxor).decrypt_columns(&enc).is_err());
    }

    #[test]
    fn gate_cost_model() {
        let mxor = MXor::from_rows(&[
            vec![1, 1, 0, 0], // 2 taps: 1 xor, parity → 1 inv
            vec![1, 1, 1, 0], // 3 taps: 2 xors, no inv
            vec![1, 0, 0, 0], // 1 tap: 0 xors, no inv
        ])
        .unwrap();
        let d = Decryptor::new(mxor);
        assert_eq!(d.gate_cost(), (3, 1));
        // deepest row has 3 taps → balanced XOR tree depth ⌈log2 3⌉ = 2
        assert_eq!(d.gate_depth(), 2);
    }

    #[test]
    fn expansion_ratio() {
        let mut rng = Pcg32::seeded(7);
        let d = Decryptor::new(MXor::with_ntap(20, 8, 2, &mut rng).unwrap());
        assert_eq!(d.expansion(), 2.5);
    }
}
