//! Packed bit vectors — the storage format of encrypted weights.
//!
//! Convention throughout the crate: **bit = 1 ⇔ the stored real value is
//! negative** (sign −1); bit = 0 ⇔ sign +1. This matches the Python side's
//! `neg = (1 − sign)/2` and makes GF(2) XOR equal to sign multiplication
//! in the ±1 domain.

use anyhow::{ensure, Result};

/// A fixed-length bit vector packed into `u64` words (LSB-first).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BitVec {
    len: usize,
    words: Vec<u64>,
}

impl BitVec {
    pub fn zeros(len: usize) -> Self {
        BitVec { len, words: vec![0; len.div_ceil(64)] }
    }

    /// Pack from sign values: negative → 1, non-negative → 0.
    pub fn from_signs(signs: &[f32]) -> Self {
        let mut bv = BitVec::zeros(signs.len());
        for (i, &s) in signs.iter().enumerate() {
            if s < 0.0 {
                bv.set(i, true);
            }
        }
        bv
    }

    /// Pack from 0/1 bytes.
    pub fn from_bits(bits: &[u8]) -> Self {
        let mut bv = BitVec::zeros(bits.len());
        for (i, &b) in bits.iter().enumerate() {
            if b != 0 {
                bv.set(i, true);
            }
        }
        bv
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        (self.words[i / 64] >> (i % 64)) & 1 == 1
    }

    #[inline]
    pub fn set(&mut self, i: usize, v: bool) {
        debug_assert!(i < self.len);
        let (w, b) = (i / 64, i % 64);
        if v {
            self.words[w] |= 1 << b;
        } else {
            self.words[w] &= !(1 << b);
        }
    }

    pub fn count_ones(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Unpack to ±1 signs (bit 1 → −1.0).
    pub fn to_signs(&self) -> Vec<f32> {
        (0..self.len).map(|i| if self.get(i) { -1.0 } else { 1.0 }).collect()
    }

    pub fn words(&self) -> &[u64] {
        &self.words
    }

    pub fn words_mut(&mut self) -> &mut [u64] {
        &mut self.words
    }

    /// Raw little-endian byte serialization (length NOT included).
    pub fn to_bytes(&self) -> Vec<u8> {
        self.words.iter().flat_map(|w| w.to_le_bytes()).collect()
    }

    /// Rebuild from `to_bytes` output and an explicit bit length.
    pub fn from_bytes(len: usize, bytes: &[u8]) -> Result<Self> {
        let n_words = len.div_ceil(64);
        ensure!(bytes.len() == n_words * 8, "bitvec byte length mismatch");
        let words = bytes
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect::<Vec<_>>();
        // ensure padding bits are zero so equality/count work
        if len % 64 != 0 {
            if let Some(&last) = words.last() {
                ensure!(
                    last >> (len % 64) == 0,
                    "nonzero padding bits in serialized bitvec"
                );
            }
        }
        Ok(BitVec { len, words })
    }
}

/// A slice-major bit matrix: `slices` rows of `width` bits each, stored
/// **column-major** (one BitVec of length `slices` per column). This is the
/// layout the decryption engine wants: decrypting output bit `r` for 64
/// slices is a handful of whole-word XORs over tap columns.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ColumnBits {
    slices: usize,
    columns: Vec<BitVec>,
}

impl ColumnBits {
    pub fn zeros(slices: usize, width: usize) -> Self {
        ColumnBits { slices, columns: vec![BitVec::zeros(slices); width] }
    }

    /// Build from row-major bits: `bits[s*width + j]` is slice `s`, col `j`.
    pub fn from_row_major(bits: &[u8], width: usize) -> Result<Self> {
        ensure!(width > 0, "zero width");
        ensure!(bits.len() % width == 0, "bits not a multiple of width");
        let slices = bits.len() / width;
        let mut cb = ColumnBits::zeros(slices, width);
        for s in 0..slices {
            for j in 0..width {
                if bits[s * width + j] != 0 {
                    cb.columns[j].set(s, true);
                }
            }
        }
        Ok(cb)
    }

    /// Build from a row-major sign array (negative → bit 1).
    pub fn from_signs_row_major(signs: &[f32], width: usize) -> Result<Self> {
        let bits: Vec<u8> = signs.iter().map(|&s| (s < 0.0) as u8).collect();
        Self::from_row_major(&bits, width)
    }

    pub fn slices(&self) -> usize {
        self.slices
    }

    pub fn width(&self) -> usize {
        self.columns.len()
    }

    pub fn column(&self, j: usize) -> &BitVec {
        &self.columns[j]
    }

    pub fn column_mut(&mut self, j: usize) -> &mut BitVec {
        &mut self.columns[j]
    }

    pub fn get(&self, slice: usize, j: usize) -> bool {
        self.columns[j].get(slice)
    }

    pub fn set(&mut self, slice: usize, j: usize, v: bool) {
        self.columns[j].set(slice, v);
    }

    /// Flatten back to row-major 0/1 bytes.
    pub fn to_row_major(&self) -> Vec<u8> {
        let mut out = vec![0u8; self.slices * self.width()];
        for (j, col) in self.columns.iter().enumerate() {
            for s in 0..self.slices {
                out[s * self.width() + j] = col.get(s) as u8;
            }
        }
        out
    }

    /// Total stored bits (slices × width).
    pub fn bit_count(&self) -> usize {
        self.slices * self.width()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::ptest::{check, Gen};

    #[test]
    fn set_get_count() {
        let mut bv = BitVec::zeros(130);
        bv.set(0, true);
        bv.set(64, true);
        bv.set(129, true);
        assert!(bv.get(0) && bv.get(64) && bv.get(129));
        assert!(!bv.get(1));
        assert_eq!(bv.count_ones(), 3);
        bv.set(64, false);
        assert_eq!(bv.count_ones(), 2);
    }

    #[test]
    fn signs_roundtrip() {
        let signs = vec![1.0, -1.0, -1.0, 1.0, -0.0, 1.0, -3.5];
        let bv = BitVec::from_signs(&signs);
        let back = bv.to_signs();
        // -0.0 is not < 0, so it packs as +1
        assert_eq!(back, vec![1.0, -1.0, -1.0, 1.0, 1.0, 1.0, -1.0]);
    }

    #[test]
    fn bytes_roundtrip() {
        check("bitvec bytes roundtrip", 50, |g: &mut Gen| {
            let n = g.usize_in(1, 500);
            let mut bv = BitVec::zeros(n);
            for i in 0..n {
                if g.bool() {
                    bv.set(i, true);
                }
            }
            BitVec::from_bytes(n, &bv.to_bytes()).unwrap() == bv
        });
    }

    #[test]
    fn bytes_rejects_bad_padding() {
        let bv = BitVec::from_bits(&[1, 1, 1]);
        let mut bytes = bv.to_bytes();
        bytes[1] = 0xFF; // set bits beyond len
        assert!(BitVec::from_bytes(3, &bytes).is_err());
        assert!(BitVec::from_bytes(5, &bytes[..4]).is_err()); // wrong size
    }

    #[test]
    fn column_bits_roundtrip() {
        check("column bits row-major roundtrip", 50, |g: &mut Gen| {
            let width = g.usize_in(1, 24);
            let slices = g.usize_in(1, 200);
            let bits: Vec<u8> = (0..width * slices).map(|_| g.bool() as u8).collect();
            let cb = ColumnBits::from_row_major(&bits, width).unwrap();
            cb.to_row_major() == bits && cb.slices() == slices && cb.width() == width
        });
    }

    #[test]
    fn column_bits_indexing() {
        let bits = vec![1, 0, 0, 1, 1, 1]; // 3 slices × 2 cols
        let cb = ColumnBits::from_row_major(&bits, 2).unwrap();
        assert!(cb.get(0, 0) && !cb.get(0, 1));
        assert!(!cb.get(1, 0) && cb.get(1, 1));
        assert!(cb.get(2, 0) && cb.get(2, 1));
        assert_eq!(cb.column(0).count_ones(), 2);
        assert_eq!(cb.bit_count(), 6);
    }

    #[test]
    fn column_bits_validation() {
        assert!(ColumnBits::from_row_major(&[1, 0, 1], 2).is_err());
        assert!(ColumnBits::from_row_major(&[], 0).is_err());
    }
}
