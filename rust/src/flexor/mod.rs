//! FleXOR core: the paper's encryption/decryption system in Rust.
//!
//! * [`matrix`] — the XOR-gate network `M⊕` (construction, Hamming
//!   analysis, JSON interop with the Python compile path);
//! * [`bitpack`] — packed bit vectors (the storage format of encrypted
//!   weights);
//! * [`decrypt`] — the bit-level decryption engine (word-parallel GF(2)
//!   mat-vec: 64 slices per XOR op — the CPU analogue of the paper's
//!   parallel XOR gates);
//! * [`binarycodes`] — binary-code weight reconstruction `Σ α_i b_i` and
//!   multiply-free dot products;
//! * [`fxr`] — the `.fxr` encrypted checkpoint container;
//! * [`analysis`] — output-diversity / compression / gate-cost models
//!   backing the paper's §2 claims.

pub mod matrix;
pub mod search;
pub mod bitpack;
pub mod decrypt;
pub mod binarycodes;
pub mod fxr;
pub mod analysis;

pub use bitpack::BitVec;
pub use decrypt::Decryptor;
pub use matrix::MXor;

/// Effective fractional rate: `q · N_in / N_out` bits per weight.
pub fn bits_per_weight(q: usize, n_in: usize, n_out: usize) -> f64 {
    q as f64 * n_in as f64 / n_out as f64
}

/// Number of `N_out`-bit slices covering `n_weights` quantized bits.
pub fn num_slices(n_weights: usize, n_out: usize) -> usize {
    n_weights.div_ceil(n_out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rate_math() {
        assert_eq!(bits_per_weight(1, 8, 10), 0.8);
        assert_eq!(bits_per_weight(2, 8, 20), 0.8);
        assert_eq!(num_slices(100, 10), 10);
        assert_eq!(num_slices(101, 10), 11);
    }
}
