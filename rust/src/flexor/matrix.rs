//! The XOR-gate network `M⊕ ∈ {0,1}^{N_out×N_in}` (paper §2).
//!
//! Rows are stored as `u32` tap masks (`N_in ≤ 32` everywhere in the paper;
//! enforced), which makes the decryption engine's inner loop a handful of
//! word ops. Matrices interop with the Python compile path through the
//! row-list JSON in `artifacts/<cfg>/meta.json`, so training and Rust
//! inference are guaranteed to use the identical network.

use anyhow::{bail, ensure, Result};

use crate::substrate::json::Json;
use crate::substrate::prng::Pcg32;

/// Maximum supported `N_in` (paper uses ≤ 20).
pub const MAX_N_IN: usize = 32;

/// An XOR-gate network: `y = M⊕ x` over GF(2).
#[derive(Clone, Debug, PartialEq)]
pub struct MXor {
    n_out: usize,
    n_in: usize,
    /// Tap mask per output row; bit `j` set ⇔ input `x_j` feeds this row.
    rows: Vec<u32>,
}

impl MXor {
    /// Build from explicit tap masks.
    pub fn from_masks(n_in: usize, rows: Vec<u32>) -> Result<Self> {
        ensure!(n_in >= 1 && n_in <= MAX_N_IN, "n_in {n_in} out of range");
        ensure!(!rows.is_empty(), "M⊕ needs at least one row");
        let valid = if n_in == 32 { u32::MAX } else { (1u32 << n_in) - 1 };
        for (r, &m) in rows.iter().enumerate() {
            ensure!(m & !valid == 0, "row {r} has taps beyond n_in");
            ensure!(m != 0, "row {r} is all-zero (decodes a constant)");
        }
        Ok(MXor { n_out: rows.len(), n_in, rows })
    }

    /// Build from a dense 0/1 row-major matrix (the meta.json layout).
    pub fn from_rows(rows01: &[Vec<u8>]) -> Result<Self> {
        ensure!(!rows01.is_empty(), "empty M⊕");
        let n_in = rows01[0].len();
        let mut masks = Vec::with_capacity(rows01.len());
        for (i, row) in rows01.iter().enumerate() {
            ensure!(row.len() == n_in, "ragged row {i}");
            let mut m = 0u32;
            for (j, &v) in row.iter().enumerate() {
                match v {
                    0 => {}
                    1 => m |= 1 << j,
                    _ => bail!("row {i} has non-binary entry {v}"),
                }
            }
            masks.push(m);
        }
        Self::from_masks(n_in, masks)
    }

    /// Parse the meta.json serialization: `[[0,1,...], ...]`.
    pub fn from_json(v: &Json) -> Result<Self> {
        let rows = v.as_arr().ok_or_else(|| anyhow::anyhow!("M⊕ not an array"))?;
        let mut rows01 = Vec::with_capacity(rows.len());
        for r in rows {
            let row = r
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("M⊕ row not an array"))?
                .iter()
                .map(|x| x.as_i64().unwrap_or(-1) as u8)
                .collect::<Vec<_>>();
            rows01.push(row);
        }
        Self::from_rows(&rows01)
    }

    pub fn to_json(&self) -> Json {
        Json::arr(self.rows.iter().map(|&m| {
            Json::arr((0..self.n_in).map(|j| Json::num(((m >> j) & 1) as f64)))
        }))
    }

    /// Random iid-Bernoulli(1/2) fill with non-zero rows (paper Fig. 4).
    pub fn random(n_out: usize, n_in: usize, rng: &mut Pcg32) -> Result<Self> {
        ensure!(n_in >= 1 && n_in <= MAX_N_IN);
        let valid = if n_in == 32 { u32::MAX } else { (1u32 << n_in) - 1 };
        let rows = (0..n_out)
            .map(|_| loop {
                let m = rng.next_u32() & valid;
                if m != 0 {
                    break m;
                }
            })
            .collect();
        Self::from_masks(n_in, rows)
    }

    /// Exactly `n_tap` taps per row (paper §4 technique 1, `N_tap=2`).
    pub fn with_ntap(n_out: usize, n_in: usize, n_tap: usize, rng: &mut Pcg32) -> Result<Self> {
        ensure!(n_tap >= 1 && n_tap <= n_in, "n_tap {n_tap} not in [1,{n_in}]");
        let rows = (0..n_out)
            .map(|_| {
                rng.choose_k(n_in, n_tap)
                    .into_iter()
                    .fold(0u32, |m, j| m | (1 << j))
            })
            .collect();
        Self::from_masks(n_in, rows)
    }

    pub fn n_out(&self) -> usize {
        self.n_out
    }

    pub fn n_in(&self) -> usize {
        self.n_in
    }

    pub fn row_mask(&self, r: usize) -> u32 {
        self.rows[r]
    }

    pub fn rows(&self) -> &[u32] {
        &self.rows
    }

    /// Taps (number of 1s) in row `r`.
    pub fn n_tap(&self, r: usize) -> usize {
        self.rows[r].count_ones() as usize
    }

    /// The constant `(-1)^(n_tap-1)` parity bit per row (1 = flip sign).
    /// A row with even tap count has parity 1: XOR of its bits is negated
    /// in the ±1 mapping (Eq. 4's `(-1)^{n-1}` factor).
    pub fn parity_bit(&self, r: usize) -> bool {
        (self.n_tap(r) - 1) % 2 == 1
    }

    /// Decrypt a single slice given input bits (bit j of `x` = 1 ⇔ the
    /// stored sign is negative). Returns output "negative" bits.
    /// Reference semantics for the fast engine in `decrypt.rs`.
    pub fn decrypt_slice(&self, x: u32) -> u64 {
        let mut out = 0u64;
        for (r, &mask) in self.rows.iter().enumerate() {
            let parity = (x & mask).count_ones() as usize + self.n_tap(r) - 1;
            if parity % 2 == 1 {
                out |= 1 << r;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn paper_example() -> MXor {
        // Appendix A's 6×4 example.
        MXor::from_rows(&[
            vec![1, 0, 1, 1],
            vec![1, 1, 0, 0],
            vec![1, 1, 1, 0],
            vec![0, 0, 1, 1],
            vec![0, 1, 0, 1],
            vec![0, 1, 1, 1],
        ])
        .unwrap()
    }

    #[test]
    fn from_rows_masks() {
        let m = paper_example();
        assert_eq!(m.n_out(), 6);
        assert_eq!(m.n_in(), 4);
        assert_eq!(m.row_mask(0), 0b1101); // x1, x3, x4 (bit0 = x1)
        assert_eq!(m.row_mask(1), 0b0011);
        assert_eq!(m.n_tap(0), 3);
        assert_eq!(m.n_tap(1), 2);
    }

    #[test]
    fn parity_bits() {
        let m = paper_example();
        // 3 taps → (-1)^2 = +1 → no flip; 2 taps → (-1)^1 → flip.
        assert!(!m.parity_bit(0));
        assert!(m.parity_bit(1));
    }

    #[test]
    fn decrypt_slice_appendix_a() {
        // Appendix A states y = M⊕ x over GF(2) in the paper's bit
        // convention (bit 1 ↔ sign +1, "0 is replaced with -1").
        // `decrypt_slice` uses the crate's negative-bit convention
        // (bit 1 ↔ sign −1), so convert: p = ¬x (within N_in / N_out).
        let m = paper_example();
        for p in 0u32..16 {
            let x_neg = !p & 0xF;
            let out_neg = m.decrypt_slice(x_neg);
            for (r, taps) in [(0, [0, 2, 3].as_slice()), (1, &[0, 1]), (2, &[0, 1, 2]),
                              (3, &[2, 3]), (4, &[1, 3]), (5, &[1, 2, 3])] {
                let want_pos = taps.iter().fold(0u32, |acc, &j| acc ^ ((p >> j) & 1));
                let got_pos = 1 - ((out_neg >> r) & 1);
                assert_eq!(got_pos, want_pos as u64, "p={p:04b} row {r}");
            }
        }
    }

    #[test]
    fn decrypt_slice_flips_even_tap_rows() {
        // Single row, 2 taps, input 0 ⇒ GF(2) XOR is 0 but the ±1-domain
        // convention stores the "negative" bit: (-1)^(2-1)·(+1)(+1) = -1.
        // decrypt_slice reports XOR-with-parity, i.e. bit set.
        let m = MXor::from_masks(2, vec![0b11]).unwrap();
        assert_eq!(m.decrypt_slice(0b00) & 1, 1);
        assert_eq!(m.decrypt_slice(0b01) & 1, 0);
        assert_eq!(m.decrypt_slice(0b11) & 1, 1);
    }

    #[test]
    fn rejects_bad_inputs() {
        assert!(MXor::from_masks(4, vec![0]).is_err()); // zero row
        assert!(MXor::from_masks(4, vec![0b10000]).is_err()); // tap ≥ n_in
        assert!(MXor::from_masks(0, vec![1]).is_err());
        assert!(MXor::from_masks(33, vec![1]).is_err());
        assert!(MXor::from_rows(&[vec![0, 2]]).is_err()); // non-binary
        assert!(MXor::from_rows(&[vec![1, 0], vec![1]]).is_err()); // ragged
    }

    #[test]
    fn generation_shapes_and_determinism() {
        let mut r1 = Pcg32::seeded(1);
        let mut r2 = Pcg32::seeded(1);
        let a = MXor::with_ntap(20, 8, 2, &mut r1).unwrap();
        let b = MXor::with_ntap(20, 8, 2, &mut r2).unwrap();
        assert_eq!(a, b);
        assert!(a.rows().iter().all(|m| m.count_ones() == 2));
        let c = MXor::random(20, 8, &mut r1).unwrap();
        assert!(c.rows().iter().all(|&m| m != 0 && m < (1 << 8)));
    }

    #[test]
    fn json_roundtrip() {
        let m = paper_example();
        let j = m.to_json();
        let back = MXor::from_json(&j).unwrap();
        assert_eq!(m, back);
    }
}
