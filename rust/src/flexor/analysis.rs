//! Analysis backing the paper's §2 encryption-quality arguments and the
//! "negligible overhead" claim: Hamming-distance statistics, output
//! diversity of the XOR network, and the ASIC-style gate cost/latency model.

use super::decrypt::Decryptor;
use super::matrix::MXor;
use crate::substrate::json::Json;

/// Pairwise row statistics of `M⊕` (paper Eq. (1)).
///
/// For two *linear* Boolean functions f1, f2 over {0,1}^{N_in}, the Hamming
/// distance is 0 when the tap sets are identical and 2^{N_in−1} otherwise —
/// so the informative statistics are the fraction of distinct row pairs and
/// the tap-overlap structure.
#[derive(Clone, Debug, PartialEq)]
pub struct HammingStats {
    pub n_out: usize,
    pub n_in: usize,
    pub total_pairs: usize,
    pub distinct_pairs: usize,
    pub mean_hamming: f64,
    pub mean_tap_overlap: f64,
    pub ntap_min: usize,
    pub ntap_max: usize,
}

pub fn hamming_stats(m: &MXor) -> HammingStats {
    let n_out = m.n_out();
    let n_in = m.n_in();
    let mut distinct = 0usize;
    let mut total = 0usize;
    let mut overlap_sum = 0usize;
    let mut hamming_sum = 0f64;
    let pair_dist = if n_in >= 1 { 2f64.powi(n_in as i32 - 1) } else { 0.0 };
    for i in 0..n_out {
        for j in i + 1..n_out {
            total += 1;
            let (a, b) = (m.row_mask(i), m.row_mask(j));
            if a != b {
                distinct += 1;
                hamming_sum += pair_dist;
            }
            overlap_sum += (a & b).count_ones() as usize;
        }
    }
    let ntaps: Vec<usize> = (0..n_out).map(|r| m.n_tap(r)).collect();
    HammingStats {
        n_out,
        n_in,
        total_pairs: total,
        distinct_pairs: distinct,
        mean_hamming: if total > 0 { hamming_sum / total as f64 } else { 0.0 },
        mean_tap_overlap: if total > 0 {
            overlap_sum as f64 / total as f64
        } else {
            0.0
        },
        ntap_min: ntaps.iter().copied().min().unwrap_or(0),
        ntap_max: ntaps.iter().copied().max().unwrap_or(0),
    }
}

/// Output-diversity profile: enumerate all 2^{N_in} inputs (N_in ≤ 20 in
/// practice) and measure how the decrypted N_out-bit outputs spread through
/// the 2^{N_out} space — the paper's "evenly distributed" design goal.
#[derive(Clone, Debug, PartialEq)]
pub struct DiversityStats {
    pub inputs: usize,
    /// Number of distinct decrypted outputs (≤ inputs; equality means the
    /// map is injective — the encryption loses nothing).
    pub distinct_outputs: usize,
    /// Mean pairwise Hamming distance between decrypted outputs of
    /// consecutive Gray-code inputs (sensitivity: how much one stored-bit
    /// flip shuffles the quantized bits).
    pub mean_flip_sensitivity: f64,
    /// Per-output-bit bias |P(bit=1) − 0.5| averaged over bits.
    pub mean_bit_bias: f64,
}

pub fn diversity_stats(m: &MXor) -> DiversityStats {
    assert!(m.n_in() <= 20, "diversity enumeration limited to N_in ≤ 20");
    let n = 1usize << m.n_in();
    let mut seen = std::collections::HashSet::with_capacity(n);
    let mut ones_per_bit = vec![0usize; m.n_out()];
    let mut flip_sum = 0usize;
    let mut prev: Option<u64> = None;
    for g in 0..n {
        // Gray code order: consecutive inputs differ by exactly one bit.
        let x = (g ^ (g >> 1)) as u32;
        let y = m.decrypt_slice(x);
        seen.insert(y);
        for (r, c) in ones_per_bit.iter_mut().enumerate() {
            *c += ((y >> r) & 1) as usize;
        }
        if let Some(p) = prev {
            flip_sum += (p ^ y).count_ones() as usize;
        }
        prev = Some(y);
    }
    let mean_bit_bias = ones_per_bit
        .iter()
        .map(|&c| (c as f64 / n as f64 - 0.5).abs())
        .sum::<f64>()
        / m.n_out() as f64;
    DiversityStats {
        inputs: n,
        distinct_outputs: seen.len(),
        mean_flip_sensitivity: if n > 1 {
            flip_sum as f64 / (n - 1) as f64
        } else {
            0.0
        },
        mean_bit_bias,
    }
}

/// ASIC-style overhead model for the shared XOR network (the paper cites
/// VLSI-testing work for "negligible" area/latency; this quantifies it).
#[derive(Clone, Debug, PartialEq)]
pub struct GateCost {
    pub xor_gates: usize,
    pub inverters: usize,
    pub depth_levels: usize,
    /// Gate count relative to decrypted bits per slice (gates/bit).
    pub gates_per_output_bit: f64,
}

pub fn gate_cost(m: &MXor) -> GateCost {
    let d = Decryptor::new(m.clone());
    let (xor_gates, inverters) = d.gate_cost();
    GateCost {
        xor_gates,
        inverters,
        depth_levels: d.gate_depth(),
        gates_per_output_bit: (xor_gates + inverters) as f64 / m.n_out() as f64,
    }
}

/// JSON report combining all M⊕ analyses (used by `flexor analyze`).
pub fn report(m: &MXor) -> Json {
    let h = hamming_stats(m);
    let g = gate_cost(m);
    let mut o = Json::obj(vec![
        ("n_out", Json::num(m.n_out() as f64)),
        ("n_in", Json::num(m.n_in() as f64)),
        ("expansion", Json::num(m.n_out() as f64 / m.n_in() as f64)),
        ("distinct_row_pairs", Json::num(h.distinct_pairs as f64)),
        ("total_row_pairs", Json::num(h.total_pairs as f64)),
        ("mean_hamming", Json::num(h.mean_hamming)),
        ("mean_tap_overlap", Json::num(h.mean_tap_overlap)),
        ("ntap_min", Json::num(h.ntap_min as f64)),
        ("ntap_max", Json::num(h.ntap_max as f64)),
        ("xor_gates", Json::num(g.xor_gates as f64)),
        ("inverters", Json::num(g.inverters as f64)),
        ("depth_levels", Json::num(g.depth_levels as f64)),
        ("gates_per_output_bit", Json::num(g.gates_per_output_bit)),
    ]);
    if m.n_in() <= 16 {
        let d = diversity_stats(m);
        o.set("enumerated_inputs", Json::num(d.inputs as f64));
        o.set("distinct_outputs", Json::num(d.distinct_outputs as f64));
        o.set("injective", Json::Bool(d.distinct_outputs == d.inputs));
        o.set("mean_flip_sensitivity", Json::num(d.mean_flip_sensitivity));
        o.set("mean_bit_bias", Json::num(d.mean_bit_bias));
    }
    o
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::prng::Pcg32;

    #[test]
    fn hamming_identical_vs_distinct() {
        let m = MXor::from_rows(&[vec![1, 1, 0], vec![1, 1, 0], vec![0, 1, 1]])
            .unwrap();
        let h = hamming_stats(&m);
        assert_eq!(h.total_pairs, 3);
        assert_eq!(h.distinct_pairs, 2);
        assert!((h.mean_hamming - (0.0 + 4.0 + 4.0) / 3.0).abs() < 1e-12);
        assert_eq!(h.ntap_min, 2);
        assert_eq!(h.ntap_max, 2);
    }

    #[test]
    fn diversity_full_rank_square_is_injective() {
        // identity M⊕ (N_out = N_in) is trivially injective
        let m = MXor::from_rows(&[
            vec![1, 0, 0],
            vec![0, 1, 0],
            vec![0, 0, 1],
        ])
        .unwrap();
        let d = diversity_stats(&m);
        assert_eq!(d.inputs, 8);
        assert_eq!(d.distinct_outputs, 8);
        assert_eq!(d.mean_bit_bias, 0.0);
        // one input flip flips exactly one output bit
        assert!((d.mean_flip_sensitivity - 1.0).abs() < 1e-12);
    }

    #[test]
    fn diversity_expansion_keeps_injectivity_with_good_rows() {
        // Appendix A's matrix: first 4 rows... take rows forming identityish
        let mut rng = Pcg32::seeded(1);
        let m = MXor::random(12, 8, &mut rng).unwrap();
        let d = diversity_stats(&m);
        assert_eq!(d.inputs, 256);
        assert!(d.distinct_outputs <= 256);
        // random linear map over GF(2) with n_out > n_in is injective iff
        // rank = n_in; with 12 random rows over 8 dims that is near-certain
        assert_eq!(d.distinct_outputs, 256);
        // a bit flip shuffles multiple output bits (N_tap ≈ N_in/2 taps hit)
        assert!(d.mean_flip_sensitivity > 1.5);
    }

    #[test]
    fn linearity_zero_maps_to_parity_constant() {
        // GF(2) linearity: decrypt(0) = parity constants only.
        let mut rng = Pcg32::seeded(2);
        let m = MXor::with_ntap(10, 8, 2, &mut rng).unwrap();
        let y0 = m.decrypt_slice(0);
        for r in 0..10 {
            assert_eq!((y0 >> r) & 1 == 1, m.parity_bit(r));
        }
    }

    #[test]
    fn gate_cost_ntap2() {
        // N_tap=2 everywhere: 1 XOR per row, inverter on every row
        // (2 taps ⇒ parity flip), depth 1.
        let mut rng = Pcg32::seeded(3);
        let m = MXor::with_ntap(20, 8, 2, &mut rng).unwrap();
        let g = gate_cost(&m);
        assert_eq!(g.xor_gates, 20);
        assert_eq!(g.inverters, 20);
        assert_eq!(g.depth_levels, 1);
        assert!((g.gates_per_output_bit - 2.0).abs() < 1e-12);
    }

    #[test]
    fn report_includes_diversity_for_small_nin() {
        let mut rng = Pcg32::seeded(4);
        let m = MXor::with_ntap(10, 8, 2, &mut rng).unwrap();
        let r = report(&m);
        assert_eq!(r.get("n_out").as_i64(), Some(10));
        assert!(!r.get("distinct_outputs").is_null());
        assert!(!r.get("mean_hamming").is_null());
    }
}
