//! Fractional-rate allocation search — the tool version of Table 2 (and of
//! the paper's closing "exciting research topic": how many bits should each
//! layer get when rates can be fractional?).
//!
//! Given layer groups (parameter counts) and a candidate `N_in` menu at
//! fixed `N_out`, find the per-group assignment minimizing predicted
//! accuracy loss subject to an average bits/weight budget — the fractional
//! analogue of HAQ-style mixed-precision search, tractable exactly because
//! the search space is (menu)^groups with small groups.
//!
//! The accuracy proxy is pluggable ([`Sensitivity`]): unit tests use a
//! synthetic diminishing-returns model; the `rate_search` example measures
//! real proxy losses with short trainings through the coordinator.

use anyhow::{ensure, Result};

/// One group of layers sharing an M⊕ configuration.
#[derive(Clone, Debug)]
pub struct Group {
    pub name: String,
    pub weights: usize,
}

/// Predicted accuracy penalty (lower is better) for giving `group` a rate
/// of `bits_per_weight`. Implementations must be monotone non-increasing
/// in the rate for the search's dominance pruning to be exact.
pub trait Sensitivity {
    fn penalty(&self, group: usize, bits_per_weight: f64) -> f64;
}

/// Diminishing-returns synthetic model: penalty = c_g · 2^(−rate/τ_g).
/// Useful for tests and as a prior when no measurements exist; c_g defaults
/// to 1/√weights (big layers are more redundant — the paper's Table 2
/// observation).
pub struct PriorModel {
    pub c: Vec<f64>,
    pub tau: f64,
}

impl PriorModel {
    pub fn from_groups(groups: &[Group], tau: f64) -> Self {
        let c = groups
            .iter()
            .map(|g| 1.0 / (g.weights as f64).sqrt().max(1.0))
            .collect();
        PriorModel { c, tau }
    }
}

impl Sensitivity for PriorModel {
    fn penalty(&self, group: usize, bits_per_weight: f64) -> f64 {
        self.c[group] * (-bits_per_weight / self.tau).exp2()
    }
}

/// A solved assignment.
#[derive(Clone, Debug, PartialEq)]
pub struct Assignment {
    /// Chosen N_in per group (same order as the input groups).
    pub n_in: Vec<usize>,
    pub avg_bits_per_weight: f64,
    pub total_penalty: f64,
}

/// Exhaustive search (exact) — the menu and group counts of Table 2 are
/// tiny (≤ 20 options, ≤ 8 groups ⇒ ≤ 2.6e10 worst case; we prune by
/// bound). For larger instances use [`search_greedy`].
pub fn search_exact(
    groups: &[Group],
    menu: &[usize],
    n_out: usize,
    q: usize,
    budget_bpw: f64,
    model: &dyn Sensitivity,
) -> Result<Assignment> {
    ensure!(!groups.is_empty() && !menu.is_empty());
    ensure!(menu.iter().all(|&n| n >= 1 && n <= n_out));
    let total_w: f64 = groups.iter().map(|g| g.weights as f64).sum();
    let mut best: Option<Assignment> = None;
    let mut chosen = vec![0usize; groups.len()];

    fn rec(
        g: usize,
        groups: &[Group],
        menu: &[usize],
        n_out: usize,
        q: usize,
        budget_bits: f64,
        bits_so_far: f64,
        pen_so_far: f64,
        chosen: &mut Vec<usize>,
        model: &dyn Sensitivity,
        total_w: f64,
        best: &mut Option<Assignment>,
    ) {
        if let Some(b) = best {
            if pen_so_far >= b.total_penalty {
                return; // penalties only grow
            }
        }
        if g == groups.len() {
            if bits_so_far <= budget_bits + 1e-9 {
                let a = Assignment {
                    n_in: chosen.clone(),
                    avg_bits_per_weight: bits_so_far / total_w,
                    total_penalty: pen_so_far,
                };
                if best.as_ref().map_or(true, |b| a.total_penalty < b.total_penalty) {
                    *best = Some(a);
                }
            }
            return;
        }
        // cheapest possible completion (min menu) must fit the budget
        let min_rate = *menu.iter().min().unwrap() as f64 * q as f64 / n_out as f64;
        let min_rest: f64 = groups[g..]
            .iter()
            .map(|grp| min_rate * grp.weights as f64)
            .sum();
        if bits_so_far + min_rest > budget_bits + 1e-9 {
            return;
        }
        for &n_in in menu {
            let rate = n_in as f64 * q as f64 / n_out as f64;
            let bits = bits_so_far + rate * groups[g].weights as f64;
            chosen[g] = n_in;
            rec(
                g + 1,
                groups,
                menu,
                n_out,
                q,
                budget_bits,
                bits,
                pen_so_far + model.penalty(g, rate),
                chosen,
                model,
                total_w,
                best,
            );
        }
    }

    rec(
        0,
        groups,
        menu,
        n_out,
        q,
        budget_bpw * total_w,
        0.0,
        0.0,
        &mut chosen,
        model,
        total_w,
        &mut best,
    );
    best.ok_or_else(|| anyhow::anyhow!("budget {budget_bpw} b/w infeasible with this menu"))
}

/// Greedy refinement: start every group at the max rate, repeatedly lower
/// the group whose penalty-increase per bit saved is smallest until the
/// budget holds. O(groups² · menu) — fine for hundreds of groups.
pub fn search_greedy(
    groups: &[Group],
    menu: &[usize],
    n_out: usize,
    q: usize,
    budget_bpw: f64,
    model: &dyn Sensitivity,
) -> Result<Assignment> {
    ensure!(!groups.is_empty() && !menu.is_empty());
    let mut sorted = menu.to_vec();
    sorted.sort_unstable();
    let total_w: f64 = groups.iter().map(|g| g.weights as f64).sum();
    let rate = |n_in: usize| n_in as f64 * q as f64 / n_out as f64;

    // index into `sorted` per group, start at max
    let mut level = vec![sorted.len() - 1; groups.len()];
    let bits = |levels: &[usize]| -> f64 {
        levels
            .iter()
            .zip(groups)
            .map(|(&l, g)| rate(sorted[l]) * g.weights as f64)
            .sum()
    };
    let mut cur_bits = bits(&level);
    let budget_bits = budget_bpw * total_w;
    while cur_bits > budget_bits + 1e-9 {
        // pick the best single-step reduction
        let mut best: Option<(usize, f64)> = None;
        for g in 0..groups.len() {
            if level[g] == 0 {
                continue;
            }
            let r_hi = rate(sorted[level[g]]);
            let r_lo = rate(sorted[level[g] - 1]);
            let dpen = model.penalty(g, r_lo) - model.penalty(g, r_hi);
            let dbits = (r_hi - r_lo) * groups[g].weights as f64;
            let score = dpen / dbits.max(1e-12);
            if best.map_or(true, |(_, s)| score < s) {
                best = Some((g, score));
            }
        }
        let Some((g, _)) = best else {
            anyhow::bail!("budget {budget_bpw} b/w infeasible with this menu");
        };
        cur_bits -= (rate(sorted[level[g]]) - rate(sorted[level[g] - 1]))
            * groups[g].weights as f64;
        level[g] -= 1;
    }
    let n_in: Vec<usize> = level.iter().map(|&l| sorted[l]).collect();
    let total_penalty = n_in
        .iter()
        .enumerate()
        .map(|(g, &n)| model.penalty(g, rate(n)))
        .sum();
    Ok(Assignment {
        n_in,
        avg_bits_per_weight: cur_bits / total_w,
        total_penalty,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table2_groups() -> Vec<Group> {
        // the paper's Table 2 layer groups (ResNet-20)
        vec![
            Group { name: "layer2-7".into(), weights: 13_500 },
            Group { name: "layer8-13".into(), weights: 45_000 },
            Group { name: "layer14-19".into(), weights: 180_000 },
        ]
    }

    #[test]
    fn exact_respects_budget_and_prefers_small_nin_for_big_groups() {
        let groups = table2_groups();
        let model = PriorModel::from_groups(&groups, 0.35);
        let menu: Vec<usize> = (4..=20).collect();
        let a = search_exact(&groups, &menu, 20, 1, 0.5, &model).unwrap();
        assert!(a.avg_bits_per_weight <= 0.5 + 1e-9);
        // Table 2's qualitative structure: the big third group gets the
        // smallest N_in of the three
        assert!(a.n_in[2] <= a.n_in[0]);
        assert!(a.n_in[2] <= a.n_in[1]);
    }

    #[test]
    fn exact_infeasible_budget_errors() {
        let groups = table2_groups();
        let model = PriorModel::from_groups(&groups, 0.35);
        assert!(search_exact(&groups, &[8, 12], 20, 1, 0.1, &model).is_err());
    }

    #[test]
    fn greedy_matches_exact_on_small_instances() {
        let groups = table2_groups();
        let model = PriorModel::from_groups(&groups, 0.35);
        let menu = [4usize, 8, 12, 16, 20];
        for budget in [0.4, 0.5, 0.6, 0.8] {
            let e = search_exact(&groups, &menu, 20, 1, budget, &model).unwrap();
            let g = search_greedy(&groups, &menu, 20, 1, budget, &model).unwrap();
            assert!(g.avg_bits_per_weight <= budget + 1e-9);
            // greedy is near-optimal on convex penalties; allow 5% slack
            assert!(
                g.total_penalty <= e.total_penalty * 1.05 + 1e-12,
                "budget {budget}: greedy {} vs exact {}",
                g.total_penalty,
                e.total_penalty
            );
        }
    }

    #[test]
    fn q2_budget_accounting() {
        let groups = table2_groups();
        let model = PriorModel::from_groups(&groups, 0.35);
        let a = search_exact(&groups, &[4, 8, 12, 16, 20], 20, 2, 1.2, &model).unwrap();
        // q=2 doubles the rate per N_in choice
        let recompute: f64 = a
            .n_in
            .iter()
            .zip(&groups)
            .map(|(&n, g)| 2.0 * n as f64 / 20.0 * g.weights as f64)
            .sum::<f64>()
            / groups.iter().map(|g| g.weights as f64).sum::<f64>();
        assert!((recompute - a.avg_bits_per_weight).abs() < 1e-9);
        assert!(a.avg_bits_per_weight <= 1.2 + 1e-9);
    }

    #[test]
    fn single_group_budget_binds_exactly() {
        let groups = vec![Group { name: "g".into(), weights: 1000 }];
        let model = PriorModel::from_groups(&groups, 0.3);
        let a = search_exact(&groups, &(1..=20).collect::<Vec<_>>(), 20, 1, 0.75, &model)
            .unwrap();
        // best monotone choice = largest N_in within budget = 15 (0.75 b/w)
        assert_eq!(a.n_in, vec![15]);
    }

    #[test]
    fn greedy_large_instance_terminates() {
        let groups: Vec<Group> = (0..64)
            .map(|i| Group { name: format!("g{i}"), weights: 1000 * (i + 1) })
            .collect();
        let model = PriorModel::from_groups(&groups, 0.4);
        let menu: Vec<usize> = (2..=20).collect();
        let a = search_greedy(&groups, &menu, 20, 1, 0.5, &model).unwrap();
        assert!(a.avg_bits_per_weight <= 0.5 + 1e-9);
        assert_eq!(a.n_in.len(), 64);
    }
}
