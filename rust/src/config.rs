//! Experiment configuration (the launcher's contract): a JSON file pairing
//! an AOT artifact with a dataset, schedule and run length.
//!
//! ```json
//! {
//!   "artifact": "e2e_resnet14_f08",
//!   "dataset": "shapes32",
//!   "seed": 0,
//!   "steps": 600,
//!   "steps_per_epoch": 100,
//!   "eval_every": 100,
//!   "eval_examples": 512,
//!   "schedule": {
//!     "base_lr": 0.05, "warmup_epochs": 1.0,
//!     "decay_epochs": [4.0, 5.0], "decay_factor": 0.5,
//!     "s_tanh_start": 5.0, "s_tanh_base": 10.0, "s_tanh_decay_mult": 2.0
//!   },
//!   "out_dir": "runs/e2e"
//! }
//! ```

use std::path::Path;

use anyhow::{Context, Result};

use crate::coordinator::Schedule;
use crate::substrate::json::{self, Json};

#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub artifact: String,
    pub dataset: String,
    pub seed: u64,
    pub steps: usize,
    pub steps_per_epoch: usize,
    pub eval_every: usize,
    pub eval_examples: usize,
    pub schedule: Schedule,
    pub out_dir: Option<String>,
}

impl ExperimentConfig {
    pub fn from_json(v: &Json) -> Result<Self> {
        let artifact = v
            .get("artifact")
            .as_str()
            .context("config needs 'artifact'")?
            .to_string();
        let steps_per_epoch = v.get("steps_per_epoch").as_usize().unwrap_or(100);
        let s = v.get("schedule");
        let schedule = Schedule {
            base_lr: s.get("base_lr").as_f64().unwrap_or(0.05) as f32,
            warmup_epochs: s.get("warmup_epochs").as_f64().unwrap_or(0.0) as f32,
            decay_epochs: s
                .get("decay_epochs")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|d| d.as_f64())
                .map(|d| d as f32)
                .collect(),
            decay_factor: s.get("decay_factor").as_f64().unwrap_or(0.5) as f32,
            s_tanh_start: s.get("s_tanh_start").as_f64().unwrap_or(5.0) as f32,
            s_tanh_base: s.get("s_tanh_base").as_f64().unwrap_or(10.0) as f32,
            s_tanh_decay_mult: s.get("s_tanh_decay_mult").as_f64().unwrap_or(2.0)
                as f32,
            relax_lambda0: s.get("relax_lambda0").as_f64().unwrap_or(1.0) as f32,
            relax_growth: s.get("relax_growth").as_f64().unwrap_or(1.02) as f32,
            steps_per_epoch,
        };
        Ok(ExperimentConfig {
            artifact,
            dataset: v.get("dataset").as_str().unwrap_or("shapes32").to_string(),
            seed: v.get("seed").as_usize().unwrap_or(0) as u64,
            steps: v.get("steps").as_usize().unwrap_or(300),
            steps_per_epoch,
            eval_every: v.get("eval_every").as_usize().unwrap_or(100),
            eval_examples: v.get("eval_examples").as_usize().unwrap_or(256),
            schedule,
            out_dir: v.get("out_dir").as_str().map(str::to_string),
        })
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::from_json(&json::parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_config() {
        let v = json::parse(
            r#"{
              "artifact": "a", "dataset": "digits", "seed": 3,
              "steps": 50, "steps_per_epoch": 10, "eval_every": 25,
              "eval_examples": 128,
              "schedule": {"base_lr": 0.1, "warmup_epochs": 1.0,
                           "decay_epochs": [3.0], "decay_factor": 0.25},
              "out_dir": "runs/x"
            }"#,
        )
        .unwrap();
        let c = ExperimentConfig::from_json(&v).unwrap();
        assert_eq!(c.artifact, "a");
        assert_eq!(c.dataset, "digits");
        assert_eq!(c.steps, 50);
        assert_eq!(c.schedule.decay_epochs, vec![3.0]);
        assert_eq!(c.schedule.decay_factor, 0.25);
        assert_eq!(c.schedule.steps_per_epoch, 10);
        assert_eq!(c.out_dir.as_deref(), Some("runs/x"));
    }

    #[test]
    fn defaults_fill_in() {
        let v = json::parse(r#"{"artifact": "a"}"#).unwrap();
        let c = ExperimentConfig::from_json(&v).unwrap();
        assert_eq!(c.dataset, "shapes32");
        assert_eq!(c.schedule.base_lr, 0.05);
        assert!(c.out_dir.is_none());
    }

    #[test]
    fn missing_artifact_rejected() {
        let v = json::parse(r#"{"dataset": "digits"}"#).unwrap();
        assert!(ExperimentConfig::from_json(&v).is_err());
    }
}
