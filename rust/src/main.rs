//! `flexor` — the launcher. Subcommands:
//!
//! ```text
//! flexor list                         show available artifacts
//! flexor train <config.json|artifact> run a training experiment
//! flexor analyze --n-out 20 --n-in 8  M⊕ encryption-quality report
//! flexor infer <bundle-dir> <stem>    load a bundle, run a smoke batch
//! flexor profile <bundle-dir> <stem>  per-layer stage timing table
//! flexor serve <bundle-dir> <stem>    host a bundle over HTTP until killed
//! flexor synth <dir> <stem>           synthesize a quantized-MLP bundle
//! flexor repo <init|publish|list|verify|fetch>
//!                                     signed bundle repository (DESIGN.md §13)
//! ```

use std::path::Path;

use anyhow::{bail, Context, Result};

use flexor::config::ExperimentConfig;
use flexor::coordinator::{export_bundle, MetricsSink, TrainSession};
use flexor::data;
use flexor::flexor::{analysis, MXor};
use flexor::runtime::{Manifest, Runtime};
use flexor::substrate::argparse::Args;
use flexor::substrate::prng::Pcg32;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        println!("flexor {} — FleXOR trainable fractional quantization", flexor::VERSION);
        println!("subcommands: list | train | analyze | infer | profile | serve | synth | repo  (--help per command)");
        return Ok(());
    }
    let cmd = argv.remove(0);
    match cmd.as_str() {
        "list" => cmd_list(argv),
        "train" => cmd_train(argv),
        "analyze" => cmd_analyze(argv),
        "infer" => cmd_infer(argv),
        "profile" => cmd_profile(argv),
        "serve" => cmd_serve(argv),
        "synth" => cmd_synth(argv),
        "repo" => cmd_repo(argv),
        other => {
            bail!("unknown subcommand '{other}' (try: list, train, analyze, infer, profile, serve, synth, repo)")
        }
    }
}

fn manifest(root: &str) -> Result<Manifest> {
    Manifest::load(Path::new(root))
}

fn cmd_list(argv: Vec<String>) -> Result<()> {
    let a = Args::new("flexor list", "list AOT artifacts")
        .flag("artifacts", "artifacts directory", Some(flexor::ARTIFACTS_DIR))
        .parse_from(argv)
        .map_err(|m| anyhow::anyhow!("{m}"))?;
    let man = manifest(a.get("artifacts"))?;
    for name in man.names() {
        let meta = man.config(name)?;
        println!(
            "{name:36} {:12} {:12} {:5.2} b/w  batch {}",
            meta.model, meta.quantizer_kind, meta.bits_per_weight, meta.batch
        );
    }
    Ok(())
}

fn cmd_train(argv: Vec<String>) -> Result<()> {
    let a = Args::new("flexor train", "run a training experiment")
        .positional("config", "experiment config JSON (or bare artifact name)")
        .flag("artifacts", "artifacts directory", Some(flexor::ARTIFACTS_DIR))
        .flag("steps", "override step count", None)
        .flag("export", "export a deployment bundle to this dir", None)
        .switch("quiet", "suppress per-eval logging")
        .parse_from(argv)
        .map_err(|m| anyhow::anyhow!("{m}"))?;

    let spec = a.pos(0).unwrap();
    let mut cfg = if spec.ends_with(".json") {
        ExperimentConfig::load(Path::new(spec))?
    } else {
        // bare artifact name: sensible defaults
        ExperimentConfig::from_json(&flexor::substrate::json::parse(&format!(
            r#"{{"artifact": "{spec}"}}"#
        ))?)?
    };
    if let Some(s) = a.get_opt("steps") {
        cfg.steps = s.parse().context("--steps")?;
    }

    let rt = Runtime::cpu()?;
    let man = manifest(a.get("artifacts"))?;
    let mut session = TrainSession::new(&rt, &man, &cfg.artifact)?;
    let ds = data::by_name(&cfg.dataset, cfg.seed)?;
    let mut sink = match &cfg.out_dir {
        Some(dir) => {
            std::fs::create_dir_all(dir)?;
            MetricsSink::with_jsonl(&Path::new(dir).join("metrics.jsonl"))?
        }
        None => MetricsSink::new(),
    };

    println!(
        "training {} ({}, {:.2} b/w) on {} for {} steps",
        cfg.artifact, session.meta.model, session.meta.bits_per_weight,
        cfg.dataset, cfg.steps
    );
    let ev = session.train_loop(ds.as_ref(), &cfg.schedule, cfg.steps,
                                cfg.eval_every, cfg.eval_examples, &mut sink)?;
    println!(
        "final: loss {:.4}  top1 {:.4}  top5 {:.4}  ({} examples)",
        ev.loss, ev.top1, ev.top5, ev.examples
    );
    if !a.get_bool("quiet") {
        for e in &sink.eval {
            println!("  eval @ step {:>6}: loss {:.4} top1 {:.4}", e.step, e.loss, e.top1);
        }
    }
    if let Some(dir) = &cfg.out_dir {
        sink.write_train_csv(&Path::new(dir).join("train.csv"))?;
        sink.write_eval_csv(&Path::new(dir).join("eval.csv"))?;
    }
    if let Some(dir) = a.get_opt("export") {
        export_bundle(&session, Path::new(dir), &cfg.artifact)?;
        println!("exported bundle to {dir}/{}.*", cfg.artifact);
    }
    Ok(())
}

fn cmd_analyze(argv: Vec<String>) -> Result<()> {
    let a = Args::new("flexor analyze", "M⊕ encryption-quality report (paper §2)")
        .flag("n-out", "output bits per slice", Some("20"))
        .flag("n-in", "stored bits per slice", Some("8"))
        .flag("n-tap", "taps per row (0 = random fill)", Some("2"))
        .flag("seed", "rng seed", Some("7"))
        .parse_from(argv)
        .map_err(|m| anyhow::anyhow!("{m}"))?;
    let (n_out, n_in) = (a.get_usize("n-out"), a.get_usize("n-in"));
    let n_tap = a.get_usize("n-tap");
    let mut rng = Pcg32::seeded(a.get_u64("seed"));
    let m = if n_tap == 0 {
        MXor::random(n_out, n_in, &mut rng)?
    } else {
        MXor::with_ntap(n_out, n_in, n_tap, &mut rng)?
    };
    println!("{}", analysis::report(&m).to_string_pretty());
    Ok(())
}

fn cmd_infer(argv: Vec<String>) -> Result<()> {
    let a = Args::new("flexor infer", "load a deployment bundle, run a smoke batch")
        .positional("dir", "bundle directory")
        .positional("stem", "bundle stem (config name)")
        .flag("dataset", "dataset for the smoke batch", Some("shapes32"))
        .flag("batch", "examples", Some("32"))
        .flag(
            "compute-mode",
            "policy: <mode>[@min=<weights>][,<idx>=<mode>]* with mode = dense | bitplane[:<m>] | encrypted[:<m>] (default: FLEXOR_COMPUTE env, else dense)",
            Some(""),
        )
        .parse_from(argv)
        .map_err(|m| anyhow::anyhow!("{m}"))?;
    let policy = match a.get("compute-mode") {
        "" => flexor::inference::ModePolicy::default_from_env()?,
        s => flexor::inference::ModePolicy::parse(s)?,
    };
    let model = flexor::inference::InferenceModel::load_with_policy(
        Path::new(a.pos(0).unwrap()),
        a.pos(1).unwrap(),
        policy,
    )?;
    println!(
        "loaded {} ({:.2} b/w, {:.1}× compression, {} mode, {} quantized bytes resident, {} simd kernel)",
        model.model,
        model.bits_per_weight,
        model.compression_ratio,
        model.mode_label(),
        model.quantized_resident_bytes(),
        flexor::inference::bitslice::popcount::active().label()
    );
    if model.is_mixed() {
        for lm in model.layer_modes() {
            println!(
                "  layer {:>2}: {:8} ({} weights)",
                lm.idx,
                lm.mode.label(),
                lm.weights
            );
        }
    }
    let ds = data::by_name(a.get("dataset"), 0)?;
    let n = a.get_usize("batch");
    let (xs, ys) = data::Batcher::eval_set(ds.as_ref(), data::Split::Test, n);
    let t0 = std::time::Instant::now();
    let preds = model.predict(&xs, n)?;
    let dt = t0.elapsed().as_secs_f64();
    let correct = preds.iter().zip(&ys).filter(|(p, y)| p == y).count();
    println!(
        "top1 {}/{} ({:.1}%), {:.2} ms/example",
        correct, n, 100.0 * correct as f64 / n as f64, dt * 1e3 / n as f64
    );
    Ok(())
}

fn cmd_serve(argv: Vec<String>) -> Result<()> {
    use flexor::serve::{HttpMode, Registry, ServeConfig, Server};

    let a = Args::new(
        "flexor serve",
        "host a deployment bundle over HTTP (POST /predict, GET /models | /metrics | /healthz | /readyz) until killed",
    )
    .positional("dir", "bundle directory")
    .positional("stem", "bundle stem (config name)")
    .flag("addr", "listen address", Some("127.0.0.1:8080"))
    .flag("name", "registry name requests address the model by", Some("default"))
    .flag("workers", "worker threads draining the queue", Some("2"))
    .flag("intra-threads", "GEMM threads per forward (0 = auto)", Some("0"))
    .flag("max-batch", "max coalesced batch size", Some("16"))
    .flag("max-wait-us", "batching linger window (µs)", Some("2000"))
    .flag("queue-capacity", "admission bound; beyond it requests get 503 + Retry-After", Some("1024"))
    .flag(
        "deadline-ms",
        "default per-request deadline in ms, shed with 503 once expired (0 = FLEXOR_DEADLINE_MS env, else none)",
        Some("0"),
    )
    .flag(
        "max-body-bytes",
        "request body bound, larger bodies get 413 (0 = FLEXOR_MAX_BODY_BYTES env, else 8 MiB)",
        Some("0"),
    )
    .flag(
        "http-mode",
        "front-end: event-loop (nonblocking readiness loop, keep-alive + pipelining) or threads (one thread per connection; default: FLEXOR_HTTP_MODE env, else event-loop)",
        Some(""),
    )
    .flag(
        "idle-ms",
        "event-loop: close keep-alive connections idle this long (0 = FLEXOR_HTTP_IDLE_MS env, else 30000)",
        Some("0"),
    )
    .flag(
        "header-ms",
        "event-loop: 408 a connection whose request head/body stalls this long (0 = FLEXOR_HTTP_HEADER_MS env, else 10000)",
        Some("0"),
    )
    .flag(
        "max-connections",
        "event-loop: concurrent connection cap, beyond it accepts get 503 (0 = FLEXOR_MAX_CONNECTIONS env, else 4096)",
        Some("0"),
    )
    .flag(
        "compute-mode",
        "policy: <mode>[@min=<weights>][,<idx>=<mode>]* with mode = dense | bitplane[:<m>] | encrypted[:<m>] (default: FLEXOR_COMPUTE env, else dense)",
        Some(""),
    )
    .flag(
        "repo",
        "attach a signed bundle repo root — enables POST /models hot-swap and lazy reload (DESIGN.md §13)",
        Some(""),
    )
    .flag("key", "repo signing key (default: FLEXOR_REPO_KEY env)", Some(""))
    .flag(
        "max-resident-bytes",
        "LRU-evict repo-backed models beyond this resident-weight budget (0 = FLEXOR_MAX_RESIDENT_BYTES env, else unbounded)",
        Some("0"),
    )
    .flag(
        "preload",
        "comma-separated name@version specs admitted from the repo before serving",
        Some(""),
    )
    .parse_from(argv)
    .map_err(|m| anyhow::anyhow!("{m}"))?;

    let policy = match a.get("compute-mode") {
        "" => flexor::inference::ModePolicy::default_from_env()?,
        s => flexor::inference::ModePolicy::parse(s)?,
    };
    let deadline = a.get_u64("deadline-ms");
    let max_body = a.get_usize("max-body-bytes");
    let http_mode = match a.get("http-mode") {
        "" => None, // fall through to FLEXOR_HTTP_MODE, then the default
        "threads" | "thread" => Some(HttpMode::Threads),
        "event-loop" | "event_loop" | "eventloop" | "epoll" => Some(HttpMode::EventLoop),
        other => anyhow::bail!("unknown --http-mode {other:?} (expected event-loop or threads)"),
    };
    let idle_ms = a.get_u64("idle-ms");
    let header_ms = a.get_u64("header-ms");
    let max_conns = a.get_usize("max-connections");
    let cfg = ServeConfig {
        workers: a.get_usize("workers"),
        intra_threads: a.get_usize("intra-threads"),
        max_batch: a.get_usize("max-batch"),
        max_wait_us: a.get_u64("max-wait-us"),
        queue_capacity: a.get_usize("queue-capacity"),
        default_deadline_ms: (deadline > 0).then_some(deadline),
        max_body_bytes: (max_body > 0).then_some(max_body),
        http_mode,
        idle_timeout_ms: (idle_ms > 0).then_some(idle_ms),
        header_timeout_ms: (header_ms > 0).then_some(header_ms),
        max_connections: (max_conns > 0).then_some(max_conns),
        trace: None,
    };

    // a corrupt bundle is rejected here with the failing section named
    // (DESIGN.md §12) — the server never starts on bad weights
    let mut registry = Registry::with_default_policy(policy);
    let budget = match a.get_usize("max-resident-bytes") {
        0 => std::env::var("FLEXOR_MAX_RESIDENT_BYTES")
            .ok()
            .and_then(|v| v.trim().parse::<usize>().ok())
            .unwrap_or(0),
        b => b,
    };
    if budget > 0 {
        registry.set_resident_budget(Some(budget));
    }
    match a.get("repo") {
        "" => {}
        root => {
            let repo = flexor::repo::BundleRepo::open(Path::new(root), &repo_key(&a)?)?;
            registry.set_repo(repo);
        }
    }
    let entry = registry.load(
        a.get("name"),
        Path::new(a.pos(0).unwrap()),
        a.pos(1).unwrap(),
    )?;
    println!(
        "loaded '{}' in {:.1} ms ({:.2} b/w, {:.1}× compression, {} mode)",
        entry.name, entry.load_ms, entry.model.bits_per_weight,
        entry.model.compression_ratio, entry.model.mode_label()
    );
    for spec in a.get("preload").split(',').map(str::trim).filter(|s| !s.is_empty()) {
        let report = registry
            .admit_from_repo(spec, false)
            .map_err(|e| anyhow::anyhow!("preloading {spec}: {e}"))?;
        println!("preloaded '{}' from repo in {:.1} ms", report.name, report.load_ms);
    }

    let server = Server::start(a.get("addr"), registry, cfg)?;
    println!(
        "serving on http://{}  ({} workers, max_batch {}, queue {}, deadline {})",
        server.local_addr(),
        cfg.workers,
        cfg.max_batch,
        cfg.queue_capacity,
        match cfg.default_deadline_ms {
            Some(ms) => format!("{ms} ms"),
            None => "env/none".to_string(),
        }
    );
    println!("endpoints: POST /predict | GET|POST /models | DELETE /models/<name> | GET /metrics /healthz /readyz  (ctrl-c to stop)");
    loop {
        std::thread::park();
    }
}

/// Resolve the repo signing key: `--key` flag, else `FLEXOR_REPO_KEY`.
fn repo_key(a: &Args) -> Result<Vec<u8>> {
    match a.get("key") {
        "" => match std::env::var("FLEXOR_REPO_KEY") {
            Ok(k) if !k.is_empty() => Ok(k.into_bytes()),
            _ => bail!("no repo key: pass --key or set FLEXOR_REPO_KEY"),
        },
        k => Ok(k.as_bytes().to_vec()),
    }
}

fn cmd_synth(argv: Vec<String>) -> Result<()> {
    let a = Args::new(
        "flexor synth",
        "synthesize a quantized-MLP deployment bundle (seeded; no artifacts or runtime needed)",
    )
    .positional("dir", "output directory")
    .positional("stem", "bundle stem (config name)")
    .flag("seed", "rng seed", Some("7"))
    .flag("d-in", "input feature width", Some("64"))
    .flag("hidden", "comma-separated hidden widths", Some("32,24"))
    .flag("classes", "output classes", Some("10"))
    .parse_from(argv)
    .map_err(|m| anyhow::anyhow!("{m}"))?;
    let hidden: Vec<usize> = a
        .get("hidden")
        .split(',')
        .map(|s| s.trim().parse::<usize>().context("--hidden expects integers"))
        .collect::<Result<_>>()?;
    let dir = Path::new(a.pos(0).unwrap());
    let stem = a.pos(1).unwrap();
    std::fs::create_dir_all(dir)?;
    flexor::coordinator::export_synthetic_mlp_bundle(
        dir,
        stem,
        a.get_u64("seed"),
        a.get_usize("d-in"),
        &hidden,
        a.get_usize("classes"),
    )?;
    println!("synthesized bundle {}/{stem}.*", dir.display());
    Ok(())
}

fn cmd_repo(mut argv: Vec<String>) -> Result<()> {
    use flexor::repo::{parse_spec, BundleRepo};

    let usage = "usage: flexor repo <init|publish|list|verify|fetch> ... (--help per action)";
    if argv.is_empty() {
        bail!("{usage}");
    }
    let action = argv.remove(0);
    match action.as_str() {
        "init" => {
            let a = Args::new("flexor repo init", "create an empty signed bundle repository")
                .positional("root", "repository root directory")
                .flag("key", "repo signing key (default: FLEXOR_REPO_KEY env)", Some(""))
                .parse_from(argv)
                .map_err(|m| anyhow::anyhow!("{m}"))?;
            let root = Path::new(a.pos(0).unwrap());
            BundleRepo::init(root, &repo_key(&a)?)?;
            println!("initialized bundle repo at {}", root.display());
        }
        "publish" => {
            let a = Args::new(
                "flexor repo publish",
                "hash, sign and copy a bundle triple into the repository",
            )
            .positional("root", "repository root directory")
            .positional("spec", "bundle spec, name@version (e.g. resnet20@v2)")
            .positional("src-dir", "directory holding <stem>.fxr/.fp.bin/.bundle.json")
            .positional("stem", "bundle stem (config name)")
            .flag("key", "repo signing key (default: FLEXOR_REPO_KEY env)", Some(""))
            .parse_from(argv)
            .map_err(|m| anyhow::anyhow!("{m}"))?;
            let (name, version) = parse_spec(a.pos(1).unwrap())?;
            let repo = BundleRepo::open(Path::new(a.pos(0).unwrap()), &repo_key(&a)?)?;
            let rec = repo.publish(&name, &version, Path::new(a.pos(2).unwrap()), a.pos(3).unwrap())?;
            let total: u64 = rec.files.iter().map(|f| f.bytes).sum();
            println!(
                "published {}@{} ({} files, {} bytes, sig {}…)",
                rec.name,
                rec.version,
                rec.files.len(),
                total,
                &rec.signature[..16.min(rec.signature.len())]
            );
        }
        "list" => {
            let a = Args::new("flexor repo list", "list published bundles")
                .positional("root", "repository root directory")
                .flag("key", "repo signing key (default: FLEXOR_REPO_KEY env)", Some(""))
                .parse_from(argv)
                .map_err(|m| anyhow::anyhow!("{m}"))?;
            let repo = BundleRepo::open(Path::new(a.pos(0).unwrap()), &repo_key(&a)?)?;
            for r in repo.list()? {
                let total: u64 = r.files.iter().map(|f| f.bytes).sum();
                println!(
                    "{:32} stem {:16} {:3} files {:>10} bytes",
                    format!("{}@{}", r.name, r.version),
                    r.stem,
                    r.files.len(),
                    total
                );
            }
        }
        "verify" => {
            let a = Args::new(
                "flexor repo verify",
                "check a bundle's HMAC signature and per-file SHA-256 digests",
            )
            .positional("root", "repository root directory")
            .positional("spec", "bundle spec, name@version")
            .flag("key", "repo signing key (default: FLEXOR_REPO_KEY env)", Some(""))
            .parse_from(argv)
            .map_err(|m| anyhow::anyhow!("{m}"))?;
            let (name, version) = parse_spec(a.pos(1).unwrap())?;
            let repo = BundleRepo::open(Path::new(a.pos(0).unwrap()), &repo_key(&a)?)?;
            let v = repo.verify(&name, &version)?;
            println!(
                "verified {name}@{version}: signature + {} file digests ok",
                v.record.files.len()
            );
        }
        "fetch" => {
            let a = Args::new(
                "flexor repo fetch",
                "verify a bundle, then copy its files into a destination directory",
            )
            .positional("root", "repository root directory")
            .positional("spec", "bundle spec, name@version")
            .positional("dest", "destination directory")
            .flag("key", "repo signing key (default: FLEXOR_REPO_KEY env)", Some(""))
            .parse_from(argv)
            .map_err(|m| anyhow::anyhow!("{m}"))?;
            let (name, version) = parse_spec(a.pos(1).unwrap())?;
            let dest = Path::new(a.pos(2).unwrap());
            let repo = BundleRepo::open(Path::new(a.pos(0).unwrap()), &repo_key(&a)?)?;
            let v = repo.fetch(&name, &version, dest)?;
            println!(
                "fetched {name}@{version} (stem {}) into {}",
                v.stem,
                dest.display()
            );
        }
        other => bail!("unknown repo action '{other}'\n{usage}"),
    }
    Ok(())
}

fn cmd_profile(argv: Vec<String>) -> Result<()> {
    use flexor::substrate::trace;

    let a = Args::new(
        "flexor profile",
        "per-layer stage timing for a deployment bundle (trace-instrumented forwards)",
    )
    .positional("dir", "bundle directory")
    .positional("stem", "bundle stem (config name)")
    .flag("dataset", "dataset for the profiled batches", Some("shapes32"))
    .flag("batch", "examples per forward", Some("8"))
    .flag("iters", "profiled forward passes", Some("10"))
    .flag(
        "compute-mode",
        "policy: <mode>[@min=<weights>][,<idx>=<mode>]* with mode = dense | bitplane[:<m>] | encrypted[:<m>] (default: FLEXOR_COMPUTE env, else dense)",
        Some(""),
    )
    .parse_from(argv)
    .map_err(|m| anyhow::anyhow!("{m}"))?;
    let policy = match a.get("compute-mode") {
        "" => flexor::inference::ModePolicy::default_from_env()?,
        s => flexor::inference::ModePolicy::parse(s)?,
    };
    let model = flexor::inference::InferenceModel::load_with_policy(
        Path::new(a.pos(0).unwrap()),
        a.pos(1).unwrap(),
        policy,
    )?;
    let ds = data::by_name(a.get("dataset"), 0)?;
    let n = a.get_usize("batch").max(1);
    let iters = a.get_usize("iters").max(1);
    let (xs, _ys) = data::Batcher::eval_set(ds.as_ref(), data::Split::Test, n);

    model.predict(&xs, n)?; // warm-up (pool build, scratch arenas) untraced

    let profile = std::sync::Arc::new(trace::Profile::new());
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        let _t = trace::scope_with(trace::TraceMode::All, Some(profile.clone()));
        model.predict(&xs, n)?;
    }
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;

    println!(
        "{} — {} forwards × batch {} ({} mode, {} simd kernel)",
        model.model,
        iters,
        n,
        model.mode_label(),
        flexor::inference::bitslice::popcount::active().label()
    );
    println!(
        "{:<26} {:<10} {:>7} {:>12} {:>10}",
        "layer", "stage", "count", "total ms", "mean µs"
    );
    for r in profile.rows() {
        let layer = if r.layer.is_empty() { "-" } else { r.layer.as_str() };
        println!(
            "{:<26} {:<10} {:>7} {:>12.3} {:>10.1}",
            layer,
            r.stage,
            r.count,
            r.total_ns as f64 / 1e6,
            r.total_ns as f64 / r.count.max(1) as f64 / 1e3
        );
    }
    println!(
        "traced {} forwards in {:.1} ms wall",
        profile.traced_forwards(),
        wall_ms
    );
    Ok(())
}
