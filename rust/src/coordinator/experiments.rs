//! Shared harness for the table/figure reproduction runners (examples/).
//!
//! Every runner enumerates [`RunSpec`]s (artifact × dataset × schedule ×
//! seeds), trains them through the coordinator, and prints a paper-style
//! table next to the paper's published rows. Reduced-scale policy is
//! DESIGN.md §5: orderings and trends are the reproduction target, not
//! absolute percentages (our substrate is procedural data on CPU).

use anyhow::Result;

use crate::data;
use crate::runtime::{Manifest, Runtime};
use crate::substrate::stats::Moments;

use super::metrics::MetricsSink;
use super::schedule::Schedule;
use super::trainer::TrainSession;

/// One experiment point (possibly multi-seed).
#[derive(Clone, Debug)]
pub struct RunSpec {
    /// Display label (e.g. "FleXOR (0.8 bit)").
    pub label: String,
    pub artifact: String,
    pub dataset: String,
    pub schedule: Schedule,
    pub steps: usize,
    pub eval_every: usize,
    pub eval_examples: usize,
    pub seeds: Vec<u64>,
    /// The paper's published number for this row, if any (for side-by-side).
    pub paper: Option<f64>,
}

impl RunSpec {
    pub fn new(label: &str, artifact: &str, dataset: &str, steps: usize) -> Self {
        RunSpec {
            label: label.to_string(),
            artifact: artifact.to_string(),
            dataset: dataset.to_string(),
            schedule: Schedule::cifar(0.05, 1.0, vec![4.0, 5.0], 100),
            steps,
            eval_every: steps.max(1),
            eval_examples: 512,
            seeds: vec![0],
            paper: None,
        }
    }

    pub fn schedule(mut self, s: Schedule) -> Self {
        self.schedule = s;
        self
    }

    pub fn seeds(mut self, seeds: Vec<u64>) -> Self {
        self.seeds = seeds;
        self
    }

    pub fn paper(mut self, value: f64) -> Self {
        self.paper = Some(value);
        self
    }

    pub fn eval_every(mut self, n: usize) -> Self {
        self.eval_every = n;
        self
    }
}

/// Aggregated outcome of one spec.
#[derive(Clone, Debug)]
pub struct RunOutcome {
    pub spec: RunSpec,
    pub bits_per_weight: f64,
    pub top1_mean: f64,
    pub top1_std: f64,
    pub top5_mean: f64,
    pub final_loss_mean: f64,
    pub per_seed_top1: Vec<f64>,
    /// Eval trajectory of the first seed (step, top1) for figure curves.
    pub curve: Vec<(usize, f64)>,
    pub wall_s: f64,
}

/// Train one spec across its seeds.
pub fn run_spec(rt: &Runtime, man: &Manifest, spec: &RunSpec) -> Result<RunOutcome> {
    let t0 = std::time::Instant::now();
    let mut top1 = Moments::new();
    let mut top5 = Moments::new();
    let mut loss = Moments::new();
    let mut per_seed = Vec::new();
    let mut curve = Vec::new();
    let mut bits = 32.0;
    for (i, &seed) in spec.seeds.iter().enumerate() {
        let mut session = TrainSession::new(rt, man, &spec.artifact)?;
        bits = session.meta.bits_per_weight;
        let ds = data::by_name(&spec.dataset, seed)?;
        let mut sink = MetricsSink::new();
        let ev = session.train_loop(ds.as_ref(), &spec.schedule, spec.steps,
                                    spec.eval_every, spec.eval_examples,
                                    &mut sink)?;
        let best = sink.best_top1().unwrap_or(ev.top1) as f64;
        top1.push(best);
        top5.push(ev.top5 as f64);
        loss.push(ev.loss as f64);
        per_seed.push(best);
        if i == 0 {
            curve = sink
                .eval
                .iter()
                .map(|e| (e.step, e.top1 as f64))
                .collect();
        }
    }
    Ok(RunOutcome {
        spec: spec.clone(),
        bits_per_weight: bits,
        top1_mean: top1.mean(),
        top1_std: top1.std(),
        top5_mean: top5.mean(),
        final_loss_mean: loss.mean(),
        per_seed_top1: per_seed,
        curve,
        wall_s: t0.elapsed().as_secs_f64(),
    })
}

/// Run a list of specs, printing progress, returning outcomes.
pub fn run_all(rt: &Runtime, man: &Manifest, specs: &[RunSpec]) -> Result<Vec<RunOutcome>> {
    let mut out = Vec::with_capacity(specs.len());
    for (i, spec) in specs.iter().enumerate() {
        eprintln!(
            "[{}/{}] {} ({} steps × {} seeds on {}) ...",
            i + 1,
            specs.len(),
            spec.label,
            spec.steps,
            spec.seeds.len(),
            spec.dataset
        );
        let o = run_spec(rt, man, spec)?;
        eprintln!(
            "        top1 {:.2}% ± {:.2} ({:.0}s)",
            100.0 * o.top1_mean,
            100.0 * o.top1_std,
            o.wall_s
        );
        out.push(o);
    }
    Ok(out)
}

/// Print a paper-style comparison table.
pub fn print_table(title: &str, outcomes: &[RunOutcome]) {
    println!("\n=== {title} ===");
    println!(
        "{:<34} {:>6} {:>12} {:>8} {:>12}",
        "method", "b/w", "top1 (ours)", "±std", "paper top1"
    );
    for o in outcomes {
        let paper = o
            .spec
            .paper
            .map(|p| format!("{p:>11.2}%"))
            .unwrap_or_else(|| format!("{:>12}", "—"));
        println!(
            "{:<34} {:>6.2} {:>11.2}% {:>7.2}% {paper}",
            o.spec.label,
            o.bits_per_weight,
            100.0 * o.top1_mean,
            100.0 * o.top1_std,
        );
    }
}

/// Print accuracy-vs-step curves (figure reproduction as aligned columns).
pub fn print_curves(title: &str, outcomes: &[RunOutcome]) {
    println!("\n=== {title} (top1 vs step) ===");
    print!("{:>8}", "step");
    for o in outcomes {
        print!(" {:>22}", truncate(&o.spec.label, 22));
    }
    println!();
    let steps: Vec<usize> = outcomes
        .first()
        .map(|o| o.curve.iter().map(|c| c.0).collect())
        .unwrap_or_default();
    for (row, &s) in steps.iter().enumerate() {
        print!("{s:>8}");
        for o in outcomes {
            match o.curve.get(row) {
                Some((_, v)) => print!(" {:>21.2}%", 100.0 * v),
                None => print!(" {:>22}", "-"),
            }
        }
        println!();
    }
}

fn truncate(s: &str, n: usize) -> String {
    if s.len() <= n {
        s.to_string()
    } else {
        format!("{}…", &s[..n - 1])
    }
}

/// Common CLI scale handling for the runners: `--scale 0.25` shrinks step
/// counts (never below 40) so a full table can be smoke-run quickly.
pub fn scaled(steps: usize, scale: f32) -> usize {
    ((steps as f32 * scale) as usize).max(40)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_builder() {
        let s = RunSpec::new("x", "a", "digits", 100)
            .seeds(vec![1, 2])
            .paper(91.2)
            .eval_every(10);
        assert_eq!(s.seeds, vec![1, 2]);
        assert_eq!(s.paper, Some(91.2));
        assert_eq!(s.eval_every, 10);
    }

    #[test]
    fn scaled_floors() {
        assert_eq!(scaled(400, 0.5), 200);
        assert_eq!(scaled(400, 0.01), 40);
    }

    #[test]
    fn truncate_labels() {
        assert_eq!(truncate("short", 22), "short");
        assert_eq!(truncate("a-very-long-label-exceeding", 10).chars().count(), 10);
    }
}
