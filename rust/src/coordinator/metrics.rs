//! Metric sinks: in-memory rows + CSV/JSONL writers, loss-curve summaries
//! and the encrypted-weight histograms of Figs. 6/13/14.

use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

use crate::substrate::json::Json;
use crate::substrate::stats::Histogram;

#[derive(Clone, Debug, PartialEq)]
pub struct TrainRow {
    pub step: usize,
    pub epoch: f32,
    pub loss: f32,
    pub acc: f32,
    pub lr: f32,
    pub s_tanh: f32,
    pub wall_ms: f64,
}

#[derive(Clone, Debug, PartialEq)]
pub struct EvalRow {
    pub step: usize,
    pub loss: f32,
    pub top1: f32,
    pub top5: f32,
}

/// Collects rows during a run; optionally streams JSONL to disk.
#[derive(Debug, Default)]
pub struct MetricsSink {
    pub train: Vec<TrainRow>,
    pub eval: Vec<EvalRow>,
    pub histograms: Vec<(usize, Histogram)>,
    jsonl: Option<std::fs::File>,
}

impl MetricsSink {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_jsonl(path: &Path) -> Result<Self> {
        let f = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        Ok(MetricsSink { jsonl: Some(f), ..Default::default() })
    }

    pub fn push_train(&mut self, row: TrainRow) {
        if let Some(f) = &mut self.jsonl {
            let j = Json::obj(vec![
                ("kind", Json::str("train")),
                ("step", Json::num(row.step as f64)),
                ("epoch", Json::num(row.epoch as f64)),
                ("loss", Json::num(row.loss as f64)),
                ("acc", Json::num(row.acc as f64)),
                ("lr", Json::num(row.lr as f64)),
                ("s_tanh", Json::num(row.s_tanh as f64)),
                ("wall_ms", Json::num(row.wall_ms)),
            ]);
            let _ = writeln!(f, "{j}");
        }
        self.train.push(row);
    }

    pub fn push_eval(&mut self, row: EvalRow) {
        if let Some(f) = &mut self.jsonl {
            let j = Json::obj(vec![
                ("kind", Json::str("eval")),
                ("step", Json::num(row.step as f64)),
                ("loss", Json::num(row.loss as f64)),
                ("top1", Json::num(row.top1 as f64)),
                ("top5", Json::num(row.top5 as f64)),
            ]);
            let _ = writeln!(f, "{j}");
        }
        self.eval.push(row);
    }

    pub fn push_histogram(&mut self, step: usize, h: Histogram) {
        self.histograms.push((step, h));
    }

    /// Best eval top-1 over the run (the number every table reports).
    pub fn best_top1(&self) -> Option<f32> {
        self.eval.iter().map(|e| e.top1).fold(None, |m, x| {
            Some(match m {
                None => x,
                Some(m) => m.max(x),
            })
        })
    }

    pub fn final_top1(&self) -> Option<f32> {
        self.eval.last().map(|e| e.top1)
    }

    /// Mean training loss over the last `k` rows (convergence check).
    pub fn tail_loss(&self, k: usize) -> Option<f32> {
        if self.train.is_empty() {
            return None;
        }
        let tail = &self.train[self.train.len().saturating_sub(k)..];
        Some(tail.iter().map(|r| r.loss).sum::<f32>() / tail.len() as f32)
    }

    /// Write train rows as CSV.
    pub fn write_train_csv(&self, path: &Path) -> Result<()> {
        let mut s = String::from("step,epoch,loss,acc,lr,s_tanh,wall_ms\n");
        for r in &self.train {
            s.push_str(&format!(
                "{},{},{},{},{},{},{}\n",
                r.step, r.epoch, r.loss, r.acc, r.lr, r.s_tanh, r.wall_ms
            ));
        }
        std::fs::write(path, s).with_context(|| format!("writing {}", path.display()))
    }

    pub fn write_eval_csv(&self, path: &Path) -> Result<()> {
        let mut s = String::from("step,loss,top1,top5\n");
        for r in &self.eval {
            s.push_str(&format!("{},{},{},{}\n", r.step, r.loss, r.top1, r.top5));
        }
        std::fs::write(path, s).with_context(|| format!("writing {}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(step: usize, loss: f32) -> TrainRow {
        TrainRow { step, epoch: step as f32 / 10.0, loss, acc: 0.5, lr: 0.1,
                   s_tanh: 10.0, wall_ms: 1.0 }
    }

    #[test]
    fn best_and_final_top1() {
        let mut m = MetricsSink::new();
        assert_eq!(m.best_top1(), None);
        m.push_eval(EvalRow { step: 1, loss: 1.0, top1: 0.6, top5: 0.9 });
        m.push_eval(EvalRow { step: 2, loss: 1.0, top1: 0.8, top5: 0.95 });
        m.push_eval(EvalRow { step: 3, loss: 1.0, top1: 0.7, top5: 0.93 });
        assert_eq!(m.best_top1(), Some(0.8));
        assert_eq!(m.final_top1(), Some(0.7));
    }

    #[test]
    fn tail_loss_window() {
        let mut m = MetricsSink::new();
        for i in 0..10 {
            m.push_train(row(i, i as f32));
        }
        assert_eq!(m.tail_loss(2), Some(8.5));
        assert_eq!(m.tail_loss(100), Some(4.5));
    }

    #[test]
    fn csv_and_jsonl_outputs() {
        let dir = std::env::temp_dir();
        let jl = dir.join("flexor_metrics_test.jsonl");
        let mut m = MetricsSink::with_jsonl(&jl).unwrap();
        m.push_train(row(0, 2.0));
        m.push_eval(EvalRow { step: 0, loss: 2.0, top1: 0.1, top5: 0.5 });
        let csv = dir.join("flexor_metrics_test.csv");
        m.write_train_csv(&csv).unwrap();
        let csv_text = std::fs::read_to_string(&csv).unwrap();
        assert!(csv_text.starts_with("step,epoch,loss"));
        assert_eq!(csv_text.lines().count(), 2);
        drop(m);
        let jl_text = std::fs::read_to_string(&jl).unwrap();
        assert_eq!(jl_text.lines().count(), 2);
        assert!(jl_text.contains("\"kind\": \"train\"") || jl_text.contains("\"kind\":\"train\""));
        std::fs::remove_file(jl).ok();
        std::fs::remove_file(csv).ok();
    }
}
