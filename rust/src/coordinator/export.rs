//! Checkpoint export: trained state → `.fxr` encrypted container (the
//! quantized payload the paper ships) + an FXIN "FP sidecar" holding the
//! full-precision residue (stem/head/BN params + running stats) the
//! inference engine needs.

use std::path::Path;

use anyhow::{ensure, Context, Result};

use crate::flexor::bitpack::ColumnBits;
use crate::flexor::fxr::{Container, Layer, Plane};
use crate::runtime::initbin::{self, Leaf, LeafType};
use crate::substrate::json::Json;

use super::trainer::TrainSession;

/// Build the `.fxr` container from the session's current parameters.
///
/// Per quantized layer `i`: `sign(w_enc[plane])` is bit-packed per plane,
/// with that layer's M⊕ (from the artifact metadata — identical to what the
/// training HLO baked in) and trained α.
pub fn export_fxr(session: &TrainSession) -> Result<Container> {
    let meta = &session.meta;
    ensure!(
        meta.quantizer_kind == "flexor",
        "export_fxr requires a flexor-quantized config (got {})",
        meta.quantizer_kind
    );
    let qleaves = meta.quantized_param_leaves();
    ensure!(!qleaves.is_empty(), "no quantized layers found in leaf paths");

    let mut container = Container::new(Json::obj(vec![
        ("config", Json::str(meta.name.clone())),
        ("model", Json::str(meta.model.clone())),
        ("bits_per_weight", Json::num(meta.bits_per_weight)),
    ]));

    for (layer_idx, (enc_leaf, alpha_leaf)) in &qleaves {
        let spec = meta
            .spec_for(*layer_idx)
            .with_context(|| format!("no spec for layer {layer_idx}"))?;
        let storage = meta
            .storage_layers
            .iter()
            .find(|l| l.idx == *layer_idx)
            .with_context(|| format!("no storage row for layer {layer_idx}"))?;
        let enc_meta = &meta.leaves[*enc_leaf];
        ensure!(
            enc_meta.shape.len() == 3
                && enc_meta.shape[0] == spec.q
                && enc_meta.shape[2] == spec.n_in,
            "layer {layer_idx}: w_enc shape {:?} inconsistent with spec q={} n_in={}",
            enc_meta.shape,
            spec.q,
            spec.n_in
        );
        let slices = enc_meta.shape[1];
        let c_out = *storage.shape.last().unwrap();

        let enc = session.leaf_f32(*enc_leaf)?;
        let alpha = session.leaf_f32(*alpha_leaf)?;
        ensure!(alpha.len() == spec.q * c_out, "alpha length mismatch");
        ensure!(spec.mxor.len() == spec.q, "M⊕ plane count != q");

        let plane_len = slices * spec.n_in;
        let planes = (0..spec.q)
            .map(|p| -> Result<Plane> {
                let signs = &enc[p * plane_len..(p + 1) * plane_len];
                Ok(Plane {
                    mxor: spec.mxor[p].clone(),
                    alpha: alpha[p * c_out..(p + 1) * c_out].to_vec(),
                    enc: ColumnBits::from_signs_row_major(signs, spec.n_in)?,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        container.push(Layer {
            name: format!("q{layer_idx}"),
            n_weights: storage.weights,
            c_out,
            planes,
        })?;
    }
    Ok(container)
}

/// The FP sidecar: every params/bn leaf that is *not* encrypted payload
/// (stem, head, biases, BN scale/bias, BN running stats), FXIN-serialized
/// with a JSON index so the inference engine can address leaves by path.
pub fn export_fp_sidecar(session: &TrainSession) -> Result<(Vec<u8>, Json)> {
    let meta = &session.meta;
    let mut leaves = Vec::new();
    let mut index = Vec::new();
    for (i, lm) in meta.leaves.iter().enumerate() {
        let keep = (lm.role == "params"
            && !lm.path.contains("'w_enc'")
            && !lm.path.contains("'alpha'"))
            || lm.role == "bn";
        if !keep {
            continue;
        }
        let data = session.leaf_f32(i)?;
        leaves.push(Leaf {
            dtype: LeafType::F32,
            shape: lm.shape.clone(),
            bytes: data.iter().flat_map(|x| x.to_le_bytes()).collect(),
        });
        index.push(Json::obj(vec![
            ("role", Json::str(lm.role.clone())),
            ("path", Json::str(lm.path.clone())),
            ("shape", Json::arr(lm.shape.iter().map(|&d| Json::num(d as f64)))),
        ]));
    }
    Ok((initbin::write_init_bin(&leaves), Json::arr(index)))
}

/// Write the deployment bundle: `<stem>.fxr`, `<stem>.fp.bin`,
/// `<stem>.bundle.json`.
pub fn export_bundle(session: &TrainSession, dir: &Path, stem: &str) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    let fxr = export_fxr(session)?;
    fxr.save(&dir.join(format!("{stem}.fxr")))?;
    let (fp_bytes, fp_index) = export_fp_sidecar(session)?;
    std::fs::write(dir.join(format!("{stem}.fp.bin")), fp_bytes)?;
    let stats = fxr.stats();
    let layer_shapes = Json::arr(session.meta.storage_layers.iter().map(|l| {
        Json::obj(vec![
            ("name", Json::str(format!("q{}", l.idx))),
            ("idx", Json::num(l.idx as f64)),
            ("shape", Json::arr(l.shape.iter().map(|&d| Json::num(d as f64)))),
        ])
    }));
    let bundle = Json::obj(vec![
        ("config", Json::str(session.meta.name.clone())),
        ("model", Json::str(session.meta.model.clone())),
        ("steps", Json::num(session.steps_done as f64)),
        ("input_shape",
         Json::arr(session.meta.input_shape.iter().skip(1).map(|&d| Json::num(d as f64)))),
        ("num_classes", Json::num(session.meta.num_classes as f64)),
        ("quantized_layers", layer_shapes),
        ("fp_index", fp_index),
        ("encrypted_bits", Json::num(stats.encrypted_bits as f64)),
        ("bits_per_weight", Json::num(stats.bits_per_weight)),
        ("compression_ratio_weights_only",
         Json::num(stats.compression_ratio_weights_only)),
        ("compression_ratio_with_alpha",
         Json::num(stats.compression_ratio_with_alpha)),
    ]);
    std::fs::write(dir.join(format!("{stem}.bundle.json")),
                   bundle.to_string_pretty())?;
    Ok(())
}
