//! Checkpoint export: trained state → `.fxr` encrypted container (the
//! quantized payload the paper ships) + an FXIN "FP sidecar" holding the
//! full-precision residue (stem/head/BN params + running stats) the
//! inference engine needs.

use std::path::Path;

use anyhow::{ensure, Context, Result};

use crate::flexor::bitpack::ColumnBits;
use crate::flexor::fxr::{Container, Layer, Plane};
use crate::flexor::matrix::MXor;
use crate::flexor::num_slices;
use crate::runtime::initbin::{self, Leaf, LeafType};
use crate::substrate::json::Json;
use crate::substrate::prng::Pcg32;

use super::trainer::TrainSession;

/// Build the `.fxr` container from the session's current parameters.
///
/// Per quantized layer `i`: `sign(w_enc[plane])` is bit-packed per plane,
/// with that layer's M⊕ (from the artifact metadata — identical to what the
/// training HLO baked in) and trained α.
pub fn export_fxr(session: &TrainSession) -> Result<Container> {
    let meta = &session.meta;
    ensure!(
        meta.quantizer_kind == "flexor",
        "export_fxr requires a flexor-quantized config (got {})",
        meta.quantizer_kind
    );
    let qleaves = meta.quantized_param_leaves();
    ensure!(!qleaves.is_empty(), "no quantized layers found in leaf paths");

    let mut container = Container::new(Json::obj(vec![
        ("config", Json::str(meta.name.clone())),
        ("model", Json::str(meta.model.clone())),
        ("bits_per_weight", Json::num(meta.bits_per_weight)),
    ]));

    for (layer_idx, (enc_leaf, alpha_leaf)) in &qleaves {
        let spec = meta
            .spec_for(*layer_idx)
            .with_context(|| format!("no spec for layer {layer_idx}"))?;
        let storage = meta
            .storage_layers
            .iter()
            .find(|l| l.idx == *layer_idx)
            .with_context(|| format!("no storage row for layer {layer_idx}"))?;
        let enc_meta = &meta.leaves[*enc_leaf];
        ensure!(
            enc_meta.shape.len() == 3
                && enc_meta.shape[0] == spec.q
                && enc_meta.shape[2] == spec.n_in,
            "layer {layer_idx}: w_enc shape {:?} inconsistent with spec q={} n_in={}",
            enc_meta.shape,
            spec.q,
            spec.n_in
        );
        let slices = enc_meta.shape[1];
        let c_out = *storage.shape.last().unwrap();

        let enc = session.leaf_f32(*enc_leaf)?;
        let alpha = session.leaf_f32(*alpha_leaf)?;
        ensure!(alpha.len() == spec.q * c_out, "alpha length mismatch");
        ensure!(spec.mxor.len() == spec.q, "M⊕ plane count != q");

        let plane_len = slices * spec.n_in;
        let planes = (0..spec.q)
            .map(|p| -> Result<Plane> {
                let signs = &enc[p * plane_len..(p + 1) * plane_len];
                Ok(Plane {
                    mxor: spec.mxor[p].clone(),
                    alpha: alpha[p * c_out..(p + 1) * c_out].to_vec(),
                    enc: ColumnBits::from_signs_row_major(signs, spec.n_in)?,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        container.push(Layer {
            name: format!("q{layer_idx}"),
            n_weights: storage.weights,
            c_out,
            planes,
        })?;
    }
    Ok(container)
}

/// The FP sidecar: every params/bn leaf that is *not* encrypted payload
/// (stem, head, biases, BN scale/bias, BN running stats), FXIN-serialized
/// with a JSON index so the inference engine can address leaves by path.
pub fn export_fp_sidecar(session: &TrainSession) -> Result<(Vec<u8>, Json)> {
    let meta = &session.meta;
    let mut leaves = Vec::new();
    let mut index = Vec::new();
    for (i, lm) in meta.leaves.iter().enumerate() {
        let keep = (lm.role == "params"
            && !lm.path.contains("'w_enc'")
            && !lm.path.contains("'alpha'"))
            || lm.role == "bn";
        if !keep {
            continue;
        }
        let data = session.leaf_f32(i)?;
        leaves.push(Leaf {
            dtype: LeafType::F32,
            shape: lm.shape.clone(),
            bytes: data.iter().flat_map(|x| x.to_le_bytes()).collect(),
        });
        index.push(Json::obj(vec![
            ("role", Json::str(lm.role.clone())),
            ("path", Json::str(lm.path.clone())),
            ("shape", Json::arr(lm.shape.iter().map(|&d| Json::num(d as f64)))),
        ]));
    }
    Ok((initbin::write_init_bin(&leaves), Json::arr(index)))
}

/// One synthetic quantized layer: seeded random M⊕ / α / encrypted bits
/// for a weight of the given shape, with α drawn from `[alpha_lo, alpha_hi)`
/// (callers scale by fan-in to keep deep forwards numerically tame).
fn synth_qlayer(
    rng: &mut Pcg32,
    idx: usize,
    shape: &[usize],
    (q, n_in, n_out): (usize, usize, usize),
    (alpha_lo, alpha_hi): (f32, f32),
) -> Result<(Layer, Json)> {
    let n_weights: usize = shape.iter().product();
    let c_out = *shape.last().unwrap();
    let slices = num_slices(n_weights, n_out);
    let planes = (0..q)
        .map(|_| -> Result<Plane> {
            let mxor = MXor::with_ntap(n_out, n_in, 2, rng)?;
            let alpha = (0..c_out).map(|_| rng.range_f32(alpha_lo, alpha_hi)).collect();
            let bits: Vec<u8> =
                (0..slices * n_in).map(|_| rng.bernoulli(0.5) as u8).collect();
            Ok(Plane { mxor, alpha, enc: ColumnBits::from_row_major(&bits, n_in)? })
        })
        .collect::<Result<Vec<_>>>()?;
    let layer = Layer { name: format!("q{idx}"), n_weights, c_out, planes };
    let index = Json::obj(vec![
        ("name", Json::str(format!("q{idx}"))),
        ("idx", Json::num(idx as f64)),
        ("shape", Json::arr(shape.iter().map(|&d| Json::num(d as f64)))),
    ]);
    Ok((layer, index))
}

fn push_fp_leaf(
    leaves: &mut Vec<Leaf>,
    fp_index: &mut Vec<Json>,
    role: &str,
    path: String,
    shape: Vec<usize>,
    data: Vec<f32>,
) {
    leaves.push(Leaf {
        dtype: LeafType::F32,
        shape: shape.clone(),
        bytes: data.iter().flat_map(|x| x.to_le_bytes()).collect(),
    });
    fp_index.push(Json::obj(vec![
        ("role", Json::str(role)),
        ("path", Json::str(path)),
        ("shape", Json::arr(shape.iter().map(|&d| Json::num(d as f64)))),
    ]));
}

/// Seeded random BN pack (`scale`/`bias`/`mean`/`var`) for site `i`.
fn synth_bn_site(
    rng: &mut Pcg32,
    i: usize,
    w: usize,
    leaves: &mut Vec<Leaf>,
    fp_index: &mut Vec<Json>,
) {
    let scale: Vec<f32> = (0..w).map(|_| rng.range_f32(0.5, 1.5)).collect();
    let bias: Vec<f32> = (0..w).map(|_| 0.1 * rng.normal()).collect();
    let mean: Vec<f32> = (0..w).map(|_| 0.1 * rng.normal()).collect();
    let var: Vec<f32> = (0..w).map(|_| rng.range_f32(0.5, 1.5)).collect();
    for (field, data) in [("scale", scale), ("bias", bias), ("mean", mean), ("var", var)] {
        push_fp_leaf(leaves, fp_index, "bn", format!("['bn'][{i}]['{field}']"),
                     vec![w], data);
    }
}

/// Synthesize a small quantized-MLP deployment bundle — same file set as
/// [`export_bundle`] (`<stem>.fxr` + `<stem>.fp.bin` + bundle index) but
/// with seeded random encrypted bits / α / FP residue instead of a
/// training session. Fixture for the serve subsystem's tests, benches and
/// offline demos: the bundle exercises the full decrypt-at-load +
/// binary-code forward path without artifacts or a PJRT runtime.
pub fn export_synthetic_mlp_bundle(
    dir: &Path,
    stem: &str,
    seed: u64,
    d_in: usize,
    hidden: &[usize],
    num_classes: usize,
) -> Result<()> {
    ensure!(d_in > 0 && num_classes > 0, "degenerate geometry");
    ensure!(!hidden.is_empty(), "synthetic mlp needs at least one hidden layer");
    let mut rng = Pcg32::seeded(seed);
    // the paper's quickstart rate: q=1, 8 encrypted bits → 10 quantized
    let (q, n_in, n_out) = (1usize, 8usize, 10usize);

    let mut widths = vec![d_in];
    widths.extend_from_slice(hidden);

    let mut container = Container::new(Json::obj(vec![
        ("config", Json::str(format!("synthetic_mlp_seed{seed}"))),
        ("model", Json::str("mlp")),
    ]));
    let mut layer_index = Vec::new();
    for (i, pair) in widths.windows(2).enumerate() {
        let (layer, index) = synth_qlayer(&mut rng, i, &[pair[0], pair[1]],
                                          (q, n_in, n_out), (0.05, 0.5))?;
        container.push(layer)?;
        layer_index.push(index);
    }

    // FP residue: one BN pack per quantized layer + the FP head — exactly
    // the leaves `InferenceModel::forward_mlp` consumes.
    let mut leaves = Vec::new();
    let mut fp_index = Vec::new();
    for (i, &w) in hidden.iter().enumerate() {
        synth_bn_site(&mut rng, i, w, &mut leaves, &mut fp_index);
    }
    let last = *hidden.last().unwrap();
    let head_w: Vec<f32> =
        (0..last * num_classes).map(|_| 0.5 * rng.normal()).collect();
    let head_b: Vec<f32> = (0..num_classes).map(|_| 0.1 * rng.normal()).collect();
    push_fp_leaf(&mut leaves, &mut fp_index, "params", "['head']['w']".to_string(),
                 vec![last, num_classes], head_w);
    push_fp_leaf(&mut leaves, &mut fp_index, "params", "['head']['b']".to_string(),
                 vec![num_classes], head_b);

    std::fs::create_dir_all(dir)?;
    container.save(&dir.join(format!("{stem}.fxr")))?;
    std::fs::write(dir.join(format!("{stem}.fp.bin")), initbin::write_init_bin(&leaves))?;
    let stats = container.stats();
    let bundle = Json::obj(vec![
        ("config", Json::str(format!("synthetic_mlp_seed{seed}"))),
        ("model", Json::str("mlp")),
        ("steps", Json::num(0.0)),
        ("input_shape", Json::arr([Json::num(d_in as f64)])),
        ("num_classes", Json::num(num_classes as f64)),
        ("quantized_layers", Json::arr(layer_index)),
        ("fp_index", Json::arr(fp_index)),
        ("encrypted_bits", Json::num(stats.encrypted_bits as f64)),
        ("bits_per_weight", Json::num(stats.bits_per_weight)),
        ("compression_ratio_weights_only",
         Json::num(stats.compression_ratio_weights_only)),
        ("compression_ratio_with_alpha",
         Json::num(stats.compression_ratio_with_alpha)),
    ]);
    std::fs::write(dir.join(format!("{stem}.bundle.json")),
                   bundle.to_string_pretty())?;
    Ok(())
}

/// Synthesize a quantized-resnet deployment bundle (`resnet8` …
/// `resnet32`) with seeded random encrypted bits / α / FP residue — the
/// conv-heavy fixture the compute-engine benchmarks and equivalence tests
/// run on without artifacts or a PJRT runtime. Walks the same block
/// geometry as `InferenceModel::forward_resnet` (stem → [conv1, conv2,
/// optional downsample shortcut] per block → head), emitting quantized
/// conv layers in consumption order and BN packs in conv-site order.
/// α is scaled by `1/√fan_in` so the ~20-conv forward stays finite.
pub fn export_synthetic_resnet_bundle(
    dir: &Path,
    stem: &str,
    seed: u64,
    model: &str,
    input_hw: usize,
    num_classes: usize,
) -> Result<()> {
    ensure!(input_hw >= 4 && num_classes > 0, "degenerate geometry");
    let (blocks, widths) = crate::inference::model::resnet_geometry(model)?;
    let mut rng = Pcg32::seeded(seed);
    let (q, n_in, n_out) = (1usize, 8usize, 10usize);
    let ci = 3usize;

    // walk the block structure: quantized conv shapes in consumption
    // order, BN widths in site order (stem first)
    let mut qshapes: Vec<Vec<usize>> = Vec::new();
    let mut bn_widths: Vec<usize> = vec![widths[0]];
    let mut c_in = widths[0];
    for (si, (&nb, &wd)) in blocks.iter().zip(&widths).enumerate() {
        for bi in 0..nb {
            let stride = if si > 0 && bi == 0 { 2 } else { 1 };
            qshapes.push(vec![3, 3, c_in, wd]);
            bn_widths.push(wd);
            qshapes.push(vec![3, 3, wd, wd]);
            bn_widths.push(wd);
            if stride != 1 || c_in != wd {
                qshapes.push(vec![1, 1, c_in, wd]);
                bn_widths.push(wd);
            }
            c_in = wd;
        }
    }

    let mut container = Container::new(Json::obj(vec![
        ("config", Json::str(format!("synthetic_{model}_seed{seed}"))),
        ("model", Json::str(model)),
    ]));
    let mut layer_index = Vec::new();
    for (i, shape) in qshapes.iter().enumerate() {
        let fan_in: usize = shape.iter().take(shape.len() - 1).product();
        let s = 1.0 / (fan_in as f32).sqrt();
        let (layer, index) = synth_qlayer(&mut rng, i, shape,
                                          (q, n_in, n_out), (0.8 * s, 1.6 * s))?;
        container.push(layer)?;
        layer_index.push(index);
    }

    let mut leaves = Vec::new();
    let mut fp_index = Vec::new();
    let stem_shape = vec![3, 3, ci, widths[0]];
    let stem_fan = (9 * ci) as f32;
    let stem_w: Vec<f32> = (0..9 * ci * widths[0])
        .map(|_| rng.normal() / stem_fan.sqrt())
        .collect();
    push_fp_leaf(&mut leaves, &mut fp_index, "params", "['stem']['w']".to_string(),
                 stem_shape, stem_w);
    for (i, &w) in bn_widths.iter().enumerate() {
        synth_bn_site(&mut rng, i, w, &mut leaves, &mut fp_index);
    }
    let last = *widths.last().unwrap();
    let head_w: Vec<f32> = (0..last * num_classes)
        .map(|_| rng.normal() / (last as f32).sqrt())
        .collect();
    let head_b: Vec<f32> = (0..num_classes).map(|_| 0.1 * rng.normal()).collect();
    push_fp_leaf(&mut leaves, &mut fp_index, "params", "['head']['w']".to_string(),
                 vec![last, num_classes], head_w);
    push_fp_leaf(&mut leaves, &mut fp_index, "params", "['head']['b']".to_string(),
                 vec![num_classes], head_b);

    std::fs::create_dir_all(dir)?;
    container.save(&dir.join(format!("{stem}.fxr")))?;
    std::fs::write(dir.join(format!("{stem}.fp.bin")), initbin::write_init_bin(&leaves))?;
    let stats = container.stats();
    let bundle = Json::obj(vec![
        ("config", Json::str(format!("synthetic_{model}_seed{seed}"))),
        ("model", Json::str(model)),
        ("steps", Json::num(0.0)),
        ("input_shape",
         Json::arr([Json::num(input_hw as f64), Json::num(input_hw as f64),
                    Json::num(ci as f64)])),
        ("num_classes", Json::num(num_classes as f64)),
        ("quantized_layers", Json::arr(layer_index)),
        ("fp_index", Json::arr(fp_index)),
        ("encrypted_bits", Json::num(stats.encrypted_bits as f64)),
        ("bits_per_weight", Json::num(stats.bits_per_weight)),
        ("compression_ratio_weights_only",
         Json::num(stats.compression_ratio_weights_only)),
        ("compression_ratio_with_alpha",
         Json::num(stats.compression_ratio_with_alpha)),
    ]);
    std::fs::write(dir.join(format!("{stem}.bundle.json")),
                   bundle.to_string_pretty())?;
    Ok(())
}

/// Write the deployment bundle: `<stem>.fxr`, `<stem>.fp.bin`,
/// `<stem>.bundle.json`.
pub fn export_bundle(session: &TrainSession, dir: &Path, stem: &str) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    let fxr = export_fxr(session)?;
    fxr.save(&dir.join(format!("{stem}.fxr")))?;
    let (fp_bytes, fp_index) = export_fp_sidecar(session)?;
    std::fs::write(dir.join(format!("{stem}.fp.bin")), fp_bytes)?;
    let stats = fxr.stats();
    let layer_shapes = Json::arr(session.meta.storage_layers.iter().map(|l| {
        Json::obj(vec![
            ("name", Json::str(format!("q{}", l.idx))),
            ("idx", Json::num(l.idx as f64)),
            ("shape", Json::arr(l.shape.iter().map(|&d| Json::num(d as f64)))),
        ])
    }));
    let bundle = Json::obj(vec![
        ("config", Json::str(session.meta.name.clone())),
        ("model", Json::str(session.meta.model.clone())),
        ("steps", Json::num(session.steps_done as f64)),
        ("input_shape",
         Json::arr(session.meta.input_shape.iter().skip(1).map(|&d| Json::num(d as f64)))),
        ("num_classes", Json::num(session.meta.num_classes as f64)),
        ("quantized_layers", layer_shapes),
        ("fp_index", fp_index),
        ("encrypted_bits", Json::num(stats.encrypted_bits as f64)),
        ("bits_per_weight", Json::num(stats.bits_per_weight)),
        ("compression_ratio_weights_only",
         Json::num(stats.compression_ratio_weights_only)),
        ("compression_ratio_with_alpha",
         Json::num(stats.compression_ratio_with_alpha)),
    ]);
    std::fs::write(dir.join(format!("{stem}.bundle.json")),
                   bundle.to_string_pretty())?;
    Ok(())
}
