//! L3 training coordinator: owns the training loop the paper's recipes
//! describe (§3 MNIST/Adam, §4 CIFAR/SGD with warmup + step decay + S_tanh
//! doubling, §5 ImageNet), the metric sinks, and checkpoint export to the
//! `.fxr` encrypted container.
//!
//! The compute graph never changes at runtime — schedules are *inputs* to
//! the lowered HLO (`lr`, `s_tanh`, `relax_lambda` scalars per step).

pub mod experiments;
pub mod export;
pub mod metrics;
pub mod schedule;
pub mod trainer;

pub use export::{export_bundle, export_fp_sidecar, export_fxr,
                 export_synthetic_mlp_bundle, export_synthetic_resnet_bundle};
pub use metrics::{EvalRow, MetricsSink, TrainRow};
pub use schedule::Schedule;
pub use trainer::{EvalResult, TrainSession};
