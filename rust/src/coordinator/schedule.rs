//! Training schedules (paper §4 technique 4 and §5):
//!
//! * learning rate: linear warmup from 0 to `base_lr` over `warmup_epochs`,
//!   then multiplicative decay at `decay_epochs`;
//! * `S_tanh`: linear warmup from `s_tanh_start` to `s_tanh_base` on the
//!   same warmup window, then ×`s_tanh_decay_mult` at every LR decay point
//!   ("as learning rate decays, S_tanh is empirically multiplied by 2");
//! * BinaryRelax λ: multiplicative growth per epoch (λ→∞ anneals the
//!   relaxation to a hard sign).

/// All schedule state is derived from (epoch fraction) — pure functions of
/// the step index, so runs are exactly resumable.
#[derive(Clone, Debug)]
pub struct Schedule {
    pub base_lr: f32,
    pub warmup_epochs: f32,
    /// Epochs at which LR is multiplied by `decay_factor`.
    pub decay_epochs: Vec<f32>,
    pub decay_factor: f32,
    pub s_tanh_start: f32,
    pub s_tanh_base: f32,
    pub s_tanh_decay_mult: f32,
    /// λ(e) = relax_lambda0 · relax_growth^e (BinaryRelax baseline).
    pub relax_lambda0: f32,
    pub relax_growth: f32,
    pub steps_per_epoch: usize,
}

impl Schedule {
    /// The paper's CIFAR recipe shape (Fig. 7): warmup, decay ×0.5.
    pub fn cifar(base_lr: f32, warmup_epochs: f32, decay_epochs: Vec<f32>,
                 steps_per_epoch: usize) -> Self {
        Schedule {
            base_lr,
            warmup_epochs,
            decay_epochs,
            decay_factor: 0.5,
            s_tanh_start: 5.0,
            s_tanh_base: 10.0,
            s_tanh_decay_mult: 2.0,
            relax_lambda0: 1.0,
            relax_growth: 1.02,
            steps_per_epoch: steps_per_epoch.max(1),
        }
    }

    /// The MNIST recipe: constant Adam LR, constant high S_tanh (§3).
    pub fn mnist(base_lr: f32, steps_per_epoch: usize) -> Self {
        Schedule {
            base_lr,
            warmup_epochs: 0.0,
            decay_epochs: vec![],
            decay_factor: 1.0,
            s_tanh_start: 100.0,
            s_tanh_base: 100.0,
            s_tanh_decay_mult: 1.0,
            relax_lambda0: 1.0,
            relax_growth: 1.02,
            steps_per_epoch: steps_per_epoch.max(1),
        }
    }

    pub fn epoch_of(&self, step: usize) -> f32 {
        step as f32 / self.steps_per_epoch as f32
    }

    fn decays_done(&self, e: f32) -> usize {
        self.decay_epochs.iter().filter(|&&d| e >= d).count()
    }

    pub fn lr(&self, step: usize) -> f32 {
        let e = self.epoch_of(step);
        let warm = if self.warmup_epochs > 0.0 && e < self.warmup_epochs {
            e / self.warmup_epochs
        } else {
            1.0
        };
        self.base_lr * warm * self.decay_factor.powi(self.decays_done(e) as i32)
    }

    pub fn s_tanh(&self, step: usize) -> f32 {
        let e = self.epoch_of(step);
        let base = if self.warmup_epochs > 0.0 && e < self.warmup_epochs {
            self.s_tanh_start
                + (self.s_tanh_base - self.s_tanh_start) * (e / self.warmup_epochs)
        } else {
            self.s_tanh_base
        };
        base * self.s_tanh_decay_mult.powi(self.decays_done(e) as i32)
    }

    pub fn relax_lambda(&self, step: usize) -> f32 {
        self.relax_lambda0 * self.relax_growth.powf(self.epoch_of(step))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::ptest::check_msg;

    fn s() -> Schedule {
        Schedule::cifar(0.1, 2.0, vec![6.0, 8.0], 100)
    }

    #[test]
    fn lr_warmup_then_decay() {
        let sch = s();
        assert_eq!(sch.lr(0), 0.0);
        assert!((sch.lr(100) - 0.05).abs() < 1e-6); // epoch 1 of 2 warmup
        assert!((sch.lr(200) - 0.1).abs() < 1e-6); // warmup done
        assert!((sch.lr(599) - 0.1).abs() < 1e-6);
        assert!((sch.lr(600) - 0.05).abs() < 1e-6); // first decay at e6
        assert!((sch.lr(800) - 0.025).abs() < 1e-6); // second decay at e8
    }

    #[test]
    fn s_tanh_warmup_and_doubling() {
        let sch = s();
        assert_eq!(sch.s_tanh(0), 5.0);
        assert!((sch.s_tanh(100) - 7.5).abs() < 1e-6);
        assert_eq!(sch.s_tanh(200), 10.0);
        assert_eq!(sch.s_tanh(600), 20.0); // doubled with first decay
        assert_eq!(sch.s_tanh(800), 40.0);
    }

    #[test]
    fn mnist_recipe_is_constant() {
        let sch = Schedule::mnist(1e-4, 50);
        for step in [0, 10, 1000, 50_000] {
            assert_eq!(sch.lr(step), 1e-4);
            assert_eq!(sch.s_tanh(step), 100.0);
        }
    }

    #[test]
    fn relax_lambda_grows() {
        let sch = s();
        assert!(sch.relax_lambda(0) < sch.relax_lambda(1000));
    }

    #[test]
    fn lr_monotone_within_phases() {
        check_msg("lr non-increasing after warmup", 30, |g| {
            let spe = g.usize_in(10, 200);
            let sch = Schedule::cifar(
                g.f32_in(0.01, 0.5),
                g.f32_in(0.0, 3.0),
                vec![g.f32_in(3.0, 5.0), g.f32_in(5.0, 9.0)],
                spe,
            );
            let warm_end = (sch.warmup_epochs * spe as f32).ceil() as usize + 1;
            let mut prev = f32::INFINITY;
            for step in warm_end..spe * 10 {
                let lr = sch.lr(step);
                if lr > prev + 1e-9 {
                    return Err(format!("lr rose at step {step}"));
                }
                prev = lr;
            }
            Ok(())
        });
    }
}
