//! The training session: executes the AOT train/eval HLO step by step,
//! feeding back state literals and schedule scalars (no Python anywhere).

use std::sync::Arc;
use std::time::Instant;

use anyhow::{ensure, Context, Result};
use xla::Literal;

use crate::data::{Batcher, Dataset, Split};
use crate::runtime::client::{lit, Executable, Runtime};
use crate::runtime::initbin;
use crate::runtime::manifest::{ConfigMeta, Manifest};
use crate::substrate::stats::Histogram;

use super::metrics::{EvalRow, MetricsSink, TrainRow};
use super::schedule::Schedule;

/// Aggregated evaluation result over a fixed test set.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EvalResult {
    pub loss: f32,
    pub top1: f32,
    pub top5: f32,
    pub examples: usize,
}

/// One live training run of one lowered config.
pub struct TrainSession {
    pub meta: ConfigMeta,
    train_exe: Arc<Executable>,
    eval_exe: Arc<Executable>,
    /// Flat state literals: params ++ opt ++ bn (the HLO feedback set).
    pub state: Vec<Literal>,
    pub steps_done: usize,
}

impl TrainSession {
    /// Load artifacts for `config_name`, compile, and initialize state.
    pub fn new(rt: &Runtime, manifest: &Manifest, config_name: &str) -> Result<Self> {
        let meta = manifest.config(config_name)?;
        let train_exe = rt.load_hlo(&meta.train_hlo_path())?;
        let eval_exe = rt.load_hlo(&meta.eval_hlo_path())?;
        let leaves = initbin::load_init_bin(&meta.init_bin_path())?;
        ensure!(
            leaves.len() == meta.n_state(),
            "init.bin has {} leaves, meta expects {}",
            leaves.len(),
            meta.n_state()
        );
        for (i, (leaf, lm)) in leaves.iter().zip(&meta.leaves).enumerate() {
            ensure!(
                leaf.shape == lm.shape,
                "leaf {i} shape {:?} != meta {:?} ({})",
                leaf.shape,
                lm.shape,
                lm.path
            );
        }
        let state = leaves.iter().map(|l| l.to_literal()).collect();
        Ok(TrainSession { meta, train_exe, eval_exe, state, steps_done: 0 })
    }

    /// Input tensor dims for one batch (batch-major NHWC or NC).
    pub fn batch_dims(&self) -> Vec<usize> {
        self.meta.input_shape.clone()
    }

    /// One optimizer step. `x` is the flat batch (matching input_shape),
    /// `y` int labels. Returns (loss, batch accuracy).
    pub fn step(&mut self, x: &[f32], y: &[i32], lr: f32, s_tanh: f32,
                relax_lambda: f32) -> Result<(f32, f32)> {
        let xs = lit::f32_tensor(x, &self.meta.input_shape)?;
        let ys = lit::i32_vec(y);
        let scalars = [
            lit::f32_scalar(lr),
            lit::f32_scalar(s_tanh),
            lit::f32_scalar(relax_lambda),
        ];
        let mut inputs: Vec<&Literal> = Vec::with_capacity(self.state.len() + 5);
        inputs.extend(self.state.iter());
        inputs.push(&xs);
        inputs.push(&ys);
        inputs.extend(scalars.iter());

        let mut out = self.train_exe.run(&inputs)?;
        let n_state = self.meta.n_state();
        ensure!(
            out.len() == n_state + 2,
            "train_step returned {} outputs, expected {}",
            out.len(),
            n_state + 2
        );
        let correct = lit::scalar_f32(&out[n_state + 1])?;
        let loss = lit::scalar_f32(&out[n_state])?;
        out.truncate(n_state);
        self.state = out;
        self.steps_done += 1;
        Ok((loss, correct / y.len() as f32))
    }

    /// Evaluate on a fixed set (len must be a multiple of the batch size;
    /// callers round). Uses running BN statistics (eval-mode HLO).
    pub fn eval(&self, xs: &[f32], ys: &[i32], s_tanh: f32,
                relax_lambda: f32) -> Result<EvalResult> {
        let b = self.meta.batch;
        let fl: usize = self.meta.input_shape.iter().skip(1).product();
        ensure!(!ys.is_empty() && ys.len() % b == 0,
                "eval set size {} not a multiple of batch {}", ys.len(), b);
        let n_chunks = ys.len() / b;
        let (mut loss_sum, mut top1_sum, mut top5_sum) = (0f64, 0f64, 0f64);
        for c in 0..n_chunks {
            let xc = &xs[c * b * fl..(c + 1) * b * fl];
            let yc = &ys[c * b..(c + 1) * b];
            let xl = lit::f32_tensor(xc, &self.meta.input_shape)?;
            let yl = lit::i32_vec(yc);
            let s1 = lit::f32_scalar(s_tanh);
            let s2 = lit::f32_scalar(relax_lambda);
            let mut inputs: Vec<&Literal> =
                Vec::with_capacity(self.meta.n_params + self.meta.n_bn + 4);
            inputs.extend(self.state[..self.meta.n_params].iter());
            inputs.extend(self.state[self.meta.n_params + self.meta.n_opt..].iter());
            inputs.push(&xl);
            inputs.push(&yl);
            inputs.push(&s1);
            inputs.push(&s2);
            let out = self.eval_exe.run(&inputs)?;
            ensure!(out.len() == 3, "eval returned {} outputs", out.len());
            loss_sum += lit::scalar_f32(&out[0])? as f64;
            top1_sum += lit::scalar_f32(&out[1])? as f64;
            top5_sum += lit::scalar_f32(&out[2])? as f64;
        }
        let n = ys.len() as f64;
        Ok(EvalResult {
            loss: (loss_sum / n_chunks as f64) as f32,
            top1: (top1_sum / n) as f32,
            top5: (top5_sum / n) as f32,
            examples: ys.len(),
        })
    }

    /// Run `steps` training steps over `ds` with `schedule`, evaluating on a
    /// fixed test set of `eval_n` examples every `eval_every` steps (and at
    /// the end). Returns the final eval.
    pub fn train_loop(&mut self, ds: &dyn Dataset, schedule: &Schedule,
                      steps: usize, eval_every: usize, eval_n: usize,
                      sink: &mut MetricsSink) -> Result<EvalResult> {
        ensure!(ds.feature_len() == self.meta.input_shape.iter().skip(1).product::<usize>(),
                "dataset geometry {:?} != artifact input {:?}",
                ds.input_dims(), &self.meta.input_shape[1..]);
        ensure!(ds.num_classes() >= 2);
        let b = self.meta.batch;
        let mut batcher = Batcher::new(ds, Split::Train, b,
                                       (schedule.steps_per_epoch * b) as u64);
        let eval_n = (eval_n / b).max(1) * b;
        let (ex, ey) = Batcher::eval_set(ds, Split::Test, eval_n);

        let mut last_eval = None;
        for _ in 0..steps {
            let step = self.steps_done;
            let (x, y) = batcher.next_batch();
            let t0 = Instant::now();
            let (loss, acc) = self.step(
                &x, &y,
                schedule.lr(step),
                schedule.s_tanh(step),
                schedule.relax_lambda(step),
            )?;
            sink.push_train(TrainRow {
                step,
                epoch: schedule.epoch_of(step),
                loss,
                acc,
                lr: schedule.lr(step),
                s_tanh: schedule.s_tanh(step),
                wall_ms: t0.elapsed().as_secs_f64() * 1e3,
            });
            let done = self.steps_done;
            if eval_every > 0 && done % eval_every == 0 || done == steps {
                let ev = self.eval(&ex, &ey, schedule.s_tanh(done),
                                   schedule.relax_lambda(done))?;
                sink.push_eval(EvalRow {
                    step: done,
                    loss: ev.loss,
                    top1: ev.top1,
                    top5: ev.top5,
                });
                last_eval = Some(ev);
            }
        }
        last_eval.context("no eval ran (steps == 0?)")
    }

    /// Host copy of one state leaf.
    pub fn leaf_f32(&self, leaf_idx: usize) -> Result<Vec<f32>> {
        ensure!(leaf_idx < self.state.len(), "leaf index out of range");
        Ok(self.state[leaf_idx].to_vec::<f32>()?)
    }

    /// Histogram of all encrypted weights (Figs. 6/13/14).
    pub fn encrypted_weight_histogram(&self, lo: f64, hi: f64, bins: usize)
                                      -> Result<Histogram> {
        let mut h = Histogram::new(lo, hi, bins);
        for (i, lm) in self.meta.leaves.iter().enumerate() {
            if lm.role == "params" && lm.path.contains("'w_enc'") {
                for v in self.leaf_f32(i)? {
                    h.push(v as f64);
                }
            }
        }
        Ok(h)
    }

    /// Serialize the full training state (FXIN) for resume.
    pub fn save_checkpoint(&self, path: &std::path::Path) -> Result<()> {
        let leaves: Vec<initbin::Leaf> = self
            .state
            .iter()
            .zip(&self.meta.leaves)
            .map(|(l, lm)| -> Result<initbin::Leaf> {
                let (dtype, bytes) = if lm.dtype == "int32" {
                    let v = l.to_vec::<i32>()?;
                    (initbin::LeafType::I32,
                     v.iter().flat_map(|x| x.to_le_bytes()).collect())
                } else {
                    let v = l.to_vec::<f32>()?;
                    (initbin::LeafType::F32,
                     v.iter().flat_map(|x| x.to_le_bytes()).collect())
                };
                Ok(initbin::Leaf { dtype, shape: lm.shape.clone(), bytes })
            })
            .collect::<Result<_>>()?;
        std::fs::write(path, initbin::write_init_bin(&leaves))
            .with_context(|| format!("writing {}", path.display()))
    }

    /// Restore training state saved by [`save_checkpoint`].
    pub fn load_checkpoint(&mut self, path: &std::path::Path) -> Result<()> {
        let leaves = initbin::load_init_bin(path)?;
        ensure!(leaves.len() == self.meta.n_state(), "checkpoint leaf count");
        for (leaf, lm) in leaves.iter().zip(&self.meta.leaves) {
            ensure!(leaf.shape == lm.shape, "checkpoint shape mismatch at {}", lm.path);
        }
        self.state = leaves.iter().map(|l| l.to_literal()).collect();
        Ok(())
    }
}
