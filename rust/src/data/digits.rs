//! "digits" — the MNIST substitute: 28×28 grayscale stroke-rendered digit
//! glyphs with nuisance factors (translation, scale, shear, stroke
//! thickness, intensity jitter, pixel noise). Deterministic per
//! (seed, split, index); non-trivially separable but learnable — the
//! property the paper's MNIST experiments (Fig. 4/12/13) exercise.

use super::{example_rng, Dataset, Split};

pub const HW: usize = 28;

/// 5×7 bitmap font, row-major, one byte-string per digit.
const GLYPHS: [[u8; 35]; 10] = [
    // 0
    [0,1,1,1,0, 1,0,0,0,1, 1,0,0,1,1, 1,0,1,0,1, 1,1,0,0,1, 1,0,0,0,1, 0,1,1,1,0],
    // 1
    [0,0,1,0,0, 0,1,1,0,0, 0,0,1,0,0, 0,0,1,0,0, 0,0,1,0,0, 0,0,1,0,0, 0,1,1,1,0],
    // 2
    [0,1,1,1,0, 1,0,0,0,1, 0,0,0,0,1, 0,0,0,1,0, 0,0,1,0,0, 0,1,0,0,0, 1,1,1,1,1],
    // 3
    [1,1,1,1,1, 0,0,0,1,0, 0,0,1,0,0, 0,0,0,1,0, 0,0,0,0,1, 1,0,0,0,1, 0,1,1,1,0],
    // 4
    [0,0,0,1,0, 0,0,1,1,0, 0,1,0,1,0, 1,0,0,1,0, 1,1,1,1,1, 0,0,0,1,0, 0,0,0,1,0],
    // 5
    [1,1,1,1,1, 1,0,0,0,0, 1,1,1,1,0, 0,0,0,0,1, 0,0,0,0,1, 1,0,0,0,1, 0,1,1,1,0],
    // 6
    [0,0,1,1,0, 0,1,0,0,0, 1,0,0,0,0, 1,1,1,1,0, 1,0,0,0,1, 1,0,0,0,1, 0,1,1,1,0],
    // 7
    [1,1,1,1,1, 0,0,0,0,1, 0,0,0,1,0, 0,0,1,0,0, 0,1,0,0,0, 0,1,0,0,0, 0,1,0,0,0],
    // 8
    [0,1,1,1,0, 1,0,0,0,1, 1,0,0,0,1, 0,1,1,1,0, 1,0,0,0,1, 1,0,0,0,1, 0,1,1,1,0],
    // 9
    [0,1,1,1,0, 1,0,0,0,1, 1,0,0,0,1, 0,1,1,1,1, 0,0,0,0,1, 0,0,0,1,0, 0,1,1,0,0],
];

pub struct Digits {
    seed: u64,
    noise: f32,
}

impl Digits {
    pub fn new(seed: u64) -> Self {
        Digits { seed, noise: 0.15 }
    }

    pub fn with_noise(seed: u64, noise: f32) -> Self {
        Digits { seed, noise }
    }
}

impl Dataset for Digits {
    fn feature_len(&self) -> usize {
        HW * HW
    }

    fn input_dims(&self) -> Vec<usize> {
        vec![HW, HW, 1]
    }

    fn num_classes(&self) -> usize {
        10
    }

    fn example(&self, split: Split, index: u64, out: &mut [f32]) -> i32 {
        debug_assert_eq!(out.len(), HW * HW);
        let mut rng = example_rng(self.seed ^ 0xd161, split, index);
        let label = rng.below(10) as usize;
        let glyph = &GLYPHS[label];

        // nuisance parameters
        let scale = rng.range_f32(2.6, 3.6); // glyph cell → pixels
        let dx = rng.range_f32(-3.0, 3.0) + (HW as f32 - 5.0 * scale) / 2.0;
        let dy = rng.range_f32(-3.0, 3.0) + (HW as f32 - 7.0 * scale) / 2.0;
        let shear = rng.range_f32(-0.15, 0.15);
        let thick = rng.range_f32(0.55, 0.95); // coverage radius in cells
        let gain = rng.range_f32(0.75, 1.0);

        // render: for each output pixel, inverse-map into glyph space and
        // take soft coverage against the nearest inked cell center.
        for py in 0..HW {
            for px in 0..HW {
                let fy = (py as f32 - dy) / scale;
                let fx = (px as f32 - dx) / scale - shear * (fy - 3.5);
                let mut v: f32 = 0.0;
                let cy = fy.floor() as i32;
                let cx = fx.floor() as i32;
                for gy in cy - 1..=cy + 1 {
                    for gx in cx - 1..=cx + 1 {
                        if (0..7).contains(&gy) && (0..5).contains(&gx) {
                            if glyph[gy as usize * 5 + gx as usize] == 1 {
                                let ddx = fx - (gx as f32 + 0.5);
                                let ddy = fy - (gy as f32 + 0.5);
                                let d = (ddx * ddx + ddy * ddy).sqrt();
                                let cov = (1.0 - (d / thick)).clamp(0.0, 1.0);
                                v = v.max(cov);
                            }
                        }
                    }
                }
                let noisy = gain * v + self.noise * rng.normal();
                out[py * HW + px] = noisy.clamp(0.0, 1.0);
            }
        }
        label as i32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn render(label_want: Option<i32>, idx: u64) -> (Vec<f32>, i32) {
        let ds = Digits::new(1);
        let mut buf = vec![0.0f32; HW * HW];
        let mut i = idx;
        loop {
            let y = ds.example(Split::Train, i, &mut buf);
            if label_want.is_none() || Some(y) == label_want {
                return (buf, y);
            }
            i += 1;
        }
    }

    #[test]
    fn values_in_range_and_nontrivial() {
        let (img, _) = render(None, 0);
        assert!(img.iter().all(|&v| (0.0..=1.0).contains(&v)));
        let ink: f32 = img.iter().sum();
        assert!(ink > 10.0, "image nearly empty: {ink}");
        assert!(ink < 500.0, "image nearly full: {ink}");
    }

    #[test]
    fn deterministic_and_index_varied() {
        let ds = Digits::new(1);
        let mut a = vec![0.0; HW * HW];
        let mut b = vec![0.0; HW * HW];
        assert_eq!(
            ds.example(Split::Train, 5, &mut a),
            ds.example(Split::Train, 5, &mut b)
        );
        assert_eq!(a, b);
        ds.example(Split::Train, 6, &mut b);
        assert_ne!(a, b);
    }

    #[test]
    fn all_labels_reachable() {
        let ds = Digits::new(2);
        let mut seen = [false; 10];
        let mut buf = vec![0.0; HW * HW];
        for i in 0..200 {
            seen[ds.example(Split::Train, i, &mut buf) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "{seen:?}");
    }

    #[test]
    fn same_class_examples_are_more_similar_than_cross_class() {
        // template correlation: same-digit pairs should correlate more than
        // different-digit pairs on average (i.e. the task is learnable).
        let (a1, _) = render(Some(3), 0);
        let (a2, _) = render(Some(3), 40);
        let (b1, _) = render(Some(1), 0);
        let dot = |x: &[f32], y: &[f32]| -> f32 {
            let nx = x.iter().map(|v| v * v).sum::<f32>().sqrt();
            let ny = y.iter().map(|v| v * v).sum::<f32>().sqrt();
            x.iter().zip(y).map(|(p, q)| p * q).sum::<f32>() / (nx * ny)
        };
        assert!(dot(&a1, &a2) > dot(&a1, &b1), "3-3 {} vs 3-1 {}", dot(&a1, &a2), dot(&a1, &b1));
    }
}
