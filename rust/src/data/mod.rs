//! Synthetic dataset substrates (DESIGN.md §5: no network access, so the
//! paper's MNIST / CIFAR-10 / ImageNet are substituted with deterministic
//! procedural datasets of matching geometry):
//!
//! * [`digits`]   — 28×28×1, 10 classes: stroke-rendered digit glyphs with
//!   random shift / scale / rotation-ish shear / noise (MNIST substitute);
//! * [`shapes`]   — 32×32×3 ("shapes32", CIFAR-10 sub) and 64×64×3 with 20
//!   classes ("shapes64", ImageNet sub): textured geometric shapes with
//!   color/position/scale/noise nuisance factors;
//! * [`gaussian`] — K-class gaussian mixtures for MLP unit tests;
//! * [`Dataset`]  — the common batching/shuffling/split interface the
//!   coordinator consumes.

pub mod digits;
pub mod gaussian;
pub mod shapes;

use crate::substrate::prng::Pcg32;

/// A deterministic, generate-on-demand labeled dataset.
pub trait Dataset: Send + Sync {
    /// Flat feature length per example (e.g. 28·28 or 32·32·3).
    fn feature_len(&self) -> usize;
    /// Input tensor dims per example (without batch), e.g. [28, 28, 1].
    fn input_dims(&self) -> Vec<usize>;
    fn num_classes(&self) -> usize;
    /// Generate example `index` of split `split` into `out` (len = feature_len).
    /// Deterministic in (seed, split, index).
    fn example(&self, split: Split, index: u64, out: &mut [f32]) -> i32;
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Split {
    Train,
    Test,
}

impl Split {
    fn stream(self) -> u64 {
        match self {
            Split::Train => 0x7121,
            Split::Test => 0x7e57,
        }
    }
}

/// Batched iterator over a Dataset: fills contiguous NHWC buffers.
pub struct Batcher<'a> {
    ds: &'a dyn Dataset,
    split: Split,
    batch: usize,
    /// Virtual epoch length (procedural data is infinite; this bounds an
    /// "epoch" for schedule purposes).
    epoch_len: u64,
    cursor: u64,
}

impl<'a> Batcher<'a> {
    pub fn new(ds: &'a dyn Dataset, split: Split, batch: usize, epoch_len: u64) -> Self {
        assert!(batch > 0 && epoch_len > 0);
        Batcher { ds, split, batch, epoch_len, cursor: 0 }
    }

    pub fn batch_size(&self) -> usize {
        self.batch
    }

    pub fn batches_per_epoch(&self) -> u64 {
        self.epoch_len / self.batch as u64
    }

    /// Next batch: (features NHWC row-major, labels).
    pub fn next_batch(&mut self) -> (Vec<f32>, Vec<i32>) {
        let fl = self.ds.feature_len();
        let mut xs = vec![0.0f32; self.batch * fl];
        let mut ys = vec![0i32; self.batch];
        for b in 0..self.batch {
            let idx = self.cursor % self.epoch_len;
            ys[b] = self
                .ds
                .example(self.split, idx, &mut xs[b * fl..(b + 1) * fl]);
            self.cursor += 1;
        }
        (xs, ys)
    }

    /// Materialize a fixed evaluation set of `n` examples.
    pub fn eval_set(ds: &dyn Dataset, split: Split, n: usize) -> (Vec<f32>, Vec<i32>) {
        let fl = ds.feature_len();
        let mut xs = vec![0.0f32; n * fl];
        let mut ys = vec![0i32; n];
        for i in 0..n {
            ys[i] = ds.example(split, i as u64, &mut xs[i * fl..(i + 1) * fl]);
        }
        (xs, ys)
    }
}

/// Per-example RNG: independent stream per (seed, split, index).
pub(crate) fn example_rng(seed: u64, split: Split, index: u64) -> Pcg32 {
    Pcg32::new(
        seed ^ index.wrapping_mul(0x9E3779B97F4A7C15),
        split.stream() ^ index,
    )
}

/// Build a dataset by name (the config-file interface).
pub fn by_name(name: &str, seed: u64) -> anyhow::Result<Box<dyn Dataset>> {
    match name {
        "digits" => Ok(Box::new(digits::Digits::new(seed))),
        "shapes32" => Ok(Box::new(shapes::Shapes::cifar_like(seed))),
        "shapes64" => Ok(Box::new(shapes::Shapes::imagenet_like(seed))),
        "gaussian" => Ok(Box::new(gaussian::GaussianMixture::new(seed, 32, 10, 0.35))),
        other => anyhow::bail!("unknown dataset '{other}'"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batcher_shapes_and_determinism() {
        let ds = gaussian::GaussianMixture::new(7, 8, 3, 0.3);
        let mut b1 = Batcher::new(&ds, Split::Train, 4, 64);
        let mut b2 = Batcher::new(&ds, Split::Train, 4, 64);
        let (x1, y1) = b1.next_batch();
        let (x2, y2) = b2.next_batch();
        assert_eq!(x1, x2);
        assert_eq!(y1, y2);
        assert_eq!(x1.len(), 4 * 8);
        assert!(y1.iter().all(|&y| (0..3).contains(&y)));
        // second batch differs
        let (x3, _) = b1.next_batch();
        assert_ne!(x1, x3);
    }

    #[test]
    fn train_test_streams_differ() {
        let ds = gaussian::GaussianMixture::new(7, 8, 3, 0.3);
        let mut tr = Batcher::new(&ds, Split::Train, 4, 64);
        let mut te = Batcher::new(&ds, Split::Test, 4, 64);
        assert_ne!(tr.next_batch().0, te.next_batch().0);
    }

    #[test]
    fn epoch_wraps() {
        let ds = gaussian::GaussianMixture::new(1, 4, 2, 0.3);
        let mut b = Batcher::new(&ds, Split::Train, 2, 4);
        let (x1, _) = b.next_batch();
        let _ = b.next_batch();
        let (x3, _) = b.next_batch(); // cursor 4,5 → wraps to 0,1
        assert_eq!(x1, x3);
        assert_eq!(b.batches_per_epoch(), 2);
    }

    #[test]
    fn by_name_registry() {
        for n in ["digits", "shapes32", "shapes64", "gaussian"] {
            let ds = by_name(n, 1).unwrap();
            assert!(ds.feature_len() > 0);
            assert!(ds.num_classes() >= 2);
            let dims: usize = ds.input_dims().iter().product();
            assert_eq!(dims, ds.feature_len());
        }
        assert!(by_name("nope", 1).is_err());
    }
}
