//! "shapes32" / "shapes64" — the CIFAR-10 / ImageNet substitutes: RGB
//! images of textured geometric shapes with heavy nuisance variation
//! (position, scale, rotation, fg/bg color, texture phase, noise).
//!
//! shapes32: 32×32×3, 10 classes (one per shape family).
//! shapes64: 64×64×3, 20 classes (shape family × texture family).

use super::{example_rng, Dataset, Split};

#[derive(Clone, Copy, Debug)]
enum ShapeKind {
    Disk,
    Square,
    Triangle,
    Cross,
    Ring,
    HStripes,
    VStripes,
    Checker,
    Diamond,
    DotGrid,
}

const KINDS: [ShapeKind; 10] = [
    ShapeKind::Disk,
    ShapeKind::Square,
    ShapeKind::Triangle,
    ShapeKind::Cross,
    ShapeKind::Ring,
    ShapeKind::HStripes,
    ShapeKind::VStripes,
    ShapeKind::Checker,
    ShapeKind::Diamond,
    ShapeKind::DotGrid,
];

pub struct Shapes {
    seed: u64,
    hw: usize,
    classes: usize,
    noise: f32,
}

impl Shapes {
    pub fn cifar_like(seed: u64) -> Self {
        Shapes { seed, hw: 32, classes: 10, noise: 0.10 }
    }

    pub fn imagenet_like(seed: u64) -> Self {
        Shapes { seed, hw: 64, classes: 20, noise: 0.10 }
    }

    pub fn custom(seed: u64, hw: usize, classes: usize, noise: f32) -> Self {
        assert!(classes <= 20, "≤ 20 classes supported");
        Shapes { seed, hw, classes, noise }
    }

    /// Shape mask value at normalized body coordinates (u, v) ∈ [-1, 1].
    fn mask(kind: ShapeKind, u: f32, v: f32, phase: f32) -> f32 {
        let r = (u * u + v * v).sqrt();
        let inside = |b: bool| if b { 1.0 } else { 0.0 };
        match kind {
            ShapeKind::Disk => inside(r < 0.85),
            ShapeKind::Square => inside(u.abs() < 0.75 && v.abs() < 0.75),
            ShapeKind::Triangle => {
                inside(v > -0.7 && v < 0.8 && u.abs() < (0.8 - v) * 0.66)
            }
            ShapeKind::Cross => {
                inside((u.abs() < 0.3 && v.abs() < 0.9) || (v.abs() < 0.3 && u.abs() < 0.9))
            }
            ShapeKind::Ring => inside(r > 0.45 && r < 0.85),
            ShapeKind::HStripes => {
                inside(r < 0.95 && ((v * 3.0 + phase).sin() > 0.0))
            }
            ShapeKind::VStripes => {
                inside(r < 0.95 && ((u * 3.0 + phase).sin() > 0.0))
            }
            ShapeKind::Checker => inside(
                r < 0.95 && ((u * 2.5 + phase).sin() * (v * 2.5 + phase).sin() > 0.0),
            ),
            ShapeKind::Diamond => inside(u.abs() + v.abs() < 0.95),
            ShapeKind::DotGrid => {
                let fu = (u * 2.2 + phase).sin();
                let fv = (v * 2.2 + phase).sin();
                inside(r < 0.95 && fu * fu + fv * fv > 1.2)
            }
        }
    }
}

impl Dataset for Shapes {
    fn feature_len(&self) -> usize {
        self.hw * self.hw * 3
    }

    fn input_dims(&self) -> Vec<usize> {
        vec![self.hw, self.hw, 3]
    }

    fn num_classes(&self) -> usize {
        self.classes
    }

    fn example(&self, split: Split, index: u64, out: &mut [f32]) -> i32 {
        let hw = self.hw;
        debug_assert_eq!(out.len(), hw * hw * 3);
        let mut rng = example_rng(self.seed ^ 0x5AE5, split, index);
        let label = rng.below(self.classes as u32) as usize;
        let kind = KINDS[label % 10];
        // shapes64's second decade = same shapes, inverted-texture family
        let family = label / 10;

        let cx = rng.range_f32(0.35, 0.65) * hw as f32;
        let cy = rng.range_f32(0.35, 0.65) * hw as f32;
        let radius = rng.range_f32(0.25, 0.42) * hw as f32;
        let rot = rng.range_f32(0.0, std::f32::consts::TAU);
        let (sr, cr) = rot.sin_cos();
        let phase = rng.range_f32(0.0, std::f32::consts::TAU);
        let fg = [rng.range_f32(0.55, 1.0), rng.range_f32(0.55, 1.0), rng.range_f32(0.55, 1.0)];
        let bg = [rng.range_f32(0.0, 0.35), rng.range_f32(0.0, 0.35), rng.range_f32(0.0, 0.35)];
        // background gradient direction
        let gdir = rng.range_f32(0.0, std::f32::consts::TAU);
        let (gs, gc) = gdir.sin_cos();

        for py in 0..hw {
            for px in 0..hw {
                let x = px as f32;
                let y = py as f32;
                // body coords with rotation
                let du = (x - cx) / radius;
                let dv = (y - cy) / radius;
                let u = cr * du + sr * dv;
                let v = -sr * du + cr * dv;
                let mut m = Self::mask(kind, u, v, phase);
                if family == 1 {
                    // texture family 2: invert interior texture
                    let rr = (u * u + v * v).sqrt();
                    if rr < 0.95 {
                        m = if m > 0.5 { 0.0 } else { 1.0 };
                    }
                }
                let grad = 0.15 * ((x * gc + y * gs) / hw as f32);
                for c in 0..3 {
                    let base = bg[c] + grad;
                    let val = base * (1.0 - m) + fg[c] * m + self.noise * rng.normal();
                    out[(py * hw + px) * 3 + c] = val.clamp(0.0, 1.0);
                }
            }
        }
        label as i32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes32_geometry() {
        let ds = Shapes::cifar_like(1);
        assert_eq!(ds.feature_len(), 32 * 32 * 3);
        assert_eq!(ds.num_classes(), 10);
        let mut buf = vec![0.0f32; ds.feature_len()];
        let y = ds.example(Split::Train, 0, &mut buf);
        assert!((0..10).contains(&y));
        assert!(buf.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn shapes64_has_20_classes() {
        let ds = Shapes::imagenet_like(1);
        assert_eq!(ds.num_classes(), 20);
        let mut seen = vec![false; 20];
        let mut buf = vec![0.0f32; ds.feature_len()];
        for i in 0..400 {
            seen[ds.example(Split::Train, i, &mut buf) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn foreground_differs_from_background() {
        let ds = Shapes::cifar_like(3);
        let mut buf = vec![0.0f32; ds.feature_len()];
        // find a Disk example; its center should be brighter than corners
        for i in 0..300 {
            let y = ds.example(Split::Train, i, &mut buf);
            if y == 0 {
                let hw = 32;
                let mean_px = |px: usize, py: usize| -> f32 {
                    (0..3).map(|c| buf[(py * hw + px) * 3 + c]).sum::<f32>() / 3.0
                };
                // average around the image center region
                let mut center = 0.0;
                let mut n = 0;
                for py in 12..20 {
                    for px in 12..20 {
                        center += mean_px(px, py);
                        n += 1;
                    }
                }
                center /= n as f32;
                let corners = (mean_px(0, 0) + mean_px(31, 0) + mean_px(0, 31)
                    + mean_px(31, 31))
                    / 4.0;
                // fg ∈ [.55,1], bg ∈ [0,.35(+grad)] — the disk covers the
                // center for most draws; allow a miss but not many
                if center > corners + 0.1 {
                    return; // property observed
                }
            }
        }
        panic!("no disk example had bright center vs corners");
    }

    #[test]
    fn deterministic() {
        let ds = Shapes::cifar_like(5);
        let mut a = vec![0.0f32; ds.feature_len()];
        let mut b = vec![0.0f32; ds.feature_len()];
        assert_eq!(
            ds.example(Split::Test, 9, &mut a),
            ds.example(Split::Test, 9, &mut b)
        );
        assert_eq!(a, b);
    }
}
