//! K-class gaussian-mixture classification (the MLP unit-test workload):
//! class means drawn on a scaled hypersphere, isotropic class noise.

use super::{example_rng, Dataset, Split};
use crate::substrate::prng::Pcg32;

pub struct GaussianMixture {
    seed: u64,
    dim: usize,
    k: usize,
    noise: f32,
    means: Vec<Vec<f32>>,
}

impl GaussianMixture {
    pub fn new(seed: u64, dim: usize, k: usize, noise: f32) -> Self {
        let mut rng = Pcg32::new(seed, 0x6a55);
        let means = (0..k)
            .map(|_| {
                let v: Vec<f32> = (0..dim).map(|_| rng.normal()).collect();
                let n = v.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-6);
                v.iter().map(|x| x / n * 2.0).collect()
            })
            .collect();
        GaussianMixture { seed, dim, k, noise, means }
    }
}

impl Dataset for GaussianMixture {
    fn feature_len(&self) -> usize {
        self.dim
    }

    fn input_dims(&self) -> Vec<usize> {
        vec![self.dim]
    }

    fn num_classes(&self) -> usize {
        self.k
    }

    fn example(&self, split: Split, index: u64, out: &mut [f32]) -> i32 {
        debug_assert_eq!(out.len(), self.dim);
        let mut rng = example_rng(self.seed, split, index);
        let label = rng.below(self.k as u32) as usize;
        for (o, m) in out.iter_mut().zip(&self.means[label]) {
            *o = m + self.noise * rng.normal();
        }
        label as i32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn examples_cluster_around_means() {
        let ds = GaussianMixture::new(3, 16, 4, 0.1);
        let mut buf = vec![0.0f32; 16];
        for i in 0..200 {
            let y = ds.example(Split::Train, i, &mut buf) as usize;
            let d2: f32 = buf
                .iter()
                .zip(&ds.means[y])
                .map(|(a, b)| (a - b) * (a - b))
                .sum();
            // noise 0.1 in 16 dims: E d² = 16·0.01 = 0.16, allow slack
            assert!(d2 < 1.0, "example {i} too far from its mean: {d2}");
        }
    }

    #[test]
    fn classes_are_separable() {
        // nearest-mean classification must be near-perfect at low noise
        let ds = GaussianMixture::new(5, 8, 3, 0.15);
        let mut buf = vec![0.0f32; 8];
        let mut correct = 0;
        for i in 0..300 {
            let y = ds.example(Split::Test, i, &mut buf);
            let pred = (0..3)
                .min_by(|&a, &b| {
                    let da: f32 = buf
                        .iter()
                        .zip(&ds.means[a])
                        .map(|(x, m)| (x - m) * (x - m))
                        .sum();
                    let db: f32 = buf
                        .iter()
                        .zip(&ds.means[b])
                        .map(|(x, m)| (x - m) * (x - m))
                        .sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap() as i32;
            correct += (pred == y) as usize;
        }
        assert!(correct > 280, "nearest-mean acc {correct}/300");
    }

    #[test]
    fn deterministic() {
        let ds = GaussianMixture::new(9, 8, 3, 0.2);
        let mut a = vec![0.0f32; 8];
        let mut b = vec![0.0f32; 8];
        let ya = ds.example(Split::Train, 42, &mut a);
        let yb = ds.example(Split::Train, 42, &mut b);
        assert_eq!(a, b);
        assert_eq!(ya, yb);
    }
}
