//! Bundle loader + forward passes for the deployed models.
//!
//! An exported bundle (`coordinator::export::export_bundle`) consists of
//! `<stem>.fxr` (encrypted quantized weights), `<stem>.fp.bin` (FXIN FP
//! residue: stem/head/biases/BN), and `<stem>.bundle.json` (index). This
//! module decrypts the quantized layers through the word-parallel XOR
//! engine, rebuilds the architecture, and runs forward passes on three
//! engines selected **per quantized layer** by a [`ModePolicy`] at load
//! (a uniform policy is the plain [`ComputeMode`] behavior):
//!
//! * **DenseF32** — reconstructs dense weights with `Σ α_i b_i`; logits
//!   match the AOT eval HLO (verified in `rust/tests/e2e_train.rs`).
//! * **BitPlane** — repacks the decryptor output straight into
//!   [`PlaneStore`] bit-plane panels (never materializing FP weights)
//!   and runs the XNOR/popcount engine over binarized activations
//!   (DESIGN.md §8/§9).
//! * **Encrypted** — keeps the layer **encrypted** resident
//!   ([`EncryptedStore`], sub-1-bit/weight — exactly the `.fxr`
//!   payload + XOR-network params) and decrypts NR-channel panels on
//!   demand inside the XNOR GEMM tile loop; forwards are bit-identical
//!   to BitPlane at the same `act_planes` (DESIGN.md §11).
//!
//! A mixed policy (threshold or per-layer overrides) keeps tiny layers —
//! where FP is cheap and approximation error hurts most per weight — on
//! the exact engine while the big convs ride the bit-plane engine;
//! [`InferenceModel::layer_modes`] reports the per-layer decision
//! (`GET /models` serves it).
//!
//! Forward passes run on the packed compute engine (DESIGN.md §7): every
//! GEMM right-hand side — quantized layers, stem, head — is packed once
//! at load into [`gemm::PackedB`] panels, conv/dense layers execute as
//! one fused kernel invocation (`conv → bn → relu`, residual tails
//! included) sharded across the substrate thread pool, and activations
//! cycle through the per-thread scratch arena instead of being
//! reallocated per request. [`InferenceModel::forward_reference`] keeps
//! the original separate-pass scalar composition as the equivalence
//! oracle for property tests and the baseline for `benches/inference.rs`.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::flexor::binarycodes::reconstruct_dense;
use crate::flexor::fxr::Container;
use crate::flexor::Decryptor;
use crate::runtime::initbin;
use crate::substrate::json::{self, Json};
use crate::substrate::pool::{self, ThreadPool};
use crate::substrate::trace;

use super::bitslice::{self, ComputeMode, EncryptedStore, ModePolicy, PlaneStore};
use super::gemm::{self, conv2d_fused, dense_fused, Epilogue, PackedB};
use super::tensor::{self, Tensor};

const BN_EPS: f32 = 1e-5;

/// (blocks per stage, stage widths) for every resnet variant this engine
/// rebuilds — mirrors `python/compile/models/resnet.py`. Public so bundle
/// generators (synthetic fixtures) can walk the same geometry.
pub fn resnet_geometry(model: &str) -> Result<(Vec<usize>, Vec<usize>)> {
    Ok(match model {
        "resnet8" => (vec![1, 1, 1], vec![8, 16, 32]),
        "resnet14" => (vec![2, 2, 2], vec![16, 32, 64]),
        "resnet20" => (vec![3, 3, 3], vec![16, 32, 64]),
        "resnet32" => (vec![5, 5, 5], vec![16, 32, 64]),
        "resnet10img" => (vec![1, 1, 1, 1], vec![16, 32, 64, 128]),
        "resnet18img" => (vec![2, 2, 2, 2], vec![64, 128, 256, 512]),
        other => bail!("unknown resnet variant {other}"),
    })
}

/// FP leaf store addressed by jax keystr path.
struct FpStore {
    by_path: BTreeMap<String, (Vec<usize>, Vec<f32>)>,
}

impl FpStore {
    fn load(bin: &[u8], index: &Json) -> Result<Self> {
        let leaves = initbin::read_init_bin(bin)?;
        let idx = index.as_arr().context("fp_index not an array")?;
        ensure!(idx.len() == leaves.len(), "fp index/leaf count mismatch");
        let mut by_path = BTreeMap::new();
        for (e, leaf) in idx.iter().zip(leaves) {
            let path = e.get("path").as_str().context("fp index path")?;
            by_path.insert(path.to_string(), (leaf.shape.clone(), leaf.as_f32()?));
        }
        Ok(FpStore { by_path })
    }

    fn get(&self, path: &str) -> Result<&(Vec<usize>, Vec<f32>)> {
        self.by_path
            .get(path)
            .with_context(|| format!("missing FP leaf {path}"))
    }

    fn vec(&self, path: &str) -> Result<Vec<f32>> {
        Ok(self.get(path)?.1.clone())
    }

    fn tensor(&self, path: &str) -> Result<Tensor> {
        let (shape, data) = self.get(path)?;
        Ok(Tensor::new(shape.clone(), data.clone()))
    }

    fn has(&self, path: &str) -> bool {
        self.by_path.contains_key(path)
    }
}

/// BN parameter pack for one normalization site: raw leaves for the
/// reference path plus the eval-mode `a·x + b` fold the fused epilogue
/// consumes (precomputed once at load, not per forward).
struct Bn {
    scale: Vec<f32>,
    bias: Vec<f32>,
    mean: Vec<f32>,
    var: Vec<f32>,
    a: Vec<f32>,
    b: Vec<f32>,
}

impl Bn {
    fn new(scale: Vec<f32>, bias: Vec<f32>, mean: Vec<f32>, var: Vec<f32>) -> Bn {
        let (a, b) = tensor::bn_fold(&scale, &bias, &mean, &var, BN_EPS);
        Bn { scale, bias, mean, var, a, b }
    }

    fn apply(&self, x: &mut Tensor) {
        tensor::batch_norm_eval(x, &self.scale, &self.bias, &self.mean,
                                &self.var, BN_EPS);
    }

    /// The fused epilogue for this site.
    fn affine(&self, relu: bool) -> Epilogue<'_> {
        Epilogue::Affine { a: &self.a, b: &self.b, relu }
    }
}

/// Load-time materialization for the packed engine: every GEMM-side
/// weight packed once, every FP leaf the forward needs cached — the
/// per-request `FpStore` clones are gone.
#[derive(Default)]
struct Engine {
    qpacked: BTreeMap<usize, PackedB>,
    stem: Option<Tensor>,
    stem_packed: Option<PackedB>,
    head_w: Option<Tensor>,
    head_packed: Option<PackedB>,
    head_b: Option<Vec<f32>>,
    /// LeNet conv/dense biases by site index (`['bias'][i]`).
    biases: Vec<Vec<f32>>,
}

/// One quantized layer's engine assignment under the load policy —
/// what `GET /models` reports per entry.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LayerMode {
    /// Quantized-layer index (the bundle's `q<idx>` naming).
    pub idx: usize,
    /// Engine this layer runs on.
    pub mode: ComputeMode,
    /// Weights in the layer (what the policy threshold compares).
    pub weights: usize,
}

/// A fully materialized inference model.
pub struct InferenceModel {
    pub model: String,
    pub num_classes: usize,
    pub input_dims: Vec<usize>,
    /// The per-layer compute policy this model was loaded under.
    policy: ModePolicy,
    /// The engine each quantized layer actually runs on (resolved from
    /// `policy` at load).
    qmodes: BTreeMap<usize, ComputeMode>,
    /// Declared shapes of quantized layers, by layer index (always
    /// populated; the geometry source for both engines).
    qshapes: BTreeMap<usize, Vec<usize>>,
    /// Dense weights of quantized layers, reconstructed from the
    /// encrypted container (decrypt + Σ α_i b_i). DenseF32 layers only.
    qweights: BTreeMap<usize, Tensor>,
    /// Packed bit-plane stores of quantized layers. BitPlane layers only
    /// — their dense FP weights are never materialized.
    qplanes: BTreeMap<usize, PlaneStore>,
    /// Encrypted stores of quantized layers. Encrypted layers only —
    /// nothing decrypted is ever resident; panels are decrypted on
    /// demand inside the GEMM tile loop.
    qencrypted: BTreeMap<usize, EncryptedStore>,
    bns: Vec<Bn>,
    engine: Engine,
    /// Paper-format storage stats, carried for reporting.
    pub bits_per_weight: f64,
    pub compression_ratio: f64,
}

impl InferenceModel {
    /// Load `<stem>.fxr` + `<stem>.fp.bin` + `<stem>.bundle.json` on the
    /// default (DenseF32) engine.
    pub fn load(dir: &Path, stem: &str) -> Result<Self> {
        Self::load_with_mode(dir, stem, ComputeMode::DenseF32)
    }

    /// Load a bundle with every quantized layer on `mode` (a uniform
    /// [`ModePolicy`]). DenseF32 decrypts to dense `Σ α_i b_i` weights
    /// and packs panels; BitPlane repacks the decryptor's output
    /// straight into panelized bit-plane rows ([`PlaneStore`]) — those
    /// layers never exist as dense FP; Encrypted keeps the container's
    /// payload as-is ([`EncryptedStore`]) — those layers are never even
    /// decrypted at load.
    pub fn load_with_mode(dir: &Path, stem: &str, mode: ComputeMode) -> Result<Self> {
        Self::load_with_policy(dir, stem, ModePolicy::uniform(mode))
    }

    /// Load a bundle under a per-layer compute policy: each quantized
    /// layer is materialized for exactly the engine
    /// [`ModePolicy::mode_for`] assigns it (dense tensors + packed
    /// panels, or bit-plane panels — never both).
    pub fn load_with_policy(dir: &Path, stem: &str, policy: ModePolicy) -> Result<Self> {
        let bundle_text =
            std::fs::read_to_string(dir.join(format!("{stem}.bundle.json")))?;
        let bundle = json::parse(&bundle_text)?;
        let fxr = Container::load(&dir.join(format!("{stem}.fxr")))?;
        let fp_bytes = std::fs::read(dir.join(format!("{stem}.fp.bin")))?;
        let fp = FpStore::load(&fp_bytes, bundle.get("fp_index"))?;

        // shapes of quantized layers
        let mut shapes: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
        for e in bundle.get("quantized_layers").as_arr().unwrap_or(&[]) {
            let idx = e.get("idx").as_usize().context("layer idx")?;
            let shape = e
                .get("shape")
                .as_arr()
                .context("layer shape")?
                .iter()
                .map(|d| d.as_usize().context("dim"))
                .collect::<Result<Vec<_>>>()?;
            shapes.insert(idx, shape);
        }

        // a policy override naming a layer this bundle doesn't have is
        // an operator typo — fail loudly instead of silently ignoring it
        for idx in policy.overrides.keys() {
            ensure!(
                shapes.contains_key(idx),
                "compute-mode override for layer {idx}, but bundle has no quantized \
                 layer {idx} (layers: {:?})",
                shapes.keys().collect::<Vec<_>>()
            );
        }

        // materialize every quantized layer per its policy-assigned
        // engine: dense Σ α_i b_i tensors (DenseF32), packed bit-plane
        // stores (BitPlane — no FP weights, ever), or the raw encrypted
        // payload (Encrypted — nothing decrypted, ever)
        let mut qweights = BTreeMap::new();
        let mut qplanes = BTreeMap::new();
        let mut qencrypted = BTreeMap::new();
        let mut qmodes = BTreeMap::new();
        for layer in &fxr.layers {
            let idx: usize = layer
                .name
                .strip_prefix('q')
                .and_then(|s| s.parse().ok())
                .with_context(|| format!("bad layer name {}", layer.name))?;
            let shape = shapes
                .get(&idx)
                .with_context(|| format!("no shape for layer {idx}"))?;
            ensure!(shape.iter().product::<usize>() == layer.n_weights,
                    "layer {idx}: shape {:?} != n_weights {}", shape, layer.n_weights);
            ensure!(*shape.last().unwrap() == layer.c_out,
                    "layer {idx}: shape {:?} last axis != c_out {}",
                    shape, layer.c_out);
            let lmode = policy.mode_for(idx, layer.n_weights);
            qmodes.insert(idx, lmode);
            match lmode {
                ComputeMode::DenseF32 => {
                    let mut planes = Vec::with_capacity(layer.q());
                    let mut alphas = Vec::with_capacity(layer.q());
                    for p in &layer.planes {
                        let d = Decryptor::new(p.mxor.clone());
                        planes.push(d.decrypt_to_signs(&p.enc, layer.n_weights)?);
                        alphas.push(p.alpha.clone());
                    }
                    let dense = reconstruct_dense(&planes, &alphas, layer.c_out)?;
                    qweights.insert(idx, Tensor::new(shape.clone(), dense));
                }
                ComputeMode::BitPlane { .. } => {
                    let mut planes = Vec::with_capacity(layer.q());
                    for p in &layer.planes {
                        let d = Decryptor::new(p.mxor.clone());
                        let rows = d.decrypt_to_plane_rows(
                            &p.enc,
                            layer.n_weights,
                            layer.c_out,
                        )?;
                        planes.push((rows, p.alpha.clone()));
                    }
                    qplanes.insert(idx, PlaneStore::from_decrypted(shape, planes)?);
                }
                ComputeMode::Encrypted { .. } => {
                    qencrypted.insert(idx, EncryptedStore::from_layer(shape, layer)?);
                }
            }
        }

        // BN packs, in conv-site order (paths ['bn'][i][...])
        let mut bns = Vec::new();
        for i in 0.. {
            let p = |f: &str| format!("['bn'][{i}]['{f}']");
            if !fp.has(&p("scale")) {
                break;
            }
            bns.push(Bn::new(
                fp.vec(&p("scale"))?,
                fp.vec(&p("bias"))?,
                fp.vec(&p("mean"))?,
                fp.vec(&p("var"))?,
            ));
        }

        // pack every GEMM right-hand side once; cache the FP leaves the
        // forwards consume. Quantized panels only exist for DenseF32
        // layers (BitPlane layers keep their PlaneStores instead).
        let mut engine = Engine::default();
        for (idx, w) in &qweights {
            engine.qpacked.insert(*idx, PackedB::from_tensor(w));
        }
        if fp.has("['stem']['w']") {
            let t = fp.tensor("['stem']['w']")?;
            engine.stem_packed = Some(PackedB::from_tensor(&t));
            engine.stem = Some(t);
        }
        if fp.has("['head']['w']") {
            let t = fp.tensor("['head']['w']")?;
            engine.head_packed = Some(PackedB::from_tensor(&t));
            engine.head_w = Some(t);
        }
        if fp.has("['head']['b']") {
            engine.head_b = Some(fp.vec("['head']['b']")?);
        }
        for i in 0.. {
            let p = format!("['bias'][{i}]");
            if !fp.has(&p) {
                break;
            }
            engine.biases.push(fp.vec(&p)?);
        }

        let stats = fxr.stats();
        Ok(InferenceModel {
            model: bundle.get("model").as_str().context("model")?.to_string(),
            num_classes: bundle.get("num_classes").as_usize().unwrap_or(10),
            input_dims: bundle
                .get("input_shape")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|d| d.as_usize())
                .collect(),
            policy,
            qmodes,
            qshapes: shapes,
            qweights,
            qplanes,
            qencrypted,
            bns,
            engine,
            bits_per_weight: stats.bits_per_weight,
            compression_ratio: stats.compression_ratio_with_alpha,
        })
    }

    /// The policy's base engine (the whole-model mode for uniform
    /// loads). Per-layer decisions are in [`InferenceModel::layer_modes`].
    pub fn compute_mode(&self) -> ComputeMode {
        self.policy.base
    }

    /// The policy this model was loaded under.
    pub fn mode_policy(&self) -> &ModePolicy {
        &self.policy
    }

    /// The engine quantized layer `idx` runs on.
    fn layer_mode(&self, idx: usize) -> ComputeMode {
        self.qmodes.get(&idx).copied().unwrap_or(self.policy.base)
    }

    /// Summary label for `/models` and log lines: `"dense"` /
    /// `"bitplane"` / `"encrypted"` when every quantized layer agrees,
    /// `"mixed"` otherwise.
    pub fn mode_label(&self) -> &'static str {
        if self.is_mixed() {
            "mixed"
        } else if let Some(m) = self.qmodes.values().next() {
            m.label()
        } else {
            self.policy.base.label() // no quantized layers
        }
    }

    /// Do this model's quantized layers run on more than one engine?
    pub fn is_mixed(&self) -> bool {
        let mut labels = self.qmodes.values().map(ComputeMode::label);
        match labels.next() {
            Some(first) => labels.any(|l| l != first),
            None => false,
        }
    }

    /// Per-quantized-layer engine assignments, in layer order.
    pub fn layer_modes(&self) -> Vec<LayerMode> {
        self.qshapes
            .iter()
            .map(|(&idx, shape)| LayerMode {
                idx,
                mode: self.layer_mode(idx),
                weights: shape.iter().product(),
            })
            .collect()
    }

    /// Bytes the quantized layers keep resident under this model's
    /// per-layer modes: dense tensors + packed panels (DenseF32 layers),
    /// panelized bit-plane rows + α (BitPlane layers), plus encrypted
    /// column words **and the XOR-gate network / scale parameters
    /// themselves** (Encrypted layers — nothing decrypted is resident).
    /// The `/models` accounting.
    pub fn quantized_resident_bytes(&self) -> usize {
        let dense: usize = self
            .qweights
            .values()
            .map(|t| t.data.len() * std::mem::size_of::<f32>())
            .sum();
        let packed: usize =
            self.engine.qpacked.values().map(PackedB::resident_bytes).sum();
        let planes: usize = self.qplanes.values().map(PlaneStore::resident_bytes).sum();
        let enc: usize =
            self.qencrypted.values().map(EncryptedStore::resident_bytes).sum();
        dense + packed + planes + enc
    }

    /// Total weights across quantized layers (the denominator of
    /// [`InferenceModel::resident_bits_per_weight`]).
    pub fn quantized_weight_count(&self) -> usize {
        self.qshapes.values().map(|s| s.iter().product::<usize>()).sum()
    }

    /// Resident bits per quantized weight under the active per-layer
    /// modes — the serving-time analogue of the container's
    /// `bits_per_weight`. Sub-1.0 on the Encrypted engine (the paper's
    /// fractional rate plus XOR-network/α overhead); ≥ q on BitPlane;
    /// ≥ 32 on DenseF32. 0.0 when the bundle has no quantized layers.
    pub fn resident_bits_per_weight(&self) -> f64 {
        let weights = self.quantized_weight_count();
        if weights == 0 {
            return 0.0;
        }
        (self.quantized_resident_bytes() * 8) as f64 / weights as f64
    }

    /// Bytes of the FP residue (stem/head/biases/BN packs) — identical
    /// across compute modes.
    pub fn fp_resident_bytes(&self) -> usize {
        let f = std::mem::size_of::<f32>();
        let t = |o: &Option<Tensor>| o.as_ref().map_or(0, |t| t.data.len() * f);
        let p = |o: &Option<PackedB>| o.as_ref().map_or(0, PackedB::resident_bytes);
        let mut bytes = t(&self.engine.stem) + p(&self.engine.stem_packed);
        bytes += t(&self.engine.head_w) + p(&self.engine.head_packed);
        bytes += self.engine.head_b.as_ref().map_or(0, |b| b.len() * f);
        bytes += self.engine.biases.iter().map(|b| b.len() * f).sum::<usize>();
        // each BN site caches 6 per-channel vectors (raw + a·x+b fold)
        bytes += self.bns.iter().map(|b| 6 * b.scale.len() * f).sum::<usize>();
        bytes
    }

    /// Total resident weight bytes (quantized + FP residue).
    pub fn resident_bytes(&self) -> usize {
        self.quantized_resident_bytes() + self.fp_resident_bytes()
    }

    fn qweight(&self, idx: usize) -> Result<&Tensor> {
        self.qweights
            .get(&idx)
            .with_context(|| format!("missing quantized layer {idx}"))
    }

    fn qplane(&self, idx: usize) -> Result<&PlaneStore> {
        self.qplanes
            .get(&idx)
            .with_context(|| format!("missing bit-plane layer {idx}"))
    }

    fn qenc(&self, idx: usize) -> Result<&EncryptedStore> {
        self.qencrypted
            .get(&idx)
            .with_context(|| format!("missing encrypted layer {idx}"))
    }

    /// Packed panels + (kh, kw, ci) conv geometry of quantized layer `idx`.
    fn qpacked(&self, idx: usize) -> Result<(&PackedB, (usize, usize, usize))> {
        let p = self
            .engine
            .qpacked
            .get(&idx)
            .with_context(|| format!("missing packed layer {idx}"))?;
        let dims = self
            .qshapes
            .get(&idx)
            .with_context(|| format!("missing shape for layer {idx}"))?;
        let geom = if dims.len() == 4 { (dims[0], dims[1], dims[2]) } else { (0, 0, 0) };
        Ok((p, geom))
    }

    /// Is quantized layer `idx` present? (Engine-agnostic existence test.)
    fn has_qlayer(&self, idx: usize) -> bool {
        self.qshapes.contains_key(&idx)
    }

    /// Trace label for quantized layer `idx`: `q<idx>:<mode>`, with the
    /// active-plane count and popcount kernel appended on the binarized
    /// engines (`q3:bitplane1@avx2`, `q3:encrypted1@avx2`). Only built
    /// inside a traced scope.
    fn layer_label(&self, idx: usize) -> String {
        match self.layer_mode(idx) {
            ComputeMode::DenseF32 => format!("q{idx}:dense"),
            ComputeMode::BitPlane { act_planes } => format!(
                "q{idx}:bitplane{act_planes}@{}",
                bitslice::popcount::active().label()
            ),
            ComputeMode::Encrypted { act_planes } => format!(
                "q{idx}:encrypted{act_planes}@{}",
                bitslice::popcount::active().label()
            ),
        }
    }

    /// Quantized conv → epilogue on the layer's assigned engine.
    fn qconv(
        &self,
        pool: &ThreadPool,
        x: &Tensor,
        idx: usize,
        stride: usize,
        epi: Epilogue<'_>,
    ) -> Result<Tensor> {
        let _l = trace::layer_span(|| self.layer_label(idx));
        match self.layer_mode(idx) {
            ComputeMode::DenseF32 => {
                let (w, g) = self.qpacked(idx)?;
                Ok(conv2d_fused(pool, x, w, g, stride, epi))
            }
            ComputeMode::BitPlane { act_planes } => Ok(bitslice::conv2d_bitplane(
                pool,
                x,
                self.qplane(idx)?,
                stride,
                act_planes,
                epi,
            )),
            ComputeMode::Encrypted { act_planes } => Ok(bitslice::conv2d_encrypted(
                pool,
                x,
                self.qenc(idx)?,
                stride,
                act_planes,
                epi,
            )),
        }
    }

    /// Quantized dense → epilogue on the layer's assigned engine.
    fn qdense(
        &self,
        pool: &ThreadPool,
        x: &Tensor,
        idx: usize,
        epi: Epilogue<'_>,
    ) -> Result<Tensor> {
        let _l = trace::layer_span(|| self.layer_label(idx));
        match self.layer_mode(idx) {
            ComputeMode::DenseF32 => {
                let (w, _) = self.qpacked(idx)?;
                Ok(dense_fused(pool, x, w, epi))
            }
            ComputeMode::BitPlane { act_planes } => Ok(bitslice::dense_bitplane(
                pool,
                x,
                self.qplane(idx)?,
                act_planes,
                epi,
            )),
            ComputeMode::Encrypted { act_planes } => Ok(bitslice::dense_encrypted(
                pool,
                x,
                self.qenc(idx)?,
                act_planes,
                epi,
            )),
        }
    }

    /// Reference quantized conv (separate-pass oracle): dense math for
    /// DenseF32 layers; for BitPlane layers the same binarization
    /// contract as the engine but dense math over reconstructed
    /// rows/weights.
    fn ref_qconv(&self, x: &Tensor, idx: usize, stride: usize) -> Result<Tensor> {
        match self.layer_mode(idx) {
            ComputeMode::DenseF32 => Ok(tensor::conv2d(x, self.qweight(idx)?, stride)),
            ComputeMode::BitPlane { act_planes } => Ok(
                bitslice::gemm::conv2d_bitplane_reference(
                    x,
                    self.qplane(idx)?,
                    stride,
                    act_planes,
                ),
            ),
            ComputeMode::Encrypted { act_planes } => Ok(
                bitslice::encrypted::conv2d_encrypted_reference(
                    x,
                    self.qenc(idx)?,
                    stride,
                    act_planes,
                ),
            ),
        }
    }

    /// Reference quantized dense (no bias — callers compose it).
    fn ref_qdense(&self, x: &Tensor, idx: usize) -> Result<Tensor> {
        match self.layer_mode(idx) {
            ComputeMode::DenseF32 => Ok(tensor::dense(x, self.qweight(idx)?, None)),
            ComputeMode::BitPlane { act_planes } => Ok(
                bitslice::gemm::dense_bitplane_reference(
                    x,
                    self.qplane(idx)?,
                    act_planes,
                ),
            ),
            ComputeMode::Encrypted { act_planes } => Ok(
                bitslice::encrypted::dense_encrypted_reference(
                    x,
                    self.qenc(idx)?,
                    act_planes,
                ),
            ),
        }
    }

    fn bn(&self, idx: usize) -> Result<&Bn> {
        self.bns.get(idx).context("ran out of BN packs")
    }

    fn lenet_bias(&self, i: usize) -> Result<&[f32]> {
        self.engine
            .biases
            .get(i)
            .map(Vec::as_slice)
            .with_context(|| format!("missing bias {i}"))
    }

    /// Batched forward on the active compute engine: x flat NHWC (or NC
    /// for mlp), returns (N, classes) logits in a scratch-arena buffer
    /// (callers may `gemm::scratch::give` it back, as `predict` does).
    pub fn forward(&self, x: &[f32], n: usize) -> Result<Vec<f32>> {
        self.forward_with_pool(x, n, pool::global())
    }

    /// [`forward`](Self::forward) on an explicit thread pool — lets tests
    /// pin exact thread counts (both engines are bit-identical across
    /// pool sizes).
    pub fn forward_with_pool(&self, x: &[f32], n: usize, pool: &ThreadPool) -> Result<Vec<f32>> {
        // End-to-end span: the per-layer spans below must sum to (nearly)
        // this — the profile endpoint's coverage contract (DESIGN.md §10).
        let _f = trace::span("forward");
        match self.model.as_str() {
            m if m.starts_with("resnet") => self.forward_resnet(x, n, pool),
            "lenet5" => self.forward_lenet(x, n, pool),
            "mlp" => self.forward_mlp(x, n, pool),
            other => bail!("unknown model {other}"),
        }
    }

    /// The separate-pass composition (scalar blocked GEMM, one
    /// full-tensor pass per op). Semantically ≡ [`forward`] under the
    /// same compute mode — in BitPlane mode the quantized layers apply
    /// the identical activation-binarization contract before dense
    /// math — so it is the property-test oracle and the
    /// `benches/inference.rs` baseline for both engines.
    pub fn forward_reference(&self, x: &[f32], n: usize) -> Result<Vec<f32>> {
        match self.model.as_str() {
            m if m.starts_with("resnet") => self.forward_resnet_ref(x, n),
            "lenet5" => self.forward_lenet_ref(x, n),
            "mlp" => self.forward_mlp_ref(x, n),
            other => bail!("unknown model {other}"),
        }
    }

    /// argmax over forward logits. NaN-tolerant: NaN logits are skipped
    /// (never selected), an all-NaN row deterministically maps to class 0
    /// instead of panicking the serving worker.
    pub fn predict(&self, x: &[f32], n: usize) -> Result<Vec<i32>> {
        let logits = self.forward(x, n)?;
        let c = self.num_classes;
        let out = (0..n)
            .map(|i| argmax_row(&logits[i * c..(i + 1) * c]) as i32)
            .collect();
        gemm::scratch::give(logits);
        Ok(out)
    }

    // ---- packed-engine architectures ----------------------------------------

    fn input_hwc(&self) -> Result<(usize, usize, usize)> {
        ensure!(self.input_dims.len() == 3, "expected HWC input dims");
        Ok((self.input_dims[0], self.input_dims[1], self.input_dims[2]))
    }

    fn take_input(&self, x: &[f32], dims: Vec<usize>) -> Result<Tensor> {
        ensure!(x.len() == dims.iter().product::<usize>(), "input length mismatch");
        let mut data = gemm::scratch::take(x.len());
        data.copy_from_slice(x);
        Ok(Tensor::new(dims, data))
    }

    fn head_fused(&self, pooled: Tensor, pool: &ThreadPool) -> Result<Vec<f32>> {
        let _l = trace::layer_span(|| "head".to_string());
        let head = self.engine.head_packed.as_ref().context("missing FP head")?;
        let head_b = self.engine.head_b.as_ref().context("missing head bias")?;
        let logits =
            dense_fused(pool, &pooled, head, Epilogue::Bias { bias: head_b, relu: false });
        gemm::scratch::give(pooled.data);
        Ok(logits.data)
    }

    fn forward_resnet(&self, x: &[f32], n: usize, pool: &ThreadPool) -> Result<Vec<f32>> {
        let (blocks, widths) = resnet_geometry(&self.model)?;
        let (h, w, ci) = self.input_hwc()?;
        let xin = self.take_input(x, vec![n, h, w, ci])?;

        // stem (FP): conv → bn → relu, one invocation
        let stem = self.engine.stem_packed.as_ref().context("missing FP stem")?;
        let sd = &self.engine.stem.as_ref().unwrap().dims;
        let mut bn_i = 0usize;
        let mut q_i = 0usize;
        let mut cur = {
            let _l = trace::layer_span(|| "stem".to_string());
            conv2d_fused(pool, &xin, stem, (sd[0], sd[1], sd[2]), 1,
                         self.bn(bn_i)?.affine(true))
        };
        bn_i += 1;
        gemm::scratch::give(xin.data);

        let mut c_in = widths[0];
        for (si, (&nb, &wd)) in blocks.iter().zip(&widths).enumerate() {
            for bi in 0..nb {
                let stride = if si > 0 && bi == 0 { 2 } else { 1 };
                let downsample = stride != 1 || c_in != wd;

                let bn1 = self.bn(bn_i)?;
                let bn2 = self.bn(bn_i + 1)?;
                let (q1, q2) = (q_i, q_i + 1);
                q_i += 2;
                bn_i += 2;

                // conv1 → bn → relu fused
                let out1 = self.qconv(pool, &cur, q1, stride, bn1.affine(true))?;

                // shortcut first, so conv2's epilogue can fuse the
                // residual add (+ final relu) into its output tile
                let short = if downsample {
                    let bns = self.bn(bn_i)?;
                    let qs = q_i;
                    q_i += 1;
                    bn_i += 1;
                    Some(self.qconv(pool, &cur, qs, stride, bns.affine(false))?)
                } else {
                    None
                };
                let residual = short.as_ref().map_or(&cur.data[..], |s| &s.data[..]);
                let out = self.qconv(
                    pool,
                    &out1,
                    q2,
                    1,
                    Epilogue::AffineAdd { a: &bn2.a, b: &bn2.b, residual, relu: true },
                )?;

                gemm::scratch::give(out1.data);
                if let Some(s) = short {
                    gemm::scratch::give(s.data);
                }
                gemm::scratch::give(std::mem::replace(&mut cur, out).data);
                c_in = wd;
            }
        }
        let pooled = {
            let _l = trace::layer_span(|| "pool".to_string());
            tensor::avg_pool_global(&cur)
        };
        gemm::scratch::give(cur.data);
        self.head_fused(pooled, pool)
    }

    fn forward_lenet(&self, x: &[f32], n: usize, pool: &ThreadPool) -> Result<Vec<f32>> {
        let (h, w, ci) = self.input_hwc()?;
        let mut t = self.take_input(x, vec![n, h, w, ci])?;

        for i in 0..2 {
            let conv = self.qconv(pool, &t, i, 1,
                                  Epilogue::Bias { bias: self.lenet_bias(i)?, relu: true })?;
            gemm::scratch::give(std::mem::replace(&mut t, conv).data);
            let pooled = {
                let _l = trace::layer_span(|| "pool".to_string());
                tensor::max_pool2(&t)
            };
            gemm::scratch::give(std::mem::replace(&mut t, pooled).data);
        }

        let flat_len: usize = t.dims[1] * t.dims[2] * t.dims[3];
        let flat = Tensor::new(vec![n, flat_len], t.data);

        let fc = self.qdense(pool, &flat, 2,
                             Epilogue::Bias { bias: self.lenet_bias(2)?, relu: true })?;
        gemm::scratch::give(flat.data);
        let out = self.qdense(pool, &fc, 3,
                              Epilogue::Bias { bias: self.lenet_bias(3)?, relu: false })?;
        gemm::scratch::give(fc.data);
        Ok(out.data)
    }

    fn forward_mlp(&self, x: &[f32], n: usize, pool: &ThreadPool) -> Result<Vec<f32>> {
        let d_in = x.len() / n;
        let mut t = self.take_input(x, vec![n, d_in])?;
        for i in 0.. {
            if !self.has_qlayer(i) {
                break;
            }
            let bn = self.bns.get(i).context("missing BN pack for mlp layer")?;
            let next = self.qdense(pool, &t, i, bn.affine(true))?;
            gemm::scratch::give(std::mem::replace(&mut t, next).data);
        }
        self.head_fused(t, pool)
    }

    // ---- reference architectures (separate passes, scalar GEMM) -------------

    fn forward_resnet_ref(&self, x: &[f32], n: usize) -> Result<Vec<f32>> {
        let (blocks, widths) = resnet_geometry(&self.model)?;
        let (h, w, ci) = self.input_hwc()?;
        ensure!(x.len() == n * h * w * ci, "input length mismatch");

        let mut bn_i = 0usize;
        let mut q_i = 0usize;
        let mut bn = |t: &mut Tensor, bns: &[Bn]| -> Result<()> {
            ensure!(bn_i < bns.len(), "ran out of BN packs");
            bns[bn_i].apply(t);
            bn_i += 1;
            Ok(())
        };

        // stem (FP)
        let stem = self.engine.stem.as_ref().context("missing FP stem")?;
        let mut hmap = tensor::conv2d(
            &Tensor::new(vec![n, h, w, ci], x.to_vec()),
            stem,
            1,
        );
        bn(&mut hmap, &self.bns)?;
        tensor::relu(&mut hmap);

        let mut c_in = widths[0];
        for (si, (&nb, &wd)) in blocks.iter().zip(&widths).enumerate() {
            for bi in 0..nb {
                let stride = if si > 0 && bi == 0 { 2 } else { 1 };
                let identity = hmap.clone();
                let mut out = self.ref_qconv(&hmap, q_i, stride)?;
                q_i += 1;
                bn(&mut out, &self.bns)?;
                tensor::relu(&mut out);
                let mut out = self.ref_qconv(&out, q_i, 1)?;
                q_i += 1;
                bn(&mut out, &self.bns)?;
                let short = if stride != 1 || c_in != wd {
                    let mut s = self.ref_qconv(&identity, q_i, stride)?;
                    q_i += 1;
                    bn(&mut s, &self.bns)?;
                    s
                } else {
                    identity
                };
                tensor::add_inplace(&mut out, &short);
                tensor::relu(&mut out);
                hmap = out;
                c_in = wd;
            }
        }
        let pooled = tensor::avg_pool_global(&hmap);
        let head_w = self.engine.head_w.as_ref().context("missing FP head")?;
        let head_b = self.engine.head_b.as_ref().context("missing head bias")?;
        Ok(tensor::dense(&pooled, head_w, Some(head_b)).data)
    }

    fn forward_lenet_ref(&self, x: &[f32], n: usize) -> Result<Vec<f32>> {
        let (h, w, ci) = self.input_hwc()?;
        let mut t = Tensor::new(vec![n, h, w, ci], x.to_vec());

        for i in 0..2 {
            let mut conv = self.ref_qconv(&t, i, 1)?;
            add_bias_nhwc(&mut conv, self.lenet_bias(i)?);
            tensor::relu(&mut conv);
            t = tensor::max_pool2(&conv);
        }

        let flat_len: usize = t.dims[1] * t.dims[2] * t.dims[3];
        let flat = Tensor::new(vec![n, flat_len], t.data);

        let mut fc = self.ref_qdense(&flat, 2)?;
        add_bias_nhwc(&mut fc, self.lenet_bias(2)?);
        tensor::relu(&mut fc);
        let mut out = self.ref_qdense(&fc, 3)?;
        add_bias_nhwc(&mut out, self.lenet_bias(3)?);
        Ok(out.data)
    }

    fn forward_mlp_ref(&self, x: &[f32], n: usize) -> Result<Vec<f32>> {
        let d_in = x.len() / n;
        let mut t = Tensor::new(vec![n, d_in], x.to_vec());
        for i in 0.. {
            if !self.has_qlayer(i) {
                break;
            }
            t = self.ref_qdense(&t, i)?;
            self.bns
                .get(i)
                .context("missing BN pack for mlp layer")?
                .apply(&mut t);
            tensor::relu(&mut t);
        }
        let head_w = self.engine.head_w.as_ref().context("missing FP head")?;
        let head_b = self.engine.head_b.as_ref().context("missing head bias")?;
        Ok(tensor::dense(&t, head_w, Some(head_b)).data)
    }
}

/// NaN-tolerant argmax: strict `>` skips NaNs, all-NaN rows map to 0.
fn argmax_row(row: &[f32]) -> usize {
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in row.iter().enumerate() {
        if v > bv {
            bv = v;
            best = i;
        }
    }
    best
}

fn add_bias_nhwc(t: &mut Tensor, bias: &[f32]) {
    let c = *t.dims.last().unwrap();
    assert_eq!(bias.len(), c);
    for (i, v) in t.data.iter_mut().enumerate() {
        *v += bias[i % c];
    }
}

#[cfg(test)]
mod tests {
    //! Full-bundle tests live in rust/tests/e2e_train.rs (they need
    //! artifacts + a trained session) and rust/tests/cross_layer.rs; the
    //! packed-engine ≡ reference equivalence over whole synthetic bundles
    //! lives in rust/tests/serve.rs. Here: geometry table + argmax edge
    //! cases.
    use super::*;

    fn dummy(model: &str) -> InferenceModel {
        InferenceModel {
            model: model.into(),
            num_classes: 10,
            input_dims: vec![32, 32, 3],
            policy: ModePolicy::uniform(ComputeMode::DenseF32),
            qmodes: BTreeMap::new(),
            qshapes: BTreeMap::new(),
            qweights: BTreeMap::new(),
            qplanes: BTreeMap::new(),
            qencrypted: BTreeMap::new(),
            bns: vec![],
            engine: Engine::default(),
            bits_per_weight: 0.8,
            compression_ratio: 35.0,
        }
    }

    #[test]
    fn resnet_geometry_table() {
        assert_eq!(resnet_geometry("resnet20").unwrap().0, vec![3, 3, 3]);
        assert_eq!(resnet_geometry("resnet10img").unwrap().1,
                   vec![16, 32, 64, 128]);
        assert!(resnet_geometry("resnet99").is_err());
    }

    #[test]
    fn mode_label_with_no_quantized_layers_follows_policy_base() {
        assert_eq!(dummy("mlp").mode_label(), "dense");
        assert!(dummy("mlp").layer_modes().is_empty());
    }

    #[test]
    fn unknown_model_rejected() {
        assert!(dummy("vgg").forward(&[0.0; 10], 1).is_err());
        assert!(dummy("vgg").forward_reference(&[0.0; 10], 1).is_err());
    }

    #[test]
    fn argmax_is_nan_tolerant_and_deterministic() {
        assert_eq!(argmax_row(&[0.1, 0.9, 0.3]), 1);
        assert_eq!(argmax_row(&[f32::NAN, 0.2, 0.5]), 2);
        assert_eq!(argmax_row(&[0.5, f32::NAN, 0.2]), 0);
        assert_eq!(argmax_row(&[f32::NAN, f32::NAN]), 0);
        assert_eq!(argmax_row(&[f32::NEG_INFINITY, f32::NEG_INFINITY]), 0);
        assert_eq!(argmax_row(&[-1.0, f32::INFINITY, f32::NAN]), 1);
    }
}
