//! Bundle loader + forward passes for the deployed models.
//!
//! An exported bundle (`coordinator::export::export_bundle`) consists of
//! `<stem>.fxr` (encrypted quantized weights), `<stem>.fp.bin` (FXIN FP
//! residue: stem/head/biases/BN), and `<stem>.bundle.json` (index). This
//! module decrypts the quantized layers through the word-parallel XOR
//! engine, reconstructs dense weights with `Σ α_i b_i`, rebuilds the
//! architecture, and runs forward passes whose logits match the AOT eval
//! HLO (verified in `rust/tests/e2e_train.rs`).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::flexor::binarycodes::reconstruct_dense;
use crate::flexor::fxr::Container;
use crate::flexor::Decryptor;
use crate::runtime::initbin;
use crate::substrate::json::{self, Json};

use super::tensor::{self, Tensor};

const BN_EPS: f32 = 1e-5;

/// FP leaf store addressed by jax keystr path.
struct FpStore {
    by_path: BTreeMap<String, (Vec<usize>, Vec<f32>)>,
}

impl FpStore {
    fn load(bin: &[u8], index: &Json) -> Result<Self> {
        let leaves = initbin::read_init_bin(bin)?;
        let idx = index.as_arr().context("fp_index not an array")?;
        ensure!(idx.len() == leaves.len(), "fp index/leaf count mismatch");
        let mut by_path = BTreeMap::new();
        for (e, leaf) in idx.iter().zip(leaves) {
            let path = e.get("path").as_str().context("fp index path")?;
            by_path.insert(path.to_string(), (leaf.shape.clone(), leaf.as_f32()?));
        }
        Ok(FpStore { by_path })
    }

    fn get(&self, path: &str) -> Result<&(Vec<usize>, Vec<f32>)> {
        self.by_path
            .get(path)
            .with_context(|| format!("missing FP leaf {path}"))
    }

    fn vec(&self, path: &str) -> Result<Vec<f32>> {
        Ok(self.get(path)?.1.clone())
    }

    fn tensor(&self, path: &str) -> Result<Tensor> {
        let (shape, data) = self.get(path)?;
        Ok(Tensor::new(shape.clone(), data.clone()))
    }

    fn has(&self, path: &str) -> bool {
        self.by_path.contains_key(path)
    }
}

/// BN parameter pack for one normalization site.
struct Bn {
    scale: Vec<f32>,
    bias: Vec<f32>,
    mean: Vec<f32>,
    var: Vec<f32>,
}

impl Bn {
    fn apply(&self, x: &mut Tensor) {
        tensor::batch_norm_eval(x, &self.scale, &self.bias, &self.mean,
                                &self.var, BN_EPS);
    }
}

/// A fully materialized inference model.
pub struct InferenceModel {
    pub model: String,
    pub num_classes: usize,
    pub input_dims: Vec<usize>,
    /// Dense weights of quantized layers, by layer index, reconstructed
    /// from the encrypted container (decrypt + Σ α_i b_i).
    qweights: BTreeMap<usize, Tensor>,
    fp: FpStore,
    bns: Vec<Bn>,
    /// Paper-format storage stats, carried for reporting.
    pub bits_per_weight: f64,
    pub compression_ratio: f64,
}

impl InferenceModel {
    /// Load `<stem>.fxr` + `<stem>.fp.bin` + `<stem>.bundle.json`.
    pub fn load(dir: &Path, stem: &str) -> Result<Self> {
        let bundle_text =
            std::fs::read_to_string(dir.join(format!("{stem}.bundle.json")))?;
        let bundle = json::parse(&bundle_text)?;
        let fxr = Container::load(&dir.join(format!("{stem}.fxr")))?;
        let fp_bytes = std::fs::read(dir.join(format!("{stem}.fp.bin")))?;
        let fp = FpStore::load(&fp_bytes, bundle.get("fp_index"))?;

        // shapes of quantized layers
        let mut shapes: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
        for e in bundle.get("quantized_layers").as_arr().unwrap_or(&[]) {
            let idx = e.get("idx").as_usize().context("layer idx")?;
            let shape = e
                .get("shape")
                .as_arr()
                .context("layer shape")?
                .iter()
                .map(|d| d.as_usize().context("dim"))
                .collect::<Result<Vec<_>>>()?;
            shapes.insert(idx, shape);
        }

        // decrypt every quantized layer
        let mut qweights = BTreeMap::new();
        for layer in &fxr.layers {
            let idx: usize = layer
                .name
                .strip_prefix('q')
                .and_then(|s| s.parse().ok())
                .with_context(|| format!("bad layer name {}", layer.name))?;
            let shape = shapes
                .get(&idx)
                .with_context(|| format!("no shape for layer {idx}"))?;
            ensure!(shape.iter().product::<usize>() == layer.n_weights,
                    "layer {idx}: shape {:?} != n_weights {}", shape, layer.n_weights);
            let mut planes = Vec::with_capacity(layer.q());
            let mut alphas = Vec::with_capacity(layer.q());
            for p in &layer.planes {
                let d = Decryptor::new(p.mxor.clone());
                planes.push(d.decrypt_to_signs(&p.enc, layer.n_weights)?);
                alphas.push(p.alpha.clone());
            }
            let dense = reconstruct_dense(&planes, &alphas, layer.c_out)?;
            qweights.insert(idx, Tensor::new(shape.clone(), dense));
        }

        // BN packs, in conv-site order (paths ['bn'][i][...])
        let mut bns = Vec::new();
        for i in 0.. {
            let p = |f: &str| format!("['bn'][{i}]['{f}']");
            if !fp.has(&p("scale")) {
                break;
            }
            bns.push(Bn {
                scale: fp.vec(&p("scale"))?,
                bias: fp.vec(&p("bias"))?,
                mean: fp.vec(&p("mean"))?,
                var: fp.vec(&p("var"))?,
            });
        }

        let stats = fxr.stats();
        Ok(InferenceModel {
            model: bundle.get("model").as_str().context("model")?.to_string(),
            num_classes: bundle.get("num_classes").as_usize().unwrap_or(10),
            input_dims: bundle
                .get("input_shape")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|d| d.as_usize())
                .collect(),
            qweights,
            fp,
            bns,
            bits_per_weight: stats.bits_per_weight,
            compression_ratio: stats.compression_ratio_with_alpha,
        })
    }

    fn qweight(&self, idx: usize) -> Result<&Tensor> {
        self.qweights
            .get(&idx)
            .with_context(|| format!("missing quantized layer {idx}"))
    }

    /// Batched forward: x flat NHWC (or NC for mlp), returns (N, classes).
    pub fn forward(&self, x: &[f32], n: usize) -> Result<Vec<f32>> {
        match self.model.as_str() {
            m if m.starts_with("resnet") => self.forward_resnet(x, n),
            "lenet5" => self.forward_lenet(x, n),
            "mlp" => self.forward_mlp(x, n),
            other => bail!("unknown model {other}"),
        }
    }

    /// argmax over forward logits.
    pub fn predict(&self, x: &[f32], n: usize) -> Result<Vec<i32>> {
        let logits = self.forward(x, n)?;
        let c = self.num_classes;
        Ok((0..n)
            .map(|i| {
                let row = &logits[i * c..(i + 1) * c];
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0 as i32
            })
            .collect())
    }

    // ---- architectures -------------------------------------------------------

    fn resnet_geometry(&self) -> Result<(Vec<usize>, Vec<usize>)> {
        // (blocks per stage, widths) — mirrors python/compile/models/resnet.py
        Ok(match self.model.as_str() {
            "resnet8" => (vec![1, 1, 1], vec![8, 16, 32]),
            "resnet14" => (vec![2, 2, 2], vec![16, 32, 64]),
            "resnet20" => (vec![3, 3, 3], vec![16, 32, 64]),
            "resnet32" => (vec![5, 5, 5], vec![16, 32, 64]),
            "resnet10img" => (vec![1, 1, 1, 1], vec![16, 32, 64, 128]),
            "resnet18img" => (vec![2, 2, 2, 2], vec![64, 128, 256, 512]),
            other => bail!("unknown resnet variant {other}"),
        })
    }

    fn forward_resnet(&self, x: &[f32], n: usize) -> Result<Vec<f32>> {
        let (blocks, widths) = self.resnet_geometry()?;
        ensure!(self.input_dims.len() == 3, "resnet expects HWC input dims");
        let (h, w, ci) = (self.input_dims[0], self.input_dims[1], self.input_dims[2]);
        ensure!(x.len() == n * h * w * ci, "input length mismatch");

        let mut bn_i = 0usize;
        let mut q_i = 0usize;
        let mut bn = |t: &mut Tensor, bns: &[Bn]| -> Result<()> {
            ensure!(bn_i < bns.len(), "ran out of BN packs");
            bns[bn_i].apply(t);
            bn_i += 1;
            Ok(())
        };

        // stem (FP)
        let stem = self.fp.tensor("['stem']['w']")?;
        let mut hmap = tensor::conv2d(
            &Tensor::new(vec![n, h, w, ci], x.to_vec()),
            &stem,
            1,
        );
        bn(&mut hmap, &self.bns)?;
        tensor::relu(&mut hmap);

        let mut c_in = widths[0];
        for (si, (&nb, &wd)) in blocks.iter().zip(&widths).enumerate() {
            for bi in 0..nb {
                let stride = if si > 0 && bi == 0 { 2 } else { 1 };
                let identity = hmap.clone();
                let w1 = self.qweight(q_i)?;
                q_i += 1;
                let mut out = tensor::conv2d(&hmap, w1, stride);
                bn(&mut out, &self.bns)?;
                tensor::relu(&mut out);
                let w2 = self.qweight(q_i)?;
                q_i += 1;
                let mut out = tensor::conv2d(&out, w2, 1);
                bn(&mut out, &self.bns)?;
                let short = if stride != 1 || c_in != wd {
                    let wd_w = self.qweight(q_i)?;
                    q_i += 1;
                    let mut s = tensor::conv2d(&identity, wd_w, stride);
                    bn(&mut s, &self.bns)?;
                    s
                } else {
                    identity
                };
                tensor::add_inplace(&mut out, &short);
                tensor::relu(&mut out);
                hmap = out;
                c_in = wd;
            }
        }
        let pooled = tensor::avg_pool_global(&hmap);
        let head_w = self.fp.tensor("['head']['w']")?;
        let head_b = self.fp.vec("['head']['b']")?;
        Ok(tensor::dense(&pooled, &head_w, Some(&head_b)).data)
    }

    fn forward_lenet(&self, x: &[f32], n: usize) -> Result<Vec<f32>> {
        ensure!(self.input_dims.len() == 3);
        let (h, w, ci) = (self.input_dims[0], self.input_dims[1], self.input_dims[2]);
        let bias = |i: usize| self.fp.vec(&format!("['bias'][{i}]"));
        let mut t = Tensor::new(vec![n, h, w, ci], x.to_vec());

        let w0 = self.qweight(0)?;
        t = tensor::conv2d(&t, w0, 1);
        add_bias_nhwc(&mut t, &bias(0)?);
        tensor::relu(&mut t);
        t = tensor::max_pool2(&t);

        let w1 = self.qweight(1)?;
        t = tensor::conv2d(&t, w1, 1);
        add_bias_nhwc(&mut t, &bias(1)?);
        tensor::relu(&mut t);
        t = tensor::max_pool2(&t);

        let flat_len: usize = t.dims[1] * t.dims[2] * t.dims[3];
        let flat = Tensor::new(vec![n, flat_len], t.data);

        let w2 = self.qweight(2)?;
        let mut fc = tensor::dense(&flat, w2, Some(&bias(2)?));
        tensor::relu(&mut fc);
        let w3 = self.qweight(3)?;
        Ok(tensor::dense(&fc, w3, Some(&bias(3)?)).data)
    }

    fn forward_mlp(&self, x: &[f32], n: usize) -> Result<Vec<f32>> {
        let d_in = x.len() / n;
        let mut t = Tensor::new(vec![n, d_in], x.to_vec());
        for i in 0.. {
            let Some(w) = self.qweights.get(&i) else { break };
            t = tensor::dense(&t, w, None);
            self.bns
                .get(i)
                .context("missing BN pack for mlp layer")?
                .apply(&mut t);
            tensor::relu(&mut t);
        }
        let head_w = self.fp.tensor("['head']['w']")?;
        let head_b = self.fp.vec("['head']['b']")?;
        Ok(tensor::dense(&t, &head_w, Some(&head_b)).data)
    }
}

fn add_bias_nhwc(t: &mut Tensor, bias: &[f32]) {
    let c = *t.dims.last().unwrap();
    assert_eq!(bias.len(), c);
    for (i, v) in t.data.iter_mut().enumerate() {
        *v += bias[i % c];
    }
}

#[cfg(test)]
mod tests {
    //! Full-bundle tests live in rust/tests/e2e_train.rs (they need
    //! artifacts + a trained session). Here: geometry table only.
    use super::*;

    fn dummy(model: &str) -> InferenceModel {
        InferenceModel {
            model: model.into(),
            num_classes: 10,
            input_dims: vec![32, 32, 3],
            qweights: BTreeMap::new(),
            fp: FpStore { by_path: BTreeMap::new() },
            bns: vec![],
            bits_per_weight: 0.8,
            compression_ratio: 35.0,
        }
    }

    #[test]
    fn resnet_geometry_table() {
        assert_eq!(dummy("resnet20").resnet_geometry().unwrap().0, vec![3, 3, 3]);
        assert_eq!(dummy("resnet10img").resnet_geometry().unwrap().1,
                   vec![16, 32, 64, 128]);
        assert!(dummy("resnet99").resnet_geometry().is_err());
    }

    #[test]
    fn unknown_model_rejected() {
        assert!(dummy("vgg").forward(&[0.0; 10], 1).is_err());
    }
}
