//! Packed-panel parallel GEMM with fused epilogues — the inference hot
//! path (DESIGN.md §7).
//!
//! The right-hand side of every inference GEMM is a weight matrix that
//! never changes after bundle load, so it is packed **once** into
//! cache-aligned column panels ([`PackedB`]). The forward pass then runs a
//! register-blocked MR×NR microkernel over row blocks of the activation
//! matrix, sharded across the [`crate::substrate::pool`] thread pool, and applies
//! the layer epilogue (bias / eval-mode batch-norm in `a·x+b` form / ReLU /
//! residual add) inside the output tile while it is still hot in registers
//! — `conv2d → bn → relu` is one kernel invocation instead of three
//! full-tensor passes.
//!
//! Determinism: every output element is produced by exactly one shard with
//! a fixed k-ascending accumulation order, so results are bit-identical
//! across thread counts — serve responses byte-match direct inference no
//! matter the thread budget.

use crate::substrate::pool::ThreadPool;
use crate::substrate::trace;

use super::tensor::{self, Tensor};

/// Microkernel row block (rows of A per tile). Kept at 4 so the NR-wide
/// accumulator rows fit the baseline x86-64 SSE register file without
/// spills; bump alongside NR when building with wider SIMD.
pub const MR: usize = 4;
/// Microkernel column block (columns of B per panel).
pub const NR: usize = 8;

/// Rows of C per pool shard (a multiple of MR keeps tiles unsplit).
/// Shared with the bit-plane engine so both shard identically.
pub(crate) const ROWS_PER_SHARD: usize = 64;

/// 64-byte-aligned storage block so panel rows start on cache-line
/// boundaries regardless of allocator mood.
#[repr(align(64))]
#[derive(Clone, Copy)]
struct AlignedBlock([f32; 16]);

/// A (k × n) row-major matrix re-laid-out as `ceil(n/NR)` contiguous
/// panels: panel `p` holds columns `[p·NR, p·NR+NR)` as `k` rows of NR
/// consecutive floats (zero-padded past `n`). Packed once at model load.
pub struct PackedB {
    k: usize,
    n: usize,
    buf: Vec<AlignedBlock>,
}

impl PackedB {
    /// Pack row-major `b` (k × n).
    pub fn pack(b: &[f32], k: usize, n: usize) -> PackedB {
        assert_eq!(b.len(), k * n, "PackedB: {k}x{n} vs len {}", b.len());
        let panels = n.div_ceil(NR);
        let floats = panels * k * NR;
        let mut buf = vec![AlignedBlock([0.0; 16]); floats.div_ceil(16).max(1)];
        {
            let dst = floats_mut(&mut buf);
            for p in 0..panels {
                let j0 = p * NR;
                let jw = (n - j0).min(NR);
                let panel = &mut dst[p * k * NR..(p + 1) * k * NR];
                for kk in 0..k {
                    for jr in 0..jw {
                        panel[kk * NR + jr] = b[kk * n + j0 + jr];
                    }
                }
            }
        }
        PackedB { k, n, buf }
    }

    /// Pack a weight tensor: conv HWIO collapses to (kh·kw·ci, co), dense
    /// (in, out) is already the GEMM layout.
    pub fn from_tensor(w: &Tensor) -> PackedB {
        let n = *w.dims.last().expect("weight tensor needs dims");
        let k = w.data.len() / n;
        PackedB::pack(&w.data, k, n)
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn n(&self) -> usize {
        self.n
    }

    fn panels(&self) -> usize {
        self.n.div_ceil(NR)
    }

    fn panel(&self, p: usize) -> &[f32] {
        &floats(&self.buf)[p * self.k * NR..(p + 1) * self.k * NR]
    }

    /// Bytes this packed copy keeps resident (the `/models` accounting).
    pub fn resident_bytes(&self) -> usize {
        self.buf.len() * std::mem::size_of::<AlignedBlock>()
    }
}

fn floats(buf: &[AlignedBlock]) -> &[f32] {
    // Safety: AlignedBlock is exactly 16 f32s with stricter alignment.
    unsafe { std::slice::from_raw_parts(buf.as_ptr() as *const f32, buf.len() * 16) }
}

fn floats_mut(buf: &mut [AlignedBlock]) -> &mut [f32] {
    unsafe {
        std::slice::from_raw_parts_mut(buf.as_mut_ptr() as *mut f32, buf.len() * 16)
    }
}

/// What happens to an output tile before it is stored — the fusion
/// contract (DESIGN.md §7). Column index selects the per-channel
/// parameter; `residual` shares C's row-major (m × n) layout.
#[derive(Clone, Copy)]
pub enum Epilogue<'a> {
    /// Store raw accumulators.
    None,
    /// `y = x + bias[col]`, then optional ReLU.
    Bias { bias: &'a [f32], relu: bool },
    /// Eval-mode batch norm folded to `y = x·a[col] + b[col]`, optional ReLU.
    Affine { a: &'a [f32], b: &'a [f32], relu: bool },
    /// Residual block tail: `y = x·a[col] + b[col] + residual[row,col]`,
    /// optional ReLU.
    AffineAdd { a: &'a [f32], b: &'a [f32], residual: &'a [f32], relu: bool },
}

/// `C = epilogue(A · B)` into caller storage. `a` is (m × k) row-major,
/// `c` is (m × n) fully overwritten. Row blocks are sharded across `pool`.
pub fn gemm_packed_into(
    pool: &ThreadPool,
    a: &[f32],
    m: usize,
    k: usize,
    b: &PackedB,
    epi: Epilogue<'_>,
    c: &mut [f32],
) {
    assert_eq!(a.len(), m * k, "A is {m}x{k}");
    assert_eq!(b.k, k, "B expects k={}, got {k}", b.k);
    assert_eq!(c.len(), m * b.n, "C is {m}x{}", b.n);
    validate_epilogue(&epi, b.n, c.len());
    let n = b.n;
    // One span for the whole sharded GEMM: A-packing and the fused
    // epilogue happen inside the tile loop, so they are part of this
    // stage by construction (DESIGN.md §10).
    let _s = trace::span("gemm");
    pool.run_chunks_mut(c, ROWS_PER_SHARD * n, |_shard, start, c_part| {
        let i0 = start / n;
        let rows = c_part.len() / n;
        scratch::with(|arena| {
            let mut apack = arena.take(MR * k);
            for t0 in (0..rows).step_by(MR) {
                let mh = (rows - t0).min(MR);
                pack_a_tile(a, k, i0 + t0, mh, &mut apack);
                for p in 0..b.panels() {
                    let mut acc = [[0.0f32; NR]; MR];
                    kernel(&apack, b.panel(p), k, &mut acc);
                    store_tile(&acc, c_part, t0, i0, mh, p * NR, n, &epi);
                }
            }
            arena.give(apack);
        });
    });
}

/// `epilogue(A · B)` into a scratch-arena buffer.
pub fn gemm_packed(
    pool: &ThreadPool,
    a: &[f32],
    m: usize,
    k: usize,
    b: &PackedB,
    epi: Epilogue<'_>,
) -> Vec<f32> {
    let mut c = scratch::take(m * b.n);
    gemm_packed_into(pool, a, m, k, b, epi, &mut c);
    c
}

/// Validate per-channel epilogue parameters up front (the reference
/// path's batch_norm_eval asserts the same) so a malformed bundle fails
/// with a clear message, not an index panic inside a shard. Shared with
/// the bit-plane engine.
pub(crate) fn validate_epilogue(epi: &Epilogue<'_>, n: usize, c_len: usize) {
    match *epi {
        Epilogue::None => {}
        Epilogue::Bias { bias, .. } => {
            assert_eq!(bias.len(), n, "bias length must match n={n}");
        }
        Epilogue::Affine { a: ea, b: eb, .. } => {
            assert!(ea.len() == n && eb.len() == n,
                    "affine params must match n={n}");
        }
        Epilogue::AffineAdd { a: ea, b: eb, residual, .. } => {
            assert!(ea.len() == n && eb.len() == n,
                    "affine params must match n={n}");
            assert_eq!(residual.len(), c_len, "residual must match C");
        }
    }
}

/// Transpose `mh` rows of A (starting at `row0`) into the MR-interleaved
/// tile layout `apack[kk·MR + r]`; rows past `mh` are zeroed so the
/// microkernel always runs a full MR block.
fn pack_a_tile(a: &[f32], k: usize, row0: usize, mh: usize, apack: &mut [f32]) {
    for r in 0..MR {
        if r < mh {
            let row = &a[(row0 + r) * k..(row0 + r + 1) * k];
            for (kk, &v) in row.iter().enumerate() {
                apack[kk * MR + r] = v;
            }
        } else {
            for kk in 0..k {
                apack[kk * MR + r] = 0.0;
            }
        }
    }
}

/// The register-blocked MR×NR microkernel: a rank-1 update per k step over
/// fixed-size arrays, written so LLVM auto-vectorizes the NR-wide rows.
#[inline]
fn kernel(apack: &[f32], panel: &[f32], k: usize, acc: &mut [[f32; NR]; MR]) {
    for kk in 0..k {
        let arow: &[f32; MR] = apack[kk * MR..kk * MR + MR].try_into().unwrap();
        let brow: &[f32; NR] = panel[kk * NR..kk * NR + NR].try_into().unwrap();
        for r in 0..MR {
            let av = arow[r];
            for j in 0..NR {
                acc[r][j] += av * brow[j];
            }
        }
    }
}

/// Apply the epilogue to one tile and store its live `mh × jw` region.
/// `t0` is the tile's first row inside `c_part`; `i0` the part's first
/// absolute row (for residual addressing). Shared with the bit-plane
/// engine ([`super::bitslice`]) so both honour one fusion contract.
#[inline]
pub(crate) fn store_tile(
    acc: &[[f32; NR]; MR],
    c_part: &mut [f32],
    t0: usize,
    i0: usize,
    mh: usize,
    j0: usize,
    n: usize,
    epi: &Epilogue<'_>,
) {
    let jw = (n - j0).min(NR);
    for r in 0..mh {
        let out = &mut c_part[(t0 + r) * n + j0..(t0 + r) * n + j0 + jw];
        match *epi {
            Epilogue::None => out.copy_from_slice(&acc[r][..jw]),
            Epilogue::Bias { bias, relu } => {
                for j in 0..jw {
                    let v = acc[r][j] + bias[j0 + j];
                    out[j] = if relu && v < 0.0 { 0.0 } else { v };
                }
            }
            Epilogue::Affine { a, b, relu } => {
                for j in 0..jw {
                    let v = acc[r][j] * a[j0 + j] + b[j0 + j];
                    out[j] = if relu && v < 0.0 { 0.0 } else { v };
                }
            }
            Epilogue::AffineAdd { a, b, residual, relu } => {
                let res = &residual[(i0 + t0 + r) * n + j0..][..jw];
                for j in 0..jw {
                    let v = acc[r][j] * a[j0 + j] + b[j0 + j] + res[j];
                    out[j] = if relu && v < 0.0 { 0.0 } else { v };
                }
            }
        }
    }
}

// ---- fused layer ops --------------------------------------------------------

/// Fused `conv2d → epilogue` over a pre-packed HWIO weight: im2col into a
/// recycled scratch buffer (sharded across the pool by disjoint row
/// ranges), one packed GEMM, epilogue applied in-tile.
/// `(kh, kw, ci)` is the kernel geometry the packed weight was built from.
pub fn conv2d_fused(
    pool: &ThreadPool,
    x: &Tensor,
    w: &PackedB,
    (kh, kw, ci): (usize, usize, usize),
    stride: usize,
    epi: Epilogue<'_>,
) -> Tensor {
    assert_eq!(x.rank(), 4, "conv input must be NHWC");
    assert_eq!(x.dims[3], ci, "channel mismatch");
    assert_eq!(w.k(), kh * kw * ci, "packed weight geometry mismatch");
    let n = x.dims[0];
    let dims = (n, x.dims[1], x.dims[2], ci);
    let (ho, wo, _, _) = tensor::conv_out_geometry((x.dims[1], x.dims[2]), (kh, kw), stride);
    let k = kh * kw * ci;
    let rows = n * ho * wo;
    let mut col = scratch::take(rows * k);
    {
        let _s = trace::span("im2col");
        pool.run_chunks_mut(&mut col, ROWS_PER_SHARD * k, |_shard, start, part| {
            tensor::im2col_rows(&x.data, dims, (kh, kw), stride, start / k, part);
        });
    }
    let out = gemm_packed(pool, &col, rows, k, w, epi);
    scratch::give(col);
    Tensor::new(vec![n, ho, wo, w.n()], out)
}

/// Fused `dense → epilogue`: x (N, In) · packed (In, Out).
pub fn dense_fused(
    pool: &ThreadPool,
    x: &Tensor,
    w: &PackedB,
    epi: Epilogue<'_>,
) -> Tensor {
    assert_eq!(x.rank(), 2, "dense input must be (N, In)");
    assert_eq!(x.dims[1], w.k(), "dense in-features mismatch");
    let out = gemm_packed(pool, &x.data, x.dims[0], x.dims[1], w, epi);
    Tensor::new(vec![x.dims[0], w.n()], out)
}

// ---- per-thread scratch arena -----------------------------------------------

/// Per-thread buffer recycling so im2col columns, activations, logits —
/// and the bit-plane engine's packed u64 activation planes — are not
/// reallocated on every request. Buffers come back via [`give`] /
/// [`give_u64`]; contents of a taken buffer are unspecified (callers
/// fully overwrite, or zero what they only OR into).
pub mod scratch {
    use std::cell::RefCell;

    /// Free buffers retained per thread per element type (bounds idle
    /// memory).
    const MAX_FREE: usize = 16;

    /// Best-fit pick: the smallest free buffer whose capacity suffices,
    /// else the largest (it will grow the least).
    fn best_fit<T>(free: &mut Vec<Vec<T>>, len: usize) -> Vec<T> {
        let pick = free
            .iter()
            .enumerate()
            .filter(|(_, v)| v.capacity() >= len)
            .min_by_key(|(_, v)| v.capacity())
            .map(|(i, _)| i)
            .or_else(|| {
                free.iter()
                    .enumerate()
                    .max_by_key(|(_, v)| v.capacity())
                    .map(|(i, _)| i)
            });
        match pick {
            Some(i) => free.swap_remove(i),
            None => Vec::new(),
        }
    }

    fn keep<T>(free: &mut Vec<Vec<T>>, v: Vec<T>) {
        if free.len() < MAX_FREE && v.capacity() > 0 {
            free.push(v);
        }
    }

    pub struct Arena {
        free: Vec<Vec<f32>>,
        free64: Vec<Vec<u64>>,
    }

    impl Arena {
        /// A buffer of exactly `len` floats with unspecified contents.
        pub fn take(&mut self, len: usize) -> Vec<f32> {
            let mut v = best_fit(&mut self.free, len);
            if v.len() > len {
                v.truncate(len);
            } else {
                v.resize(len, 0.0);
            }
            v
        }

        /// Return a buffer for reuse by later takes on this thread.
        pub fn give(&mut self, v: Vec<f32>) {
            keep(&mut self.free, v);
        }

        /// A buffer of exactly `len` u64 words with unspecified
        /// contents (the bit-plane engine's activation planes).
        pub fn take_u64(&mut self, len: usize) -> Vec<u64> {
            let mut v = best_fit(&mut self.free64, len);
            if v.len() > len {
                v.truncate(len);
            } else {
                v.resize(len, 0);
            }
            v
        }

        /// Return a u64 buffer for reuse by later takes on this thread.
        pub fn give_u64(&mut self, v: Vec<u64>) {
            keep(&mut self.free64, v);
        }
    }

    thread_local! {
        static ARENA: RefCell<Arena> =
            const { RefCell::new(Arena { free: Vec::new(), free64: Vec::new() }) };
    }

    /// Run `f` with this thread's arena.
    pub fn with<R>(f: impl FnOnce(&mut Arena) -> R) -> R {
        ARENA.with(|a| f(&mut a.borrow_mut()))
    }

    /// [`Arena::take`] on the current thread's arena.
    pub fn take(len: usize) -> Vec<f32> {
        with(|a| a.take(len))
    }

    /// [`Arena::give`] on the current thread's arena.
    pub fn give(v: Vec<f32>) {
        with(|a| a.give(v));
    }

    /// [`Arena::take_u64`] on the current thread's arena.
    pub fn take_u64(len: usize) -> Vec<u64> {
        with(|a| a.take_u64(len))
    }

    /// [`Arena::give_u64`] on the current thread's arena.
    pub fn give_u64(v: Vec<u64>) {
        with(|a| a.give_u64(v));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::ptest::check_msg;

    fn close(a: f32, b: f32) -> bool {
        (a - b).abs() <= 1e-3 * (1.0 + b.abs())
    }

    /// Satellite property: packed parallel GEMM ≡ naive GEMM across
    /// thread counts and ragged m/k/n.
    #[test]
    fn packed_gemm_matches_naive_across_threads() {
        let pools = [ThreadPool::new(1), ThreadPool::new(2), ThreadPool::new(4)];
        check_msg("packed parallel gemm == naive", 30, |g| {
            let m = g.usize_in(1, 70);
            let k = g.usize_in(1, 90);
            let n = g.usize_in(1, 70);
            let a: Vec<f32> = (0..m * k).map(|_| g.normal()).collect();
            let b: Vec<f32> = (0..k * n).map(|_| g.normal()).collect();
            let want = tensor::gemm(&a, m, k, &b, n);
            let packed = PackedB::pack(&b, k, n);
            for pool in &pools {
                let got = gemm_packed(pool, &a, m, k, &packed, Epilogue::None);
                for (i, (x, y)) in got.iter().zip(&want).enumerate() {
                    if !close(*x, *y) {
                        return Err(format!(
                            "threads={} ({m}x{k}x{n}) elem {i}: {x} vs {y}",
                            pool.threads()
                        ));
                    }
                }
                scratch::give(got);
            }
            Ok(())
        });
    }

    #[test]
    fn packed_gemm_deterministic_across_thread_counts() {
        let a: Vec<f32> = (0..57 * 33).map(|i| (i as f32 * 0.37).sin()).collect();
        let b: Vec<f32> = (0..33 * 29).map(|i| (i as f32 * 0.11).cos()).collect();
        let packed = PackedB::pack(&b, 33, 29);
        let one = gemm_packed(&ThreadPool::new(1), &a, 57, 33, &packed, Epilogue::None);
        let four = gemm_packed(&ThreadPool::new(4), &a, 57, 33, &packed, Epilogue::None);
        assert_eq!(one, four, "thread count changed the bits");
    }

    /// Satellite property: fused conv+bn+relu ≡ the separate-pass
    /// composition (`conv2d` → `batch_norm_eval` → `relu`).
    #[test]
    fn fused_conv_bn_relu_matches_separate_passes() {
        let pool = ThreadPool::new(2);
        check_msg("fused conv+bn+relu == separate passes", 20, |g| {
            let n = g.usize_in(1, 3);
            let h = g.usize_in(2, 8);
            let wd = g.usize_in(2, 8);
            let ci = g.usize_in(1, 4);
            let co = g.usize_in(1, 9);
            let kk = [1usize, 3][g.usize_in(0, 2)];
            let stride = 1 + g.usize_in(0, 2);
            let x = Tensor::new(
                vec![n, h, wd, ci],
                (0..n * h * wd * ci).map(|_| g.normal()).collect(),
            );
            let w = Tensor::new(
                vec![kk, kk, ci, co],
                (0..kk * kk * ci * co).map(|_| g.normal()).collect(),
            );
            let scale: Vec<f32> = (0..co).map(|_| g.f32_in(0.5, 1.5)).collect();
            let bias: Vec<f32> = (0..co).map(|_| g.normal()).collect();
            let mean: Vec<f32> = (0..co).map(|_| 0.3 * g.normal()).collect();
            let var: Vec<f32> = (0..co).map(|_| g.f32_in(0.5, 1.5)).collect();

            // reference: three separate full-tensor passes
            let mut want = tensor::conv2d(&x, &w, stride);
            tensor::batch_norm_eval(&mut want, &scale, &bias, &mean, &var, 1e-5);
            tensor::relu(&mut want);

            // fused: one kernel invocation over the same folded params
            let (a, b) = tensor::bn_fold(&scale, &bias, &mean, &var, 1e-5);
            let packed = PackedB::from_tensor(&w);
            let got = conv2d_fused(
                &pool,
                &x,
                &packed,
                (kk, kk, ci),
                stride,
                Epilogue::Affine { a: &a, b: &b, relu: true },
            );
            if got.dims != want.dims {
                return Err(format!("dims {:?} vs {:?}", got.dims, want.dims));
            }
            for (i, (x, y)) in got.data.iter().zip(&want.data).enumerate() {
                if !close(*x, *y) {
                    return Err(format!("elem {i}: {x} vs {y} (k={kk} s={stride})"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn bias_and_residual_epilogues() {
        let pool = ThreadPool::new(2);
        // 2x3 · 3x2 with bias+relu
        let a = [1.0f32, 0.0, -1.0, 2.0, 1.0, 0.5];
        let b = [1.0f32, 2.0, 0.0, 1.0, 1.0, -1.0];
        let packed = PackedB::pack(&b, 3, 2);
        let got = gemm_packed(
            &pool,
            &a,
            2,
            3,
            &packed,
            Epilogue::Bias { bias: &[0.5, -10.0], relu: true },
        );
        // raw: [0,3],[2.5,4.5]; +bias: [0.5,-7],[3,-5.5]; relu clamps col 1
        assert_eq!(got, vec![0.5, 0.0, 3.0, 0.0]);

        // affine+residual (no relu): y = x*a + b + res
        let res = [10.0f32, 20.0, 30.0, 40.0];
        let got = gemm_packed(
            &pool,
            &a,
            2,
            3,
            &packed,
            Epilogue::AffineAdd {
                a: &[2.0, 1.0],
                b: &[1.0, 0.0],
                residual: &res,
                relu: false,
            },
        );
        assert_eq!(got, vec![11.0, 23.0, 36.0, 44.5]);
    }

    #[test]
    fn dense_fused_matches_dense() {
        let pool = ThreadPool::new(2);
        let x = Tensor::new(vec![2, 3], vec![1.0, 2.0, 3.0, -1.0, 0.5, 2.0]);
        let w = Tensor::new(vec![3, 2], vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0]);
        let bias = [0.25f32, -0.25];
        let want = tensor::dense(&x, &w, Some(&bias));
        let packed = PackedB::from_tensor(&w);
        let got = dense_fused(
            &pool,
            &x,
            &packed,
            Epilogue::Bias { bias: &bias, relu: false },
        );
        assert_eq!(got.dims, want.dims);
        assert_eq!(got.data, want.data);
    }

    #[test]
    fn scratch_arena_recycles() {
        let v = scratch::take(128);
        let p = v.as_ptr();
        scratch::give(v);
        let v2 = scratch::take(64);
        assert_eq!(v2.as_ptr(), p, "arena should reuse the freed buffer");
        assert_eq!(v2.len(), 64);
        scratch::give(v2);
    }

    #[test]
    fn scratch_arena_recycles_u64() {
        let v = scratch::take_u64(256);
        let p = v.as_ptr();
        scratch::give_u64(v);
        let v2 = scratch::take_u64(100);
        assert_eq!(v2.as_ptr(), p, "u64 arena should reuse the freed buffer");
        assert_eq!(v2.len(), 100);
        // the two free-lists are independent: an f32 take never returns
        // u64 storage
        let f = scratch::take(100);
        assert_ne!(f.as_ptr() as usize, p as usize);
        scratch::give(f);
        scratch::give_u64(v2);
    }
}
