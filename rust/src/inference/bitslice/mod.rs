//! Bit-plane XNOR/popcount compute engine (DESIGN.md §8): serve
//! encrypted bundles **without dequantizing to dense FP**.
//!
//! The DenseF32 engine (§4/§7) decrypts once at load and materializes
//! `Σ α_p b_p` f32 weights — ~32× the resident bytes the `.fxr` format
//! was designed to avoid. This subsystem keeps quantized layers as
//! packed bit-planes for their entire serving lifetime:
//!
//! * [`plane`]    — [`PlaneStore`]: per-output-channel u64 bit rows + α,
//!   repacked straight off the word-parallel decryptor
//!   (`Decryptor::decrypt_to_plane_rows`);
//! * [`binarize`] — the activation contract: each im2col row becomes up
//!   to `m` greedy sign/scale planes (`a ≈ Σ β_m h_m`, exact for ±1
//!   rows);
//! * [`gemm`]     — the XNOR/popcount GEMM: `k − 2·popcount(h ⊕ b)` per
//!   plane pair, α/β scaling, row-sharded on the substrate pool and
//!   finished by the same [`Epilogue`](super::gemm::Epilogue) fusion
//!   contract as the packed-FP engine.
//!
//! [`ComputeMode`] selects the engine per model: a single server mixes
//! FP-exact models with high-density bit-plane models (`serve::Registry`
//! reports each entry's resident bytes).

pub mod binarize;
pub mod gemm;
pub mod plane;

pub use binarize::{BinarizedActs, DEFAULT_ACT_PLANES, MAX_ACT_PLANES};
pub use gemm::{conv2d_bitplane, dense_bitplane, popcount_dot, xnor_gemm_into};
pub use plane::PlaneStore;

use anyhow::{bail, Result};

/// Which compute engine a loaded model runs on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ComputeMode {
    /// Decrypt once at load, materialize dense `Σ α_p b_p` f32 weights,
    /// run the packed-FP fused engine (§7). Exact.
    DenseF32,
    /// Keep quantized layers as packed bit-planes and run the
    /// XNOR/popcount engine over activations binarized into
    /// `act_planes` sign/scale planes per im2col row. Exact when every
    /// row is representable in ≤ `act_planes` planes (e.g. ±1 inputs),
    /// an approximation otherwise — see DESIGN.md §8.
    BitPlane {
        /// Activation sign/scale planes per row (1..=[`MAX_ACT_PLANES`]).
        act_planes: usize,
    },
}

impl ComputeMode {
    /// BitPlane with the serving default of [`DEFAULT_ACT_PLANES`].
    pub fn bit_plane() -> ComputeMode {
        ComputeMode::BitPlane { act_planes: DEFAULT_ACT_PLANES }
    }

    /// Parse `dense` / `bitplane` / `bitplane:<m>` (CLI flags and the
    /// `FLEXOR_COMPUTE` env var).
    pub fn parse(s: &str) -> Result<ComputeMode> {
        let t = s.trim().to_ascii_lowercase();
        match t.as_str() {
            "dense" | "densef32" | "fp32" => Ok(ComputeMode::DenseF32),
            "bitplane" | "bit-plane" | "xnor" => Ok(ComputeMode::bit_plane()),
            other => {
                if let Some(m) = other.strip_prefix("bitplane:") {
                    match m.parse::<usize>() {
                        Ok(m) if (1..=MAX_ACT_PLANES).contains(&m) => {
                            Ok(ComputeMode::BitPlane { act_planes: m })
                        }
                        _ => bail!(
                            "bad act-plane count {m:?} (want 1..={MAX_ACT_PLANES})"
                        ),
                    }
                } else {
                    bail!(
                        "unknown compute mode {s:?} (want dense | bitplane | bitplane:<m>)"
                    )
                }
            }
        }
    }

    /// The process default: `FLEXOR_COMPUTE` when set, else DenseF32.
    pub fn default_from_env() -> Result<ComputeMode> {
        match std::env::var("FLEXOR_COMPUTE") {
            Ok(v) if !v.trim().is_empty() => ComputeMode::parse(&v),
            _ => Ok(ComputeMode::DenseF32),
        }
    }

    /// Short name for `/models` JSON and log lines.
    pub fn label(&self) -> &'static str {
        match self {
            ComputeMode::DenseF32 => "dense",
            ComputeMode::BitPlane { .. } => "bitplane",
        }
    }

    /// Activation planes when in BitPlane mode.
    pub fn act_planes(&self) -> Option<usize> {
        match *self {
            ComputeMode::DenseF32 => None,
            ComputeMode::BitPlane { act_planes } => Some(act_planes),
        }
    }

    pub fn is_bit_plane(&self) -> bool {
        matches!(self, ComputeMode::BitPlane { .. })
    }
}

impl Default for ComputeMode {
    fn default() -> Self {
        ComputeMode::DenseF32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_modes() {
        assert_eq!(ComputeMode::parse("dense").unwrap(), ComputeMode::DenseF32);
        assert_eq!(ComputeMode::parse(" FP32 ").unwrap(), ComputeMode::DenseF32);
        assert_eq!(
            ComputeMode::parse("bitplane").unwrap(),
            ComputeMode::BitPlane { act_planes: DEFAULT_ACT_PLANES }
        );
        assert_eq!(
            ComputeMode::parse("bitplane:16").unwrap(),
            ComputeMode::BitPlane { act_planes: 16 }
        );
        assert!(ComputeMode::parse("bitplane:0").is_err());
        assert!(ComputeMode::parse("bitplane:999").is_err());
        assert!(ComputeMode::parse("quantum").is_err());
    }

    #[test]
    fn labels_and_accessors() {
        assert_eq!(ComputeMode::DenseF32.label(), "dense");
        assert_eq!(ComputeMode::bit_plane().label(), "bitplane");
        assert_eq!(ComputeMode::DenseF32.act_planes(), None);
        assert_eq!(ComputeMode::bit_plane().act_planes(), Some(DEFAULT_ACT_PLANES));
        assert!(ComputeMode::bit_plane().is_bit_plane());
        assert!(!ComputeMode::default().is_bit_plane());
    }
}
