//! Bit-plane XNOR/popcount compute engine (DESIGN.md §8/§9): serve
//! encrypted bundles **without dequantizing to dense FP**.
//!
//! The DenseF32 engine (§4/§7) decrypts once at load and materializes
//! `Σ α_p b_p` f32 weights — ~32× the resident bytes the `.fxr` format
//! was designed to avoid. This subsystem keeps quantized layers as
//! packed bit-planes for their entire serving lifetime:
//!
//! * [`plane`]    — [`PlaneStore`]: per-output-channel u64 bit rows + α,
//!   repacked straight off the word-parallel decryptor
//!   (`Decryptor::decrypt_to_plane_rows`) into cache-aligned NR-channel
//!   panels (the SIMD-friendly mirror of the packed-FP `PackedB`);
//! * [`binarize`] — the activation contract: each im2col row becomes up
//!   to `m` greedy sign/scale planes (`a ≈ Σ β_m h_m`, exact for ±1
//!   rows), packed into arena-recycled u64 buffers;
//! * [`popcount`] — the runtime-dispatched popcount kernels
//!   ([`popcount::panel_dot`]): portable scalar, unrolled multi-word
//!   scalar, and AVX2 `vpshufb` — selected by CPU detection, overridable
//!   with `FLEXOR_SIMD=scalar|unrolled|avx2`, all bit-identical;
//! * [`gemm`]     — the XNOR/popcount GEMM: `k − 2·popcount(h ⊕ b)` per
//!   plane pair, NR channels per `panel_dot`, α/β scaling, row-sharded
//!   on the substrate pool and finished by the same
//!   [`Epilogue`](super::gemm::Epilogue) fusion contract as the
//!   packed-FP engine.
//!
//! * [`encrypted`] — decrypt-on-demand serving ([`EncryptedStore`],
//!   DESIGN.md §11): the quantized RHS stays **encrypted** resident
//!   (sub-1-bit/weight, exactly the `.fxr` payload) and the XOR-gate
//!   decryptor runs inside the GEMM tile loop, one NR-channel panel at
//!   a time into a per-thread scratch tile consumed by the same
//!   `panel_dot` kernels.
//!
//! [`ComputeMode`] selects the engine per model and [`ModePolicy`]
//! refines it **per layer**: big conv/dense layers ride the bit-plane
//! or encrypted engine while tiny stems/heads stay FP-exact, with a
//! weight-count threshold and explicit per-layer overrides
//! (`serve::Registry` reports each entry's per-layer modes and resident
//! bytes).

pub mod binarize;
pub mod encrypted;
pub mod gemm;
pub mod plane;
pub mod popcount;

pub use binarize::{BinarizedActs, DEFAULT_ACT_PLANES, MAX_ACT_PLANES};
pub use encrypted::{
    conv2d_encrypted, dense_encrypted, xnor_gemm_encrypted_into,
    xnor_gemm_encrypted_into_with_kernel, EncryptedStore,
};
pub use gemm::{
    conv2d_bitplane, dense_bitplane, popcount_dot, xnor_gemm_into,
    xnor_gemm_into_with_kernel,
};
pub use plane::PlaneStore;
pub use popcount::Kernel;

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// Which compute engine a quantized layer (or whole model) runs on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ComputeMode {
    /// Decrypt once at load, materialize dense `Σ α_p b_p` f32 weights,
    /// run the packed-FP fused engine (§7). Exact.
    DenseF32,
    /// Keep quantized layers as packed bit-planes and run the
    /// XNOR/popcount engine over activations binarized into
    /// `act_planes` sign/scale planes per im2col row. Exact when every
    /// row is representable in ≤ `act_planes` planes (e.g. ±1 inputs),
    /// an approximation otherwise — see DESIGN.md §8.
    BitPlane {
        /// Activation sign/scale planes per row (1..=[`MAX_ACT_PLANES`]).
        act_planes: usize,
    },
    /// Keep quantized layers **encrypted** resident (sub-1-bit/weight)
    /// and decrypt NR-channel panels on demand inside the XNOR GEMM
    /// tile loop ([`EncryptedStore`], DESIGN.md §11). Forward outputs
    /// are bit-identical to [`ComputeMode::BitPlane`] at the same
    /// `act_planes`; only residency and per-forward decrypt cost
    /// differ.
    Encrypted {
        /// Activation sign/scale planes per row (1..=[`MAX_ACT_PLANES`]).
        act_planes: usize,
    },
}

impl ComputeMode {
    /// BitPlane with the serving default of [`DEFAULT_ACT_PLANES`].
    pub fn bit_plane() -> ComputeMode {
        ComputeMode::BitPlane { act_planes: DEFAULT_ACT_PLANES }
    }

    /// Encrypted with the serving default of [`DEFAULT_ACT_PLANES`].
    pub fn encrypted() -> ComputeMode {
        ComputeMode::Encrypted { act_planes: DEFAULT_ACT_PLANES }
    }

    /// Parse `dense` / `bitplane[:<m>]` / `encrypted[:<m>]` (CLI flags
    /// and the `FLEXOR_COMPUTE` env var). For the per-layer policy
    /// grammar see [`ModePolicy::parse`].
    ///
    /// # Examples
    ///
    /// ```
    /// use flexor::inference::ComputeMode;
    ///
    /// assert_eq!(ComputeMode::parse("dense").unwrap(), ComputeMode::DenseF32);
    /// assert_eq!(
    ///     ComputeMode::parse("bitplane:16").unwrap(),
    ///     ComputeMode::BitPlane { act_planes: 16 }
    /// );
    /// assert_eq!(
    ///     ComputeMode::parse("encrypted:4").unwrap(),
    ///     ComputeMode::Encrypted { act_planes: 4 }
    /// );
    /// assert!(ComputeMode::parse("quantum").is_err());
    /// ```
    pub fn parse(s: &str) -> Result<ComputeMode> {
        fn act_planes(m: &str) -> Result<usize> {
            match m.parse::<usize>() {
                Ok(m) if (1..=MAX_ACT_PLANES).contains(&m) => Ok(m),
                _ => bail!("bad act-plane count {m:?} (want 1..={MAX_ACT_PLANES})"),
            }
        }
        let t = s.trim().to_ascii_lowercase();
        match t.as_str() {
            "dense" | "densef32" | "fp32" => Ok(ComputeMode::DenseF32),
            "bitplane" | "bit-plane" | "xnor" => Ok(ComputeMode::bit_plane()),
            "encrypted" | "enc" => Ok(ComputeMode::encrypted()),
            other => {
                if let Some(m) = other.strip_prefix("bitplane:") {
                    Ok(ComputeMode::BitPlane { act_planes: act_planes(m)? })
                } else if let Some(m) = other.strip_prefix("encrypted:") {
                    Ok(ComputeMode::Encrypted { act_planes: act_planes(m)? })
                } else {
                    bail!(
                        "unknown compute mode {s:?} \
                         (want dense | bitplane[:<m>] | encrypted[:<m>])"
                    )
                }
            }
        }
    }

    /// The process default: `FLEXOR_COMPUTE` when set, else DenseF32.
    /// (Policy-aware callers use [`ModePolicy::default_from_env`].)
    pub fn default_from_env() -> Result<ComputeMode> {
        match std::env::var("FLEXOR_COMPUTE") {
            Ok(v) if !v.trim().is_empty() => ComputeMode::parse(&v),
            _ => Ok(ComputeMode::DenseF32),
        }
    }

    /// Short name for `/models` JSON and log lines.
    pub fn label(&self) -> &'static str {
        match self {
            ComputeMode::DenseF32 => "dense",
            ComputeMode::BitPlane { .. } => "bitplane",
            ComputeMode::Encrypted { .. } => "encrypted",
        }
    }

    /// Activation planes when in a binarized (BitPlane/Encrypted) mode.
    pub fn act_planes(&self) -> Option<usize> {
        match *self {
            ComputeMode::DenseF32 => None,
            ComputeMode::BitPlane { act_planes }
            | ComputeMode::Encrypted { act_planes } => Some(act_planes),
        }
    }

    pub fn is_bit_plane(&self) -> bool {
        matches!(self, ComputeMode::BitPlane { .. })
    }

    pub fn is_encrypted(&self) -> bool {
        matches!(self, ComputeMode::Encrypted { .. })
    }
}

impl Default for ComputeMode {
    fn default() -> Self {
        ComputeMode::DenseF32
    }
}

/// Per-layer compute-mode policy: a base engine, a weight-count
/// threshold under which layers fall back to DenseF32 (tiny stems,
/// shortcut convs and heads are cheap in FP and most accuracy-sensitive
/// per weight), and explicit per-layer overrides that always win.
///
/// Uniform policies (`ModePolicy::uniform(mode)`) reproduce the old
/// whole-model `ComputeMode` behavior exactly.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ModePolicy {
    /// Engine for layers without an override at/above the threshold.
    pub base: ComputeMode,
    /// Quantized layers with fewer weights than this run DenseF32 even
    /// when `base` is BitPlane or Encrypted (0 = no threshold).
    pub dense_below: usize,
    /// Explicit per-layer engine overrides, by quantized-layer index.
    pub overrides: BTreeMap<usize, ComputeMode>,
}

impl ModePolicy {
    /// Every quantized layer on `mode` — the whole-model behavior.
    pub fn uniform(mode: ComputeMode) -> ModePolicy {
        ModePolicy { base: mode, dense_below: 0, overrides: BTreeMap::new() }
    }

    /// The engine quantized layer `idx` (with `n_weights` weights) runs
    /// on under this policy.
    pub fn mode_for(&self, idx: usize, n_weights: usize) -> ComputeMode {
        if let Some(m) = self.overrides.get(&idx) {
            return *m;
        }
        match self.base {
            ComputeMode::BitPlane { .. } | ComputeMode::Encrypted { .. }
                if n_weights < self.dense_below =>
            {
                ComputeMode::DenseF32
            }
            m => m,
        }
    }

    /// No threshold and no overrides — layers all follow `base`.
    pub fn is_uniform(&self) -> bool {
        self.dense_below == 0 && self.overrides.is_empty()
    }

    /// Parse the policy grammar
    /// `<mode>[@min=<weights>][,<idx>=<mode>]*` — a plain
    /// [`ComputeMode`] string is a uniform policy, `@min=` sets the
    /// DenseF32 fallback threshold, and `,<idx>=<mode>` pins single
    /// layers (CLI flags and the `FLEXOR_COMPUTE` env var).
    ///
    /// # Examples
    ///
    /// ```
    /// use flexor::inference::{ComputeMode, ModePolicy};
    ///
    /// let p = ModePolicy::parse("bitplane:16@min=4096,0=dense").unwrap();
    /// assert_eq!(p.base, ComputeMode::BitPlane { act_planes: 16 });
    /// // layer 0 pinned dense, small layers fall back, big ones ride bitplane
    /// assert_eq!(p.mode_for(0, 100_000), ComputeMode::DenseF32);
    /// assert_eq!(p.mode_for(1, 1024), ComputeMode::DenseF32);
    /// assert!(p.mode_for(1, 8192).is_bit_plane());
    /// ```
    pub fn parse(s: &str) -> Result<ModePolicy> {
        let mut segs = s.split(',');
        let head = segs.next().context("empty compute-mode policy")?;
        let (mode_str, opt) = match head.split_once('@') {
            Some((m, o)) => (m, Some(o)),
            None => (head, None),
        };
        let base = ComputeMode::parse(mode_str)?;
        let mut dense_below = 0usize;
        if let Some(o) = opt {
            let o = o.trim();
            if let Some(v) = o.strip_prefix("min=") {
                dense_below = v
                    .trim()
                    .parse()
                    .map_err(|_| anyhow::anyhow!("bad threshold in {o:?} (want min=<weights>)"))?;
            } else {
                bail!("unknown policy option {o:?} (want min=<weights>)");
            }
        }
        let mut overrides = BTreeMap::new();
        for seg in segs {
            let (idx, m) = seg.split_once('=').with_context(|| {
                format!("bad layer override {seg:?} (want <idx>=<mode>)")
            })?;
            let idx: usize = idx
                .trim()
                .parse()
                .map_err(|_| anyhow::anyhow!("bad layer index in {seg:?}"))?;
            overrides.insert(idx, ComputeMode::parse(m)?);
        }
        Ok(ModePolicy { base, dense_below, overrides })
    }

    /// The process default policy: `FLEXOR_COMPUTE` (full policy
    /// grammar) when set, else uniform DenseF32.
    pub fn default_from_env() -> Result<ModePolicy> {
        match std::env::var("FLEXOR_COMPUTE") {
            Ok(v) if !v.trim().is_empty() => ModePolicy::parse(&v),
            _ => Ok(ModePolicy::uniform(ComputeMode::DenseF32)),
        }
    }
}

impl Default for ModePolicy {
    fn default() -> Self {
        ModePolicy::uniform(ComputeMode::DenseF32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_modes() {
        assert_eq!(ComputeMode::parse("dense").unwrap(), ComputeMode::DenseF32);
        assert_eq!(ComputeMode::parse(" FP32 ").unwrap(), ComputeMode::DenseF32);
        assert_eq!(
            ComputeMode::parse("bitplane").unwrap(),
            ComputeMode::BitPlane { act_planes: DEFAULT_ACT_PLANES }
        );
        assert_eq!(
            ComputeMode::parse("bitplane:16").unwrap(),
            ComputeMode::BitPlane { act_planes: 16 }
        );
        assert_eq!(
            ComputeMode::parse("encrypted").unwrap(),
            ComputeMode::Encrypted { act_planes: DEFAULT_ACT_PLANES }
        );
        assert_eq!(
            ComputeMode::parse(" Encrypted:3 ").unwrap(),
            ComputeMode::Encrypted { act_planes: 3 }
        );
        assert!(ComputeMode::parse("bitplane:0").is_err());
        assert!(ComputeMode::parse("bitplane:999").is_err());
        assert!(ComputeMode::parse("encrypted:0").is_err());
        assert!(ComputeMode::parse("encrypted:999").is_err());
        assert!(ComputeMode::parse("quantum").is_err());
    }

    #[test]
    fn labels_and_accessors() {
        assert_eq!(ComputeMode::DenseF32.label(), "dense");
        assert_eq!(ComputeMode::bit_plane().label(), "bitplane");
        assert_eq!(ComputeMode::encrypted().label(), "encrypted");
        assert_eq!(ComputeMode::DenseF32.act_planes(), None);
        assert_eq!(ComputeMode::bit_plane().act_planes(), Some(DEFAULT_ACT_PLANES));
        assert_eq!(ComputeMode::encrypted().act_planes(), Some(DEFAULT_ACT_PLANES));
        assert!(ComputeMode::bit_plane().is_bit_plane());
        assert!(!ComputeMode::default().is_bit_plane());
        assert!(ComputeMode::encrypted().is_encrypted());
        assert!(!ComputeMode::encrypted().is_bit_plane());
        assert!(!ComputeMode::bit_plane().is_encrypted());
    }

    #[test]
    fn parse_policies() {
        let p = ModePolicy::parse("bitplane").unwrap();
        assert!(p.is_uniform());
        assert_eq!(p.base, ComputeMode::bit_plane());

        let p = ModePolicy::parse("bitplane:4@min=1000").unwrap();
        assert_eq!(p.dense_below, 1000);
        assert_eq!(p.mode_for(3, 999), ComputeMode::DenseF32);
        assert_eq!(p.mode_for(3, 1000), ComputeMode::BitPlane { act_planes: 4 });

        let p = ModePolicy::parse("dense,2=bitplane:6").unwrap();
        assert_eq!(p.mode_for(0, 50), ComputeMode::DenseF32);
        assert_eq!(p.mode_for(2, 50), ComputeMode::BitPlane { act_planes: 6 });

        // overrides beat the threshold in both directions
        let p = ModePolicy::parse("bitplane@min=100,0=dense,1=bitplane:2").unwrap();
        assert_eq!(p.mode_for(0, 1_000_000), ComputeMode::DenseF32);
        assert_eq!(p.mode_for(1, 10), ComputeMode::BitPlane { act_planes: 2 });
        assert!(!p.is_uniform());

        // encrypted base: same threshold + override semantics as bitplane
        let p = ModePolicy::parse("encrypted:4@min=1000,1=bitplane").unwrap();
        assert_eq!(p.base, ComputeMode::Encrypted { act_planes: 4 });
        assert_eq!(p.mode_for(0, 999), ComputeMode::DenseF32);
        assert_eq!(p.mode_for(0, 1000), ComputeMode::Encrypted { act_planes: 4 });
        assert_eq!(p.mode_for(1, 10), ComputeMode::bit_plane());

        assert!(ModePolicy::parse("bitplane@max=4").is_err());
        assert!(ModePolicy::parse("bitplane@min=abc").is_err());
        assert!(ModePolicy::parse("bitplane,3").is_err());
        assert!(ModePolicy::parse("bitplane,x=dense").is_err());
        assert!(ModePolicy::parse("bitplane,3=warp").is_err());
    }

    #[test]
    fn uniform_policy_reproduces_compute_mode() {
        let p = ModePolicy::uniform(ComputeMode::bit_plane());
        for (idx, w) in [(0usize, 1usize), (7, 1_000_000)] {
            assert_eq!(p.mode_for(idx, w), ComputeMode::bit_plane());
        }
        assert_eq!(ModePolicy::default().base, ComputeMode::DenseF32);
    }
}
