//! [`EncryptedStore`] — decrypt-on-demand serving (DESIGN.md §11): the
//! quantized GEMM right-hand side stays **encrypted** for its entire
//! lifetime, realizing the paper's sub-1-bit storage claim at inference
//! time instead of only on disk.
//!
//! The BitPlane engine (§8/§9) already avoids dense FP weights, but its
//! resident [`PlaneStore`] holds `q` **decrypted** bit-planes — ≥ q
//! bits/weight, above the fractional `q·N_in/N_out` the `.fxr` container
//! stores. This engine keeps exactly the container's payload resident
//! (encrypted column words + the XOR-gate network `M⊕` + α) and fuses
//! the [`Decryptor`] into the XNOR GEMM tile loop:
//!
//! * per pool shard, the NR-channel panel loop runs **outermost**;
//! * each panel of each bit-plane is decrypted once per (shard, panel)
//!   into a per-thread scratch tile
//!   ([`Decryptor::decrypt_panel_into`] → the interleaved
//!   [`PlaneStore`] panel layout), recycled through the
//!   [`scratch`](crate::inference::gemm::scratch) arena;
//! * the existing [`panel_dot`](super::popcount::panel_dot) kernels run
//!   over the scratch panel exactly as they do over a resident one, and
//!   the tile is discarded when the shard moves on.
//!
//! Determinism: every output element still accumulates in the fixed
//! (weight-plane `p` outer, activation-plane `m` inner, word-ascending)
//! order over bit-identical decrypted panels, and elements are
//! independent of tile *visit* order — so encrypted-mode forwards are
//! **bit-identical** to BitPlane forwards at the same `act_planes`,
//! across thread counts and across popcount kernels
//! (`rust/tests/engines.rs` pins the whole matrix).

use anyhow::{ensure, Result};

use crate::flexor::bitpack::ColumnBits;
use crate::flexor::fxr;
use crate::flexor::matrix::MXor;
use crate::flexor::{num_slices, Decryptor};
use crate::substrate::fault;
use crate::substrate::pool::ThreadPool;
use crate::substrate::trace;

use super::super::gemm::{self, scratch, Epilogue, MR, NR, ROWS_PER_SHARD};
use super::super::tensor::{self, Tensor};
use super::binarize::{self, BinarizedActs};
use super::plane::PlaneStore;
use super::popcount::{self, Kernel};

/// One bit-plane kept encrypted: the decryptor (XOR-gate network +
/// parity), the per-channel α, and the packed encrypted column words —
/// byte-for-byte what the `.fxr` container ships.
struct EncryptedPlane {
    dec: Decryptor,
    alpha: Vec<f32>,
    enc: ColumnBits,
    /// FNV-1a fingerprint of the encrypted column words, taken at
    /// construction; re-checked before every GEMM so in-memory panel
    /// corruption is caught before it can silently skew an answer.
    fnv: u64,
}

/// FNV-1a over a plane's packed column words in column order. The
/// optional `xor_first` mask flips bits of the very first word as seen
/// by the *hasher only* — the fault-injection hook for simulating
/// memory corruption without touching the real panel.
fn plane_fingerprint(enc: &ColumnBits, xor_first: u64) -> u64 {
    let mut h = fxr::Fnv64::new();
    let mut first = true;
    for j in 0..enc.width() {
        for &w in enc.column(j).words() {
            h.write_u64(if first { w ^ xor_first } else { w });
            first = false;
        }
    }
    h.finish()
}

/// A quantized layer whose weights stay encrypted while serving; panels
/// are decrypted on demand inside the GEMM tile loop and never stored.
pub struct EncryptedStore {
    /// Original weight tensor dims (HWIO for conv, `(in, out)` for dense).
    shape: Vec<usize>,
    k: usize,
    n: usize,
    /// Words per channel row: `⌈k/64⌉`.
    wpr: usize,
    n_weights: usize,
    planes: Vec<EncryptedPlane>,
}

impl EncryptedStore {
    /// Build from raw per-plane parts (M⊕, α, encrypted columns) —
    /// everything [`EncryptedStore::decrypt_panel_tile`] relies on is
    /// validated here, so the hot loop never sees a malformed layer.
    pub fn from_parts(
        shape: &[usize],
        planes: Vec<(MXor, Vec<f32>, ColumnBits)>,
    ) -> Result<EncryptedStore> {
        ensure!(!shape.is_empty(), "empty weight shape");
        ensure!(!planes.is_empty(), "no encrypted planes");
        let n = *shape.last().unwrap();
        let total: usize = shape.iter().product();
        ensure!(n > 0 && total % n == 0, "bad weight shape {shape:?}");
        let k = total / n;
        let mut packed = Vec::with_capacity(planes.len());
        for (pi, (mxor, alpha, enc)) in planes.into_iter().enumerate() {
            ensure!(alpha.len() == n, "plane {pi}: alpha len != n {n}");
            ensure!(
                enc.width() == mxor.n_in(),
                "plane {pi}: encrypted width {} != N_in {}",
                enc.width(),
                mxor.n_in()
            );
            ensure!(
                total <= enc.slices() * mxor.n_out(),
                "plane {pi}: {} weights exceed {} decrypted bits",
                total,
                enc.slices() * mxor.n_out()
            );
            let fnv = plane_fingerprint(&enc, 0);
            packed.push(EncryptedPlane { dec: Decryptor::new(mxor), alpha, enc, fnv });
        }
        Ok(EncryptedStore {
            shape: shape.to_vec(),
            k,
            n,
            wpr: k.div_ceil(64),
            n_weights: total,
            planes: packed,
        })
    }

    /// Build straight from a `.fxr` container layer — the load path.
    pub fn from_layer(shape: &[usize], layer: &fxr::Layer) -> Result<EncryptedStore> {
        ensure!(
            shape.iter().product::<usize>() == layer.n_weights,
            "shape {shape:?} != n_weights {}",
            layer.n_weights
        );
        ensure!(
            *shape.last().unwrap_or(&0) == layer.c_out,
            "shape {shape:?} last axis != c_out {}",
            layer.c_out
        );
        EncryptedStore::from_parts(
            shape,
            layer
                .planes
                .iter()
                .map(|p| (p.mxor.clone(), p.alpha.clone(), p.enc.clone()))
                .collect(),
        )
    }

    /// Reduction length (rows of the GEMM right-hand side).
    pub fn k(&self) -> usize {
        self.k
    }

    /// Output channels (columns of the GEMM right-hand side).
    pub fn n(&self) -> usize {
        self.n
    }

    /// Bit planes (the paper's q).
    pub fn q(&self) -> usize {
        self.planes.len()
    }

    /// Words per channel bit row.
    pub fn words_per_row(&self) -> usize {
        self.wpr
    }

    /// Channel panels per plane: `⌈n/NR⌉`.
    pub fn num_panels(&self) -> usize {
        self.n.div_ceil(NR)
    }

    /// Words one decrypted panel occupies: `wpr · NR`.
    pub fn panel_words(&self) -> usize {
        self.wpr * NR
    }

    /// Words the per-shard scratch tile needs: one panel per plane.
    pub fn tile_words(&self) -> usize {
        self.q() * self.panel_words()
    }

    /// Original weight tensor dims.
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// `(kh, kw, ci)` when this is a conv weight (rank-4 HWIO shape).
    pub fn conv_geometry(&self) -> Option<(usize, usize, usize)> {
        if self.shape.len() == 4 {
            Some((self.shape[0], self.shape[1], self.shape[2]))
        } else {
            None
        }
    }

    /// Plane `p`'s per-channel α.
    #[inline]
    pub fn alpha(&self, p: usize) -> &[f32] {
        &self.planes[p].alpha
    }

    /// Decrypt the NR-channel panel at column `j0` of **every** plane
    /// into `tile` (plane `p`'s panel at `tile[p·panel_words()..]`, the
    /// interleaved [`PlaneStore`] layout `panel[w·NR + jj]`). `tile` may
    /// be dirty — each panel is fully overwritten, padding slots zeroed.
    ///
    /// Inputs are validated at construction, so this cannot fail on a
    /// well-formed store (the GEMM shard loop relies on that).
    #[inline]
    pub fn decrypt_panel_tile(&self, j0: usize, tile: &mut [u64]) {
        debug_assert!(j0 < self.n && j0 % NR == 0);
        let pw = self.panel_words();
        debug_assert_eq!(tile.len(), self.q() * pw);
        let j1 = (j0 + NR).min(self.n);
        for (p, plane) in self.planes.iter().enumerate() {
            plane
                .dec
                .decrypt_panel_into(
                    &plane.enc,
                    self.n_weights,
                    self.n,
                    j0..j1,
                    NR,
                    &mut tile[p * pw..(p + 1) * pw],
                )
                .expect("encrypted panel geometry validated at construction");
        }
    }

    /// Decrypt everything into a resident [`PlaneStore`] — oracle /
    /// reference use only (the serving path never materializes this).
    pub fn to_plane_store(&self) -> Result<PlaneStore> {
        let mut decrypted = Vec::with_capacity(self.planes.len());
        for plane in &self.planes {
            let rows =
                plane
                    .dec
                    .decrypt_to_plane_rows(&plane.enc, self.n_weights, self.n)?;
            decrypted.push((rows, plane.alpha.clone()));
        }
        PlaneStore::from_decrypted(&self.shape, decrypted)
    }

    /// Re-fingerprint every plane's encrypted words against the hash
    /// taken at construction (DESIGN.md §12). `fault::flip_word_mask()`
    /// feeds the hasher a flipped first word when the `flip_word` fault
    /// is armed, so the chaos harness can exercise this path without
    /// corrupting shared state. Runs before every encrypted GEMM.
    pub fn verify_integrity(&self) -> std::result::Result<(), String> {
        for (p, plane) in self.planes.iter().enumerate() {
            let computed = plane_fingerprint(&plane.enc, fault::flip_word_mask());
            if computed != plane.fnv {
                return Err(format!(
                    "integrity: encrypted plane {p} fnv64 mismatch \
                     (expected {:#018x}, computed {computed:#018x}) — \
                     refusing to serve corrupt panels",
                    plane.fnv
                ));
            }
        }
        Ok(())
    }

    /// Bytes this layer keeps resident in Encrypted mode: the packed
    /// encrypted column words **plus the XOR-gate network and scale
    /// parameters themselves** — `M⊕` row masks (4 B each), the derived
    /// parity bits, and the per-channel α. Nothing decrypted is counted
    /// because nothing decrypted is resident.
    pub fn resident_bytes(&self) -> usize {
        self.planes
            .iter()
            .map(|p| {
                let enc_words = p.enc.width() * p.enc.slices().div_ceil(64);
                let n_out = p.dec.mxor().n_out();
                enc_words * 8 // encrypted columns
                    + n_out * 4 // M⊕ row masks (u32 each)
                    + n_out // parity complement bits (bool each)
                    + p.alpha.len() * 4 // α scales
            })
            .sum()
    }
}

/// `C = epilogue(Â · W)` with W decrypted panel-by-panel on demand, on
/// the process-wide popcount kernel.
pub fn xnor_gemm_encrypted_into(
    pool: &ThreadPool,
    acts: &BinarizedActs,
    w: &EncryptedStore,
    epi: Epilogue<'_>,
    c: &mut [f32],
) {
    xnor_gemm_encrypted_into_with_kernel(pool, acts, w, popcount::active(), epi, c)
}

/// [`xnor_gemm_encrypted_into`] with an explicit popcount kernel.
///
/// Same sharding (`ROWS_PER_SHARD` rows of C per shard) and same
/// per-element accumulation order as the resident-plane GEMM
/// ([`super::gemm::xnor_gemm_into_with_kernel`]); the only structural
/// difference is the panel loop hoisted outermost so each panel is
/// decrypted **once per shard** into the arena tile, not once per row
/// tile. Output elements are independent of tile visit order, so the
/// result is bit-identical to the BitPlane engine's.
pub fn xnor_gemm_encrypted_into_with_kernel(
    pool: &ThreadPool,
    acts: &BinarizedActs,
    w: &EncryptedStore,
    kernel: Kernel,
    epi: Epilogue<'_>,
    c: &mut [f32],
) {
    // Integrity gate: a corrupted panel must panic (contained by the
    // serving worker's catch_unwind) rather than produce a wrong answer.
    if let Err(msg) = w.verify_integrity() {
        panic!("{msg}");
    }
    let k = w.k();
    let n = w.n();
    assert_eq!(acts.k(), k, "activation rows are length {}, W expects {k}", acts.k());
    assert_eq!(c.len(), acts.rows() * n, "C is {}x{n}", acts.rows());
    gemm::validate_epilogue(&epi, n, c.len());
    popcount::count_dispatch(kernel);
    let pw = w.panel_words();
    let _s = trace::span("xnor_gemm");
    pool.run_chunks_mut(c, ROWS_PER_SHARD * n, |_shard, start, c_part| {
        let i0 = start / n;
        let prows = c_part.len() / n;
        scratch::with(|arena| {
            let mut tile = arena.take_u64(w.tile_words());
            for j0 in (0..n).step_by(NR) {
                let jw = (n - j0).min(NR);
                w.decrypt_panel_tile(j0, &mut tile);
                for t0 in (0..prows).step_by(MR) {
                    let mh = (prows - t0).min(MR);
                    let mut acc = [[0.0f32; NR]; MR];
                    for (r, acc_row) in acc.iter_mut().enumerate().take(mh) {
                        let i = i0 + t0 + r;
                        for p in 0..w.q() {
                            let alpha = &w.alpha(p)[j0..j0 + jw];
                            let panel = &tile[p * pw..(p + 1) * pw];
                            for m in 0..acts.planes() {
                                let beta = acts.scale(i, m);
                                if beta == 0.0 {
                                    continue;
                                }
                                let dots = popcount::panel_dot(
                                    kernel,
                                    acts.row_bits(i, m),
                                    panel,
                                    k,
                                );
                                for (jj, av) in
                                    acc_row.iter_mut().enumerate().take(jw)
                                {
                                    *av += beta * alpha[jj] * dots[jj] as f32;
                                }
                            }
                        }
                    }
                    gemm::store_tile(&acc, c_part, t0, i0, mh, j0, n, &epi);
                }
            }
            arena.give_u64(tile);
        });
    });
}

/// Fused `conv2d → epilogue` on the encrypted engine: im2col + binarize
/// exactly as the bit-plane path, then the decrypt-on-demand GEMM.
pub fn conv2d_encrypted(
    pool: &ThreadPool,
    x: &Tensor,
    w: &EncryptedStore,
    stride: usize,
    act_planes: usize,
    epi: Epilogue<'_>,
) -> Tensor {
    let (kh, kw, ci) = w
        .conv_geometry()
        .expect("conv2d_encrypted needs a rank-4 HWIO encrypted store");
    assert_eq!(x.rank(), 4, "conv input must be NHWC");
    assert_eq!(x.dims[3], ci, "channel mismatch");
    let n_im = x.dims[0];
    let dims = (n_im, x.dims[1], x.dims[2], ci);
    let (ho, wo, _, _) =
        tensor::conv_out_geometry((x.dims[1], x.dims[2]), (kh, kw), stride);
    let k = kh * kw * ci;
    debug_assert_eq!(w.k(), k);
    let rows = n_im * ho * wo;
    let mut col = scratch::take(rows * k);
    {
        let _s = trace::span("im2col");
        pool.run_chunks_mut(&mut col, ROWS_PER_SHARD * k, |_shard, start, part| {
            tensor::im2col_rows(&x.data, dims, (kh, kw), stride, start / k, part);
        });
    }
    let acts = {
        let _s = trace::span("binarize");
        binarize::binarize_rows(pool, &col, rows, k, act_planes)
    };
    scratch::give(col);
    let mut out = scratch::take(rows * w.n());
    xnor_gemm_encrypted_into(pool, &acts, w, epi, &mut out);
    acts.recycle();
    Tensor::new(vec![n_im, ho, wo, w.n()], out)
}

/// Fused `dense → epilogue` on the encrypted engine.
pub fn dense_encrypted(
    pool: &ThreadPool,
    x: &Tensor,
    w: &EncryptedStore,
    act_planes: usize,
    epi: Epilogue<'_>,
) -> Tensor {
    assert_eq!(x.rank(), 2, "dense input must be (N, In)");
    assert_eq!(x.dims[1], w.k(), "dense in-features mismatch");
    let acts = {
        let _s = trace::span("binarize");
        binarize::binarize_rows(pool, &x.data, x.dims[0], x.dims[1], act_planes)
    };
    let mut out = scratch::take(x.dims[0] * w.n());
    xnor_gemm_encrypted_into(pool, &acts, w, epi, &mut out);
    acts.recycle();
    Tensor::new(vec![x.dims[0], w.n()], out)
}

// ---- reference path (oracle) ------------------------------------------------

/// Reference conv for Encrypted mode: decrypt everything up front (the
/// one thing serving never does) and run the bit-plane reference —
/// identical binarization contract, dense math.
pub fn conv2d_encrypted_reference(
    x: &Tensor,
    w: &EncryptedStore,
    stride: usize,
    act_planes: usize,
) -> Tensor {
    let store = w.to_plane_store().expect("validated at construction");
    super::gemm::conv2d_bitplane_reference(x, &store, stride, act_planes)
}

/// Reference dense for Encrypted mode (see [`conv2d_encrypted_reference`]).
pub fn dense_encrypted_reference(
    x: &Tensor,
    w: &EncryptedStore,
    act_planes: usize,
) -> Tensor {
    let store = w.to_plane_store().expect("validated at construction");
    super::gemm::dense_bitplane_reference(x, &store, act_planes)
}

#[cfg(test)]
mod tests {
    use super::super::gemm::xnor_gemm_into_with_kernel;
    use super::*;
    use crate::substrate::prng::Pcg32;
    use crate::substrate::ptest::check_msg;

    /// Random encrypted fixture: q planes of (M⊕, α, encrypted columns)
    /// for a (k × n) weight, mirroring the `.fxr` layer geometry.
    fn rand_store(
        rng: &mut Pcg32,
        shape: &[usize],
        q: usize,
        n_in: usize,
        n_out: usize,
    ) -> EncryptedStore {
        let n = *shape.last().unwrap();
        let total: usize = shape.iter().product();
        let slices = num_slices(total, n_out);
        let planes = (0..q)
            .map(|_| {
                let mxor = MXor::with_ntap(n_out, n_in, 2, rng).unwrap();
                let alpha: Vec<f32> = (0..n).map(|_| rng.range_f32(0.05, 0.5)).collect();
                let bits: Vec<u8> =
                    (0..slices * n_in).map(|_| rng.bernoulli(0.5) as u8).collect();
                let enc = ColumnBits::from_row_major(&bits, n_in).unwrap();
                (mxor, alpha, enc)
            })
            .collect();
        EncryptedStore::from_parts(shape, planes).unwrap()
    }

    /// Tentpole property: the decrypt-on-demand GEMM is bit-identical to
    /// the resident bit-plane GEMM over the same decrypted content,
    /// across 1/2/4 threads and every supported popcount kernel —
    /// including ragged channel tails (n not divisible by NR) and k
    /// straddling word boundaries.
    #[test]
    fn encrypted_gemm_bit_identical_to_bitplane() {
        let pools = [ThreadPool::new(1), ThreadPool::new(2), ThreadPool::new(4)];
        let kernels = popcount::available();
        check_msg("encrypted gemm == bitplane gemm (bits)", 12, |g| {
            let rows = g.usize_in(1, 80);
            let k = g.usize_in(1, 140);
            let n = g.usize_in(1, 21);
            let q = 1 + g.usize_in(0, 2);
            let m = 1 + g.usize_in(0, 4);
            let n_in = 4 + g.usize_in(0, 6);
            let n_out = n_in + g.usize_in(1, 6);
            let a: Vec<f32> = (0..rows * k).map(|_| g.normal()).collect();
            let store = rand_store(g.rng(), &[k, n], q, n_in, n_out);
            let resident = store.to_plane_store().map_err(|e| e.to_string())?;

            let mut first: Option<Vec<f32>> = None;
            for pool in &pools {
                let acts = binarize::binarize_rows(pool, &a, rows, k, m);
                for kern in &kernels {
                    let mut want = vec![0.0f32; rows * n];
                    xnor_gemm_into_with_kernel(
                        pool,
                        &acts,
                        &resident,
                        *kern,
                        Epilogue::None,
                        &mut want,
                    );
                    let mut got = vec![0.0f32; rows * n];
                    xnor_gemm_encrypted_into_with_kernel(
                        pool,
                        &acts,
                        &store,
                        *kern,
                        Epilogue::None,
                        &mut got,
                    );
                    if got != want {
                        return Err(format!(
                            "threads={} kernel={} ({rows}x{k}x{n} q={q} m={m}): \
                             encrypted != bitplane",
                            pool.threads(),
                            kern.label()
                        ));
                    }
                    match &first {
                        None => first = Some(got),
                        Some(f) => {
                            if *f != got {
                                return Err(format!(
                                    "threads={} kernel={} changed the bits",
                                    pool.threads(),
                                    kern.label()
                                ));
                            }
                        }
                    }
                }
                acts.recycle();
            }
            Ok(())
        });
    }

    /// Fused conv on the encrypted engine ≡ the decrypt-up-front
    /// reference composition.
    #[test]
    fn conv_encrypted_matches_reference() {
        let pool = ThreadPool::new(2);
        check_msg("encrypted conv == reference", 8, |g| {
            let n_im = g.usize_in(1, 3);
            let h = g.usize_in(2, 7);
            let wd = g.usize_in(2, 7);
            let ci = g.usize_in(1, 4);
            let co = g.usize_in(1, 7);
            let kk = [1usize, 3][g.usize_in(0, 2)];
            let stride = 1 + g.usize_in(0, 2);
            let m = 1 + g.usize_in(0, 5);
            let x = Tensor::new(
                vec![n_im, h, wd, ci],
                (0..n_im * h * wd * ci).map(|_| g.normal()).collect(),
            );
            let store = rand_store(g.rng(), &[kk, kk, ci, co], 1, 6, 10);
            let got = conv2d_encrypted(&pool, &x, &store, stride, m, Epilogue::None);
            let want = conv2d_encrypted_reference(&x, &store, stride, m);
            if got.dims != want.dims {
                return Err(format!("dims {:?} vs {:?}", got.dims, want.dims));
            }
            for (i, (a, b)) in got.data.iter().zip(&want.data).enumerate() {
                let ok = (a - b).abs() <= 1e-3 * (1.0 + b.abs());
                if !ok {
                    return Err(format!("elem {i}: {a} vs {b} (k={kk} s={stride} m={m})"));
                }
            }
            scratch::give(got.data);
            Ok(())
        });
    }

    /// Resident accounting counts the encrypted payload + XOR-network
    /// params, hand-computed: k=130, n=3, q=2, n_in=8, n_out=10 ⇒
    /// 390 weights → 39 slices → 1 word per column.
    #[test]
    fn resident_bytes_counts_encrypted_words_and_xor_network() {
        let mut rng = Pcg32::seeded(47);
        let store = rand_store(&mut rng, &[130, 3], 2, 8, 10);
        // per plane: 8 columns × ⌈39/64⌉=1 word × 8 B = 64 B encrypted,
        // + 10 row masks × 4 B + 10 parity bytes + 3 α × 4 B = 116 B
        assert_eq!(store.resident_bytes(), 2 * (64 + 40 + 10 + 12));
        // and strictly below the decrypted bit-plane residency
        let resident = store.to_plane_store().unwrap();
        assert!(store.resident_bytes() < resident.resident_bytes());
        assert_eq!((store.k(), store.n(), store.q()), (130, 3, 2));
        assert_eq!(store.words_per_row(), 3);
        assert_eq!(store.num_panels(), 1);
        assert_eq!(store.tile_words(), 2 * 3 * NR);
        assert!(store.conv_geometry().is_none());
    }

    /// A pristine store verifies; flipping one packed word in place is
    /// caught and named. (The `flip_word` fault hook exercises the same
    /// path end-to-end in `rust/tests/chaos.rs`.)
    #[test]
    fn integrity_check_catches_flipped_word() {
        let mut rng = Pcg32::seeded(59);
        let mut store = rand_store(&mut rng, &[130, 3], 2, 8, 10);
        store.verify_integrity().unwrap();
        store.planes[1].enc.column_mut(0).words_mut()[0] ^= 1 << 17;
        let err = store.verify_integrity().unwrap_err();
        assert!(err.contains("integrity"), "{err}");
        assert!(err.contains("plane 1"), "{err}");
        // restore and it verifies again
        store.planes[1].enc.column_mut(0).words_mut()[0] ^= 1 << 17;
        store.verify_integrity().unwrap();
    }

    #[test]
    fn validation() {
        let mut rng = Pcg32::seeded(53);
        assert!(EncryptedStore::from_parts(&[4, 2], vec![]).is_err());
        let mxor = MXor::with_ntap(10, 8, 2, &mut rng).unwrap();
        let bits: Vec<u8> = (0..13 * 8).map(|_| rng.bernoulli(0.5) as u8).collect();
        let enc = ColumnBits::from_row_major(&bits, 8).unwrap();
        // alpha length mismatch
        assert!(EncryptedStore::from_parts(
            &[65, 2],
            vec![(mxor.clone(), vec![1.0; 3], enc.clone())]
        )
        .is_err());
        // more weights than decrypted bits (13 slices × 10 = 130)
        assert!(EncryptedStore::from_parts(
            &[100, 2],
            vec![(mxor.clone(), vec![1.0; 2], enc.clone())]
        )
        .is_err());
        assert!(
            EncryptedStore::from_parts(&[65, 2], vec![(mxor, vec![1.0; 2], enc)])
                .is_ok()
        );
    }
}
