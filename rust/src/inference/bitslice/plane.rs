//! [`PlaneStore`] — a quantized GEMM right-hand side kept as packed
//! bit-planes for its entire serving lifetime (DESIGN.md §8/§9).
//!
//! A `(k × n)` weight matrix (`k` = reduction length, `n` = output
//! channels) is stored as `q` planes. Plane `p` holds the channels'
//! k-bit rows (LSB-first, bit = 1 ⇔ that ±1 weight bit is −1 — the
//! crate-wide convention) **panelized** for the SIMD popcount kernels:
//! channels are grouped into `⌈n/NR⌉` panels of
//! [`NR`](crate::inference::gemm::NR) channels, and inside a panel word
//! `w` of the NR channels sits interleaved (`panel[w·NR + jj]`) so one
//! activation word XORs against NR contiguous channel words — the exact
//! mirror of the packed-FP engine's [`PackedB`](crate::inference::gemm::PackedB)
//! column panels. Storage is 64-byte-aligned; with NR = 8 every
//! interleaved word-row is one cache line. Channels past `n` and bits
//! past `k` are zero, so XOR/popcount over whole words and panels is
//! exact. Per-channel scales `α_p` ride alongside; the dense f32 tensor
//! the DenseF32 engine materializes is never built.

use anyhow::{ensure, Result};

use super::super::gemm::NR;
use crate::flexor::bitpack::BitVec;

/// 64-byte-aligned block of 8 u64 words — one interleaved panel
/// word-row (NR = 8 channel words) per cache line.
#[repr(align(64))]
#[derive(Clone, Copy)]
struct AlignedWords([u64; 8]);

const _: () = assert!(NR == 8, "AlignedWords packs exactly one NR-wide word-row");

/// One bit-plane: panelized per-channel packed bit rows + α scales.
struct WeightPlane {
    /// `⌈n/NR⌉` panels × `wpr` word-rows × NR interleaved channel words
    /// (zero-padded past `n` and `k`). One `AlignedWords` block per
    /// word-row.
    buf: Vec<AlignedWords>,
    /// `alpha[j]` — the per-output-channel scale of this plane.
    alpha: Vec<f32>,
}

/// A quantized layer held as packed bit-plane panels (never dense f32).
pub struct PlaneStore {
    /// Original weight tensor dims (HWIO for conv, `(in, out)` for dense).
    shape: Vec<usize>,
    k: usize,
    n: usize,
    /// Words per channel row: `⌈k/64⌉`.
    wpr: usize,
    planes: Vec<WeightPlane>,
}

fn words(buf: &[AlignedWords]) -> &[u64] {
    // Safety: AlignedWords is exactly 8 u64s with stricter alignment.
    unsafe { std::slice::from_raw_parts(buf.as_ptr() as *const u64, buf.len() * 8) }
}

fn words_mut(buf: &mut [AlignedWords]) -> &mut [u64] {
    unsafe {
        std::slice::from_raw_parts_mut(buf.as_mut_ptr() as *mut u64, buf.len() * 8)
    }
}

impl PlaneStore {
    /// Build from decrypted per-output-channel bit rows — the output of
    /// [`crate::flexor::Decryptor::decrypt_to_plane_rows`] — plus each
    /// plane's α, repacking the rows into the panelized layout. `shape`
    /// is the weight tensor's dims (last axis = output channel).
    pub fn from_decrypted(
        shape: &[usize],
        planes: Vec<(Vec<BitVec>, Vec<f32>)>,
    ) -> Result<PlaneStore> {
        ensure!(!shape.is_empty(), "empty weight shape");
        ensure!(!planes.is_empty(), "no bit planes");
        let n = *shape.last().unwrap();
        let total: usize = shape.iter().product();
        ensure!(n > 0 && total % n == 0, "bad weight shape {shape:?}");
        let k = total / n;
        let wpr = k.div_ceil(64);
        let npanels = n.div_ceil(NR);
        let mut packed = Vec::with_capacity(planes.len());
        for (pi, (rows, alpha)) in planes.into_iter().enumerate() {
            ensure!(rows.len() == n, "plane {pi}: {} rows != n {n}", rows.len());
            ensure!(alpha.len() == n, "plane {pi}: alpha len != n {n}");
            let mut buf = vec![AlignedWords([0u64; 8]); npanels * wpr];
            {
                let dst = words_mut(&mut buf);
                for (j, row) in rows.iter().enumerate() {
                    ensure!(row.len() == k, "plane {pi} ch {j}: row len != k {k}");
                    let rw = row.words();
                    debug_assert_eq!(rw.len(), wpr);
                    // channel j lands in panel j/NR at interleave slot j%NR
                    let base = (j / NR) * wpr * NR + j % NR;
                    for (w, &word) in rw.iter().enumerate() {
                        dst[base + w * NR] = word;
                    }
                }
            }
            packed.push(WeightPlane { buf, alpha });
        }
        Ok(PlaneStore { shape: shape.to_vec(), k, n, wpr, planes: packed })
    }

    /// Build from row-major ±1 sign planes (`planes[p][t·n + j]`) — the
    /// fixture path for tests and benches (real loads come off the
    /// decryptor via [`PlaneStore::from_decrypted`]).
    pub fn from_sign_planes(
        shape: &[usize],
        planes: &[Vec<f32>],
        alpha: &[Vec<f32>],
    ) -> Result<PlaneStore> {
        ensure!(planes.len() == alpha.len(), "planes/alpha count mismatch");
        ensure!(!shape.is_empty(), "empty weight shape");
        let n = *shape.last().unwrap();
        let total: usize = shape.iter().product();
        ensure!(n > 0 && total % n == 0, "bad weight shape {shape:?}");
        let k = total / n;
        let mut decrypted = Vec::with_capacity(planes.len());
        for (p, a) in planes.iter().zip(alpha) {
            ensure!(p.len() == total, "plane size mismatch");
            let mut rows = Vec::with_capacity(n);
            for j in 0..n {
                let mut bv = BitVec::zeros(k);
                for t in 0..k {
                    if p[t * n + j] < 0.0 {
                        bv.set(t, true);
                    }
                }
                rows.push(bv);
            }
            decrypted.push((rows, a.clone()));
        }
        PlaneStore::from_decrypted(shape, decrypted)
    }

    /// Reduction length (rows of the GEMM right-hand side).
    pub fn k(&self) -> usize {
        self.k
    }

    /// Output channels (columns of the GEMM right-hand side).
    pub fn n(&self) -> usize {
        self.n
    }

    /// Bit planes (the paper's q).
    pub fn q(&self) -> usize {
        self.planes.len()
    }

    /// Words per channel bit row.
    pub fn words_per_row(&self) -> usize {
        self.wpr
    }

    /// Channel panels per plane: `⌈n/NR⌉`.
    pub fn num_panels(&self) -> usize {
        self.n.div_ceil(NR)
    }

    /// Original weight tensor dims.
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// `(kh, kw, ci)` when this is a conv weight (rank-4 HWIO shape).
    pub fn conv_geometry(&self) -> Option<(usize, usize, usize)> {
        if self.shape.len() == 4 {
            Some((self.shape[0], self.shape[1], self.shape[2]))
        } else {
            None
        }
    }

    /// Channel panel `cp` of plane `p`: `wpr` word-rows of NR
    /// interleaved channel words (`panel[w·NR + jj]` = word `w` of
    /// channel `cp·NR + jj`), the operand shape
    /// [`popcount::panel_dot`](super::popcount::panel_dot) consumes.
    #[inline]
    pub fn panel(&self, p: usize, cp: usize) -> &[u64] {
        let stride = self.wpr * NR;
        &words(&self.planes[p].buf)[cp * stride..(cp + 1) * stride]
    }

    /// Plane `p`'s per-channel α.
    #[inline]
    pub fn alpha(&self, p: usize) -> &[f32] {
        &self.planes[p].alpha
    }

    /// Materialize the dense `Σ α_p b_p` matrix (row-major `k × n`) —
    /// reference/oracle use only; the serving path never calls this.
    pub fn reconstruct_dense(&self) -> Vec<f32> {
        let mut w = vec![0.0f32; self.k * self.n];
        for pi in 0..self.planes.len() {
            for j in 0..self.n {
                let a = self.planes[pi].alpha[j];
                let pan = self.panel(pi, j / NR);
                let jj = j % NR;
                for t in 0..self.k {
                    let neg = (pan[(t / 64) * NR + jj] >> (t % 64)) & 1 == 1;
                    w[t * self.n + j] += if neg { -a } else { a };
                }
            }
        }
        w
    }

    /// Bytes this layer keeps resident in BitPlane mode (panelized bit
    /// rows + α). Panel padding (channels rounded up to NR) is counted —
    /// it is genuinely resident.
    pub fn resident_bytes(&self) -> usize {
        self.planes
            .iter()
            .map(|p| p.buf.len() * std::mem::size_of::<AlignedWords>() + p.alpha.len() * 4)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flexor::binarycodes;
    use crate::substrate::prng::Pcg32;

    #[test]
    fn reconstruct_matches_binarycodes() {
        let mut rng = Pcg32::seeded(41);
        // k straddles a word boundary, n straddles a panel boundary
        let (k, n, q) = (70, 11, 2);
        let planes: Vec<Vec<f32>> = (0..q)
            .map(|_| {
                (0..k * n)
                    .map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 })
                    .collect()
            })
            .collect();
        let alpha: Vec<Vec<f32>> = (0..q)
            .map(|_| (0..n).map(|_| rng.range_f32(0.1, 1.0)).collect())
            .collect();
        let store = PlaneStore::from_sign_planes(&[k, n], &planes, &alpha).unwrap();
        assert_eq!((store.k(), store.n(), store.q()), (k, n, q));
        assert_eq!(store.words_per_row(), 2);
        assert_eq!(store.num_panels(), 2);
        let want = binarycodes::reconstruct_dense(&planes, &alpha, n).unwrap();
        let got = store.reconstruct_dense();
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!((g - w).abs() < 1e-6, "elem {i}: {g} vs {w}");
        }
    }

    #[test]
    fn panel_layout_interleaves_channels() {
        // channel j all-negative ⇒ its interleave slot is all ones up to
        // k; other channels all-positive ⇒ zero words
        let (k, n) = (70, 10);
        let mut plane = vec![1.0f32; k * n];
        let j_neg = 8usize; // second panel, slot 0
        for t in 0..k {
            plane[t * n + j_neg] = -1.0;
        }
        let store =
            PlaneStore::from_sign_planes(&[k, n], &[plane], &[vec![1.0; n]]).unwrap();
        let p0 = store.panel(0, 0);
        assert!(p0.iter().all(|&w| w == 0), "panel 0 should be all +1");
        let p1 = store.panel(0, 1);
        for w in 0..store.words_per_row() {
            let row = &p1[w * NR..(w + 1) * NR];
            let want = if w == 0 { u64::MAX } else { (1u64 << (k - 64)) - 1 };
            assert_eq!(row[0], want, "word {w} of the all-negative channel");
            assert!(row[1..].iter().all(|&x| x == 0), "padding channels must be zero");
        }
    }

    #[test]
    fn resident_bytes_accounting() {
        let planes = vec![vec![1.0f32; 130 * 3]];
        let alpha = vec![vec![0.5f32; 3]];
        let store =
            PlaneStore::from_sign_planes(&[130, 3], &planes, &alpha).unwrap();
        // 1 panel × ⌈130/64⌉=3 word-rows × 64 B + 3 α × 4 B
        assert_eq!(store.resident_bytes(), 3 * 64 + 3 * 4);
        assert!(store.conv_geometry().is_none());
    }

    #[test]
    fn validation() {
        assert!(PlaneStore::from_sign_planes(&[4, 2], &[], &[]).is_err());
        assert!(
            PlaneStore::from_sign_planes(&[4, 2], &[vec![1.0; 8]], &[vec![1.0; 3]])
                .is_err()
        );
        assert!(
            PlaneStore::from_sign_planes(&[4, 2], &[vec![1.0; 7]], &[vec![1.0; 2]])
                .is_err()
        );
    }
}
