//! [`PlaneStore`] — a quantized GEMM right-hand side kept as packed
//! bit-planes for its entire serving lifetime (DESIGN.md §8).
//!
//! A `(k × n)` weight matrix (`k` = reduction length, `n` = output
//! channels) is stored as `q` planes; plane `p` holds one u64 bit row per
//! output channel (`k` bits, LSB-first, bit = 1 ⇔ that ±1 weight bit is
//! −1 — the crate-wide convention) plus the per-channel scale `α_p`.
//! Resident cost is `q·n·⌈k/64⌉` words + `q·n` floats — the dense f32
//! tensor the DenseF32 engine materializes is never built.

use anyhow::{ensure, Result};

use crate::flexor::bitpack::BitVec;

/// One bit-plane: per-output-channel packed bit rows + α scales.
struct WeightPlane {
    /// `bits[j·wpr .. (j+1)·wpr]` = channel `j`'s k-bit row (zero-padded
    /// past `k`, so XOR/popcount over whole words is exact).
    bits: Vec<u64>,
    /// `alpha[j]` — the per-output-channel scale of this plane.
    alpha: Vec<f32>,
}

/// A quantized layer held as packed bit-planes (never dense f32).
pub struct PlaneStore {
    /// Original weight tensor dims (HWIO for conv, `(in, out)` for dense).
    shape: Vec<usize>,
    k: usize,
    n: usize,
    /// Words per channel row: `⌈k/64⌉`.
    wpr: usize,
    planes: Vec<WeightPlane>,
}

impl PlaneStore {
    /// Build from decrypted per-output-channel bit rows — the output of
    /// [`crate::flexor::Decryptor::decrypt_to_plane_rows`] — plus each
    /// plane's α. `shape` is the weight tensor's dims (last axis = output
    /// channel).
    pub fn from_decrypted(
        shape: &[usize],
        planes: Vec<(Vec<BitVec>, Vec<f32>)>,
    ) -> Result<PlaneStore> {
        ensure!(!shape.is_empty(), "empty weight shape");
        ensure!(!planes.is_empty(), "no bit planes");
        let n = *shape.last().unwrap();
        let total: usize = shape.iter().product();
        ensure!(n > 0 && total % n == 0, "bad weight shape {shape:?}");
        let k = total / n;
        let wpr = k.div_ceil(64);
        let mut packed = Vec::with_capacity(planes.len());
        for (pi, (rows, alpha)) in planes.into_iter().enumerate() {
            ensure!(rows.len() == n, "plane {pi}: {} rows != n {n}", rows.len());
            ensure!(alpha.len() == n, "plane {pi}: alpha len != n {n}");
            let mut bits = Vec::with_capacity(n * wpr);
            for (j, row) in rows.iter().enumerate() {
                ensure!(row.len() == k, "plane {pi} ch {j}: row len != k {k}");
                debug_assert_eq!(row.words().len(), wpr);
                bits.extend_from_slice(row.words());
            }
            packed.push(WeightPlane { bits, alpha });
        }
        Ok(PlaneStore { shape: shape.to_vec(), k, n, wpr, planes: packed })
    }

    /// Build from row-major ±1 sign planes (`planes[p][t·n + j]`) — the
    /// fixture path for tests and benches (real loads come off the
    /// decryptor via [`PlaneStore::from_decrypted`]).
    pub fn from_sign_planes(
        shape: &[usize],
        planes: &[Vec<f32>],
        alpha: &[Vec<f32>],
    ) -> Result<PlaneStore> {
        ensure!(planes.len() == alpha.len(), "planes/alpha count mismatch");
        ensure!(!shape.is_empty(), "empty weight shape");
        let n = *shape.last().unwrap();
        let total: usize = shape.iter().product();
        ensure!(n > 0 && total % n == 0, "bad weight shape {shape:?}");
        let k = total / n;
        let mut decrypted = Vec::with_capacity(planes.len());
        for (p, a) in planes.iter().zip(alpha) {
            ensure!(p.len() == total, "plane size mismatch");
            let mut rows = Vec::with_capacity(n);
            for j in 0..n {
                let mut bv = BitVec::zeros(k);
                for t in 0..k {
                    if p[t * n + j] < 0.0 {
                        bv.set(t, true);
                    }
                }
                rows.push(bv);
            }
            decrypted.push((rows, a.clone()));
        }
        PlaneStore::from_decrypted(shape, decrypted)
    }

    /// Reduction length (rows of the GEMM right-hand side).
    pub fn k(&self) -> usize {
        self.k
    }

    /// Output channels (columns of the GEMM right-hand side).
    pub fn n(&self) -> usize {
        self.n
    }

    /// Bit planes (the paper's q).
    pub fn q(&self) -> usize {
        self.planes.len()
    }

    /// Words per channel bit row.
    pub fn words_per_row(&self) -> usize {
        self.wpr
    }

    /// Original weight tensor dims.
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// `(kh, kw, ci)` when this is a conv weight (rank-4 HWIO shape).
    pub fn conv_geometry(&self) -> Option<(usize, usize, usize)> {
        if self.shape.len() == 4 {
            Some((self.shape[0], self.shape[1], self.shape[2]))
        } else {
            None
        }
    }

    /// Channel `j`'s packed bit row in plane `p`.
    #[inline]
    pub fn col_bits(&self, p: usize, j: usize) -> &[u64] {
        &self.planes[p].bits[j * self.wpr..(j + 1) * self.wpr]
    }

    /// Plane `p`'s per-channel α.
    #[inline]
    pub fn alpha(&self, p: usize) -> &[f32] {
        &self.planes[p].alpha
    }

    /// Materialize the dense `Σ α_p b_p` matrix (row-major `k × n`) —
    /// reference/oracle use only; the serving path never calls this.
    pub fn reconstruct_dense(&self) -> Vec<f32> {
        let mut w = vec![0.0f32; self.k * self.n];
        for plane in &self.planes {
            for j in 0..self.n {
                let bits = &plane.bits[j * self.wpr..(j + 1) * self.wpr];
                let a = plane.alpha[j];
                for t in 0..self.k {
                    let neg = (bits[t / 64] >> (t % 64)) & 1 == 1;
                    w[t * self.n + j] += if neg { -a } else { a };
                }
            }
        }
        w
    }

    /// Bytes this layer keeps resident in BitPlane mode (bit rows + α).
    pub fn resident_bytes(&self) -> usize {
        self.planes
            .iter()
            .map(|p| p.bits.len() * 8 + p.alpha.len() * 4)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flexor::binarycodes;
    use crate::substrate::prng::Pcg32;

    #[test]
    fn reconstruct_matches_binarycodes() {
        let mut rng = Pcg32::seeded(41);
        let (k, n, q) = (70, 5, 2); // k straddles a word boundary
        let planes: Vec<Vec<f32>> = (0..q)
            .map(|_| {
                (0..k * n)
                    .map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 })
                    .collect()
            })
            .collect();
        let alpha: Vec<Vec<f32>> = (0..q)
            .map(|_| (0..n).map(|_| rng.range_f32(0.1, 1.0)).collect())
            .collect();
        let store = PlaneStore::from_sign_planes(&[k, n], &planes, &alpha).unwrap();
        assert_eq!((store.k(), store.n(), store.q()), (k, n, q));
        assert_eq!(store.words_per_row(), 2);
        let want = binarycodes::reconstruct_dense(&planes, &alpha, n).unwrap();
        let got = store.reconstruct_dense();
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!((g - w).abs() < 1e-6, "elem {i}: {g} vs {w}");
        }
    }

    #[test]
    fn resident_bytes_accounting() {
        let planes = vec![vec![1.0f32; 130 * 3]];
        let alpha = vec![vec![0.5f32; 3]];
        let store =
            PlaneStore::from_sign_planes(&[130, 3], &planes, &alpha).unwrap();
        // 3 channels × ⌈130/64⌉=3 words × 8 bytes + 3 α × 4 bytes
        assert_eq!(store.resident_bytes(), 3 * 3 * 8 + 3 * 4);
        assert!(store.conv_geometry().is_none());
    }

    #[test]
    fn validation() {
        assert!(PlaneStore::from_sign_planes(&[4, 2], &[], &[]).is_err());
        assert!(
            PlaneStore::from_sign_planes(&[4, 2], &[vec![1.0; 8]], &[vec![1.0; 3]])
                .is_err()
        );
        assert!(
            PlaneStore::from_sign_planes(&[4, 2], &[vec![1.0; 7]], &[vec![1.0; 2]])
                .is_err()
        );
    }
}
