//! Activation binarization — the input-side contract of the XNOR engine
//! (DESIGN.md §8).
//!
//! Each im2col row `a` (length `k`) is decomposed greedily into `m`
//! sign/scale planes: `a ≈ Σ_p β_p · h_p` with `h_p ∈ {±1}^k` and
//! `β_p ≥ 0` per **row**. Plane `p` takes `h_p = sign(r)` and
//! `β_p = mean|r|` of the current residual `r` (the L2-optimal scale for
//! those signs, per XNOR-Net), then subtracts `β_p·h_p`. The residual's
//! L2 norm contracts at every step (strictly, unless already zero), so
//! the decomposition is exact for rows whose values share one magnitude
//! (e.g. ±1 inputs ⇒ one plane, β = 1) and converges geometrically for
//! smooth distributions — `m = 8` is the serving default, higher `m`
//! trades popcount passes for fidelity.
//!
//! Everything is per-row, so a row's planes are identical no matter how
//! rows are sharded across threads — binarization never breaks the
//! engine's bit-identical-across-thread-counts guarantee.
//!
//! Buffer lifecycle: the packed u64 plane buffer and the f32 scale
//! buffer come from the per-thread scratch arena
//! ([`scratch::take_u64`](super::super::gemm::scratch) / `take`) and go
//! back via [`BinarizedActs::recycle`] — a forward pass reuses the same
//! activation-plane storage instead of allocating per layer. Arena
//! buffers arrive dirty; each shard zeroes its own slice before packing.

use crate::substrate::pool::{SendPtr, ThreadPool};

use super::super::gemm::{scratch, ROWS_PER_SHARD};

/// Upper bound on activation planes (beyond ~24 the residual is at f32
/// noise level; the cap keeps `bitplane:<m>` CLI input sane).
pub const MAX_ACT_PLANES: usize = 32;

/// The serving default: ~0.6^8 ≈ 2% residual L2 on smooth activations.
pub const DEFAULT_ACT_PLANES: usize = 8;

/// A batch of binarized rows: per row, `m` packed sign planes + scales.
pub struct BinarizedActs {
    rows: usize,
    k: usize,
    /// Words per row plane: `⌈k/64⌉`.
    wpr: usize,
    m: usize,
    /// `bits[((i·m)+p)·wpr ..][w]` — row `i`, plane `p` (bit 1 ⇔ −1;
    /// padding bits past `k` are zero).
    bits: Vec<u64>,
    /// `scales[i·m + p]` = row `i`'s β_p (0 ⇒ plane unused).
    scales: Vec<f32>,
}

impl BinarizedActs {
    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn k(&self) -> usize {
        self.k
    }

    /// Activation planes per row.
    pub fn planes(&self) -> usize {
        self.m
    }

    pub fn words_per_row(&self) -> usize {
        self.wpr
    }

    /// Row `i`'s packed sign bits for plane `p`.
    #[inline]
    pub fn row_bits(&self, i: usize, p: usize) -> &[u64] {
        let base = (i * self.m + p) * self.wpr;
        &self.bits[base..base + self.wpr]
    }

    /// Row `i`'s β_p.
    #[inline]
    pub fn scale(&self, i: usize, p: usize) -> f32 {
        self.scales[i * self.m + p]
    }

    /// Return the plane/scale buffers to the current thread's scratch
    /// arena so the next binarize (or any other taker) reuses them.
    pub fn recycle(self) {
        scratch::give_u64(self.bits);
        scratch::give(self.scales);
    }

    /// Dequantize back to dense rows (`rows × k`) — the oracle for
    /// equivalence tests; serving never calls this.
    pub fn reconstruct(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.rows * self.k];
        for i in 0..self.rows {
            let row = &mut out[i * self.k..(i + 1) * self.k];
            for p in 0..self.m {
                let beta = self.scale(i, p);
                if beta == 0.0 {
                    continue;
                }
                let bits = self.row_bits(i, p);
                for (t, v) in row.iter_mut().enumerate() {
                    let neg = (bits[t / 64] >> (t % 64)) & 1 == 1;
                    *v += if neg { -beta } else { beta };
                }
            }
        }
        out
    }
}

/// Greedily binarize one row: `src` → up to `m` (bits, β) planes written
/// into `bits` (`m·wpr` words, must arrive zeroed) and `scales` (`m`
/// floats, must arrive zeroed). `r` is a scratch residual buffer of
/// length `k`. Stops early once the residual mean is zero or non-finite
/// (remaining planes stay β = 0 ⇒ contribute nothing).
fn binarize_row(src: &[f32], r: &mut [f32], wpr: usize, bits: &mut [u64], scales: &mut [f32]) {
    let k = src.len();
    debug_assert_eq!(r.len(), k);
    debug_assert_eq!(bits.len(), scales.len() * wpr);
    r.copy_from_slice(src);
    for (p, scale) in scales.iter_mut().enumerate() {
        let beta = r.iter().map(|v| v.abs()).sum::<f32>() / k as f32;
        if !(beta > 0.0) || !beta.is_finite() {
            break;
        }
        *scale = beta;
        let pb = &mut bits[p * wpr..(p + 1) * wpr];
        for (t, v) in r.iter_mut().enumerate() {
            if *v < 0.0 {
                pb[t / 64] |= 1 << (t % 64);
                *v += beta;
            } else {
                *v -= beta;
            }
        }
    }
}

/// Binarize `rows` rows of length `k` (row-major in `a`) into `m` planes
/// each, sharded across `pool` by row ranges.
pub fn binarize_rows(
    pool: &ThreadPool,
    a: &[f32],
    rows: usize,
    k: usize,
    m: usize,
) -> BinarizedActs {
    assert_eq!(a.len(), rows * k, "activations are {rows}x{k}");
    assert!(k > 0, "zero-length rows");
    let m = m.clamp(1, MAX_ACT_PLANES);
    let wpr = k.div_ceil(64);
    // arena-recycled (dirty) buffers: each shard zeroes its own slice
    let mut bits = scratch::take_u64(rows * m * wpr);
    let mut scales = scratch::take(rows * m);
    let scales_ptr = SendPtr(scales.as_mut_ptr());
    let row_words = m * wpr;
    pool.run_chunks_mut(&mut bits, ROWS_PER_SHARD * row_words, |_shard, start, part| {
        part.fill(0);
        let row0 = start / row_words;
        let nrows = part.len() / row_words;
        scratch::with(|arena| {
            let mut r = arena.take(k);
            for t in 0..nrows {
                let i = row0 + t;
                // Safety: row ranges are disjoint across shards, so each
                // row's m scales are written by exactly one shard.
                let row_scales = unsafe {
                    std::slice::from_raw_parts_mut(scales_ptr.0.add(i * m), m)
                };
                row_scales.fill(0.0);
                binarize_row(
                    &a[i * k..(i + 1) * k],
                    &mut r,
                    wpr,
                    &mut part[t * row_words..(t + 1) * row_words],
                    row_scales,
                );
            }
            arena.give(r);
        });
    });
    BinarizedActs { rows, k, wpr, m, bits, scales }
}

/// Serial binarize → reconstruct: the dense image of the binarization
/// contract, consumed by the reference forward ("`forward_reference`
/// with binarized activations") and equivalence tests.
pub fn binarize_reconstruct_rows(a: &[f32], rows: usize, k: usize, m: usize) -> Vec<f32> {
    assert_eq!(a.len(), rows * k, "activations are {rows}x{k}");
    assert!(k > 0, "zero-length rows");
    let m = m.clamp(1, MAX_ACT_PLANES);
    let wpr = k.div_ceil(64);
    let mut out = vec![0.0f32; rows * k];
    let mut r = vec![0.0f32; k];
    let mut bits = vec![0u64; m * wpr];
    let mut scales = vec![0.0f32; m];
    for i in 0..rows {
        bits.iter_mut().for_each(|w| *w = 0);
        scales.iter_mut().for_each(|s| *s = 0.0);
        binarize_row(&a[i * k..(i + 1) * k], &mut r, wpr, &mut bits, &mut scales);
        let row = &mut out[i * k..(i + 1) * k];
        for (p, &beta) in scales.iter().enumerate() {
            if beta == 0.0 {
                continue;
            }
            let pb = &bits[p * wpr..(p + 1) * wpr];
            for (t, v) in row.iter_mut().enumerate() {
                let neg = (pb[t / 64] >> (t % 64)) & 1 == 1;
                *v += if neg { -beta } else { beta };
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::prng::Pcg32;

    #[test]
    fn pm1_rows_are_exact_with_one_plane() {
        let mut rng = Pcg32::seeded(5);
        for k in [1usize, 63, 64, 65, 127, 128] {
            let row: Vec<f32> =
                (0..k).map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 }).collect();
            let pool = ThreadPool::new(1);
            let acts = binarize_rows(&pool, &row, 1, k, 4);
            assert_eq!(acts.scale(0, 0), 1.0, "k={k}");
            for p in 1..4 {
                assert_eq!(acts.scale(0, p), 0.0, "k={k} plane {p} should be unused");
            }
            let back = acts.reconstruct();
            assert_eq!(back, row, "±1 row must binarize exactly (k={k})");
        }
    }

    #[test]
    fn residual_error_shrinks_with_planes() {
        let mut rng = Pcg32::seeded(6);
        let k = 200;
        // half-normal-ish (post-ReLU shaped) rows are the hard case
        let row: Vec<f32> = (0..k).map(|_| rng.normal().abs()).collect();
        let norm: f32 = row.iter().map(|v| v * v).sum::<f32>().sqrt();
        let mut last = f32::INFINITY;
        for m in [1usize, 2, 4, 8, 16] {
            let back = binarize_reconstruct_rows(&row, 1, k, m);
            let err: f32 = row
                .iter()
                .zip(&back)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f32>()
                .sqrt();
            assert!(err <= last + 1e-6, "m={m}: error {err} grew from {last}");
            last = err;
            if m == 16 {
                assert!(err < 0.02 * norm, "m=16 residual {err} vs norm {norm}");
            }
        }
    }

    #[test]
    fn sharded_binarize_matches_serial_reconstruct() {
        let mut rng = Pcg32::seeded(7);
        let (rows, k, m) = (150, 70, 5);
        let a: Vec<f32> = (0..rows * k).map(|_| rng.normal()).collect();
        let serial = binarize_reconstruct_rows(&a, rows, k, m);
        for threads in [1usize, 2, 4] {
            let pool = ThreadPool::new(threads);
            let acts = binarize_rows(&pool, &a, rows, k, m);
            assert_eq!(
                acts.reconstruct(),
                serial,
                "threads={threads}: sharded binarize diverged"
            );
        }
    }

    /// Satellite: arena-recycled buffers arrive dirty — poisoned u64
    /// plane words and NaN scales must not leak into the packed planes.
    #[test]
    fn recycled_dirty_buffers_do_not_leak_into_planes() {
        let pool = ThreadPool::new(1); // chunks run inline ⇒ this thread's arena
        let (rows, k, m) = (6, 70, 3);
        let wpr = k.div_ceil(64);
        let mut dirty = scratch::take_u64(rows * m * wpr);
        dirty.iter_mut().for_each(|w| *w = u64::MAX);
        scratch::give_u64(dirty);
        let mut dirty_scales = scratch::take(rows * m);
        dirty_scales.iter_mut().for_each(|v| *v = f32::NAN);
        scratch::give(dirty_scales);

        let mut rng = Pcg32::seeded(8);
        let a: Vec<f32> = (0..rows * k).map(|_| rng.normal()).collect();
        let acts = binarize_rows(&pool, &a, rows, k, m);
        // padding bits past k must still be zero (XOR exactness contract)
        for i in 0..rows {
            for p in 0..m {
                let bits = acts.row_bits(i, p);
                assert_eq!(bits[wpr - 1] >> (k % 64), 0, "row {i} plane {p} padding");
            }
        }
        assert_eq!(
            acts.reconstruct(),
            binarize_reconstruct_rows(&a, rows, k, m),
            "dirty arena buffers leaked into binarization"
        );
        acts.recycle();
    }

    #[test]
    fn zero_and_degenerate_rows() {
        let pool = ThreadPool::new(2);
        let a = vec![0.0f32; 64];
        let acts = binarize_rows(&pool, &a, 1, 64, 3);
        assert!(acts.reconstruct().iter().all(|&v| v == 0.0));
        // NaN rows collapse to zero planes instead of poisoning bits
        let a = vec![f32::NAN; 8];
        let acts = binarize_rows(&pool, &a, 1, 8, 3);
        assert!((0..3).all(|p| acts.scale(0, p) == 0.0));
    }
}
