//! Popcount kernels for the panelized XNOR GEMM (DESIGN.md §9).
//!
//! For ±1 vectors packed LSB-first (bit 1 ⇔ −1), the dot product over
//! `len` lanes is `len − 2·popcount(a ⊕ b)`. This module supplies that
//! primitive at two granularities:
//!
//! * [`popcount_dot`] — one packed pair at a time (the word-at-a-time
//!   form the PR 4 engine used; still the reference and the oracle);
//! * [`panel_dot`] — one activation row against a *channel panel* of
//!   [`NR`](crate::inference::gemm::NR) interleaved weight rows
//!   ([`super::PlaneStore`] layout), returning all NR dots at once.
//!
//! `panel_dot` dispatches over [`Kernel`]:
//!
//! * `Scalar`   — portable `u64::count_ones`, one word-row per step;
//! * `Unrolled` — 4 word-rows per step with independent accumulators
//!   (breaks the POPCNT dependency chain on x86, auto-vectorizes
//!   elsewhere);
//! * `Avx2`     — `vpshufb` nibble-LUT popcount (Muła) with `vpsadbw`
//!   lane reduction, 4 channels per 256-bit vector, guarded by
//!   `is_x86_feature_detected!` at dispatch.
//!
//! Every kernel returns **exact integer popcounts**, so downstream α/β
//! FP accumulation sees identical operands no matter the kernel —
//! results are bit-identical across `Scalar`/`Unrolled`/`Avx2`, which
//! the property tests assert and the engine's determinism contract
//! relies on.
//!
//! Selection: [`active`] picks the best supported kernel once per
//! process, overridable with `FLEXOR_SIMD=scalar|unrolled|avx2` for A/B
//! benchmarking and [`set_override`] for in-process forcing (benches,
//! tests).

use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::OnceLock;

use anyhow::{bail, Result};

use super::super::gemm::NR;

// The panel layout and the AVX2 kernel (2×4 u64 lanes) assume NR == 8.
const _: () = assert!(NR == 8, "bitslice panels are built for NR == 8");

/// `Σ_t a_t·b_t` for two packed ±1 vectors of `len` bits (bit 1 ⇔ −1):
/// `len − 2·popcount(a ⊕ b)`. Padding bits past `len` must be zero in
/// both operands (they then XOR to zero and drop out of the count).
///
/// # Examples
///
/// ```
/// use flexor::inference::bitslice::popcount_dot;
///
/// // a = [+1, +1, −1], b = [+1, −1, −1]  (LSB-first, bit 1 ⇔ −1)
/// let a = [0b100u64];
/// let b = [0b110u64];
/// assert_eq!(popcount_dot(&a, &b, 3), 1); // 1·1 + 1·(−1) + (−1)·(−1)
/// ```
#[inline]
pub fn popcount_dot(a: &[u64], b: &[u64], len: usize) -> i64 {
    let words = len.div_ceil(64);
    debug_assert!(a.len() >= words && b.len() >= words);
    let mut pc = 0u32;
    for w in 0..words {
        pc += (a[w] ^ b[w]).count_ones();
    }
    len as i64 - 2 * pc as i64
}

/// Which `panel_dot` implementation runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kernel {
    /// Portable `u64::count_ones`, one word-row per step.
    Scalar,
    /// 4 word-rows per step, independent accumulators.
    Unrolled,
    /// `vpshufb` nibble-LUT popcount; requires AVX2 (runtime-detected).
    Avx2,
}

impl Kernel {
    /// Short name for bench records, log lines and `FLEXOR_SIMD`.
    pub fn label(&self) -> &'static str {
        match self {
            Kernel::Scalar => "scalar",
            Kernel::Unrolled => "unrolled",
            Kernel::Avx2 => "avx2",
        }
    }

    /// Can this kernel run on the current CPU?
    pub fn is_supported(&self) -> bool {
        match self {
            Kernel::Scalar | Kernel::Unrolled => true,
            Kernel::Avx2 => avx2_supported(),
        }
    }

    /// Parse a `FLEXOR_SIMD` value.
    pub fn parse(s: &str) -> Result<Kernel> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Ok(Kernel::Scalar),
            "unrolled" => Ok(Kernel::Unrolled),
            "avx2" | "simd" => Ok(Kernel::Avx2),
            other => bail!("unknown SIMD kernel {other:?} (want scalar | unrolled | avx2)"),
        }
    }
}

fn avx2_supported() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        is_x86_feature_detected!("avx2")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// Every kernel the current CPU can run, in escalation order
/// (`Scalar` first, the widest SIMD last).
pub fn available() -> Vec<Kernel> {
    [Kernel::Scalar, Kernel::Unrolled, Kernel::Avx2]
        .into_iter()
        .filter(Kernel::is_supported)
        .collect()
}

/// In-process override (0 = none, else kernel code + 1) — see
/// [`set_override`].
static OVERRIDE: AtomicU8 = AtomicU8::new(0);
/// The auto-selected kernel, resolved once per process.
static DETECTED: OnceLock<Kernel> = OnceLock::new();

fn code(k: Kernel) -> u8 {
    match k {
        Kernel::Scalar => 1,
        Kernel::Unrolled => 2,
        Kernel::Avx2 => 3,
    }
}

/// The kernel [`panel_dot`] callers should use: an in-process
/// [`set_override`] wins, else `FLEXOR_SIMD` (when set to a kernel this
/// CPU supports), else the best supported kernel (`Avx2` where
/// detected, `Unrolled` otherwise).
pub fn active() -> Kernel {
    match OVERRIDE.load(Ordering::Relaxed) {
        1 => Kernel::Scalar,
        2 => Kernel::Unrolled,
        3 => Kernel::Avx2,
        _ => *DETECTED.get_or_init(detect),
    }
}

/// Pin the process-wide kernel (`Some`) or return to auto selection
/// (`None`). Refuses unsupported kernels (returns `false`). Bench/test
/// hook: because every kernel is bit-identical, flipping this mid-serve
/// can change speed but never results.
pub fn set_override(k: Option<Kernel>) -> bool {
    match k {
        Some(k) if !k.is_supported() => false,
        Some(k) => {
            OVERRIDE.store(code(k), Ordering::Relaxed);
            true
        }
        None => {
            OVERRIDE.store(0, Ordering::Relaxed);
            true
        }
    }
}

/// Cumulative XNOR-GEMM dispatches per kernel (indexed by `code - 1`).
/// Bumped once per GEMM call, not per `panel_dot`, so the counter never
/// contends on the inner-loop cache lines.
static DISPATCHES: [AtomicU64; 3] =
    [AtomicU64::new(0), AtomicU64::new(0), AtomicU64::new(0)];

/// Record one XNOR-GEMM dispatch through `kernel` (called by
/// `bitslice::gemm` at GEMM granularity).
pub fn count_dispatch(kernel: Kernel) {
    DISPATCHES[code(kernel) as usize - 1].fetch_add(1, Ordering::Relaxed);
}

/// Cumulative dispatch counts for every kernel (including never-used
/// ones, so exposition rows are stable), in `[Scalar, Unrolled, Avx2]`
/// order.
pub fn dispatch_counts() -> Vec<(Kernel, u64)> {
    [Kernel::Scalar, Kernel::Unrolled, Kernel::Avx2]
        .into_iter()
        .map(|k| (k, DISPATCHES[code(k) as usize - 1].load(Ordering::Relaxed)))
        .collect()
}

fn detect() -> Kernel {
    let best = if avx2_supported() { Kernel::Avx2 } else { Kernel::Unrolled };
    match std::env::var("FLEXOR_SIMD") {
        Ok(v) if !v.trim().is_empty() => match Kernel::parse(&v) {
            Ok(k) if k.is_supported() => k,
            Ok(k) => {
                eprintln!(
                    "FLEXOR_SIMD={} unsupported on this CPU; using {}",
                    k.label(),
                    best.label()
                );
                best
            }
            Err(e) => {
                eprintln!("ignoring FLEXOR_SIMD: {e}");
                best
            }
        },
        _ => best,
    }
}

/// Dot one packed activation row against one channel panel: `out[jj]` is
/// the ±1 dot product of `abits` with panel channel `jj` over `k` lanes.
///
/// `panel` is the [`super::PlaneStore`] interleaved layout —
/// `panel[w·NR + jj]` holds word `w` of channel `jj` — with zeroed
/// padding (bits past `k`, channels past the live width). Lanes past the
/// live channel width return garbage the caller discards.
///
/// Exactness contract: every kernel returns the same integers.
#[inline]
pub fn panel_dot(kernel: Kernel, abits: &[u64], panel: &[u64], k: usize) -> [i64; NR] {
    let words = k.div_ceil(64);
    // real asserts, not debug_asserts: the AVX2 arm reads through raw
    // pointers, so this length check is what keeps the safe API sound
    assert!(abits.len() >= words, "activation row too short");
    assert!(panel.len() >= words * NR, "panel too short");
    match kernel {
        Kernel::Scalar => panel_dot_scalar(abits, panel, words, k),
        Kernel::Unrolled => panel_dot_unrolled(abits, panel, words, k),
        #[cfg(target_arch = "x86_64")]
        // Safety: dispatch is gated on runtime AVX2 detection.
        Kernel::Avx2 if avx2_supported() => unsafe {
            avx2::panel_dot(abits, panel, words, k)
        },
        Kernel::Avx2 => panel_dot_unrolled(abits, panel, words, k),
    }
}

#[inline]
fn finish(pc: [u32; NR], k: usize) -> [i64; NR] {
    let mut out = [0i64; NR];
    for j in 0..NR {
        out[j] = k as i64 - 2 * pc[j] as i64;
    }
    out
}

fn panel_dot_scalar(abits: &[u64], panel: &[u64], words: usize, k: usize) -> [i64; NR] {
    let mut pc = [0u32; NR];
    for w in 0..words {
        let a = abits[w];
        let row = &panel[w * NR..(w + 1) * NR];
        for j in 0..NR {
            pc[j] += (a ^ row[j]).count_ones();
        }
    }
    finish(pc, k)
}

fn panel_dot_unrolled(abits: &[u64], panel: &[u64], words: usize, k: usize) -> [i64; NR] {
    let mut pc = [0u32; NR];
    let mut w = 0usize;
    while w + 4 <= words {
        let (a0, a1, a2, a3) = (abits[w], abits[w + 1], abits[w + 2], abits[w + 3]);
        let rows = &panel[w * NR..(w + 4) * NR];
        for j in 0..NR {
            pc[j] += (a0 ^ rows[j]).count_ones()
                + (a1 ^ rows[NR + j]).count_ones()
                + (a2 ^ rows[2 * NR + j]).count_ones()
                + (a3 ^ rows[3 * NR + j]).count_ones();
        }
        w += 4;
    }
    while w < words {
        let a = abits[w];
        let row = &panel[w * NR..(w + 1) * NR];
        for j in 0..NR {
            pc[j] += (a ^ row[j]).count_ones();
        }
        w += 1;
    }
    finish(pc, k)
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::NR;
    use std::arch::x86_64::*;

    /// Per-64-bit-lane popcount of a 256-bit vector: `vpshufb` nibble
    /// LUT (Muła) for byte counts, `vpsadbw` to fold each 8-byte group
    /// into its u64 lane. Byte counts are ≤ 8 and lane sums ≤ 64 — no
    /// overflow anywhere.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn popcnt_epi64(v: __m256i) -> __m256i {
        let lut = _mm256_setr_epi8(
            0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4, //
            0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4,
        );
        let low = _mm256_set1_epi8(0x0f);
        let lo = _mm256_and_si256(v, low);
        let hi = _mm256_and_si256(_mm256_srli_epi16::<4>(v), low);
        let cnt =
            _mm256_add_epi8(_mm256_shuffle_epi8(lut, lo), _mm256_shuffle_epi8(lut, hi));
        _mm256_sad_epu8(cnt, _mm256_setzero_si256())
    }

    /// # Safety
    ///
    /// Caller must ensure AVX2 is available, `abits.len() >= words` and
    /// `panel.len() >= words * NR`.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn panel_dot(
        abits: &[u64],
        panel: &[u64],
        words: usize,
        k: usize,
    ) -> [i64; NR] {
        // channels 0..4 in acc0, 4..8 in acc1 — one u64 count per lane
        let mut acc0 = _mm256_setzero_si256();
        let mut acc1 = _mm256_setzero_si256();
        let p = panel.as_ptr();
        for (w, &aw) in abits.iter().enumerate().take(words) {
            let a = _mm256_set1_epi64x(aw as i64);
            let b0 = _mm256_loadu_si256(p.add(w * NR) as *const __m256i);
            let b1 = _mm256_loadu_si256(p.add(w * NR + 4) as *const __m256i);
            acc0 = _mm256_add_epi64(acc0, popcnt_epi64(_mm256_xor_si256(a, b0)));
            acc1 = _mm256_add_epi64(acc1, popcnt_epi64(_mm256_xor_si256(a, b1)));
        }
        let mut pc = [0i64; NR];
        _mm256_storeu_si256(pc.as_mut_ptr() as *mut __m256i, acc0);
        _mm256_storeu_si256(pc.as_mut_ptr().add(4) as *mut __m256i, acc1);
        let mut out = [0i64; NR];
        for j in 0..NR {
            out[j] = k as i64 - 2 * pc[j];
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::prng::Pcg32;

    /// Random packed operands with zeroed padding past `k` — the layout
    /// invariant both `PlaneStore` and `BinarizedActs` maintain.
    fn random_packed(rng: &mut Pcg32, words: usize, k: usize, lanes: usize) -> Vec<u64> {
        let mask_last = if k % 64 == 0 { u64::MAX } else { (1u64 << (k % 64)) - 1 };
        (0..words * lanes)
            .map(|i| {
                let w = i / lanes;
                let v = rng.next_u64();
                if w + 1 == words {
                    v & mask_last
                } else {
                    v
                }
            })
            .collect()
    }

    /// Satellite: every supported kernel returns bit-identical panel
    /// dots at K values straddling u64 word boundaries, and lane 0
    /// agrees with the pairwise word-at-a-time `popcount_dot`.
    #[test]
    fn kernels_agree_at_word_boundaries() {
        let mut rng = Pcg32::seeded(21);
        let kernels = available();
        assert!(kernels.contains(&Kernel::Scalar) && kernels.contains(&Kernel::Unrolled));
        for k in [1usize, 63, 64, 65, 127, 128, 1000] {
            let words = k.div_ceil(64);
            for _ in 0..6 {
                let abits = random_packed(&mut rng, words, k, 1);
                let panel = random_packed(&mut rng, words, k, NR);
                let want = panel_dot(Kernel::Scalar, &abits, &panel, k);
                for jj in 0..NR {
                    let col: Vec<u64> = (0..words).map(|w| panel[w * NR + jj]).collect();
                    assert_eq!(
                        want[jj],
                        popcount_dot(&abits, &col, k),
                        "scalar panel lane {jj} vs pairwise (k={k})"
                    );
                }
                for kern in &kernels {
                    assert_eq!(
                        panel_dot(*kern, &abits, &panel, k),
                        want,
                        "kernel {} diverged from scalar at k={k}",
                        kern.label()
                    );
                }
            }
        }
    }

    #[test]
    fn parse_and_labels() {
        assert_eq!(Kernel::parse("scalar").unwrap(), Kernel::Scalar);
        assert_eq!(Kernel::parse(" AVX2 ").unwrap(), Kernel::Avx2);
        assert_eq!(Kernel::parse("unrolled").unwrap(), Kernel::Unrolled);
        assert!(Kernel::parse("neon").is_err());
        assert_eq!(Kernel::Unrolled.label(), "unrolled");
        assert!(Kernel::Scalar.is_supported());
    }

    #[test]
    fn override_round_trip() {
        // Kernels are bit-identical, so flipping the override is safe
        // even while other tests run forwards concurrently.
        assert!(set_override(Some(Kernel::Scalar)));
        assert_eq!(active(), Kernel::Scalar);
        assert!(set_override(None));
        let auto = active();
        assert!(auto.is_supported());
        assert_ne!(auto, Kernel::Scalar, "auto selection should beat scalar");
    }

    #[test]
    fn padded_lanes_do_not_disturb_live_ones() {
        // zero channel words (padding channels) yield k − 2·pc(a) in
        // their lane; live lanes are unaffected
        let k = 70;
        let words = k.div_ceil(64);
        let mut rng = Pcg32::seeded(9);
        let abits = random_packed(&mut rng, words, k, 1);
        let mut panel = random_packed(&mut rng, words, k, NR);
        for w in 0..words {
            for jj in 5..NR {
                panel[w * NR + jj] = 0; // channels 5.. are padding
            }
        }
        let dots = panel_dot(Kernel::Unrolled, &abits, &panel, k);
        for jj in 0..5 {
            let col: Vec<u64> = (0..words).map(|w| panel[w * NR + jj]).collect();
            assert_eq!(dots[jj], popcount_dot(&abits, &col, k));
        }
    }
}
