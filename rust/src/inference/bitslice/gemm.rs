//! The XNOR/popcount GEMM over panelized bit-planes (DESIGN.md §8/§9).
//!
//! For ±1 vectors packed LSB-first (bit 1 ⇔ −1), the dot product over
//! `len` lanes is `len − 2·popcount(a ⊕ b)` — 64 multiply-accumulates
//! per XOR+POPCNT word pair, zero FP multiplies in the reduction. A
//! quantized layer output is then pure α/β algebra over those integer
//! counts:
//!
//! ```text
//! y[i][j] = Σ_m β_m[i] · Σ_p α_p[j] · ( k − 2·pc(h_m[i] ⊕ b_p[j]) )
//! ```
//!
//! with `h_m` the activation sign planes ([`super::binarize`]) and `b_p`
//! the weight bit planes ([`super::PlaneStore`]). The inner product runs
//! NR channels at a time through [`super::popcount::panel_dot`] — the
//! runtime-dispatched scalar/unrolled/AVX2 kernel over the interleaved
//! channel panels — row-sharded across the substrate pool exactly like
//! the packed-FP engine and finished by the **same** [`Epilogue`] fusion
//! contract (`gemm::store_tile`), so bias / eval-BN / ReLU / residual
//! fuse into the output tile here too.
//!
//! Determinism: each output element is produced by one shard with a
//! fixed (plane, word) accumulation order, shard boundaries depend only
//! on the constant shard size, and every popcount kernel returns the
//! same exact integers — results are bit-identical across thread counts
//! **and** across `Kernel::{Scalar, Unrolled, Avx2}`.

use crate::substrate::pool::ThreadPool;
use crate::substrate::trace;

use super::super::gemm::{self, scratch, Epilogue, MR, NR, ROWS_PER_SHARD};
use super::super::tensor::{self, Tensor};
use super::binarize::{self, BinarizedActs};
use super::plane::PlaneStore;
use super::popcount::{self, Kernel};

pub use super::popcount::popcount_dot;

/// `C = epilogue(Â · W)` on the process-wide popcount kernel
/// ([`popcount::active`]). `Â` is binarized activations, `W` a
/// panelized bit-plane store; `c` is (rows × n) fully overwritten and
/// row blocks are sharded across `pool`.
pub fn xnor_gemm_into(
    pool: &ThreadPool,
    acts: &BinarizedActs,
    w: &PlaneStore,
    epi: Epilogue<'_>,
    c: &mut [f32],
) {
    xnor_gemm_into_with_kernel(pool, acts, w, popcount::active(), epi, c)
}

/// [`xnor_gemm_into`] with an explicit popcount kernel — the A/B seam
/// for benches and the kernel-equivalence property tests (all kernels
/// are bit-identical, so this only ever changes speed).
pub fn xnor_gemm_into_with_kernel(
    pool: &ThreadPool,
    acts: &BinarizedActs,
    w: &PlaneStore,
    kernel: Kernel,
    epi: Epilogue<'_>,
    c: &mut [f32],
) {
    let k = w.k();
    let n = w.n();
    assert_eq!(acts.k(), k, "activation rows are length {}, W expects {k}", acts.k());
    assert_eq!(c.len(), acts.rows() * n, "C is {}x{n}", acts.rows());
    gemm::validate_epilogue(&epi, n, c.len());
    popcount::count_dispatch(kernel);
    let _s = trace::span("xnor_gemm");
    pool.run_chunks_mut(c, ROWS_PER_SHARD * n, |_shard, start, c_part| {
        let i0 = start / n;
        let prows = c_part.len() / n;
        for t0 in (0..prows).step_by(MR) {
            let mh = (prows - t0).min(MR);
            for j0 in (0..n).step_by(NR) {
                let jw = (n - j0).min(NR);
                let mut acc = [[0.0f32; NR]; MR];
                for (r, acc_row) in acc.iter_mut().enumerate().take(mh) {
                    let i = i0 + t0 + r;
                    for p in 0..w.q() {
                        let alpha = &w.alpha(p)[j0..j0 + jw];
                        let panel = w.panel(p, j0 / NR);
                        for m in 0..acts.planes() {
                            let beta = acts.scale(i, m);
                            if beta == 0.0 {
                                continue;
                            }
                            let dots =
                                popcount::panel_dot(kernel, acts.row_bits(i, m), panel, k);
                            for (jj, av) in acc_row.iter_mut().enumerate().take(jw) {
                                *av += beta * alpha[jj] * dots[jj] as f32;
                            }
                        }
                    }
                }
                gemm::store_tile(&acc, c_part, t0, i0, mh, j0, n, &epi);
            }
        }
    });
}

/// Fused `conv2d → epilogue` on the bit-plane engine: im2col into a
/// recycled scratch buffer (sharded like the packed-FP path), binarize
/// the rows into `act_planes` sign/scale planes, one XNOR GEMM with the
/// epilogue applied in-tile. The weight never exists as dense FP, and
/// the activation plane buffers recycle through the per-thread arena.
pub fn conv2d_bitplane(
    pool: &ThreadPool,
    x: &Tensor,
    w: &PlaneStore,
    stride: usize,
    act_planes: usize,
    epi: Epilogue<'_>,
) -> Tensor {
    let (kh, kw, ci) = w
        .conv_geometry()
        .expect("conv2d_bitplane needs a rank-4 HWIO plane store");
    assert_eq!(x.rank(), 4, "conv input must be NHWC");
    assert_eq!(x.dims[3], ci, "channel mismatch");
    let n_im = x.dims[0];
    let dims = (n_im, x.dims[1], x.dims[2], ci);
    let (ho, wo, _, _) =
        tensor::conv_out_geometry((x.dims[1], x.dims[2]), (kh, kw), stride);
    let k = kh * kw * ci;
    debug_assert_eq!(w.k(), k);
    let rows = n_im * ho * wo;
    let mut col = scratch::take(rows * k);
    {
        let _s = trace::span("im2col");
        pool.run_chunks_mut(&mut col, ROWS_PER_SHARD * k, |_shard, start, part| {
            tensor::im2col_rows(&x.data, dims, (kh, kw), stride, start / k, part);
        });
    }
    let acts = {
        let _s = trace::span("binarize");
        binarize::binarize_rows(pool, &col, rows, k, act_planes)
    };
    scratch::give(col);
    let mut out = scratch::take(rows * w.n());
    xnor_gemm_into(pool, &acts, w, epi, &mut out);
    acts.recycle();
    Tensor::new(vec![n_im, ho, wo, w.n()], out)
}

/// Fused `dense → epilogue` on the bit-plane engine: x (N, In) rows are
/// binarized directly (a dense layer's rows *are* its im2col rows).
pub fn dense_bitplane(
    pool: &ThreadPool,
    x: &Tensor,
    w: &PlaneStore,
    act_planes: usize,
    epi: Epilogue<'_>,
) -> Tensor {
    assert_eq!(x.rank(), 2, "dense input must be (N, In)");
    assert_eq!(x.dims[1], w.k(), "dense in-features mismatch");
    let acts = {
        let _s = trace::span("binarize");
        binarize::binarize_rows(pool, &x.data, x.dims[0], x.dims[1], act_planes)
    };
    let mut out = scratch::take(x.dims[0] * w.n());
    xnor_gemm_into(pool, &acts, w, epi, &mut out);
    acts.recycle();
    Tensor::new(vec![x.dims[0], w.n()], out)
}

// ---- reference path (oracle) ------------------------------------------------

/// Reference conv for BitPlane mode: identical im2col + **identical
/// binarization contract**, but dense f32 math over the reconstructed
/// rows and the reconstructed `Σ α_p b_p` weight. No epilogue — callers
/// compose separate passes, mirroring `forward_reference`.
pub fn conv2d_bitplane_reference(
    x: &Tensor,
    w: &PlaneStore,
    stride: usize,
    act_planes: usize,
) -> Tensor {
    let (kh, kw, ci) = w
        .conv_geometry()
        .expect("conv2d_bitplane_reference needs a rank-4 HWIO plane store");
    assert_eq!(x.rank(), 4, "conv input must be NHWC");
    assert_eq!(x.dims[3], ci, "channel mismatch");
    let n_im = x.dims[0];
    let mut col = Vec::new();
    let (rows, k, ho, wo) = tensor::im2col_into(
        &x.data,
        (n_im, x.dims[1], x.dims[2], ci),
        (kh, kw),
        stride,
        &mut col,
    );
    let binz = binarize::binarize_reconstruct_rows(&col, rows, k, act_planes);
    let dense_w = w.reconstruct_dense();
    let out = tensor::gemm(&binz, rows, k, &dense_w, w.n());
    Tensor::new(vec![n_im, ho, wo, w.n()], out)
}

/// Reference dense for BitPlane mode (see [`conv2d_bitplane_reference`]).
pub fn dense_bitplane_reference(x: &Tensor, w: &PlaneStore, act_planes: usize) -> Tensor {
    assert_eq!(x.rank(), 2, "dense input must be (N, In)");
    assert_eq!(x.dims[1], w.k(), "dense in-features mismatch");
    let binz =
        binarize::binarize_reconstruct_rows(&x.data, x.dims[0], x.dims[1], act_planes);
    let out = tensor::gemm(&binz, x.dims[0], x.dims[1], w.reconstruct_dense().as_slice(), w.n());
    Tensor::new(vec![x.dims[0], w.n()], out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flexor::binarycodes::dot_binary;
    use crate::flexor::bitpack::BitVec;
    use crate::substrate::prng::Pcg32;
    use crate::substrate::ptest::check_msg;

    fn close(a: f32, b: f32) -> bool {
        (a - b).abs() <= 1e-3 * (1.0 + b.abs())
    }

    /// Satellite: popcount dot ≡ `binarycodes::dot_binary` on ±1 vectors
    /// at lengths straddling u64 word boundaries.
    #[test]
    fn popcount_dot_matches_dot_binary_at_word_boundaries() {
        let mut rng = Pcg32::seeded(13);
        for len in [1usize, 63, 64, 65, 127, 128] {
            for _ in 0..8 {
                let a_signs: Vec<f32> = (0..len)
                    .map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 })
                    .collect();
                let b_signs: Vec<f32> = (0..len)
                    .map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 })
                    .collect();
                let a_bits = BitVec::from_signs(&a_signs);
                let b_bits = BitVec::from_signs(&b_signs);
                let want = dot_binary(&a_signs, &b_bits);
                let got = popcount_dot(a_bits.words(), b_bits.words(), len);
                assert_eq!(
                    got as f32, want,
                    "len={len}: popcount {got} vs dot_binary {want}"
                );
            }
        }
    }

    /// XNOR GEMM ≡ dense GEMM over the reconstructed binarized rows and
    /// the reconstructed dense weight, across 1/2/4 threads and across
    /// every supported popcount kernel, plus bit-identical results
    /// across all of those.
    #[test]
    fn xnor_gemm_matches_dense_on_binarized_rows_across_threads_and_kernels() {
        let pools = [ThreadPool::new(1), ThreadPool::new(2), ThreadPool::new(4)];
        let kernels = popcount::available();
        check_msg("xnor gemm == dense on binarized rows", 15, |g| {
            let rows = g.usize_in(1, 40);
            let k = g.usize_in(1, 150);
            let n = g.usize_in(1, 20);
            let q = 1 + g.usize_in(0, 2);
            let m = 1 + g.usize_in(0, 5);
            let a: Vec<f32> = (0..rows * k).map(|_| g.normal()).collect();
            let planes: Vec<Vec<f32>> = (0..q)
                .map(|_| {
                    (0..k * n)
                        .map(|_| if g.bool() { 1.0 } else { -1.0 })
                        .collect()
                })
                .collect();
            let alpha: Vec<Vec<f32>> = (0..q)
                .map(|_| (0..n).map(|_| g.f32_in(0.05, 0.5)).collect())
                .collect();
            let store = PlaneStore::from_sign_planes(&[k, n], &planes, &alpha)
                .map_err(|e| e.to_string())?;

            let binz = binarize::binarize_reconstruct_rows(&a, rows, k, m);
            let want = tensor::gemm(&binz, rows, k, &store.reconstruct_dense(), n);

            let mut first: Option<Vec<f32>> = None;
            for pool in &pools {
                let acts = binarize::binarize_rows(pool, &a, rows, k, m);
                for kern in &kernels {
                    let mut c = vec![0.0f32; rows * n];
                    xnor_gemm_into_with_kernel(
                        pool,
                        &acts,
                        &store,
                        *kern,
                        Epilogue::None,
                        &mut c,
                    );
                    for (i, (x, y)) in c.iter().zip(&want).enumerate() {
                        if !close(*x, *y) {
                            return Err(format!(
                                "threads={} kernel={} ({rows}x{k}x{n} q={q} m={m}) elem {i}: {x} vs {y}",
                                pool.threads(),
                                kern.label()
                            ));
                        }
                    }
                    match &first {
                        None => first = Some(c),
                        Some(f) => {
                            if *f != c {
                                return Err(format!(
                                    "threads={} kernel={} changed the bits",
                                    pool.threads(),
                                    kern.label()
                                ));
                            }
                        }
                    }
                }
                acts.recycle();
            }
            Ok(())
        });
    }

    /// The shared epilogue contract holds on the bit-plane engine too:
    /// fused bias/affine/residual ≡ GEMM then separate passes.
    #[test]
    fn epilogues_fuse_identically() {
        let pool = ThreadPool::new(2);
        let mut rng = Pcg32::seeded(77);
        let (rows, k, n) = (9, 70, 5);
        let a: Vec<f32> = (0..rows * k).map(|_| rng.normal()).collect();
        let plane: Vec<f32> = (0..k * n)
            .map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 })
            .collect();
        let alpha: Vec<f32> = (0..n).map(|_| rng.range_f32(0.05, 0.5)).collect();
        let store =
            PlaneStore::from_sign_planes(&[k, n], &[plane], &[alpha]).unwrap();
        let acts = binarize::binarize_rows(&pool, &a, rows, k, 4);

        let mut raw = vec![0.0f32; rows * n];
        xnor_gemm_into(&pool, &acts, &store, Epilogue::None, &mut raw);

        let ea: Vec<f32> = (0..n).map(|_| rng.range_f32(0.5, 1.5)).collect();
        let eb: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let res: Vec<f32> = (0..rows * n).map(|_| rng.normal()).collect();
        let mut fused = vec![0.0f32; rows * n];
        xnor_gemm_into(
            &pool,
            &acts,
            &store,
            Epilogue::AffineAdd { a: &ea, b: &eb, residual: &res, relu: true },
            &mut fused,
        );
        for i in 0..rows * n {
            let v = raw[i] * ea[i % n] + eb[i % n] + res[i];
            let want = if v < 0.0 { 0.0 } else { v };
            assert_eq!(fused[i], want, "elem {i}");
        }
    }

    /// Fused conv on the bit-plane engine ≡ the serial reference
    /// composition (same binarization, dense math).
    #[test]
    fn conv_bitplane_matches_reference() {
        let pool = ThreadPool::new(2);
        check_msg("bitplane conv == reference", 10, |g| {
            let n_im = g.usize_in(1, 3);
            let h = g.usize_in(2, 7);
            let wd = g.usize_in(2, 7);
            let ci = g.usize_in(1, 4);
            let co = g.usize_in(1, 7);
            let kk = [1usize, 3][g.usize_in(0, 2)];
            let stride = 1 + g.usize_in(0, 2);
            let m = 1 + g.usize_in(0, 7);
            let x = Tensor::new(
                vec![n_im, h, wd, ci],
                (0..n_im * h * wd * ci).map(|_| g.normal()).collect(),
            );
            let kdim = kk * kk * ci;
            let plane: Vec<f32> = (0..kdim * co)
                .map(|_| if g.bool() { 1.0 } else { -1.0 })
                .collect();
            let alpha: Vec<f32> = (0..co).map(|_| g.f32_in(0.05, 0.5)).collect();
            let store =
                PlaneStore::from_sign_planes(&[kk, kk, ci, co], &[plane], &[alpha])
                    .map_err(|e| e.to_string())?;
            let got = conv2d_bitplane(&pool, &x, &store, stride, m, Epilogue::None);
            let want = conv2d_bitplane_reference(&x, &store, stride, m);
            if got.dims != want.dims {
                return Err(format!("dims {:?} vs {:?}", got.dims, want.dims));
            }
            for (i, (a, b)) in got.data.iter().zip(&want.data).enumerate() {
                if !close(*a, *b) {
                    return Err(format!("elem {i}: {a} vs {b} (k={kk} s={stride} m={m})"));
                }
            }
            scratch::give(got.data);
            Ok(())
        });
    }
}
