//! Pure-Rust binary-code inference engine (the deployment path of Fig. 1:
//! decrypt stored bits with XOR gates, then compute with binary codes —
//! no Python, no XLA).
//!
//! * [`tensor`] — minimal NHWC f32 tensor ops (conv2d via im2col + blocked
//!   GEMM, maxpool, global avgpool, batchnorm in eval mode, dense, relu);
//! * [`model`]  — rebuilds the model graphs (mlp / lenet5 / resnet family)
//!   from an exported bundle (`.fxr` + FP sidecar) and runs batched
//!   forward passes whose logits match the AOT eval HLO.

pub mod model;
pub mod tensor;

pub use model::InferenceModel;
