//! Pure-Rust binary-code inference engine (the deployment path of Fig. 1:
//! decrypt stored bits with XOR gates, then compute with binary codes —
//! no Python, no XLA).
//!
//! * [`tensor`] — minimal NHWC f32 tensor ops (reference conv2d via
//!   im2col + blocked GEMM, maxpool, global avgpool, batchnorm in eval
//!   mode, dense, relu);
//! * [`gemm`]   — the hot-path compute engine (DESIGN.md §7): weights
//!   packed once at load into cache-aligned panels, a register-blocked
//!   microkernel sharded row-parallel across the substrate thread pool,
//!   epilogues (bias / BN / ReLU / residual) fused into the output tile,
//!   and a per-thread scratch arena for im2col/activation buffers;
//! * [`bitslice`] — the bit-plane XNOR/popcount engine (DESIGN.md §8/§9):
//!   quantized layers stay packed bit-plane *panels* for their whole
//!   serving lifetime, activations are binarized per im2col row into
//!   arena-recycled plane buffers, and dot products run NR channels at a
//!   time through runtime-dispatched popcount kernels
//!   (scalar / unrolled / AVX2, all bit-identical) — dense FP weights
//!   are never materialized in [`ComputeMode::BitPlane`];
//! * [`model`]  — rebuilds the model graphs (mlp / lenet5 / resnet family)
//!   from an exported bundle (`.fxr` + FP sidecar) and runs batched
//!   forward passes whose logits match the AOT eval HLO, with the engine
//!   chosen **per quantized layer** by a [`ModePolicy`] (uniform, or
//!   mixed via weight-count threshold / per-layer overrides).

pub mod bitslice;
pub mod gemm;
pub mod model;
pub mod tensor;

pub use bitslice::{ComputeMode, ModePolicy, PlaneStore};
pub use model::{InferenceModel, LayerMode};
