//! Minimal NHWC f32 tensor ops for the inference engine.
//!
//! Layout conventions match the Python side exactly: activations NHWC,
//! conv weights HWIO, dense (in, out). Conv is im2col + a blocked GEMM
//! (the hot path; see EXPERIMENTS.md §Perf).

/// Dense row-major tensor with explicit dims.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(dims: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len(),
                   "dims {dims:?} vs len {}", data.len());
        Tensor { dims, data }
    }

    pub fn zeros(dims: Vec<usize>) -> Self {
        let n = dims.iter().product();
        Tensor { dims, data: vec![0.0; n] }
    }

    pub fn rank(&self) -> usize {
        self.dims.len()
    }
}

/// `same`-padded stride-s conv: x (N,H,W,Ci) ⊛ w (kh,kw,Ci,Co) → (N,H',W',Co).
pub fn conv2d(x: &Tensor, w: &Tensor, stride: usize) -> Tensor {
    assert_eq!(x.rank(), 4, "conv input must be NHWC");
    assert_eq!(w.rank(), 4, "conv weight must be HWIO");
    let (n, h, wd, ci) = (x.dims[0], x.dims[1], x.dims[2], x.dims[3]);
    let (kh, kw, wci, co) = (w.dims[0], w.dims[1], w.dims[2], w.dims[3]);
    assert_eq!(ci, wci, "channel mismatch");
    let mut col = Vec::new();
    let (rows, k, ho, wo) = im2col_into(&x.data, (n, h, wd, ci), (kh, kw), stride, &mut col);
    // GEMM: (rows × k) · (k × co)
    let out = gemm(&col, rows, k, &w.data, co);
    Tensor::new(vec![n, ho, wo, co], out)
}

/// SAME-padding output geometry shared by all im2col entry points:
/// `(ho, wo, pt, pl)` (matches lax conv with padding="SAME").
pub fn conv_out_geometry(
    (h, wd): (usize, usize),
    (kh, kw): (usize, usize),
    stride: usize,
) -> (usize, usize, usize, usize) {
    let ho = h.div_ceil(stride);
    let wo = wd.div_ceil(stride);
    let pad_h = ((ho - 1) * stride + kh).saturating_sub(h);
    let pad_w = ((wo - 1) * stride + kw).saturating_sub(wd);
    (ho, wo, pad_h / 2, pad_w / 2)
}

/// SAME-padded im2col (matches lax conv with padding="SAME") into a
/// caller-owned buffer so the hot path reuses allocations across requests.
/// `col` is resized to `(n·ho·wo) × (kh·kw·ci)`; its previous contents are
/// irrelevant (padding regions are zero-filled explicitly, everything else
/// is overwritten). Returns `(rows, k, ho, wo)`.
pub fn im2col_into(
    x: &[f32],
    dims: (usize, usize, usize, usize),
    kernel: (usize, usize),
    stride: usize,
    col: &mut Vec<f32>,
) -> (usize, usize, usize, usize) {
    let (n, h, wd, ci) = dims;
    let (ho, wo, _, _) = conv_out_geometry((h, wd), kernel, stride);
    let k = kernel.0 * kernel.1 * ci;
    let rows = n * ho * wo;
    col.resize(rows * k, 0.0);
    im2col_rows(x, dims, kernel, stride, 0, col);
    (rows, k, ho, wo)
}

/// Fill `out.len() / k` consecutive im2col rows starting at global output
/// row `r0` — the shardable core of im2col, so the fused conv path can
/// split one column buffer across the thread pool (disjoint row ranges).
/// Interior patch rows are single contiguous `kw·ci` copies; the
/// in-bounds checks only run on the image border; padding regions are
/// zero-filled explicitly (the buffer need not arrive zeroed).
pub fn im2col_rows(
    x: &[f32],
    (n, h, wd, ci): (usize, usize, usize, usize),
    (kh, kw): (usize, usize),
    stride: usize,
    r0: usize,
    out: &mut [f32],
) {
    assert_eq!(x.len(), n * h * wd * ci, "input length mismatch");
    let (ho, wo, pt, pl) = conv_out_geometry((h, wd), (kh, kw), stride);
    let k = kh * kw * ci;
    debug_assert_eq!(out.len() % k, 0);
    let count = out.len() / k;
    assert!(r0 + count <= n * ho * wo, "row range out of bounds");
    for t in 0..count {
        let r = r0 + t;
        let b = r / (ho * wo);
        let rem = r % (ho * wo);
        let (oy, ox) = (rem / wo, rem % wo);
        let base = t * k;
        let ix0 = (ox * stride) as isize - pl as isize;
        let interior_x = ix0 >= 0 && ix0 + kw as isize <= wd as isize;
        for ky in 0..kh {
            let iy = (oy * stride + ky) as isize - pt as isize;
            let dst = base + ky * kw * ci;
            if iy < 0 || iy >= h as isize {
                // whole padded patch row: one bulk zero-fill
                out[dst..dst + kw * ci].fill(0.0);
                continue;
            }
            let row0 = ((b * h + iy as usize) * wd) as isize;
            if interior_x {
                // fast path: the kw·ci run is contiguous in x
                let src = ((row0 + ix0) as usize) * ci;
                out[dst..dst + kw * ci].copy_from_slice(&x[src..src + kw * ci]);
            } else {
                for kx in 0..kw {
                    let ix = ix0 + kx as isize;
                    let d = dst + kx * ci;
                    if ix < 0 || ix >= wd as isize {
                        out[d..d + ci].fill(0.0);
                    } else {
                        let src = ((row0 + ix) as usize) * ci;
                        out[d..d + ci].copy_from_slice(&x[src..src + ci]);
                    }
                }
            }
        }
    }
}

/// Blocked (cache-tiled) GEMM: a (m×k) row-major · b (k×n) row-major.
pub fn gemm(a: &[f32], m: usize, k: usize, b: &[f32], n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    let mut c = vec![0.0f32; m * n];
    const MB: usize = 32;
    const KB: usize = 64;
    for i0 in (0..m).step_by(MB) {
        for k0 in (0..k).step_by(KB) {
            let i1 = (i0 + MB).min(m);
            let k1 = (k0 + KB).min(k);
            for i in i0..i1 {
                let crow = &mut c[i * n..(i + 1) * n];
                for kk in k0..k1 {
                    let av = a[i * k + kk];
                    if av == 0.0 {
                        continue;
                    }
                    let brow = &b[kk * n..(kk + 1) * n];
                    for (cv, bv) in crow.iter_mut().zip(brow) {
                        *cv += av * bv;
                    }
                }
            }
        }
    }
    c
}

/// 2×2 stride-2 max pool (VALID), matching nn.max_pool defaults.
pub fn max_pool2(x: &Tensor) -> Tensor {
    let (n, h, w, c) = (x.dims[0], x.dims[1], x.dims[2], x.dims[3]);
    let (ho, wo) = (h / 2, w / 2);
    let mut out = Tensor::zeros(vec![n, ho, wo, c]);
    for b in 0..n {
        for oy in 0..ho {
            for ox in 0..wo {
                for ch in 0..c {
                    let mut m = f32::NEG_INFINITY;
                    for dy in 0..2 {
                        for dx in 0..2 {
                            let v = x.data
                                [((b * h + oy * 2 + dy) * w + ox * 2 + dx) * c + ch];
                            m = m.max(v);
                        }
                    }
                    out.data[((b * ho + oy) * wo + ox) * c + ch] = m;
                }
            }
        }
    }
    out
}

/// Global average pool NHWC → (N, C).
pub fn avg_pool_global(x: &Tensor) -> Tensor {
    let (n, h, w, c) = (x.dims[0], x.dims[1], x.dims[2], x.dims[3]);
    let mut out = Tensor::zeros(vec![n, c]);
    let scale = 1.0 / (h * w) as f32;
    for b in 0..n {
        for y in 0..h {
            for xx in 0..w {
                for ch in 0..c {
                    out.data[b * c + ch] += x.data[((b * h + y) * w + xx) * c + ch];
                }
            }
        }
    }
    for v in &mut out.data {
        *v *= scale;
    }
    out
}

/// Fold eval-mode batch-norm parameters into `y = a·x + b` form — the
/// single definition shared by the separate-pass [`batch_norm_eval`] and
/// the fused-epilogue path (`model::Bn`), so the two can never diverge.
pub fn bn_fold(scale: &[f32], bias: &[f32], mean: &[f32], var: &[f32],
               eps: f32) -> (Vec<f32>, Vec<f32>) {
    let c = scale.len();
    assert!(bias.len() == c && mean.len() == c && var.len() == c,
            "BN parameter lengths must agree");
    let a: Vec<f32> = (0..c).map(|i| scale[i] / (var[i] + eps).sqrt()).collect();
    let b: Vec<f32> = (0..c).map(|i| bias[i] - mean[i] * a[i]).collect();
    (a, b)
}

/// Eval-mode batch norm over the last axis.
pub fn batch_norm_eval(x: &mut Tensor, scale: &[f32], bias: &[f32],
                       mean: &[f32], var: &[f32], eps: f32) {
    let c = *x.dims.last().unwrap();
    assert!(scale.len() == c && bias.len() == c && mean.len() == c && var.len() == c);
    let (a, b) = bn_fold(scale, bias, mean, var, eps);
    for (i, v) in x.data.iter_mut().enumerate() {
        let ch = i % c;
        *v = *v * a[ch] + b[ch];
    }
}

pub fn relu(x: &mut Tensor) {
    for v in &mut x.data {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// Dense: x (N, In) · w (In, Out) + b.
pub fn dense(x: &Tensor, w: &Tensor, bias: Option<&[f32]>) -> Tensor {
    let (n, d_in) = (x.dims[0], x.dims[1]);
    let (wi, wo) = (w.dims[0], w.dims[1]);
    assert_eq!(d_in, wi);
    let mut out = gemm(&x.data, n, d_in, &w.data, wo);
    if let Some(b) = bias {
        assert_eq!(b.len(), wo);
        for r in 0..n {
            for c in 0..wo {
                out[r * wo + c] += b[c];
            }
        }
    }
    Tensor::new(vec![n, wo], out)
}

/// Elementwise add (residual connections).
pub fn add_inplace(x: &mut Tensor, y: &Tensor) {
    assert_eq!(x.dims, y.dims);
    for (a, b) in x.data.iter_mut().zip(&y.data) {
        *a += b;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::ptest::check_msg;

    /// Naive direct convolution (reference semantics for the property test).
    fn conv2d_naive(x: &Tensor, w: &Tensor, stride: usize) -> Tensor {
        let (n, h, wd, ci) = (x.dims[0], x.dims[1], x.dims[2], x.dims[3]);
        let (kh, kw, _, co) = (w.dims[0], w.dims[1], w.dims[2], w.dims[3]);
        let ho = h.div_ceil(stride);
        let wo = wd.div_ceil(stride);
        let pad_h = ((ho - 1) * stride + kh).saturating_sub(h);
        let pad_w = ((wo - 1) * stride + kw).saturating_sub(wd);
        let (pt, pl) = (pad_h / 2, pad_w / 2);
        let mut out = Tensor::zeros(vec![n, ho, wo, co]);
        for b in 0..n {
            for oy in 0..ho {
                for ox in 0..wo {
                    for oc in 0..co {
                        let mut acc = 0.0f32;
                        for ky in 0..kh {
                            for kx in 0..kw {
                                let iy = (oy * stride + ky) as isize - pt as isize;
                                let ix = (ox * stride + kx) as isize - pl as isize;
                                if iy < 0 || ix < 0 || iy >= h as isize || ix >= wd as isize {
                                    continue;
                                }
                                for ic in 0..ci {
                                    acc += x.data[((b * h + iy as usize) * wd
                                        + ix as usize) * ci + ic]
                                        * w.data[((ky * kw + kx) * ci + ic) * co + oc];
                                }
                            }
                        }
                        out.data[((b * ho + oy) * wo + ox) * co + oc] = acc;
                    }
                }
            }
        }
        out
    }

    #[test]
    fn conv2d_matches_naive_reference() {
        check_msg("im2col conv == naive conv", 25, |g| {
            let n = g.usize_in(1, 3);
            let h = g.usize_in(2, 9);
            let wd = g.usize_in(2, 9);
            let ci = g.usize_in(1, 4);
            let co = g.usize_in(1, 5);
            let k = [1usize, 3, 5][g.usize_in(0, 3)];
            let stride = 1 + g.usize_in(0, 2);
            let x = Tensor::new(
                vec![n, h, wd, ci],
                (0..n * h * wd * ci).map(|_| g.normal()).collect(),
            );
            let w = Tensor::new(
                vec![k, k, ci, co],
                (0..k * k * ci * co).map(|_| g.normal()).collect(),
            );
            let fast = conv2d(&x, &w, stride);
            let slow = conv2d_naive(&x, &w, stride);
            if fast.dims != slow.dims {
                return Err(format!("dims {:?} vs {:?}", fast.dims, slow.dims));
            }
            for (i, (a, b)) in fast.data.iter().zip(&slow.data).enumerate() {
                if (a - b).abs() > 1e-3 * (1.0 + b.abs()) {
                    return Err(format!("elem {i}: {a} vs {b} (k={k} s={stride})"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn im2col_reused_dirty_buffer_matches_fresh() {
        // padding zero-fill must not depend on the buffer arriving zeroed
        check_msg("im2col into dirty buffer == fresh", 20, |g| {
            let n = g.usize_in(1, 3);
            let h = g.usize_in(2, 7);
            let wd = g.usize_in(2, 7);
            let ci = g.usize_in(1, 3);
            let kk = [1usize, 3, 5][g.usize_in(0, 3)];
            let stride = 1 + g.usize_in(0, 2);
            let x: Vec<f32> = (0..n * h * wd * ci).map(|_| g.normal()).collect();
            let mut fresh = Vec::new();
            let fresh_meta =
                im2col_into(&x, (n, h, wd, ci), (kk, kk), stride, &mut fresh);
            let mut dirty = vec![f32::NAN; fresh.len() + 13];
            let dirty_meta =
                im2col_into(&x, (n, h, wd, ci), (kk, kk), stride, &mut dirty);
            if fresh_meta != dirty_meta {
                return Err(format!("meta {fresh_meta:?} vs {dirty_meta:?}"));
            }
            for (i, (a, b)) in fresh.iter().zip(&dirty).enumerate() {
                if a.to_bits() != b.to_bits() {
                    return Err(format!("elem {i}: {a} vs {b}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn gemm_matches_naive() {
        check_msg("blocked gemm == naive", 30, |g| {
            let m = g.usize_in(1, 40);
            let k = g.usize_in(1, 80);
            let n = g.usize_in(1, 40);
            let a: Vec<f32> = (0..m * k).map(|_| g.normal()).collect();
            let b: Vec<f32> = (0..k * n).map(|_| g.normal()).collect();
            let fast = gemm(&a, m, k, &b, n);
            for i in 0..m {
                for j in 0..n {
                    let want: f32 = (0..k).map(|kk| a[i * k + kk] * b[kk * n + j]).sum();
                    let got = fast[i * n + j];
                    if (got - want).abs() > 1e-3 * (1.0 + want.abs()) {
                        return Err(format!("({i},{j}): {got} vs {want}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn gemm_small() {
        // [1 2; 3 4] · [5 6; 7 8] = [19 22; 43 50]
        let c = gemm(&[1.0, 2.0, 3.0, 4.0], 2, 2, &[5.0, 6.0, 7.0, 8.0], 2);
        assert_eq!(c, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn conv_identity_kernel() {
        // 1×1 conv with identity weights = passthrough
        let x = Tensor::new(vec![1, 2, 2, 2],
                            vec![1., 2., 3., 4., 5., 6., 7., 8.]);
        let w = Tensor::new(vec![1, 1, 2, 2], vec![1., 0., 0., 1.]);
        let y = conv2d(&x, &w, 1);
        assert_eq!(y.dims, vec![1, 2, 2, 2]);
        assert_eq!(y.data, x.data);
    }

    #[test]
    fn conv_same_padding_sums() {
        // 3×3 all-ones kernel over all-ones input: interior = 9, corner = 4
        let x = Tensor::new(vec![1, 4, 4, 1], vec![1.0; 16]);
        let w = Tensor::new(vec![3, 3, 1, 1], vec![1.0; 9]);
        let y = conv2d(&x, &w, 1);
        assert_eq!(y.dims, vec![1, 4, 4, 1]);
        assert_eq!(y.data[0], 4.0); // corner
        assert_eq!(y.data[5], 9.0); // interior
    }

    #[test]
    fn conv_stride2_shape() {
        let x = Tensor::zeros(vec![2, 8, 8, 3]);
        let w = Tensor::zeros(vec![3, 3, 3, 5]);
        let y = conv2d(&x, &w, 2);
        assert_eq!(y.dims, vec![2, 4, 4, 5]);
    }

    #[test]
    fn maxpool_and_avgpool() {
        let x = Tensor::new(vec![1, 2, 2, 1], vec![1.0, 5.0, 3.0, 2.0]);
        let m = max_pool2(&x);
        assert_eq!(m.dims, vec![1, 1, 1, 1]);
        assert_eq!(m.data, vec![5.0]);
        let a = avg_pool_global(&x);
        assert_eq!(a.dims, vec![1, 1]);
        assert_eq!(a.data, vec![2.75]);
    }

    #[test]
    fn batchnorm_eval_formula() {
        let mut x = Tensor::new(vec![1, 1, 1, 2], vec![2.0, -1.0]);
        batch_norm_eval(&mut x, &[1.0, 2.0], &[0.5, 0.0], &[1.0, -1.0],
                        &[4.0, 1.0], 0.0);
        // ch0: (2-1)/2*1 + 0.5 = 1.0 ; ch1: (-1 - -1)/1*2 + 0 = 0
        assert!((x.data[0] - 1.0).abs() < 1e-6);
        assert!((x.data[1] - 0.0).abs() < 1e-6);
    }

    #[test]
    fn dense_with_bias() {
        let x = Tensor::new(vec![1, 2], vec![1.0, 2.0]);
        let w = Tensor::new(vec![2, 2], vec![1.0, 0.0, 0.0, 1.0]);
        let y = dense(&x, &w, Some(&[10.0, 20.0]));
        assert_eq!(y.data, vec![11.0, 22.0]);
    }

    #[test]
    fn relu_and_add() {
        let mut x = Tensor::new(vec![2], vec![-1.0, 2.0]);
        relu(&mut x);
        assert_eq!(x.data, vec![0.0, 2.0]);
        add_inplace(&mut x, &Tensor::new(vec![2], vec![1.0, 1.0]));
        assert_eq!(x.data, vec![1.0, 3.0]);
    }
}
