//! Decryption-engine benchmarks — the paper's "negligible overhead" claim
//! (Fig. 1-3, Algorithm 1) quantified on CPU:
//!
//!   * word-parallel vs scalar GF(2) decrypt across (N_in, N_out, N_tap);
//!   * decrypted throughput in Gbit/s and in weights/s;
//!   * FXR container encode/decode;
//!   * binary-code matvec vs dense f32 matvec (the "q multiplies instead
//!     of v" arithmetic).

use flexor::flexor::binarycodes::BinaryCodeMatrix;
use flexor::flexor::bitpack::ColumnBits;
use flexor::flexor::fxr::{Container, Layer, Plane};
use flexor::flexor::{Decryptor, MXor};
use flexor::substrate::bench::{black_box, Bench};
use flexor::substrate::json::Json;
use flexor::substrate::prng::Pcg32;

fn rand_enc(rng: &mut Pcg32, slices: usize, n_in: usize) -> ColumnBits {
    let bits: Vec<u8> = (0..slices * n_in).map(|_| rng.bernoulli(0.5) as u8).collect();
    ColumnBits::from_row_major(&bits, n_in).unwrap()
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let mut b = if quick { Bench::quick() } else { Bench::new() };
    let mut rng = Pcg32::seeded(42);

    println!("# decrypt engine (per-call: 1M weights decoded unless noted)\n");

    for (n_in, n_out, n_tap) in [(8usize, 10usize, Some(2usize)),
                                 (8, 20, Some(2)),
                                 (16, 20, Some(2)),
                                 (8, 10, None)] {
        let slices = 1_000_000 / n_out;
        let mxor = match n_tap {
            Some(t) => MXor::with_ntap(n_out, n_in, t, &mut rng).unwrap(),
            None => MXor::random(n_out, n_in, &mut rng).unwrap(),
        };
        let d = Decryptor::new(mxor);
        let enc = rand_enc(&mut rng, slices, n_in);
        let out_bits = (slices * n_out) as f64;
        let tap = n_tap.map(|t| t.to_string()).unwrap_or("rand".into());
        b.run_with_throughput(
            &format!("decrypt/word-parallel n_in={n_in} n_out={n_out} tap={tap}"),
            Some(out_bits),
            "bit",
            || {
                black_box(d.decrypt_columns(black_box(&enc)).unwrap());
            },
        );
        // scalar engine on 1/10th of the data (it is much slower)
        let enc_small = rand_enc(&mut rng, slices / 10, n_in);
        b.run_with_throughput(
            &format!("decrypt/scalar        n_in={n_in} n_out={n_out} tap={tap}"),
            Some(out_bits / 10.0),
            "bit",
            || {
                black_box(d.decrypt_scalar(black_box(&enc_small)).unwrap());
            },
        );
    }

    println!("\n# decrypt-to-signs (incl. ±1 materialization)\n");
    let mxor = MXor::with_ntap(10, 8, 2, &mut rng).unwrap();
    let d = Decryptor::new(mxor);
    let slices = 100_000;
    let enc = rand_enc(&mut rng, slices, 8);
    b.run_with_throughput(
        "decrypt_to_signs 1M weights",
        Some((slices * 10) as f64),
        "weight",
        || {
            black_box(d.decrypt_to_signs(black_box(&enc), slices * 10).unwrap());
        },
    );

    println!("\n# FXR container\n");
    let mk_layer = |rng: &mut Pcg32, n_weights: usize| {
        let mxor = MXor::with_ntap(10, 8, 2, rng).unwrap();
        let slices = n_weights.div_ceil(10);
        Layer {
            name: "l".into(),
            n_weights,
            c_out: 64,
            planes: vec![Plane {
                mxor,
                alpha: (0..64).map(|_| rng.range_f32(0.1, 0.5)).collect(),
                enc: rand_enc(rng, slices, 8),
            }],
        }
    };
    let mut c = Container::new(Json::Null);
    c.push(mk_layer(&mut rng, 1_000_000)).unwrap();
    let bytes = c.to_bytes();
    println!("(container: 1M weights -> {} bytes stored)", bytes.len());
    b.run_with_throughput("fxr/encode 1M weights", Some(1e6), "weight", || {
        black_box(c.to_bytes());
    });
    b.run_with_throughput("fxr/decode 1M weights", Some(1e6), "weight", || {
        black_box(Container::from_bytes(black_box(&bytes)).unwrap());
    });

    println!("\n# binary-code arithmetic (v=4096, c=256)\n");
    let (v, cc) = (4096usize, 256usize);
    let planes: Vec<Vec<f32>> = (0..1)
        .map(|_| (0..v * cc).map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 }).collect())
        .collect();
    let alpha = vec![(0..cc).map(|_| rng.range_f32(0.1, 0.5)).collect::<Vec<_>>()];
    let bcm = BinaryCodeMatrix::from_planes(v, cc, &planes, &alpha).unwrap();
    let a: Vec<f32> = (0..v).map(|_| rng.normal()).collect();
    let dense: Vec<f32> = planes[0]
        .iter()
        .enumerate()
        .map(|(i, &s)| s * alpha[0][i % cc])
        .collect();
    b.run_with_throughput("matvec/binary-code q=1", Some((v * cc) as f64), "MAC", || {
        black_box(bcm.matvec(black_box(&a)).unwrap());
    });
    b.run_with_throughput("matvec/dense f32 reference", Some((v * cc) as f64), "MAC", || {
        let mut out = vec![0f32; cc];
        for row in 0..v {
            let av = a[row];
            let dr = &dense[row * cc..(row + 1) * cc];
            for (o, w) in out.iter_mut().zip(dr) {
                *o += av * w;
            }
        }
        black_box(out);
    });

    std::fs::create_dir_all("runs").ok();
    std::fs::write("runs/bench_decrypt.json", b.to_json().to_string_pretty()).ok();
    println!("\nwrote runs/bench_decrypt.json");
}
