//! Train-step latency through the PJRT runtime, per artifact: the L3-side
//! cost of one optimizer step (literal marshalling + HLO execution + state
//! readback), plus the marshalling overhead measured separately so the
//! coordinator's share is visible (DESIGN.md §Perf: L3 must not be the
//! bottleneck).

use std::path::Path;

use flexor::coordinator::TrainSession;
use flexor::data::{self, Batcher, Split};
use flexor::runtime::{Manifest, Runtime};
use flexor::substrate::bench::{black_box, Bench};

fn main() {
    let root = Path::new("artifacts");
    if !root.join("manifest.json").exists() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    let quick = std::env::args().any(|a| a == "--quick");
    let mut b = if quick { Bench::quick() } else { Bench::new() };
    let rt = Runtime::cpu().unwrap();
    let man = Manifest::load(root).unwrap();

    for (cfg, dataset) in [
        ("quickstart_mlp", "digits"),
        ("quickstart_mlp_pallas", "digits"),
        ("e2e_resnet14_f08", "shapes32"),
        ("e2e_resnet14_fp", "shapes32"),
    ] {
        if !man.configs.contains_key(cfg) {
            continue;
        }
        let mut session = TrainSession::new(&rt, &man, cfg).unwrap();
        let ds = data::by_name(dataset, 0).unwrap();
        let mut batcher = Batcher::new(ds.as_ref(), Split::Train, session.meta.batch, 1024);
        let (x, y) = batcher.next_batch();
        let bsz = session.meta.batch as f64;
        b.run_with_throughput(
            &format!("train_step/{cfg} (batch {})", session.meta.batch),
            Some(bsz),
            "example",
            || {
                black_box(session.step(&x, &y, 1e-3, 10.0, 0.0).unwrap());
            },
        );
        // data generation cost (L3-side) for one batch
        b.run_with_throughput(
            &format!("datagen/{dataset} (batch {})", session.meta.batch),
            Some(bsz),
            "example",
            || {
                black_box(batcher.next_batch());
            },
        );
        // eval step
        let (ex, ey) = Batcher::eval_set(ds.as_ref(), Split::Test, session.meta.batch);
        b.run_with_throughput(
            &format!("eval_step/{cfg} (batch {})", session.meta.batch),
            Some(bsz),
            "example",
            || {
                black_box(session.eval(&ex, &ey, 10.0, 0.0).unwrap());
            },
        );
    }

    std::fs::create_dir_all("runs").ok();
    std::fs::write("runs/bench_train_step.json", b.to_json().to_string_pretty()).ok();
    println!("\nwrote runs/bench_train_step.json");
}
