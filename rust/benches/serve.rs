//! Serving-path benchmarks — all against a synthetic encrypted bundle,
//! so they run on a fresh checkout (no artifacts / PJRT needed):
//!
//! * admission-queue push + coalescing pop throughput,
//! * batched forward amortization (examples/s at batch 1 / 8 / 32),
//! * end-to-end HTTP predict round-trip on loopback.
//!
//! ```bash
//! cargo bench --bench serve            # full
//! cargo bench --bench serve -- --quick # CI smoke
//! ```

use std::sync::Arc;
use std::time::Duration;

use flexor::coordinator::export_synthetic_mlp_bundle;
use flexor::inference::InferenceModel;
use flexor::serve::{http, BatchQueue, Registry, ServeConfig, Server};
use flexor::substrate::bench::{black_box, merge_bench_history, merge_bench_json, Bench, CaseMeta};
use flexor::substrate::fault::{self, FaultPlan};
use flexor::substrate::json::Json;
use flexor::substrate::pool;
use flexor::substrate::prng::Pcg32;

const D_IN: usize = 16;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let mut b = if quick { Bench::quick() } else { Bench::new() };

    let dir = std::env::temp_dir().join(format!("flexor_serve_bench_{}", std::process::id()));
    export_synthetic_mlp_bundle(&dir, "bench", 11, D_IN, &[64, 32], 10)
        .expect("synthetic bundle");

    // 1. queue: uncontended push + drain in coalesced pops
    let q: Arc<BatchQueue<u64>> = Arc::new(BatchQueue::bounded(4096));
    b.run_with_throughput("queue: push 1024 + pop_batch(32) drain", Some(1024.0), "req", || {
        for i in 0..1024u64 {
            q.try_push(i).unwrap();
        }
        let mut got = 0usize;
        while got < 1024 {
            got += q.pop_batch(32, Duration::ZERO).unwrap().len();
        }
        black_box(got);
    });

    // 2. forward amortization: the reason micro-batching exists
    let model = InferenceModel::load(&dir, "bench").expect("bundle load");
    let threads = pool::global().threads();
    let mut rng = Pcg32::seeded(5);
    let xs: Vec<f32> = (0..32 * D_IN).map(|_| rng.normal()).collect();
    for batch in [1usize, 8, 32] {
        let x = &xs[..batch * D_IN];
        b.run_case(
            &format!("forward mlp batch={batch}"),
            Some(CaseMeta::new("predict_mlp", &format!("{batch}x{D_IN}"), threads)),
            Some(batch as f64),
            "ex",
            || {
                black_box(model.predict(x, batch).unwrap());
            },
        );
    }

    // 3. end-to-end HTTP round-trip (single sequential client: the
    //    per-request floor; concurrency numbers live in the example)
    let mut registry = Registry::new();
    registry.load("bench", &dir, "bench").unwrap();
    let cfg = ServeConfig { max_wait_us: 0, ..ServeConfig::default() };
    let server = Server::start("127.0.0.1:0", registry, cfg).expect("server start");
    let addr = server.local_addr();
    let body = Json::obj(vec![
        ("model", Json::str("bench")),
        ("features", Json::arr(xs[..D_IN].iter().map(|&v| Json::num(v)))),
    ])
    .to_string();
    b.run_case(
        "http POST /predict round-trip",
        Some(CaseMeta::new("http_predict_roundtrip", &format!("1x{D_IN}"), threads)),
        Some(1.0),
        "req",
        || {
            let (status, resp) =
                http::client::request(addr, "POST", "/predict", Some(&body)).unwrap();
            assert_eq!(status, 200, "{resp}");
            black_box(resp);
        },
    );
    // 4. load-shed fast path: a draining server answers a coded 503 +
    //    Retry-After without touching the queue or a worker — the cost
    //    of saying no (DESIGN.md §12)
    server.begin_drain();
    b.run_case(
        "http POST /predict shed (draining 503)",
        Some(CaseMeta::new("http_predict_shed", &format!("1x{D_IN}"), threads)),
        Some(1.0),
        "req",
        || {
            let (status, resp) =
                http::client::request(addr, "POST", "/predict", Some(&body)).unwrap();
            assert_eq!(status, 503, "{resp}");
            black_box(resp);
        },
    );
    server.shutdown();

    // 5. panic containment → recovery: one injected batch panic (coded
    //    500, caught by the worker's catch_unwind), then the first
    //    healthy answer on the same worker — the per-fault recovery cost
    let mut registry = Registry::new();
    registry.load("bench", &dir, "bench").unwrap();
    let cfg = ServeConfig { workers: 1, max_wait_us: 0, ..ServeConfig::default() };
    let server = Server::start("127.0.0.1:0", registry, cfg).expect("server start");
    let addr = server.local_addr();
    b.run_case(
        "panic containment + recovery cycle",
        Some(CaseMeta::new("panic_recovery", "1 worker", threads)),
        Some(1.0),
        "cycle",
        || {
            fault::arm(FaultPlan { panic_shard_p: 1.0, ..FaultPlan::default() });
            let (status, _) =
                http::client::request(addr, "POST", "/predict", Some(&body)).unwrap();
            assert_eq!(status, 500, "injected panic not surfaced");
            fault::disarm();
            let (status, resp) =
                http::client::request(addr, "POST", "/predict", Some(&body)).unwrap();
            assert_eq!(status, 200, "no recovery after disarm: {resp}");
            black_box(resp);
        },
    );
    fault::disarm();
    server.shutdown();

    println!("\n{}", b.to_json().to_string_pretty());
    merge_bench_json(std::path::Path::new("BENCH_infer.json"), "serve", b.to_json())
        .expect("writing BENCH_infer.json");
    merge_bench_history("serve", b.to_json()).expect("writing bench_history snapshot");
    println!("wrote BENCH_infer.json (source=serve, mirrored to bench_history/)");
    std::fs::remove_dir_all(&dir).ok();
}
