//! Serving-path benchmarks — all against a synthetic encrypted bundle,
//! so they run on a fresh checkout (no artifacts / PJRT needed):
//!
//! * admission-queue push + coalescing pop throughput,
//! * batched forward amortization (examples/s at batch 1 / 8 / 32),
//! * end-to-end HTTP predict round-trip on loopback,
//! * keep-alive concurrency: hundreds of persistent connections against
//!   the event-loop front-end vs the thread-per-connection oracle
//!   (`concurrent_connections_*` records, DESIGN.md §14).
//!
//! ```bash
//! cargo bench --bench serve            # full
//! cargo bench --bench serve -- --quick # CI smoke
//! ```

use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use flexor::coordinator::export_synthetic_mlp_bundle;
use flexor::inference::InferenceModel;
use flexor::repo::BundleRepo;
use flexor::serve::{http, BatchQueue, HttpMode, Registry, ServeConfig, Server};
use flexor::substrate::bench::{black_box, merge_bench_history, merge_bench_json, Bench, CaseMeta};
use flexor::substrate::fault::{self, FaultPlan};
use flexor::substrate::json::Json;
use flexor::substrate::pool;
use flexor::substrate::prng::Pcg32;

const D_IN: usize = 16;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let mut b = if quick { Bench::quick() } else { Bench::new() };

    let dir = std::env::temp_dir().join(format!("flexor_serve_bench_{}", std::process::id()));
    export_synthetic_mlp_bundle(&dir, "bench", 11, D_IN, &[64, 32], 10)
        .expect("synthetic bundle");

    // 1. queue: uncontended push + drain in coalesced pops
    let q: Arc<BatchQueue<u64>> = Arc::new(BatchQueue::bounded(4096));
    b.run_with_throughput("queue: push 1024 + pop_batch(32) drain", Some(1024.0), "req", || {
        for i in 0..1024u64 {
            q.try_push(i).unwrap();
        }
        let mut got = 0usize;
        while got < 1024 {
            got += q.pop_batch(32, Duration::ZERO).unwrap().len();
        }
        black_box(got);
    });

    // 2. forward amortization: the reason micro-batching exists
    let model = InferenceModel::load(&dir, "bench").expect("bundle load");
    let threads = pool::global().threads();
    let mut rng = Pcg32::seeded(5);
    let xs: Vec<f32> = (0..32 * D_IN).map(|_| rng.normal()).collect();
    for batch in [1usize, 8, 32] {
        let x = &xs[..batch * D_IN];
        b.run_case(
            &format!("forward mlp batch={batch}"),
            Some(CaseMeta::new("predict_mlp", &format!("{batch}x{D_IN}"), threads)),
            Some(batch as f64),
            "ex",
            || {
                black_box(model.predict(x, batch).unwrap());
            },
        );
    }

    // 3. end-to-end HTTP round-trip (single sequential client: the
    //    per-request floor; concurrency numbers live in the example)
    let registry = Registry::new();
    registry.load("bench", &dir, "bench").unwrap();
    let cfg = ServeConfig { max_wait_us: 0, ..ServeConfig::default() };
    let server = Server::start("127.0.0.1:0", registry, cfg).expect("server start");
    let addr = server.local_addr();
    let body = Json::obj(vec![
        ("model", Json::str("bench")),
        ("features", Json::arr(xs[..D_IN].iter().map(|&v| Json::num(v)))),
    ])
    .to_string();
    b.run_case(
        "http POST /predict round-trip",
        Some(CaseMeta::new("http_predict_roundtrip", &format!("1x{D_IN}"), threads)),
        Some(1.0),
        "req",
        || {
            let (status, resp) =
                http::client::request(addr, "POST", "/predict", Some(&body)).unwrap();
            assert_eq!(status, 200, "{resp}");
            black_box(resp);
        },
    );
    // 4. load-shed fast path: a draining server answers a coded 503 +
    //    Retry-After without touching the queue or a worker — the cost
    //    of saying no (DESIGN.md §12)
    server.begin_drain();
    b.run_case(
        "http POST /predict shed (draining 503)",
        Some(CaseMeta::new("http_predict_shed", &format!("1x{D_IN}"), threads)),
        Some(1.0),
        "req",
        || {
            let (status, resp) =
                http::client::request(addr, "POST", "/predict", Some(&body)).unwrap();
            assert_eq!(status, 503, "{resp}");
            black_box(resp);
        },
    );
    server.shutdown();

    // 5. panic containment → recovery: one injected batch panic (coded
    //    500, caught by the worker's catch_unwind), then the first
    //    healthy answer on the same worker — the per-fault recovery cost
    let registry = Registry::new();
    registry.load("bench", &dir, "bench").unwrap();
    let cfg = ServeConfig { workers: 1, max_wait_us: 0, ..ServeConfig::default() };
    let server = Server::start("127.0.0.1:0", registry, cfg).expect("server start");
    let addr = server.local_addr();
    b.run_case(
        "panic containment + recovery cycle",
        Some(CaseMeta::new("panic_recovery", "1 worker", threads)),
        Some(1.0),
        "cycle",
        || {
            fault::arm(FaultPlan { panic_shard_p: 1.0, ..FaultPlan::default() });
            let (status, _) =
                http::client::request(addr, "POST", "/predict", Some(&body)).unwrap();
            assert_eq!(status, 500, "injected panic not surfaced");
            fault::disarm();
            let (status, resp) =
                http::client::request(addr, "POST", "/predict", Some(&body)).unwrap();
            assert_eq!(status, 200, "no recovery after disarm: {resp}");
            black_box(resp);
        },
    );
    fault::disarm();
    server.shutdown();

    // 6. hot-swap under load: per-request p99 over a steady window vs a
    //    window containing a `POST /models` drain-then-swap (DESIGN.md
    //    §13) — the control plane's latency tax on in-flight traffic
    let repo_root = dir.join("repo");
    let repo = BundleRepo::init(&repo_root, b"bench-repo-key").expect("repo init");
    repo.publish("bench", "v1", &dir, "bench").expect("publish v1");
    repo.publish("bench", "v2", &dir, "bench").expect("publish v2");
    let mut registry = Registry::new();
    registry.set_repo(repo);
    registry.admit_from_repo("bench@v1", false).expect("admit v1");
    let cfg = ServeConfig { max_wait_us: 0, ..ServeConfig::default() };
    let server = Server::start("127.0.0.1:0", registry, cfg).expect("server start");
    let addr = server.local_addr();
    let window = if quick { 100 } else { 400 };
    let measure_window = |swap_at: Option<usize>| -> Vec<f64> {
        let mut lat_ms = Vec::with_capacity(window);
        let mut swapper: Option<thread::JoinHandle<()>> = None;
        for i in 0..window {
            if swap_at == Some(i) {
                swapper = Some(thread::spawn(move || {
                    let (status, resp) = http::client::request(
                        addr,
                        "POST",
                        "/models",
                        Some(r#"{"name":"bench@v2"}"#),
                    )
                    .unwrap();
                    assert_eq!(status, 200, "swap failed: {resp}");
                }));
            }
            let t0 = Instant::now();
            let (status, resp) =
                http::client::request(addr, "POST", "/predict", Some(&body)).unwrap();
            assert_eq!(status, 200, "{resp}");
            lat_ms.push(t0.elapsed().as_secs_f64() * 1e3);
        }
        if let Some(h) = swapper {
            h.join().unwrap();
        }
        lat_ms
    };
    let p99 = |mut v: Vec<f64>| -> f64 {
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v[((v.len() as f64 * 0.99).ceil() as usize).clamp(1, v.len()) - 1]
    };
    let steady_p99_ms = p99(measure_window(None));
    let swap_p99_ms = p99(measure_window(Some(window / 4)));
    println!(
        "hot-swap window: steady p99 {steady_p99_ms:.3} ms, swap-window p99 {swap_p99_ms:.3} ms"
    );
    server.shutdown();

    // 7. concurrency headroom: N persistent keep-alive connections, one
    //    socket per client, measured against both front-end modes. The
    //    event loop holds every socket on one thread; the thread-per-
    //    connection oracle runs at 1/16 the connection count as the
    //    baseline the §14 "10× more connections at equal-or-better p99"
    //    claim is judged against.
    let mut conc_records: Vec<Json> = Vec::new();
    {
        let ev_conns = if quick { 64 } else { 512 };
        let per_conn = if quick { 4 } else { 8 };
        for (mode, conns) in
            [(HttpMode::EventLoop, ev_conns), (HttpMode::Threads, (ev_conns / 16).max(4))]
        {
            let registry = Registry::new();
            registry.load("bench", &dir, "bench").unwrap();
            let cfg = ServeConfig {
                max_wait_us: 0,
                http_mode: Some(mode),
                max_connections: Some(conns * 2),
                ..ServeConfig::default()
            };
            let server = Server::start("127.0.0.1:0", registry, cfg).expect("server start");
            let addr = server.local_addr();
            let t_all = Instant::now();
            let handles: Vec<_> = (0..conns)
                .map(|_| {
                    let body = body.clone();
                    thread::spawn(move || -> Vec<f64> {
                        let mut c = http::client::Conn::connect(addr).expect("connect");
                        let mut lat = Vec::with_capacity(per_conn);
                        for _ in 0..per_conn {
                            let t0 = Instant::now();
                            let (status, resp) =
                                c.request("POST", "/predict", Some(&body)).expect("request");
                            assert_eq!(status, 200, "{resp}");
                            lat.push(t0.elapsed().as_secs_f64() * 1e3);
                        }
                        lat
                    })
                })
                .collect();
            let mut lat: Vec<f64> = Vec::with_capacity(conns * per_conn);
            for h in handles {
                lat.extend(h.join().expect("client thread panicked"));
            }
            let total_s = t_all.elapsed().as_secs_f64();
            let p50_ms = {
                let mut v = lat.clone();
                v.sort_by(|a, b| a.partial_cmp(b).unwrap());
                v[v.len() / 2]
            };
            let p99_ms = p99(lat);
            let rps = (conns * per_conn) as f64 / total_s;
            println!(
                "concurrency {}: {conns} keep-alive conns × {per_conn} req → \
                 p50 {p50_ms:.3} ms, p99 {p99_ms:.3} ms, {rps:.0} req/s",
                mode.label()
            );
            conc_records.push(Json::obj(vec![
                ("name", Json::str(format!("concurrent keep-alive predict ({})", mode.label()))),
                ("op", Json::str("concurrent_connections")),
                ("http_mode", Json::str(mode.label())),
                ("connections", Json::num(conns as f64)),
                ("requests", Json::num((conns * per_conn) as f64)),
                ("concurrent_connections_p50_ms", Json::num(p50_ms)),
                ("concurrent_connections_p99_ms", Json::num(p99_ms)),
                ("throughput_rps", Json::num(rps)),
            ]));
            server.shutdown();
        }
    }

    let mut records = b.to_json().as_arr().unwrap_or_default().to_vec();
    records.push(Json::obj(vec![
        ("name", Json::str("http predict p99 across hot-swap")),
        ("op", Json::str("swap_under_load")),
        ("shape", Json::str(format!("{window}x1x{D_IN}"))),
        ("steady_p99_ms", Json::num(steady_p99_ms)),
        ("swap_under_load_p99_ms", Json::num(swap_p99_ms)),
    ]));
    records.extend(conc_records);
    let records = Json::Arr(records);
    println!("\n{}", records.to_string_pretty());
    merge_bench_json(std::path::Path::new("BENCH_infer.json"), "serve", records.clone())
        .expect("writing BENCH_infer.json");
    merge_bench_history("serve", records).expect("writing bench_history snapshot");
    println!("wrote BENCH_infer.json (source=serve, mirrored to bench_history/)");
    std::fs::remove_dir_all(&dir).ok();
}
