//! End-to-end table smoke bench: runs a micro version of each paper-table
//! experiment (few training steps per point) to verify every artifact the
//! runners need compiles + executes, and reports per-table wall time.
//! The real reproductions live in examples/ (see DESIGN.md §4); this bench
//! is the CI-sized canary for all of them.

use std::path::Path;
use std::time::Instant;

use flexor::coordinator::experiments::{run_spec, RunSpec};
use flexor::coordinator::Schedule;
use flexor::runtime::{Manifest, Runtime};

fn main() {
    let root = Path::new("artifacts");
    if !root.join("manifest.json").exists() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let man = Manifest::load(root).unwrap();

    // one representative point per table/figure
    let points: Vec<(&str, &str, &str)> = vec![
        ("fig4", "fig4_lenet_tap2_ni8_no10", "digits"),
        ("fig5", "fig5_flexor", "shapes32"),
        ("fig7", "sweep_q1_ni8_no20", "shapes32"),
        ("table1", "t1_r8_f08", "shapes32"),
        ("table2", "t2_mixed_19_16_7", "shapes32"),
        ("table3/fig8", "t3_img_f08", "shapes64"),
        ("table5", "sweep_q1_ni8_no10", "shapes32"),
        ("table6/fig16", "sweep_q2_ni8_no10", "shapes32"),
    ];

    println!("{:<14} {:<26} {:>10} {:>10} {:>9}", "table", "artifact", "steps/s", "top1@20", "wall s");
    for (table, artifact, dataset) in points {
        if !man.configs.contains_key(artifact) {
            println!("{table:<14} {artifact:<26} {:>10} (artifact missing — make artifacts SET=full)", "-");
            continue;
        }
        let t0 = Instant::now();
        let spec = RunSpec::new(table, artifact, dataset, 20)
            .schedule(Schedule::cifar(0.05, 0.2, vec![], 100))
            .eval_every(20);
        match run_spec(&rt, &man, &spec) {
            Ok(o) => {
                let wall = t0.elapsed().as_secs_f64();
                println!(
                    "{:<14} {:<26} {:>10.2} {:>9.1}% {:>9.1}",
                    table,
                    artifact,
                    20.0 / wall,
                    100.0 * o.top1_mean,
                    wall
                );
            }
            Err(e) => println!("{table:<14} {artifact:<26} ERROR: {e:#}"),
        }
    }
}
